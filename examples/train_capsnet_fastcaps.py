"""End-to-end driver (deliverable b): train a CapsNet for a few hundred
steps on the synthetic digit set, run the full FastCaps methodology
(LAKP prune -> fine-tune -> compact -> optimized routing), and report
accuracy + compression + throughput — the complete paper pipeline.

    PYTHONPATH=src python examples/train_capsnet_fastcaps.py
    PYTHONPATH=src python examples/train_capsnet_fastcaps.py --steps 300
"""

import subprocess
import sys

if __name__ == "__main__":
    args = sys.argv[1:] or ["--steps", "200"]
    cmd = [sys.executable, "-m", "repro.launch.train",
           "--arch", "capsnet-mnist", "--reduced",
           "--prune", "lakp:0.8", "--finetune-steps", "80",
           "--n-train", "512"] + args
    raise SystemExit(subprocess.call(cmd))
