"""End-to-end FastCaps driver on the new ``repro.deploy`` API: train a
CapsNet on the synthetic digit set, run the full Fig. 6 methodology
(LAKP prune -> masked fine-tune -> compact) through ``FastCapsPipeline``,
compile the ``DeployedCapsNet``, and serve the test set through
``CapsuleEngine`` — reporting accuracy, compression, and served FPS.

    PYTHONPATH=src python examples/train_capsnet_fastcaps.py
    PYTHONPATH=src python examples/train_capsnet_fastcaps.py --steps 300
"""

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import capsnet as cn
from repro.core import pruning as pr
from repro.data import synthetic_digits as sd
from repro.deploy import FastCapsPipeline
from repro.optim import AdamWConfig
from repro.serving import CapsuleEngine, ImageRequest
from repro.training import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--finetune-steps", type=int, default=80)
    ap.add_argument("--n-train", type=int, default=512)
    ap.add_argument("--sparsity", type=float, default=0.8)
    ap.add_argument("--routing", default="pallas",
                    choices=["reference", "optimized", "pallas"])
    ap.add_argument("--batch", type=int, default=16)
    args = ap.parse_args()

    cfg = cn.CapsNetConfig(arch_id="fastcaps-demo", conv1_channels=16,
                           caps_types=4, decoder_hidden=(32, 64))
    data = sd.load(sd.DigitsConfig(n_train=args.n_train, n_test=256))
    tr_x, tr_y = data["train"]
    te_x, te_y = data["test"]

    def loss_fn(p, b):
        return cn.loss_fn(p, cfg, b["images"], b["labels"])

    def batches(seed=0):
        for bx, by in sd.batches(tr_x, tr_y, 32, seed, epochs=1000):
            yield {"images": bx, "labels": by}

    # 1. train dense
    tcfg = TrainerConfig(
        optim=AdamWConfig(lr=1e-3, weight_decay=0.0,
                          warmup_steps=max(args.steps // 10, 1),
                          total_steps=args.steps),
        log_every=max(args.steps // 4, 1))
    res = Trainer(tcfg, loss_fn, lambda k: cn.init(cfg, k)).run(
        batches(), args.steps)
    print(f"[{cfg.arch_id}] trained {res.step} steps; "
          f"final: {res.history[-1] if res.history else {}}")

    # 2. FastCapsPipeline: prune -> masked fine-tune -> compact -> compile
    def finetune(masked, masks):
        ft = Trainer(
            TrainerConfig(optim=AdamWConfig(
                lr=3e-4, weight_decay=0.0, warmup_steps=1,
                total_steps=args.finetune_steps)),
            loss_fn, lambda k: masked,
            mask_fn=lambda g: pr.mask_gradients(g, masks))
        return ft.run(batches(seed=7), args.finetune_steps).params

    pipe = FastCapsPipeline(cfg, params=res.params)
    pipe.prune(args.sparsity, args.sparsity, method="lakp")
    pipe.finetune(finetune).compact()
    deployed = pipe.compile(routing=args.routing)
    print(f"  compression={pipe.compression:.4f} "
          f"({deployed.cfg.caps_types}/{cfg.caps_types} capsule types, "
          f"{deployed.cfg.n_primary_caps} capsules, "
          f"{deployed.n_params:,} params)")

    # 3. accuracy of the deployed artifact + served throughput
    acc = float(jnp.mean((deployed.classify(te_x) == te_y)))
    engine = CapsuleEngine(deployed, batch_size=args.batch)
    engine.warmup()
    rng = np.random.RandomState(0)
    frames = np.asarray(te_x)
    cuts = np.sort(rng.choice(np.arange(1, len(frames)),
                              size=7, replace=False))
    reqs = [ImageRequest(images=chunk, rid=i)
            for i, chunk in enumerate(np.split(frames, cuts))]
    engine.serve(reqs)
    s = engine.stats()
    print(f"  deployed[{deployed.spec.mode}] test acc: {acc:.4f}; served "
          f"{s.frames} frames in {s.batches} batches: {s.fps:.1f} FPS")


if __name__ == "__main__":
    main()
