"""Serve a (reduced) assigned-architecture LM with batched requests
through the slot-based engine (deliverable b: serving driver).

    PYTHONPATH=src python examples/serve_lm.py
    PYTHONPATH=src python examples/serve_lm.py --arch deepseek-moe-16b
"""

import subprocess
import sys

if __name__ == "__main__":
    args = sys.argv[1:]
    if not any(a.startswith("--arch") for a in args):
        args = ["--arch", "llama3.2-1b"] + args
    cmd = [sys.executable, "-m", "repro.launch.serve",
           "--requests", "6", "--slots", "3", "--max-new", "10"] + args
    raise SystemExit(subprocess.call(cmd))
