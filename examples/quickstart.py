"""Quickstart: the FastCaps pipeline in ~60 lines.

    PYTHONPATH=src python examples/quickstart.py

Builds a CapsNet, scores its kernels with Look-Ahead Kernel Pruning
(paper Algorithm 1), prunes + compacts it, and runs the optimized
(fused-routing + Taylor-softmax) deployment — printing the compression
and agreement between original and optimized predictions.
"""

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import capsnet as cn
from repro.core import pruning as pr

# 1. a CapsNet (Sabour et al. architecture; small for the demo)
cfg = cn.CapsNetConfig(arch_id="quickstart", conv1_channels=32,
                       caps_types=8, decoder_hidden=(64, 128))
params = cn.init(cfg, jax.random.key(0))
print(f"dense CapsNet: {cn.param_count(params):,} params, "
      f"{cfg.n_primary_caps} primary capsules")

# 2. LAKP prune (60% conv1 kernels, 90% conv2 kernels, keep 2/8 capsule
#    types) and physically compact the survivors
res = pr.prune_capsnet(params, cfg, sparsity_conv1=0.6, sparsity_conv2=0.9,
                       method="lakp", type_keep=2)
print(f"pruned: compression={res.compression:.2%}, "
      f"{res.compact_cfg.n_primary_caps} capsules survive, "
      f"{cn.param_count(res.compact_params):,} params, "
      f"index overhead={res.index_overhead_frac:.4%}")

# 3. FastCaps deployment: fused VMEM-resident routing + Eq.2 softmax
dep_cfg = dataclasses.replace(res.compact_cfg, routing_mode="pallas",
                              softmax_mode="taylor")
images = jax.random.uniform(jax.random.key(1), (8, 28, 28, 1))
lengths_ref, _ = cn.forward(res.compact_params, res.compact_cfg, images)
lengths_opt, _ = cn.forward(res.compact_params, dep_cfg, images)
agree = float(jnp.mean((jnp.argmax(lengths_ref, -1)
                        == jnp.argmax(lengths_opt, -1))))
print(f"optimized-vs-reference prediction agreement: {agree:.0%}")
print(f"max |Δ capsule length|: "
      f"{float(jnp.max(jnp.abs(lengths_ref - lengths_opt))):.2e}")
