"""Quickstart: the canonical ``repro.deploy`` pipeline in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py

``FastCapsPipeline`` carries a CapsNet through the paper's Fig. 6
methodology — ``build() -> prune() -> compact() -> compile()`` — and
returns an immutable ``DeployedCapsNet``.  Routing variants are typed
``RoutingSpec``s resolved through the deploy registry (Pallas interpret
mode is probed from the backend, never hand-threaded), and
``deployed.serve(scheduler=...)`` hands the artifact straight to the
async serving engine (``repro.serving``).
"""

import jax
import jax.numpy as jnp

from repro.core import capsnet as cn
from repro.deploy import FastCapsPipeline, RoutingSpec

# 1. a CapsNet pipeline (Sabour et al. architecture; small for the demo)
cfg = cn.CapsNetConfig(arch_id="quickstart", conv1_channels=32,
                      caps_types=8, decoder_hidden=(64, 128))
pipe = FastCapsPipeline(cfg).build(seed=0)
print(f"dense CapsNet: {cn.param_count(pipe.params):,} params, "
      f"{cfg.n_primary_caps} primary capsules")

# 2. LAKP prune (60% conv1 kernels, 90% conv2 kernels, keep 2/8 capsule
#    types) and physically compact the survivors
pipe.prune(sparsity_conv1=0.6, sparsity_conv2=0.9, method="lakp",
           type_keep=2).compact()
print(f"pruned: compression={pipe.compression:.2%}, "
      f"{pipe.cfg.n_primary_caps} capsules survive, "
      f"{cn.param_count(pipe.params):,} params, "
      f"index overhead={pipe.index_overhead_frac:.4%}")

# 3. FastCaps deployment: fused VMEM-resident routing + Eq.2 softmax,
#    compiled against the reference deployment for the agreement check
dep_ref = pipe.compile(routing="reference")
dep_opt = pipe.compile(routing=RoutingSpec.pallas(softmax="taylor"))
images = jax.random.uniform(jax.random.key(1), (8, 28, 28, 1))
lengths_ref = dep_ref.forward(images)
lengths_opt = dep_opt.forward(images)
agree = float(jnp.mean((jnp.argmax(lengths_ref, -1)
                        == jnp.argmax(lengths_opt, -1))))
print(f"optimized-vs-reference prediction agreement: {agree:.0%}")
print(f"max |Δ capsule length|: "
      f"{float(jnp.max(jnp.abs(lengths_ref - lengths_opt))):.2e}")
