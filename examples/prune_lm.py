"""LAKP beyond CapsNet (DESIGN.md §5): structured look-ahead pruning of an
LM's FFN hidden blocks, attention-head groups and MoE experts — the
paper's technique generalized to the assigned architectures.

    PYTHONPATH=src python examples/prune_lm.py
"""

import jax
import jax.numpy as jnp

from repro import configs as cfg_lib
from repro.core import pruning as pr
from repro.data.tokens import TokenStream, TokenStreamConfig
from repro.models import lm

ARCH = "qwen3-1.7b"
cfg = cfg_lib.reduced(cfg_lib.get_config(ARCH))
params = lm.init(cfg, jax.random.key(0))
stream = TokenStream(TokenStreamConfig(vocab=cfg.vocab))
batch = jax.tree.map(jnp.asarray, stream.sample(8, 64, seed=0))

loss0, _ = lm.loss_fn(params, cfg, batch)
print(f"[{ARCH} reduced] dense loss: {float(loss0):.4f}")

# prune 50% of FFN hidden blocks in every layer with look-ahead scores
units = params["units"]
ffn = units["block"]["ffn"]
n_layers = ffn["wi"].shape[0]
masks = []
new_wi, new_wg, new_wo = [], [], []
for layer in range(n_layers):
    layer_p = {k: ffn[k][layer] for k in ("wi", "wg", "wo")}
    pruned, mask = pr.prune_lm_ffn(layer_p, n_blocks=8, sparsity=0.5,
                                   method="lakp")
    new_wi.append(pruned["wi"])
    new_wg.append(pruned["wg"])
    new_wo.append(pruned["wo"])
    masks.append(mask)
ffn_p = dict(ffn, wi=jnp.stack(new_wi), wg=jnp.stack(new_wg),
             wo=jnp.stack(new_wo))
params_p = dict(params)
params_p["units"] = dict(units, block=dict(units["block"], ffn=ffn_p))

loss1, _ = lm.loss_fn(params_p, cfg, batch)
kept = sum(int(m.sum()) for m in masks)
print(f"pruned 50% FFN blocks ({kept}/{n_layers * 8} survive): "
      f"loss {float(loss1):.4f} (untrained net: loss should barely move)")

# attention-head pruning on one layer (KV-group granularity)
attn = {k: units["block"]["attn"][k][0] for k in ("wq", "wk", "wv", "wo")}
pruned_attn, head_mask = pr.prune_lm_heads(
    attn, cfg.n_heads, cfg.n_kv_heads, sparsity=0.5)
print(f"head pruning: {int(head_mask.sum())}/{cfg.n_kv_heads} KV groups "
      f"survive -> KV cache shrinks by "
      f"{(1 - float(head_mask.mean())):.0%} (the PrimaryCaps-elimination "
      f"analogue)")
