from repro.parallel.sharding import (
    AxisRules,
    DEFAULT_RULES,
    logical_to_spec,
    spec_tree_to_shardings,
    shard_constraint,
    rules_for_arch,
)

__all__ = [
    "AxisRules",
    "DEFAULT_RULES",
    "logical_to_spec",
    "spec_tree_to_shardings",
    "shard_constraint",
    "rules_for_arch",
]
