"""Logical-axis sharding: map named tensor axes onto mesh axes.

Every parameter / activation in the framework is annotated with a tuple of
*logical* axis names (one per dimension, ``None`` for replicated dims).  A
rule table maps logical names onto mesh axis names (or tuples of them).  This
is the MaxText/T5X pattern: the model definition never mentions the mesh, so
the same model lowers onto 1-device CPU, a 16x16 single pod, or a 2x16x16
multi-pod mesh purely by swapping the rule table.

Design notes for scale (1000+ nodes):
  * FSDP ("zero-3") is expressed by mapping the ``embed`` logical axis of
    weight matrices onto the ``data`` mesh axis; XLA SPMD then emits
    all-gather on use / reduce-scatter on grad, which the latency-hiding
    scheduler overlaps with layer compute when the layer stack is scanned.
  * Tensor parallelism maps ``mlp`` / ``heads`` / ``vocab`` / ``expert`` onto
    ``model``.
  * The slow cross-pod axis ``pod`` only ever carries batch (pure DP) by
    default, so the only cross-pod collective is the gradient all-reduce.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Mapping, Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MeshAxes = Union[None, str, Tuple[str, ...]]


@dataclasses.dataclass(frozen=True)
class AxisRules:
    """Mapping from logical axis names to mesh axes.

    ``rules`` maps a logical name to a mesh axis name, a tuple of mesh axis
    names (the dim is sharded over their product), or None (replicated).
    Mesh axes that do not exist on the actual mesh are silently dropped so a
    single rule table serves single-pod and multi-pod meshes.
    """

    rules: Mapping[str, MeshAxes]

    def lookup(self, name: Optional[str], mesh_axis_names: Sequence[str]) -> MeshAxes:
        if name is None:
            return None
        axes = self.rules.get(name, None)
        if axes is None:
            return None
        if isinstance(axes, str):
            axes = (axes,)
        present = tuple(a for a in axes if a in mesh_axis_names)
        if not present:
            return None
        if len(present) == 1:
            return present[0]
        return present

    def with_overrides(self, **overrides: MeshAxes) -> "AxisRules":
        merged = dict(self.rules)
        merged.update(overrides)
        return AxisRules(merged)


# The default production rule table.  ``batch`` spans the cross-pod axis and
# the data axis (pure DP over pods, DP+FSDP within a pod); weight ``embed``
# dims are FSDP-sharded over ``data``; model-parallel structures go to
# ``model``.
DEFAULT_RULES = AxisRules(
    {
        # activations
        "batch": ("pod", "data"),
        "seq": None,
        "act_embed": None,
        "act_heads": "model",
        "act_mlp": "model",
        "act_kv_heads": "model",
        "act_expert": "model",
        # weights
        "embed": "data",          # FSDP axis
        "embed_tp": "model",      # used when a weight's embed dim is the TP-reduced dim
        "mlp": "model",
        "heads": "model",
        "kv_heads": "model",
        "qkv_dim": None,
        "head_dim": None,
        "vocab": "model",
        "expert": "model",
        "expert_mlp": None,
        "state": None,
        "conv_in": None,
        "conv_out": "model",
        "caps_in": "data",
        "caps_out": "model",
        "caps_dim": None,
        "layers": None,           # scan-stacked layer axis: never sharded
        # SSM / xLSTM
        "mamba_inner": "model",
        "mamba_conv": "model",
        "mlstm_up": "model",
        "mlstm_inner": "model",
        "slstm_gates": None,
        "head_dim_v": None,       # xlstm TP axis (see rules_for_arch)
        # KV cache: batch claims data first; when batch can't shard (B=1
        # long-context) kv_seq claims data; when kv_heads can't shard
        # (GQA kv < model axis) kv_head_dim claims model.
        "kv_seq": "data",
        "kv_head_dim": "model",
    }
)

# CPU / single-device rules: everything replicated.
REPLICATED_RULES = AxisRules({})


# Small models must not be tensor-parallelised 256 ways: the per-layer
# activation all-reduce (B_loc x S x d) dwarfs the per-chip matmul work
# when d_model is small (§Perf H-A1: xlstm train collective 23.4s vs
# compute 0.93s at TP=16).  Policy: d_model <= 2048 -> pure DP + FSDP
# (batch additionally claims the model axis; weights FSDP over data);
# MoE keeps expert->model (EP without TP).
_NO_TP_OVERRIDES = dict(
    batch=("pod", "data", "model"),
    mlp=None, heads=None, kv_heads=None, vocab=None,
    act_heads=None, act_mlp=None, act_kv_heads=None,
    mamba_inner=None, mamba_conv=None,
    mlstm_up=None, mlstm_inner=None, head_dim_v=None,
    conv_out=None,
    kv_head_dim=None,
)

_NO_TP_ARCHS = ("xlstm-1.3b", "zamba2-1.2b", "qwen3-1.7b", "llama3.2-1b",
                "deepseek-moe-16b", "hubert-xlarge")


def rules_for_arch(arch_id: str, base: AxisRules = DEFAULT_RULES,
                   kind: str = "train") -> AxisRules:
    """Per-architecture, per-step-kind overrides of the default rule table.

    ``kind="decode"`` keeps the default TP/EP rules for every arch: decode
    wants weights STATIONARY (FSDP would all-gather the full model per
    generated token — §Perf iteration C2 refutation: deepseek decode
    memory term 0.38 s -> 6.1 s under no-TP/FSDP rules).
    """
    if kind != "decode" and arch_id in _NO_TP_ARCHS:
        rules = base.with_overrides(**_NO_TP_OVERRIDES)
        if arch_id == "deepseek-moe-16b":
            # EP stays: experts across model; dispatch/combine collectives
            # are the only model-axis traffic.
            rules = rules.with_overrides(expert="model",
                                         act_expert="model")
        return rules
    if arch_id.startswith("capsnet"):
        # CapsNet is small; shard input capsules over data, output capsules
        # over model (the routing contraction reduces over caps_in).
        return base
    return base


def logical_to_spec(
    logical_axes: Sequence[Optional[str]],
    rules: AxisRules,
    mesh_axis_names: Sequence[str],
) -> P:
    """Turn a tuple of logical axis names into a PartitionSpec.

    Guarantees each mesh axis is used at most once (first logical dim wins),
    which is a PartitionSpec validity requirement.
    """
    used: set = set()
    out = []
    for name in logical_axes:
        axes = rules.lookup(name, mesh_axis_names)
        if axes is None:
            out.append(None)
            continue
        tup = (axes,) if isinstance(axes, str) else tuple(axes)
        tup = tuple(a for a in tup if a not in used)
        if not tup:
            out.append(None)
            continue
        used.update(tup)
        out.append(tup[0] if len(tup) == 1 else tup)
    # strip trailing Nones (canonical form)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def spec_tree_to_shardings(
    spec_tree: Any,
    mesh: Mesh,
    rules: AxisRules,
) -> Any:
    """Map a pytree of logical-axis tuples to a pytree of NamedShardings."""
    names = mesh.axis_names

    def _one(axes):
        if isinstance(axes, P):
            return NamedSharding(mesh, axes)
        return NamedSharding(mesh, logical_to_spec(axes, rules, names))

    return jax.tree.map(
        _one, spec_tree, is_leaf=lambda x: isinstance(x, (tuple, P)) or x is None
    )


def spec_tree_to_pspecs(spec_tree: Any, rules: AxisRules, mesh_axis_names) -> Any:
    """Same as above but returns raw PartitionSpecs (for in_shardings args)."""

    def _one(axes):
        if isinstance(axes, P):
            return axes
        return logical_to_spec(axes, rules, mesh_axis_names)

    return jax.tree.map(
        _one, spec_tree, is_leaf=lambda x: isinstance(x, (tuple, P)) or x is None
    )


def shape_aware_spec(
    logical_axes: Sequence[Optional[str]],
    shape: Sequence[int],
    rules: AxisRules,
    mesh_shape: Mapping[str, int],
) -> P:
    """Single-pass shape-aware spec builder (the production policy):

    For each dim, the rule-mapped mesh axes are kept only if (a) not already
    claimed by an earlier dim of this tensor and (b) the dim size is
    divisible by the axes' product.  An axis freed by (b) on one dim remains
    claimable by a later dim — e.g. a decode KV cache (L, B=1, T, K=8, D)
    on (data=16, model=16): batch(1) frees ``data`` which ``kv_seq`` then
    claims, kv_heads(8) frees ``model`` which ``kv_head_dim`` claims."""
    names = list(mesh_shape.keys())
    used: set = set()
    out = []
    for d, name in enumerate(tuple(logical_axes)):
        axes = rules.lookup(name, names)
        if axes is None:
            out.append(None)
            continue
        tup = (axes,) if isinstance(axes, str) else tuple(axes)
        tup = tuple(a for a in tup if a not in used)
        # longest prefix whose product divides the dim
        while tup:
            total = 1
            for a in tup:
                total *= mesh_shape[a]
            if total > 0 and shape[d] % total == 0:
                break
            tup = tup[:-1]
        if not tup:
            out.append(None)
            continue
        used.update(tup)
        out.append(tup[0] if len(tup) == 1 else tup)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def shard_constraint(x, logical_axes, rules: AxisRules):
    """with_sharding_constraint by logical axes; no-op when no mesh is set.

    Uses the shape-aware single-pass policy (indivisible dims replicate)."""
    get_mesh = getattr(jax.sharding, "get_abstract_mesh", None)
    if get_mesh is None:                 # older jax: no ambient-mesh query
        return x
    env_mesh = get_mesh()
    if env_mesh is None or not env_mesh.axis_names:
        return x
    spec = shape_aware_spec(logical_axes, x.shape, rules,
                            dict(env_mesh.shape))
    return jax.lax.with_sharding_constraint(x, spec)


def shardings_for(structs: Any, axes_tree: Any, rules: AxisRules, mesh: Mesh
                  ) -> Any:
    """NamedShardings for a tree of ShapeDtypeStructs/arrays, with the
    shape-aware single-pass policy (the production entry point)."""
    mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))

    def _one(struct, axes):
        if axes is None:
            return NamedSharding(mesh, P())
        if isinstance(axes, P):
            return NamedSharding(mesh, axes)
        spec = shape_aware_spec(axes, struct.shape, rules, mesh_shape)
        return NamedSharding(mesh, spec)

    s_leaves, treedef = jax.tree.flatten(structs)
    a_leaves = treedef.flatten_up_to(_mark_none(axes_tree))
    a_leaves = [None if isinstance(a, _NoneAxes) else a for a in a_leaves]
    return jax.tree.unflatten(
        treedef, [_one(s, a) for s, a in zip(s_leaves, a_leaves)])


class _NoneAxes:
    pass


_NONE_AXES = _NoneAxes()


def _mark_none(tree: Any) -> Any:
    """Replace None leaves with a sentinel so tree structures line up."""
    def walk(x):
        if x is None:
            return _NONE_AXES
        if isinstance(x, (tuple, P)):
            return x
        if isinstance(x, dict):
            return {k: walk(v) for k, v in x.items()}
        if isinstance(x, list):
            return [walk(v) for v in x]
        return x
    return walk(tree)


def replicated_shardings(tree: Any, mesh: Mesh) -> Any:
    """Fully-replicated ``NamedSharding`` for every leaf of ``tree``.

    The placement for state that *crosses* engines instead of living on
    one — e.g. the per-request cache rows of a serving handoff: the rows
    are replicated onto the target mesh so the subsequent scatter into
    the (possibly slot-sharded) resident state reads device-locally on
    every shard, whatever slot the scheduler picked."""
    sharding = NamedSharding(mesh, P())
    return jax.tree.map(lambda _: sharding, tree)


def divisible_or_none(dim: int, axes: MeshAxes, mesh: Mesh) -> bool:
    """Check shardability of ``dim`` over ``axes`` of ``mesh``."""
    if axes is None:
        return True
    tup = (axes,) if isinstance(axes, str) else axes
    total = 1
    for a in tup:
        total *= mesh.shape[a]
    return dim % total == 0


def disjoint_submeshes(n: int, axis_name: str = "data",
                       devices: Optional[Sequence[Any]] = None
                       ) -> Tuple[Mesh, ...]:
    """``n`` single-axis meshes over disjoint device groups.

    The multi-host emulation primitive for disaggregated serving: give
    the prefill engine and each decode engine its *own* mesh so cache
    handoffs must genuinely cross device boundaries (and a
    device-to-device transport has real work to do).  With ``d`` devices
    each submesh gets ``d // n`` of them (any remainder stays unused so
    the groups stay equal-sized).  When the host has fewer devices than
    requested groups the meshes degrade to 1-device each and *reuse*
    devices round-robin — distinct Mesh objects, degenerate placement —
    so single-device CI still exercises every code path.
    """
    if n <= 0:
        raise ValueError(f"need a positive submesh count, got {n}")
    devs = list(devices) if devices is not None else list(jax.devices())
    if not devs:
        raise ValueError("no devices to build submeshes from")
    per = max(len(devs) // n, 1)
    groups = [[devs[(i * per + j) % len(devs)] for j in range(per)]
              for i in range(n)]
    return tuple(Mesh(np.array(g, dtype=object), (axis_name,))
                 for g in groups)
