"""``repro.deploy`` — the canonical FastCaps deployment API.

  * :mod:`repro.deploy.registry` — typed :class:`RoutingSpec` + the
    routing-variant registry (capability probing, backend-chosen interpret
    mode);
  * :mod:`repro.deploy.pipeline` — :class:`FastCapsPipeline`, the Fig. 6
    methodology as one chainable object
    (``build() -> prune() -> finetune() -> compact() -> compile()``)
    producing an immutable :class:`DeployedCapsNet`;
  * :class:`repro.serving.CapsuleEngine` consumes the deployed model for
    batched, FPS-measured image serving — ``deployed.serve(scheduler=...)``
    wires the Fig. 6 pipeline straight into the async serving engine.

The old free functions (``core.routing.route``,
``core.pruning.prune_capsnet``) and the stringly ``routing_mode=`` /
``softmax_mode=`` config fields completed their deprecation cycle and are
gone; typed :class:`RoutingSpec` is the only routing selection path.
"""

from repro.deploy.registry import (RoutingRegistry, RoutingSpec,  # noqa: F401
                                   RoutingVariant, normalize, registry,
                                   resolve)

# pipeline imports core.capsnet, which itself imports this package for
# RoutingSpec — load it lazily (PEP 562) to keep the import graph acyclic.
_PIPELINE_ATTRS = ("FastCapsPipeline", "DeployedCapsNet", "PipelineError",
                   "capsnet_flops_per_image", "pipeline")


def __getattr__(name):
    if name in _PIPELINE_ATTRS:
        import importlib

        pipeline = importlib.import_module("repro.deploy.pipeline")
        if name == "pipeline":
            return pipeline
        return getattr(pipeline, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(list(globals()) + list(_PIPELINE_ATTRS))
