"""Routing-variant registry: typed specs + capability-probed dispatch.

This replaces the stringly-typed ``mode=... softmax_mode=... interpret=...``
kwargs threading in ``core/routing.py``.  A routing variant is registered
once with:

  * a ``build(spec)`` factory returning the concrete route function
    ``fn(u_hat, n_iters) -> (v, c)``;
  * an availability probe (e.g. "is the Pallas toolchain importable");
  * an optional fallback variant used when the probe fails.

Callers hold a :class:`RoutingSpec` — a small frozen dataclass carried by
``CapsNetConfig.routing`` — and resolve it to a callable via
:func:`resolve`.  Backend-dependent choices (Pallas interpret mode off-TPU)
are made here, by probing ``jax.default_backend()``, never hardcoded at the
call site.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Dict, Optional, Tuple

import jax

RouteFn = Callable[..., Tuple[jax.Array, jax.Array]]

_SOFTMAX_MODES = ("exact", "taylor")


@dataclasses.dataclass(frozen=True)
class RoutingSpec:
    """Typed description of a dynamic-routing configuration.

    ``interpret=None`` means "let the registry probe the backend": Pallas
    kernels run compiled on TPU and in interpret mode everywhere else.
    """

    mode: str = "reference"           # registered variant name
    softmax: str = "exact"            # exact | taylor (paper Eq. 2)
    div_exp_log: bool = False         # paper Eq. 3 (optimized variant only)
    interpret: Optional[bool] = None  # pallas only; None -> backend probe

    def __post_init__(self):
        if self.softmax not in _SOFTMAX_MODES:
            raise ValueError(
                f"softmax must be one of {_SOFTMAX_MODES}, got "
                f"{self.softmax!r}")

    # -- canonical constructors --------------------------------------------

    @classmethod
    def reference(cls) -> "RoutingSpec":
        return cls(mode="reference")

    @classmethod
    def optimized(cls, softmax: str = "taylor",
                  div_exp_log: bool = False) -> "RoutingSpec":
        return cls(mode="optimized", softmax=softmax,
                   div_exp_log=div_exp_log)

    @classmethod
    def pallas(cls, softmax: str = "taylor",
               interpret: Optional[bool] = None) -> "RoutingSpec":
        return cls(mode="pallas", softmax=softmax, interpret=interpret)

    @classmethod
    def named(cls, name: str) -> "RoutingSpec":
        """The deployment-default spec for a variant name (paper §III-B:
        the optimized/pallas paths ship with the Taylor softmax)."""
        table = {"reference": cls.reference(),
                 "optimized": cls.optimized(),
                 "pallas": cls.pallas()}
        if name not in table:
            raise ValueError(
                f"unknown routing variant {name!r}; known: "
                f"{sorted(table)}")
        return table[name]


@dataclasses.dataclass(frozen=True)
class RoutingVariant:
    """One registered routing implementation."""

    name: str
    build: Callable[[RoutingSpec], RouteFn]
    is_available: Callable[[], bool] = lambda: True
    fallback: Optional[str] = None    # resolved when is_available() is False


class RoutingRegistry:
    def __init__(self):
        self._variants: Dict[str, RoutingVariant] = {}

    def register(self, variant: RoutingVariant) -> RoutingVariant:
        self._variants[variant.name] = variant
        return variant

    def names(self):
        return sorted(self._variants)

    def get(self, name: str) -> RoutingVariant:
        try:
            return self._variants[name]
        except KeyError:
            raise ValueError(
                f"unknown routing mode {name!r}; registered: "
                f"{self.names()}") from None

    def normalize(self, spec: RoutingSpec) -> RoutingSpec:
        """Fill backend-dependent fields and apply availability fallback.

        The returned spec is fully concrete: its mode names an available
        variant and (for pallas) ``interpret`` is True/False, chosen from
        ``jax.default_backend()`` unless the caller pinned it.
        """
        variant = self.get(spec.mode)
        while not variant.is_available():
            if variant.fallback is None:
                raise RuntimeError(
                    f"routing variant {variant.name!r} unavailable and has "
                    f"no fallback")
            spec = dataclasses.replace(spec, mode=variant.fallback)
            variant = self.get(spec.mode)
        if spec.mode == "pallas" and spec.interpret is None:
            from repro.kernels import needs_interpret

            spec = dataclasses.replace(spec, interpret=needs_interpret())
        return spec

    def resolve(self, spec: RoutingSpec) -> RouteFn:
        """Spec -> concrete ``fn(u_hat, n_iters) -> (v, c)``."""
        spec = self.normalize(spec)
        return self.get(spec.mode).build(spec)


# ---------------------------------------------------------------------------
# Default registry: the three paper variants
# ---------------------------------------------------------------------------

registry = RoutingRegistry()


def _build_reference(spec: RoutingSpec) -> RouteFn:
    from repro.core import routing

    return routing.route_reference


def _build_optimized(spec: RoutingSpec) -> RouteFn:
    from repro.core import routing

    return functools.partial(
        routing.route_optimized, softmax_mode=spec.softmax,
        use_div_exp_log=spec.div_exp_log)


def _pallas_available() -> bool:
    # thin view over the kernel registry: availability is whatever the
    # fused_routing KernelSpec's own probe says
    from repro.kernels.registry import registry as kernel_registry

    return kernel_registry.get("fused_routing").is_available()


def _build_pallas(spec: RoutingSpec) -> RouteFn:
    from repro import kernels

    def route_pallas(u_hat, n_iters: int = 3):
        return kernels.fused_routing(
            u_hat, n_iters=n_iters, softmax_mode=spec.softmax,
            interpret=spec.interpret)

    return route_pallas


registry.register(RoutingVariant("reference", _build_reference))
registry.register(RoutingVariant("optimized", _build_optimized))
registry.register(RoutingVariant("pallas", _build_pallas,
                                 is_available=_pallas_available,
                                 fallback="optimized"))


def resolve(spec: RoutingSpec) -> RouteFn:
    return registry.resolve(spec)


def normalize(spec: RoutingSpec) -> RoutingSpec:
    return registry.normalize(spec)
