"""FastCapsPipeline: the paper's Fig. 6 methodology as one object.

    pipe = FastCapsPipeline(cfg).build(seed=0)
    pipe.prune(sparsity_conv1=0.6, sparsity_conv2=0.9, type_keep=7)
    pipe.finetune(finetune_fn)          # optional (masked fine-tuning)
    pipe.compact()                      # 1152 -> 252 capsules
    deployed = pipe.compile(routing="pallas")

``compile`` returns an immutable :class:`DeployedCapsNet`: config + params
frozen together with a jitted fixed-signature forward, parameter/FLOP
accounting, and a checkpoint hook — the artifact
:class:`repro.serving.CapsuleEngine` serves.  ``deployed.serve(
scheduler=...)`` wraps it in that engine directly, so the Fig. 6 pipeline
flows into SLO-scheduled serving in one chain.

Stages are enforced in order (``prune`` before ``compact``; ``compact``
before a second ``prune``), matching the one-way arrows of Fig. 6; every
stage returns ``self`` so the pipeline chains.
"""

from __future__ import annotations

import dataclasses
import json
import os
from typing import Any, Callable, Dict, Optional, Tuple, Union

import jax
import jax.numpy as jnp

from repro.checkpointing import checkpoint
from repro.core import capsnet as capsnet_lib
from repro.core import lakp as lakp_lib
from repro.core import routing as routing_lib
from repro.deploy.registry import RoutingSpec, normalize


class PipelineError(RuntimeError):
    """A pipeline stage was invoked out of Fig. 6 order."""


def capsnet_flops_per_image(cfg: capsnet_lib.CapsNetConfig) -> int:
    """Analytic forward FLOPs (conv + prediction + routing) per image."""
    conv1 = 2 * cfg.conv1_out_hw ** 2 * cfg.conv1_channels * (
        cfg.in_channels * cfg.conv1_kernel ** 2)
    conv2 = 2 * cfg.caps_out_hw ** 2 * cfg.primary_conv_channels * (
        cfg.conv1_channels * cfg.caps_kernel ** 2)
    pred = 2 * cfg.n_primary_caps * cfg.n_classes * cfg.caps_dim * \
        cfg.digit_dim
    route = routing_lib.routing_flops(
        1, cfg.n_primary_caps, cfg.n_classes, cfg.digit_dim,
        cfg.routing_iters)
    return conv1 + conv2 + pred + route


@dataclasses.dataclass(frozen=True)
class DeployedCapsNet:
    """Immutable deployment artifact: config + params + jitted forward."""

    cfg: capsnet_lib.CapsNetConfig
    params: Dict[str, Any]
    spec: RoutingSpec                 # normalized (backend-concrete)
    n_params: int
    flops_per_image: int
    _forward: Callable[[Dict[str, Any], jax.Array], jax.Array] = \
        dataclasses.field(repr=False, compare=False, default=None)

    def forward(self, images: jax.Array) -> jax.Array:
        """images (B, H, W, C) -> class capsule lengths (B, n_classes)."""
        return self._forward(self.params, images)

    __call__ = forward

    def classify(self, images: jax.Array) -> jax.Array:
        """images -> predicted class ids (B,)."""
        return jnp.argmax(self.forward(images), axis=-1)

    def serve(self, batch_size: int = 32, scheduler: Any = None,
              kernel_tune: Any = None):
        """Wrap this artifact in a :class:`repro.serving.CapsuleEngine`
        so the Fig. 6 pipeline flows straight into serving:

            engine = pipe.compile(routing="pallas").serve(
                scheduler=SLOBatchScheduler(target_p95_ms=20))

        ``batch_size`` is the engine capacity (max frames per tick);
        ``scheduler`` is any :class:`repro.serving.Scheduler` (FIFO when
        None).  The returned engine's ``submit()`` is thread-safe and
        non-blocking; drive it with ``run_until_idle()`` or a ``tick()``
        loop and read per-class latency p50/p95 from ``stats()``.
        ``kernel_tune=True`` makes ``engine.warmup()`` autotune the fused
        routing kernel's block sizes and bind the winners into the tick
        executables (see :mod:`repro.kernels.tuning`).
        """
        from repro.serving import CapsuleEngine

        return CapsuleEngine(self, batch_size=batch_size,
                             scheduler=scheduler, kernel_tune=kernel_tune)

    def save(self, directory: str, step: int = 0) -> str:
        """Checkpoint the params (atomic publish) + a deploy manifest."""
        path = checkpoint.save(directory, step, self.params)
        meta = {"cfg": dataclasses.asdict(
                    dataclasses.replace(self.cfg, routing=None)),
                "routing": dataclasses.asdict(self.spec),
                "n_params": self.n_params,
                "flops_per_image": self.flops_per_image}
        with open(os.path.join(directory, "deploy.json"), "w") as f:
            json.dump(meta, f, indent=2)
        return path


class FastCapsPipeline:
    """Chainable Fig. 6 pipeline; the canonical `repro.deploy` entry point.

    ``FastCapsPipeline(cfg, params=...)`` adopts already-trained params
    (skipping ``build``); otherwise call ``build(seed=...)`` first.
    """

    _ORDER = ("init", "built", "pruned", "finetuned", "compacted")

    def __init__(self, cfg: capsnet_lib.CapsNetConfig,
                 params: Optional[Dict[str, Any]] = None):
        self.cfg = cfg
        self.params = params
        self.masks: Optional[Tuple[jax.Array, jax.Array]] = None
        self.index: Dict[str, jax.Array] = {}
        self.compression: Optional[float] = None
        self.index_overhead_frac: Optional[float] = None
        self._stage = "built" if params is not None else "init"

    # -- stage machinery ---------------------------------------------------

    def _require(self, *stages: str) -> None:
        if self._stage not in stages:
            raise PipelineError(
                f"stage {self._stage!r} cannot run this step; expected one "
                f"of {stages}")

    @property
    def stage(self) -> str:
        return self._stage

    # -- Fig. 6 stages -----------------------------------------------------

    def build(self, seed: int = 0,
              key: Optional[jax.Array] = None) -> "FastCapsPipeline":
        """Initialize dense params (or adopt a key for reproducibility)."""
        self._require("init")
        self.params = capsnet_lib.init(
            self.cfg, key if key is not None else jax.random.key(seed))
        self._stage = "built"
        return self

    def prune(self, sparsity_conv1: float, sparsity_conv2: float,
              method: str = "lakp", norm: str = "l1",
              type_keep: Optional[int] = None) -> "FastCapsPipeline":
        """LAKP/KP kernel scoring + masking (+ capsule-type elimination)."""
        self._require("built", "compacted")
        self.masks = capsnet_lib.lakp_masks(
            self.params, self.cfg, sparsity_conv1, sparsity_conv2,
            method=method, norm=norm, type_keep=type_keep)
        conv_ws = [self.params["conv1"]["w"], self.params["conv2"]["w"]]
        self.compression = lakp_lib.effective_compression(
            list(self.masks), conv_ws)
        self.params = capsnet_lib.apply_masks(self.params, self.masks)
        self._stage = "pruned"
        return self

    def finetune(self, finetune_fn: Callable[[Dict[str, Any], Any],
                                             Dict[str, Any]]
                 ) -> "FastCapsPipeline":
        """Masked fine-tuning: ``finetune_fn(masked_params, masks)`` is
        injected by the trainer (keeps the pipeline optimizer-free)."""
        self._require("pruned")
        self.params = finetune_fn(self.params, self.masks)
        self._stage = "finetuned"
        return self

    def compact(self) -> "FastCapsPipeline":
        """Physically remove dead kernels/capsule types (index study)."""
        self._require("pruned", "finetuned")
        self.params, self.cfg, self.index = capsnet_lib.compact(
            self.params, self.cfg, self.masks)
        surviving = sum(int(x.size) for x in jax.tree.leaves(self.params))
        self.index_overhead_frac = lakp_lib.index_overhead_bytes(
            list(self.masks)) / max(surviving * 4, 1)
        self._stage = "compacted"
        return self

    def compile(self, routing: Union[None, str, RoutingSpec] = None,
                ) -> DeployedCapsNet:
        """Freeze the current model into a :class:`DeployedCapsNet`.

        ``routing``: a :class:`RoutingSpec`, a variant name (deployment
        defaults via ``RoutingSpec.named``), or None to keep the config's
        own spec.  Valid from any stage with params (deploy-the-dense-model
        is the Fig. 1 baseline).
        """
        self._require("built", "pruned", "finetuned", "compacted")
        if routing is None:
            spec = self.cfg.routing_spec()
        elif isinstance(routing, str):
            spec = RoutingSpec.named(routing)
        else:
            spec = routing
        spec = normalize(spec)
        cfg = dataclasses.replace(self.cfg, routing=spec)
        fwd = jax.jit(lambda p, x: capsnet_lib.forward(p, cfg, x)[0])
        return DeployedCapsNet(
            cfg=cfg,
            params=self.params,
            spec=spec,
            n_params=capsnet_lib.param_count(self.params),
            flops_per_image=capsnet_flops_per_image(cfg),
            _forward=fwd,
        )

    # -- one-call convenience ----------------------------------------------

    def deploy(self, sparsity_conv1: float, sparsity_conv2: float,
               method: str = "lakp", type_keep: Optional[int] = None,
               finetune_fn: Optional[Callable] = None,
               routing: Union[None, str, RoutingSpec] = "pallas",
               ) -> DeployedCapsNet:
        """build -> prune -> [finetune] -> compact -> compile in one call."""
        if self._stage == "init":
            self.build()
        self.prune(sparsity_conv1, sparsity_conv2, method=method,
                   type_keep=type_keep)
        if finetune_fn is not None:
            self.finetune(finetune_fn)
        self.compact()
        return self.compile(routing=routing)
