"""Unified kernel registry: typed specs + capability-probed dispatch.

This generalizes the probe-and-fallback design of
``repro.deploy.registry`` from routing variants to *every* Pallas kernel
in the repo.  One :class:`KernelSpec` per kernel declares:

  * ``build()`` — the jitted Pallas entry point (lazy import, so merely
    importing ``repro.kernels`` never pulls ``jax.experimental.pallas``);
  * ``reference()`` — the pure-jnp oracle with the same semantics;
  * ``is_available()`` — the capability probe (Pallas importable);
  * ``space`` — the FastCaps design space for this kernel: the tunable
    block sizes (measured by :mod:`repro.kernels.tuning`) plus the
    numerics-changing knobs (``softmax_mode``) that benchmarks and the
    parity harness sweep but the timing tuner never flips;
  * ``legalize`` — shape-aware config legalization (every block size
    becomes a divisor of its dimension via ``largest_divisor``);
  * ``example_cases`` / ``make_example`` — canonical inputs shared by
    the parity tests, the selfcheck CLI and the pretuner.

Dispatch (:meth:`KernelRegistry.call`) resolves, in order: explicit
per-call overrides > tuned config from the on-disk cache (when the
:func:`repro.kernels.tuning.tuning` scope or ``tune=`` asks for it) >
the deterministic legalized defaults (the ``tune=False`` CI path).
Backend capability (``interpret`` mode off-TPU) is probed in exactly one
place — :func:`repro.kernels.tuning.needs_interpret` — and an
unavailable Pallas toolchain falls back to the reference oracle, so the
same call sites run everywhere.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Mapping, Optional, Tuple

from repro.kernels import tuning
from repro.kernels.tuning import largest_divisor, needs_interpret  # noqa: F401


@dataclasses.dataclass(frozen=True)
class KernelSpec:
    """One registered kernel: impls, probe, and its tunable design space.

    ``space`` maps every design-space knob to its candidate values;
    ``tuned`` names the subset the measured autotuner may vary (block
    sizes — numerics-preserving by construction).  ``base_config`` holds
    the historical hard-coded values; ``legalize(config, *args, **kw)``
    clamps a candidate to the concrete shapes (divisibility).  The
    ``example_cases`` dicts drive the registry-wide parity harness and
    the pretune CLI: ``make_example(case) -> (args, kwargs)``.
    """

    name: str
    build: Callable[[], Callable[..., Any]]
    reference: Callable[[], Callable[..., Any]]
    space: Mapping[str, tuple]
    tuned: Tuple[str, ...]
    base_config: Mapping[str, Any]
    legalize: Callable[..., Dict[str, Any]]
    make_example: Callable[[Mapping[str, Any]], Tuple[tuple, dict]]
    example_cases: Tuple[Mapping[str, Any], ...] = ()
    ref_accepts: Tuple[str, ...] = ()     # semantic kwargs the oracle takes
    is_available: Callable[[], bool] = lambda: True
    #: tuned key -> the array dimension it must divide, evaluated on the
    #: call arguments (ShapeDtypeStructs suffice — only ``.shape`` is
    #: read).  ``legalize`` is derived from this via
    #: :func:`_legalize_blocks`, so the capslint kernel-legality checker
    #: verifies the *same* dimension mapping dispatch uses.
    block_dims: Optional[Callable[..., Dict[str, int]]] = None
    #: cross-knob divisibility constraints: each ``(a, b)`` pair declares
    #: that the legalized ``config[a]`` must divide ``config[b]`` (e.g. a
    #: paged-cache ``page_size`` dividing the ``kv_block`` so KV blocks
    #: stay page-aligned).  ``_legalize_blocks`` enforces the pairs and
    #: the capslint kernel-legality checker proves them on every tuner
    #: candidate.
    block_divisors: Tuple[Tuple[str, str], ...] = ()

    def ref_call(self, *args, **kwargs):
        """Invoke the jnp oracle, filtering kwargs it does not accept."""
        fn = self.reference()
        return fn(*args, **{k: v for k, v in kwargs.items()
                            if k in self.ref_accepts})


class KernelRegistry:
    """Name -> :class:`KernelSpec`; resolution + dispatch."""

    def __init__(self):
        self._specs: Dict[str, KernelSpec] = {}

    def register(self, spec: KernelSpec) -> KernelSpec:
        self._specs[spec.name] = spec
        return spec

    def names(self):
        return sorted(self._specs)

    def get(self, name: str) -> KernelSpec:
        try:
            return self._specs[name]
        except KeyError:
            raise ValueError(f"unknown kernel {name!r}; registered: "
                             f"{self.names()}") from None

    # -- config resolution -------------------------------------------------

    def default_config(self, name: str, *args, **kwargs) -> Dict[str, Any]:
        """The deterministic ``tune=False`` config for these shapes."""
        spec = self.get(name)
        return spec.legalize(dict(spec.base_config), *args, **kwargs)

    def resolve_config(self, name: str, *args,
                       overrides: Optional[Dict[str, Any]] = None,
                       tune: Optional[bool] = None, **kwargs
                       ) -> Dict[str, Any]:
        """Overrides > tuned cache entry (if tuning) > legalized defaults.

        With tuning on and a cache miss, concrete arguments trigger a
        measured :func:`repro.kernels.tuning.autotune` on the spot;
        tracers (dispatch at ``jax.jit`` trace time) only read the cache.
        """
        spec = self.get(name)
        config = spec.legalize(dict(spec.base_config), *args, **kwargs)
        use_tune = tune if tune is not None else tuning.tune_enabled()
        if use_tune:
            cache = tuning.default_cache()
            cached = cache.get(tuning.cache_key_for(spec, args))
            if cached is None and _all_concrete(args):
                cached, _ = tuning.autotune(spec, args, kwargs, cache=cache)
            if cached is not None:
                merged = dict(spec.base_config)
                merged.update(cached)
                config = spec.legalize(merged, *args, **kwargs)
        if overrides:
            config.update({k: v for k, v in overrides.items()
                           if v is not None})
            config = spec.legalize(config, *args, **kwargs)
        return config

    # -- dispatch ----------------------------------------------------------

    def call(self, name: str, *args,
             config: Optional[Dict[str, Any]] = None,
             interpret: Optional[bool] = None,
             tune: Optional[bool] = None, **kwargs) -> Any:
        """Dispatch ``name`` on ``args``: Pallas impl with the resolved
        config when available, the jnp reference otherwise.  ``kwargs``
        are semantic (``n_iters``, ``softmax_mode``, ``causal``, ...);
        tunable overrides ride in ``config``."""
        spec = self.get(name)
        if not spec.is_available():
            return spec.ref_call(*args, **kwargs)
        resolved = self.resolve_config(name, *args, overrides=config,
                                       tune=tune, **kwargs)
        if interpret is None:
            interpret = needs_interpret()
        return spec.build()(*args, interpret=interpret, **kwargs, **resolved)


def _all_concrete(args) -> bool:
    import jax

    return not any(isinstance(a, jax.core.Tracer) for a in args)


def _pallas_available() -> bool:
    try:
        from jax.experimental import pallas  # noqa: F401
    # Capability probe: *any* import failure (missing extra, broken
    # toolchain, platform plugin) means the same thing — "Pallas
    # unavailable" — and dispatch falls back to the reference oracle.
    # capslint: disable=exception-hygiene
    except Exception:
        return False
    return True


def _legalize_blocks(dims_fn: Callable[..., Dict[str, int]],
                     divisors: Tuple[Tuple[str, str], ...] = ()
                     ) -> Callable[..., Dict[str, Any]]:
    """Build a spec ``legalize`` from its ``block_dims`` mapping: every
    block-size key becomes ``largest_divisor(dim, requested)``.  Keeping
    legalization derived from the dimension map (rather than hand-written
    per kernel) is what lets ``repro.analysis``'s kernel-legality rule
    *prove* divisibility — the checker evaluates the same ``dims_fn``.

    ``divisors`` pairs (the spec's ``block_divisors``) are enforced
    after the divisor pass: for each ``(a, b)``, ``config[a]`` is first
    clamped to divide ``b``'s dimension, then ``config[b]`` is walked
    down in ``config[a]``-sized steps until it both divides the
    dimension and is a multiple of ``config[a]`` — so e.g. a KV block
    never straddles a cache-page boundary.  The procedure is idempotent
    (a requirement the legality checker's ``unstable-legalize`` rule
    enforces)."""

    def legalize(config: Dict[str, Any], *args, **kwargs) -> Dict[str, Any]:
        dims = dims_fn(*args, **kwargs)
        for key, dim in dims.items():
            config[key] = largest_divisor(dim, config[key])
        for a, b in divisors:
            dim = dims.get(b)
            va = int(config[a])
            if dim is not None:
                va = largest_divisor(dim, va)
                config[a] = va
            vb = max(int(config[b]), va)
            vb = vb // va * va
            if dim is not None:
                while vb > va and dim % vb:
                    vb -= va
            config[b] = vb
        return config

    return legalize


# ---------------------------------------------------------------------------
# Registered kernels (jitted entry points live here; the kernel packages
# keep only the Pallas bodies and the jnp oracles)
# ---------------------------------------------------------------------------

registry = KernelRegistry()


def _rand(seed: int, shape, dtype="float32", scale: float = 1.0):
    import jax
    import jax.numpy as jnp

    x = jax.random.normal(jax.random.key(seed), shape) * scale
    return x.astype(jnp.dtype(dtype))


# -- fused_routing ----------------------------------------------------------


@functools.lru_cache(maxsize=None)
def _build_fused_routing():
    import jax

    from repro.kernels.routing.routing_kernel import fused_routing_pallas

    @functools.partial(jax.jit, static_argnames=(
        "n_iters", "softmax_mode", "batch_block", "interpret"))
    def fused_routing_entry(u_hat, n_iters=3, softmax_mode="exact",
                            batch_block=8, interpret=True):
        return fused_routing_pallas(
            u_hat, n_iters=n_iters, softmax_mode=softmax_mode,
            batch_block=batch_block, interpret=interpret)

    return fused_routing_entry


def _routing_reference():
    from repro.kernels.routing.ref import fused_routing_ref

    return fused_routing_ref


def _routing_block_dims(u_hat, **kwargs):
    return {"batch_block": u_hat.shape[0]}


def _routing_example(case):
    shape = case.get("shape", (4, 24, 10, 16))
    u = _rand(case.get("seed", 0), shape, case.get("dtype", "float32"),
              scale=0.2)
    return (u,), {"n_iters": case.get("n_iters", 3),
                  "softmax_mode": case.get("softmax_mode", "exact")}


registry.register(KernelSpec(
    name="fused_routing",
    build=_build_fused_routing,
    reference=_routing_reference,
    space={"batch_block": (1, 2, 4, 8, 16),
           "softmax_mode": ("exact", "taylor")},
    tuned=("batch_block",),
    base_config={"batch_block": 8},
    legalize=_legalize_blocks(_routing_block_dims),
    block_dims=_routing_block_dims,
    make_example=_routing_example,
    example_cases=(
        {"shape": (4, 24, 10, 16), "softmax_mode": "exact", "atol": 1e-5},
        {"shape": (9, 30, 10, 16), "softmax_mode": "exact", "atol": 1e-5},
        {"shape": (6, 36, 5, 8), "softmax_mode": "taylor", "atol": 1e-4},
        {"shape": (3, 252, 10, 16), "softmax_mode": "taylor", "atol": 1e-4},
    ),
    ref_accepts=("n_iters", "softmax_mode"),
    is_available=_pallas_available,
))


# -- taylor_softmax ---------------------------------------------------------


@functools.lru_cache(maxsize=None)
def _build_taylor_softmax():
    import jax

    from repro.kernels.softmax.kernel import taylor_softmax_pallas

    @functools.partial(jax.jit, static_argnames=("row_block", "interpret"))
    def taylor_softmax_entry(x, row_block=256, interpret=True):
        return taylor_softmax_pallas(x, row_block=row_block,
                                     interpret=interpret)

    return taylor_softmax_entry


def _softmax_reference():
    from repro.kernels.softmax.ref import taylor_softmax_ref

    return taylor_softmax_ref


def _softmax_block_dims(x, **kwargs):
    rows = 1
    for d in x.shape[:-1]:
        rows *= d
    return {"row_block": rows}


def _softmax_example(case):
    shape = case.get("shape", (8, 16))
    x = _rand(case.get("seed", 0), shape, case.get("dtype", "float32"),
              scale=case.get("scale", 5.0))
    return (x,), {}


registry.register(KernelSpec(
    name="taylor_softmax",
    build=_build_taylor_softmax,
    reference=_softmax_reference,
    space={"row_block": (32, 64, 128, 256, 512)},
    tuned=("row_block",),
    base_config={"row_block": 256},
    legalize=_legalize_blocks(_softmax_block_dims),
    block_dims=_softmax_block_dims,
    make_example=_softmax_example,
    example_cases=(
        {"shape": (8, 16), "atol": 1e-6},
        {"shape": (33, 250), "atol": 1e-6},          # odd/ragged rows
        {"shape": (4, 7, 64), "atol": 1e-6},
        {"shape": (1, 1024), "atol": 1e-6},
        {"shape": (16, 64), "dtype": "bfloat16", "scale": 3.0,
         "atol": 1e-2},
    ),
    ref_accepts=(),
    is_available=_pallas_available,
))


# -- flash_attention --------------------------------------------------------


@functools.lru_cache(maxsize=None)
def _build_flash_attention():
    import jax

    from repro.kernels.attention.kernel import flash_attention_pallas

    @functools.partial(jax.jit, static_argnames=(
        "causal", "q_offset", "q_block", "kv_block", "softmax_mode",
        "interpret"))
    def flash_attention_entry(q, k, v, causal=True, q_offset=0,
                              softmax_mode="exact", q_block=512,
                              kv_block=512, interpret=True):
        """(B, S, H, D) GQA API over the (BK, G, S, D) flash kernel."""
        b, s, h, d = q.shape
        t, nkv = k.shape[1], k.shape[2]
        g = h // nkv
        qr = (q.reshape(b, s, nkv, g, d).transpose(0, 2, 3, 1, 4)
              .reshape(b * nkv, g, s, d))
        kr = k.transpose(0, 2, 1, 3).reshape(b * nkv, t, d)
        vr = v.transpose(0, 2, 1, 3).reshape(b * nkv, t, d)
        o = flash_attention_pallas(
            qr, kr, vr, causal=causal, q_offset=q_offset, q_block=q_block,
            kv_block=kv_block, softmax_mode=softmax_mode,
            interpret=interpret)
        return (o.reshape(b, nkv, g, s, d).transpose(0, 3, 1, 2, 4)
                .reshape(b, s, h, d))

    return flash_attention_entry


def _attention_reference():
    from repro.kernels.attention.ref import attention_ref

    return attention_ref


def _attention_block_dims(q, k=None, v=None, **kwargs):
    s = q.shape[1]
    t = k.shape[1] if k is not None else s
    return {"q_block": s, "kv_block": t}


def _attention_example(case):
    b, s, t, h, k, d = case.get("dims", (2, 128, 128, 4, 2, 32))
    dtype = case.get("dtype", "float32")
    q = _rand(case.get("seed", 0), (b, s, h, d), dtype)
    kk = _rand(case.get("seed", 0) + 1, (b, t, k, d), dtype)
    v = _rand(case.get("seed", 0) + 2, (b, t, k, d), dtype)
    return (q, kk, v), {"causal": case.get("causal", True),
                        "q_offset": case.get("q_offset", 0),
                        "softmax_mode": case.get("softmax_mode", "exact")}


registry.register(KernelSpec(
    name="flash_attention",
    build=_build_flash_attention,
    reference=_attention_reference,
    space={"q_block": (64, 128, 256, 512),
           "kv_block": (64, 128, 256, 512),
           "softmax_mode": ("exact", "taylor")},
    tuned=("q_block", "kv_block"),
    base_config={"q_block": 512, "kv_block": 512},
    legalize=_legalize_blocks(_attention_block_dims),
    block_dims=_attention_block_dims,
    make_example=_attention_example,
    example_cases=(
        {"dims": (2, 128, 128, 8, 4, 32), "causal": True, "atol": 2e-5},
        {"dims": (2, 64, 256, 8, 2, 32), "causal": False, "atol": 2e-5},
        {"dims": (1, 192, 192, 2, 1, 64), "causal": True,
         "atol": 2e-5},                               # non-pow2 seq
        {"dims": (1, 64, 256, 4, 2, 32), "causal": True, "q_offset": 192,
         "atol": 2e-5},                               # decode window
        {"dims": (1, 128, 128, 4, 2, 32), "softmax_mode": "taylor",
         "atol": 5e-2},                # vs exact oracle: approx-exp bound
    ),
    ref_accepts=("causal", "q_offset"),
    is_available=_pallas_available,
))


# -- flash_attention_dequant ------------------------------------------------
# Dequant-on-read attention over int8 KV pages (repro.serving.pages):
# k/v arrive quantized with per-row fp32 scales and are dequantized
# block-at-a-time inside the kernel, so the resident cache stays int8.
# ``page_size`` is a structural knob (the pool's page length, not
# tuned); ``block_divisors`` keeps the KV block a multiple of it, so a
# block's scale rows never straddle a page boundary.


@functools.lru_cache(maxsize=None)
def _build_flash_attention_dequant():
    import jax
    import jax.numpy as jnp

    from repro.kernels.attention.kernel import flash_attention_dequant_pallas

    @functools.partial(jax.jit, static_argnames=(
        "causal", "q_offset", "q_block", "kv_block", "page_size",
        "softmax_mode", "interpret"))
    def flash_attention_dequant_entry(q, kq, ks, vq, vs, causal=True,
                                      q_offset=0, softmax_mode="exact",
                                      q_block=512, kv_block=512,
                                      page_size=64, interpret=True):
        """(B, S, H, D) GQA API over the int8-KV flash kernel; scales
        (B, T) are shared by the KV heads (per-row quantization)."""
        b, s, h, d = q.shape
        t, nkv = kq.shape[1], kq.shape[2]
        g = h // nkv
        qr = (q.reshape(b, s, nkv, g, d).transpose(0, 2, 3, 1, 4)
              .reshape(b * nkv, g, s, d))
        kr = kq.transpose(0, 2, 1, 3).reshape(b * nkv, t, d)
        vr = vq.transpose(0, 2, 1, 3).reshape(b * nkv, t, d)
        ksr = jnp.repeat(ks.astype(jnp.float32), nkv, axis=0)
        vsr = jnp.repeat(vs.astype(jnp.float32), nkv, axis=0)
        o = flash_attention_dequant_pallas(
            qr, kr, ksr, vr, vsr, causal=causal, q_offset=q_offset,
            q_block=q_block, kv_block=kv_block, page_size=page_size,
            softmax_mode=softmax_mode, interpret=interpret)
        return (o.reshape(b, nkv, g, s, d).transpose(0, 3, 1, 2, 4)
                .reshape(b, s, h, d))

    return flash_attention_dequant_entry


def _attention_dequant_reference():
    from repro.kernels.attention.ref import attention_dequant_ref

    return attention_dequant_ref


def _attention_dequant_block_dims(q, kq=None, ks=None, vq=None, vs=None,
                                  **kwargs):
    s = q.shape[1]
    t = kq.shape[1] if kq is not None else s
    return {"q_block": s, "kv_block": t}


def _attention_dequant_example(case):
    import jax.numpy as jnp

    from repro.models.attention import quantize_kv_rows

    b, s, t, h, k, d = case.get("dims", (2, 128, 128, 4, 2, 32))
    q = _rand(case.get("seed", 0), (b, s, h, d), "float32")
    kk = _rand(case.get("seed", 0) + 1, (b, t, k, d), "float32")
    v = _rand(case.get("seed", 0) + 2, (b, t, k, d), "float32")
    kq, ks = quantize_kv_rows(kk)
    vq, vs = quantize_kv_rows(v)
    return ((q, kq.astype(jnp.int8), ks, vq.astype(jnp.int8), vs),
            {"causal": case.get("causal", True),
             "q_offset": case.get("q_offset", 0),
             "softmax_mode": case.get("softmax_mode", "exact")})


registry.register(KernelSpec(
    name="flash_attention_dequant",
    build=_build_flash_attention_dequant,
    reference=_attention_dequant_reference,
    space={"q_block": (64, 128, 256, 512),
           "kv_block": (64, 128, 256, 512),
           "page_size": (8, 16, 32, 64, 128),
           "softmax_mode": ("exact", "taylor")},
    tuned=("q_block", "kv_block"),
    base_config={"q_block": 512, "kv_block": 512, "page_size": 64},
    legalize=_legalize_blocks(_attention_dequant_block_dims,
                              divisors=(("page_size", "kv_block"),)),
    block_dims=_attention_dequant_block_dims,
    block_divisors=(("page_size", "kv_block"),),
    make_example=_attention_dequant_example,
    example_cases=(
        # parity vs the dequantizing oracle is tight: both read the same
        # int8 rows, so quantization error cancels and only the online
        # softmax differs.  (The *quantization* tolerance vs an
        # unquantized cache is asserted end-to-end in the serving tests.)
        {"dims": (2, 128, 128, 8, 4, 32), "causal": True, "atol": 2e-5},
        {"dims": (2, 64, 256, 8, 2, 32), "causal": False, "atol": 2e-5},
        {"dims": (1, 64, 256, 4, 2, 32), "causal": True, "q_offset": 192,
         "atol": 2e-5},                               # decode window
        {"dims": (1, 192, 192, 2, 1, 64), "causal": True,
         "atol": 2e-5},                               # non-pow2 seq
    ),
    ref_accepts=("causal", "q_offset"),
    is_available=_pallas_available,
))


# -- decode_attention -------------------------------------------------------
# q_len=1 serving decode: one query token per slot against a ragged KV
# cache (``kv_valid_len`` masks each slot's tail).  The cache arrives
# either dense (B, T, K, D) — optionally int8 with (B, T) row scales —
# or as the paged pool's native (n_pages, page, K, D) leaves plus the
# per-slot page ``tables`` (B, P): the kernel reads pages through the
# table as a scalar-prefetch operand, so serving skips the
# gather-to-dense materialization entirely.  ``page_size`` is structural
# (the pool's page length); ``block_divisors`` keeps the dense-path KV
# block page-aligned exactly like ``flash_attention_dequant``.


@functools.lru_cache(maxsize=None)
def _build_decode_attention():
    import jax

    from repro.kernels.attention.kernel import (
        decode_attention_dequant_pallas,
        decode_attention_paged_dequant_pallas, decode_attention_paged_pallas,
        decode_attention_pallas)

    @functools.partial(jax.jit, static_argnames=(
        "softmax_mode", "kv_block", "slot_block", "page_size", "interpret"))
    def decode_attention_entry(q, k, v, kv_valid_len, tables=None, ks=None,
                               vs=None, softmax_mode="exact", kv_block=512,
                               slot_block=1, page_size=64, interpret=True):
        """(B, 1, H, D) GQA decode API over the (B, K, G, D) kernels.

        Dense cache: k/v (B, T, K, D) (+ optional int8 scales ks/vs
        (B, T)).  Paged cache: k/v are pool leaves (n_pages, page, K, D)
        (+ optional pool scale leaves (n_pages, page)) and ``tables``
        (B, P) holds pre-clipped page ids.
        """
        b, s, h, d = q.shape
        nkv = k.shape[-2]
        g = h // nkv
        qr = q.reshape(b, nkv, g, d)
        # `tables`/`ks` being None is pytree *structure*, fixed at trace
        # time (jit retraces when an optional cache input appears) — the
        # branches below never inspect a tracer's value.
        # capslint: disable=jit-purity — None-vs-array is static structure
        if tables is not None:
            # capslint: disable=jit-purity — None-vs-array is static
            if ks is not None:
                o = decode_attention_paged_dequant_pallas(
                    qr, k, ks, v, vs, kv_valid_len, tables,
                    softmax_mode=softmax_mode, interpret=interpret)
            else:
                o = decode_attention_paged_pallas(
                    qr, k, v, kv_valid_len, tables,
                    softmax_mode=softmax_mode, interpret=interpret)
        # capslint: disable=jit-purity — None-vs-array is static
        elif ks is not None:
            o = decode_attention_dequant_pallas(
                qr, k, ks, v, vs, kv_valid_len, kv_block=kv_block,
                slot_block=slot_block, softmax_mode=softmax_mode,
                interpret=interpret)
        else:
            o = decode_attention_pallas(
                qr, k, v, kv_valid_len, kv_block=kv_block,
                slot_block=slot_block, softmax_mode=softmax_mode,
                interpret=interpret)
        return o.reshape(b, 1, h, d)

    return decode_attention_entry


def _decode_attention_reference():
    from repro.kernels.attention.ref import decode_attention_ref

    return decode_attention_ref


def _decode_attention_block_dims(q, k=None, v=None, kv_valid_len=None,
                                 tables=None, **kwargs):
    if tables is not None and k is not None:
        t = int(tables.shape[1]) * int(k.shape[1])   # pages x page length
    elif k is not None:
        t = k.shape[1]
    else:
        t = q.shape[1]
    return {"kv_block": t, "slot_block": q.shape[0]}


def _decode_attention_example(case):
    import jax.numpy as jnp

    from repro.models.attention import quantize_kv_rows

    b, t, h, nkv, d = case.get("dims", (4, 128, 8, 4, 32))
    seed = case.get("seed", 0)
    q = _rand(seed, (b, 1, h, d), "float32")
    valid = jnp.asarray(case["valid"], jnp.int32)
    kwargs = {"softmax_mode": case.get("softmax_mode", "exact")}
    paged = case.get("paged")
    if paged:
        n_pages, page, p_per = paged
        kk = _rand(seed + 1, (n_pages, page, nkv, d), "float32")
        v = _rand(seed + 2, (n_pages, page, nkv, d), "float32")
        kwargs["tables"] = ((jnp.arange(b * p_per, dtype=jnp.int32)
                             .reshape(b, p_per)) * 7 + 3) % n_pages
    else:
        kk = _rand(seed + 1, (b, t, nkv, d), "float32")
        v = _rand(seed + 2, (b, t, nkv, d), "float32")
    if case.get("quant"):
        kq, ks = quantize_kv_rows(kk)
        vq, vs = quantize_kv_rows(v)
        kk, v = kq.astype(jnp.int8), vq.astype(jnp.int8)
        kwargs["ks"] = ks
        kwargs["vs"] = vs
    return (q, kk, v, valid), kwargs


registry.register(KernelSpec(
    name="decode_attention",
    build=_build_decode_attention,
    reference=_decode_attention_reference,
    space={"kv_block": (64, 128, 256, 512),
           "slot_block": (1, 2, 4, 8),
           "page_size": (8, 16, 32, 64, 128),
           "softmax_mode": ("exact", "taylor")},
    tuned=("kv_block", "slot_block"),
    base_config={"kv_block": 512, "slot_block": 1, "page_size": 64},
    legalize=_legalize_blocks(_decode_attention_block_dims,
                              divisors=(("page_size", "kv_block"),)),
    block_dims=_decode_attention_block_dims,
    block_divisors=(("page_size", "kv_block"),),
    make_example=_decode_attention_example,
    example_cases=(
        # NB: the batch axis value is kept distinct from every other
        # axis in each case — the legality checker's bucket scaling
        # rewrites *all* axes equal to a block dimension's value, so a
        # batch that collides with e.g. the KV-head count would scale
        # the head axis to serving-bucket size unblocked.
        {"dims": (4, 128, 8, 2, 32), "valid": (128, 64, 1, 97),
         "atol": 2e-5},
        # ragged odd lengths + a fully-masked slot (valid=0 -> zeros)
        {"dims": (3, 96, 4, 2, 16), "valid": (5, 96, 0), "atol": 2e-5},
        {"dims": (6, 128, 4, 2, 32), "valid": (128, 31, 77, 1, 64, 9),
         "quant": True, "atol": 2e-5},
        # paged: (n_pages, page, pages_per_slot) pool, table indirection
        {"dims": (3, 64, 4, 2, 32), "valid": (64, 17, 1),
         "paged": (12, 16, 4), "atol": 2e-5},
        {"dims": (3, 64, 4, 2, 32), "valid": (49, 64, 8),
         "paged": (12, 16, 4), "quant": True, "atol": 2e-5},
        {"dims": (5, 128, 4, 2, 32), "valid": (100, 128, 64, 1, 27),
         "softmax_mode": "taylor", "atol": 5e-2},
    ),
    ref_accepts=("tables", "ks", "vs"),
    is_available=_pallas_available,
))


# -- fused_sampling ---------------------------------------------------------
# Temperature / top-k / top-p masking + the categorical draw fused into
# one launch over the serving tick's logits, with counter-based
# randomness (request seed x sequence position x vocab lane), so a
# sampled token is a pure function of (seed, pos, logits) — independent
# of slot order, batch composition, preemption and handoff.  Greedy
# (temperature <= 0) is an exact raw-logits argmax.


@functools.lru_cache(maxsize=None)
def _build_fused_sampling():
    import jax

    from repro.kernels.sampling.kernel import fused_sampling_pallas

    @functools.partial(jax.jit, static_argnames=("row_block", "interpret"))
    def fused_sampling_entry(logits, temperature, seeds, pos, top_k, top_p,
                             row_block=8, interpret=True):
        return fused_sampling_pallas(
            logits, temperature, seeds, pos, top_k, top_p,
            row_block=row_block, interpret=interpret)

    return fused_sampling_entry


def _sampling_reference():
    from repro.kernels.sampling.ref import fused_sampling_ref

    return fused_sampling_ref


def _sampling_block_dims(logits, *args, **kwargs):
    return {"row_block": logits.shape[0]}


def _sampling_example(case):
    import jax.numpy as jnp

    b, v = case.get("dims", (8, 64))
    logits = _rand(case.get("seed", 0), (b, v), "float32", scale=3.0)
    temperature = jnp.asarray(case.get("temperature", (1.0,) * b),
                              jnp.float32)
    seeds = jnp.asarray([(i * 0x9E3779B1 + 17) & 0x7FFFFFFF
                         for i in range(b)], jnp.int32)
    pos = jnp.asarray([i * 5 + case.get("pos0", 1) for i in range(b)],
                      jnp.int32)
    top_k = jnp.asarray(case.get("top_k", (0,) * b), jnp.int32)
    top_p = jnp.asarray(case.get("top_p", (1.0,) * b), jnp.float32)
    return (logits, temperature, seeds, pos, top_k, top_p), {}


registry.register(KernelSpec(
    name="fused_sampling",
    build=_build_fused_sampling,
    reference=_sampling_reference,
    space={"row_block": (1, 2, 4, 8, 16)},
    tuned=("row_block",),
    base_config={"row_block": 8},
    legalize=_legalize_blocks(_sampling_block_dims),
    block_dims=_sampling_block_dims,
    make_example=_sampling_example,
    example_cases=(
        # tokens are int32 — the parity harness's allclose means *equal*
        {"dims": (8, 64), "temperature": (0.0,) * 8},          # greedy
        {"dims": (8, 64)},                                     # temp 1.0
        {"dims": (6, 50), "temperature": (0.0, 0.7, 1.0, 1.3, 0.0, 2.0)},
        {"dims": (4, 64), "top_k": (5, 1, 64, 0)},
        {"dims": (4, 64), "top_p": (0.1, 0.5, 0.9, 1.0)},
        {"dims": (3, 33), "temperature": (0.8, 0.9, 1.1),
         "top_k": (7, 0, 3), "top_p": (0.9, 0.3, 1.0), "pos0": 11},
    ),
    ref_accepts=(),
    is_available=_pallas_available,
))


# ---------------------------------------------------------------------------
# Public dispatch wrappers (ergonomic signatures over registry.call)
# ---------------------------------------------------------------------------


def fused_routing(u_hat, n_iters: int = 3, softmax_mode: str = "exact",
                  batch_block: Optional[int] = None,
                  interpret: Optional[bool] = None,
                  tune: Optional[bool] = None):
    """Fused dynamic routing: u_hat (B, I, J, D) -> (v, c)."""
    return registry.call(
        "fused_routing", u_hat, n_iters=n_iters, softmax_mode=softmax_mode,
        config={"batch_block": batch_block}, interpret=interpret, tune=tune)


def taylor_softmax(x, row_block: Optional[int] = None,
                   interpret: Optional[bool] = None,
                   tune: Optional[bool] = None):
    """Eq. 2 Taylor softmax over the last axis (any leading shape)."""
    return registry.call("taylor_softmax", x,
                         config={"row_block": row_block},
                         interpret=interpret, tune=tune)


def flash_attention(q, k, v, causal: bool = True, q_offset: int = 0,
                    softmax_mode: str = "exact",
                    q_block: Optional[int] = None,
                    kv_block: Optional[int] = None,
                    interpret: Optional[bool] = None,
                    tune: Optional[bool] = None):
    """q (B, S, H, D); k, v (B, T, K, D); H = K * G -> (B, S, H, D)."""
    return registry.call(
        "flash_attention", q, k, v, causal=causal, q_offset=q_offset,
        softmax_mode=softmax_mode,
        config={"q_block": q_block, "kv_block": kv_block},
        interpret=interpret, tune=tune)


def flash_attention_dequant(q, kq, ks, vq, vs, causal: bool = True,
                            q_offset: int = 0, softmax_mode: str = "exact",
                            q_block: Optional[int] = None,
                            kv_block: Optional[int] = None,
                            page_size: Optional[int] = None,
                            interpret: Optional[bool] = None,
                            tune: Optional[bool] = None):
    """q (B, S, H, D); kq, vq (B, T, K, D) int8 with per-row fp32
    scales ks, vs (B, T) (``quantize_kv_rows`` layout) -> (B, S, H, D).
    ``page_size`` (the cache pool's page length) keeps the legalized KV
    block page-aligned so dequant scales never straddle a page."""
    return registry.call(
        "flash_attention_dequant", q, kq, ks, vq, vs, causal=causal,
        q_offset=q_offset, softmax_mode=softmax_mode,
        config={"q_block": q_block, "kv_block": kv_block,
                "page_size": page_size},
        interpret=interpret, tune=tune)


def decode_attention(q, k, v, kv_valid_len, tables=None, ks=None, vs=None,
                     softmax_mode: str = "exact",
                     kv_block: Optional[int] = None,
                     slot_block: Optional[int] = None,
                     page_size: Optional[int] = None,
                     interpret: Optional[bool] = None,
                     tune: Optional[bool] = None):
    """q_len=1 decode attention: q (B, 1, H, D) -> (B, 1, H, D).

    Dense cache: k/v (B, T, K, D), optionally int8 with per-row fp32
    scales ks/vs (B, T); ``kv_valid_len`` (B,) masks each slot's ragged
    tail.  Paged cache: k/v are the pool's (n_pages, page, K, D) leaves
    (scales (n_pages, page)) and ``tables`` (B, P) holds each slot's
    page ids, pre-clipped to valid pool pages (sentinel entries rely on
    ``kv_valid_len`` masking).
    """
    return registry.call(
        "decode_attention", q, k, v, kv_valid_len, tables=tables,
        ks=ks, vs=vs, softmax_mode=softmax_mode,
        config={"kv_block": kv_block, "slot_block": slot_block,
                "page_size": page_size},
        interpret=interpret, tune=tune)


def fused_sampling(logits, temperature, seeds, pos, top_k=None, top_p=None,
                   row_block: Optional[int] = None,
                   interpret: Optional[bool] = None,
                   tune: Optional[bool] = None):
    """Fused device sampling: logits (B, V) + per-row temperature /
    seed / position / top_k / top_p -> (B,) int32 tokens.  Scalars are
    broadcast; ``top_k=None``/``0`` and ``top_p=None``/``1.0`` disable
    the respective restriction."""
    import jax.numpy as jnp

    b = logits.shape[0]

    def _row(x, dtype, default):
        if x is None:
            x = default
        return jnp.broadcast_to(jnp.asarray(x, dtype), (b,))

    return registry.call(
        "fused_sampling", logits,
        _row(temperature, jnp.float32, 0.0),
        _row(seeds, jnp.int32, 0),
        _row(pos, jnp.int32, 0),
        _row(top_k, jnp.int32, 0),
        _row(top_p, jnp.float32, 1.0),
        config={"row_block": row_block}, interpret=interpret, tune=tune)
