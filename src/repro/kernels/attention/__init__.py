# Dispatch lives in repro.kernels.registry ("flash_attention"); this
# package keeps the Pallas body and the jnp oracle only.
from repro.kernels.attention import ref  # noqa: F401
from repro.kernels.attention.kernel import flash_attention_pallas  # noqa: F401
