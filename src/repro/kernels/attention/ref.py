"""Oracle for the flash-attention kernel: exact GQA attention, fp32."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                  causal: bool = True, q_offset: int = 0) -> jax.Array:
    """q (B, S, H, D); k, v (B, T, K, D); H = K * G -> (B, S, H, D)."""
    b, s, h, d = q.shape
    t, nkv = k.shape[1], k.shape[2]
    g = h // nkv
    qg = q.reshape(b, s, nkv, g, d).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, kf) / math.sqrt(d)
    if causal:
        qpos = jnp.arange(s) + q_offset
        kpos = jnp.arange(t)
        mask = (kpos[None, :] <= qpos[:, None])[None, None, None]
        scores = jnp.where(mask, scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", p, vf)
    return out.reshape(b, s, h, d).astype(q.dtype)


def attention_dequant_ref(q: jax.Array, kq: jax.Array, ks: jax.Array,
                          vq: jax.Array, vs: jax.Array,
                          causal: bool = True, q_offset: int = 0
                          ) -> jax.Array:
    """Oracle for the dequantizing kernel: dequantize the int8 KV rows
    (``kq``/``vq`` (B, T, K, D) with per-row scales ``ks``/``vs``
    (B, T) — ``repro.models.attention.quantize_kv_rows`` layout), then
    exact fp32 attention."""
    k = kq.astype(jnp.float32) * ks[..., None, None]
    v = vq.astype(jnp.float32) * vs[..., None, None]
    return attention_ref(q, k, v, causal=causal, q_offset=q_offset)


def decode_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                         kv_valid_len: jax.Array, tables=None,
                         ks=None, vs=None) -> jax.Array:
    """Oracle for the q_len=1 decode kernel.

    Dense cache:  q (B, 1, H, D); k/v (B, T, K, D); optional ``ks``/``vs``
    (B, T) per-row scales when k/v are int8.

    Paged cache:  k/v are pool leaves (n_pages, page, K, D) and ``tables``
    (B, P) maps each slot's page index to a pool page; optional scales are
    the pool scale leaves (n_pages, page).  Sentinel (negative) table
    entries address page 0 after clipping and rely on ``kv_valid_len``
    masking, mirroring the kernel.

    Rows with ``kv_valid_len <= 0`` return zeros (the kernel's init state
    is never overwritten for them).
    """
    b, s, h, d = q.shape
    if tables is not None:
        n_pages, page = k.shape[0], k.shape[1]
        tv = jnp.clip(tables.astype(jnp.int32), 0, n_pages - 1)
        per_slot = tv.shape[1] * page
        k = k[tv].reshape(b, per_slot, k.shape[2], k.shape[3])
        v = v[tv].reshape(b, per_slot, v.shape[2], v.shape[3])
        if ks is not None:
            ks = ks[tv].reshape(b, per_slot)
            vs = vs[tv].reshape(b, per_slot)
    if ks is not None:
        k = k.astype(jnp.float32) * ks[..., None, None]
        v = v.astype(jnp.float32) * vs[..., None, None]
    t, nkv = k.shape[1], k.shape[2]
    g = h // nkv
    qg = q.reshape(b, s, nkv, g, d).astype(jnp.float32)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg,
                        k.astype(jnp.float32)) / math.sqrt(d)
    valid = kv_valid_len.astype(jnp.int32)
    mask = (jnp.arange(t)[None, :] < valid[:, None])[:, None, None, None]
    scores = jnp.where(mask, scores, NEG_INF)
    m = jnp.max(scores, axis=-1, keepdims=True)
    e = jnp.where(mask, jnp.exp(scores - m), 0.0)
    out = jnp.einsum("bkgst,btkd->bskgd", e, v.astype(jnp.float32))
    l = jnp.maximum(jnp.sum(e, axis=-1), 1e-30)        # (b, k, g, s)
    out = out / l.transpose(0, 3, 1, 2)[..., None]
    return out.reshape(b, s, h, d).astype(q.dtype)
