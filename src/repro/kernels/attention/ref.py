"""Oracle for the flash-attention kernel: exact GQA attention, fp32."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                  causal: bool = True, q_offset: int = 0) -> jax.Array:
    """q (B, S, H, D); k, v (B, T, K, D); H = K * G -> (B, S, H, D)."""
    b, s, h, d = q.shape
    t, nkv = k.shape[1], k.shape[2]
    g = h // nkv
    qg = q.reshape(b, s, nkv, g, d).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, kf) / math.sqrt(d)
    if causal:
        qpos = jnp.arange(s) + q_offset
        kpos = jnp.arange(t)
        mask = (kpos[None, :] <= qpos[:, None])[None, None, None]
        scores = jnp.where(mask, scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", p, vf)
    return out.reshape(b, s, h, d).astype(q.dtype)


def attention_dequant_ref(q: jax.Array, kq: jax.Array, ks: jax.Array,
                          vq: jax.Array, vs: jax.Array,
                          causal: bool = True, q_offset: int = 0
                          ) -> jax.Array:
    """Oracle for the dequantizing kernel: dequantize the int8 KV rows
    (``kq``/``vq`` (B, T, K, D) with per-row scales ``ks``/``vs``
    (B, T) — ``repro.models.attention.quantize_kv_rows`` layout), then
    exact fp32 attention."""
    k = kq.astype(jnp.float32) * ks[..., None, None]
    v = vq.astype(jnp.float32) * vs[..., None, None]
    return attention_ref(q, k, v, causal=causal, q_offset=q_offset)
