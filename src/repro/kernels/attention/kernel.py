"""Blocked flash attention (Pallas, TPU target) with GQA and optional
Taylor-softmax (paper Eq. 2) as the exp.

Layout and grid
---------------
    q   (BK, G, S, D)    BK = batch * kv_heads, G = query heads per KV head
    k,v (BK, T, D)
    out (BK, G, S, D)

    grid = (BK, G, num_q_blocks, num_kv_blocks)     kv minor-most

The kv axis is the sequential ("arbitrary") axis: online-softmax running
max ``m``, denominator ``l`` and the output accumulator live in VMEM
scratch and persist across kv grid steps (canonical Pallas-TPU flash
pattern).  Block shapes default to (q=512, kv=512): with D=128 fp32 that is
q 256 KB + k/v 512 KB + acc 256 KB ~ 1.3 MB — comfortably VMEM-resident
with headroom for double buffering.

Causal masking: kv blocks fully above the diagonal are skipped with
``pl.when`` (no MXU work); the diagonal block applies the element mask.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.approx_math import E_A, TAYLOR_COEFFS

NEG_INF = -1e30


def _exp(x, mode: str):
    if mode != "taylor":
        return jnp.exp(x)
    c0, c1, c2, c3, c4, c5 = TAYLOR_COEFFS
    scale = 32.0
    x = jnp.clip(x, -scale, scale) / scale
    p = c4 + c5 * x
    p = c3 + x * p
    p = c2 + x * p
    p = c1 + x * p
    p = c0 + x * p
    y = E_A * p
    for _ in range(5):
        y = y * y
    return y


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  causal: bool, q_offset: int, q_block: int, kv_block: int,
                  n_kv_blocks: int, softmax_mode: str, scale: float):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * q_block + q_offset          # traced (depends on program_id)
    k_start = ki * kv_block

    def _body():
        q = q_ref[0, 0].astype(jnp.float32) * scale     # (Qb, D)
        k = k_ref[0].astype(jnp.float32)                # (Kb, D)
        v = v_ref[0].astype(jnp.float32)                # (Kb, D)
        s = jax.lax.dot_general(
            q, k, dimension_numbers=(((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)         # (Qb, Kb)
        if causal:
            qpos = q_start + jax.lax.broadcasted_iota(
                jnp.int32, (q_block, kv_block), 0)
            kpos = k_start + jax.lax.broadcasted_iota(
                jnp.int32, (q_block, kv_block), 1)
            mask = kpos <= qpos
            s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]                             # (Qb,)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        alpha = _exp(m_prev - m_new, softmax_mode)
        p = _exp(s - m_new[:, None], softmax_mode)
        if causal:  # zero lanes the approx exp left non-zero under the mask
            p = jnp.where(mask, p, 0.0)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=-1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    if causal:
        # skip kv blocks fully above the causal diagonal
        pl.when(k_start <= q_start + q_block - 1)(_body)
    else:
        _body()

    @pl.when(ki == n_kv_blocks - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jax.Array, k: jax.Array, v: jax.Array,
    causal: bool = True, q_offset: int = 0,
    q_block: int = 512, kv_block: int = 512,
    softmax_mode: str = "exact",
    interpret: bool = True,
) -> jax.Array:
    """q (BK, G, S, D), k/v (BK, T, D) -> (BK, G, S, D)."""
    bk, g, s, d = q.shape
    t = k.shape[1]
    qb = min(q_block, s)
    while s % qb:
        qb //= 2
    kb = min(kv_block, t)
    while t % kb:
        kb //= 2
    n_kv = t // kb
    grid = (bk, g, s // qb, n_kv)
    kernel = functools.partial(
        _flash_kernel, causal=causal, q_offset=q_offset, q_block=qb,
        kv_block=kb, n_kv_blocks=n_kv, softmax_mode=softmax_mode,
        scale=1.0 / math.sqrt(d))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, qb, d), lambda b, g_, i, j: (b, g_, i, 0)),
            pl.BlockSpec((1, kb, d), lambda b, g_, i, j: (b, j, 0)),
            pl.BlockSpec((1, kb, d), lambda b, g_, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, qb, d),
                               lambda b, g_, i, j: (b, g_, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bk, g, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((qb,), jnp.float32),
            pltpu.VMEM((qb,), jnp.float32),
            pltpu.VMEM((qb, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)


# ---------------------------------------------------------------------------
# Dequantizing flash attention (int8 KV pages + per-row fp32 scales)
# ---------------------------------------------------------------------------


def _flash_dequant_kernel(q_ref, kq_ref, ks_ref, vq_ref, vs_ref, o_ref,
                          m_scr, l_scr, acc_scr, *,
                          causal: bool, q_offset: int, q_block: int,
                          kv_block: int, n_kv_blocks: int,
                          softmax_mode: str, scale: float):
    """The flash body of :func:`_flash_kernel` with int8 KV blocks
    dequantized on read (guide: "Dequantization" pattern): each KV block
    streams in as int8 plus its per-row fp32 scales, and the fp32
    k/v used by the MXU dots exist only block-at-a-time in VMEM — the
    resident cache stays int8 end to end."""
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * q_block + q_offset
    k_start = ki * kv_block

    def _body():
        q = q_ref[0, 0].astype(jnp.float32) * scale              # (Qb, D)
        k = kq_ref[0].astype(jnp.float32) * ks_ref[0][:, None]   # (Kb, D)
        v = vq_ref[0].astype(jnp.float32) * vs_ref[0][:, None]
        s = jax.lax.dot_general(
            q, k, dimension_numbers=(((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)                  # (Qb, Kb)
        if causal:
            qpos = q_start + jax.lax.broadcasted_iota(
                jnp.int32, (q_block, kv_block), 0)
            kpos = k_start + jax.lax.broadcasted_iota(
                jnp.int32, (q_block, kv_block), 1)
            mask = kpos <= qpos
            s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        alpha = _exp(m_prev - m_new, softmax_mode)
        p = _exp(s - m_new[:, None], softmax_mode)
        if causal:
            p = jnp.where(mask, p, 0.0)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=-1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    if causal:
        pl.when(k_start <= q_start + q_block - 1)(_body)
    else:
        _body()

    @pl.when(ki == n_kv_blocks - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_dequant_pallas(
    q: jax.Array, kq: jax.Array, ks: jax.Array,
    vq: jax.Array, vs: jax.Array,
    causal: bool = True, q_offset: int = 0,
    q_block: int = 512, kv_block: int = 512, page_size: int = 1,
    softmax_mode: str = "exact",
    interpret: bool = True,
) -> jax.Array:
    """q (BK, G, S, D); kq/vq (BK, T, D) int8; ks/vs (BK, T) fp32.

    ``page_size`` is the paged-cache page length the KV axis was written
    in: KV blocks are kept page-aligned (``kv_block`` a multiple of
    ``page_size`` whenever the sequence allows it), so a block's scale
    rows never straddle a partially-resident page.
    """
    bk, g, s, d = q.shape
    t = kq.shape[1]
    qb = min(q_block, s)
    while s % qb:
        qb //= 2
    ps = max(int(page_size), 1)
    while t % ps:                      # degrade like the block sizes do
        ps = max(ps // 2, 1)
    kb = max(min(kv_block, t) // ps * ps, ps)
    while t % kb:
        kb -= ps
    n_kv = t // kb
    grid = (bk, g, s // qb, n_kv)
    kernel = functools.partial(
        _flash_dequant_kernel, causal=causal, q_offset=q_offset, q_block=qb,
        kv_block=kb, n_kv_blocks=n_kv, softmax_mode=softmax_mode,
        scale=1.0 / math.sqrt(d))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, qb, d), lambda b, g_, i, j: (b, g_, i, 0)),
            pl.BlockSpec((1, kb, d), lambda b, g_, i, j: (b, j, 0)),
            pl.BlockSpec((1, kb), lambda b, g_, i, j: (b, j)),
            pl.BlockSpec((1, kb, d), lambda b, g_, i, j: (b, j, 0)),
            pl.BlockSpec((1, kb), lambda b, g_, i, j: (b, j)),
        ],
        out_specs=pl.BlockSpec((1, 1, qb, d),
                               lambda b, g_, i, j: (b, g_, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bk, g, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((qb,), jnp.float32),
            pltpu.VMEM((qb,), jnp.float32),
            pltpu.VMEM((qb, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, kq, ks, vq, vs)


# ---------------------------------------------------------------------------
# Decode attention (q_len = 1, ragged kv_valid_len, dense or paged cache)
# ---------------------------------------------------------------------------
#
# Layout: q (B, K, G, D) — one query token per slot, grouped heads.
#   dense cache   k/v (B, T, K, D);  grid (B/slot_block, K, T/kv_block)
#   paged cache   k/v are pool leaves (n_pages, page, K, D); the per-slot
#                 page tables ride in as a scalar-prefetch operand and the
#                 kv BlockSpec index_map reads ``tables[slot, page_idx]``
#                 directly, so blocks stream straight out of the pool with
#                 no gather-to-dense materialization; grid (B, K, P)
#
# The kv axis stays minor-most/sequential; m/l/acc scratch persists across
# kv steps exactly like the flash kernels above.  ``kv_valid_len`` masks
# ragged tails (and, paged, any sentinel page past the write head); blocks
# entirely past every slot's valid length are skipped with ``pl.when`` —
# for the paged grid (slot_block=1) that means a slot only ever touches
# its own resident pages.  NEG_INF is finite, so fully-masked rows keep
# m = NEG_INF, l = 0 without NaNs and finish as zeros.


def _decode_update(q, k, v, valid, k_start, m_scr, l_scr, acc_scr, *,
                   slot_block: int, kv_block: int, softmax_mode: str):
    s = jax.lax.dot_general(
        q, k, dimension_numbers=(((2,), (2,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)             # (Sb, G, Kb)
    kpos = k_start + jax.lax.broadcasted_iota(
        jnp.int32, (slot_block, kv_block), 1)
    mask = (kpos < valid[:, None])[:, None, :]          # (Sb, 1, Kb)
    s = jnp.where(mask, s, NEG_INF)
    m_prev = m_scr[...]                                 # (Sb, G)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    alpha = _exp(m_prev - m_new, softmax_mode)
    p = jnp.where(mask, _exp(s - m_new[..., None], softmax_mode), 0.0)
    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=-1)
    acc_scr[...] = acc_scr[...] * alpha[..., None] + jax.lax.dot_general(
        p, v, dimension_numbers=(((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)             # (Sb, G, D)
    m_scr[...] = m_new


def _decode_kernel(q_ref, k_ref, v_ref, valid_ref, o_ref,
                   m_scr, l_scr, acc_scr, *,
                   slot_block: int, kv_block: int, n_kv_blocks: int,
                   softmax_mode: str, scale: float):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    k_start = ki * kv_block
    valid = valid_ref[:, 0]                             # (Sb,) int32

    def _body():
        q = q_ref[:, 0].astype(jnp.float32) * scale     # (Sb, G, D)
        k = k_ref[:, :, 0].astype(jnp.float32)          # (Sb, Kb, D)
        v = v_ref[:, :, 0].astype(jnp.float32)
        _decode_update(q, k, v, valid, k_start, m_scr, l_scr, acc_scr,
                       slot_block=slot_block, kv_block=kv_block,
                       softmax_mode=softmax_mode)

    pl.when(k_start < jnp.max(valid))(_body)

    @pl.when(ki == n_kv_blocks - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[:, 0] = (acc_scr[...] / l[..., None]).astype(o_ref.dtype)


def _decode_dequant_kernel(q_ref, kq_ref, ks_ref, vq_ref, vs_ref, valid_ref,
                           o_ref, m_scr, l_scr, acc_scr, *,
                           slot_block: int, kv_block: int, n_kv_blocks: int,
                           softmax_mode: str, scale: float):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    k_start = ki * kv_block
    valid = valid_ref[:, 0]

    def _body():
        q = q_ref[:, 0].astype(jnp.float32) * scale
        k = kq_ref[:, :, 0].astype(jnp.float32) * ks_ref[...][:, :, None]
        v = vq_ref[:, :, 0].astype(jnp.float32) * vs_ref[...][:, :, None]
        _decode_update(q, k, v, valid, k_start, m_scr, l_scr, acc_scr,
                       slot_block=slot_block, kv_block=kv_block,
                       softmax_mode=softmax_mode)

    pl.when(k_start < jnp.max(valid))(_body)

    @pl.when(ki == n_kv_blocks - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[:, 0] = (acc_scr[...] / l[..., None]).astype(o_ref.dtype)


def _decode_blocks(b: int, t: int, kv_block: int, slot_block: int):
    sb = max(min(int(slot_block), b), 1)
    while b % sb:
        sb -= 1
    kb = max(min(int(kv_block), t), 1)
    while t % kb:
        kb //= 2
    return sb, kb


def _decode_scratch(sb: int, g: int, d: int):
    return [pltpu.VMEM((sb, g), jnp.float32),
            pltpu.VMEM((sb, g), jnp.float32),
            pltpu.VMEM((sb, g, d), jnp.float32)]


def decode_attention_pallas(
    q: jax.Array, k: jax.Array, v: jax.Array, kv_valid_len: jax.Array,
    kv_block: int = 512, slot_block: int = 1,
    softmax_mode: str = "exact", interpret: bool = True,
) -> jax.Array:
    """q (B, K, G, D); k/v (B, T, K, D); kv_valid_len (B,) -> (B, K, G, D)."""
    b, nkv, g, d = q.shape
    t = k.shape[1]
    sb, kb = _decode_blocks(b, t, kv_block, slot_block)
    n_kv = t // kb
    kernel = functools.partial(
        _decode_kernel, slot_block=sb, kv_block=kb, n_kv_blocks=n_kv,
        softmax_mode=softmax_mode, scale=1.0 / math.sqrt(d))
    return pl.pallas_call(
        kernel,
        grid=(b // sb, nkv, n_kv),
        in_specs=[
            pl.BlockSpec((sb, 1, g, d), lambda si, ki, ji: (si, ki, 0, 0)),
            pl.BlockSpec((sb, kb, 1, d), lambda si, ki, ji: (si, ji, ki, 0)),
            pl.BlockSpec((sb, kb, 1, d), lambda si, ki, ji: (si, ji, ki, 0)),
            pl.BlockSpec((sb, 1), lambda si, ki, ji: (si, 0)),
        ],
        out_specs=pl.BlockSpec((sb, 1, g, d),
                               lambda si, ki, ji: (si, ki, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, nkv, g, d), q.dtype),
        scratch_shapes=_decode_scratch(sb, g, d),
        interpret=interpret,
    )(q, k, v, kv_valid_len.astype(jnp.int32).reshape(b, 1))


def decode_attention_dequant_pallas(
    q: jax.Array, kq: jax.Array, ks: jax.Array,
    vq: jax.Array, vs: jax.Array, kv_valid_len: jax.Array,
    kv_block: int = 512, slot_block: int = 1,
    softmax_mode: str = "exact", interpret: bool = True,
) -> jax.Array:
    """q (B, K, G, D); kq/vq (B, T, K, D) int8; ks/vs (B, T) fp32."""
    b, nkv, g, d = q.shape
    t = kq.shape[1]
    sb, kb = _decode_blocks(b, t, kv_block, slot_block)
    n_kv = t // kb
    kernel = functools.partial(
        _decode_dequant_kernel, slot_block=sb, kv_block=kb, n_kv_blocks=n_kv,
        softmax_mode=softmax_mode, scale=1.0 / math.sqrt(d))
    return pl.pallas_call(
        kernel,
        grid=(b // sb, nkv, n_kv),
        in_specs=[
            pl.BlockSpec((sb, 1, g, d), lambda si, ki, ji: (si, ki, 0, 0)),
            pl.BlockSpec((sb, kb, 1, d), lambda si, ki, ji: (si, ji, ki, 0)),
            pl.BlockSpec((sb, kb), lambda si, ki, ji: (si, ji)),
            pl.BlockSpec((sb, kb, 1, d), lambda si, ki, ji: (si, ji, ki, 0)),
            pl.BlockSpec((sb, kb), lambda si, ki, ji: (si, ji)),
            pl.BlockSpec((sb, 1), lambda si, ki, ji: (si, 0)),
        ],
        out_specs=pl.BlockSpec((sb, 1, g, d),
                               lambda si, ki, ji: (si, ki, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, nkv, g, d), q.dtype),
        scratch_shapes=_decode_scratch(sb, g, d),
        interpret=interpret,
    )(q, kq, ks, vq, vs, kv_valid_len.astype(jnp.int32).reshape(b, 1))


def decode_attention_paged_pallas(
    q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
    kv_valid_len: jax.Array, tables: jax.Array,
    softmax_mode: str = "exact", interpret: bool = True,
) -> jax.Array:
    """q (B, K, G, D); k/v pool leaves (n_pages, page, K, D); ``tables``
    (B, P) pre-clipped page ids (scalar-prefetch operand, read inside the
    kv index_maps); kv_valid_len (B,) slot-local lengths."""
    b, nkv, g, d = q.shape
    ps = k_pages.shape[1]
    p_per = tables.shape[1]
    kernel = functools.partial(
        _decode_kernel, slot_block=1, kv_block=ps, n_kv_blocks=p_per,
        softmax_mode=softmax_mode, scale=1.0 / math.sqrt(d))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, nkv, p_per),
        in_specs=[
            pl.BlockSpec((1, 1, g, d), lambda bi, ki, pi, tb: (bi, ki, 0, 0)),
            pl.BlockSpec((1, ps, 1, d),
                         lambda bi, ki, pi, tb: (tb[bi, pi], 0, ki, 0)),
            pl.BlockSpec((1, ps, 1, d),
                         lambda bi, ki, pi, tb: (tb[bi, pi], 0, ki, 0)),
            pl.BlockSpec((1, 1), lambda bi, ki, pi, tb: (bi, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d),
                               lambda bi, ki, pi, tb: (bi, ki, 0, 0)),
        scratch_shapes=_decode_scratch(1, g, d),
    )
    def kernel_with_tables(tables_ref, *refs):
        del tables_ref                       # consumed by the index_maps
        kernel(*refs)
    return pl.pallas_call(
        kernel_with_tables,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, nkv, g, d), q.dtype),
        interpret=interpret,
    )(tables.astype(jnp.int32), q, k_pages, v_pages,
      kv_valid_len.astype(jnp.int32).reshape(b, 1))


def decode_attention_paged_dequant_pallas(
    q: jax.Array, k_pages: jax.Array, ks_pages: jax.Array,
    v_pages: jax.Array, vs_pages: jax.Array,
    kv_valid_len: jax.Array, tables: jax.Array,
    softmax_mode: str = "exact", interpret: bool = True,
) -> jax.Array:
    """Paged decode over int8 pool leaves with per-row fp32 scale leaves
    (n_pages, page) — dequantized block-at-a-time on read."""
    b, nkv, g, d = q.shape
    ps = k_pages.shape[1]
    p_per = tables.shape[1]
    kernel = functools.partial(
        _decode_dequant_kernel, slot_block=1, kv_block=ps, n_kv_blocks=p_per,
        softmax_mode=softmax_mode, scale=1.0 / math.sqrt(d))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, nkv, p_per),
        in_specs=[
            pl.BlockSpec((1, 1, g, d), lambda bi, ki, pi, tb: (bi, ki, 0, 0)),
            pl.BlockSpec((1, ps, 1, d),
                         lambda bi, ki, pi, tb: (tb[bi, pi], 0, ki, 0)),
            pl.BlockSpec((1, ps), lambda bi, ki, pi, tb: (tb[bi, pi], 0)),
            pl.BlockSpec((1, ps, 1, d),
                         lambda bi, ki, pi, tb: (tb[bi, pi], 0, ki, 0)),
            pl.BlockSpec((1, ps), lambda bi, ki, pi, tb: (tb[bi, pi], 0)),
            pl.BlockSpec((1, 1), lambda bi, ki, pi, tb: (bi, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d),
                               lambda bi, ki, pi, tb: (bi, ki, 0, 0)),
        scratch_shapes=_decode_scratch(1, g, d),
    )
    def kernel_with_tables(tables_ref, *refs):
        del tables_ref
        kernel(*refs)
    return pl.pallas_call(
        kernel_with_tables,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, nkv, g, d), q.dtype),
        interpret=interpret,
    )(tables.astype(jnp.int32), q, k_pages, ks_pages, v_pages, vs_pages,
      kv_valid_len.astype(jnp.int32).reshape(b, 1))
