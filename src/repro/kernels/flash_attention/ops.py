"""Jitted public wrapper: (B, S, H, D) GQA API over the flash kernel."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import needs_interpret
from repro.kernels.flash_attention.kernel import flash_attention_pallas


@functools.partial(jax.jit, static_argnames=(
    "causal", "q_offset", "q_block", "kv_block", "softmax_mode", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = True, q_offset: int = 0,
                    q_block: int = 512, kv_block: int = 512,
                    softmax_mode: str = "exact",
                    interpret: bool | None = None) -> jax.Array:
    """q (B, S, H, D); k, v (B, T, K, D); H = K * G -> (B, S, H, D)."""
    if interpret is None:
        interpret = needs_interpret()
    b, s, h, d = q.shape
    t, nkv = k.shape[1], k.shape[2]
    g = h // nkv
    qr = (q.reshape(b, s, nkv, g, d).transpose(0, 2, 3, 1, 4)
          .reshape(b * nkv, g, s, d))
    kr = k.transpose(0, 2, 1, 3).reshape(b * nkv, t, d)
    vr = v.transpose(0, 2, 1, 3).reshape(b * nkv, t, d)
    o = flash_attention_pallas(
        qr, kr, vr, causal=causal, q_offset=q_offset, q_block=q_block,
        kv_block=kv_block, softmax_mode=softmax_mode, interpret=interpret)
    return (o.reshape(b, nkv, g, s, d).transpose(0, 3, 1, 2, 4)
            .reshape(b, s, h, d))
