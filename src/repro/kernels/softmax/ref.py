"""Oracle for the Taylor-softmax kernel: Eq. 2 softmax over the last axis."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import approx_math


def taylor_softmax_ref(x: jax.Array, range_reduce: bool = True) -> jax.Array:
    m = jnp.max(x.astype(jnp.float32), axis=-1, keepdims=True)
    e = approx_math.taylor_exp(x.astype(jnp.float32) - m,
                               range_reduce=range_reduce)
    return (e / jnp.maximum(jnp.sum(e, axis=-1, keepdims=True), 1e-30)
            ).astype(x.dtype)
