# Dispatch lives in repro.kernels.registry ("taylor_softmax"); this
# package keeps the Pallas body and the jnp oracle only.
from repro.kernels.softmax import ref  # noqa: F401
from repro.kernels.softmax.kernel import taylor_softmax_pallas  # noqa: F401
