"""Taylor-softmax Pallas kernel — paper Eq. 2 as a row-tiled TPU kernel.

The Eq. 2 polynomial is pure MAC work (5 mul + 5 add, Horner), so the whole
softmax is VPU element-wise ops + a row reduction: no transcendental path.
Row blocks are tiled to (row_block, N); N (the softmax axis) stays whole in
VMEM because softmax is a full-row reduction.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.approx_math import E_A, TAYLOR_COEFFS


def _taylor_exp_inline(x, reduce_k: int = 5):
    c0, c1, c2, c3, c4, c5 = TAYLOR_COEFFS
    scale = float(2 ** reduce_k)
    x = jnp.clip(x, -scale, scale) / scale
    p = c4 + c5 * x
    p = c3 + x * p
    p = c2 + x * p
    p = c1 + x * p
    p = c0 + x * p
    y = E_A * p
    for _ in range(reduce_k):
        y = y * y
    return y


def _softmax_kernel(x_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)                # (Rb, N)
    m = jnp.max(x, axis=-1, keepdims=True)
    e = _taylor_exp_inline(x - m)
    o = e / jnp.maximum(jnp.sum(e, axis=-1, keepdims=True), 1e-30)
    o_ref[...] = o.astype(o_ref.dtype)


def taylor_softmax_pallas(x: jax.Array, row_block: int = 256,
                          interpret: bool = True) -> jax.Array:
    """Softmax over the last axis of x (any leading shape) using Eq. 2."""
    shape = x.shape
    n = shape[-1]
    x2 = x.reshape(-1, n)
    rows = x2.shape[0]
    rb = min(row_block, rows)
    while rows % rb:
        rb -= 1
    out = pl.pallas_call(
        _softmax_kernel,
        grid=(rows // rb,),
        in_specs=[pl.BlockSpec((rb, n), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((rb, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, n), x.dtype),
        interpret=interpret,
    )(x2)
    return out.reshape(shape)
