"""Fused device sampling (Pallas): temperature / top-k / top-p masking
and the categorical draw happen in one launch over a block of rows, so a
serving tick's sampled tokens leave the device as a single (B,) int32
transfer instead of a per-row host numpy loop over full logit rows.

The body is :func:`repro.kernels.sampling.ref.sample_tokens` applied to
the VMEM-resident row block — the kernel adds the blocking/fusion, the
math lives in one place (which is what makes oracle parity exact).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.sampling.ref import sample_tokens


def _fused_sampling_kernel(logits_ref, t_ref, seed_ref, pos_ref, tk_ref,
                           tp_ref, o_ref):
    tok = sample_tokens(logits_ref[...],
                        t_ref[...][:, 0], seed_ref[...][:, 0],
                        pos_ref[...][:, 0], tk_ref[...][:, 0],
                        tp_ref[...][:, 0])
    o_ref[...] = tok[:, None]


def fused_sampling_pallas(
    logits: jax.Array, temperature: jax.Array, seeds: jax.Array,
    pos: jax.Array, top_k: jax.Array, top_p: jax.Array,
    row_block: int = 8, interpret: bool = True,
) -> jax.Array:
    """logits (B, V); temperature/seeds/pos/top_k/top_p (B,) -> (B,) i32."""
    b, v = logits.shape
    rb = max(min(int(row_block), b), 1)
    while b % rb:
        rb -= 1
    col = pl.BlockSpec((rb, 1), lambda i: (i, 0))
    out = pl.pallas_call(
        _fused_sampling_kernel,
        grid=(b // rb,),
        in_specs=[pl.BlockSpec((rb, v), lambda i: (i, 0)),
                  col, col, col, col, col],
        out_specs=pl.BlockSpec((rb, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, 1), jnp.int32),
        interpret=interpret,
    )(logits,
      temperature.astype(jnp.float32).reshape(b, 1),
      seeds.astype(jnp.int32).reshape(b, 1),
      pos.astype(jnp.int32).reshape(b, 1),
      top_k.astype(jnp.int32).reshape(b, 1),
      top_p.astype(jnp.float32).reshape(b, 1))
    return out[:, 0]
