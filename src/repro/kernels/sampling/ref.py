"""Counter-based fused sampling: the math, shared by kernel and oracle.

Sampling must be reproducible and *slot-order independent*: a request's
token at sequence position ``pos`` may be drawn on any engine, any slot,
any batch composition, before or after a preemption or a disaggregated
handoff.  So the randomness is a pure counter-based hash of
``(request seed, position, vocab lane)`` — no RNG state object travels
anywhere — and the draw is a Gumbel-argmax over the kept lanes:

    h    = seed ^ (pos * 0x9E3779B9) ^ (lane * 0x85EBCA6B)   (uint32)
    h    = fmix32(h)                    # murmur3 finalizer
    u    = (h >> 8) * 2^-24, clamped >= 1e-7
    tok  = argmax_{kept lanes}( logits/T + (-log(-log u)) )

Top-k / top-p restrict the kept lanes via a 30-step bisection over the
scaled-logit value range (vectorized over rows; no sort, no O(V^2)
pairwise compare — both are hostile to the TPU vector unit).  The argmax
lane is always kept, and greedy (``temperature <= 0``) bypasses the draw
entirely with an exact raw-logits argmax, so temperature=0 decode is
bit-identical to the pre-kernel path.

:func:`sample_tokens` is the single source of truth: the Pallas kernel
body calls it on its VMEM blocks and :func:`fused_sampling_ref` calls it
whole-batch, which is what makes kernel-vs-oracle parity exact (same op
sequence, not merely allclose).  :func:`sample_token_host` is the numpy
mirror used by the host-sampling engine path — same algorithm and
constants; libm vs XLA transcendentals may differ in the last ulp, so
cross-path identity is only asserted for greedy.
"""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

NEG_INF = -1e30
_GOLD = 0x9E3779B9        # 2^32 / golden ratio — position stride
_MIX1 = 0x85EBCA6B        # murmur3 fmix32 constants
_MIX2 = 0xC2B2AE35
_BISECT_STEPS = 30        # halves the f32 value range to ~1e-9 resolution


def _uniform_lanes(seeds, pos, b: int, v: int):
    """(b, v) uniforms in (0, 1), pure function of (seed, pos, lane)."""
    lane = jax.lax.broadcasted_iota(jnp.uint32, (b, v), 1)
    h = (seeds.astype(jnp.uint32)[:, None]
         ^ (pos.astype(jnp.uint32)[:, None] * jnp.uint32(_GOLD))
         ^ (lane * jnp.uint32(_MIX1)))
    h = h ^ (h >> 16)
    h = h * jnp.uint32(_MIX1)
    h = h ^ (h >> 13)
    h = h * jnp.uint32(_MIX2)
    h = h ^ (h >> 16)
    u = (h >> 8).astype(jnp.float32) * jnp.float32(1.0 / (1 << 24))
    return jnp.maximum(u, jnp.float32(1e-7))


def _topk_mask(z, k):
    """Keep lanes >= the k-th largest value of each row (ties kept).

    Bisection invariant: ``count(z >= lo) >= k`` always holds, so the
    final ``z >= lo`` mask never keeps fewer than k lanes.  ``k <= 0``
    means no top-k restriction.
    """
    b, v = z.shape
    k_eff = jnp.clip(jnp.where(k <= 0, v, k), 1, v).astype(jnp.int32)
    lo = jnp.min(z, axis=-1)
    hi = jnp.max(z, axis=-1)
    for _ in range(_BISECT_STEPS):
        mid = 0.5 * (lo + hi)
        ge = jnp.sum((z >= mid[:, None]).astype(jnp.int32), axis=-1) >= k_eff
        lo = jnp.where(ge, mid, lo)
        hi = jnp.where(ge, hi, mid)
    return z >= lo[:, None]


def _topp_mask(z, p):
    """Keep the smallest prefix of probability mass >= p (nucleus).

    Bisection invariant: ``sum(softmax(z)[z > lo]) >= p``, so ``z > lo``
    is the minimal covering set up to value-resolution ties.  ``p >= 1``
    keeps everything.
    """
    m = jnp.max(z, axis=-1, keepdims=True)
    e = jnp.exp(z - m)
    probs = e / jnp.sum(e, axis=-1, keepdims=True)
    lo = jnp.min(z, axis=-1) - 1.0
    hi = jnp.max(z, axis=-1)
    for _ in range(_BISECT_STEPS):
        mid = 0.5 * (lo + hi)
        c = jnp.sum(jnp.where(z > mid[:, None], probs, 0.0), axis=-1)
        ge = c >= p
        lo = jnp.where(ge, mid, lo)
        hi = jnp.where(ge, hi, mid)
    return (z > lo[:, None]) | (p >= 1.0)[:, None]


def sample_tokens(logits, temperature, seeds, pos, top_k, top_p):
    """logits (B, V); per-row temperature/seeds/pos/top_k/top_p (B,)
    -> (B,) int32 tokens.  Pure jnp; runs identically as the Pallas
    kernel body and as the whole-batch oracle."""
    x = logits.astype(jnp.float32)
    b, v = x.shape
    temperature = temperature.astype(jnp.float32).reshape(b)
    top_p = top_p.astype(jnp.float32).reshape(b)
    greedy = jnp.argmax(x, axis=-1).astype(jnp.int32)
    u = _uniform_lanes(seeds.reshape(b), pos.reshape(b), b, v)
    gumbel = -jnp.log(-jnp.log(u))
    z = x / jnp.maximum(temperature, 1e-6)[:, None]
    keep = _topk_mask(z, top_k.reshape(b)) & _topp_mask(z, top_p)
    lane = jax.lax.broadcasted_iota(jnp.int32, (b, v), 1)
    keep = keep | (lane == greedy[:, None])     # argmax is always a candidate
    sampled = jnp.argmax(jnp.where(keep, z + gumbel, NEG_INF),
                         axis=-1).astype(jnp.int32)
    return jnp.where(temperature <= 0.0, greedy, sampled)


def fused_sampling_ref(logits, temperature, seeds, pos, top_k, top_p):
    """Oracle for the fused sampling kernel — the same math, unblocked."""
    return sample_tokens(logits, temperature, seeds, pos, top_k, top_p)


def sample_token_host(logits_row, temperature, seed, pos,
                      top_k: int = 0, top_p: float = 1.0) -> int:
    """numpy mirror of :func:`sample_tokens` for one row — the host
    sampling path.  Greedy is bitwise the same argmax; temperature>0
    follows the identical algorithm (hash, bisections, Gumbel-argmax)."""
    x = np.asarray(logits_row, np.float32)
    if temperature <= 0.0:
        return int(np.argmax(x))
    v = x.shape[0]
    base = (int(seed) ^ ((int(pos) * _GOLD) & 0xFFFFFFFF)) & 0xFFFFFFFF
    lane = np.arange(v, dtype=np.uint32)
    h = np.uint32(base) ^ (lane * np.uint32(_MIX1))
    h = h ^ (h >> np.uint32(16))
    h = h * np.uint32(_MIX1)
    h = h ^ (h >> np.uint32(13))
    h = h * np.uint32(_MIX2)
    h = h ^ (h >> np.uint32(16))
    u = (h >> np.uint32(8)).astype(np.float32) * np.float32(1.0 / (1 << 24))
    u = np.maximum(u, np.float32(1e-7))
    gumbel = -np.log(-np.log(u))
    z = x / np.float32(max(float(temperature), 1e-6))
    k_eff = v if top_k <= 0 else min(max(int(top_k), 1), v)
    lo, hi = np.float32(z.min()), np.float32(z.max())
    for _ in range(_BISECT_STEPS):
        mid = np.float32(0.5) * (lo + hi)
        if int(np.sum(z >= mid)) >= k_eff:
            lo = mid
        else:
            hi = mid
    keep = z >= lo
    if top_p < 1.0:
        e = np.exp(z - z.max())
        probs = e / e.sum()
        lo, hi = np.float32(z.min() - 1.0), np.float32(z.max())
        for _ in range(_BISECT_STEPS):
            mid = np.float32(0.5) * (lo + hi)
            if float(probs[z > mid].sum()) >= top_p:
                lo = mid
            else:
                hi = mid
        keep &= z > lo
    keep[int(np.argmax(x))] = True
    return int(np.argmax(np.where(keep, z + gumbel, np.float32(NEG_INF))))
