from repro.kernels.sampling.kernel import fused_sampling_pallas
from repro.kernels.sampling.ref import (fused_sampling_ref, sample_token_host,
                                        sample_tokens)

__all__ = ["fused_sampling_pallas", "fused_sampling_ref",
           "sample_token_host", "sample_tokens"]
