"""Fused dynamic-routing Pallas TPU kernel.

Design (DESIGN.md §2 — the FPGA->TPU adaptation of "everything in BRAM"):

* One ``pallas_call`` runs ALL routing iterations for a block of batch rows.
  ``u_hat`` (B_blk, I, J, D), the logits ``b``, couplings ``c``, votes ``v``
  never leave VMEM between iterations — zero HBM round-trips inside the
  loop, vs. 3 x 4 tensor round-trips for the unfused jnp version.

* The paper's loop reordering (Code 1 -> Code 2: make j, k the outer loops
  so the PE array vectorizes over input capsules with no write conflict)
  becomes: the FC and Agreement contractions are expressed per parent
  capsule j (static Python loop — J is 10) as batched matmuls over the
  input-capsule axis I, which is the long, lane-aligned axis:

      FC:        s_j  = c[:, :, j] @ u[:, :, j, :]        (B, 1, I) x (B, I, D)
      Agreement: b_j += u[:, :, j, :] @ v[:, j, :, None]  (B, I, D) x (B, D, 1)

  Both land on the MXU with I contiguous in lanes; ``b`` is written once
  per (iteration, j) — no scatter.

* ``softmax_mode="taylor"`` uses the paper's Eq. 2 polynomial (pure MAC
  work — no transcendental path) for the coupling softmax.

Grid: 1-D over batch blocks.  VMEM per step for the unpruned MNIST CapsNet
(B_blk=8, I=1152, J=10, D=16, fp32) is ~5.9 MB; pruned (I=252) ~1.3 MB —
both fit the ~16 MB VMEM budget with headroom.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import approx_math


def _softmax_last(x: jax.Array, mode: str) -> jax.Array:
    m = jnp.max(x, axis=-1, keepdims=True)
    z = x - m
    e = (approx_math.taylor_exp(z, range_reduce=True) if mode == "taylor"
         else jnp.exp(z))
    return e / jnp.maximum(jnp.sum(e, axis=-1, keepdims=True), 1e-30)


def _routing_kernel(u_ref, v_ref, c_ref, *, n_iters: int, softmax_mode: str):
    u = u_ref[...].astype(jnp.float32)                 # (Bb, I, J, D)
    bb, n_in, n_out, d = u.shape
    b = jnp.zeros((bb, n_in, n_out), jnp.float32)
    c = None
    v = jnp.zeros((bb, n_out, d), jnp.float32)
    for it in range(n_iters):
        c = _softmax_last(b, softmax_mode)             # (Bb, I, J)
        # FC step, j as the outer loop (paper Code 2): per-parent matmul
        s_parts = []
        for j in range(n_out):
            cj = c[:, None, :, j]                      # (Bb, 1, I)
            uj = u[:, :, j, :]                         # (Bb, I, D)
            s_parts.append(
                jax.lax.dot_general(
                    cj, uj,
                    dimension_numbers=(((2,), (1,)), ((0,), (0,))),
                    preferred_element_type=jnp.float32,
                )[:, 0, :]                             # (Bb, D)
            )
        s = jnp.stack(s_parts, axis=1)                 # (Bb, J, D)
        # Squash (paper Fig. 11a: one ||s||^2, one rsqrt)
        sq = jnp.sum(jnp.square(s), axis=-1, keepdims=True)
        inv = jax.lax.rsqrt(sq + 1e-9)
        v = s * (sq * inv / (1.0 + sq))
        if it < n_iters - 1:
            # Agreement step, again j outer: b_ij += u_ij . v_j
            b_parts = []
            for j in range(n_out):
                uj = u[:, :, j, :]                     # (Bb, I, D)
                vj = v[:, j, :, None]                  # (Bb, D, 1)
                b_parts.append(
                    jax.lax.dot_general(
                        uj, vj,
                        dimension_numbers=(((2,), (1,)), ((0,), (0,))),
                        preferred_element_type=jnp.float32,
                    )[:, :, 0]                         # (Bb, I)
                )
            b = b + jnp.stack(b_parts, axis=2)         # (Bb, I, J)
    v_ref[...] = v.astype(v_ref.dtype)
    c_ref[...] = c.astype(c_ref.dtype)


def fused_routing_pallas(
    u_hat: jax.Array,
    n_iters: int = 3,
    softmax_mode: str = "exact",
    batch_block: int = 8,
    interpret: bool = True,
) -> Tuple[jax.Array, jax.Array]:
    """u_hat (B, I, J, D) -> (v (B, J, D), c (B, I, J))."""
    bsz, n_in, n_out, d = u_hat.shape
    bb = min(batch_block, bsz)
    assert bsz % bb == 0, (bsz, bb)
    grid = (bsz // bb,)
    kernel = functools.partial(
        _routing_kernel, n_iters=n_iters, softmax_mode=softmax_mode)
    v, c = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, n_in, n_out, d), lambda i: (i, 0, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bb, n_out, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((bb, n_in, n_out), lambda i: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, n_out, d), u_hat.dtype),
            jax.ShapeDtypeStruct((bsz, n_in, n_out), jnp.float32),
        ],
        interpret=interpret,
    )(u_hat)
    return v, c
