# Dispatch lives in repro.kernels.registry ("fused_routing"); this
# package keeps the Pallas body and the jnp oracle only.
from repro.kernels.routing import ref  # noqa: F401
from repro.kernels.routing.routing_kernel import fused_routing_pallas  # noqa: F401
