from repro.kernels.routing import ops, ref  # noqa: F401
from repro.kernels.routing.routing_kernel import fused_routing_pallas  # noqa: F401
