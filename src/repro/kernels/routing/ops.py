"""Jitted public wrapper for the fused routing kernel."""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels import needs_interpret
from repro.kernels.routing.routing_kernel import fused_routing_pallas


@functools.partial(jax.jit,
                   static_argnames=("n_iters", "softmax_mode", "batch_block",
                                    "interpret"))
def fused_routing(u_hat: jax.Array, n_iters: int = 3,
                  softmax_mode: str = "exact", batch_block: int = 8,
                  interpret: bool | None = None
                  ) -> Tuple[jax.Array, jax.Array]:
    """Fused dynamic routing; interpret defaults to True off-TPU."""
    if interpret is None:
        interpret = needs_interpret()
    bsz = u_hat.shape[0]
    bb = batch_block
    while bsz % bb:
        bb //= 2
    return fused_routing_pallas(
        u_hat, n_iters=n_iters, softmax_mode=softmax_mode,
        batch_block=max(bb, 1), interpret=interpret)
