"""Pure-jnp oracle for the fused routing kernel (identical math, no tiling)."""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import approx_math


def fused_routing_ref(u_hat: jax.Array, n_iters: int = 3,
                      softmax_mode: str = "exact"
                      ) -> Tuple[jax.Array, jax.Array]:
    """u_hat (B, I, J, D) -> (v (B, J, D), c (B, I, J)); fp32 internally."""
    u = u_hat.astype(jnp.float32)
    bsz, i_, j_, d_ = u.shape
    b = jnp.zeros((bsz, i_, j_), jnp.float32)
    c = v = None
    for it in range(n_iters):
        if softmax_mode == "taylor":
            c = approx_math.taylor_softmax(b, axis=-1, range_reduce=True)
        else:
            c = jax.nn.softmax(b, axis=-1)
        s = jnp.einsum("bij,bijd->bjd", c, u)
        v = approx_math.squash_fast(s, axis=-1)
        if it < n_iters - 1:
            b = b + jnp.einsum("bijd,bjd->bij", u, v)
    return v.astype(u_hat.dtype), c
