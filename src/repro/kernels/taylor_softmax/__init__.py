from repro.kernels.taylor_softmax import ops, ref  # noqa: F401
from repro.kernels.taylor_softmax.kernel import taylor_softmax_pallas  # noqa: F401
