"""Jitted wrapper for the Taylor-softmax kernel."""

from __future__ import annotations

import functools

import jax

from repro.kernels import needs_interpret
from repro.kernels.taylor_softmax.kernel import taylor_softmax_pallas


@functools.partial(jax.jit, static_argnames=("row_block", "interpret"))
def taylor_softmax(x: jax.Array, row_block: int = 256,
                   interpret: bool | None = None) -> jax.Array:
    if interpret is None:
        interpret = needs_interpret()
    return taylor_softmax_pallas(x, row_block=row_block, interpret=interpret)
