"""Measured block-size autotuning for the kernel registry.

FastCaps' methodology is a *design-space search* over kernel
configurations (Fig. 1/8: simplified nonlinearities, reordered loops,
parallelization factors chosen per target).  This module is the search
half of that story for the Pallas kernels: every
:class:`repro.kernels.KernelSpec` declares a tunable block-size space,
and the tuner measures the candidates on the live backend and remembers
the winner.

Three pieces:

* **Deterministic defaults** (``tune=False``, the CI path) — config
  resolution never measures anything: the spec's base config is
  legalized against the concrete shapes (``largest_divisor`` replaces
  the old per-kernel halving loops, so e.g. an odd batch of 9 gets
  ``batch_block=3`` instead of degrading to 1).
* **The measured tuner** (:func:`autotune`) — times every legalized
  candidate config of a kernel on example inputs (median wall-clock,
  compile excluded) and returns the winner plus the full timing table.
  The base config is always a candidate, so the tuned choice is never
  slower than the old hard-coded blocks on the measuring machine.
* **The on-disk cache** (:class:`TuneCache`) — winners are stored as
  JSON keyed by ``(kernel, backend, shape-bucket, dtype)`` under
  ``~/.cache/repro-kernels`` (override with ``REPRO_KERNEL_CACHE_DIR``),
  so tuning survives processes and CI runs can cache the artifact.
  Shapes are bucketed to powers of two: one tuning run covers the whole
  bucket, keeping the cache small and lookups O(1).

Whether dispatch *consults* the tuner is a scoped policy, not a global:
``with tuning(True): ...`` (thread-local) or the ``REPRO_KERNEL_TUNE=1``
environment variable.  Inside a ``jax.jit`` trace the arguments are
tracers, so dispatch can only *read* the cache (shape buckets are known
at trace time); filling it requires concrete arrays — that is what
bind-time pretuning in ``repro.serving`` and the
``python -m repro.kernels.tuning`` CLI are for.
"""

from __future__ import annotations

import contextlib
import json
import os
import threading
import time
from typing import Any, Callable, Dict, Iterable, List, Optional, Tuple

CACHE_ENV = "REPRO_KERNEL_CACHE_DIR"
TUNE_ENV = "REPRO_KERNEL_TUNE"
CACHE_VERSION = 1


# ---------------------------------------------------------------------------
# Deterministic config helpers (shared by every spec's legalizer)
# ---------------------------------------------------------------------------


def largest_divisor(n: int, cap: int) -> int:
    """Largest divisor of ``n`` that is <= ``cap`` (>= 1).

    This is the shared block-size default: the whole dimension is covered
    by equal full blocks, and an odd size degrades gracefully (n=9, cap=8
    -> 3) instead of collapsing to 1 the way halving-from-8 did.

    Raises :class:`ValueError` on ``n <= 0`` or ``cap <= 0`` — a zero-size
    dimension or a zero/negative block request is always a caller bug
    (empty example case, config typo), and silently returning 1 used to
    hide it until the kernel produced garbage grids.
    """
    n, cap = int(n), int(cap)
    if n <= 0:
        raise ValueError(f"largest_divisor: dimension must be positive, "
                         f"got n={n}")
    if cap <= 0:
        raise ValueError(f"largest_divisor: block cap must be positive, "
                         f"got cap={cap} (for dimension n={n})")
    for d in range(min(n, cap), 0, -1):
        if n % d == 0:
            return d
    return 1


def next_pow2(n: int) -> int:
    """Smallest power of two >= n (bucket key for cache shapes).  Named
    distinctly from ``serving.schedulers.pow2_bucket(n, cap)``, which
    clamps — confusing the two picks the wrong bucket."""
    b = 1
    while b < int(n):
        b *= 2
    return b


def shape_bucket(shapes: Iterable[Tuple[int, ...]]) -> str:
    """Cache-key string for a tuple of array shapes, pow2-bucketed per dim
    (``(9, 252, 10, 16)`` -> ``"16x256x16x16"``)."""
    return ",".join("x".join(str(next_pow2(d)) for d in s) or "scalar"
                    for s in shapes)


def config_label(config: Dict[str, Any]) -> str:
    """Canonical label for a config in timing tables and reports
    (``{"q_block": 64, "kv_block": 128}`` -> ``"kv_block=128,q_block=64"``).
    The single source of the format — :func:`autotune` keys its timing
    table with it, and benches/tests must index with it, never rebuild
    the string by hand."""
    return ",".join(f"{k}={config[k]}" for k in sorted(config))


# ---------------------------------------------------------------------------
# Tuning policy (scoped, thread-local)
# ---------------------------------------------------------------------------

_POLICY = threading.local()


def tune_enabled() -> bool:
    """Whether dispatch should consult the tuner cache (scope > env)."""
    scoped = getattr(_POLICY, "tune", None)
    if scoped is not None:
        return scoped
    return (os.environ.get(TUNE_ENV, "").strip().lower()
            not in ("", "0", "false", "off", "no"))


@contextlib.contextmanager
def tuning(enabled: bool = True):
    """Scope in which registry dispatch prefers tuned configs.

    Thread-local, so one serving engine can bind tuned executables while
    another thread stays on deterministic defaults.
    """
    prev = getattr(_POLICY, "tune", None)
    _POLICY.tune = bool(enabled)
    try:
        yield
    finally:
        _POLICY.tune = prev


# ---------------------------------------------------------------------------
# On-disk cache
# ---------------------------------------------------------------------------


class TuneCache:
    """JSON-backed winner cache keyed ``kernel|backend|bucket|dtype``.

    The file is read lazily once and written atomically (per-writer tmp
    + rename, with a merge of the on-disk entries first), so multiple
    processes sharing one cache dir can write concurrently without ever
    publishing corrupt JSON or erasing each other's keys; an unwritable
    cache dir degrades to memory-only.  Entries store the winning config
    plus the measured timing table for reporting::

        {"version": 1,
         "entries": {"fused_routing|cpu|32x256x16x16|float32":
                     {"config": {"batch_block": 8},
                      "timings": {"batch_block=8": 0.0012, ...}}}}
    """

    def __init__(self, path: Optional[str] = None):
        if path is None:
            root = os.environ.get(CACHE_ENV) or os.path.join(
                os.path.expanduser("~"), ".cache", "repro-kernels")
            path = os.path.join(root, "autotune.json")
        self.path = path
        self._entries: Optional[Dict[str, Dict[str, Any]]] = (
            None)                                      # guarded-by: _lock
        self._written: set = set()                     # guarded-by: _lock
        #   ^ the keys THIS instance put (the merge-on-write overlay set)
        self._lock = threading.Lock()

    @staticmethod
    def key(kernel: str, backend: str, bucket: str, dtype: str) -> str:
        return f"{kernel}|{backend}|{bucket}|{dtype}"

    def _load_locked(self) -> Dict[str, Dict[str, Any]]:
        """Lazy read of the on-disk cache; ``_locked`` = caller holds
        ``self._lock`` (every public entry point takes it first)."""
        if self._entries is None:
            entries: Dict[str, Dict[str, Any]] = {}
            try:
                with open(self.path) as f:
                    blob = json.load(f)
                if blob.get("version") == CACHE_VERSION:
                    entries = dict(blob.get("entries", {}))
            except (OSError, ValueError):
                pass
            self._entries = entries
        return self._entries

    def get(self, key: str) -> Optional[Dict[str, Any]]:
        with self._lock:
            entry = self._load_locked().get(key)
            return dict(entry["config"]) if entry else None

    def entry(self, key: str) -> Optional[Dict[str, Any]]:
        with self._lock:
            e = self._load_locked().get(key)
            return json.loads(json.dumps(e)) if e else None

    def put(self, key: str, config: Dict[str, Any],
            timings: Optional[Dict[str, float]] = None) -> None:
        with self._lock:
            entries = self._load_locked()
            entries[key] = {"config": dict(config),
                            "timings": dict(timings or {})}
            self._written.add(key)
            try:
                os.makedirs(os.path.dirname(self.path), exist_ok=True)
                # Concurrent writers (two serving processes sharing one
                # REPRO_KERNEL_CACHE_DIR) must never corrupt the file or
                # erase each other's keys:
                #   * an exclusive flock on a sidecar lock file brackets
                #     the whole read-merge-replace, so no other writer's
                #     publish can land inside our window (platforms
                #     without fcntl skip the lock: writes stay corruption
                #     -free via the rename, a racing key may be lost);
                #   * merge-on-write — re-read the file under the lock
                #     and overlay ONLY the keys this instance itself
                #     wrote, so entries another process published since
                #     our lazy load survive (overlaying the whole stale
                #     in-memory snapshot would silently revert them);
                #   * a per-writer tmp name — a shared `.tmp` would let
                #     two processes interleave writes into one file and
                #     os.replace() would then publish the garbage;
                #   * atomic rename — readers only ever see a complete
                #     JSON document.
                with self._file_lock():
                    merged: Dict[str, Dict[str, Any]] = {}
                    try:
                        with open(self.path) as f:
                            blob = json.load(f)
                        if blob.get("version") == CACHE_VERSION:
                            merged.update(blob.get("entries", {}))
                    except (OSError, ValueError):
                        pass
                    merged.update({k: entries[k] for k in self._written
                                   if k in entries})
                    self._entries = merged
                    tmp = (f"{self.path}.{os.getpid()}."
                           f"{threading.get_ident()}.tmp")
                    with open(tmp, "w") as f:
                        json.dump({"version": CACHE_VERSION,
                                   "entries": merged},
                                  f, indent=1, sort_keys=True)
                    os.replace(tmp, self.path)
            except OSError:
                pass                      # memory-only fallback

    @contextlib.contextmanager
    def _file_lock(self):
        """Exclusive cross-process lock around read-merge-replace (a
        sidecar ``.lock`` file, never the data file itself — locking the
        file we os.replace would lock a dead inode)."""
        try:
            import fcntl
        except ImportError:               # non-POSIX: best-effort, no lock
            yield
            return
        with open(self.path + ".lock", "w") as lf:
            fcntl.flock(lf, fcntl.LOCK_EX)
            try:
                yield
            finally:
                fcntl.flock(lf, fcntl.LOCK_UN)

    def clear_memory(self) -> None:
        """Drop the in-memory view (tests: re-read after env changes)."""
        with self._lock:
            self._entries = None


_default_cache = TuneCache()


def default_cache() -> TuneCache:
    """Process-wide cache; re-targets if REPRO_KERNEL_CACHE_DIR changed."""
    global _default_cache
    root = os.environ.get(CACHE_ENV) or os.path.join(
        os.path.expanduser("~"), ".cache", "repro-kernels")
    expect = os.path.join(root, "autotune.json")
    if _default_cache.path != expect:
        _default_cache = TuneCache(expect)
    return _default_cache


# ---------------------------------------------------------------------------
# Measurement
# ---------------------------------------------------------------------------


def _time_call(fn: Callable[[], Any], warmup: int = 1, iters: int = 3
               ) -> float:
    import jax

    for _ in range(warmup):
        jax.block_until_ready(fn())
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def candidate_configs(spec, *args, **kwargs) -> List[Dict[str, Any]]:
    """Legalized, deduplicated candidate configs for ``spec`` on these
    shapes: the cartesian product of the tuned axes of ``spec.space``,
    with the (legalized) base config guaranteed present and first."""
    import itertools

    base = spec.legalize(dict(spec.base_config), *args, **kwargs)
    seen, out = set(), []

    def push(cfg):
        key = tuple(sorted(cfg.items()))
        if key not in seen:
            seen.add(key)
            out.append(cfg)

    push(base)
    axes = [(k, spec.space[k]) for k in spec.tuned]
    for combo in itertools.product(*(vals for _, vals in axes)):
        cand = dict(spec.base_config)
        cand.update({k: v for (k, _), v in zip(axes, combo)})
        push(spec.legalize(cand, *args, **kwargs))
    return out


def autotune(spec, args: tuple, kwargs: Optional[dict] = None,
             cache: Optional[TuneCache] = None, warmup: int = 1,
             iters: int = 3) -> Tuple[Dict[str, Any], Dict[str, float]]:
    """Measure every candidate config of ``spec`` on concrete ``args``.

    Returns ``(best_config, timings)`` where ``timings`` maps a compact
    config label to median seconds; the winner is stored in ``cache``
    (the default on-disk cache when None) under the shape-bucket key, so
    later dispatches — including trace-time dispatch inside ``jax.jit``
    — pick it up.
    """
    kwargs = dict(kwargs or {})
    cache = cache or default_cache()
    key = cache_key_for(spec, args)
    impl = spec.build()
    interpret = needs_interpret()
    best_cfg, best_t = None, float("inf")
    timings: Dict[str, float] = {}
    for cfg in candidate_configs(spec, *args, **kwargs):
        label = config_label(cfg)
        t = _time_call(
            lambda cfg=cfg: impl(*args, interpret=interpret,
                                 **kwargs, **cfg),
            warmup=warmup, iters=iters)
        timings[label] = t
        if t < best_t:
            best_cfg, best_t = cfg, t
    assert best_cfg is not None
    cache.put(key, best_cfg, timings)
    return best_cfg, timings


def cache_key_for(spec, args: tuple) -> str:
    """(kernel, backend, shape-bucket, dtype) key for these arguments."""
    import jax
    import numpy as np

    shapes = [tuple(getattr(a, "shape", ())) for a in args
              if hasattr(a, "shape")]
    first = next((a for a in args if hasattr(a, "dtype")), None)
    dtype = str(np.dtype(first.dtype)) if first is not None else "none"
    return TuneCache.key(spec.name, jax.default_backend(),
                         shape_bucket(shapes), dtype)


def needs_interpret() -> bool:
    """THE backend capability probe for every Pallas kernel: compiled
    natively only on TPU; every other backend (cpu, gpu) runs the Pallas
    interpreter.  This is the single place that probes — wrappers and
    registries import it, never re-derive it."""
    import jax

    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# CLI: selfcheck (tune=False parity on the interpret path) and pretune
# ---------------------------------------------------------------------------


def _selfcheck() -> int:
    """tune=False dispatch of every registered kernel on this backend's
    interpret path, checked against the jnp reference.  CI runs this to
    pin the deterministic default path."""
    import numpy as np

    from repro.kernels.registry import registry

    failures = []
    for name in registry.names():
        spec = registry.get(name)
        if not spec.is_available():
            print(f"[selfcheck] {name}: SKIP (unavailable)")
            continue
        for i, case in enumerate(spec.example_cases):
            args, kwargs = spec.make_example(case)
            # tune=False is passed explicitly: under ``python -m`` this
            # module also exists as __main__, so a tuning() scope set
            # here would toggle the wrong module's thread-local
            got = registry.call(name, *args, tune=False, **kwargs)
            want = spec.ref_call(*args, **kwargs)
            ok = True
            for g, w in zip(_leaves(got), _leaves(want)):
                if not np.allclose(np.asarray(g, np.float32),
                                   np.asarray(w, np.float32),
                                   atol=case.get("atol", 1e-5)):
                    ok = False
            status = "ok" if ok else "FAIL"
            cfg = registry.default_config(name, *args, **kwargs)
            print(f"[selfcheck] {name} case#{i} {cfg}: {status}")
            if not ok:
                failures.append((name, i))
    if failures:
        print(f"[selfcheck] FAILED: {failures}")
        return 1
    print("[selfcheck] all kernels dispatch with tune=False: OK")
    return 0


def _leaves(x):
    return list(x) if isinstance(x, (tuple, list)) else [x]


def _pretune(names: List[str], warmup: int, iters: int,
             force: bool = False) -> int:
    from repro.kernels.registry import registry

    cache = default_cache()
    for name in names:
        spec = registry.get(name)
        if not spec.is_available():
            print(f"[pretune] {name}: SKIP (unavailable)")
            continue
        for case in spec.example_cases:
            args, kwargs = spec.make_example(case)
            key = cache_key_for(spec, args)
            if not force and cache.get(key) is not None:
                print(f"[pretune] {name} {key}: cached")
                continue
            best, _ = autotune(spec, args, kwargs, cache=cache,
                               warmup=warmup, iters=iters)
            print(f"[pretune] {name} {key} -> {best}")
    print(f"[pretune] cache: {cache.path}")
    return 0


def main(argv=None) -> int:
    import argparse

    from repro.kernels.registry import registry

    ap = argparse.ArgumentParser(
        description="Kernel autotuner: selfcheck / pretune the cache")
    ap.add_argument("--selfcheck", action="store_true",
                    help="tune=False parity of every kernel vs reference")
    ap.add_argument("--pretune", default=None,
                    help="autotune one kernel by name, or 'all'")
    ap.add_argument("--force", action="store_true",
                    help="re-measure even when the cache has an entry")
    ap.add_argument("--iters", type=int, default=3)
    ap.add_argument("--warmup", type=int, default=1)
    args = ap.parse_args(argv)
    rc = 0
    if args.selfcheck:
        rc |= _selfcheck()
    if args.pretune:
        names = (registry.names() if args.pretune == "all"
                 else [args.pretune])
        rc |= _pretune(names, args.warmup, args.iters, force=args.force)
    if not args.selfcheck and not args.pretune:
        ap.print_help()
    return rc


if __name__ == "__main__":
    raise SystemExit(main())
