"""Pallas kernel subsystem: registry-dispatched, autotunable kernels.

Every kernel in this package is registered once in
:mod:`repro.kernels.registry` as a typed :class:`KernelSpec` — Pallas
impl, pure-jnp reference, availability probe, and its tunable
block-size space — and dispatched through :data:`registry` (or the
ergonomic wrappers re-exported here).  Block sizes come from the
deterministic legalized defaults (``tune=False``, the CI path) or the
measured on-disk autotuner cache (:mod:`repro.kernels.tuning`).

Backend capability (Pallas compiles natively only on TPU; cpu/gpu run
the interpreter) is probed in exactly one place: ``needs_interpret``.
"""

from repro.kernels.registry import (KernelRegistry, KernelSpec,  # noqa: F401
                                    decode_attention, flash_attention,
                                    flash_attention_dequant, fused_routing,
                                    fused_sampling, needs_interpret, registry,
                                    taylor_softmax)
from repro.kernels import tuning  # noqa: F401
