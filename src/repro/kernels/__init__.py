# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.

import jax


def needs_interpret() -> bool:
    """Shared backend capability probe for every Pallas wrapper: the
    kernels compile natively only on TPU; all other backends (cpu, gpu)
    run the Pallas interpreter."""
    return jax.default_backend() != "tpu"
