"""Checkpointing: atomic, keep-N, async, mesh-shape independent.

Layout (one directory per step):

    <dir>/step_000042/
        manifest.json      {key_path: {file, shape, dtype}}
        <leaf>.npy         full (unsharded) logical arrays

* **Atomic publish** — written to ``step_X.tmp`` then ``os.rename``d, so a
  reader never sees a partial checkpoint and a killed writer leaves only a
  ``.tmp`` turd that is ignored (and garbage-collected on the next save).
* **Mesh independence / elastic restore** — leaves are stored as *full
  logical arrays*; ``load_latest(..., shardings=...)`` re-shards onto
  whatever mesh the restarted job has (16x16 -> 2x16x16 restart works).
  On a real multi-host fleet the same layout is written per-host via
  ``jax.experimental.multihost_utils`` gather; the publish/restore protocol
  is identical.
* **Async** — ``AsyncCheckpointer`` snapshots to host (device_get) on the
  caller thread (cheap, overlapped with the next step's dispatch) and does
  file I/O on a background thread; queue depth 1 applies backpressure.
"""

from __future__ import annotations

import json
import os
import re
import shutil
import threading
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

_STEP_RE = re.compile(r"^step_(\d+)$")


def _flatten_with_paths(tree: Any) -> List[Tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(_path_str(p) for p in path)
        out.append((key, leaf))
    return out


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


def save(directory: str, step: int, tree: Any, keep: int = 3) -> str:
    """Blocking save with atomic publish; returns the final path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:012d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    manifest = {}
    for key, leaf in _flatten_with_paths(tree):
        arr = np.asarray(jax.device_get(leaf))
        fname = key.replace("/", "__") + ".npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest[key] = {"file": fname, "shape": list(arr.shape),
                         "dtype": str(arr.dtype)}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump({"step": step, "leaves": manifest}, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)                       # atomic publish
    _garbage_collect(directory, keep)
    return final


def _garbage_collect(directory: str, keep: int) -> None:
    steps = list_steps(directory)
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(directory, f"step_{s:012d}"),
                      ignore_errors=True)
    for name in os.listdir(directory):          # stale tmp dirs
        if name.endswith(".tmp"):
            shutil.rmtree(os.path.join(directory, name), ignore_errors=True)


def list_steps(directory: str) -> List[int]:
    if not os.path.isdir(directory):
        return []
    steps = []
    for name in os.listdir(directory):
        m = _STEP_RE.match(name)
        if m and os.path.exists(os.path.join(directory, name,
                                             "manifest.json")):
            steps.append(int(m.group(1)))
    return sorted(steps)


def load(directory: str, step: int, target: Any,
         shardings: Optional[Any] = None) -> Any:
    """Restore into the structure of ``target`` (a pytree of arrays or
    ShapeDtypeStructs).  ``shardings``: matching pytree of NamedShardings
    for elastic placement on the current mesh."""
    path = os.path.join(directory, f"step_{step:012d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)["leaves"]
    keys_and_leaves = _flatten_with_paths(target)
    tdef = jax.tree_util.tree_structure(target)
    shard_leaves = (jax.tree_util.tree_leaves(shardings)
                    if shardings is not None else
                    [None] * len(keys_and_leaves))
    out = []
    for (key, leaf), shard in zip(keys_and_leaves, shard_leaves):
        ent = manifest.get(key)
        if ent is None:
            raise KeyError(f"checkpoint {path} is missing leaf {key}")
        arr = np.load(os.path.join(path, ent["file"]))
        expect = tuple(leaf.shape)
        if tuple(arr.shape) != expect:
            raise ValueError(
                f"leaf {key}: checkpoint shape {arr.shape} != {expect}")
        if shard is not None:
            out.append(jax.device_put(arr, shard))
        else:
            out.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(tdef, out)


def load_latest(directory: str, target: Any,
                shardings: Optional[Any] = None
                ) -> Optional[Tuple[int, Any]]:
    steps = list_steps(directory)
    if not steps:
        return None
    step = steps[-1]
    return step, load(directory, step, target, shardings)


class AsyncCheckpointer:
    """Background-thread checkpoint writer with queue depth 1."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._pool = ThreadPoolExecutor(max_workers=1)
        self._pending: Optional[Future] = None
        self._lock = threading.Lock()

    def save(self, step: int, tree: Any) -> None:
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                 tree)
        with self._lock:
            if self._pending is not None:
                self._pending.result()          # backpressure
            self._pending = self._pool.submit(
                save, self.directory, step, host_tree, self.keep)

    def wait(self) -> None:
        with self._lock:
            if self._pending is not None:
                self._pending.result()
                self._pending = None

    def close(self) -> None:
        self.wait()
        self._pool.shutdown()
