from repro.checkpointing.checkpoint import (AsyncCheckpointer, list_steps,
                                            load, load_latest, save)  # noqa: F401
