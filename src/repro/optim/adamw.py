"""AdamW + schedules + global-norm clipping (self-contained, no optax).

State is a plain pytree {m, v, step}; the update is fully fused into the
caller's train_step (one jit).  Moments are fp32 regardless of param dtype.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    schedule: str = "cosine"      # cosine | linear | constant
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1


def schedule_lr(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    s = step.astype(jnp.float32)
    warm = jnp.minimum(s / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    frac = jnp.clip((s - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    if cfg.schedule == "cosine":
        decay = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
            1 + jnp.cos(jnp.pi * frac))
    elif cfg.schedule == "linear":
        decay = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * (1 - frac)
    else:
        decay = jnp.float32(1.0)
    return cfg.lr * warm * decay


def init_state(params: Any) -> Dict[str, Any]:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return {"m": zeros,
            "v": jax.tree.map(jnp.copy, zeros),
            "step": jnp.zeros((), jnp.int32)}


def state_specs(param_axes: Any) -> Dict[str, Any]:
    """Moments shard exactly like their parameters."""
    return {"m": param_axes, "v": param_axes, "step": None}


def global_norm(grads: Any) -> jax.Array:
    leaves = [jnp.sum(jnp.square(g.astype(jnp.float32)))
              for g in jax.tree.leaves(grads)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads: Any, max_norm: float
                        ) -> Tuple[Any, jax.Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), norm


def apply_updates(params: Any, grads: Any, state: Dict[str, Any],
                  cfg: AdamWConfig,
                  mask_fn: Optional[Callable[[Any], Any]] = None
                  ) -> Tuple[Any, Dict[str, Any], Dict[str, jax.Array]]:
    """One fused AdamW step.  ``mask_fn`` (e.g. pruning masks) is applied to
    the gradients before the moment update so pruned weights stay zero."""
    if mask_fn is not None:
        grads = mask_fn(grads)
    if cfg.grad_clip:
        grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    else:
        grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        gnorm = global_norm(grads)
    step = state["step"] + 1
    lr = schedule_lr(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        m_n = b1 * m + (1 - b1) * g
        v_n = b2 * v + (1 - b2) * jnp.square(g)
        mh = m_n / bc1
        vh = v_n / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if cfg.weight_decay:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m_n, v_n

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state["m"])
    flat_v = tdef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v
           in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
    new_state = {"m": new_m, "v": new_v, "step": step}
    return new_p, new_state, {"grad_norm": gnorm, "lr": lr}
