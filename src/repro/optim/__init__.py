from repro.optim.adamw import (AdamWConfig, apply_updates, clip_by_global_norm,
                               global_norm, init_state, schedule_lr,
                               state_specs)  # noqa: F401
