"""Trainer: fused train step (grad-accum microbatches), fault tolerance
(checkpoint/restart), deterministic-size steps (straggler posture).

Fault-tolerance contract (DESIGN.md §4):
  * every ``ckpt_every`` steps the full state (params, opt, step) is saved
    asynchronously with atomic publish;
  * ``Trainer.run`` always begins by restoring the latest valid checkpoint
    (missing -> fresh start), so a killed/preempted process resumes by
    simply being re-executed — this is the unit-tested crash/resume path;
  * checkpoints are mesh-shape independent, so the restart may use a
    different device count (elastic scaling).

Straggler mitigation at 1000+-node scale is a scheduling concern under
synchronous SPMD: steps are deterministic-size (capacity-factor MoE, no
data-dependent shapes), grad-accum microbatches amortize per-host jitter,
and a node that fails health checks is replaced + the job restarts from
the last atomic checkpoint (this file implements the restart half).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Iterator, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.checkpointing import checkpoint as ckpt_lib
from repro.optim import adamw


@dataclasses.dataclass
class TrainerConfig:
    optim: adamw.AdamWConfig = dataclasses.field(
        default_factory=adamw.AdamWConfig)
    grad_accum: int = 1           # microbatches per step
    ckpt_every: int = 50
    ckpt_dir: Optional[str] = None
    ckpt_keep: int = 3
    log_every: int = 10
    # donate (params, opt_state) buffers into the step.  On for real runs
    # (halves peak param memory); off by default so callers that keep a
    # reference to the initial params (e.g. the prune->finetune pipeline,
    # which reuses masked_params after fine-tuning) stay valid.
    donate: bool = False


def make_train_step(loss_fn: Callable[[Any, Dict[str, jax.Array]],
                                      Tuple[jax.Array, Dict[str, jax.Array]]],
                    tcfg: TrainerConfig,
                    mask_fn: Optional[Callable[[Any], Any]] = None,
                    donate: bool = True):
    """Build a jitted (params, opt_state, batch) -> (params, opt_state,
    metrics) step.  ``batch`` leaves have a leading microbatch dim when
    grad_accum > 1 (accumulated with a scan, fp32 accumulators)."""

    def grads_of(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        return grads, metrics

    def step(params, opt_state, batch):
        if tcfg.grad_accum > 1:
            def micro(acc, mb):
                grads, metrics = grads_of(params, mb)
                acc = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32), acc, grads)
                return acc, metrics
            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            grads, metrics_all = jax.lax.scan(micro, zeros, batch)
            grads = jax.tree.map(lambda g: g / tcfg.grad_accum, grads)
            metrics = jax.tree.map(lambda m: m[-1], metrics_all)
        else:
            grads, metrics = grads_of(params, batch)
        params, opt_state, opt_metrics = adamw.apply_updates(
            params, grads, opt_state, tcfg.optim, mask_fn=mask_fn)
        metrics = dict(metrics, **opt_metrics)
        return params, opt_state, metrics

    donate_argnums = (0, 1) if donate else ()
    return jax.jit(step, donate_argnums=donate_argnums)


@dataclasses.dataclass
class TrainResult:
    params: Any
    opt_state: Any
    step: int
    history: list


class Trainer:
    def __init__(self, tcfg: TrainerConfig, loss_fn, init_params_fn,
                 mask_fn=None):
        self.tcfg = tcfg
        self.loss_fn = loss_fn
        self.init_params_fn = init_params_fn
        self.mask_fn = mask_fn
        self.train_step = make_train_step(loss_fn, tcfg, mask_fn,
                                          donate=tcfg.donate)
        self.checkpointer = (
            ckpt_lib.AsyncCheckpointer(tcfg.ckpt_dir, keep=tcfg.ckpt_keep)
            if tcfg.ckpt_dir else None)

    def _restore_or_init(self, key) -> Tuple[Any, Any, int]:
        params = self.init_params_fn(key)
        opt_state = adamw.init_state(params)
        if self.tcfg.ckpt_dir:
            state_struct = {"params": params, "opt": opt_state}
            found = ckpt_lib.load_latest(self.tcfg.ckpt_dir, state_struct)
            if found is not None:
                step, state = found
                return state["params"], state["opt"], step
        return params, opt_state, 0

    def run(self, batches: Iterator[Dict[str, Any]], n_steps: int,
            key: Optional[jax.Array] = None,
            crash_at: Optional[int] = None) -> TrainResult:
        """Train for n_steps total (resuming counts).  ``crash_at`` raises
        mid-run after that step — used by the fault-tolerance tests."""
        key = key if key is not None else jax.random.key(0)
        params, opt_state, start = self._restore_or_init(key)
        history = []
        step = start
        for batch in batches:
            if step >= n_steps:
                break
            batch = jax.tree.map(jnp.asarray, batch)
            params, opt_state, metrics = self.train_step(
                params, opt_state, batch)
            step += 1
            if step % self.tcfg.log_every == 0 or step == n_steps:
                history.append({k: float(v) for k, v in metrics.items()})
            if self.checkpointer and step % self.tcfg.ckpt_every == 0:
                self.checkpointer.save(
                    step, {"params": params, "opt": opt_state})
            if crash_at is not None and step >= crash_at:
                if self.checkpointer:
                    self.checkpointer.wait()
                raise RuntimeError(f"simulated crash at step {step}")
        if self.checkpointer:
            self.checkpointer.save(step, {"params": params,
                                          "opt": opt_state})
            self.checkpointer.wait()
        return TrainResult(params, opt_state, step, history)
