from repro.training.trainer import Trainer, TrainerConfig, make_train_step  # noqa: F401
