"""Shared AST / module-graph loader for the capslint checkers.

Every checker sees the same :class:`Project`: each ``.py`` file under the
scanned roots parsed exactly once into a :class:`Module` carrying its AST,
its comments (by line — the lock-discipline ``# guarded-by:`` annotations
and the ``# capslint: disable=`` suppressions both live in comments, which
``ast`` alone drops), and its dotted module name.  The loader also builds
the import map each module exposes (`lm` -> ``repro.models.lm``), which is
what lets the jit-purity checker chase calls across module boundaries
without executing anything.

Nothing here imports the code under analysis — the loader is pure
``ast``/``tokenize`` — so the checkers can run on broken or
jax-unavailable trees.  (The kernel-legality checker is the one exception
and does its own runtime import of the kernel registry.)
"""

from __future__ import annotations

import ast
import dataclasses
import io
import re
import tokenize
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Set, Tuple

#: ``# capslint: disable=rule-a,rule-b`` (or ``all``) — trailing on the
#: offending line or on the line directly above it.
_DISABLE_RE = re.compile(r"capslint:\s*disable=([A-Za-z0-9_.,\- ]+)")


@dataclasses.dataclass
class Module:
    """One parsed source file."""

    name: str                         # dotted module name ("repro.serving.core")
    path: Path                        # absolute file path
    relpath: str                      # path findings report (posix, repo-relative)
    source: str
    tree: ast.Module
    comments: Dict[int, str]          # line -> comment text (sans leading '#')

    def disabled_rules(self, line: int) -> Set[str]:
        """Rule names suppressed at ``line`` (same line or the line above)."""
        out: Set[str] = set()
        for ln in (line, line - 1):
            m = _DISABLE_RE.search(self.comments.get(ln, ""))
            if m:
                out.update(tok.strip() for tok in m.group(1).split(",")
                           if tok.strip())
        return out

    # -- import map ---------------------------------------------------------

    def imports(self) -> Dict[str, Tuple[str, Optional[str]]]:
        """Local name -> ``(module, attr)``: what each imported name means.

        ``import repro.models.lm as lm``      -> ``lm: ("repro.models.lm", None)``
        ``from repro.models import lm``       -> ``lm: ("repro.models.lm", None)``
        ``from repro.models.lm import decode``-> ``decode: ("repro.models.lm", "decode")``

        ``from X import Y`` is ambiguous between submodule and attribute;
        callers disambiguate against the project's module table.
        """
        out: Dict[str, Tuple[str, Optional[str]]] = {}
        for node in ast.walk(self.tree):
            if isinstance(node, ast.Import):
                for alias in node.names:
                    local = alias.asname or alias.name.split(".")[0]
                    target = alias.name if alias.asname else \
                        alias.name.split(".")[0]
                    out[local] = (target, None)
            elif isinstance(node, ast.ImportFrom) and node.module \
                    and node.level == 0:
                for alias in node.names:
                    if alias.name == "*":
                        continue
                    local = alias.asname or alias.name
                    out[local] = (node.module, alias.name)
        return out


class Project:
    """Every scanned module, plus the cross-module lookups checkers share."""

    def __init__(self, modules: List[Module], root: Path):
        self.root = root
        self.modules: Dict[str, Module] = {m.name: m for m in modules}
        self._by_relpath = {m.relpath: m for m in modules}

    # -- construction --------------------------------------------------------

    @classmethod
    def load(cls, paths: Iterable[Path], root: Optional[Path] = None
             ) -> "Project":
        """Parse every ``.py`` file under ``paths`` (files or directories).

        ``root`` anchors the repo-relative paths findings report; it
        defaults to the common parent that makes ``src/...`` visible (the
        directory two levels above a ``src/<pkg>`` scan root) or the
        parent of the first path.
        """
        files: List[Path] = []
        for p in paths:
            p = Path(p).resolve()
            if p.is_dir():
                files.extend(sorted(p.rglob("*.py")))
            elif p.suffix == ".py":
                files.append(p)
        if root is None:
            root = _infer_root(files)
        root = Path(root).resolve()
        modules = [m for m in (_parse(f, root) for f in files)
                   if m is not None]
        return cls(modules, root)

    # -- lookups -------------------------------------------------------------

    def module_for_path(self, path: Path) -> Optional[Module]:
        try:
            rel = Path(path).resolve().relative_to(self.root).as_posix()
        except ValueError:
            return None
        return self._by_relpath.get(rel)

    def relpath(self, path) -> str:
        """Repo-relative posix path for reporting (falls back to the
        original string for files outside the root)."""
        try:
            return Path(path).resolve().relative_to(self.root).as_posix()
        except ValueError:
            return str(path)

    def get(self, modname: str) -> Optional[Module]:
        return self.modules.get(modname)

    def resolve_import(self, module: Module, local: str
                       ) -> Optional[Tuple[str, Optional[str]]]:
        """What imported name ``local`` refers to, normalized against the
        project's module table: returns ``(modname, attr_or_None)`` with
        ``from X import Y`` resolved to the submodule ``X.Y`` when that
        submodule was scanned."""
        target = module.imports().get(local)
        if target is None:
            return None
        modname, attr = target
        if attr is not None and f"{modname}.{attr}" in self.modules:
            return (f"{modname}.{attr}", None)
        return (modname, attr)


def _infer_root(files: List[Path]) -> Path:
    for f in files:
        for parent in f.parents:
            if parent.name == "src" and (parent / "repro").exists():
                return parent.parent
    return files[0].parent if files else Path.cwd()


def _parse(path: Path, root: Path) -> Optional[Module]:
    try:
        source = path.read_text()
        tree = ast.parse(source, filename=str(path))
    except (OSError, SyntaxError):
        return None                   # unreadable/unparsable: not ours to lint
    try:
        rel = path.relative_to(root).as_posix()
    except ValueError:
        rel = str(path)
    return Module(name=_module_name(path), path=path, relpath=rel,
                  source=source, tree=tree, comments=_comments(source))


def _module_name(path: Path) -> str:
    """Dotted module name by walking up through ``__init__.py`` packages.

    One extra hop for the ``src/<namespace-pkg>`` layout: ``repro`` itself
    ships no ``__init__.py`` (PEP 420), so after the regular-package walk
    a directory sitting directly under ``src`` still joins the name
    (``src/repro/serving/core.py`` -> ``repro.serving.core``)."""
    parts = [path.stem] if path.stem != "__init__" else []
    parent = path.parent
    while (parent / "__init__.py").exists():
        parts.insert(0, parent.name)
        parent = parent.parent
    if parent.name.isidentifier() and parent.parent.name == "src":
        parts.insert(0, parent.name)
    return ".".join(parts) or path.stem


def _comments(source: str) -> Dict[int, str]:
    out: Dict[int, str] = {}
    try:
        for tok in tokenize.generate_tokens(io.StringIO(source).readline):
            if tok.type == tokenize.COMMENT:
                out[tok.start[0]] = tok.string.lstrip("#").strip()
    except tokenize.TokenError:
        pass                          # partial comment map beats crashing
    return out
