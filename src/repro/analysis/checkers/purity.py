"""jit-purity: code reachable from ``jax.jit`` / ``pl.pallas_call`` stays pure.

A traced function runs *once*, at trace time, against abstract tracer
values — so four whole classes of Python are silently wrong inside it:

* **tracer-branch** — a Python ``if``/``while`` on a tracer-derived value
  (the branch freezes at trace time; under real jit it raises a
  ``TracerBoolConversionError`` at the worst possible moment);
* **tracer-cast** — ``int()`` / ``float()`` / ``bool()`` / ``.item()`` on a
  tracer (host sync at best, trace error at worst);
* **impure-call** — reading wall-clock (``time.time`` & friends) or global
  RNG state (stdlib ``random``, legacy ``np.random.*``) inside traced
  code: the value is frozen into the executable and silently reused;
* **mutable-closure** — traced code reading engine shared state
  (``# guarded-by:`` annotated fields, the same annotation the
  lock-discipline rule uses): the trace captures one snapshot, the
  engine keeps mutating, and the executable goes stale.

How it works, entirely on the AST (nothing is imported or executed):

1. **Roots** — functions decorated with ``jax.jit`` (including
   ``functools.partial(jax.jit, static_argnames=...)``), functions or
   lambdas passed to ``jax.jit(...)`` / ``pl.pallas_call(...)`` calls
   (through local ``functools.partial`` wrappers, whose keyword names
   become static), and ``self.<method>`` references passed to either.
2. **Taint** — at each root, parameters not named static are tracers;
   taint propagates through assignments and expressions
   (``x.shape`` / ``x.dtype`` / ``len(x)`` are trace-time constants and
   *un*-taint).  tracer-branch / tracer-cast are reported where a tainted
   value hits a Python branch or cast.
3. **Reachability** — calls are chased through same-module defs, package
   imports (``lm.decode_step`` -> ``repro.models.lm``), and ``self.``
   methods; every reachable function is scanned for impure-call,
   ``.item()``, and mutable-closure.  Dynamic dispatch (a method on a
   registry *instance*, higher-order callables) ends the chase — by
   design: trace-time config resolution behind ``registry.call`` is
   allowed to read its cache.
"""

from __future__ import annotations

import ast
import dataclasses
from typing import Dict, Iterator, List, Optional, Set, Tuple, Union

from repro.analysis.checkers.locks import class_guarded_fields, _resolve_base
from repro.analysis.findings import Finding
from repro.analysis.loader import Module, Project

FuncNode = Union[ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda]

#: attributes that are trace-time constants even on a tracer
UNTAINT_ATTRS = frozenset({"shape", "dtype", "ndim", "size", "weak_type",
                           "sharding", "aval", "itemsize"})
#: builtins whose result is never a tracer
UNTAINT_CALLS = frozenset({"len", "isinstance", "hasattr", "getattr",
                           "type", "repr", "str", "id"})
CAST_CALLS = frozenset({"int", "float", "bool", "complex"})
CAST_METHODS = frozenset({"item", "tolist"})
CLOCK_FUNCS = frozenset({"time", "perf_counter", "monotonic",
                         "process_time", "time_ns", "perf_counter_ns",
                         "monotonic_ns"})


@dataclasses.dataclass(frozen=True)
class _Fn:
    """One function in the call graph (module + optional class context)."""

    module: Module
    node: FuncNode
    cls: Optional[ast.ClassDef] = None

    @property
    def symbol(self) -> str:
        name = getattr(self.node, "name", "<lambda>")
        return f"{self.cls.name}.{name}" if self.cls else name

    def key(self) -> Tuple[str, str, int]:
        return (self.module.name, self.symbol, self.node.lineno)


@dataclasses.dataclass(frozen=True)
class _Root:
    fn: _Fn
    statics: frozenset


class JitPurityChecker:
    name = "jit-purity"
    description = ("functions reachable from jax.jit / pl.pallas_call "
                   "must not branch on tracers, cast them to Python "
                   "scalars, read wall-clock/RNG globals, or close over "
                   "guarded engine state")
    codes = {
        "tracer-branch": "Python `if`/`while` on a tracer-derived value",
        "tracer-cast": "int()/float()/bool()/.item() on a tracer value",
        "impure-call": "wall-clock or global-RNG read inside traced code",
        "mutable-closure": "traced code reads a `# guarded-by:` engine "
                           "field",
    }

    def run(self, project: Project) -> Iterator[Finding]:
        roots: List[_Root] = []
        for module in project.modules.values():
            roots.extend(_find_roots(module))
        emitted: Set[Tuple[str, str, int]] = set()
        # pass 1: taint analysis at each root
        for root in roots:
            for f in _taint_scan(root):
                if self._fresh(emitted, f):
                    yield f
        # pass 2: purity scan over everything reachable from any root
        for fn in _reachable(project, [r.fn for r in roots]):
            for f in _purity_scan(project, fn):
                if self._fresh(emitted, f):
                    yield f

    @staticmethod
    def _fresh(emitted: Set[Tuple[str, str, int]], f: Finding) -> bool:
        key = (f.code, f.path, f.line)
        if key in emitted:
            return False
        emitted.add(key)
        return True


# ---------------------------------------------------------------------------
# Root discovery
# ---------------------------------------------------------------------------


def _is_jit_ref(node: ast.expr) -> bool:
    if isinstance(node, ast.Attribute) and node.attr == "jit" \
            and isinstance(node.value, ast.Name) and node.value.id == "jax":
        return True
    return isinstance(node, ast.Name) and node.id == "jit"


def _is_pallas_call_ref(node: ast.expr) -> bool:
    if isinstance(node, ast.Attribute) and node.attr == "pallas_call":
        return True
    return isinstance(node, ast.Name) and node.id == "pallas_call"


def _is_partial_ref(node: ast.expr) -> bool:
    if isinstance(node, ast.Attribute) and node.attr == "partial":
        return True
    return isinstance(node, ast.Name) and node.id == "partial"


def _static_names(call: ast.Call, func: Optional[FuncNode]) -> Set[str]:
    """Parameter names a ``jax.jit`` call marks static (by name or index)."""
    out: Set[str] = set()
    pos: List[str] = []
    if func is not None and not isinstance(func, ast.Lambda):
        a = func.args
        pos = [p.arg for p in a.posonlyargs + a.args]
    elif isinstance(func, ast.Lambda):
        pos = [p.arg for p in func.args.args]
    for kw in call.keywords:
        if kw.arg == "static_argnames":
            for c in ast.walk(kw.value):
                if isinstance(c, ast.Constant) and isinstance(c.value, str):
                    out.add(c.value)
        elif kw.arg == "static_argnums":
            for c in ast.walk(kw.value):
                if isinstance(c, ast.Constant) and isinstance(c.value, int) \
                        and 0 <= c.value < len(pos):
                    out.add(pos[c.value])
    return out


class _Scope:
    """One lexical frame: local function defs and simple assignments."""

    def __init__(self, body: List[ast.stmt]):
        self.defs: Dict[str, FuncNode] = {}
        self.assigns: Dict[str, ast.expr] = {}
        for stmt in body:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self.defs[stmt.name] = stmt
            elif isinstance(stmt, ast.Assign):
                for t in stmt.targets:
                    if isinstance(t, ast.Name):
                        self.assigns[t.id] = stmt.value


def _find_roots(module: Module) -> List[_Root]:
    roots: List[_Root] = []

    def resolve(expr: ast.expr, scopes: List[_Scope],
                cls: Optional[ast.ClassDef], statics: Set[str]
                ) -> Optional[Tuple[FuncNode, Set[str]]]:
        if isinstance(expr, ast.Lambda):
            return (expr, statics)
        if isinstance(expr, ast.Call) and _is_partial_ref(expr.func) \
                and expr.args:
            kw_statics = {kw.arg for kw in expr.keywords if kw.arg}
            return resolve(expr.args[0], scopes, cls, statics | kw_statics)
        if isinstance(expr, ast.Name):
            for scope in reversed(scopes):
                if expr.id in scope.defs:
                    return (scope.defs[expr.id], statics)
                if expr.id in scope.assigns:
                    return resolve(scope.assigns[expr.id], scopes[:-1]
                                   if scope is scopes[-1] else scopes,
                                   cls, statics)
            return None
        if isinstance(expr, ast.Attribute) \
                and isinstance(expr.value, ast.Name) \
                and expr.value.id == "self" and cls is not None:
            for stmt in cls.body:
                if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef))\
                        and stmt.name == expr.attr:
                    return (stmt, statics)
        return None

    def visit(node: ast.AST, scopes: List[_Scope],
              cls: Optional[ast.ClassDef]) -> None:
        if isinstance(node, ast.ClassDef):
            for child in node.body:
                visit(child, scopes, node)
            return
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            # decorated jit roots: @jax.jit / @partial(jax.jit, ...)
            for dec in node.decorator_list:
                if _is_jit_ref(dec):
                    roots.append(_Root(_Fn(module, node, cls), frozenset()))
                elif isinstance(dec, ast.Call):
                    if _is_jit_ref(dec.func):
                        roots.append(_Root(_Fn(module, node, cls),
                                           frozenset(_static_names(dec,
                                                                   node))))
                    elif _is_partial_ref(dec.func) and dec.args \
                            and _is_jit_ref(dec.args[0]):
                        roots.append(_Root(_Fn(module, node, cls),
                                           frozenset(_static_names(dec,
                                                                   node))))
            inner = scopes + [_Scope(node.body)]
            for child in node.body:
                visit(child, inner, cls)
            return
        if isinstance(node, ast.Call) \
                and (_is_jit_ref(node.func) or _is_pallas_call_ref(node.func))\
                and node.args:
            resolved = resolve(node.args[0], scopes, cls, set())
            if resolved is not None:
                fn, statics = resolved
                statics |= _static_names(node, fn)
                roots.append(_Root(_Fn(module, fn, cls), frozenset(statics)))
        for child in ast.iter_child_nodes(node):
            visit(child, scopes, cls)

    visit(module.tree, [_Scope(module.tree.body)], None)
    # dedupe: the same function may be rooted from several call sites
    seen: Set[Tuple[Tuple[str, str, int], frozenset]] = set()
    out = []
    for r in roots:
        key = (r.fn.key(), r.statics)
        if key not in seen:
            seen.add(key)
            out.append(r)
    return out


# ---------------------------------------------------------------------------
# Taint analysis (per root, intra-procedural, nested defs inherit taint)
# ---------------------------------------------------------------------------


def _param_names(fn: FuncNode) -> List[str]:
    a = fn.args
    names = [p.arg for p in a.posonlyargs + a.args + a.kwonlyargs]
    if a.vararg:
        names.append(a.vararg.arg)
    if a.kwarg:
        names.append(a.kwarg.arg)
    return names


def _taint_scan(root: _Root) -> Iterator[Finding]:
    module, fn = root.fn.module, root.fn.node
    findings: Dict[Tuple[str, int], Finding] = {}

    def tainted(expr: ast.expr, env: Set[str]) -> bool:
        if isinstance(expr, ast.Name):
            return expr.id in env
        if isinstance(expr, ast.Attribute):
            if expr.attr in UNTAINT_ATTRS:
                return False
            return tainted(expr.value, env)
        if isinstance(expr, ast.Call):
            if isinstance(expr.func, ast.Name) \
                    and expr.func.id in UNTAINT_CALLS:
                return False
            parts = list(expr.args) + [kw.value for kw in expr.keywords]
            if isinstance(expr.func, ast.Attribute):
                parts.append(expr.func.value)
            return any(tainted(p, env) for p in parts)
        if isinstance(expr, ast.Starred):
            return tainted(expr.value, env)
        return any(tainted(c, env) for c in ast.iter_child_nodes(expr)
                   if isinstance(c, ast.expr))

    def report(code: str, node: ast.AST, message: str, hint: str) -> None:
        findings[(code, node.lineno)] = Finding(
            rule="jit-purity", code=code, path=module.relpath,
            line=node.lineno, symbol=root.fn.symbol, message=message,
            hint=hint)

    def check_exprs(stmt: ast.stmt, env: Set[str]) -> None:
        for node in ast.walk(stmt):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.Lambda)) and node is not stmt:
                continue              # nested defs handled with their own env
            if not isinstance(node, ast.Call):
                continue
            if isinstance(node.func, ast.Name) \
                    and node.func.id in CAST_CALLS \
                    and any(tainted(a, env) for a in node.args):
                report("tracer-cast", node,
                       f"`{node.func.id}()` applied to a tracer-derived "
                       f"value inside a jitted function",
                       "use jnp ops, or mark the argument static "
                       "(static_argnames)")
            if isinstance(node.func, ast.Attribute) \
                    and node.func.attr in CAST_METHODS \
                    and tainted(node.func.value, env):
                report("tracer-cast", node,
                       f"`.{node.func.attr}()` on a tracer-derived value "
                       f"inside a jitted function",
                       "keep the value on-device (jnp) or compute it "
                       "outside the traced function")

    def exec_body(body: List[ast.stmt], env: Set[str]) -> None:
        for _ in range(2):            # two passes: loop-carried taint
            for stmt in body:
                exec_stmt(stmt, env)

    def exec_stmt(stmt: ast.stmt, env: Set[str]) -> None:
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
            inner = set(env) | set(_param_names(stmt)) - {"self"}
            exec_body(stmt.body, inner)
            return
        check_exprs(stmt, env)
        for node in ast.walk(stmt):
            if isinstance(node, ast.Lambda):
                inner = set(env) | {p.arg for p in node.args.args}
                check_exprs(ast.Expr(value=node.body, lineno=node.lineno,
                                     col_offset=0), inner)
        if isinstance(stmt, ast.Assign):
            if tainted(stmt.value, env):
                for t in stmt.targets:
                    _taint_target(t, env)
        elif isinstance(stmt, ast.AnnAssign) and stmt.value is not None:
            if tainted(stmt.value, env):
                _taint_target(stmt.target, env)
        elif isinstance(stmt, ast.AugAssign):
            if tainted(stmt.value, env):
                _taint_target(stmt.target, env)
        elif isinstance(stmt, (ast.If, ast.While)):
            if tainted(stmt.test, env):
                kind = "if" if isinstance(stmt, ast.If) else "while"
                report("tracer-branch", stmt,
                       f"Python `{kind}` branches on a tracer-derived "
                       f"value inside a jitted function",
                       "use jnp.where / jax.lax.cond / jax.lax.while_loop, "
                       "or mark the driver static")
            exec_body(stmt.body, env)
            exec_body(stmt.orelse, env)
        elif isinstance(stmt, ast.For):
            if tainted(stmt.iter, env):
                _taint_target(stmt.target, env)
            exec_body(stmt.body, env)
            exec_body(stmt.orelse, env)
        elif isinstance(stmt, (ast.With, ast.Try)):
            for field in ("body", "orelse", "finalbody"):
                exec_body(getattr(stmt, field, []) or [], env)
            for h in getattr(stmt, "handlers", []) or []:
                exec_body(h.body, env)

    def _taint_target(t: ast.expr, env: Set[str]) -> None:
        if isinstance(t, ast.Name):
            env.add(t.id)
        elif isinstance(t, (ast.Tuple, ast.List)):
            for e in t.elts:
                _taint_target(e, env)
        elif isinstance(t, ast.Starred):
            _taint_target(t.value, env)

    env = {n for n in _param_names(fn) if n not in root.statics} - {"self"}
    body = fn.body if isinstance(fn.body, list) else None
    if body is None:                  # lambda root: one expression
        check_exprs(ast.Expr(value=fn.body, lineno=fn.lineno, col_offset=0),
                    env)
    else:
        exec_body(body, env)
    yield from findings.values()


# ---------------------------------------------------------------------------
# Reachability + purity scan
# ---------------------------------------------------------------------------


def _reachable(project: Project, roots: List[_Fn]) -> List[_Fn]:
    seen: Set[Tuple[str, str, int]] = set()
    out: List[_Fn] = []
    work = list(roots)
    while work:
        fn = work.pop()
        if fn.key() in seen:
            continue
        seen.add(fn.key())
        out.append(fn)
        body = fn.node.body if isinstance(fn.node.body, list) \
            else [ast.Expr(value=fn.node.body, lineno=fn.node.lineno,
                           col_offset=0)]
        for stmt in body:
            for node in ast.walk(stmt):
                if isinstance(node, ast.Call):
                    target = _resolve_call(project, fn, node.func)
                    if target is not None:
                        work.append(target)
    return out


def _resolve_call(project: Project, fn: _Fn, func: ast.expr
                  ) -> Optional[_Fn]:
    module = fn.module
    if isinstance(func, ast.Name):
        # same-module top-level def, else a from-import of a function
        for stmt in module.tree.body:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and stmt.name == func.id:
                return _Fn(module, stmt)
        target = project.resolve_import(module, func.id)
        if target and target[1] is not None:
            other = project.get(target[0])
            if other:
                for stmt in other.tree.body:
                    if isinstance(stmt, (ast.FunctionDef,
                                         ast.AsyncFunctionDef)) \
                            and stmt.name == target[1]:
                        return _Fn(other, stmt)
        return None
    if isinstance(func, ast.Attribute) and isinstance(func.value, ast.Name):
        if func.value.id == "self" and fn.cls is not None:
            return _resolve_method(project, module, fn.cls, func.attr)
        target = project.resolve_import(module, func.value.id)
        if target and target[1] is None:
            other = project.get(target[0])
            if other:
                for stmt in other.tree.body:
                    if isinstance(stmt, (ast.FunctionDef,
                                         ast.AsyncFunctionDef)) \
                            and stmt.name == func.attr:
                        return _Fn(other, stmt)
    return None


def _resolve_method(project: Project, module: Module, cls: ast.ClassDef,
                    name: str) -> Optional[_Fn]:
    seen: Set[Tuple[str, str]] = set()

    def find(mod: Module, cdef: ast.ClassDef) -> Optional[_Fn]:
        if (mod.name, cdef.name) in seen:
            return None
        seen.add((mod.name, cdef.name))
        for stmt in cdef.body:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and stmt.name == name:
                return _Fn(mod, stmt, cdef)
        for base in cdef.bases:
            resolved = _resolve_base(project, mod, base)
            if resolved:
                hit = find(*resolved)
                if hit is not None:
                    return hit
        return None

    return find(module, cls)


def _purity_scan(project: Project, fn: _Fn) -> Iterator[Finding]:
    module = fn.module
    imports = module.imports()
    guarded = (class_guarded_fields(project, module, fn.cls)
               if fn.cls is not None else {})

    def is_module(name: str, expect: str) -> bool:
        target = imports.get(name)
        return target is not None and target == (expect, None)

    body = fn.node.body if isinstance(fn.node.body, list) \
        else [ast.Expr(value=fn.node.body, lineno=fn.node.lineno,
                       col_offset=0)]
    for stmt in body:
        for node in ast.walk(stmt):
            if isinstance(node, ast.Call) \
                    and isinstance(node.func, ast.Attribute):
                f = node.func
                if isinstance(f.value, ast.Name):
                    if f.value.id == "time" and f.attr in CLOCK_FUNCS \
                            and is_module("time", "time"):
                        yield Finding(
                            rule="jit-purity", code="impure-call",
                            path=module.relpath, line=node.lineno,
                            symbol=fn.symbol,
                            message=(f"`time.{f.attr}()` inside jit-"
                                     f"reachable code — the clock value "
                                     f"freezes at trace time"),
                            hint="measure outside the traced function "
                                 "(engine hooks run eagerly)")
                    if f.value.id == "random" \
                            and is_module("random", "random"):
                        yield Finding(
                            rule="jit-purity", code="impure-call",
                            path=module.relpath, line=node.lineno,
                            symbol=fn.symbol,
                            message=(f"stdlib `random.{f.attr}()` inside "
                                     f"jit-reachable code — global RNG "
                                     f"state freezes at trace time"),
                            hint="thread a jax.random key through the "
                                 "traced function")
                elif isinstance(f.value, ast.Attribute) \
                        and f.value.attr == "random" \
                        and isinstance(f.value.value, ast.Name) \
                        and is_module(f.value.value.id, "numpy"):
                    yield Finding(
                        rule="jit-purity", code="impure-call",
                        path=module.relpath, line=node.lineno,
                        symbol=fn.symbol,
                        message=(f"`np.random.{f.attr}` inside jit-"
                                 f"reachable code — numpy RNG draws "
                                 f"freeze at trace time"),
                        hint="thread a jax.random key through the traced "
                             "function")
                if f.attr in CAST_METHODS and fn.cls is None \
                        and not isinstance(fn.node, ast.Lambda):
                    pass              # taint pass owns .item() at roots;
                    #                   reachable helpers checked below
            if isinstance(node, ast.Attribute) \
                    and isinstance(node.value, ast.Name) \
                    and node.value.id == "self" \
                    and isinstance(node.ctx, ast.Load) \
                    and node.attr in guarded:
                yield Finding(
                    rule="jit-purity", code="mutable-closure",
                    path=module.relpath, line=node.lineno,
                    symbol=fn.symbol,
                    message=(f"jit-reachable method reads `self."
                             f"{node.attr}` (a `# guarded-by: "
                             f"{guarded[node.attr]}` field) — the trace "
                             f"captures one stale snapshot of shared "
                             f"engine state"),
                    hint="pass the state in as a traced argument instead "
                         "of closing over it")
            if isinstance(node, ast.Call) \
                    and isinstance(node.func, ast.Attribute) \
                    and node.func.attr == "item" \
                    and not node.args:
                yield Finding(
                    rule="jit-purity", code="tracer-cast",
                    path=module.relpath, line=node.lineno,
                    symbol=fn.symbol,
                    message=("`.item()` inside jit-reachable code — "
                             "forces a host sync and fails under trace"),
                    hint="keep the value on-device, or hoist the read "
                         "out of the traced path")
