"""exception-hygiene: no broad `except` that swallows errors silently.

``except Exception:`` has exactly three legitimate shapes in this
codebase:

1. it **re-raises** (possibly after cleanup or wrapping),
2. it **records** the failure (log / print / traceback) so the error is
   observable even though the process survives — the failover paths in
   ``repro.serving.disagg`` are the canonical example, or
3. it carries a written justification: a ``# capslint:
   disable=exception-hygiene — <why>`` comment (capability probes such as
   ``repro.kernels.registry._pallas_available``, where *any* failure
   means the same thing).

Everything else is a silent swallow: the error vanishes, the caller sees
a default, and the bug surfaces three layers away.  This checker flags
handlers whose type is bare, ``Exception``, or ``BaseException``
(including inside tuples) and whose body neither raises nor records.
"""

from __future__ import annotations

import ast
from typing import Iterator, Optional

from repro.analysis.findings import Finding
from repro.analysis.loader import Module, Project

BROAD = frozenset({"Exception", "BaseException"})
#: call names that make a swallow observable
LOG_NAME_CALLS = frozenset({"print"})
LOG_ATTR_CALLS = frozenset({
    "debug", "info", "warning", "warn", "error", "exception", "critical",
    "log", "print_exc", "print_exception", "format_exc", "record",
})


def _is_broad(handler: ast.ExceptHandler) -> bool:
    t = handler.type
    if t is None:
        return True
    exprs = t.elts if isinstance(t, ast.Tuple) else [t]
    for e in exprs:
        name = e.id if isinstance(e, ast.Name) else (
            e.attr if isinstance(e, ast.Attribute) else None)
        if name in BROAD:
            return True
    return False


def _handles(handler: ast.ExceptHandler) -> bool:
    """True when the handler re-raises or records the failure."""
    for node in ast.walk(handler):
        if isinstance(node, ast.Raise):
            return True
        if isinstance(node, ast.Call):
            if isinstance(node.func, ast.Name) \
                    and node.func.id in LOG_NAME_CALLS:
                return True
            if isinstance(node.func, ast.Attribute) \
                    and node.func.attr in LOG_ATTR_CALLS:
                return True
    return False


class ExceptionHygieneChecker:
    name = "exception-hygiene"
    description = ("`except Exception` / bare `except` must re-raise, "
                   "record the failure, or carry a `# capslint: disable` "
                   "justification")
    codes = {
        "silent-swallow": "broad handler neither re-raises nor records "
                          "the failure",
    }

    def run(self, project: Project) -> Iterator[Finding]:
        for module in project.modules.values():
            tree = module.tree
            for node, symbol in _walk_with_symbol(tree):
                if not isinstance(node, ast.ExceptHandler):
                    continue
                if _is_broad(node) and not _handles(node):
                    label = ("bare `except`" if node.type is None else
                             f"`except "
                             f"{ast.unparse(node.type)}`")
                    yield Finding(
                        rule=self.name, code="silent-swallow",
                        path=module.relpath, line=node.lineno,
                        symbol=symbol or "",
                        message=(f"{label} swallows the error without "
                                 f"re-raising or recording it"),
                        hint="narrow the exception type, log/re-raise, "
                             "or justify with `# capslint: "
                             "disable=exception-hygiene — <why>`")


def _walk_with_symbol(tree: ast.AST):
    """Yield ``(node, enclosing "Class.method" symbol)`` for every node."""

    def visit(node: ast.AST, cls: Optional[str], fn: Optional[str]):
        symbol = f"{cls}.{fn}" if cls and fn else (fn or cls)
        yield node, symbol
        for child in ast.iter_child_nodes(node):
            if isinstance(child, ast.ClassDef):
                yield from visit(child, child.name, None)
            elif isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                yield from visit(child, cls, child.name)
            else:
                yield from visit(child, cls, fn)

    yield from visit(tree, None, None)
