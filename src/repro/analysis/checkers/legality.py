"""kernel-legality: every legalized kernel config is provably dispatchable.

The FastCaps design-space story rests on one invariant: whatever block
sizes the tuner proposes, ``spec.legalize`` clamps them to values the
kernel can actually run — each block divides its dimension exactly (full
equal blocks, no ragged tail) and the per-block working set fits the
target memory.  Dispatch *assumes* this; nothing used to *check* it.

This rule checks it, symbolically.  Each :class:`repro.kernels.KernelSpec`
now declares ``block_dims(*args) -> {tuned key: dimension}`` — the same
mapping its ``legalize`` is derived from (via
``repro.kernels.registry._legalize_blocks``), so the checker and dispatch
cannot drift.  For every spec the checker builds shape cases from the
spec's own ``example_cases`` *plus* variants scaled to the serving shape
buckets in :data:`repro.configs.SHAPES` (seq 4k/32k/500k, batch 1..256),
as allocation-free ``jax.ShapeDtypeStruct`` stand-ins, then for every
candidate the tuner could ever propose
(:func:`repro.kernels.tuning.candidate_configs`) proves:

* **illegal-block** — every legalized block size is a positive int;
* **non-divisor** — it divides its ``block_dims`` dimension exactly
  (``largest_divisor`` as a verified invariant, not a hope);
* **unstable-legalize** — legalization is idempotent (re-legalizing a
  legal config is the identity; a drifting legalizer would make cached
  tuner winners resolve differently than they measured);
* **divisor-violation** — every ``spec.block_divisors`` pair ``(a, b)``
  holds after legalization: ``config[a]`` divides ``config[b]`` (e.g.
  the paged dequant kernel's ``page_size`` must divide ``kv_block`` so
  a KV block's per-row scales never straddle a cache page);
* **over-budget** — the per-block working set (every array's block
  footprint, with block dims substituted) fits the per-backend budget;
* **unverifiable** (warning) — a spec without ``block_dims`` cannot be
  verified; warnings don't gate, but they show up in the report.

Unlike the other rules this one runs against the *live* registry (it
imports ``repro.kernels``), because the invariant lives in Python
callables, not source text.  It stays cheap: nothing is allocated,
compiled, or executed beyond the specs' own pure-Python legalize/dims
functions.
"""

from __future__ import annotations

from pathlib import Path
from typing import Any, Dict, Iterator, List, Optional, Set, Tuple

from repro.analysis.findings import Finding
from repro.analysis.loader import Project

#: worst-case on-chip budget a single block's working set must fit,
#: per backend family (bytes).  Conservative by design: VMEM/SMEM-class
#: memories, not HBM.
BLOCK_BUDGET_BYTES: Dict[str, int] = {
    "cpu": 1 << 30,                   # L2/L3-ish: effectively unbounded
    "gpu": 256 << 20,                 # SM-resident working set
    "tpu": 128 << 20,                 # VMEM-class
}


def _bucket_values() -> List[int]:
    """Serving shape-bucket dims (seq + batch) from repro.configs, plus an
    odd prime-ish size so divisor degradation is exercised."""
    from repro.configs import SHAPES

    vals: Set[int] = {3}
    for info in SHAPES.values():
        vals.add(int(info["seq"]))
        vals.add(int(info["batch"]))
    return sorted(vals)


class _Struct:
    """Minimal shape/dtype stand-in (independent of jax for testability)."""

    __slots__ = ("shape", "dtype", "itemsize")

    def __init__(self, shape: Tuple[int, ...], dtype: Any,
                 itemsize: int):
        self.shape = tuple(int(d) for d in shape)
        self.dtype = dtype
        self.itemsize = itemsize


def _as_struct(value: Any) -> Any:
    """Arrays (anything with .shape and .dtype) become allocation-free
    stand-ins; everything else (ints, strings, None) passes through."""
    shape = getattr(value, "shape", None)
    dtype = getattr(value, "dtype", None)
    if shape is None or dtype is None:
        return value
    itemsize = getattr(dtype, "itemsize", None)
    if itemsize is None:
        try:
            import numpy as np

            itemsize = np.dtype(dtype).itemsize
        except Exception:  # capslint: disable=exception-hygiene — exotic
            # dtype objects without numpy equivalents: 4 bytes is the
            # conservative default for budget math, nothing else uses it.
            itemsize = 4
    return _Struct(shape, dtype, int(itemsize))


def _scaled_case(args: tuple, dims: Dict[str, int], dim_value: int,
                 bucket: int) -> Optional[tuple]:
    """A case variant with every axis equal to ``dim_value`` replaced by
    ``bucket`` (how the same kernel sees a serving-sized shape)."""
    if bucket == dim_value:
        return None
    changed = False
    out = []
    for a in args:
        if isinstance(a, _Struct):
            shape = tuple(bucket if d == dim_value else d for d in a.shape)
            changed = changed or shape != a.shape
            out.append(_Struct(shape, a.dtype, a.itemsize))
        else:
            out.append(a)
    return tuple(out) if changed else None


def _block_footprint(args: tuple, dims: Dict[str, int],
                     config: Dict[str, Any]) -> int:
    """Bytes one block touches: per array, the product of its axes with
    each axis matching a block dimension narrowed to that block size."""
    total = 0
    for a in args:
        if not isinstance(a, _Struct):
            continue
        nbytes = a.itemsize
        remaining = dict(dims)        # consume each dim once per array
        for d in a.shape:
            block = d
            for key, dim in list(remaining.items()):
                if d == dim:
                    block = min(block, int(config.get(key, d)))
                    del remaining[key]
                    break
            nbytes *= max(block, 1)
        total += nbytes
    return total


class KernelLegalityChecker:
    name = "kernel-legality"
    description = ("every tuner candidate, legalized against the example "
                   "cases and the repro.configs shape buckets, divides "
                   "its block_dims dimension and fits the per-backend "
                   "block budget")
    codes = {
        "illegal-block": "legalized block size is not a positive int",
        "non-divisor": "legalized block does not divide its dimension",
        "unstable-legalize": "legalize is not idempotent on its own "
                             "output",
        "divisor-violation": "a block_divisors pair does not hold after "
                             "legalization",
        "over-budget": "per-block working set exceeds a backend budget",
        "unverifiable": "spec declares no block_dims; legality cannot "
                        "be proven",
    }

    def __init__(self, kernel_registry=None):
        self._registry = kernel_registry

    def run(self, project: Project) -> Iterator[Finding]:
        reg = self._registry
        if reg is None:
            from repro.kernels.registry import registry as reg
        emitted: Set[Tuple[str, str, str]] = set()
        for name in reg.names():
            spec = reg.get(name)
            for f in self._check_spec(project, spec):
                key = (f.code, f.symbol, f.message)
                if key not in emitted:
                    emitted.add(key)
                    yield f

    # -- per-spec ------------------------------------------------------------

    def _location(self, project: Project, spec) -> Tuple[str, int]:
        fn = spec.block_dims or spec.legalize
        code = getattr(fn, "__code__", None)
        if code is None:              # e.g. functools.partial
            inner = getattr(fn, "func", None)
            code = getattr(inner, "__code__", None)
        if code is None:
            return (f"<kernel:{spec.name}>", 1)
        path = Path(code.co_filename)
        try:
            rel = path.resolve().relative_to(project.root).as_posix()
        except ValueError:
            rel = path.as_posix()
        return (rel, code.co_firstlineno)

    def _check_spec(self, project: Project, spec) -> Iterator[Finding]:
        path, line = self._location(project, spec)
        if spec.block_dims is None:
            yield Finding(
                rule=self.name, code="unverifiable", path=path, line=line,
                symbol=spec.name, severity="warning",
                message=(f"kernel `{spec.name}` declares no block_dims "
                         f"mapping; its legalize cannot be verified"),
                hint="declare block_dims and derive legalize via "
                     "_legalize_blocks(block_dims)")
            return
        from repro.kernels import tuning

        for args, kwargs in self._cases(spec):
            dims = spec.block_dims(*args, **kwargs)
            try:
                candidates = tuning.candidate_configs(spec, *args, **kwargs)
            # The whole point: a legalize that *crashes* on a shape case
            # is itself the finding (recorded below, never swallowed).
            # capslint: disable=exception-hygiene
            except Exception as e:
                yield Finding(
                    rule=self.name, code="illegal-block", path=path,
                    line=line, symbol=spec.name,
                    message=(f"kernel `{spec.name}` fails to legalize on "
                             f"shapes {self._shapes(args)}: "
                             f"{type(e).__name__}: {e}"),
                    hint="legalize/block_dims must accept every example "
                         "and bucket-scaled shape")
                continue
            for config in candidates:
                yield from self._check_candidate(spec, path, line, args,
                                                 kwargs, dims, config)

    def _check_candidate(self, spec, path: str, line: int, args: tuple,
                         kwargs: dict, dims: Dict[str, int],
                         config: Dict[str, Any]) -> Iterator[Finding]:
        from repro.kernels import tuning

        shapes = self._shapes(args)
        for key, dim in dims.items():
            v = config.get(key)
            if not isinstance(v, int) or isinstance(v, bool) or v < 1:
                yield Finding(
                    rule=self.name, code="illegal-block", path=path,
                    line=line, symbol=spec.name,
                    message=(f"kernel `{spec.name}`: legalized "
                             f"`{key}`={v!r} on shapes {shapes} is not a "
                             f"positive int"),
                    hint="legalize must clamp every tuned key to a "
                         "positive block size")
                continue
            if dim >= 1 and dim % v != 0:
                yield Finding(
                    rule=self.name, code="non-divisor", path=path,
                    line=line, symbol=spec.name,
                    message=(f"kernel `{spec.name}`: legalized "
                             f"`{key}`={v} does not divide its dimension "
                             f"{dim} on shapes {shapes}"),
                    hint="derive legalize from block_dims via "
                         "_legalize_blocks so largest_divisor is applied")
        for a, b in getattr(spec, "block_divisors", ()) or ():
            va, vb = config.get(a), config.get(b)
            if (isinstance(va, int) and isinstance(vb, int)
                    and va >= 1 and vb % va != 0):
                yield Finding(
                    rule=self.name, code="divisor-violation", path=path,
                    line=line, symbol=spec.name,
                    message=(f"kernel `{spec.name}`: legalized "
                             f"`{a}`={va} does not divide `{b}`={vb} on "
                             f"shapes {shapes} (declared in "
                             f"block_divisors)"),
                    hint="pass the pair to _legalize_blocks(..., "
                         "divisors=...) so both knobs are clamped "
                         "together")
        relegalized = spec.legalize(dict(config), *args, **kwargs)
        if relegalized != config:
            yield Finding(
                rule=self.name, code="unstable-legalize", path=path,
                line=line, symbol=spec.name,
                message=(f"kernel `{spec.name}`: legalize is not "
                         f"idempotent on shapes {shapes} "
                         f"({tuning.config_label(config)} -> "
                         f"{tuning.config_label(relegalized)})"),
                hint="legalize(legalize(c)) must equal legalize(c), or "
                     "cached tuner winners drift on reload")
        footprint = _block_footprint(args, dims, config)
        over = [(b, budget) for b, budget in sorted(
            BLOCK_BUDGET_BYTES.items()) if footprint > budget]
        if over:
            worst = ", ".join(f"{b} budget {budget >> 20} MiB"
                              for b, budget in over)
            yield Finding(
                rule=self.name, code="over-budget", path=path, line=line,
                symbol=spec.name,
                message=(f"kernel `{spec.name}`: block working set "
                         f"{footprint >> 20} MiB with "
                         f"{tuning.config_label(config)} on shapes "
                         f"{shapes} exceeds {worst}"),
                hint="shrink the block space or legalize against a "
                     "memory cap, not just divisibility")

    # -- case generation -------------------------------------------------

    def _cases(self, spec) -> Iterator[Tuple[tuple, dict]]:
        buckets = _bucket_values()
        for case in spec.example_cases:
            args, kwargs = spec.make_example(case)
            struct_args = tuple(_as_struct(a) for a in args)
            yield struct_args, kwargs
            dims = spec.block_dims(*struct_args, **kwargs)
            for dim_value in sorted(set(dims.values())):
                for bucket in buckets:
                    scaled = _scaled_case(struct_args, dims, dim_value,
                                          bucket)
                    if scaled is not None:
                        yield scaled, kwargs

    @staticmethod
    def _shapes(args: tuple) -> str:
        return "/".join("x".join(str(d) for d in a.shape)
                        for a in args if isinstance(a, _Struct)) or "scalar"
