"""The stock capslint rules (one module per rule).

Each module exposes one checker class implementing the
:class:`repro.analysis.Checker` protocol; they are registered by
:func:`repro.analysis.default_registry`.
"""
