"""lock-discipline: ``# guarded-by:`` annotated fields mutate under their lock.

The serving engines already follow a convention by hand: shared state
(queues, request tables, stats) is declared in ``__init__`` and only ever
mutated inside ``with self._lock:`` blocks or inside helper methods whose
``*_locked`` suffix documents "caller holds the lock"
(:meth:`repro.serving.EngineCore._complete_locked` is the seed example).
This checker turns the convention into a machine-checked contract:

* a field whose defining ``__init__`` assignment carries a
  ``# guarded-by: <lock>`` comment may be **mutated** (assigned, aug-
  assigned, ``del``-ed, or hit with a mutating container method such as
  ``append`` / ``pop`` / ``update``) only

    - lexically inside ``with self.<lock>:``, or
    - inside a method whose name ends in ``_locked``;

* a ``self.*_locked(...)`` call must itself sit inside a ``with
  self.<some lock>:`` block (or inside another ``*_locked`` method) — a
  ``*_locked`` helper reached from an unlocked public path is exactly the
  bug the suffix exists to prevent.

Known limits (by design — this is a convention checker, not an alias
analysis): mutations through a local alias (``st = self._stats;
st.ticks += 1``) and reads are not tracked, and ``with`` blocks re-entered
via nested ``def``\\ s reset to unlocked (the closure runs later, when the
lock is long released).
"""

from __future__ import annotations

import ast
import re
from typing import Dict, Iterator, List, Optional, Set, Tuple

from repro.analysis.findings import Finding
from repro.analysis.loader import Module, Project

_GUARDED_RE = re.compile(r"guarded-by:\s*([A-Za-z_][A-Za-z0-9_]*)")

#: container/object methods that mutate their receiver in place
MUTATORS = frozenset({
    "append", "appendleft", "extend", "extendleft", "insert",
    "pop", "popleft", "popitem", "remove", "discard", "clear",
    "add", "update", "setdefault", "sort", "reverse", "rotate",
})


def guarded_fields(module: Module) -> Dict[str, Dict[str, str]]:
    """``{class name: {field: lock}}`` from ``# guarded-by:`` comments on
    the ``self.<field> = ...`` lines of each class ``__init__``."""
    out: Dict[str, Dict[str, str]] = {}
    for cls in module.tree.body:
        if not isinstance(cls, ast.ClassDef):
            continue
        fields: Dict[str, str] = {}
        for fn in cls.body:
            if isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                for node in ast.walk(fn):
                    if not isinstance(node, (ast.Assign, ast.AnnAssign)):
                        continue
                    m = _GUARDED_RE.search(module.comments.get(
                        node.lineno, ""))
                    if not m:
                        continue
                    targets = (node.targets if isinstance(node, ast.Assign)
                               else [node.target])
                    for t in targets:
                        field = _self_field(t)
                        if field:
                            fields[field] = m.group(1)
        if fields:
            out[cls.name] = fields
    return out


def class_guarded_fields(project: Project, module: Module,
                         cls: ast.ClassDef) -> Dict[str, str]:
    """Guarded fields of ``cls`` including fields inherited from bases the
    project can resolve (same module, or imported ``from X import Base``)."""
    merged: Dict[str, str] = {}
    seen: Set[Tuple[str, str]] = set()

    def visit(mod: Module, cdef: ast.ClassDef) -> None:
        if (mod.name, cdef.name) in seen:
            return
        seen.add((mod.name, cdef.name))
        for base in cdef.bases:
            resolved = _resolve_base(project, mod, base)
            if resolved:
                visit(*resolved)
        merged.update(guarded_fields(mod).get(cdef.name, {}))

    visit(module, cls)
    return merged


def _resolve_base(project: Project, module: Module, base: ast.expr
                  ) -> Optional[Tuple[Module, ast.ClassDef]]:
    if isinstance(base, ast.Name):
        for node in module.tree.body:       # same module first
            if isinstance(node, ast.ClassDef) and node.name == base.id:
                return (module, node)
        target = project.resolve_import(module, base.id)
        if target and target[1] is not None:
            other = project.get(target[0])
            if other:
                for node in other.tree.body:
                    if isinstance(node, ast.ClassDef) \
                            and node.name == target[1]:
                        return (other, node)
    return None


def _self_field(node: ast.expr) -> Optional[str]:
    """The engine field a store/mutation target ultimately names:
    ``self.f`` -> f, ``self.f.g`` -> f, ``self.f[i]`` -> f."""
    while isinstance(node, (ast.Subscript, ast.Attribute)):
        if isinstance(node, ast.Attribute) \
                and isinstance(node.value, ast.Name) \
                and node.value.id == "self":
            return node.attr
        node = node.value
    return None


class LockDisciplineChecker:
    name = "lock-discipline"
    description = ("fields annotated `# guarded-by: <lock>` mutate only "
                   "under `with self.<lock>:` or in `*_locked` methods, "
                   "and `*_locked` methods are only called with a lock "
                   "held")
    codes = {
        "unguarded-mutation": "guarded field mutated without its lock",
        "locked-call-unlocked": "`*_locked` method called from an "
                                "unlocked path",
    }

    def run(self, project: Project) -> Iterator[Finding]:
        for module in project.modules.values():
            if not guarded_fields(module) and "_locked" not in module.source:
                continue              # fast path: nothing to police here
            for cls in module.tree.body:
                if isinstance(cls, ast.ClassDef):
                    yield from self._check_class(project, module, cls)

    # -- per-class ----------------------------------------------------------

    def _check_class(self, project: Project, module: Module,
                     cls: ast.ClassDef) -> Iterator[Finding]:
        guarded = class_guarded_fields(project, module, cls)
        for fn in cls.body:
            if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            if fn.name == "__init__":
                continue              # defining assignments pre-date sharing
            yield from self._check_method(module, cls, fn, guarded)

    def _check_method(self, module: Module, cls: ast.ClassDef,
                      fn: ast.FunctionDef, guarded: Dict[str, str]
                      ) -> Iterator[Finding]:
        symbol = f"{cls.name}.{fn.name}"
        contract_locked = fn.name.endswith("_locked")

        def walk(node: ast.AST, held: Set[str]) -> Iterator[Finding]:
            if isinstance(node, ast.With):
                inner = held | set(self._with_locks(node))
                for item in node.items:
                    yield from walk(item.context_expr, held)
                for child in node.body:
                    yield from walk(child, inner)
                return
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.Lambda)) and node is not fn:
                # a nested def runs later, when the lock is released
                body = node.body if isinstance(node.body, list) \
                    else [node.body]
                for child in body:
                    yield from walk(child, set())
                return
            yield from self._check_node(module, symbol, node, held,
                                        guarded, contract_locked)
            for child in ast.iter_child_nodes(node):
                yield from walk(child, held)

        for stmt in fn.body:
            yield from walk(stmt, set())

    @staticmethod
    def _with_locks(node: ast.With) -> List[str]:
        locks = []
        for item in node.items:
            ctx = item.context_expr
            if isinstance(ctx, ast.Attribute) \
                    and isinstance(ctx.value, ast.Name) \
                    and ctx.value.id == "self":
                locks.append(ctx.attr)
        return locks

    def _check_node(self, module: Module, symbol: str, node: ast.AST,
                    held: Set[str], guarded: Dict[str, str],
                    contract_locked: bool) -> Iterator[Finding]:
        # mutations: assignment / augmented assignment / del targets
        targets: List[ast.expr] = []
        if isinstance(node, ast.Assign):
            targets = list(node.targets)
        elif isinstance(node, (ast.AugAssign, ast.AnnAssign)):
            targets = [node.target]
        elif isinstance(node, ast.Delete):
            targets = list(node.targets)
        for t in targets:
            for leaf in (t.elts if isinstance(t, (ast.Tuple, ast.List))
                         else [t]):
                field = _self_field(leaf)
                yield from self._mutation(module, symbol, node, field,
                                          held, guarded, contract_locked)
        # mutations: self.<field>.append(...) etc
        if isinstance(node, ast.Call) and isinstance(node.func,
                                                     ast.Attribute):
            if node.func.attr in MUTATORS:
                field = _self_field(node.func.value)
                yield from self._mutation(module, symbol, node, field,
                                          held, guarded, contract_locked)
            # `self.*_locked()` calls need a lock held at the call site
            if node.func.attr.endswith("_locked") \
                    and isinstance(node.func.value, ast.Name) \
                    and node.func.value.id == "self" \
                    and not contract_locked and not held:
                yield Finding(
                    rule=self.name, code="locked-call-unlocked",
                    path=module.relpath, line=node.lineno, symbol=symbol,
                    message=(f"`self.{node.func.attr}()` called without "
                             f"any `with self.<lock>:` held — the "
                             f"`_locked` suffix is a caller-holds-the-"
                             f"lock contract"),
                    hint="wrap the call in `with self._lock:` (or call "
                         "from another `*_locked` method)")

    def _mutation(self, module: Module, symbol: str, node: ast.AST,
                  field: Optional[str], held: Set[str],
                  guarded: Dict[str, str], contract_locked: bool
                  ) -> Iterator[Finding]:
        if field is None or field not in guarded:
            return
        lock = guarded[field]
        if lock in held or contract_locked:
            return
        yield Finding(
            rule=self.name, code="unguarded-mutation",
            path=module.relpath, line=node.lineno, symbol=symbol,
            message=(f"field `{field}` is `# guarded-by: {lock}` but is "
                     f"mutated outside `with self.{lock}:`"),
            hint=f"take `with self.{lock}:` around the mutation, or move "
                 f"it into a `*_locked` method whose callers hold the "
                 f"lock")
