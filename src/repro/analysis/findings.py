"""Structured findings, inline suppressions, and the committed baseline.

A :class:`Finding` is the one record type every checker emits: rule id,
sub-code, ``file:line``, message and fix hint.  Findings are *fingerprinted*
without their line number (rule, code, file, enclosing symbol, message), so
a committed baseline survives unrelated edits shifting lines around — the
same idea as the kernel autotune cache being keyed by shape bucket rather
than exact shape.

Two escape hatches let the gate land strict without blocking on history:

* **inline suppression** — ``# capslint: disable=<rule>`` trailing on the
  offending line (or the line directly above) waives that rule there; the
  comment doubles as the written justification the reviewer sees.
* **baseline** — ``tools/capslint_baseline.json`` holds fingerprints of
  accepted legacy findings; ``--write-baseline`` refreshes it, and the
  gate fails only on findings *not* in it.  A stale entry (nothing matches
  it any more) fails ``--strict`` so the baseline can only shrink.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
from pathlib import Path
from typing import Any, Dict, Iterable, List, Optional, Tuple

BASELINE_VERSION = 1

#: severities, most severe first; only ``error`` findings gate CI.
SEVERITIES = ("error", "warning")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation at one source location."""

    rule: str                         # checker id, e.g. "lock-discipline"
    code: str                         # sub-rule, e.g. "unguarded-mutation"
    path: str                         # repo-relative posix path
    line: int                         # 1-based
    message: str                      # what is wrong (line-number-free, so
    #                                   fingerprints survive code motion)
    symbol: str = ""                  # enclosing "Class.method" when known
    severity: str = "error"
    hint: str = ""                    # how to fix or justify

    @property
    def location(self) -> str:
        return f"{self.path}:{self.line}"

    def fingerprint(self) -> str:
        """Stable identity for baselining: everything but the line."""
        blob = "|".join((self.rule, self.code, self.path, self.symbol,
                         self.message))
        return hashlib.sha1(blob.encode()).hexdigest()[:16]

    def to_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        d["fingerprint"] = self.fingerprint()
        return d

    def render(self) -> str:
        out = (f"{self.location}: {self.severity}: "
               f"{self.rule}[{self.code}] {self.message}")
        if self.hint:
            out += f"  [hint: {self.hint}]"
        return out


def sort_findings(findings: Iterable[Finding]) -> List[Finding]:
    """Canonical report order: severity, then location, then rule."""
    return sorted(findings, key=lambda f: (SEVERITIES.index(f.severity)
                                           if f.severity in SEVERITIES else 99,
                                           f.path, f.line, f.rule, f.code))


def apply_suppressions(project, findings: Iterable[Finding]
                       ) -> Tuple[List[Finding], List[Finding]]:
    """Split findings into (kept, suppressed) using the modules' inline
    ``# capslint: disable=`` comments.  A suppression names the rule, the
    ``rule.code``, or ``all``."""
    kept: List[Finding] = []
    suppressed: List[Finding] = []
    by_relpath = {m.relpath: m for m in project.modules.values()}
    for f in findings:
        mod = by_relpath.get(f.path)
        disabled = mod.disabled_rules(f.line) if mod else set()
        if disabled & {f.rule, f"{f.rule}.{f.code}", "all"}:
            suppressed.append(f)
        else:
            kept.append(f)
    return kept, suppressed


class Baseline:
    """The committed set of accepted legacy findings (by fingerprint)."""

    def __init__(self, entries: Optional[Dict[str, Dict[str, Any]]] = None,
                 path: Optional[Path] = None):
        self.entries = dict(entries or {})
        self.path = path

    @classmethod
    def load(cls, path) -> "Baseline":
        path = Path(path)
        if not path.exists():
            return cls({}, path=path)
        blob = json.loads(path.read_text())
        if blob.get("version") != BASELINE_VERSION:
            raise ValueError(
                f"{path}: baseline version {blob.get('version')!r} != "
                f"{BASELINE_VERSION}; regenerate with --write-baseline")
        return cls({e["fingerprint"]: e for e in blob.get("findings", [])},
                   path=path)

    def save(self, path, findings: Iterable[Finding]) -> None:
        path = Path(path)
        payload = {
            "version": BASELINE_VERSION,
            "comment": ("accepted legacy capslint findings; shrink-only — "
                        "refresh with `python -m repro.analysis "
                        "--write-baseline` and justify additions in review"),
            "findings": [
                {"fingerprint": f.fingerprint(), "rule": f.rule,
                 "code": f.code, "path": f.path, "symbol": f.symbol,
                 "message": f.message}
                for f in sort_findings(findings)],
        }
        path.write_text(json.dumps(payload, indent=1, sort_keys=True) + "\n")

    def split(self, findings: Iterable[Finding]
              ) -> Tuple[List[Finding], List[Finding], List[Dict[str, Any]]]:
        """``(new, baselined, stale)``: findings not in the baseline,
        findings the baseline accepts, and baseline entries that matched
        nothing (dead weight ``--strict`` refuses to carry)."""
        findings = list(findings)
        seen = set()
        new, accepted = [], []
        for f in findings:
            fp = f.fingerprint()
            if fp in self.entries:
                accepted.append(f)
                seen.add(fp)
            else:
                new.append(f)
        stale = [e for fp, e in sorted(self.entries.items())
                 if fp not in seen]
        return new, accepted, stale
