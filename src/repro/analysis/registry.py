"""Checker protocol + registry: typed dispatch for the capslint rules.

This mirrors the :class:`repro.kernels.KernelRegistry` idiom one layer up:
one typed spec per checker (name, description, sub-rule catalogue, run
callable), a registry that resolves names with a helpful error, and a
``run()`` that fans a shared :class:`repro.analysis.loader.Project` out to
every selected checker and returns the merged, canonically-sorted finding
list.  Checkers are constructed lazily at registration time but hold no
mutable state across runs — ``run(project)`` must be a pure function of
the project (plus, for kernel-legality, the kernel registry it verifies).
"""

from __future__ import annotations

from typing import Dict, Iterable, List, Optional, Protocol, Mapping, \
    runtime_checkable

from repro.analysis.findings import Finding, sort_findings
from repro.analysis.loader import Project


@runtime_checkable
class Checker(Protocol):
    """What every capslint rule implements."""

    #: rule id findings carry and suppressions name (kebab-case)
    name: str
    #: one-line rule description (the ``--list`` catalogue)
    description: str
    #: sub-rule code -> one-line description
    codes: Mapping[str, str]

    def run(self, project: Project) -> Iterable[Finding]:
        ...


class CheckerRegistry:
    """Name -> :class:`Checker`; resolution + fan-out."""

    def __init__(self):
        self._checkers: Dict[str, Checker] = {}

    def register(self, checker: Checker) -> Checker:
        self._checkers[checker.name] = checker
        return checker

    def names(self) -> List[str]:
        return sorted(self._checkers)

    def get(self, name: str) -> Checker:
        try:
            return self._checkers[name]
        except KeyError:
            raise ValueError(f"unknown checker {name!r}; registered: "
                             f"{self.names()}") from None

    def run(self, project: Project,
            select: Optional[Iterable[str]] = None) -> List[Finding]:
        """Run the selected checkers (all by default) over one project."""
        names = list(select) if select is not None else self.names()
        out: List[Finding] = []
        for name in names:
            out.extend(self.get(name).run(project))
        return sort_findings(out)


registry = CheckerRegistry()
_populated = False


def default_registry() -> CheckerRegistry:
    """The process-wide registry with the four stock rules registered
    (lazy import: ``repro.analysis`` stays importable without pulling the
    checker modules — or jax — until a run is requested)."""
    global _populated
    if not _populated:
        from repro.analysis.checkers import (exceptions, legality, locks,
                                             purity)

        registry.register(locks.LockDisciplineChecker())
        registry.register(purity.JitPurityChecker())
        registry.register(legality.KernelLegalityChecker())
        registry.register(exceptions.ExceptionHygieneChecker())
        _populated = True
    return registry
