"""The capslint CLI / CI gate: ``python -m repro.analysis``.

Default invocation scans the installed ``repro`` package source, runs
every registered checker, applies inline ``# capslint: disable=``
suppressions and the committed baseline, prints the surviving findings
as a table (or ``--json``), and exits non-zero when a non-baselined
*error* finding remains.  ``--strict`` (the CI lane) additionally fails
on stale baseline entries, so the baseline can only ever shrink.

    python -m repro.analysis                      # human table
    python -m repro.analysis --json findings.json # CI artifact
    python -m repro.analysis --strict             # the gate
    python -m repro.analysis --changed-only       # diff vs HEAD only
    python -m repro.analysis --select lock-discipline jit-purity
    python -m repro.analysis --write-baseline     # accept current findings
    python -m repro.analysis --list               # rule catalogue
"""

from __future__ import annotations

import argparse
import json
import subprocess
import sys
from pathlib import Path
from typing import List, Optional

from repro.analysis.findings import (Baseline, Finding, apply_suppressions,
                                     sort_findings)
from repro.analysis.loader import Project
from repro.analysis.registry import default_registry


def _package_dir() -> Path:
    import repro

    # repro is a PEP 420 namespace package: no __file__, one __path__ entry
    return Path(next(iter(repro.__path__))).resolve()


def _repo_root() -> Path:
    return _package_dir().parent.parent      # src/repro -> src -> repo


def _default_scan_paths() -> List[Path]:
    return [_package_dir()]


def changed_files(root: Path, base: str = "HEAD") -> Optional[List[str]]:
    """Repo-relative paths changed vs ``base`` (staged + unstaged +
    untracked); ``None`` when git is unavailable (fail open: report
    everything rather than silently nothing)."""
    try:
        diff = subprocess.run(
            ["git", "diff", "--name-only", base, "--"],
            cwd=root, capture_output=True, text=True, timeout=30)
        untracked = subprocess.run(
            ["git", "ls-files", "--others", "--exclude-standard"],
            cwd=root, capture_output=True, text=True, timeout=30)
    except (OSError, subprocess.SubprocessError):
        return None
    if diff.returncode != 0:
        return None
    files = [ln.strip() for ln in diff.stdout.splitlines() if ln.strip()]
    if untracked.returncode == 0:
        files.extend(ln.strip() for ln in untracked.stdout.splitlines()
                     if ln.strip())
    return files


def filter_changed(findings: List[Finding], changed: List[str]
                   ) -> List[Finding]:
    allowed = set(changed)
    return [f for f in findings if f.path in allowed]


def _print_list() -> None:
    reg = default_registry()
    for name in reg.names():
        checker = reg.get(name)
        print(f"{name}: {checker.description}")
        for code in sorted(checker.codes):
            print(f"  {name}.{code}: {checker.codes[code]}")


def main(argv: Optional[List[str]] = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="capslint: the repo's static-analysis gate")
    parser.add_argument("paths", nargs="*", type=Path,
                        help="files/directories to scan "
                             "(default: the repro package)")
    parser.add_argument("--json", nargs="?", const="-", metavar="FILE",
                        help="emit findings as JSON (to FILE, or stdout)")
    parser.add_argument("--strict", action="store_true",
                        help="CI gate: also fail on stale baseline entries")
    parser.add_argument("--baseline", type=Path, default=None,
                        help="baseline file "
                             "(default: tools/capslint_baseline.json)")
    parser.add_argument("--write-baseline", action="store_true",
                        help="accept the current findings into the "
                             "baseline and exit 0")
    parser.add_argument("--changed-only", nargs="?", const="HEAD",
                        metavar="BASE",
                        help="only report findings in files changed vs "
                             "BASE (default HEAD)")
    parser.add_argument("--select", nargs="+", metavar="RULE",
                        help="run only these checkers")
    parser.add_argument("--list", action="store_true", dest="list_rules",
                        help="print the rule catalogue and exit")
    args = parser.parse_args(argv)

    if args.list_rules:
        _print_list()
        return 0

    root = _repo_root()
    scan = [p.resolve() for p in args.paths] or _default_scan_paths()
    project = Project.load(scan, root=root)
    registry = default_registry()
    raw = registry.run(project, select=args.select)
    kept, suppressed = apply_suppressions(project, raw)

    baseline_path = args.baseline or (root / "tools" /
                                      "capslint_baseline.json")
    if args.write_baseline:
        Baseline.load(baseline_path).save(baseline_path, kept)
        print(f"wrote {len(kept)} finding(s) to {baseline_path}")
        return 0

    baseline = Baseline.load(baseline_path)
    new, baselined, stale = baseline.split(kept)

    if args.changed_only is not None:
        changed = changed_files(root, args.changed_only)
        if changed is not None:
            new = filter_changed(new, changed)

    new = sort_findings(new)
    errors = [f for f in new if f.severity == "error"]
    warnings = [f for f in new if f.severity != "error"]
    gate_failed = bool(errors) or (args.strict and bool(stale))

    if args.json is not None:
        payload = {
            "version": 1,
            "findings": [f.to_dict() for f in new],
            "counts": {"new": len(new), "errors": len(errors),
                       "warnings": len(warnings),
                       "suppressed": len(suppressed),
                       "baselined": len(baselined),
                       "stale_baseline": len(stale),
                       "modules": len(project.modules)},
            "stale_baseline": stale,
            "ok": not gate_failed,
        }
        blob = json.dumps(payload, indent=1, sort_keys=True) + "\n"
        if args.json == "-":
            sys.stdout.write(blob)
        else:
            Path(args.json).write_text(blob)
    else:
        for f in new:
            print(f.render())
        if stale:
            print(f"stale baseline entries (matched nothing — remove via "
                  f"--write-baseline):")
            for e in stale:
                print(f"  {e.get('rule')}[{e.get('code')}] "
                      f"{e.get('path')} ({e.get('fingerprint')})")
        print(f"capslint: {len(project.modules)} modules, "
              f"{len(errors)} error(s), {len(warnings)} warning(s), "
              f"{len(suppressed)} suppressed, {len(baselined)} baselined, "
              f"{len(stale)} stale baseline entr"
              f"{'y' if len(stale) == 1 else 'ies'}")

    return 1 if gate_failed else 0


if __name__ == "__main__":
    sys.exit(main())
