"""capslint: the repo's own static-analysis gate (``python -m
repro.analysis``).

The serving and kernel layers rely on conventions no general-purpose
linter knows about: shared engine state mutates under its annotated lock
(``# guarded-by:``), code reachable from ``jax.jit`` / ``pl.pallas_call``
stays trace-pure, every tuner candidate a kernel's ``legalize`` can emit
is actually dispatchable, and broad ``except`` handlers never swallow
errors silently.  This package turns those conventions into machine-
checked rules:

* :mod:`repro.analysis.loader` — parses ``src/repro`` once into a
  :class:`Project` of :class:`Module` ASTs + comment maps (shared by all
  checkers; nothing analyzed is executed, except the kernel-legality rule
  which evaluates the live registry's pure config callables);
* :mod:`repro.analysis.findings` — the :class:`Finding` record,
  ``# capslint: disable=<rule>`` inline suppressions, and the committed
  shrink-only :class:`Baseline`;
* :mod:`repro.analysis.registry` — the :class:`Checker` protocol and
  :class:`CheckerRegistry` (the :class:`repro.kernels.KernelRegistry`
  idiom, one layer up);
* :mod:`repro.analysis.checkers` — the four stock rules:
  ``lock-discipline``, ``jit-purity``, ``kernel-legality``,
  ``exception-hygiene``;
* :mod:`repro.analysis.__main__` — the CLI and CI gate (``--strict``
  fails on any non-baselined error finding and on stale baseline
  entries).

See ``docs/analysis.md`` for the rule catalogue and the suppression /
baseline workflow.
"""

from repro.analysis.findings import (Baseline, Finding, apply_suppressions,
                                     sort_findings)
from repro.analysis.loader import Module, Project
from repro.analysis.registry import (Checker, CheckerRegistry,
                                     default_registry, registry)

__all__ = [
    "Baseline",
    "Checker",
    "CheckerRegistry",
    "Finding",
    "Module",
    "Project",
    "apply_suppressions",
    "default_registry",
    "registry",
    "sort_findings",
]
