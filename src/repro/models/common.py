"""Shared model substrate: configs, param declaration, norms, RoPE, embeddings.

Parameters are plain pytrees (nested dicts of jnp arrays).  Every layer
declares its parameters once as ``ParamDef``s — (shape, logical_axes, init) —
from which both the initializer and the logical-sharding spec tree are
derived, so the two can never drift apart.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Configs
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int                 # per-expert FFN hidden dim
    n_shared: int = 0             # always-on shared experts (DeepSeekMoE)
    capacity_factor: float = 1.25
    router_dtype: str = "float32"
    # dispatch: "scatter" (per-row sort/scatter, baseline) | "onehot"
    # (GShard two-one-hot einsum with explicit expert->model sharding
    # constraints; §Perf H-B1 — kills the replicated-dispatch all-reduces:
    # dbrx train collective 104 s -> 13 s)
    dispatch: str = "onehot"
    # flatten decode tokens across the batch so capacity is global
    # (ceil(B*k/E*cf)) instead of the per-row max(8, ...) floor
    # (§Perf H-C1: 16x dispatch-FLOP cut for deepseek decode)
    global_decode_dispatch: bool = True


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 (SSD) block config."""

    d_state: int = 64
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk_size: int = 256

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclasses.dataclass(frozen=True)
class XLSTMConfig:
    slstm_every: int = 8          # xLSTM[7:1]: every 8th block is sLSTM
    mlstm_proj_factor: float = 2.0
    slstm_ff_factor: float = 1.3333
    d_conv: int = 4
    chunk_size: int = 256


@dataclasses.dataclass(frozen=True)
class LMConfig:
    arch_id: str
    family: str                   # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int = 0               # 0 -> d_model // n_heads
    act: str = "silu"             # silu -> SwiGLU; gelu -> GeGLU-less plain MLP
    glu: bool = True
    qk_norm: bool = False
    qkv_bias: bool = False
    norm: str = "rmsnorm"         # rmsnorm | layernorm
    norm_eps: float = 1e-5
    rope_theta: float = 500000.0
    causal: bool = True
    tie_embeddings: bool = False
    logit_softcap: float = 0.0
    # MoE
    moe: Optional[MoEConfig] = None
    # hybrid (zamba2-style): shared transformer block applied every k SSM layers
    ssm: Optional[SSMConfig] = None
    hybrid_attn_every: int = 0
    # xLSTM
    xlstm: Optional[XLSTMConfig] = None
    # VLM: a cross-attention layer inserted after every k self-attn layers.
    # n_layers counts BOTH self and cross layers (llama-3.2-vision convention).
    cross_attn_every: int = 0
    n_image_tokens: int = 1024
    # audio/vlm frontends are stubs: inputs are precomputed embeddings
    frontend: Optional[str] = None   # None | audio | vision
    # numerics / lowering
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    remat: bool = True
    remat_group: int = 1          # save residuals only every g layers
    loss_chunks: int = 0          # 0 -> auto (seq/1024)
    scan_layers: bool = True
    attn_q_block: int = 512       # chunked-attention query block
    attn_kv_block: int = 1024
    attn_impl: str = "chunked"    # chunked | reference | pallas
    decode_impl: str = "chunked"  # chunked | pallas — q_len=1 cache-read
    #                                path (the decode_attention kernel)
    attn_scan_remat: bool = True  # checkpoint kv-block scan body (flash
    #                                bwd: recompute p instead of saving it)
    #                                §Perf H1 — baseline variant sets False
    loss_remat: bool = True       # checkpoint CE chunk body (recompute
    #                                chunk logits in bwd) — §Perf H2
    softmax_mode: str = "exact"   # exact | taylor  (FastCaps Eq.2 option)
    max_seq_len: int = 8192

    @property
    def head_dim(self) -> int:
        return self.d_head if self.d_head else self.d_model // self.n_heads

    @property
    def is_encoder(self) -> bool:
        return not self.causal

    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    def n_self_layers(self) -> int:
        if self.cross_attn_every:
            # n_layers = self + cross;   cross = self // cross_attn_every
            k = self.cross_attn_every
            n_self = self.n_layers * k // (k + 1)
            return n_self
        return self.n_layers

    def n_cross_layers(self) -> int:
        if self.cross_attn_every:
            return self.n_layers - self.n_self_layers()
        return 0

    def param_count(self, params=None) -> int:
        if params is None:
            raise ValueError("pass a params pytree")
        return sum(int(x.size) for x in jax.tree.leaves(params))


# ---------------------------------------------------------------------------
# Param declaration
# ---------------------------------------------------------------------------

InitFn = Callable[[jax.Array, Tuple[int, ...], Any], jax.Array]


def normal_init(stddev: float) -> InitFn:
    def init(key, shape, dtype):
        return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
                * stddev).astype(dtype)

    return init


def zeros_init() -> InitFn:
    def init(key, shape, dtype):
        return jnp.zeros(shape, dtype)

    return init


def ones_init() -> InitFn:
    def init(key, shape, dtype):
        return jnp.ones(shape, dtype)

    return init


def fanin_init(fan_in: Optional[int] = None) -> InitFn:
    def init(key, shape, dtype):
        fi = fan_in if fan_in is not None else shape[0]
        std = 1.0 / math.sqrt(max(fi, 1))
        return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
                * std).astype(dtype)

    return init


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: InitFn

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def init_params(defs: Any, key: jax.Array, dtype) -> Any:
    """Initialize a (nested dict) tree of ParamDefs into arrays."""
    leaves, treedef = jax.tree.flatten(
        defs, is_leaf=lambda x: isinstance(x, ParamDef)
    )
    keys = jax.random.split(key, max(len(leaves), 1))
    arrs = [d.init(k, d.shape, dtype) for d, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, arrs)


def param_specs(defs: Any) -> Any:
    """Extract the logical-axes tree from a tree of ParamDefs."""
    return jax.tree.map(
        lambda d: d.axes, defs, is_leaf=lambda x: isinstance(x, ParamDef)
    )


def stack_specs(specs: Any) -> Any:
    """Prepend the scan 'layers' axis to every spec in a layer spec tree."""
    return jax.tree.map(
        lambda axes: ("layers",) + tuple(axes),
        specs,
        is_leaf=lambda x: isinstance(x, tuple) or x is None,
    )


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rmsnorm_defs(dim: int, axis: str = "act_embed") -> Dict[str, ParamDef]:
    return {"scale": ParamDef((dim,), (None,), ones_init())}


def layernorm_defs(dim: int) -> Dict[str, ParamDef]:
    return {
        "scale": ParamDef((dim,), (None,), ones_init()),
        "bias": ParamDef((dim,), (None,), zeros_init()),
    }


def norm_defs(cfg: LMConfig, dim: Optional[int] = None) -> Dict[str, ParamDef]:
    d = dim if dim is not None else cfg.d_model
    return layernorm_defs(d) if cfg.norm == "layernorm" else rmsnorm_defs(d)


def apply_norm(params: Dict[str, jax.Array], x: jax.Array, cfg: LMConfig,
               eps: Optional[float] = None) -> jax.Array:
    eps = cfg.norm_eps if eps is None else eps
    xf = x.astype(jnp.float32)
    if "bias" in params:  # layernorm
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    else:  # rmsnorm
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + eps) * params["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


def rms_norm_simple(x: jax.Array, eps: float = 1e-6) -> jax.Array:
    """Parameter-free RMS norm (qk-norm building block)."""
    xf = x.astype(jnp.float32)
    ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps)).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: (..., seq) int32."""
    head_dim = x.shape[-1]
    freqs = rope_frequencies(head_dim, theta)                  # (hd/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., :, None, :]                     # (..., S, 1, hd/2)
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Activations
# ---------------------------------------------------------------------------


def activation(name: str) -> Callable[[jax.Array], jax.Array]:
    return {
        "silu": jax.nn.silu,
        "gelu": jax.nn.gelu,
        "relu": jax.nn.relu,
    }[name]


def softcap(logits: jax.Array, cap: float) -> jax.Array:
    if not cap:
        return logits
    return cap * jnp.tanh(logits / cap)


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------


def embedding_defs(cfg: LMConfig) -> Dict[str, ParamDef]:
    defs: Dict[str, Any] = {}
    if cfg.frontend is None:
        defs["tok"] = ParamDef(
            (cfg.vocab, cfg.d_model), ("vocab", "embed"), normal_init(1.0)
        )
    else:
        # frontend stub: a projection from precomputed feature embeddings
        defs["frontend_proj"] = ParamDef(
            (cfg.d_model, cfg.d_model), ("embed", "embed_tp"), fanin_init()
        )
    if not cfg.tie_embeddings:
        defs["unembed"] = ParamDef(
            (cfg.d_model, cfg.vocab), ("embed", "vocab"),
            normal_init(cfg.d_model ** -0.5),
        )
    return defs


def embed_inputs(params, cfg: LMConfig, batch: Dict[str, jax.Array]) -> jax.Array:
    if cfg.frontend is None:
        x = jnp.take(params["tok"], batch["tokens"], axis=0)
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    else:
        x = batch["features"].astype(cfg.cdtype()) @ params["frontend_proj"].astype(
            cfg.cdtype()
        )
    return x.astype(cfg.cdtype())


def unembed(params, cfg: LMConfig, x: jax.Array) -> jax.Array:
    if cfg.tie_embeddings:
        w = params["tok"].astype(cfg.cdtype()).T
    else:
        w = params["unembed"].astype(cfg.cdtype())
    logits = x @ w
    return softcap(logits.astype(jnp.float32), cfg.logit_softcap)
