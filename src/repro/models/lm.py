"""LM assembly: dense / MoE / hybrid-SSM / xLSTM / encoder / VLM stacks.

Layer stacking: homogeneous *scan units* are stacked (leaves get a leading
``n_units`` dim) and iterated with ``lax.scan`` so big models trace one unit
once (compile-time O(1) in depth).  A unit is:

    dense/moe       1 transformer block
    hybrid (zamba2) 1 Mamba-2 block (+ conditional shared attn block, whose
                    single param copy rides in the scan closure)
    ssm (xlstm)     1 group = (slstm_every-1) mLSTM blocks + 1 sLSTM block
    vlm             1 group = cross_attn_every self-attn blocks + 1 cross
    audio           1 encoder block (bidirectional)

Remat: each scan unit body is wrapped in ``jax.checkpoint`` (cfg.remat);
``cfg.remat_group`` > 1 reshapes (L, ...) -> (L/g, g, ...) so only every
g-th residual is saved — the activation-memory lever for the 100B models.

Losses: cross-entropy with the unembed matmul + logsumexp computed in
*sequence chunks* (``lax.scan`` over cfg.loss_chunks slices) so the full
(B, S, vocab) logits tensor is never materialized (matters at vocab 152k).
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn_lib
from repro.models import common
from repro.models import mamba2 as mamba_lib
from repro.models import mlp as mlp_lib
from repro.models import moe as moe_lib
from repro.models import xlstm as xlstm_lib
from repro.models.common import (LMConfig, ParamDef, init_params, param_specs)
from repro.parallel.sharding import shard_constraint, rules_for_arch

AUX_LOSS_COEF = 0.01


# ---------------------------------------------------------------------------
# Per-family unit definitions
# ---------------------------------------------------------------------------


def _block_defs(cfg: LMConfig, kind: str) -> Dict[str, Any]:
    """One transformer block (kind: self | cross | mamba | mlstm | slstm)."""
    if kind == "mamba":
        return {"ln": common.norm_defs(cfg), "mixer": mamba_lib.mamba2_defs(cfg)}
    if kind == "mlstm":
        return {"ln": common.norm_defs(cfg), "mixer": xlstm_lib.mlstm_defs(cfg)}
    if kind == "slstm":
        return {"ln": common.norm_defs(cfg), "mixer": xlstm_lib.slstm_defs(cfg)}
    d: Dict[str, Any] = {
        "ln1": common.norm_defs(cfg),
        "attn": attn_lib.attention_defs(cfg, cross=(kind == "cross")),
        "ln2": common.norm_defs(cfg),
    }
    if cfg.moe is not None and kind == "self":
        d["ffn"] = moe_lib.moe_defs(cfg)
    else:
        d["ffn"] = mlp_lib.mlp_defs(cfg)
    return d


def unit_defs(cfg: LMConfig) -> Dict[str, Any]:
    """Parameter defs for ONE scan unit of this family."""
    fam = cfg.family
    if fam in ("dense", "moe", "audio"):
        return {"block": _block_defs(cfg, "self")}
    if fam == "hybrid":
        return {"block": _block_defs(cfg, "mamba")}
    if fam == "ssm":
        k = cfg.xlstm.slstm_every
        return {
            "mlstm": [_block_defs(cfg, "mlstm") for _ in range(k - 1)],
            "slstm": _block_defs(cfg, "slstm"),
        }
    if fam == "vlm":
        k = cfg.cross_attn_every
        return {
            "selfs": [_block_defs(cfg, "self") for _ in range(k)],
            "cross": _block_defs(cfg, "cross"),
        }
    raise ValueError(fam)


def n_units(cfg: LMConfig) -> int:
    fam = cfg.family
    if fam in ("dense", "moe", "audio", "hybrid"):
        return cfg.n_layers
    if fam == "ssm":
        assert cfg.n_layers % cfg.xlstm.slstm_every == 0
        return cfg.n_layers // cfg.xlstm.slstm_every
    if fam == "vlm":
        k = cfg.cross_attn_every + 1
        assert cfg.n_layers % k == 0
        return cfg.n_layers // k
    raise ValueError(fam)


def model_defs(cfg: LMConfig) -> Dict[str, Any]:
    defs: Dict[str, Any] = {"embed": common.embedding_defs(cfg)}
    if cfg.cross_attn_every:
        defs["embed"]["img_proj"] = ParamDef(
            (cfg.d_model, cfg.d_model), ("embed", "embed_tp"),
            common.fanin_init())
    defs["final_ln"] = common.norm_defs(cfg)
    if cfg.family == "hybrid":
        defs["shared"] = _block_defs(cfg, "self")   # zamba2 shared block
    return defs


# ---------------------------------------------------------------------------
# Init / spec trees (stacked units)
# ---------------------------------------------------------------------------


def init(cfg: LMConfig, key: jax.Array) -> Dict[str, Any]:
    k_top, k_units = jax.random.split(key)
    params = init_params(model_defs(cfg), k_top, cfg.pdtype())
    u_defs = unit_defs(cfg)
    keys = jax.random.split(k_units, n_units(cfg))
    params["units"] = jax.vmap(
        lambda k: init_params(u_defs, k, cfg.pdtype()))(keys)
    return params


def specs(cfg: LMConfig) -> Dict[str, Any]:
    sp = param_specs(model_defs(cfg))
    unit_sp = param_specs(unit_defs(cfg))
    sp["units"] = common.stack_specs(unit_sp)
    return sp


def param_structs(cfg: LMConfig) -> Any:
    """ShapeDtypeStruct tree — no allocation (dry-run entry)."""
    return jax.eval_shape(lambda k: init(cfg, k),
                          jax.ShapeDtypeStruct((2,), jnp.uint32))


# ---------------------------------------------------------------------------
# Block bodies
# ---------------------------------------------------------------------------


def _apply_self_block(p, cfg: LMConfig, x, positions, kv_cache, cache_index,
                      rules, token_mask=None, prefill_offset=0,
                      paged_tables=None):
    h = common.apply_norm(p["ln1"], x, cfg)
    a, new_kv = attn_lib.self_attention(p["attn"], cfg, h, positions,
                                        kv_cache, cache_index,
                                        prefill_offset=prefill_offset,
                                        paged_tables=paged_tables)
    x = x + a
    h = common.apply_norm(p["ln2"], x, cfg)
    if cfg.moe is not None and "router" in p["ffn"]:
        y, aux = moe_lib.moe_apply(p["ffn"], cfg, h, token_mask=token_mask)
    else:
        y, aux = mlp_lib.mlp_apply(p["ffn"], cfg, h), 0.0
    x = x + y
    x = shard_constraint(x, ("batch", "seq", "act_embed"), rules)
    return x, new_kv, aux


def _apply_cross_block(p, cfg: LMConfig, x, img_feats, cross_cache, rules):
    h = common.apply_norm(p["ln1"], x, cfg)
    a, new_cache = attn_lib.cross_attention(p["attn"], cfg, h, img_feats,
                                            cross_cache)
    x = x + a
    h = common.apply_norm(p["ln2"], x, cfg)
    x = x + mlp_lib.mlp_apply(p["ffn"], cfg, h)
    x = shard_constraint(x, ("batch", "seq", "act_embed"), rules)
    return x, new_cache


# ---------------------------------------------------------------------------
# Forward (per family), scan-stacked with remat
# ---------------------------------------------------------------------------


def _scan_units(cfg: LMConfig, x, stacked_params, caches, body):
    """Generic scanner.  body(x, unit_p, unit_cache) -> (x, new_cache, aux).

    caches: stacked pytree with leading n_units dim (or None).
    Returns (x, new_caches, aux_total)."""
    nu = n_units(cfg)
    g = max(1, getattr(cfg, "remat_group", 1))
    if nu % g:
        g = 1

    def unit_body(carry, xs):
        x, aux_acc = carry
        p, c = xs
        x, c_new, aux = body(x, p, c)
        return (x, aux_acc + aux), c_new

    def group_body(carry, xs):
        if g == 1:
            return unit_body(carry, xs)
        for i in range(g):
            sub = jax.tree.map(lambda t: t[i], xs)
            carry_new, c_new = unit_body(carry, sub)
            carry = carry_new
            if i == 0:
                outs = [c_new]
            else:
                outs.append(c_new)
        stacked = jax.tree.map(lambda *ts: jnp.stack(ts), *outs) \
            if outs[0] is not None else None
        return carry, stacked

    wrapped = jax.checkpoint(group_body) if cfg.remat else group_body

    def regroup(t):
        return t.reshape(nu // g, g, *t.shape[1:]) if g > 1 else t

    if caches is not None:
        cache_xs = jax.tree.map(regroup, caches)
    else:
        cache_xs = (jnp.zeros((nu // g, g, 0), jnp.float32) if g > 1
                    else _nones(nu))
    xs = (jax.tree.map(regroup, stacked_params), cache_xs)
    (x, aux), new_caches = jax.lax.scan(wrapped, (x, 0.0), xs)
    if new_caches is not None and g > 1:
        new_caches = jax.tree.map(
            lambda t: t.reshape(nu, *t.shape[2:]), new_caches)
    return x, new_caches, aux


def _nones(n):
    return jnp.zeros((n, 0), jnp.float32)   # placeholder xs with leading dim


def forward(params: Dict[str, Any], cfg: LMConfig, batch: Dict[str, jax.Array],
            caches: Optional[Dict[str, Any]] = None,
            cache_index: Optional[jax.Array] = None,
            prefill_offset: int = 0,
            paged_tables=None,
            ) -> Tuple[jax.Array, Optional[Dict[str, Any]], jax.Array]:
    """Returns (final hidden states (B,S,d), new caches, aux loss).

    ``prefill_offset`` (static int): continuation prefill — the cache
    already holds rows ``[0, prefill_offset)`` (a shared prompt prefix
    restored from the paged prefix cache) and this forward writes rows
    ``[prefill_offset, prefill_offset + S)``, attending the cached prefix
    plus the fresh span.  Attention families only (dense/moe/vlm).

    ``paged_tables`` (B, P) int32: paged decode — ``caches["kv"]`` leaves
    are PagePool pool arrays (L, n_pages, page, K, D) instead of dense
    per-slot caches, and attention reads/writes pages through the per-slot
    tables (dense/moe decode only; see ``attention._paged_decode``).
    """
    rules = rules_for_arch(cfg.arch_id)
    fam = cfg.family
    x = common.embed_inputs(params["embed"], cfg, batch)
    x = shard_constraint(x, ("batch", "seq", "act_embed"), rules)
    s = x.shape[1]
    if "positions" in batch:
        positions = batch["positions"]
    elif cache_index is not None:
        ci = jnp.asarray(cache_index, jnp.int32)
        if ci.ndim == 1:                 # per-slot decode positions (B,)
            positions = jnp.broadcast_to(ci[:, None], (x.shape[0], s))
        else:
            positions = jnp.full((x.shape[0], s), 0, jnp.int32) + ci
    else:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None],
                                     (x.shape[0], s))

    if paged_tables is not None and fam not in ("dense", "moe"):
        raise ValueError("paged_tables: dense/moe decode only")

    if fam in ("dense", "moe", "audio"):
        token_mask = batch.get("token_mask")   # ragged moe exactness

        def body(x, p, c):
            kv = None if caches is None else c
            return _apply_self_block(p["block"], cfg, x, positions, kv,
                                     cache_index, rules,
                                     token_mask=token_mask,
                                     prefill_offset=prefill_offset,
                                     paged_tables=paged_tables)
        kv = caches["kv"] if caches is not None else None
        x, new_kv, aux = _scan_units(cfg, x, params["units"], kv, body)
        new_caches = {"kv": new_kv} if caches is not None else None

    elif fam == "hybrid":
        if prefill_offset:
            raise ValueError("prefill_offset: attention families only")
        x, new_caches, aux = _hybrid_forward(params, cfg, x, positions,
                                             batch, caches, cache_index,
                                             rules)

    elif fam == "ssm":
        if prefill_offset:
            raise ValueError("prefill_offset: attention families only")

        def body(x, p, c):
            k = cfg.xlstm.slstm_every
            new_m = []
            for i in range(k - 1):
                pi = p["mlstm"][i]
                h = common.apply_norm(pi["ln"], x, cfg)
                ci = None if caches is None else jax.tree.map(
                    lambda t: t[i], c["mlstm"])
                y, cs = xlstm_lib.mlstm_apply(pi["mixer"], cfg, h, ci)
                x = x + y
                new_m.append(cs)
            h = common.apply_norm(p["slstm"]["ln"], x, cfg)
            cs_in = None if caches is None else c["slstm"]
            y, ss = xlstm_lib.slstm_apply(p["slstm"]["mixer"], cfg, h, cs_in)
            x = x + y
            x = shard_constraint(x, ("batch", "seq", "act_embed"), rules)
            if caches is None:
                return x, None, 0.0
            mst = jax.tree.map(lambda *ts: jnp.stack(ts), *new_m)
            return x, {"mlstm": mst, "slstm": ss}, 0.0
        x, new_caches, aux = _scan_units(cfg, x, params["units"],
                                         caches["units"] if caches else None,
                                         body)
        new_caches = ({"units": new_caches} if caches is not None else None)

    elif fam == "vlm":
        img = batch.get("image_features")
        if img is not None:
            img = (img.astype(cfg.cdtype())
                   @ params["embed"]["img_proj"].astype(cfg.cdtype()))

        def body(x, p, c):
            aux = 0.0
            new_kv = []
            k = cfg.cross_attn_every
            for i in range(k):
                pi = p["selfs"][i]
                kv = None if caches is None else jax.tree.map(
                    lambda t: t[i], c["kv"])
                x, kv_n, a = _apply_self_block(pi, cfg, x, positions, kv,
                                               cache_index, rules,
                                               token_mask=batch.get(
                                                   "token_mask"),
                                               prefill_offset=prefill_offset)
                aux += a
                new_kv.append(kv_n)
            cross_c = None if caches is None else c["cross"]
            x, new_cross = _apply_cross_block(p["cross"], cfg, x, img,
                                              cross_c, rules)
            if caches is None:
                return x, None, aux
            kv_st = jax.tree.map(lambda *ts: jnp.stack(ts), *new_kv)
            return x, {"kv": kv_st, "cross": new_cross}, aux
        x, new_caches, aux = _scan_units(cfg, x, params["units"],
                                         caches["units"] if caches else None,
                                         body)
        new_caches = ({"units": new_caches} if caches is not None else None)
    else:
        raise ValueError(fam)

    x = common.apply_norm(params["final_ln"], x, cfg)
    return x, new_caches, aux


def _hybrid_forward(params, cfg, x, positions, batch, caches, cache_index,
                    rules):
    """zamba2: scanned Mamba-2 stack; the single shared transformer block is
    applied after flagged layers (layer_idx % hybrid_attn_every ==
    hybrid_attn_every - 1), its KV cache indexed by site."""
    k = cfg.hybrid_attn_every
    flags = (jnp.arange(cfg.n_layers) % k) == (k - 1)
    sites = jnp.cumsum(flags.astype(jnp.int32)) - 1        # site per layer
    shared_p = params["shared"]
    kv_all = None if caches is None else caches["shared_kv"]

    def body(carry, xs):
        x, aux, kv_all = carry
        p, mamba_c, flag, site = xs
        h = common.apply_norm(p["block"]["ln"], x, cfg)
        mc = None if caches is None else mamba_c
        y, mc_new = mamba_lib.mamba2_apply(p["block"]["mixer"], cfg, h, mc)
        x = x + y
        x = shard_constraint(x, ("batch", "seq", "act_embed"), rules)

        def with_shared(args):
            x, kv_all = args
            if kv_all is None:
                x2, _, _ = _apply_self_block(shared_p, cfg, x, positions,
                                             None, cache_index, rules)
                return x2, kv_all
            kv_site = jax.tree.map(
                lambda t: jax.lax.dynamic_index_in_dim(t, site, 0, False),
                kv_all)
            x2, kv_new, _ = _apply_self_block(shared_p, cfg, x, positions,
                                              kv_site, cache_index, rules)
            kv_all2 = jax.tree.map(
                lambda full, new: jax.lax.dynamic_update_index_in_dim(
                    full, new.astype(full.dtype), site, 0),
                kv_all, kv_new)
            return x2, kv_all2

        def without_shared(args):
            return args

        x, kv_all = jax.lax.cond(flag, with_shared, without_shared,
                                 (x, kv_all))
        return (x, aux, kv_all), mc_new

    wrapped = jax.checkpoint(body) if cfg.remat else body
    mamba_caches = caches["mamba"] if caches is not None else None
    xs = (params["units"],
          mamba_caches if mamba_caches is not None else _nones(cfg.n_layers),
          flags, sites)
    (x, aux, kv_all), new_mamba = jax.lax.scan(wrapped, (x, 0.0, kv_all), xs)
    new_caches = None
    if caches is not None:
        new_caches = {"mamba": new_mamba, "shared_kv": kv_all}
    return x, new_caches, aux


# ---------------------------------------------------------------------------
# Losses / steps
# ---------------------------------------------------------------------------


def chunked_ce_loss(params, cfg: LMConfig, x: jax.Array, labels: jax.Array,
                    n_chunks: int = 0) -> jax.Array:
    """Cross-entropy over (B, S) without materializing (B, S, V).

    The unembed matmul + logsumexp run per sequence chunk inside a scan."""
    b, s, d = x.shape
    if n_chunks <= 0:
        n_chunks = max(1, s // 1024)
    while s % n_chunks:
        n_chunks -= 1
    cs = s // n_chunks
    xc = x.reshape(b, n_chunks, cs, d).transpose(1, 0, 2, 3)
    lc = labels.reshape(b, n_chunks, cs).transpose(1, 0, 2)

    def chunk_loss(acc, inp):
        xk, lk = inp                                    # (B,cs,d), (B,cs)
        logits = common.unembed(params["embed"], cfg, xk)  # fp32 (B,cs,V)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lk[..., None], axis=-1)[..., 0]
        return acc + jnp.sum(lse - gold), None

    # §Perf H2: recompute chunk logits in bwd rather than saving the
    # stacked (n_chunks, B, cs, V) fp32 logits (2.5+ GB/dev at vocab 150k).
    body = jax.checkpoint(chunk_loss) if cfg.loss_remat else chunk_loss
    total, _ = jax.lax.scan(body, jnp.float32(0.0), (xc, lc))
    return total / (b * s)


def loss_fn(params, cfg: LMConfig, batch: Dict[str, jax.Array]
            ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    x, _, aux = forward(params, cfg, batch)
    labels = batch["labels"]
    ce = chunked_ce_loss(params, cfg, x, labels)
    loss = ce + AUX_LOSS_COEF * aux
    return loss, {"loss": loss, "ce": ce, "aux": aux}


def prefill_step(params, cfg: LMConfig, batch: Dict[str, jax.Array],
                 caches: Dict[str, Any]
                 ) -> Tuple[jax.Array, Dict[str, Any]]:
    """Forward + cache write; returns (last-token logits (B, V), caches)."""
    x, new_caches, _ = forward(params, cfg, batch, caches)
    logits = common.unembed(params["embed"], cfg, x[:, -1:, :])
    return logits[:, 0], new_caches


def decode_step(params, cfg: LMConfig, batch: Dict[str, jax.Array],
                caches: Dict[str, Any], paged_tables=None
                ) -> Tuple[jax.Array, Dict[str, Any]]:
    """One-token decode.  batch: tokens (B,1), pos scalar or (B,) int32.

    A vector ``pos`` gives every slot its own cache index (ragged
    continuous batching); a scalar keeps the uniform-tick behaviour.
    With ``paged_tables`` (B, P), ``caches`` carries pool-shaped leaves
    and decode addresses pages through the tables (no gather-to-dense).
    """
    x, new_caches, _ = forward(params, cfg, batch, caches,
                               cache_index=batch["pos"],
                               paged_tables=paged_tables)
    logits = common.unembed(params["embed"], cfg, x[:, -1:, :])
    return logits[:, 0], new_caches


def ragged_prefill_step(params, cfg: LMConfig, batch: Dict[str, jax.Array],
                        caches: Dict[str, Any]
                        ) -> Tuple[jax.Array, Dict[str, Any]]:
    """Prefill right-padded ragged prompts in one batched forward.

    ``batch``: ``tokens`` (B, S) left-aligned with a zero pad *suffix*,
    ``lengths`` (B,) real prompt lengths.  Positions are 0..S-1 per slot
    and the causal mask keeps every real token from attending the pad
    suffix, so dense/vlm families are exact; moe is exact too — a
    ``token_mask`` built from ``lengths`` keeps pad tokens out of expert
    routing and recomputes each row's effective GShard capacity from its
    *real* token count (see ``repro.models.moe``), so ragged moe serving
    matches per-request ``generate()`` bit for bit.  Recurrent families
    (ssm/hybrid) fold the pad suffix into their state — the same
    approximation the uniform-length engine made; keep their prompts
    uniform when exactness matters.

    Returns per-slot logits at each prompt's final *real* token and the
    updated caches.  Cache rows at indices >= length hold pad garbage; the
    vector-``pos`` decode path masks them via per-slot valid lengths.
    """
    tokens, lengths = batch["tokens"], batch["lengths"]
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None],
                                 (b, s))
    fwd_batch = dict(batch, positions=positions)
    fwd_batch.pop("lengths")
    if cfg.moe is not None:           # ragged moe exactness (capacity
        fwd_batch["token_mask"] = (   # from real, not padded, lengths)
            positions < lengths.astype(jnp.int32)[:, None])
    x, new_caches, _ = forward(params, cfg, fwd_batch, caches)
    idx = jnp.clip(lengths.astype(jnp.int32) - 1, 0, s - 1)
    last = x[jnp.arange(b), idx]                    # (B, d)
    logits = common.unembed(params["embed"], cfg, last[:, None, :])
    return logits[:, 0], new_caches


def continuation_prefill_step(params, cfg: LMConfig,
                              batch: Dict[str, jax.Array],
                              caches: Dict[str, Any], offset: int
                              ) -> Tuple[jax.Array, Dict[str, Any]]:
    """Ragged prefill of prompt *suffixes* against a cached shared prefix.

    The caches already hold KV rows ``[0, offset)`` — a prefix-cache hit
    restored at page granularity by ``repro.serving.pages``.  ``tokens``
    (B, S) are the left-aligned suffix tokens (zero pad suffix) and
    ``lengths`` (B,) the real suffix lengths.  Positions run
    ``offset .. offset+S-1`` and attention covers the cached prefix plus
    the fresh span, so the shared span is never recomputed.

    moe caveat: GShard expert capacity derives from the *suffix* token
    count, while per-request ``generate()`` derives it from the full
    prompt — routing-drop behaviour can differ when capacity binds.
    Dense/vlm suffix logits are the exact continuation of the full
    prefill.  ``offset == 0`` reduces to :func:`ragged_prefill_step`.
    """
    if offset == 0:
        return ragged_prefill_step(params, cfg, batch, caches)
    tokens, lengths = batch["tokens"], batch["lengths"]
    b, s = tokens.shape
    positions = jnp.broadcast_to(
        jnp.arange(offset, offset + s, dtype=jnp.int32)[None], (b, s))
    fwd_batch = dict(batch, positions=positions)
    fwd_batch.pop("lengths")
    if cfg.moe is not None:
        fwd_batch["token_mask"] = (
            jnp.arange(s, dtype=jnp.int32)[None]
            < lengths.astype(jnp.int32)[:, None])
    x, new_caches, _ = forward(params, cfg, fwd_batch, caches,
                               prefill_offset=offset)
    idx = jnp.clip(lengths.astype(jnp.int32) - 1, 0, s - 1)
    last = x[jnp.arange(b), idx]                    # (B, d)
    logits = common.unembed(params["embed"], cfg, last[:, None, :])
    return logits[:, 0], new_caches


# ---------------------------------------------------------------------------
# Cache construction
# ---------------------------------------------------------------------------


def make_caches(cfg: LMConfig, batch: int, max_len: int,
                as_structs: bool = False) -> Optional[Dict[str, Any]]:
    """Decode/prefill cache pytree (or ShapeDtypeStructs for the dry-run)."""
    fam = cfg.family
    hd = cfg.head_dim

    def kv(n, length):
        shape = (n, batch, length, cfg.n_kv_heads, hd)
        return {"k": jax.ShapeDtypeStruct(shape, jnp.bfloat16),
                "v": jax.ShapeDtypeStruct(shape, jnp.bfloat16)}

    if fam in ("dense", "moe"):
        out = {"kv": kv(cfg.n_layers, max_len)}
    elif fam == "audio":
        return None                                   # encoder: no decode
    elif fam == "hybrid":
        n_sites = sum(1 for i in range(cfg.n_layers)
                      if i % cfg.hybrid_attn_every == cfg.hybrid_attn_every - 1)
        mamba = jax.tree.map(
            lambda sd: jax.ShapeDtypeStruct((cfg.n_layers,) + sd.shape,
                                            sd.dtype),
            mamba_lib.mamba2_state_defs(cfg, batch))
        out = {"mamba": mamba, "shared_kv": kv(n_sites, max_len)}
    elif fam == "ssm":
        nu = n_units(cfg)
        k = cfg.xlstm.slstm_every
        ml = jax.tree.map(
            lambda sd: jax.ShapeDtypeStruct((nu, k - 1) + sd.shape, sd.dtype),
            xlstm_lib.mlstm_state_defs(cfg, batch))
        sl = jax.tree.map(
            lambda sd: jax.ShapeDtypeStruct((nu,) + sd.shape, sd.dtype),
            xlstm_lib.slstm_state_defs(cfg, batch))
        out = {"units": {"mlstm": ml, "slstm": sl}}
    elif fam == "vlm":
        nu = n_units(cfg)
        k = cfg.cross_attn_every
        self_kv = jax.tree.map(
            lambda sd: jax.ShapeDtypeStruct((nu, k) + sd.shape[1:], sd.dtype),
            kv(1, max_len))
        cross = {"k": jax.ShapeDtypeStruct(
            (nu, batch, cfg.n_image_tokens, cfg.n_kv_heads, hd), jnp.bfloat16),
            "v": jax.ShapeDtypeStruct(
            (nu, batch, cfg.n_image_tokens, cfg.n_kv_heads, hd), jnp.bfloat16)}
        out = {"units": {"kv": self_kv, "cross": cross}}
    else:
        raise ValueError(fam)
    if as_structs:
        return out
    return jax.tree.map(lambda sd: jnp.zeros(sd.shape, sd.dtype), out)


def cache_specs(cfg: LMConfig) -> Optional[Dict[str, Any]]:
    """Logical-axis tree matching make_caches output."""
    fam = cfg.family
    kv_ax = {"k": ("layers", "batch", "kv_seq", "kv_heads", "kv_head_dim"),
             "v": ("layers", "batch", "kv_seq", "kv_heads", "kv_head_dim")}
    if fam in ("dense", "moe"):
        return {"kv": kv_ax}
    if fam == "audio":
        return None
    if fam == "hybrid":
        mamba = jax.tree.map(
            lambda ax: ("layers",) + tuple(ax),
            mamba_lib.mamba2_state_specs(),
            is_leaf=lambda x: isinstance(x, tuple))
        return {"mamba": mamba, "shared_kv": kv_ax}
    if fam == "ssm":
        ml = jax.tree.map(lambda ax: ("layers", None) + tuple(ax),
                          xlstm_lib.mlstm_state_specs(),
                          is_leaf=lambda x: isinstance(x, tuple))
        sl = jax.tree.map(lambda ax: ("layers",) + tuple(ax),
                          xlstm_lib.slstm_state_specs(),
                          is_leaf=lambda x: isinstance(x, tuple))
        return {"units": {"mlstm": ml, "slstm": sl}}
    if fam == "vlm":
        self_kv = {"k": ("layers", None, "batch", "kv_seq", "kv_heads",
                         "kv_head_dim"),
                   "v": ("layers", None, "batch", "kv_seq", "kv_heads",
                         "kv_head_dim")}
        cross = {"k": ("layers", "batch", None, "kv_heads", "kv_head_dim"),
                 "v": ("layers", "batch", None, "kv_heads", "kv_head_dim")}
        return {"units": {"kv": self_kv, "cross": cross}}
    raise ValueError(fam)


def cache_shardings(cfg: LMConfig, caches: Any, mesh: Any,
                    rules: Any = None) -> Any:
    """NamedShardings placing a ``make_caches`` pytree onto ``mesh``.

    Composes :func:`cache_specs` (the logical-axis tree) with the
    shape-aware single-pass policy of ``parallel.sharding``: the cache
    ``batch`` axis — the *slot* axis in continuous-batching serving —
    claims the data-parallel mesh axes when the slot count divides them,
    so every device owns an equal contiguous block of slots for the whole
    decode (no cross-device cache traffic; the per-slot scatter/gather of
    ``attention.self_attention`` stays device-local).  Indivisible dims
    replicate, and freed axes fall through to ``kv_seq``/``kv_head_dim``
    exactly as in training placement.
    """
    from repro.parallel import sharding as sharding_lib

    if rules is None:
        rules = sharding_lib.DEFAULT_RULES
    return sharding_lib.shardings_for(caches, cache_specs(cfg), rules, mesh)


def gather_cache_rows(cfg: LMConfig, slot_idx: jax.Array, caches: Any
                      ) -> Any:
    """Gather per-slot cache rows along each leaf's ``batch`` (slot) axis.

    ``slot_idx`` is ``(k,)`` int32; the result is a ``make_caches``-shaped
    pytree whose batch dim is ``k`` — the per-request decode state of the
    selected slots (KV rows for attention families, recurrent state for
    ssm/hybrid).  The batch axis position is recovered per leaf from
    :func:`cache_specs`, never hardcoded per family; leaves without a
    ``batch`` axis (none today) pass through unchanged.  This is the
    extraction half of a serving cache handoff; the inverse is
    :func:`scatter_cache_rows`.
    """
    specs = cache_specs(cfg)

    def one(axes, c):
        if "batch" not in axes:
            return c
        ax = axes.index("batch")
        rows = jnp.take(jnp.moveaxis(c, ax, 0), slot_idx, axis=0)
        return jnp.moveaxis(rows, 0, ax)

    return jax.tree.map(one, specs, caches,
                        is_leaf=lambda x: isinstance(x, tuple))


def concat_cache_rows(cfg: LMConfig, rows_list: list) -> Any:
    """Concatenate per-slot row pytrees along each leaf's ``batch`` axis.

    Batches k single-slot :func:`gather_cache_rows` results into one
    k-row tree so a serving handoff group can be scattered with ONE
    :func:`scatter_cache_rows` call instead of k full-cache rewrites.
    """
    if not rows_list:
        raise ValueError(
            "concat_cache_rows: empty rows_list — a handoff group must "
            "contain at least one gathered row pytree")
    if len(rows_list) == 1:
        return rows_list[0]
    specs = cache_specs(cfg)

    def one(axes, *leaves):
        if "batch" not in axes:
            return leaves[0]
        return jnp.concatenate(leaves, axis=axes.index("batch"))

    return jax.tree.map(one, specs, *rows_list,
                        is_leaf=lambda x: isinstance(x, tuple))


def scatter_cache_rows(cfg: LMConfig, slot_idx: jax.Array, rows: Any,
                       caches: Any) -> Any:
    """Write sub-batch cache rows ``rows`` into ``caches`` at ``slot_idx``.

    The batch dim sits at a different axis per cache family; its index is
    recovered from the logical-axis tree (:func:`cache_specs`) rather than
    hardcoded per family.  Out-of-range indices (a sub-batch's pad rows)
    are dropped by the scatter.  Injection half of a serving cache
    handoff and of ragged batched prefill (the sub-batch prefills on
    fresh caches, then its rows scatter into the engine's slots).
    """
    specs = cache_specs(cfg)

    def one(axes, n, o):
        if "batch" not in axes:
            return o
        ax = axes.index("batch")
        om = jnp.moveaxis(o, ax, 0)
        nm = jnp.moveaxis(n, ax, 0).astype(o.dtype)
        return jnp.moveaxis(om.at[slot_idx].set(nm, mode="drop"), 0, ax)

    return jax.tree.map(one, specs, rows, caches,
                        is_leaf=lambda x: isinstance(x, tuple))


def cache_row_nbytes(rows: Any) -> int:
    """Payload size in bytes of a gathered cache-row pytree.

    What a serving handoff transport actually moves per request: the sum
    over leaves of ``nbytes`` (jax and numpy arrays both expose it
    host-side, so this never forces a device sync).  ``None``/empty
    trees size to 0."""
    return int(sum(int(getattr(leaf, "nbytes", 0))
                   for leaf in jax.tree.leaves(rows)))


def model_flops_per_token(cfg: LMConfig, params_total: int,
                          params_active: Optional[int] = None) -> float:
    """MODEL_FLOPS ~ 6 * N (active) per token (roofline §)."""
    n = params_active if params_active is not None else params_total
    return 6.0 * n
