"""Attention: GQA/MHA with RoPE, qk-norm, qkv-bias, cross-attention, KV cache.

Three interchangeable inner implementations (cfg.attn_impl):
  * ``reference`` — full score matrix, for tests/small shapes.
  * ``chunked``   — flash-style online-softmax over KV blocks via lax.scan;
                    O(S * kv_block) transient memory.  Used by the dry-run
                    (Pallas does not lower to the CPU backend non-interpreted).
  * ``pallas``    — the registry's ``flash_attention`` kernel
                    (TPU target; interpret-mode on CPU).

``softmax_mode="taylor"`` swaps the exact exp for the FastCaps Eq.2 Taylor
polynomial (with range reduction — see core/approx_math.py), reproducing the
paper's approx-softmax as a selectable mode in the LM substrate.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import approx_math
from repro.models import common
from repro.models.common import LMConfig, ParamDef, fanin_init, zeros_init, ones_init

NEG_INF = -1e30

# int8 cache-page quantization (repro.serving.pages): symmetric per-row
# scales — one fp32 scale per (layer, position) row of K and of V.  Rows
# are quantized once at cache write (prefill page write or decode row
# write) and dequantized at every read, so serving memory holds int8.
KV_QUANT_MAX = 127.0
KV_QUANT_EPS = 1e-8


def quantize_kv_rows(x: jax.Array):
    """(..., H, D) rows -> (int8 rows, fp32 per-row scales (...,))."""
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=(-2, -1))
    scale = jnp.maximum(amax / KV_QUANT_MAX, KV_QUANT_EPS)
    q = jnp.clip(jnp.round(xf / scale[..., None, None]),
                 -KV_QUANT_MAX, KV_QUANT_MAX)
    return q.astype(jnp.int8), scale


def dequantize_kv(q: jax.Array, scale: jax.Array, dtype) -> jax.Array:
    """Invert :func:`quantize_kv_rows`; broadcasts (..., ) scales."""
    return (q.astype(jnp.float32) * scale[..., None, None]).astype(dtype)


# ---------------------------------------------------------------------------
# Param defs
# ---------------------------------------------------------------------------


def attention_defs(cfg: LMConfig, cross: bool = False) -> Dict[str, Any]:
    d, hd = cfg.d_model, cfg.head_dim
    nh, nkv = cfg.n_heads, cfg.n_kv_heads
    defs: Dict[str, Any] = {
        "wq": ParamDef((d, nh, hd), ("embed", "heads", "head_dim"), fanin_init(d)),
        "wk": ParamDef((d, nkv, hd), ("embed", "kv_heads", "head_dim"), fanin_init(d)),
        "wv": ParamDef((d, nkv, hd), ("embed", "kv_heads", "head_dim"), fanin_init(d)),
        "wo": ParamDef((nh, hd, d), ("heads", "head_dim", "embed"),
                       fanin_init(nh * hd)),
    }
    if cfg.qkv_bias:
        defs["bq"] = ParamDef((nh, hd), ("heads", "head_dim"), zeros_init())
        defs["bk"] = ParamDef((nkv, hd), ("kv_heads", "head_dim"), zeros_init())
        defs["bv"] = ParamDef((nkv, hd), ("kv_heads", "head_dim"), zeros_init())
    if cfg.qk_norm:
        defs["q_norm"] = ParamDef((hd,), (None,), ones_init())
        defs["k_norm"] = ParamDef((hd,), (None,), ones_init())
    if cross:
        # tanh-gated residual injection (llama-3.2-vision style), init 0 so the
        # model starts as the pure text model.
        defs["gate"] = ParamDef((), (), zeros_init())
    return defs


# ---------------------------------------------------------------------------
# Softmax variants
# ---------------------------------------------------------------------------


def _exp(x: jax.Array, mode: str) -> jax.Array:
    if mode == "taylor":
        return approx_math.taylor_exp(x, range_reduce=True)
    return jnp.exp(x)


def _masked_softmax(scores: jax.Array, mask: Optional[jax.Array], mode: str) -> jax.Array:
    """softmax over the last axis in fp32; mask True = attend."""
    scores = scores.astype(jnp.float32)
    if mask is not None:
        scores = jnp.where(mask, scores, NEG_INF)
    m = jnp.max(scores, axis=-1, keepdims=True)
    m = jnp.maximum(m, NEG_INF)  # guard all-masked rows
    e = _exp(scores - jax.lax.stop_gradient(m), mode)
    if mask is not None:
        e = jnp.where(mask, e, 0.0)
    return e / jnp.maximum(jnp.sum(e, axis=-1, keepdims=True), 1e-30)


# ---------------------------------------------------------------------------
# Projections
# ---------------------------------------------------------------------------


def _project_qkv(params, cfg: LMConfig, xq: jax.Array, xkv: jax.Array):
    cd = cfg.cdtype()
    q = jnp.einsum("bsd,dhk->bshk", xq.astype(cd), params["wq"].astype(cd))
    k = jnp.einsum("btd,dhk->bthk", xkv.astype(cd), params["wk"].astype(cd))
    v = jnp.einsum("btd,dhk->bthk", xkv.astype(cd), params["wv"].astype(cd))
    if cfg.qkv_bias:
        q = q + params["bq"].astype(cd)
        k = k + params["bk"].astype(cd)
        v = v + params["bv"].astype(cd)
    if cfg.qk_norm:
        q = common.rms_norm_simple(q) * params["q_norm"].astype(cd)
        k = common.rms_norm_simple(k) * params["k_norm"].astype(cd)
    return q, k, v


def _out_proj(params, cfg: LMConfig, attn_out: jax.Array) -> jax.Array:
    cd = cfg.cdtype()
    return jnp.einsum("bshk,hkd->bsd", attn_out.astype(cd), params["wo"].astype(cd))


def _group_heads(q: jax.Array, n_kv: int) -> jax.Array:
    """(B,S,H,D) -> (B,S,K,G,D) where H = K*G."""
    b, s, h, d = q.shape
    g = h // n_kv
    return q.reshape(b, s, n_kv, g, d)


# ---------------------------------------------------------------------------
# Inner attention implementations
# ---------------------------------------------------------------------------


def _reference_attention(q, k, v, cfg: LMConfig, causal: bool,
                         q_offset: int = 0) -> jax.Array:
    """q: (B,S,H,D); k,v: (B,T,K,D) -> (B,S,H,D)."""
    b, s, h, d = q.shape
    t, nkv = k.shape[1], k.shape[2]
    qg = _group_heads(q, nkv)                      # (B,S,K,G,D)
    scale = 1.0 / math.sqrt(d)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, k) * scale
    mask = None
    if causal:
        qpos = jnp.arange(s) + q_offset
        kpos = jnp.arange(t)
        mask = (kpos[None, :] <= qpos[:, None])[None, None, None]
    p = _masked_softmax(scores, mask, cfg.softmax_mode).astype(v.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", p, v)
    return out.reshape(b, s, h, d)


def _chunked_attention(q, k, v, cfg: LMConfig, causal: bool,
                       q_offset: int = 0,
                       kv_valid_len: Optional[jax.Array] = None) -> jax.Array:
    """Flash-style online softmax over KV blocks (lax.scan).

    q: (B,S,H,D); k,v: (B,T,K,D).  T must be divisible by the kv block.
    ``kv_valid_len``: optional (B,) — mask out cache positions >= len.
    """
    b, s, h, d = q.shape
    t, nkv = k.shape[1], k.shape[2]
    blk = min(cfg.attn_kv_block, t)
    while t % blk:
        blk //= 2
    nblk = t // blk
    g = h // nkv
    qg = _group_heads(q, nkv)                       # (B,S,K,G,D)
    scale = 1.0 / math.sqrt(d)
    qpos = (jnp.arange(s) + q_offset)[None, :]      # (1,S)

    kb = k.reshape(b, nblk, blk, nkv, d).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(b, nblk, blk, nkv, d).transpose(1, 0, 2, 3, 4)

    def body(carry, inp):
        m_prev, l_prev, acc = carry
        idx, kblk, vblk = inp                        # kblk: (B,blk,K,D)
        kpos = idx * blk + jnp.arange(blk)           # (blk,)
        scores = jnp.einsum("bskgd,btkd->bkgst", qg, kblk).astype(jnp.float32)
        scores = scores * scale                      # (B,K,G,S,blk)
        mask = jnp.ones((b, 1, 1, s, blk), bool)
        if causal:
            mask = mask & (kpos[None, :] <= qpos[:, :, None])[:, None, None]
        if kv_valid_len is not None:
            mask = mask & (kpos[None, :] < kv_valid_len[:, None])[:, None, None, None]
        scores = jnp.where(mask, scores, NEG_INF)
        m_blk = jnp.max(scores, axis=-1)             # (B,K,G,S)
        m_new = jnp.maximum(m_prev, m_blk)
        alpha = _exp(m_prev - m_new, cfg.softmax_mode)
        p = _exp(scores - m_new[..., None], cfg.softmax_mode)
        p = jnp.where(mask, p, 0.0)
        l_new = l_prev * alpha + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bkgst,btkd->bkgsd", p.astype(vblk.dtype), vblk)
        acc = acc * alpha[..., None].astype(acc.dtype) + pv.astype(jnp.float32)
        return (m_new, l_new, acc), None

    m0 = jnp.full((b, nkv, g, s), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, nkv, g, s), jnp.float32)
    acc0 = jnp.zeros((b, nkv, g, s, d), jnp.float32)
    # flash-style backward (§Perf H1): checkpointing the kv-block body
    # recomputes scores/p in the bwd pass instead of saving the stacked
    # (nblk, B, K, G, S, blk) probability/mask residuals — the dominant
    # activation-memory term for long-context cells.
    scan_body = jax.checkpoint(body) if cfg.attn_scan_remat else body
    (m, l, acc), _ = jax.lax.scan(
        scan_body, (m0, l0, acc0), (jnp.arange(nblk), kb, vb)
    )
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = out.transpose(0, 3, 1, 2, 4).reshape(b, s, h, d)  # (B,S,K,G,D)->(B,S,H,D)
    return out.astype(q.dtype)


def _inner_attention(q, k, v, cfg: LMConfig, causal: bool, q_offset: int = 0,
                     kv_valid_len=None) -> jax.Array:
    if cfg.attn_impl == "reference":
        assert kv_valid_len is None
        return _reference_attention(q, k, v, cfg, causal, q_offset)
    if cfg.attn_impl == "pallas":
        from repro import kernels

        if kv_valid_len is None and q.shape[1] > 1:
            # registry dispatch: backend probe + tuned/default block sizes
            return kernels.flash_attention(q, k, v, causal=causal,
                                           q_offset=q_offset,
                                           softmax_mode=cfg.softmax_mode)
        # decode and masked-cache paths fall back to chunked
    return _chunked_attention(q, k, v, cfg, causal, q_offset, kv_valid_len)


# ---------------------------------------------------------------------------
# Public layer entry points
# ---------------------------------------------------------------------------


def _paged_decode(params, cfg: LMConfig, q, k, v, cache, idx, tables):
    """Decode one token against *pool-shaped* cache leaves.

    ``cache`` leaves are PagePool pool slices — k/v (n_pages, page, K, D)
    (int8 plus ``k_scale``/``v_scale`` (n_pages, page) when quantized) —
    and ``tables`` (B, P) maps each slot's logical page index to a pool
    page (negative = sentinel / unmapped).  The fresh row is written
    straight into its page (sentinel writes dropped), then the
    ``decode_attention`` kernel reads the pages through the table via
    scalar prefetch — no gather-to-dense materialization.
    """
    from repro import kernels

    b = q.shape[0]
    n_pages, page = cache["k"].shape[0], cache["k"].shape[1]
    quant = "k_scale" in cache
    rows = jnp.arange(b)
    pid = tables[rows, idx // page]
    pid = jnp.where(pid < 0, n_pages, pid)  # sentinel -> out-of-range drop
    off = idx % page
    if quant:
        kq, ks = quantize_kv_rows(k[:, 0])
        vq, vs = quantize_kv_rows(v[:, 0])
        ck = cache["k"].at[pid, off].set(kq, mode="drop")
        cv = cache["v"].at[pid, off].set(vq, mode="drop")
        ksc = cache["k_scale"].at[pid, off].set(
            ks.astype(cache["k_scale"].dtype), mode="drop")
        vsc = cache["v_scale"].at[pid, off].set(
            vs.astype(cache["v_scale"].dtype), mode="drop")
        new_cache = {"k": ck, "v": cv, "k_scale": ksc, "v_scale": vsc}
        ks_arg, vs_arg = ksc, vsc
    else:
        ck = cache["k"].at[pid, off].set(
            k[:, 0].astype(cache["k"].dtype), mode="drop")
        cv = cache["v"].at[pid, off].set(
            v[:, 0].astype(cache["v"].dtype), mode="drop")
        new_cache = {"k": ck, "v": cv}
        ks_arg = vs_arg = None
    valid = idx.astype(jnp.int32) + 1
    out = kernels.decode_attention(
        q, ck, cv, valid, tables=jnp.clip(tables, 0, n_pages - 1),
        ks=ks_arg, vs=vs_arg, softmax_mode=cfg.softmax_mode)
    return _out_proj(params, cfg, out), new_cache


def self_attention(params, cfg: LMConfig, x: jax.Array, positions: jax.Array,
                   cache: Optional[Dict[str, jax.Array]] = None,
                   cache_index: Optional[jax.Array] = None,
                   prefill_offset: int = 0,
                   paged_tables=None,
                   ) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    """Self-attention with optional KV cache.

    Modes:
      * cache=None                      — training / encoder forward.
      * cache given, x.shape[1] > 1     — prefill: writes cache[off:off+S]
                                          (``off = prefill_offset``, static;
                                          off > 0 = continuation prefill
                                          attending the cached prefix).
      * cache given, x.shape[1] == 1    — decode: writes cache[idx], attends
                                          to cache[0:idx+1].

    Quantized cache pages (repro.serving.pages): a cache dict carrying
    ``k_scale``/``v_scale`` leaves holds int8 rows with per-row fp32
    scales.  Reads dequantize (`dequantize_kv`); writes quantize the
    fresh rows (`quantize_kv_rows`) and update the scale leaves, so the
    resident cache stays int8 end to end.

    Sharding contract (serving): a vector ``cache_index`` (B,) addresses
    each batch row's own cache row, and both the row-aligned scatter
    (``cache.at[arange(B), idx]``) and the ``kv_valid_len`` mask are
    elementwise along the batch dim — so when ``repro.serving`` shards
    the cache's ``batch`` (slot) axis across a mesh, XLA SPMD keeps every
    per-slot read/write device-local and the sharded decode is
    bit-identical to the single-device engine.
    """
    q, k, v = _project_qkv(params, cfg, x, x)
    q = common.apply_rope(q, positions, cfg.rope_theta)
    k = common.apply_rope(k, positions, cfg.rope_theta)

    if cache is None:
        out = _inner_attention(q, k, v, cfg, causal=cfg.causal)
        return _out_proj(params, cfg, out), None

    quant = "k_scale" in cache
    s = x.shape[1]
    off = int(prefill_offset)
    if s > 1:  # prefill (off > 0: continuation against a cached prefix)
        if off and not cfg.causal:
            raise ValueError("continuation prefill requires a causal model")
        if quant:
            kq, ks = quantize_kv_rows(k)
            vq, vs = quantize_kv_rows(v)
            ck = jax.lax.dynamic_update_slice(cache["k"], kq, (0, off, 0, 0))
            cv = jax.lax.dynamic_update_slice(cache["v"], vq, (0, off, 0, 0))
            ksc = jax.lax.dynamic_update_slice(
                cache["k_scale"], ks.astype(cache["k_scale"].dtype), (0, off))
            vsc = jax.lax.dynamic_update_slice(
                cache["v_scale"], vs.astype(cache["v_scale"].dtype), (0, off))
            new_cache = {"k": ck, "v": cv, "k_scale": ksc, "v_scale": vsc}
            if off:
                t = off + s
                kk = dequantize_kv(ck[:, :t], ksc[:, :t], q.dtype)
                vv = dequantize_kv(cv[:, :t], vsc[:, :t], q.dtype)
                out = _inner_attention(q, kk, vv, cfg, causal=True,
                                       q_offset=off)
            else:
                out = _inner_attention(q, k, v, cfg, causal=cfg.causal)
        else:
            ck = jax.lax.dynamic_update_slice(
                cache["k"], k.astype(cache["k"].dtype), (0, off, 0, 0))
            cv = jax.lax.dynamic_update_slice(
                cache["v"], v.astype(cache["v"].dtype), (0, off, 0, 0))
            new_cache = {"k": ck, "v": cv}
            if off:
                t = off + s
                out = _inner_attention(q, ck[:, :t].astype(q.dtype),
                                       cv[:, :t].astype(q.dtype), cfg,
                                       causal=True, q_offset=off)
            else:
                out = _inner_attention(q, k, v, cfg, causal=cfg.causal)
    else:  # decode one token
        idx = cache_index if cache_index is not None else positions[:, 0].max()
        if paged_tables is not None:
            if getattr(idx, "ndim", 0) != 1:
                raise ValueError("paged decode requires vector cache_index")
            return _paged_decode(params, cfg, q, k, v, cache, idx,
                                 paged_tables)
        if getattr(idx, "ndim", 0) == 1:
            # per-slot cache indices (B,): ragged continuous batching —
            # each slot writes its own row and attends its own prefix
            b = x.shape[0]
            rows = jnp.arange(b)
            if quant:
                kq, ks = quantize_kv_rows(k[:, 0])
                vq, vs = quantize_kv_rows(v[:, 0])
                ck = cache["k"].at[rows, idx].set(kq)
                cv = cache["v"].at[rows, idx].set(vq)
                ksc = cache["k_scale"].at[rows, idx].set(
                    ks.astype(cache["k_scale"].dtype))
                vsc = cache["v_scale"].at[rows, idx].set(
                    vs.astype(cache["v_scale"].dtype))
            else:
                ck = cache["k"].at[rows, idx].set(
                    k[:, 0].astype(cache["k"].dtype))
                cv = cache["v"].at[rows, idx].set(
                    v[:, 0].astype(cache["v"].dtype))
            valid = idx.astype(jnp.int32) + 1
        else:
            if quant:
                raise ValueError(
                    "quantized cache decode requires vector cache_index")
            ck = jax.lax.dynamic_update_slice(
                cache["k"], k.astype(cache["k"].dtype), (0, idx, 0, 0))
            cv = jax.lax.dynamic_update_slice(
                cache["v"], v.astype(cache["v"].dtype), (0, idx, 0, 0))
            valid = jnp.full((x.shape[0],), idx + 1, jnp.int32)
        if quant:
            new_cache = {"k": ck, "v": cv, "k_scale": ksc, "v_scale": vsc}
        else:
            new_cache = {"k": ck, "v": cv}
        if cfg.decode_impl == "pallas":
            # dense-cache decode kernel: the cache stays resident (int8
            # stays int8 — dequantized per kv-block in-kernel) instead of
            # materializing a dequantized/cast full-cache copy per step
            from repro import kernels

            if quant:
                out = kernels.decode_attention(
                    q, ck, cv, valid, ks=ksc, vs=vsc,
                    softmax_mode=cfg.softmax_mode)
            else:
                out = kernels.decode_attention(
                    q, ck, cv, valid, softmax_mode=cfg.softmax_mode)
        else:
            if quant:
                kk = dequantize_kv(ck, ksc, q.dtype)
                vv = dequantize_kv(cv, vsc, q.dtype)
            else:
                kk, vv = ck.astype(q.dtype), cv.astype(q.dtype)
            out = _inner_attention(q, kk, vv, cfg, causal=False,
                                   kv_valid_len=valid)
    return _out_proj(params, cfg, out), new_cache


def cross_attention(params, cfg: LMConfig, x: jax.Array,
                    kv_feats: Optional[jax.Array] = None,
                    cache: Optional[Dict[str, jax.Array]] = None,
                    ) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    """Cross-attention onto (precomputed) image features; tanh-gated output.

    During prefill, kv_feats is projected and cached; during decode the cached
    K/V are reused (kv_feats=None).
    """
    if cache is not None and kv_feats is None:
        k, v = cache["k"].astype(cfg.cdtype()), cache["v"].astype(cfg.cdtype())
        cd = cfg.cdtype()
        q = jnp.einsum("bsd,dhk->bshk", x.astype(cd), params["wq"].astype(cd))
        if cfg.qkv_bias:
            q = q + params["bq"].astype(cd)
        if cfg.qk_norm:
            q = common.rms_norm_simple(q) * params["q_norm"].astype(cd)
        new_cache = cache
    else:
        q, k, v = _project_qkv(params, cfg, x, kv_feats)
        new_cache = {"k": k, "v": v}
    out = _inner_attention(q, k, v, cfg, causal=False)
    y = _out_proj(params, cfg, out)
    gate = jnp.tanh(params["gate"].astype(y.dtype))
    return y * gate, new_cache


def make_kv_cache(cfg: LMConfig, batch: int, max_len: int, n_layers: int,
                  dtype=jnp.bfloat16) -> Dict[str, jax.Array]:
    """Stacked (layers-first) KV cache pytree."""
    shape = (n_layers, batch, max_len, cfg.n_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def kv_cache_specs(stacked: bool = True):
    axes = ("batch", None, "kv_heads", None)
    if stacked:
        axes = ("layers",) + axes
    return {"k": axes, "v": axes}
