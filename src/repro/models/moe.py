"""Fine-grained Mixture-of-Experts with shared experts (DeepSeekMoE / DBRX).

Dispatch design (TPU/SPMD-aware — see DESIGN.md §4):

Tokens stay laid out as (B, S, d) with B sharded over the data axis and the
residual stream replicated over the model axis.  Dispatch is *per batch row*
(vmap over B): each row independently top-k routes its S tokens, sorts the
(token, expert) pairs by expert, and gathers into a capacity-padded
(E, C_row, d) buffer.  Because E is sharded over the model axis and the row's
tokens are replicated over it, the gather is rank-local; the only collective
the partitioner must insert is the all-reduce over the model axis when the
per-expert partial outputs are combined back into the (replicated) residual —
exactly the one reduction Megatron-style TP already pays.  There is no
(T, E, C) one-hot dispatch tensor and no cross-data-shard all-to-all.

Capacity is per-row (GShard-style per-group capacity): C = ceil(S·k/E · cf),
rounded up to a multiple of 8 for TPU lane alignment.  Overflow tokens are
dropped (standard capacity-factor semantics; the aux load-balance loss keeps
drops rare).

Ragged batches: ``moe_apply`` takes an optional ``token_mask`` (B, S) marking
real tokens.  The capacity *buffer* stays sized by the padded S (shape
stability under jit), but masked tokens neither route nor consume capacity,
and each row's *effective* capacity is recomputed from its real token count
with exactly the static formula — so a prompt prefilled inside a right-padded
ragged batch sees the same expert-capacity drops it would see prefilled
alone, making ragged moe serving exact w.r.t. per-request ``generate()``.
The capacity factor is quantized to a /1024 rational so the static (python
``math``) and dynamic (jnp integer) capacity computations cannot drift.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import LMConfig, ParamDef, fanin_init, activation
from repro.models import mlp as mlp_lib


def _cf_q(moe) -> int:
    """``capacity_factor`` as a /1024 rational numerator (>= 1)."""
    return max(1, int(round(moe.capacity_factor * 1024)))


def _capacity(seq: int, moe) -> int:
    den = 1024 * moe.n_experts
    c = (seq * moe.top_k * _cf_q(moe) + den - 1) // den
    return max(8, ((c + 7) // 8) * 8)


def _capacity_dyn(n_real: jax.Array, moe) -> jax.Array:
    """Per-row effective capacity from *real* token counts — the same
    integer formula as :func:`_capacity`, in traced arithmetic, so a
    row padded to S gets exactly the capacity its real length would
    have earned in its own batch."""
    den = 1024 * moe.n_experts
    c = (n_real.astype(jnp.int32) * moe.top_k * _cf_q(moe) + den - 1) // den
    return jnp.maximum(8, ((c + 7) // 8) * 8)


def moe_defs(cfg: LMConfig) -> Dict[str, Any]:
    assert cfg.moe is not None
    m = cfg.moe
    d, f, e = cfg.d_model, m.d_expert, m.n_experts
    defs: Dict[str, Any] = {
        "router": ParamDef((d, e), ("embed", None), fanin_init(d)),
        # additive router bias; expert pruning drives dead experts to -1e9
        "router_b": ParamDef((e,), (None,),
                             lambda k, s, dt: jnp.zeros(s, dt)),
        "wi": ParamDef((e, d, f), ("expert", "embed", "expert_mlp"), fanin_init(d)),
        "wg": ParamDef((e, d, f), ("expert", "embed", "expert_mlp"), fanin_init(d)),
        "wo": ParamDef((e, f, d), ("expert", "expert_mlp", "embed"), fanin_init(f)),
    }
    if m.n_shared:
        shared_cfg = cfg  # shared experts form one fused dense FFN
        defs["shared"] = mlp_lib.mlp_defs(shared_cfg, d_ff=m.n_shared * f)
    return defs


def _route_row(logits: jax.Array, top_k: int) -> Tuple[jax.Array, jax.Array]:
    """(S, E) fp32 logits -> (S, k) weights (softmax over the top-k), ids."""
    vals, ids = jax.lax.top_k(logits, top_k)
    w = jax.nn.softmax(vals, axis=-1)
    return w, ids


def _dispatch_row(x: jax.Array, ids: jax.Array, w: jax.Array,
                  n_experts: int, capacity: int,
                  mask: Optional[jax.Array] = None,
                  cap_row: Optional[jax.Array] = None
                  ) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """One batch row: gather tokens into (E, C, d) capacity buffers.

    x: (S, d); ids/w: (S, k).  ``mask`` (S,) marks real tokens — masked
    tokens take the sentinel expert id E (stable argsort puts them last,
    bincount and the scatter drop them) so they neither route nor steal
    capacity ranks.  ``cap_row`` is this row's effective capacity
    (<= the ``capacity`` buffer size); ``None`` means the full buffer.
    Returns (dispatched (E*C, d), combine scatter indices, sorted token
    ids, sorted weights·keep).
    """
    s, k = ids.shape
    flat_e = ids.reshape(-1)                      # (S*k,)
    if mask is not None:
        flat_e = jnp.where(jnp.repeat(mask, k), flat_e, n_experts)
    flat_t = jnp.repeat(jnp.arange(s), k)         # token index per slot
    flat_w = w.reshape(-1)

    order = jnp.argsort(flat_e, stable=True)
    e_sorted = flat_e[order]
    t_sorted = flat_t[order]
    w_sorted = flat_w[order]

    counts = jnp.bincount(flat_e, length=n_experts)  # sentinel E drops
    starts = jnp.cumsum(counts) - counts                     # exclusive
    rank = jnp.arange(s * k) - starts[jnp.minimum(e_sorted, n_experts - 1)]
    eff = capacity if cap_row is None else cap_row
    keep = (rank < eff) & (e_sorted < n_experts)
    # out-of-bounds scatter destinations drop; !keep slots also carry a
    # zeroed src, so the buffer stays exact either way
    dest = jnp.where(keep, e_sorted * capacity + rank,
                     n_experts * capacity)                   # (S*k,)

    zeros = jnp.zeros((n_experts * capacity, x.shape[-1]), x.dtype)
    src = x[t_sorted] * keep[:, None].astype(x.dtype)
    dispatched = zeros.at[dest].add(src, mode="drop")
    return dispatched, dest, t_sorted, jnp.where(keep, w_sorted, 0.0)


def _combine_row(y_exp: jax.Array, dest: jax.Array, t_sorted: jax.Array,
                 w_keep: jax.Array, seq: int) -> jax.Array:
    """Scatter expert outputs back to token order with routing weights."""
    gathered = y_exp[dest] * w_keep[:, None].astype(y_exp.dtype)   # (S*k, d)
    out = jnp.zeros((seq, y_exp.shape[-1]), y_exp.dtype)
    return out.at[t_sorted].add(gathered)


def _rank_within_expert(ids: jax.Array, n_experts: int) -> jax.Array:
    """ids (S, k) -> rank (S, k): position of each (token, slot) within its
    expert's arrival order (row-major over (S, k))."""
    s, k = ids.shape
    flat = ids.reshape(-1)                                   # (S*k,)
    oh = jax.nn.one_hot(flat, n_experts, dtype=jnp.int32)    # (S*k, E)
    rank_flat = jnp.cumsum(oh, axis=0) - oh                  # exclusive
    rank = jnp.take_along_axis(rank_flat, flat[:, None], axis=1)[:, 0]
    return rank.reshape(s, k)


def _moe_onehot(params, cfg: LMConfig, x, logits, cap: int,
                token_mask: Optional[jax.Array] = None,
                cap_rows: Optional[jax.Array] = None):
    """GShard-style dispatch/combine as two-one-hot einsums with explicit
    sharding constraints: the dispatch tensor and expert buffers are
    sharded (batch->data, expert->model) so the expert matmuls are local
    per model shard and the ONLY model-axis collective is the all-reduce
    of the combined output partial sums (§Perf H-B1)."""
    from repro.parallel.sharding import rules_for_arch, shard_constraint

    m = cfg.moe
    cd = cfg.cdtype()
    b, s, d = x.shape
    rules = rules_for_arch(cfg.arch_id)

    vals, ids = jax.lax.top_k(logits, m.top_k)               # (B,S,k)
    w = jax.nn.softmax(vals, axis=-1).astype(cd)
    if token_mask is not None:
        # sentinel expert id E: one_hot gives an all-zero row, so masked
        # tokens neither dispatch nor advance any expert's rank counter
        ids = jnp.where(token_mask[:, :, None], ids, m.n_experts)
    rank = jax.vmap(lambda i: _rank_within_expert(i, m.n_experts))(ids)
    eff = cap if cap_rows is None else cap_rows[:, None, None]
    keep = (rank < eff)
    if token_mask is not None:
        keep = keep & token_mask[:, :, None]
    oh_e = jax.nn.one_hot(ids, m.n_experts, dtype=cd)        # (B,S,k,E)
    oh_c = jax.nn.one_hot(jnp.where(keep, rank, cap), cap,
                          dtype=cd)                          # (B,S,k,C)
    # dispatch tensor D[b,e,c,s] (0/1); combine adds routing weights
    disp_w = jnp.einsum("bske,bskc->becs", oh_e, oh_c)
    comb_w = jnp.einsum("bske,bskc,bsk->becs", oh_e, oh_c,
                        w * keep.astype(cd))
    disp_w = shard_constraint(disp_w, ("batch", "act_expert", None, None),
                              rules)
    comb_w = shard_constraint(comb_w, ("batch", "act_expert", None, None),
                              rules)

    disp = jnp.einsum("becs,bsd->becd", disp_w, x.astype(cd))
    disp = shard_constraint(disp, ("batch", "act_expert", None, None),
                            rules)
    act = activation(cfg.act)
    h = act(jnp.einsum("becd,edf->becf", disp, params["wg"].astype(cd))) \
        * jnp.einsum("becd,edf->becf", disp, params["wi"].astype(cd))
    y_e = jnp.einsum("becf,efd->becd", h, params["wo"].astype(cd))
    y_e = shard_constraint(y_e, ("batch", "act_expert", None, None), rules)
    # combine: contraction over (e, c) -> partial sums all-reduce on model
    y = jnp.einsum("becs,becd->bsd", comb_w, y_e)
    y = shard_constraint(y, ("batch", None, "act_embed"), rules)
    return y


def _moe_scatter(params, cfg: LMConfig, x, logits, cap: int,
                 token_mask: Optional[jax.Array] = None,
                 cap_rows: Optional[jax.Array] = None):
    """Baseline per-row sort/scatter dispatch (vmap over batch rows)."""
    m = cfg.moe
    cd = cfg.cdtype()
    b, s, d = x.shape

    def one_row(x_row, logit_row, mask_row=None, cap_row=None):
        w, ids = _route_row(logit_row, m.top_k)
        dispatched, dest, t_sorted, w_keep = _dispatch_row(
            x_row.astype(cd), ids, w.astype(cd), m.n_experts, cap,
            mask=mask_row, cap_row=cap_row)
        disp = dispatched.reshape(m.n_experts, cap, d)          # (E, C, d)
        act = activation(cfg.act)
        h_g = jnp.einsum("ecd,edf->ecf", disp, params["wg"].astype(cd))
        h_u = jnp.einsum("ecd,edf->ecf", disp, params["wi"].astype(cd))
        h = act(h_g) * h_u
        y_e = jnp.einsum("ecf,efd->ecd", h, params["wo"].astype(cd))
        y_row = _combine_row(y_e.reshape(m.n_experts * cap, d), dest,
                             t_sorted, w_keep, s)
        return y_row

    if token_mask is None:
        return jax.vmap(one_row)(x, logits)
    return jax.vmap(one_row)(x, logits, token_mask, cap_rows)


def moe_apply(params: Dict[str, Any], cfg: LMConfig, x: jax.Array,
              token_mask: Optional[jax.Array] = None
              ) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (y, aux_loss).

    ``token_mask`` (B, S) marks real tokens in a right-padded ragged
    batch: masked tokens neither route, consume expert capacity, nor
    enter the aux loss, and each row's effective capacity derives from
    its *real* token count (see module docstring).  ``None`` (the
    uniform-batch path) keeps the padded-length behaviour."""
    m = cfg.moe
    b, s, d = x.shape

    # §Perf H-C1: decode (S==1) flattens tokens across the batch so the
    # capacity floor applies once globally, not per row.
    flattened = s == 1 and b > 1 and m.global_decode_dispatch
    if flattened:
        x = x.reshape(1, b, d)
        b, s = 1, b
        if token_mask is not None:
            token_mask = token_mask.reshape(1, s)

    cap = _capacity(s, m)
    cap_rows = None
    if token_mask is not None:
        token_mask = token_mask.astype(bool)
        # monotone formula: real count <= S means row cap <= buffer cap,
        # so the minimum is a safety net, not a behaviour change
        cap_rows = jnp.minimum(
            _capacity_dyn(jnp.sum(token_mask, axis=1), m), cap)
    logits = (x.astype(jnp.float32) @ params["router"].astype(jnp.float32))
    if "router_b" in params:
        logits = logits + params["router_b"].astype(jnp.float32)

    if m.dispatch == "onehot":
        y = _moe_onehot(params, cfg, x, logits, cap, token_mask, cap_rows)
    else:
        y = _moe_scatter(params, cfg, x, logits, cap, token_mask, cap_rows)

    # Switch-style load-balance auxiliary loss: E * sum(f_e * p_e)
    probs = jax.nn.softmax(logits, axis=-1)                     # (B,S,E)
    _, top_ids = jax.lax.top_k(logits, m.top_k)
    oh_top = jax.nn.one_hot(top_ids, m.n_experts, dtype=jnp.float32)
    if token_mask is None:
        frac = jnp.mean(oh_top, axis=(0, 1, 2))
        pmean = jnp.mean(probs, axis=(0, 1))
    else:                             # means over real tokens only
        mw = token_mask.astype(jnp.float32)
        denom = jnp.maximum(jnp.sum(mw), 1.0)
        frac = jnp.sum(oh_top * mw[:, :, None, None],
                       axis=(0, 1, 2)) / (denom * m.top_k)
        pmean = jnp.sum(probs * mw[:, :, None], axis=(0, 1)) / denom
    aux = m.n_experts * jnp.sum(frac * pmean)

    if m.n_shared:
        y = y + mlp_lib.mlp_apply(params["shared"], cfg, x)
    if flattened:
        y = y.reshape(-1, 1, d)               # (1, B, d) -> (B, 1, d)
    return y.astype(x.dtype), aux
