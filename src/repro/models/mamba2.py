"""Mamba-2 (SSD) block: chunked-parallel training scan + O(1) decode step.

State-space duality formulation (Dao & Gu 2024).  Per head h with scalar
decay a_t = exp(dt_t * A_h) (A_h < 0):

    S_t = a_t * S_{t-1} + dt_t * B_t x_t^T        S: (d_state, head_dim)
    y_t = C_t^T S_t + D_h * x_t

Chunked algorithm (chunk size Q, scan over chunks):
  within-chunk (quadratic, MXU-shaped):
      L[i,j]    = exp(cum[i] - cum[j]) for j <= i      (segment decay)
      y_intra_i = sum_{j<=i} (C_i . B_j) L[i,j] dt_j x_j
  cross-chunk (state passing):
      y_inter_i = exp(cum[i]) * C_i^T S_prev
      S_new     = exp(cum[Q-1]) S_prev
                  + sum_j exp(cum[Q-1] - cum[j]) dt_j B_j x_j^T

``cum`` is the inclusive cumulative sum of log-decays within the chunk.
All contractions are einsums over (chunk, chunk) x head_dim/d_state —
MXU-friendly; the sequential dependency is only the O(S/Q) chunk scan.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import (LMConfig, ParamDef, fanin_init, ones_init,
                                 zeros_init)


def _ssm(cfg: LMConfig):
    assert cfg.ssm is not None
    return cfg.ssm


def mamba2_defs(cfg: LMConfig) -> Dict[str, Any]:
    s = _ssm(cfg)
    d = cfg.d_model
    di = s.d_inner(d)
    nh = s.n_heads(d)
    ng = s.n_groups
    ds = s.d_state
    # in_proj packs [z (di), x (di), B (ng*ds), C (ng*ds), dt (nh)]
    d_in_proj = 2 * di + 2 * ng * ds + nh
    return {
        "in_proj": ParamDef((d, d_in_proj), ("embed", "mamba_inner"),
                            fanin_init(d)),
        "conv_w": ParamDef((s.d_conv, di + 2 * ng * ds),
                           (None, "mamba_conv"), fanin_init(s.d_conv)),
        "conv_b": ParamDef((di + 2 * ng * ds,), ("mamba_conv",), zeros_init()),
        "a_log": ParamDef((nh,), ("heads",),
                          lambda k, sh, dt: jnp.log(
                              jnp.linspace(1.0, 16.0, sh[0], dtype=dt))),
        "dt_bias": ParamDef((nh,), ("heads",), zeros_init()),
        "d_skip": ParamDef((nh,), ("heads",), ones_init()),
        "norm_scale": ParamDef((di,), ("mamba_inner",), ones_init()),
        "out_proj": ParamDef((di, d), ("mamba_inner", "embed_tp"),
                             fanin_init(di)),
    }


def _split_in_proj(cfg: LMConfig, zxbcdt: jax.Array):
    s = _ssm(cfg)
    di = s.d_inner(cfg.d_model)
    ng, ds, nh = s.n_groups, s.d_state, s.n_heads(cfg.d_model)
    z, x, bc, dt = jnp.split(
        zxbcdt, [di, 2 * di, 2 * di + 2 * ng * ds], axis=-1)
    b, c = jnp.split(bc, 2, axis=-1)
    return z, x, b, c, dt


def _causal_conv(xbc: jax.Array, w: jax.Array, bias: jax.Array,
                 state: Optional[jax.Array] = None
                 ) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv over time.  xbc (B, S, C); w (K, C).

    Returns (activated output, new conv state (B, K-1, C))."""
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros((xbc.shape[0], k - 1, xbc.shape[-1]), xbc.dtype)
    else:
        pad = state.astype(xbc.dtype)
    xp = jnp.concatenate([pad, xbc], axis=1)          # (B, S+K-1, C)
    out = sum(xp[:, i:i + xbc.shape[1]] * w[i] for i in range(k))
    new_state = xp[:, -(k - 1):] if k > 1 else jnp.zeros_like(pad)
    return jax.nn.silu(out + bias), new_state


def _gated_rmsnorm(x: jax.Array, z: jax.Array, scale: jax.Array,
                   eps: float = 1e-5) -> jax.Array:
    """Mamba-2 output norm: RMSNorm(x * silu(z)) * scale."""
    y = x * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    yf = y.astype(jnp.float32)
    ms = jnp.mean(jnp.square(yf), axis=-1, keepdims=True)
    return (yf * jax.lax.rsqrt(ms + eps) * scale.astype(jnp.float32)
            ).astype(x.dtype)


def _ssd_chunked(x: jax.Array, dt: jax.Array, a_log: jax.Array,
                 b: jax.Array, c: jax.Array, d_skip: jax.Array,
                 chunk: int, init_state: Optional[jax.Array] = None
                 ) -> Tuple[jax.Array, jax.Array]:
    """SSD scan.  x (B,S,H,P); dt (B,S,H) softplus'd; b,c (B,S,G,N).

    Returns (y (B,S,H,P), final state (B,H,P,N))."""
    bsz, s, nh, hd = x.shape
    ng, ds = b.shape[2], b.shape[3]
    rep = nh // ng
    q = min(chunk, s)
    while s % q:
        q //= 2
    nc = s // q
    a = -jnp.exp(a_log.astype(jnp.float32))               # (H,), negative
    loga = dt.astype(jnp.float32) * a                      # (B,S,H) log decay
    xf = (x.astype(jnp.float32)
          * dt.astype(jnp.float32)[..., None])             # fold dt into x

    # chunk views: (nc, B, Q, ...)
    def chunkify(t):
        return t.reshape(bsz, nc, q, *t.shape[2:]).transpose(
            1, 0, 2, *range(3, t.ndim + 1))

    x_c = chunkify(xf)                                     # (nc,B,Q,H,P)
    la_c = chunkify(loga)                                  # (nc,B,Q,H)
    b_c = chunkify(b.astype(jnp.float32))                  # (nc,B,Q,G,N)
    c_c = chunkify(c.astype(jnp.float32))                  # (nc,B,Q,G,N)

    if init_state is None:
        s0 = jnp.zeros((bsz, nh, hd, ds), jnp.float32)
    else:
        s0 = init_state.astype(jnp.float32)

    idx = jnp.arange(q)
    tri = idx[:, None] >= idx[None, :]                     # (Q,Q) j<=i

    def body(state, inp):
        xk, lak, bk, ck = inp
        cum = jnp.cumsum(lak, axis=1)                      # (B,Q,H) inclusive
        # intra-chunk: scores over (i,j)
        cb = jnp.einsum("bigd,bjgd->bgij", ck, bk)         # (B,G,Q,Q)
        cb = jnp.repeat(cb, rep, axis=1)                   # (B,H,Q,Q)
        dec = cum[:, :, None, :] - cum[:, None, :, :]      # (B,i,j,H)
        dec = jnp.where(tri[None, :, :, None], dec, -jnp.inf)
        l_mat = jnp.exp(dec).transpose(0, 3, 1, 2)         # (B,H,i,j)
        y_intra = jnp.einsum("bhij,bjhp->bihp", cb * l_mat, xk)
        # inter-chunk: carry state
        c_h = jnp.repeat(ck, rep, axis=2)                  # (B,Q,H,N)
        y_inter = jnp.einsum("bihn,bhpn->bihp",
                             c_h * jnp.exp(cum)[..., None], state)
        # state update
        total = cum[:, -1, :]                              # (B,H)
        rem = jnp.exp(total[:, None, :] - cum)             # (B,Q,H)
        b_h = jnp.repeat(bk, rep, axis=2)                  # (B,Q,H,N)
        ds_new = jnp.einsum("bjhn,bjhp->bhpn", b_h * rem[..., None], xk)
        state = state * jnp.exp(total)[:, :, None, None] + ds_new
        return state, y_intra + y_inter

    state, y_c = jax.lax.scan(body, s0, (x_c, la_c, b_c, c_c))
    y = y_c.transpose(1, 0, 2, 3, 4).reshape(bsz, s, nh, hd)
    y = y + x.astype(jnp.float32) * d_skip.astype(jnp.float32)[None, None, :,
                                                               None]
    return y.astype(x.dtype), state


def mamba2_apply(params: Dict[str, Any], cfg: LMConfig, x: jax.Array,
                 state: Optional[Dict[str, jax.Array]] = None
                 ) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    """Full block forward.  x (B, S, d_model).

    ``state`` is {"ssm": (B,H,P,N), "conv": (B,K-1,C)} for incremental use;
    None for training (zero init, state discarded)."""
    s = _ssm(cfg)
    cd = cfg.cdtype()
    di = s.d_inner(cfg.d_model)
    nh = s.n_heads(cfg.d_model)
    ng, ds = s.n_groups, s.d_state

    zxbcdt = x.astype(cd) @ params["in_proj"].astype(cd)
    z, xi, b, c, dt = _split_in_proj(cfg, zxbcdt)
    xbc = jnp.concatenate([xi, b, c], axis=-1)
    conv_state = None if state is None else state["conv"]
    xbc, new_conv = _causal_conv(xbc, params["conv_w"].astype(cd),
                                 params["conv_b"].astype(cd), conv_state)
    xi, b, c = jnp.split(xbc, [di, di + ng * ds], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))
    bsz, sl = x.shape[0], x.shape[1]
    xh = xi.reshape(bsz, sl, nh, di // nh)
    bg = b.reshape(bsz, sl, ng, ds)
    cg = c.reshape(bsz, sl, ng, ds)
    ssm_state = None if state is None else state["ssm"]
    y, new_ssm = _ssd_chunked(xh, dt, params["a_log"], bg, cg,
                              params["d_skip"], s.chunk_size, ssm_state)
    y = y.reshape(bsz, sl, di)
    y = _gated_rmsnorm(y, z, params["norm_scale"])
    out = y.astype(cd) @ params["out_proj"].astype(cd)
    new_state = None
    if state is not None:
        new_state = {"ssm": new_ssm.astype(state["ssm"].dtype),
                     "conv": new_conv.astype(state["conv"].dtype)}
    return out, new_state


def mamba2_state_defs(cfg: LMConfig, batch: int) -> Dict[str, Any]:
    """ShapeDtypeStructs for one layer's incremental state."""
    s = _ssm(cfg)
    di = s.d_inner(cfg.d_model)
    nh = s.n_heads(cfg.d_model)
    return {
        "ssm": jax.ShapeDtypeStruct((batch, nh, di // nh, s.d_state),
                                    jnp.float32),
        "conv": jax.ShapeDtypeStruct(
            (batch, s.d_conv - 1, di + 2 * s.n_groups * s.d_state),
            jnp.float32),
    }


def mamba2_init_state(cfg: LMConfig, batch: int) -> Dict[str, jax.Array]:
    return jax.tree.map(lambda sd: jnp.zeros(sd.shape, sd.dtype),
                        mamba2_state_defs(cfg, batch))


def mamba2_state_specs():
    return {"ssm": ("batch", "heads", None, None),
            "conv": ("batch", None, "mamba_conv")}
