from repro.models.common import LMConfig, MoEConfig, SSMConfig, XLSTMConfig

__all__ = ["LMConfig", "MoEConfig", "SSMConfig", "XLSTMConfig"]
