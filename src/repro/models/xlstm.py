"""xLSTM blocks: mLSTM (matrix memory, chunked-parallel) and sLSTM (scalar
memory, sequential scan) — Beck et al. 2024, arXiv:2405.04517.

mLSTM cell (per head, exponential gating, stabilizer m):

    m_t = max(logf_t + m_{t-1}, logi_t)
    C_t = exp(logf_t + m_{t-1} - m_t) C_{t-1} + exp(logi_t - m_t) v_t k_t^T
    n_t = exp(logf_t + m_{t-1} - m_t) n_{t-1} + exp(logi_t - m_t) k_t
    h_t = (C_t^T q_t) / max(|n_t . q_t|, exp(-m_t))

The output is invariant to the stabilizer, so the chunked-parallel form may
use any per-row max; we use the true row max over (intra-chunk weights,
carried-state weight), which is the tightest stabilizer.  Intra-chunk work
is (Q x Q) MXU matmuls; the sequential dependency is the O(S/Q) chunk scan
— same structure as Mamba-2 SSD (models/mamba2.py).

sLSTM keeps a scalar memory with a recurrent weight on h_{t-1}, so it is
inherently sequential (the xLSTM paper says as much); we scan over time.
xLSTM-1.3b uses a 7:1 mLSTM:sLSTM ratio (cfg.xlstm.slstm_every = 8).

TP note (DESIGN.md §5/parallel): n_heads = 4 < model axis 16, so heads
cannot carry the TP split.  Instead the value dimension Dv is sharded
("head_dim_v" -> model): C = v k^T is row-sharded by v, h = C^T q stays
local in the sharded rows, and only the down-projection reduces over Dv.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import (LMConfig, ParamDef, fanin_init, ones_init,
                                 zeros_init)


def _xl(cfg: LMConfig):
    assert cfg.xlstm is not None
    return cfg.xlstm


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


def mlstm_defs(cfg: LMConfig) -> Dict[str, Any]:
    x = _xl(cfg)
    d = cfg.d_model
    di = int(x.mlstm_proj_factor * d)
    nh = cfg.n_heads
    hd = di // nh
    return {
        "up_proj": ParamDef((d, 2 * di), ("embed", "mlstm_up"), fanin_init(d)),
        "conv_w": ParamDef((x.d_conv, di), (None, "mlstm_inner"),
                           fanin_init(x.d_conv)),
        "conv_b": ParamDef((di,), ("mlstm_inner",), zeros_init()),
        "wq": ParamDef((di, nh, hd), ("mlstm_inner", "heads", "head_dim"),
                       fanin_init(di)),
        "wk": ParamDef((di, nh, hd), ("mlstm_inner", "heads", "head_dim"),
                       fanin_init(di)),
        "wv": ParamDef((di, nh, hd), ("mlstm_inner", "heads", "head_dim_v"),
                       fanin_init(di)),
        "w_gates": ParamDef((di, 2 * nh), ("mlstm_inner", None),
                            fanin_init(di)),
        "b_gates": ParamDef((2 * nh,), (None,),
                            lambda k, s, dt: jnp.concatenate([
                                jnp.full((s[0] // 2,), -3.0, dt),   # igate
                                jnp.linspace(3.0, 6.0, s[0] // 2,
                                             dtype=dt)])),          # fgate
        "norm_scale": ParamDef((di,), ("mlstm_inner",), ones_init()),
        "skip_scale": ParamDef((di,), ("mlstm_inner",), ones_init()),
        "down_proj": ParamDef((di, d), ("mlstm_inner", "embed_tp"),
                              fanin_init(di)),
    }


def _mlstm_chunked(q, k, v, logi, logf, chunk: int,
                   carry: Optional[Tuple[jax.Array, ...]] = None):
    """q,k (B,S,H,Dk); v (B,S,H,Dv); logi/logf (B,S,H).

    Returns (h (B,S,H,Dv), (C, n, m) final carry)."""
    bsz, s, nh, dk = q.shape
    dv = v.shape[-1]
    qc = min(chunk, s)
    while s % qc:
        qc //= 2
    nc = s // qc

    qf = q.astype(jnp.float32) / math.sqrt(dk)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)

    def chunkify(t):
        return t.reshape(bsz, nc, qc, *t.shape[2:]).transpose(
            1, 0, 2, *range(3, t.ndim + 1))

    q_c, k_c, v_c = chunkify(qf), chunkify(kf), chunkify(vf)
    li_c, lf_c = chunkify(logi.astype(jnp.float32)), chunkify(
        logf.astype(jnp.float32))

    if carry is None:
        c0 = jnp.zeros((bsz, nh, dk, dv), jnp.float32)
        n0 = jnp.zeros((bsz, nh, dk), jnp.float32)
        m0 = jnp.full((bsz, nh), -jnp.inf, jnp.float32)
    else:
        c0, n0, m0 = [t.astype(jnp.float32) for t in carry]

    idx = jnp.arange(qc)
    tri = idx[:, None] >= idx[None, :]                      # j <= i

    def body(st, inp):
        c_p, n_p, m_p = st
        qk_, kk_, vk_, li, lf = inp
        cum = jnp.cumsum(lf, axis=1)                        # (B,Q,H) inclusive
        w = (cum[:, :, None, :] - cum[:, None, :, :]
             + li[:, None, :, :])                           # (B,i,j,H)
        w = jnp.where(tri[None, :, :, None], w, -jnp.inf)
        m_intra = jnp.max(w, axis=2)                        # (B,i,H)
        m_inter = cum + m_p[:, None, :]                     # (B,i,H)
        m_i = jnp.maximum(m_intra, m_inter)
        m_i = jnp.maximum(m_i, -1e30)                       # guard -inf rows
        d_mat = jnp.exp(w - m_i[:, :, None, :])             # (B,i,j,H)
        scores = jnp.einsum("bihd,bjhd->bijh", qk_, kk_) * d_mat
        numer = jnp.einsum("bijh,bjhv->bihv", scores, vk_)
        denom = jnp.sum(scores, axis=2)                     # (B,i,H)
        inter_w = jnp.exp(m_inter - m_i)                    # (B,i,H)
        numer = numer + inter_w[..., None] * jnp.einsum(
            "bihd,bhdv->bihv", qk_, c_p)
        denom = denom + inter_w * jnp.einsum("bihd,bhd->bih", qk_, n_p)
        h = numer / jnp.maximum(jnp.abs(denom),
                                jnp.exp(-m_i))[..., None]
        # carry update
        total = cum[:, -1, :]                               # (B,H)
        up_w = total[:, None, :] - cum + li                 # (B,j,H)
        m_new = jnp.maximum(total + m_p, jnp.max(up_w, axis=1))
        m_new = jnp.maximum(m_new, -1e30)
        scale_old = jnp.exp(total + m_p - m_new)            # (B,H)
        w_j = jnp.exp(up_w - m_new[:, None, :])             # (B,j,H)
        c_n = (c_p * scale_old[:, :, None, None]
               + jnp.einsum("bjhd,bjhv->bhdv", kk_ * w_j[..., None], vk_))
        n_n = n_p * scale_old[:, :, None] + jnp.sum(
            kk_ * w_j[..., None], axis=1)
        return (c_n, n_n, m_new), h

    (c_f, n_f, m_f), h_c = jax.lax.scan(body, (c0, n0, m0),
                                        (q_c, k_c, v_c, li_c, lf_c))
    h = h_c.transpose(1, 0, 2, 3, 4).reshape(bsz, s, nh, dv)
    return h.astype(q.dtype), (c_f, n_f, m_f)


def _mlstm_step(q, k, v, logi, logf, carry):
    """Single-token decode.  q,k (B,H,Dk); v (B,H,Dv); logi/logf (B,H)."""
    c_p, n_p, m_p = carry
    dk = q.shape[-1]
    qf = q.astype(jnp.float32) / math.sqrt(dk)
    m_new = jnp.maximum(logf + m_p, logi)
    scale_old = jnp.exp(logf + m_p - m_new)
    w_new = jnp.exp(logi - m_new)
    c_n = (c_p * scale_old[..., None, None]
           + jnp.einsum("bhd,bhv->bhdv", k * w_new[..., None] * 1.0, v))
    n_n = n_p * scale_old[..., None] + k * w_new[..., None]
    numer = jnp.einsum("bhd,bhdv->bhv", qf, c_n)
    denom = jnp.einsum("bhd,bhd->bh", qf, n_n)
    h = numer / jnp.maximum(jnp.abs(denom), jnp.exp(-m_new))[..., None]
    return h.astype(q.dtype), (c_n, n_n, m_new)


def _group_norm_heads(h: jax.Array, scale: jax.Array, nh: int,
                      eps: float = 1e-5) -> jax.Array:
    """Per-head RMS norm of (B,S,H,Dv) folded to (B,S,di) with scale."""
    hf = h.astype(jnp.float32)
    ms = jnp.mean(jnp.square(hf), axis=-1, keepdims=True)
    hf = hf * jax.lax.rsqrt(ms + eps)
    b, s = h.shape[0], h.shape[1]
    return (hf.reshape(b, s, -1) * scale.astype(jnp.float32)).astype(h.dtype)


def mlstm_apply(params: Dict[str, Any], cfg: LMConfig, x: jax.Array,
                state: Optional[Dict[str, jax.Array]] = None
                ) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    """mLSTM block body (post-norm residual handled by caller).

    x (B, S, d_model) -> (B, S, d_model)."""
    xl = _xl(cfg)
    cd = cfg.cdtype()
    d = cfg.d_model
    di = int(xl.mlstm_proj_factor * d)
    nh = cfg.n_heads
    bsz, s = x.shape[0], x.shape[1]

    up = x.astype(cd) @ params["up_proj"].astype(cd)       # (B,S,2di)
    inner, z = jnp.split(up, 2, axis=-1)

    from repro.models.mamba2 import _causal_conv
    conv_state = None if state is None else state["conv"]
    conv_out, new_conv = _causal_conv(inner, params["conv_w"].astype(cd),
                                      params["conv_b"].astype(cd), conv_state)

    q = jnp.einsum("bsd,dhk->bshk", conv_out, params["wq"].astype(cd))
    k = jnp.einsum("bsd,dhk->bshk", conv_out, params["wk"].astype(cd))
    v = jnp.einsum("bsd,dhk->bshk", inner, params["wv"].astype(cd))
    gates = inner.astype(jnp.float32) @ params["w_gates"].astype(jnp.float32)
    gates = gates + params["b_gates"].astype(jnp.float32)
    logi, f_raw = jnp.split(gates, 2, axis=-1)             # (B,S,H) each
    logf = jax.nn.log_sigmoid(f_raw)

    if state is None:
        h, _ = _mlstm_chunked(q, k, v, logi, logf, xl.chunk_size)
        new_state = None
    else:
        carry = (state["c"], state["n"], state["m"])
        if s == 1:
            h, carry = _mlstm_step(q[:, 0], k[:, 0], v[:, 0],
                                   logi[:, 0], logf[:, 0], carry)
            h = h[:, None]
        else:
            h, carry = _mlstm_chunked(q, k, v, logi, logf, xl.chunk_size,
                                      carry)
        new_state = {"c": carry[0], "n": carry[1], "m": carry[2],
                     "conv": new_conv}
    hn = _group_norm_heads(h, params["norm_scale"], nh)
    hn = hn + conv_out * params["skip_scale"].astype(cd)   # learnable skip
    out = (hn * jax.nn.silu(z.astype(jnp.float32)).astype(cd)
           ) @ params["down_proj"].astype(cd)
    return out, new_state


def mlstm_state_defs(cfg: LMConfig, batch: int) -> Dict[str, Any]:
    xl = _xl(cfg)
    di = int(xl.mlstm_proj_factor * cfg.d_model)
    nh = cfg.n_heads
    hd = di // nh
    return {
        "c": jax.ShapeDtypeStruct((batch, nh, hd, hd), jnp.float32),
        "n": jax.ShapeDtypeStruct((batch, nh, hd), jnp.float32),
        "m": jax.ShapeDtypeStruct((batch, nh), jnp.float32),
        "conv": jax.ShapeDtypeStruct((batch, xl.d_conv - 1, di), jnp.float32),
    }


def mlstm_state_specs():
    return {"c": ("batch", "heads", None, "head_dim_v"),
            "n": ("batch", "heads", None),
            "m": ("batch", "heads"),
            "conv": ("batch", None, "mlstm_inner")}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


def slstm_defs(cfg: LMConfig) -> Dict[str, Any]:
    xl = _xl(cfg)
    d = cfg.d_model
    nh = cfg.n_heads
    hd = d // nh
    dff = int(xl.slstm_ff_factor * d)
    # round to a multiple of 64 for TPU lane alignment
    dff = ((dff + 63) // 64) * 64
    return {
        "w_gates": ParamDef((d, 4 * nh * hd), ("embed", "slstm_gates"),
                            fanin_init(d)),
        "r_gates": ParamDef((nh, hd, 4 * hd), ("heads", None, None),
                            fanin_init(hd)),
        "b_gates": ParamDef((4 * nh * hd,), ("slstm_gates",), zeros_init()),
        "norm_scale": ParamDef((d,), (None,), ones_init()),
        "ff_up": ParamDef((d, 2 * dff), ("embed", "mlp"), fanin_init(d)),
        "ff_down": ParamDef((dff, d), ("mlp", "embed_tp"), fanin_init(dff)),
    }


def _slstm_cell(gates: jax.Array, st: Tuple[jax.Array, ...]):
    """gates (B,H,4*hd) laid out [i, f, z, o]; state (c, n, m, h)."""
    c_p, n_p, m_p, _ = st
    i_r, f_r, z_r, o_r = jnp.split(gates, 4, axis=-1)      # (B,H,hd)
    logi = i_r
    logf = jax.nn.log_sigmoid(f_r)
    m_new = jnp.maximum(logf + m_p, logi)
    c_n = (jnp.exp(logf + m_p - m_new) * c_p
           + jnp.exp(logi - m_new) * jnp.tanh(z_r))
    n_n = jnp.exp(logf + m_p - m_new) * n_p + jnp.exp(logi - m_new)
    h_n = jax.nn.sigmoid(o_r) * c_n / jnp.maximum(n_n, 1e-6)
    return (c_n, n_n, m_new, h_n)


# --- custom-VJP time scan (§Perf iteration A3) -----------------------------
#
# Plain autodiff of the recurrence accumulates the recurrent-weight gradient
# dR in the backward-scan carry: on a sharded batch that materializes an
# all-reduce of a (H, hd, 4hd) tensor at EVERY timestep (24.5k all-reduces,
# 0.41 TB/dev for xlstm-1.3b train_4k).  This VJP instead stacks the
# per-step gate cotangents and computes dR as ONE post-scan einsum over
# (B, S) — a single matmul, a single gradient reduction.


def _slstm_scan_inner(wx: jax.Array, r: jax.Array, st0):
    """wx (S,B,H,4hd) time-major; r (H,hd,4hd).  Returns (h_seq, st_f)."""
    def step(st, wx_t):
        rec = jnp.einsum("bhd,hde->bhe", st[3], r)
        st_n = _slstm_cell(wx_t + rec, st)
        return st_n, st_n[3]
    st_f, h_seq = jax.lax.scan(step, st0, wx)
    return h_seq, st_f


@jax.custom_vjp
def _slstm_scan(wx, r, st0):
    return _slstm_scan_inner(wx, r, st0)


def _slstm_scan_fwd(wx, r, st0):
    out = _slstm_scan_inner(wx, r, st0)
    return out, (wx, r, st0)


def _slstm_scan_bwd(res, ct):
    wx, r, st0 = res
    ct_h, ct_stf = ct
    if ct_stf is None:
        ct_stf = tuple(jnp.zeros_like(s) for s in st0)

    # replay forward, saving each step's INPUT state
    def step_store(st, wx_t):
        rec = jnp.einsum("bhd,hde->bhe", st[3], r)
        st_n = _slstm_cell(wx_t + rec, st)
        return st_n, st
    _, st_prevs = jax.lax.scan(step_store, st0, wx)

    def back(d_st, inp):
        wx_t, st_prev, ct_h_t = inp
        gates = wx_t + jnp.einsum("bhd,hde->bhe", st_prev[3], r)
        _, vjp = jax.vjp(lambda sp, g: _slstm_cell(g, sp), st_prev, gates)
        d_stn = (d_st[0], d_st[1], d_st[2], d_st[3] + ct_h_t)
        d_prev, d_gates = vjp(d_stn)
        d_prev = (d_prev[0], d_prev[1], d_prev[2],
                  d_prev[3] + jnp.einsum("bhe,hde->bhd", d_gates, r))
        return d_prev, d_gates

    d_st0, d_gates_seq = jax.lax.scan(
        back, tuple(ct_stf), (wx, st_prevs, ct_h), reverse=True)
    d_wx = d_gates_seq
    # the whole point: dR as ONE einsum over (S, B) — single reduction
    d_r = jnp.einsum("sbhd,sbhe->hde", st_prevs[3], d_gates_seq)
    return d_wx, d_r, d_st0


_slstm_scan.defvjp(_slstm_scan_fwd, _slstm_scan_bwd)


def slstm_apply(params: Dict[str, Any], cfg: LMConfig, x: jax.Array,
                state: Optional[Dict[str, jax.Array]] = None
                ) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    """sLSTM block body: sequential scan over time + gated FFN."""
    cd = cfg.cdtype()
    d = cfg.d_model
    nh = cfg.n_heads
    hd = d // nh
    bsz, s = x.shape[0], x.shape[1]

    wx = (x.astype(jnp.float32) @ params["w_gates"].astype(jnp.float32)
          + params["b_gates"].astype(jnp.float32))         # (B,S,4*nh*hd)
    wx = wx.reshape(bsz, s, nh, 4 * hd)

    if state is None:
        zeros = jnp.zeros((bsz, nh, hd), jnp.float32)
        st0 = (zeros, zeros, jnp.full_like(zeros, -1e30), zeros)
    else:
        st0 = (state["c"], state["n"], state["m"], state["h"])

    r = params["r_gates"].astype(jnp.float32)              # (H, hd, 4hd)

    h_seq, st_f = _slstm_scan(wx.transpose(1, 0, 2, 3), r, st0)
    h = h_seq.transpose(1, 0, 2, 3).reshape(bsz, s, d)     # (B,S,d)

    # per-block group norm + gated FFN (xLSTM post-up-proj structure)
    hf = h.astype(jnp.float32)
    ms = jnp.mean(jnp.square(hf), axis=-1, keepdims=True)
    h = (hf * jax.lax.rsqrt(ms + 1e-5)
         * params["norm_scale"].astype(jnp.float32)).astype(cd)
    up = h @ params["ff_up"].astype(cd)
    a, b = jnp.split(up, 2, axis=-1)
    out = (jax.nn.gelu(a) * b) @ params["ff_down"].astype(cd)

    new_state = None
    if state is not None:
        new_state = {"c": st_f[0], "n": st_f[1], "m": st_f[2], "h": st_f[3]}
    return out, new_state


def slstm_state_defs(cfg: LMConfig, batch: int) -> Dict[str, Any]:
    nh = cfg.n_heads
    hd = cfg.d_model // nh
    sd = jax.ShapeDtypeStruct((batch, nh, hd), jnp.float32)
    return {"c": sd, "n": sd, "m": sd, "h": sd}


def slstm_state_specs():
    ax = ("batch", "heads", None)
    return {"c": ax, "n": ax, "m": ax, "h": ax}
