"""Dense FFN blocks: SwiGLU (LLaMA-style) and plain GELU MLP (HuBERT-style)."""

from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models.common import LMConfig, ParamDef, fanin_init, zeros_init, activation


def mlp_defs(cfg: LMConfig, d_ff: int = 0) -> Dict[str, Any]:
    d, f = cfg.d_model, (d_ff or cfg.d_ff)
    defs: Dict[str, Any] = {
        "wi": ParamDef((d, f), ("embed", "mlp"), fanin_init(d)),
        "wo": ParamDef((f, d), ("mlp", "embed_tp"), fanin_init(f)),
    }
    if cfg.glu:
        defs["wg"] = ParamDef((d, f), ("embed", "mlp"), fanin_init(d))
    if cfg.norm == "layernorm":  # encoder-style MLPs carry biases
        defs["bi"] = ParamDef((f,), ("mlp",), zeros_init())
        defs["bo"] = ParamDef((d,), (None,), zeros_init())
    return defs


def mlp_apply(params: Dict[str, jax.Array], cfg: LMConfig, x: jax.Array) -> jax.Array:
    cd = cfg.cdtype()
    act = activation(cfg.act)
    h = x.astype(cd) @ params["wi"].astype(cd)
    if "bi" in params:
        h = h + params["bi"].astype(cd)
    if cfg.glu:
        g = x.astype(cd) @ params["wg"].astype(cd)
        h = act(g) * h
    else:
        h = act(h)
    y = h @ params["wo"].astype(cd)
    if "bo" in params:
        y = y + params["bo"].astype(cd)
    return y
