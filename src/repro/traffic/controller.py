"""Autoscaling controller for the elastic decode pool.

:class:`AutoscaleController` closes the loop between the
:class:`repro.serving.DisaggregatedEngine` front-end's queue-depth
telemetry (the PR-5 ``stats().depth_summary()`` signal) and its elastic
pool API (``add_decode / retire_decode / reap_retired``):

* **grow** — the depth histograms are cumulative and monotone, so the
  controller diffs their ``(count, total)`` pairs between steps to get
  the *windowed* mean backlog since the last look.  The watched signal
  is the sum of the ``"handoff"`` and ``"decode"`` phases by default:
  the front-end drains its handoff queue eagerly into the decode
  engines' admission queues, so sustained pressure lives in the
  combined backlog awaiting decode service, wherever it is parked.  A
  window mean at or above ``grow_depth`` marks the step hot;
  ``hot_steps`` consecutive hot steps (sustained pressure, not a
  one-tick blip) grow the pool by one engine from ``engine_factory``,
  up to ``max_engines``.
* **shrink** — a window whose mean backlog is at or below ``idle_depth``
  (engines keeping up: nothing queues, even if requests are resident
  and being served) marks the step idle; ``idle_steps`` consecutive
  idle steps drain the newest live engine (``retire_decode`` — resident
  requests finish normally, no new handoffs route to it), down to
  ``min_engines``.  Draining engines are reaped (removed) once empty on
  a later step.  Windows between the two thresholds reset both
  counters: only *sustained* evidence moves the pool.

Every action is recorded as a :class:`ScaleEvent`, and the controller
integrates live-engine-count over time so a replay can report the mean
pool size — the number the autoscale acceptance test compares against
a static max-size pool.  The controller is engine-agnostic beyond the
pool surface and deterministic: no internal clock, no randomness; the
caller supplies ``now`` (virtual or wall).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, List, Optional, Tuple

__all__ = ["ScaleEvent", "AutoscaleController"]


@dataclasses.dataclass(frozen=True)
class ScaleEvent:
    """One pool action: ``grow`` (engine joined), ``drain`` (engine
    began retiring) or ``reap`` (drained engine removed), with the live
    count *after* the action."""

    t: float
    action: str                       # "grow" | "drain" | "reap"
    n_live: int


class AutoscaleController:
    """Depth-signal autoscaler over a ``DisaggregatedEngine`` pool.

    ``engine_factory`` builds one ready decode engine per grow (the
    controller warms it up before joining).  Thresholds are in queue
    depth (requests parked in the handoff queue); steps are whatever
    cadence the caller drives — the replay loop steps once per engine
    tick.
    """

    def __init__(self, engine_factory: Callable[[], Any],
                 min_engines: int = 1, max_engines: int = 4,
                 grow_depth: float = 2.0, idle_depth: float = 0.0,
                 hot_steps: int = 3, idle_steps: int = 50,
                 warmup: bool = False,
                 signal: Tuple[str, ...] = ("handoff", "decode")):
        if min_engines < 1 or max_engines < min_engines:
            raise ValueError("need 1 <= min_engines <= max_engines")
        if hot_steps < 1 or idle_steps < 1:
            raise ValueError("hot_steps and idle_steps must be >= 1")
        if not idle_depth < grow_depth:
            raise ValueError("need idle_depth < grow_depth")
        self.engine_factory = engine_factory
        self.min_engines = int(min_engines)
        self.max_engines = int(max_engines)
        self.grow_depth = float(grow_depth)
        self.idle_depth = float(idle_depth)
        self.hot_steps = int(hot_steps)
        self.idle_steps = int(idle_steps)
        self.warmup = bool(warmup)
        self.signal = tuple(signal)
        self.events: List[ScaleEvent] = []
        self._hot = 0
        self._idle = 0
        self._last: Tuple[int, int] = (0, 0)   # (count, total) watermark
        self._live_integral = 0.0     # integral of n_live over time
        self._span_s = 0.0            # total stepped interval
        self._t_prev: Optional[float] = None

    # -- telemetry ---------------------------------------------------------

    def _window_depth(self, pool: Any) -> Optional[float]:
        """Mean watched backlog since the previous step, from the
        monotone cumulative depth histograms (``None`` when no new
        ticks recorded depth — nothing to conclude from an empty
        window).  The watched phases are recorded at the same ticks, so
        one phase's count is the shared tick counter."""
        depth = pool.stats().depth
        hists = [depth[k] for k in self.signal if k in depth]
        if not hists:
            return None
        count = int(hists[0].count)
        total = sum(int(h.total) for h in hists)
        dc = count - self._last[0]
        dt = total - self._last[1]
        self._last = (count, total)
        if dc <= 0:
            return None
        return dt / dc

    def mean_live(self) -> Optional[float]:
        """Time-averaged live-engine count over the stepped interval."""
        if self._span_s <= 0:
            return None
        return self._live_integral / self._span_s

    # -- control loop ------------------------------------------------------

    def step(self, pool: Any, now: float) -> Optional[ScaleEvent]:
        """One control decision; returns the event if the pool changed
        membership this step (reaps of previously-drained engines do
        not preempt a grow/drain decision — both can be recorded)."""
        # time-integrate the live count (for mean pool size reporting)
        n_live = pool.n_live_decodes
        if self._t_prev is not None:
            dt = max(now - self._t_prev, 0.0)
            self._live_integral += n_live * dt
            self._span_s += dt
        self._t_prev = now

        for _ in pool.reap_retired():
            self.events.append(ScaleEvent(t=now, action="reap",
                                          n_live=pool.n_live_decodes))

        depth = self._window_depth(pool)
        if depth is not None:
            if depth >= self.grow_depth:
                self._hot += 1
                self._idle = 0
            elif depth <= self.idle_depth:
                self._idle += 1
                self._hot = 0
            else:                     # between thresholds: no evidence
                self._hot = 0
                self._idle = 0
        elif pool.n_pending == 0:     # no ticks recorded, truly idle
            self._idle += 1
            self._hot = 0

        event: Optional[ScaleEvent] = None
        if self._hot >= self.hot_steps \
                and pool.n_live_decodes < self.max_engines:
            eng = self.engine_factory()
            if self.warmup:
                eng.warmup()
            pool.add_decode(eng)
            self._hot = 0
            event = ScaleEvent(t=now, action="grow",
                               n_live=pool.n_live_decodes)
        elif self._idle >= self.idle_steps \
                and pool.n_live_decodes > self.min_engines:
            if pool.retire_decode() is not None:
                event = ScaleEvent(t=now, action="drain",
                                   n_live=pool.n_live_decodes)
            self._idle = 0
        if event is not None:
            self.events.append(event)
        return event
