"""``repro.traffic`` — deterministic traffic generation, replay and
closed-loop control for the serving engines.

Three layers, each usable alone:

* **Traces** (:mod:`repro.traffic.traces`) — :func:`poisson_trace` and
  :func:`bursty_trace` (Markov-modulated Poisson) generate
  :class:`Trace` arrival schedules over a mix of
  :class:`RequestClass`\\ es (short/long prompts, LM vs image frames,
  per-class priority and SLO), fully deterministic from one explicit
  seed.
* **Replay** (:mod:`repro.traffic.replay`) — :func:`replay` drives any
  engine with the standard ``submit()/poll()/tick()`` surface through a
  trace on a :class:`VirtualClock`, producing a :class:`ReplayReport`
  (counts, per-class latency, the exact schedule).
* **Control** (:mod:`repro.traffic.controller` /
  :mod:`repro.traffic.admission`) — :class:`AutoscaleController` grows
  and drains a :class:`repro.serving.DisaggregatedEngine` decode pool on
  the handoff queue-depth signal; :class:`SLOAdmission` sheds arrivals
  whose class SLO is already unattainable.

See ``docs/traffic.md`` for the subsystem design notes.
"""

from repro.traffic.admission import SLOAdmission  # noqa: F401
from repro.traffic.controller import (AutoscaleController,  # noqa: F401
                                      ScaleEvent)
from repro.traffic.replay import (ReplayReport, VirtualClock,  # noqa: F401
                                  default_factory, replay)
from repro.traffic.traces import (RequestClass, Trace,  # noqa: F401
                                  TraceEvent, build_image_request,
                                  build_lm_request, bursty_trace,
                                  default_classes, poisson_trace)
