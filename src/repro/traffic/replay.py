"""Open-loop trace replay against any engine, on a virtual clock.

:func:`replay` drives an engine (anything with the standard
``submit() / poll() / tick() / stats()`` surface — :class:`ServeEngine`,
:class:`CapsuleEngine`, :class:`DisaggregatedEngine`, or a test toy)
through a :class:`repro.traffic.Trace`: events whose arrival time has
passed are submitted, the engine ticks, and the clock advances — open
loop, so a slow engine builds real backlog instead of the trace
politely waiting (that backlog is exactly what admission control and
autoscaling react to).

Time is a :class:`VirtualClock` by default: the replay owns ``now`` and
advances it by ``tick_dt`` per engine tick, jumping over silent gaps
when the engine is idle.  Engines constructed with the *same* clock
object measure request latency in virtual time, which makes latency
histograms deterministic across runs — the property the determinism
tests pin.  Passing ``clock=None`` uses wall-clock (the launcher's
live mode).

The loop also hosts the two closed-loop actors: an
:class:`repro.traffic.AutoscaleController` (stepped once per tick, may
grow or drain the pool) and an :class:`repro.traffic.SLOAdmission`
gate (consulted per arrival, may reject).  Everything that happened is
returned as a :class:`ReplayReport` — counts, per-class latency,
scale events, and the exact submission schedule.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.traffic.traces import (Trace, TraceEvent, build_image_request,
                                  build_lm_request)

__all__ = ["VirtualClock", "ReplayReport", "replay", "default_factory"]


class _WallClock:
    """Live-mode clock: real time advances itself (``advance`` is a
    no-op — engine ticks take however long they take) and idle gaps are
    slept through.  ``now`` is relative to construction so it lines up
    with trace arrival times."""

    def __init__(self):
        self._t0 = time.perf_counter()

    def now(self) -> float:
        return time.perf_counter() - self._t0

    def advance(self, dt: float) -> float:
        return self.now()

    def advance_to(self, t: float) -> float:
        time.sleep(max(float(t) - self.now(), 0.0))
        return self.now()


class VirtualClock:
    """A manually-advanced clock with the ``time.perf_counter`` calling
    convention (zero-arg callable returning seconds).  Inject one
    object into both the replay loop and the engines under test and
    every latency/transfer measurement becomes deterministic virtual
    time.  Monotone: ``advance`` rejects negative steps."""

    def __init__(self, start: float = 0.0):
        self._now = float(start)

    def __call__(self) -> float:
        return self._now

    def now(self) -> float:
        return self._now

    def advance(self, dt: float) -> float:
        if dt < 0:
            raise ValueError("clock cannot run backwards")
        self._now += float(dt)
        return self._now

    def advance_to(self, t: float) -> float:
        self._now = max(self._now, float(t))
        return self._now


@dataclasses.dataclass
class ReplayReport:
    """What one replay did, in plain data (JSON-friendly).

    ``submitted + rejected == len(trace)``; ``dropped`` is the
    never-dropped invariant check (``submitted - completed`` after the
    drain — must be 0 for a healthy engine).  ``per_class`` maps class
    name to ``(count, p50_ms, p95_ms)`` end-to-end latency;
    ``schedule`` records ``(t, cls, rid)`` per submission in order, the
    determinism witness.  ``scale_events`` / ``mean_live_engines`` come
    from the controller when one ran (else empty / None).
    """

    submitted: int = 0
    completed: int = 0
    rejected: int = 0
    dropped: int = 0
    horizon: float = 0.0
    drain_s: float = 0.0              # virtual time spent draining
    per_class: Dict[str, Tuple[int, float, float]] = dataclasses.field(
        default_factory=dict)
    schedule: List[Tuple[float, str, int]] = dataclasses.field(
        default_factory=list)
    scale_events: List[Any] = dataclasses.field(default_factory=list)
    mean_live_engines: Optional[float] = None
    stats: Any = None                 # final EngineStats snapshot


def default_factory(trace: Trace, vocab: int = 256,
                    image_shape: Tuple[int, int, int] = (28, 28, 1)
                    ) -> Callable[[TraceEvent], Any]:
    """Event -> request factory dispatching on each class's ``kind``."""
    def make(ev: TraceEvent) -> Any:
        cls = trace.classes[ev.cls]
        if cls.kind == "image":
            return build_image_request(ev, cls, shape=image_shape)
        return build_lm_request(ev, cls, vocab=vocab)
    return make


def replay(engine: Any, trace: Trace,
           factory: Optional[Callable[[TraceEvent], Any]] = None,
           clock: Optional[VirtualClock] = None,
           tick_dt: float = 1e-3,
           controller: Any = None, admission: Any = None,
           max_ticks: int = 2_000_000) -> ReplayReport:
    """Replay ``trace`` against ``engine`` and drain to idle.

    ``clock`` should be the same :class:`VirtualClock` the engine was
    constructed with; ``clock=None`` runs live on wall time (idle gaps
    are slept through, ticks take as long as they take).  ``tick_dt``
    is the virtual duration charged per engine tick; when the engine
    goes idle with arrivals still ahead the clock jumps straight to the
    next arrival, so sparse traces replay in O(events), not
    O(horizon/tick_dt).

    Per arrival: ``admission.admit(engine, event, cls, now)`` (when
    given) may veto — vetoed events count as ``rejected`` and are never
    submitted (backpressure is explicit, not a silent drop).  Per tick:
    ``controller.step(engine, now)`` (when given) may scale the pool.
    ``max_ticks`` bounds runaway loops (raises rather than hangs).
    """
    clk = clock if clock is not None else _WallClock()
    make = factory if factory is not None else default_factory(trace)
    events = sorted(trace.events, key=lambda e: e.t)
    rep = ReplayReport(horizon=trace.horizon)
    i, n = 0, len(events)
    ticks = 0
    while True:
        now = clk.now()
        while i < n and events[i].t <= now:
            ev = events[i]
            i += 1
            cls = trace.classes[ev.cls]
            if admission is not None and not admission.admit(
                    engine, ev, cls, now):
                rep.rejected += 1
                continue
            rid = engine.submit(make(ev))
            rep.submitted += 1
            rep.schedule.append((ev.t, ev.cls, rid))
        if controller is not None:
            controller.step(engine, now)
        busy = engine.tick()
        ticks += 1
        if ticks > max_ticks:
            raise RuntimeError(f"replay exceeded {max_ticks} ticks "
                               f"({engine.n_pending} still pending)")
        if busy or engine.n_pending:
            clk.advance(tick_dt)
        elif i < n:
            clk.advance_to(events[i].t)   # idle: jump the silent gap
        else:
            break                         # drained and no arrivals left
    rep.drain_s = max(clk.now() - trace.horizon, 0.0)
    # let a draining controller reap emptied engines before reporting
    if controller is not None:
        controller.step(engine, clk.now())
        rep.scale_events = list(getattr(controller, "events", []))
        rep.mean_live_engines = getattr(controller, "mean_live", None)
        if callable(rep.mean_live_engines):
            rep.mean_live_engines = rep.mean_live_engines()
    engine.poll()                     # drain the completion queue
    st = engine.stats()
    rep.completed = st.completed
    rep.dropped = rep.submitted - rep.completed
    rep.per_class = st.latency_summary()
    rep.stats = st
    return rep
