"""SLO-aware admission control (explicit backpressure, never silent).

:class:`SLOAdmission` is the arrival-side gate the replay loop consults
before submitting each request: it projects what latency a new request
of the event's class would likely see given the engine's *observed*
per-class p95 and its current backlog, and rejects the request when the
projection clearly busts the class SLO.  Rejection is a first-class
outcome (the replay counts it as ``rejected`` and reports it) — the
alternative, admitting work that cannot meet its deadline, both wastes
capacity and drags down requests that could have met theirs.

The projection is deliberately simple and conservative::

    projected_p95 = observed_p95 * (1 + backlog / capacity)

i.e. the observed tail stretched by how many engine-loads of work are
already queued ahead.  Until a class has ``min_observations``
completions the gate admits unconditionally (no SLO evidence yet), and
classes without an SLO (``slo_p95_ms=None``) are always admitted —
best-effort traffic is shed by priority scheduling, not at the door.
An optional hard ``max_backlog`` rejects any SLO-bearing class beyond
that queue depth even before latency evidence accumulates.

Paged engines (``repro.serving.pages``) add a memory signal: when the
engine exposes ``free_pages`` / ``total_pages`` (non-None only for a
paged cache), the effective capacity in the projection is scaled by the
pool's free-page headroom — a nearly-full pool means admitted requests
will wait on page churn (prefix-cache eviction, preemption spills)
beyond what queue depth shows, and a pool with *no* allocatable page
sheds SLO-bearing arrivals outright.
"""

from __future__ import annotations

from typing import Any, Optional

__all__ = ["SLOAdmission"]


class SLOAdmission:
    """Reject arrivals whose class SLO is already unattainable."""

    def __init__(self, max_backlog: Optional[int] = None,
                 min_observations: int = 8, slack: float = 1.0):
        """``slack`` scales the SLO before comparison (>1 admits more,
        <1 sheds earlier); ``max_backlog`` is an optional hard queue cap
        for SLO-bearing classes."""
        if min_observations < 1:
            raise ValueError("min_observations must be >= 1")
        if slack <= 0:
            raise ValueError("slack must be > 0")
        self.max_backlog = max_backlog
        self.min_observations = int(min_observations)
        self.slack = float(slack)
        self.rejected = 0
        self.admitted = 0

    def admit(self, engine: Any, event: Any, cls: Any,
              now: float) -> bool:
        """True to submit, False to shed.  Signature matches the replay
        loop's ``admission.admit(engine, event, cls, now)`` call."""
        slo = getattr(cls, "slo_p95_ms", None)
        if slo is None:
            self.admitted += 1
            return True
        backlog = int(getattr(engine, "n_pending", 0))
        capacity = max(int(getattr(engine, "capacity", 1)), 1)
        if self.max_backlog is not None and backlog > self.max_backlog:
            self.rejected += 1
            return False
        free = getattr(engine, "free_pages", None)
        total = getattr(engine, "total_pages", None)
        headroom = 1.0
        if free is not None and total:
            if free <= 0:
                # page pool exhausted: nothing can even prefill
                self.rejected += 1
                return False
            headroom = max(min(free / total, 1.0), 1e-6)
        st = engine.stats()
        # engines key latency by workload request class (e.g. "lm/p8");
        # pool all observed classes — the queue ahead of a new arrival
        # is shared, so the pooled tail is the right congestion signal
        count = sum(h.count for h in st.latency.values())
        if count < self.min_observations:
            self.admitted += 1
            return True
        p95 = max(h.p95_ms for h in st.latency.values())
        projected = p95 * (1.0 + backlog / (capacity * headroom))
        if projected > float(slo) * self.slack:
            self.rejected += 1
            return False
        self.admitted += 1
        return True
