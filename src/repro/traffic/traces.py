"""Arrival-trace generation: deterministic, seeded, class-mixed.

A :class:`Trace` is a sorted list of :class:`TraceEvent`\\ s — *when* a
request arrives, *which* :class:`RequestClass` it belongs to, and a
per-event ``seed`` that fully determines the request payload.  The
generators draw every random quantity (inter-arrival gaps, class picks,
per-event seeds, burst dwell times) from one explicit
``numpy.random.Generator``, so the same seed always yields the same
trace, and replaying the same trace always materialises the same
request objects — determinism is the contract, not a best effort.

Two arrival processes:

* :func:`poisson_trace` — homogeneous Poisson arrivals (exponential
  inter-arrival gaps at a single ``rate``), the steady-state baseline.
* :func:`bursty_trace` — a Markov-modulated Poisson process (MMPP): the
  trace alternates between *states* (e.g. calm / burst), each an
  exponential-dwell segment emitting Poisson arrivals at its own rate.
  This is the canonical open-loop model of bursty serving traffic and
  is what exercises autoscaling (sustained backlog during a burst,
  idle capacity after it).

Payload materialisation is separate from arrival generation:
:func:`build_lm_request` / :func:`build_image_request` turn one event
into a concrete engine request using only ``event.seed``, so a trace
can be generated once and replayed against any engine.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

__all__ = [
    "RequestClass", "TraceEvent", "Trace",
    "poisson_trace", "bursty_trace",
    "build_lm_request", "build_image_request", "default_classes",
]


def _as_rng(seed: Union[int, np.random.Generator]) -> np.random.Generator:
    """Accept an int seed or a ready Generator (never global state)."""
    if isinstance(seed, np.random.Generator):
        return seed
    return np.random.default_rng(int(seed))


@dataclasses.dataclass(frozen=True)
class RequestClass:
    """One stream of requests sharing shape, priority and SLO.

    ``kind`` selects the payload builder (``"lm"`` token sequences or
    ``"image"`` frame batches); the ``(lo, hi)`` ranges are inclusive
    and sampled per event from the event's own seed.  ``priority``
    follows the scheduler convention (0 = most urgent).  ``slo_p95_ms``
    is the class's latency target — ``None`` means best-effort; the
    admission controller and the replay report both read it.
    """

    name: str
    weight: float = 1.0               # relative arrival share
    kind: str = "lm"                  # "lm" | "image"
    prompt_len: Tuple[int, int] = (4, 16)       # lm: tokens (inclusive)
    max_new_tokens: Tuple[int, int] = (8, 16)   # lm: decode budget
    frames: Tuple[int, int] = (1, 4)            # image: frames/request
    priority: int = 0                 # 0 = most urgent
    slo_p95_ms: Optional[float] = None

    def __post_init__(self):
        if self.kind not in ("lm", "image"):
            raise ValueError(f"unknown request kind {self.kind!r}")
        if self.weight <= 0:
            raise ValueError("class weight must be > 0")


@dataclasses.dataclass(frozen=True)
class TraceEvent:
    """One arrival: time (seconds from trace start), class name, and the
    seed that fully determines the request payload."""

    t: float
    cls: str
    seed: int


@dataclasses.dataclass
class Trace:
    """A finite arrival schedule over a fixed class mix.

    ``events`` are sorted by arrival time; ``classes`` maps class name
    to its definition; ``horizon`` is the generation window in seconds
    (events never exceed it).  Traces are plain data — picklable,
    comparable, and independent of any engine.
    """

    events: List[TraceEvent]
    classes: Dict[str, RequestClass]
    horizon: float

    def __len__(self) -> int:
        return len(self.events)

    def class_counts(self) -> Dict[str, int]:
        out: Dict[str, int] = {c: 0 for c in self.classes}
        for e in self.events:
            out[e.cls] += 1
        return out

    def rate(self) -> float:
        """Mean arrival rate (events per second) over the horizon."""
        return len(self.events) / self.horizon if self.horizon > 0 else 0.0


def default_classes() -> List[RequestClass]:
    """The stock short/long + priority mix used by benchmarks and the
    launcher: interactive short prompts (tight SLO, urgent) alongside
    batch long prompts (loose SLO, deferrable)."""
    return [
        RequestClass("short", weight=3.0, prompt_len=(2, 8),
                     max_new_tokens=(4, 8), priority=0, slo_p95_ms=2000.0),
        RequestClass("long", weight=1.0, prompt_len=(12, 24),
                     max_new_tokens=(12, 24), priority=1,
                     slo_p95_ms=10000.0),
    ]


def _emit_events(ts: Sequence[float], classes: Sequence[RequestClass],
                 rng: np.random.Generator) -> List[TraceEvent]:
    """Attach a weighted class pick and a payload seed to each arrival
    time.  Draw order is fixed (class then seed, per event) so the
    event list is a pure function of the arrival times and rng state."""
    names = [c.name for c in classes]
    w = np.asarray([c.weight for c in classes], np.float64)
    p = w / w.sum()
    out = []
    for t in ts:
        cls = names[int(rng.choice(len(names), p=p))]
        seed = int(rng.integers(0, 2 ** 31 - 1))
        out.append(TraceEvent(t=float(t), cls=cls, seed=seed))
    return out


def _check_classes(classes: Sequence[RequestClass]) -> Dict[str, RequestClass]:
    if not classes:
        raise ValueError("need at least one RequestClass")
    by_name = {c.name: c for c in classes}
    if len(by_name) != len(classes):
        raise ValueError("duplicate class names")
    return by_name


def poisson_trace(classes: Sequence[RequestClass], rate: float,
                  horizon: float,
                  seed: Union[int, np.random.Generator] = 0) -> Trace:
    """Homogeneous Poisson arrivals at ``rate`` req/s for ``horizon``
    seconds.  Fully deterministic given ``seed``."""
    if rate <= 0 or horizon <= 0:
        raise ValueError("rate and horizon must be > 0")
    by_name = _check_classes(classes)
    rng = _as_rng(seed)
    ts, t = [], 0.0
    while True:
        t += float(rng.exponential(1.0 / rate))
        if t >= horizon:
            break
        ts.append(t)
    return Trace(events=_emit_events(ts, classes, rng), classes=by_name,
                 horizon=float(horizon))


def bursty_trace(classes: Sequence[RequestClass],
                 rates: Sequence[float], dwell: Sequence[float],
                 horizon: float,
                 seed: Union[int, np.random.Generator] = 0) -> Trace:
    """Markov-modulated Poisson arrivals (MMPP).

    The process holds in state ``i`` for an exponential dwell with mean
    ``dwell[i]`` seconds, emitting Poisson arrivals at ``rates[i]``
    req/s, then transitions: with two states it alternates (the classic
    on/off burst model); with more it jumps uniformly to another state.
    A rate of 0 is a silent state (pure gap).  Deterministic given
    ``seed``.
    """
    if len(rates) != len(dwell) or len(rates) < 2:
        raise ValueError("need >= 2 (rate, dwell) state pairs")
    if min(rates) < 0 or any(r <= 0 for r in dwell) or horizon <= 0:
        raise ValueError("rates must be >= 0, dwells and horizon > 0")
    if max(rates) <= 0:
        raise ValueError("at least one state must have rate > 0")
    by_name = _check_classes(classes)
    rng = _as_rng(seed)
    ts: List[float] = []
    t, state = 0.0, 0
    while t < horizon:
        seg_end = min(t + float(rng.exponential(dwell[state])), horizon)
        r = rates[state]
        if r > 0:
            tt = t
            while True:
                tt += float(rng.exponential(1.0 / r))
                if tt >= seg_end:
                    break
                ts.append(tt)
        t = seg_end
        if len(rates) == 2:
            state = 1 - state
        else:
            step = 1 + int(rng.integers(0, len(rates) - 1))
            state = (state + step) % len(rates)
    return Trace(events=_emit_events(ts, classes, rng), classes=by_name,
                 horizon=float(horizon))


def build_lm_request(event: TraceEvent, cls: RequestClass,
                     vocab: int = 256, stream: bool = False):
    """Materialise one LM request from an event: prompt tokens, decode
    budget and priority are all drawn from ``event.seed`` alone, so the
    same event always builds the same request on any engine."""
    from repro.serving.engine import Request
    if cls.kind != "lm":
        raise ValueError(f"class {cls.name!r} is not an lm class")
    rng = np.random.default_rng(event.seed)
    plen = int(rng.integers(cls.prompt_len[0], cls.prompt_len[1] + 1))
    prompt = rng.integers(1, max(vocab, 2), size=max(plen, 1)).tolist()
    mnt = int(rng.integers(cls.max_new_tokens[0],
                           cls.max_new_tokens[1] + 1))
    return Request(prompt=[int(x) for x in prompt], max_new_tokens=mnt,
                   priority=cls.priority, stream=stream)


def build_image_request(event: TraceEvent, cls: RequestClass,
                        shape: Tuple[int, int, int] = (28, 28, 1),
                        stream: bool = False):
    """Materialise one image-classification request (frame batch) from
    an event, deterministic in ``event.seed``."""
    from repro.serving.capsule_engine import ImageRequest
    if cls.kind != "image":
        raise ValueError(f"class {cls.name!r} is not an image class")
    rng = np.random.default_rng(event.seed)
    n = int(rng.integers(cls.frames[0], cls.frames[1] + 1))
    images = rng.standard_normal((max(n, 1),) + tuple(shape))
    return ImageRequest(images=np.asarray(images, np.float32),
                        stream=stream)
