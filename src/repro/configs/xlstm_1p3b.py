"""xlstm-1.3b [ssm]: sLSTM + mLSTM blocks, 7:1 ratio (xLSTM[7:1]).
48L d_model=2048 4H d_ff=0 (mLSTM blocks carry their own 2x up-projection;
sLSTM blocks carry a 4/3 gated FFN) vocab=50304.  [arXiv:2405.04517]

long_500k: RUNS — O(1) recurrent state.
TP note: 4 heads < model axis; the value dim carries the TP split
(parallel/sharding.rules_for_arch).
"""

from repro.models.common import LMConfig, XLSTMConfig

CONFIG = LMConfig(
    arch_id="xlstm-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab=50304,
    xlstm=XLSTMConfig(slstm_every=8, mlstm_proj_factor=2.0,
                      slstm_ff_factor=1.3333, d_conv=4, chunk_size=256),
    remat_group=1,
)
