"""qwen3-1.7b [dense]: 28L d_model=2048 16H (GQA kv=8) d_ff=6144
vocab=151936, qk-norm.  [hf:Qwen/Qwen3-1.7B family]

long_500k: SKIP — pure full attention.
"""

from repro.models.common import LMConfig

CONFIG = LMConfig(
    arch_id="qwen3-1.7b",
    family="dense",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    d_ff=6144,
    vocab=151936,
    qk_norm=True,
    d_head=128,
    rope_theta=1000000.0,
    loss_chunks=8,
)
