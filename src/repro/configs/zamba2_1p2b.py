"""zamba2-1.2b [hybrid]: Mamba-2 backbone + ONE shared attention+MLP block
applied every 6th layer (weights shared across all 6 application sites).
38L d_model=2048 32H (MHA kv=32) d_ff=8192 vocab=32000 ssm_state=64.
[arXiv:2411.15242; hf]

long_500k: RUNS — the backbone state is O(1); the shared-attn KV grows
linearly but decode cost per token is linear in KV, not quadratic.
"""

from repro.models.common import LMConfig, SSMConfig

CONFIG = LMConfig(
    arch_id="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab=32000,
    ssm=SSMConfig(d_state=64, d_conv=4, expand=2, head_dim=64, n_groups=1,
                  chunk_size=256),
    hybrid_attn_every=6,
    rope_theta=10000.0,
    remat_group=2,
)
