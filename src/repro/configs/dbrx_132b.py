"""dbrx-132b [moe]: 40L d_model=6144 48H (GQA kv=8) d_ff=10752 (per
expert) vocab=100352; 16 experts top-4, fine-grained.
[hf:databricks/dbrx-base]

long_500k: SKIP — full attention.
"""

from repro.models.common import LMConfig, MoEConfig

CONFIG = LMConfig(
    arch_id="dbrx-132b",
    family="moe",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=10752,
    vocab=100352,
    moe=MoEConfig(n_experts=16, top_k=4, d_expert=10752, n_shared=0,
                  capacity_factor=1.25),
    rope_theta=500000.0,
    remat_group=4,
    loss_chunks=8,
)
