"""capsnet-fmnist — same architecture, F-MNIST-shaped task (28x28x1, 10
classes).  Paper: pruning keeps 12/32 capsule types (432 capsules),
compression 98.84%."""

from repro.core.capsnet import CapsNetConfig
from repro.deploy import RoutingSpec

CONFIG = CapsNetConfig(
    arch_id="capsnet-fmnist",
    image_hw=28,
    in_channels=1,
    n_classes=10,
    conv1_channels=256,
    caps_types=32,
    caps_dim=8,
    digit_dim=16,
    routing_iters=3,
    routing=RoutingSpec.reference(),
)
