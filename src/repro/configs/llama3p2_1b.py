"""llama3.2-1b [dense]: 16L d_model=2048 32H (GQA kv=8) d_ff=8192
vocab=128256, tied embeddings.  [hf:meta-llama/Llama-3.2-1B]

long_500k: SKIP — pure full attention.
"""

from repro.models.common import LMConfig

CONFIG = LMConfig(
    arch_id="llama3.2-1b",
    family="dense",
    n_layers=16,
    d_model=2048,
    n_heads=32,
    n_kv_heads=8,
    d_ff=8192,
    vocab=128256,
    tie_embeddings=True,
    rope_theta=500000.0,
    loss_chunks=8,
)
