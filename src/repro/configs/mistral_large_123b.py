"""mistral-large-123b [dense]: 88L d_model=12288 96H (GQA kv=8)
d_ff=28672 vocab=32768.  [hf:mistralai/Mistral-Large-Instruct-2407]

long_500k: SKIP — pure full attention (DESIGN.md §5.1).
"""

from repro.models.common import LMConfig

CONFIG = LMConfig(
    arch_id="mistral-large-123b",
    family="dense",
    n_layers=88,
    d_model=12288,
    n_heads=96,
    n_kv_heads=8,
    d_ff=28672,
    vocab=32768,
    rope_theta=1000000.0,
    remat_group=4,
    loss_chunks=8,
)
