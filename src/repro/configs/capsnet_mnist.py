"""capsnet-mnist — the paper's own architecture (Sabour et al. [4], Fig. 3).

Conv1 9x9/256 -> PrimaryCaps 9x9 s2 (32 types x 8D = 1152 capsules) ->
DigitCaps (10 x 16D, 3 routing iterations) + FC decoder 512/1024/784.

The FastCaps deployment config (pruned + optimized) is derived from this
via ``repro.deploy.FastCapsPipeline`` at the paper's sparsity (conv2
kernels pruned until 7/32 capsule types survive -> 252 capsules) with the
typed ``RoutingSpec.pallas(softmax="taylor")`` routing.
"""

import dataclasses as _dc

from repro.core.capsnet import CapsNetConfig
from repro.deploy import RoutingSpec

CONFIG = CapsNetConfig(
    arch_id="capsnet-mnist",
    image_hw=28,
    in_channels=1,
    n_classes=10,
    conv1_channels=256,
    caps_types=32,
    caps_dim=8,
    digit_dim=16,
    routing_iters=3,
    routing=RoutingSpec.reference(),
)

# FastCaps deployment variant (paper §III-B optimizations on)
OPTIMIZED = _dc.replace(CONFIG, routing=RoutingSpec.pallas(softmax="taylor"))
