"""deepseek-moe-16b [moe]: 28L d_model=2048 16H (MHA kv=16) d_ff=1408
(per expert) vocab=102400; 2 shared + 64 routed experts, top-6,
fine-grained.  [arXiv:2401.06066]

Deviation note (DESIGN.md §7): the real model's first layer is a dense
FFN; here all 28 layers are MoE to keep the stack scan-homogeneous
(<2% parameter deviation).

long_500k: SKIP — full attention.  LAKP applicability: expert blocks
(core/pruning.prune_moe_experts).
"""

from repro.models.common import LMConfig, MoEConfig

CONFIG = LMConfig(
    arch_id="deepseek-moe-16b",
    family="moe",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab=102400,
    moe=MoEConfig(n_experts=64, top_k=6, d_expert=1408, n_shared=2,
                  capacity_factor=1.25),
    rope_theta=10000.0,
    loss_chunks=8,
)
