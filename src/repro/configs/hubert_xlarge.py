"""hubert-xlarge [audio]: encoder-only, 48L d_model=1280 16H (MHA kv=16)
d_ff=5120 vocab=504 (masked-prediction cluster targets).
[arXiv:2106.07447]

The conv waveform frontend is a STUB per the assignment: ``input_specs``
provides precomputed frame embeddings (B, S, d_model).

decode/long shapes: SKIP — encoder-only, no autoregressive step.
vocab=504 is not divisible by the model axis -> replicated unembed
(handled automatically by divisibility-aware sharding).
"""

from repro.models.common import LMConfig

CONFIG = LMConfig(
    arch_id="hubert-xlarge",
    family="audio",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    d_ff=5120,
    vocab=504,
    causal=False,
    norm="layernorm",
    glu=False,
    act="gelu",
    frontend="audio",
    remat_group=2,
)
