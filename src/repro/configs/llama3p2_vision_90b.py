"""llama-3.2-vision-90b [vlm]: 100L (80 self + 20 cross-attn image layers,
one cross layer after every 4 self layers) d_model=8192 64H (GQA kv=8)
d_ff=28672 vocab=128256.  [hf:meta-llama/Llama-3.2-90B-Vision]

The vision tower is a STUB per the assignment: ``input_specs`` provides
precomputed patch embeddings (B, 1024, d_model), projected by a learned
img_proj and cross-attended with tanh-gated residuals (gate init 0).

long_500k: SKIP — full attention.
"""

from repro.models.common import LMConfig

CONFIG = LMConfig(
    arch_id="llama-3.2-vision-90b",
    family="vlm",
    n_layers=100,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab=128256,
    cross_attn_every=4,
    n_image_tokens=1024,
    rope_theta=500000.0,
    remat_group=2,
    loss_chunks=8,
)
