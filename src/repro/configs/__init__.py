"""Config registry: the 10 assigned architectures + the paper's CapsNets.

``get_config(arch_id)`` returns the full published config;
``reduced(cfg)`` returns a CPU-smoke-sized config of the same family;
``CELLS`` is the (arch x shape) dry-run matrix with skip annotations;
``input_specs(cfg, shape)`` returns ShapeDtypeStruct stand-ins for every
model input of that cell (weak-type-correct, shardable, no allocation).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs import (capsnet_fmnist, capsnet_mnist, dbrx_132b,
                           deepseek_moe_16b, hubert_xlarge, llama3p2_1b,
                           llama3p2_vision_90b, mistral_large_123b,
                           qwen1p5_110b, qwen3_1p7b, xlstm_1p3b, zamba2_1p2b)
from repro.core.capsnet import CapsNetConfig
from repro.models.common import LMConfig, MoEConfig, SSMConfig, XLSTMConfig

_MODULES = {
    "zamba2-1.2b": zamba2_1p2b,
    "xlstm-1.3b": xlstm_1p3b,
    "mistral-large-123b": mistral_large_123b,
    "llama3.2-1b": llama3p2_1b,
    "qwen3-1.7b": qwen3_1p7b,
    "qwen1.5-110b": qwen1p5_110b,
    "deepseek-moe-16b": deepseek_moe_16b,
    "dbrx-132b": dbrx_132b,
    "hubert-xlarge": hubert_xlarge,
    "llama-3.2-vision-90b": llama3p2_vision_90b,
    "capsnet-mnist": capsnet_mnist,
    "capsnet-fmnist": capsnet_fmnist,
}

ASSIGNED_ARCHS: List[str] = [
    "zamba2-1.2b", "xlstm-1.3b", "mistral-large-123b", "llama3.2-1b",
    "qwen3-1.7b", "qwen1.5-110b", "deepseek-moe-16b", "dbrx-132b",
    "hubert-xlarge", "llama-3.2-vision-90b",
]
PAPER_ARCHS: List[str] = ["capsnet-mnist", "capsnet-fmnist"]


def list_archs(include_paper: bool = True) -> List[str]:
    return ASSIGNED_ARCHS + (PAPER_ARCHS if include_paper else [])


def get_config(arch_id: str):
    return _MODULES[arch_id].CONFIG


# ---------------------------------------------------------------------------
# Shapes / cells
# ---------------------------------------------------------------------------

SHAPES: Dict[str, Dict[str, int]] = {
    "train_4k":    {"seq": 4096,   "batch": 256, "kind": "train"},
    "prefill_32k": {"seq": 32768,  "batch": 32,  "kind": "prefill"},
    "decode_32k":  {"seq": 32768,  "batch": 128, "kind": "decode"},
    "long_500k":   {"seq": 524288, "batch": 1,   "kind": "decode"},
}

# archs whose state is sub-quadratic in context (run long_500k)
_SUBQUADRATIC = {"zamba2-1.2b", "xlstm-1.3b"}
_ENCODER_ONLY = {"hubert-xlarge"}


def cell_status(arch_id: str, shape: str) -> Optional[str]:
    """None if the cell runs; otherwise the skip reason (DESIGN.md §5.1)."""
    if arch_id in _ENCODER_ONLY and SHAPES[shape]["kind"] == "decode":
        return "SKIP(encoder-only: no autoregressive decode step)"
    if shape == "long_500k" and arch_id not in _SUBQUADRATIC:
        return "SKIP(pure full attention: 500k context needs sub-quadratic)"
    return None


CELLS: List[Tuple[str, str]] = [
    (a, s) for a in ASSIGNED_ARCHS for s in SHAPES
]


def runnable_cells() -> List[Tuple[str, str]]:
    return [(a, s) for (a, s) in CELLS if cell_status(a, s) is None]


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStructs, no allocation)
# ---------------------------------------------------------------------------


def input_specs(cfg: LMConfig, shape: str) -> Dict[str, Any]:
    """Model-input stand-ins for a cell.  For train/prefill these are the
    batch dict; decode adds tokens(B,1) + pos.  Caches are built separately
    (models/lm.make_caches(as_structs=True))."""
    info = SHAPES[shape]
    b, s = info["batch"], info["seq"]
    kind = info["kind"]
    i32 = jnp.int32
    if kind == "train":
        if cfg.family == "audio":
            batch = {
                "features": jax.ShapeDtypeStruct((b, s, cfg.d_model),
                                                 jnp.float32),
                "labels": jax.ShapeDtypeStruct((b, s), i32),
            }
        elif cfg.family == "vlm":
            batch = {
                "tokens": jax.ShapeDtypeStruct((b, s), i32),
                "labels": jax.ShapeDtypeStruct((b, s), i32),
                "image_features": jax.ShapeDtypeStruct(
                    (b, cfg.n_image_tokens, cfg.d_model), jnp.float32),
            }
        else:
            batch = {"tokens": jax.ShapeDtypeStruct((b, s), i32),
                     "labels": jax.ShapeDtypeStruct((b, s), i32)}
        return batch
    if kind == "prefill":
        if cfg.family == "audio":
            return {"features": jax.ShapeDtypeStruct((b, s, cfg.d_model),
                                                     jnp.float32)}
        batch = {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
        if cfg.family == "vlm":
            batch["image_features"] = jax.ShapeDtypeStruct(
                (b, cfg.n_image_tokens, cfg.d_model), jnp.float32)
        return batch
    # decode: one new token against a KV/state cache of length s
    return {"tokens": jax.ShapeDtypeStruct((b, 1), i32),
            "pos": jax.ShapeDtypeStruct((), i32)}


def batch_axes(cfg, shape: str) -> Dict[str, Any]:
    """Logical axes for the input batch (for in_shardings)."""
    info = SHAPES[shape]
    kind = info["kind"]
    ax: Dict[str, Any] = {}
    if kind in ("train", "prefill"):
        if getattr(cfg, "family", None) == "audio":
            ax["features"] = ("batch", "seq", "act_embed")
        else:
            ax["tokens"] = ("batch", "seq")
        if kind == "train":
            ax["labels"] = ("batch", "seq")
        if getattr(cfg, "family", None) == "vlm":
            ax["image_features"] = ("batch", None, "act_embed")
        return ax
    return {"tokens": ("batch", None), "pos": None}


# ---------------------------------------------------------------------------
# Reduced (smoke) configs — same family, CPU-sized
# ---------------------------------------------------------------------------


def reduced(cfg) -> Any:
    """Shrink any config to CPU-smoke size, preserving family + features."""
    if isinstance(cfg, CapsNetConfig):
        return dataclasses.replace(
            cfg, conv1_channels=16, caps_types=4, decoder_hidden=(32, 64))
    assert isinstance(cfg, LMConfig)
    kw: Dict[str, Any] = dict(
        n_layers=_reduced_layers(cfg),
        d_model=64,
        n_heads=max(2, min(cfg.n_heads, 4)),
        n_kv_heads=0,  # fixed below
        d_ff=128 if cfg.d_ff else 0,
        vocab=128,
        remat=False,
        remat_group=1,
        loss_chunks=2,
        max_seq_len=128,
        n_image_tokens=8 if cfg.cross_attn_every else cfg.n_image_tokens,
        attn_q_block=32,
        attn_kv_block=32,
    )
    kw["n_kv_heads"] = (kw["n_heads"] if cfg.n_kv_heads == cfg.n_heads
                        else max(1, kw["n_heads"] // 2))
    if cfg.d_head:
        kw["d_head"] = 16
    if cfg.moe is not None:
        kw["moe"] = MoEConfig(n_experts=8, top_k=min(cfg.moe.top_k, 2),
                              d_expert=32, n_shared=cfg.moe.n_shared,
                              capacity_factor=cfg.moe.capacity_factor)
        kw["d_ff"] = 32
    if cfg.ssm is not None:
        kw["ssm"] = SSMConfig(d_state=8, d_conv=4, expand=2, head_dim=16,
                              n_groups=1, chunk_size=16)
    if cfg.xlstm is not None:
        kw["xlstm"] = XLSTMConfig(slstm_every=cfg.xlstm.slstm_every,
                                  mlstm_proj_factor=2.0,
                                  slstm_ff_factor=cfg.xlstm.slstm_ff_factor,
                                  d_conv=4, chunk_size=16)
    return dataclasses.replace(cfg, **kw)


def _reduced_layers(cfg: LMConfig) -> int:
    if cfg.family == "ssm":
        return cfg.xlstm.slstm_every          # one group
    if cfg.family == "vlm":
        return cfg.cross_attn_every + 1       # one group
    if cfg.family == "hybrid":
        return 2 * cfg.hybrid_attn_every      # two shared-attn sites
    return 2
