from repro.data import synthetic_digits, tokens  # noqa: F401
