"""Deterministic synthetic token streams for LM training/serving tests.

A seeded order-1 Markov chain over the vocabulary with a small number of
high-probability transitions gives a stream with learnable structure
(loss drops quickly below uniform entropy), with O(1) memory.  Batches are
(tokens, labels) next-token pairs.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator

import numpy as np


@dataclasses.dataclass(frozen=True)
class TokenStreamConfig:
    vocab: int = 1024
    branch: int = 4               # likely successors per token
    p_follow: float = 0.9         # prob of taking a likely successor
    seed: int = 0


class TokenStream:
    def __init__(self, cfg: TokenStreamConfig):
        self.cfg = cfg
        rng = np.random.RandomState(cfg.seed)
        self.successors = rng.randint(
            0, cfg.vocab, size=(cfg.vocab, cfg.branch)).astype(np.int32)

    def sample(self, batch: int, seq_len: int, seed: int
               ) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.RandomState(seed)
        toks = np.empty((batch, seq_len + 1), np.int32)
        toks[:, 0] = rng.randint(0, cfg.vocab, size=batch)
        for t in range(seq_len):
            follow = rng.rand(batch) < cfg.p_follow
            pick = rng.randint(0, cfg.branch, size=batch)
            nxt = self.successors[toks[:, t], pick]
            rand = rng.randint(0, cfg.vocab, size=batch)
            toks[:, t + 1] = np.where(follow, nxt, rand)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def batches(self, batch: int, seq_len: int, n_steps: int, seed: int = 0
                ) -> Iterator[Dict[str, np.ndarray]]:
        for step in range(n_steps):
            yield self.sample(batch, seq_len, seed * 100_003 + step)
