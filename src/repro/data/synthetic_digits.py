"""Procedural digit / fashion datasets (MNIST / F-MNIST stand-ins).

Offline-deterministic replacements with matched shapes (28x28x1, 10
classes).  Digits are stroke polylines rendered as distance fields; the
fashion set uses per-class silhouette primitives.  Per-sample augmentation
(rotation, translation, scale, noise) is seeded, so the prune->finetune->
eval pipeline is end-to-end reproducible.  Error rates on these sets are
compared *relatively* (LAKP vs KP at matched sparsity), mirroring the
paper's claim structure (DESIGN.md §7.6).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, List, Tuple

import numpy as np

HW = 28

# Stroke polylines per digit class in a unit box [0,1]^2 (x, y), y down.
_DIGIT_STROKES: Dict[int, List[List[Tuple[float, float]]]] = {
    0: [[(0.3, 0.2), (0.7, 0.2), (0.8, 0.5), (0.7, 0.8), (0.3, 0.8),
         (0.2, 0.5), (0.3, 0.2)]],
    1: [[(0.5, 0.15), (0.5, 0.85)], [(0.35, 0.3), (0.5, 0.15)]],
    2: [[(0.25, 0.3), (0.5, 0.15), (0.75, 0.3), (0.3, 0.8), (0.75, 0.8)]],
    3: [[(0.25, 0.2), (0.7, 0.25), (0.45, 0.5), (0.7, 0.7), (0.25, 0.82)]],
    4: [[(0.65, 0.85), (0.65, 0.15), (0.25, 0.6), (0.8, 0.6)]],
    5: [[(0.75, 0.15), (0.3, 0.15), (0.28, 0.45), (0.65, 0.45),
         (0.72, 0.68), (0.3, 0.82)]],
    6: [[(0.65, 0.15), (0.35, 0.45), (0.3, 0.7), (0.55, 0.82),
         (0.7, 0.62), (0.35, 0.55)]],
    7: [[(0.25, 0.18), (0.75, 0.18), (0.45, 0.85)]],
    8: [[(0.5, 0.15), (0.7, 0.3), (0.3, 0.6), (0.5, 0.82), (0.7, 0.6),
         (0.3, 0.3), (0.5, 0.15)]],
    9: [[(0.7, 0.4), (0.45, 0.5), (0.35, 0.3), (0.6, 0.18), (0.7, 0.4),
         (0.6, 0.85)]],
}

# Fashion-ish silhhouettes: each class = list of (kind, params) primitives;
# kind: "rect" (x0,y0,x1,y1) or "line" polyline.
_FASHION_PRIMS: Dict[int, List] = {
    0: [("rect", (0.3, 0.25, 0.7, 0.8))],                       # tshirt body
    1: [("rect", (0.38, 0.2, 0.62, 0.85))],                     # trouser
    2: [("rect", (0.28, 0.25, 0.72, 0.75)),
        ("line", [(0.28, 0.3), (0.15, 0.55)]),
        ("line", [(0.72, 0.3), (0.85, 0.55)])],                 # pullover
    3: [("rect", (0.35, 0.2, 0.65, 0.55)),
        ("rect", (0.3, 0.55, 0.7, 0.85))],                      # dress
    4: [("rect", (0.27, 0.25, 0.73, 0.8)),
        ("line", [(0.5, 0.25), (0.5, 0.8)])],                   # coat
    5: [("line", [(0.3, 0.6), (0.7, 0.55), (0.75, 0.7), (0.3, 0.75),
                  (0.3, 0.6)])],                                # sandal
    6: [("rect", (0.32, 0.22, 0.68, 0.78)),
        ("line", [(0.32, 0.22), (0.68, 0.78)])],                # shirt
    7: [("line", [(0.25, 0.65), (0.6, 0.6), (0.78, 0.68), (0.75, 0.78),
                  (0.25, 0.78), (0.25, 0.65)])],                # sneaker
    8: [("rect", (0.3, 0.35, 0.7, 0.75)),
        ("line", [(0.35, 0.35), (0.4, 0.2), (0.6, 0.2), (0.65, 0.35)])],
    9: [("line", [(0.3, 0.25), (0.35, 0.7), (0.5, 0.8), (0.75, 0.75),
                  (0.72, 0.6), (0.45, 0.6), (0.42, 0.25), (0.3, 0.25)])],
}


def _dist_to_segment(px, py, ax, ay, bx, by):
    vx, vy = bx - ax, by - ay
    wx, wy = px - ax, py - ay
    denom = max(vx * vx + vy * vy, 1e-9)
    t = np.clip((wx * vx + wy * vy) / denom, 0.0, 1.0)
    dx, dy = wx - t * vx, wy - t * vy
    return np.sqrt(dx * dx + dy * dy)


def _render(prims, angle: float, dx: float, dy: float, scale: float,
            sigma: float) -> np.ndarray:
    ys, xs = np.mgrid[0:HW, 0:HW]
    px = xs / (HW - 1.0)
    py = ys / (HW - 1.0)
    # inverse-transform pixel coords into the canonical frame
    cx = px - 0.5 - dx
    cy = py - 0.5 - dy
    ca, sa = np.cos(-angle), np.sin(-angle)
    rx = (ca * cx - sa * cy) / scale + 0.5
    ry = (sa * cx + ca * cy) / scale + 0.5
    dist = np.full((HW, HW), 1e9)
    for prim in prims:
        if prim[0] == "rect":
            x0, y0, x1, y1 = prim[1]
            segs = [((x0, y0), (x1, y0)), ((x1, y0), (x1, y1)),
                    ((x1, y1), (x0, y1)), ((x0, y1), (x0, y0))]
            for (a, b) in segs:
                dist = np.minimum(dist, _dist_to_segment(
                    rx, ry, a[0], a[1], b[0], b[1]))
        else:
            pts = prim[1]
            for a, b in zip(pts[:-1], pts[1:]):
                dist = np.minimum(dist, _dist_to_segment(
                    rx, ry, a[0], a[1], b[0], b[1]))
    return np.exp(-0.5 * (dist / sigma) ** 2).astype(np.float32)


@dataclasses.dataclass(frozen=True)
class DigitsConfig:
    variant: str = "digits"       # digits | fashion
    n_train: int = 2048
    n_test: int = 512
    seed: int = 0
    noise: float = 0.05
    sigma: float = 0.05


def _make_split(cfg: DigitsConfig, n: int, seed: int
                ) -> Tuple[np.ndarray, np.ndarray]:
    rng = np.random.RandomState(seed)
    labels = rng.randint(0, 10, size=n)
    images = np.zeros((n, HW, HW, 1), np.float32)
    table = _DIGIT_STROKES if cfg.variant == "digits" else None
    for i in range(n):
        cls = int(labels[i])
        if cfg.variant == "digits":
            prims = [("line", s) for s in _DIGIT_STROKES[cls]]
        else:
            prims = _FASHION_PRIMS[cls]
        angle = rng.uniform(-0.25, 0.25)
        dx, dy = rng.uniform(-0.08, 0.08, size=2)
        scale = rng.uniform(0.85, 1.15)
        img = _render(prims, angle, dx, dy, scale, cfg.sigma)
        img += rng.randn(HW, HW).astype(np.float32) * cfg.noise
        images[i, :, :, 0] = np.clip(img, 0.0, 1.0)
    return images, labels.astype(np.int32)


def load(cfg: DigitsConfig):
    """Returns dict with train/test images (N,28,28,1) in [0,1] and labels."""
    tr_x, tr_y = _make_split(cfg, cfg.n_train, cfg.seed)
    te_x, te_y = _make_split(cfg, cfg.n_test, cfg.seed + 10_000)
    return {"train": (tr_x, tr_y), "test": (te_x, te_y)}


def batches(x: np.ndarray, y: np.ndarray, batch_size: int, seed: int,
            epochs: int = 1) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
    rng = np.random.RandomState(seed)
    n = x.shape[0]
    for _ in range(epochs):
        order = rng.permutation(n)
        for i in range(0, n - batch_size + 1, batch_size):
            idx = order[i:i + batch_size]
            yield x[idx], y[idx]
