"""``repro.serving`` — the unified async serving engine API.

One :class:`EngineCore` owns slot state, fixed-shape jitted ticks,
streaming results and cumulative stats (with per-request-class latency
and per-phase queue-depth histograms); pluggable :class:`Scheduler`s
decide admission, batch shape, device placement and prefill/decode tick
interleaving; :class:`CapsuleEngine` (CapsNet image frames, the paper's
Fig. 1 workload) and :class:`ServeEngine` (LM decode, optionally sharded
across a mesh) are thin workload adapters sharing the ``submit() /
poll() / run_until_idle() / stats()`` surface with true async admission.
:class:`DisaggregatedEngine` (``repro.serving.disagg``) keeps that same
surface while splitting prefill and decode onto dedicated engines joined
by typed :class:`CacheHandoff`\\ s.

``ServeEngine(page_size=...)`` swaps the dense slot caches for the
block-paged layout of ``repro.serving.pages`` (:class:`PagePool`):
a global page pool with per-slot page tables, content-addressed prefix
reuse across requests, optional int8 page quantization
(``quantize_pages=True``), and page-reference handoffs/preemption.

See ``docs/serving.md`` for the engine lifecycle and design notes.
"""

from repro.serving.capsule_engine import (CapsuleEngine,  # noqa: F401
                                          ImageCompletion, ImageRequest)
from repro.serving.core import (DepthHistogram, EngineCore,  # noqa: F401
                                EngineStats, LatencyHistogram, SlotTask,
                                StreamEvent)
from repro.serving.disagg import (CacheHandoff, DecodeEngine,  # noqa: F401
                                  DisaggregatedEngine, HandoffRequest,
                                  PrefillEngine, disaggregated_lm_engine,
                                  multihost_disaggregated_lm_engine)
from repro.serving.engine import Completion, Request, ServeEngine  # noqa: F401
from repro.serving.pages import PagePool, PagePoolExhausted  # noqa: F401
from repro.serving.schedulers import (DisaggScheduler,  # noqa: F401
                                      FIFOScheduler, InterleavingScheduler,
                                      PriorityScheduler, Scheduler,
                                      ShardedScheduler, SLOBatchScheduler,
                                      TickRecord, pow2_bucket)
from repro.serving.transport import (DeviceToDeviceTransport,  # noqa: F401
                                     HostStagedTransport, InProcessTransport,
                                     TransferRecord, Transport,
                                     TransportError, make_transport,
                                     select_transport)
