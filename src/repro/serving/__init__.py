"""``repro.serving`` — the unified async serving engine API.

One :class:`EngineCore` owns slot state, fixed-shape jitted ticks,
streaming results and cumulative stats (with per-request-class latency
histograms); pluggable :class:`Scheduler`s decide admission, batch shape,
device placement and prefill/decode tick interleaving;
:class:`CapsuleEngine` (CapsNet image frames, the paper's Fig. 1
workload) and :class:`ServeEngine` (LM decode, optionally sharded across
a mesh) are thin workload adapters sharing the ``submit() / poll() /
run_until_idle() / stats()`` surface with true async admission.

See ``docs/serving.md`` for the engine lifecycle and design notes.
"""

from repro.serving.capsule_engine import (CapsuleEngine,  # noqa: F401
                                          ImageCompletion, ImageRequest)
from repro.serving.core import (EngineCore, EngineStats,  # noqa: F401
                                LatencyHistogram, SlotTask, StreamEvent)
from repro.serving.engine import Completion, Request, ServeEngine  # noqa: F401
from repro.serving.schedulers import (FIFOScheduler,  # noqa: F401
                                      InterleavingScheduler, Scheduler,
                                      ShardedScheduler, SLOBatchScheduler,
                                      TickRecord, pow2_bucket)
