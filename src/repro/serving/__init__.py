"""``repro.serving`` — the unified async serving engine API.

One :class:`EngineCore` owns slot state, fixed-shape jitted ticks and
cumulative stats; pluggable :class:`Scheduler`s decide admission, batch
shape and device placement; :class:`CapsuleEngine` (CapsNet image frames,
the paper's Fig. 1 workload) and :class:`ServeEngine` (LM decode) are thin
workload adapters sharing the ``submit() / poll() / run_until_idle() /
stats()`` surface with true async admission.
"""

from repro.serving.capsule_engine import (CapsuleEngine,  # noqa: F401
                                          ImageCompletion, ImageRequest)
from repro.serving.core import (EngineCore, EngineStats,  # noqa: F401
                                SlotTask)
from repro.serving.engine import Completion, Request, ServeEngine  # noqa: F401
from repro.serving.schedulers import (FIFOScheduler,  # noqa: F401
                                      Scheduler, ShardedScheduler,
                                      SLOBatchScheduler, TickRecord,
                                      pow2_bucket)
