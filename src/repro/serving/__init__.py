from repro.serving.capsule_engine import (CapsuleEngine,  # noqa: F401
                                          EngineStats, ImageCompletion,
                                          ImageRequest)
from repro.serving.engine import Completion, Request, ServeEngine  # noqa: F401
