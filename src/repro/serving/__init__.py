from repro.serving.engine import Completion, Request, ServeEngine  # noqa: F401
