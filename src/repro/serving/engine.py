"""Serving engine: KV-cache slot management, batched prefill + decode.

A fixed-size batch of ``n_slots`` request slots (continuous-batching lite):
requests join free slots, prefill writes their cache rows, and one fused
``decode_step`` advances every active slot per tick.  Finished slots are
recycled without disturbing the others — the decode step is shape-stable,
which keeps it a single compiled executable (and keeps steps
deterministic-size for the straggler posture, DESIGN.md §4).

The engine works for every cached family (dense/moe/hybrid/vlm); encoder
(audio) models have no decode path.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import lm
from repro.models.common import LMConfig


@dataclasses.dataclass
class Request:
    prompt: List[int]
    max_new_tokens: int = 16
    temperature: float = 0.0      # 0 -> greedy
    rid: int = 0


@dataclasses.dataclass
class Completion:
    rid: int
    tokens: List[int]


class ServeEngine:
    def __init__(self, cfg: LMConfig, params: Any, n_slots: int = 4,
                 max_len: int = 512, seed: int = 0):
        assert cfg.family != "audio", "encoder models have no decode path"
        self.cfg = cfg
        self.params = params
        self.n_slots = n_slots
        self.max_len = max_len
        self.key = jax.random.key(seed)
        self._decode = jax.jit(
            lambda p, b, c: lm.decode_step(p, cfg, b, c))
        self._prefill = jax.jit(
            lambda p, b, c: lm.prefill_step(p, cfg, b, c))

    # -- single-batch convenience ------------------------------------------

    def generate(self, prompts: List[List[int]], max_new_tokens: int = 16,
                 temperature: float = 0.0) -> List[List[int]]:
        """Batched prefill + greedy/temperature decode for equal-priority
        prompts (right-aligned padding to the longest prompt)."""
        cfg = self.cfg
        b = len(prompts)
        plen = max(len(p) for p in prompts)
        toks = np.zeros((b, plen), np.int32)
        for i, p in enumerate(prompts):
            toks[i, :len(p)] = p                # left-aligned, pad right
        caches = lm.make_caches(cfg, b, self.max_len)
        logits, caches = self._prefill(
            self.params, {"tokens": jnp.asarray(toks)}, caches)
        # NOTE: uniform prompt length assumed for cache-position simplicity;
        # ragged prompts are padded and the pad tokens attended (documented
        # serving limitation; slot engine below re-prefills per request).
        out = [list(p) for p in prompts]
        pos = plen
        for _ in range(max_new_tokens):
            nxt = self._sample(logits, temperature)
            for i in range(b):
                out[i].append(int(nxt[i]))
            batch = {"tokens": nxt[:, None],
                     "pos": jnp.int32(pos)}
            logits, caches = self._decode(self.params, batch, caches)
            pos += 1
            if pos >= self.max_len:
                break
        return out

    def _sample(self, logits: jax.Array, temperature: float) -> jax.Array:
        if temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        self.key, sub = jax.random.split(self.key)
        return jax.random.categorical(
            sub, logits / temperature, axis=-1).astype(jnp.int32)

    # -- slot-based continuous batching ------------------------------------

    def serve(self, requests: List[Request]) -> List[Completion]:
        """Run all requests to completion with n_slots-way batched decode."""
        cfg = self.cfg
        queue = list(requests)
        active: List[Optional[dict]] = [None] * self.n_slots
        caches = lm.make_caches(cfg, self.n_slots, self.max_len)
        tokens = np.zeros((self.n_slots, 1), np.int32)
        pos = 0                                  # uniform tick position
        done: List[Completion] = []

        # simple generational scheme: fill all slots, decode until all
        # finish, then admit the next generation (keeps `pos` uniform
        # without per-slot position plumbing).
        while queue or any(a is not None for a in active):
            admitted = False
            for s in range(self.n_slots):
                if active[s] is None and queue:
                    req = queue.pop(0)
                    active[s] = {"req": req, "out": list(req.prompt),
                                 "left": req.max_new_tokens}
                    admitted = True
            if admitted:
                plen = max(len(a["req"].prompt) for a in active
                           if a is not None)
                toks = np.zeros((self.n_slots, plen), np.int32)
                for s, a in enumerate(active):
                    if a is not None:
                        p = a["req"].prompt
                        toks[s, :len(p)] = p
                caches = lm.make_caches(cfg, self.n_slots, self.max_len)
                logits, caches = self._prefill(
                    self.params, {"tokens": jnp.asarray(toks)}, caches)
                pos = plen
            nxt = self._sample(logits, 0.0)
            for s, a in enumerate(active):
                if a is None:
                    continue
                a["out"].append(int(nxt[s]))
                a["left"] -= 1
                if a["left"] <= 0 or pos + 1 >= self.max_len:
                    done.append(Completion(a["req"].rid, a["out"]))
                    active[s] = None
            if all(a is None for a in active):
                continue                         # admit next generation
            batch = {"tokens": nxt[:, None], "pos": jnp.int32(pos)}
            logits, caches = self._decode(self.params, batch, caches)
            pos += 1
        return done
