"""ServeEngine: LM decode serving over the shared EngineCore.

A fixed batch of ``n_slots`` KV-cache slots (continuous batching):
requests join free slots as they arrive — mid-flight, no generational
barrier — get a *ragged* batched prefill (per-slot prompt lengths and
position ids), and one fused ``decode_step`` advances every active slot
per tick with per-slot cache indices.  Finished slots are recycled without
disturbing the others; the decode step stays one compiled executable.

Ragged prefill correctness: prompts are left-aligned with a zero pad
*suffix*, so causal attention keeps real tokens from ever attending pads;
per-slot last-token logits seed generation and the vector-``pos`` decode
path masks each slot's cache beyond its own length.  Dense/vlm families
are exact — matching per-request generation token-for-token (regression-
tested); moe is exact up to GShard expert-capacity effects (capacity is
derived from the *padded* length, which depends on who shares the prefill
bucket).  Recurrent families (ssm/hybrid) cannot mask a pad suffix out of
their state after the fact, so their admission is *length-bucketed*: each
tick's new prompts are grouped by exact length and prefilled with no pad
suffix at all (exact, regression-tested; one compiled prefill shape per
distinct prompt length).  ``generate()`` raises on ragged recurrent
batches instead of silently approximating.

The engine shares ``submit() / poll() / run_until_idle() / stats()`` with
:class:`repro.serving.CapsuleEngine` via :class:`repro.serving.EngineCore`
and takes the same pluggable schedulers (an SLO scheduler throttles
*admission concurrency* here; the decode shape is pinned by the caches).

Sharded decode: under a :class:`repro.serving.ShardedScheduler` the KV
caches themselves are sharded — the cache ``batch`` axis is the slot
axis, so the mesh's data-parallel devices each own ``n_slots /
n_devices`` cache rows for the whole decode (``lm.cache_shardings``),
params are replicated, and each tick's token/position vectors are placed
with the same rules.  Decode then runs SPMD: per-slot cache reads/writes
stay device-local, and results are bit-identical to the unsharded engine
(regression-tested on a 2-device mesh).  ``n_slots`` must divide evenly
over the mesh's batch-axis devices.

Streaming: requests submitted with ``stream=True`` additionally emit one
:class:`repro.serving.StreamEvent` per generated token (prompt tokens are
not echoed), drained via ``poll(stream=True)``; the final event carries
the :class:`Completion`.  Plain ``poll()`` stays completion-level.

Paged mode (``page_size=...``): the dense slot caches are replaced by a
:class:`repro.serving.pages.PagePool` — a global page pool with
per-slot page tables, a content-addressed prefix cache (shared prompt
prefixes prefill once; later requests pin the shared read-only pages
and prefill only their suffix via ``lm.continuation_prefill_step``),
optional int8 page quantization, reference-splice preemption (O(1), no
device traffic), and host-spill fallback when the pool runs dry.  The
unquantized paged engine is bit-identical to the dense one
(regression-tested); see ``docs/serving.md``.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import lm
from repro.models.common import LMConfig
from repro.serving.core import EngineCore, SlotTask
from repro.serving.schedulers import Scheduler, ShardedScheduler, pow2_bucket


@dataclasses.dataclass
class Request:
    prompt: List[int]
    max_new_tokens: int = 16
    temperature: float = 0.0      # 0 -> greedy
    rid: Optional[int] = None     # None -> engine-assigned
    stream: bool = False          # emit per-token StreamEvents
    priority: int = 0             # 0 = most urgent (PriorityScheduler)
    seed: Optional[int] = None    # None -> engine-derived at admission;
    #                               counter-based sampling makes temp>0
    #                               decode reproducible and slot-order
    #                               independent (see kernels.sampling)
    top_k: int = 0                # 0 -> no top-k restriction
    top_p: float = 1.0            # 1.0 -> no nucleus restriction


@dataclasses.dataclass
class Completion:
    rid: int
    tokens: List[int]             # prompt + generated
    latency_s: float = 0.0        # submit -> completion wall-clock


class ServeEngine(EngineCore):
    """Slot-based continuous-batching LM engine (one request per slot).

    Thread-safety follows :class:`repro.serving.EngineCore`: ``submit``
    may be called from any thread while ticks are in flight; ``tick`` /
    ``run_until_idle`` assume a single ticker thread.  Shape contracts:
    prompts are 1-D int token lists with ``0 < len < max_len``;
    completions carry ``prompt + generated`` tokens; stats count
    *generated* tokens as items.  Under a
    :class:`repro.serving.ShardedScheduler` the KV caches are sharded
    over the mesh's batch axes (slot-parallel) — ``n_slots`` must divide
    the mesh's batch-axis device count.
    """

    def __init__(self, cfg: LMConfig, params: Any, n_slots: int = 4,
                 max_len: int = 512, seed: int = 0,
                 scheduler: Optional[Scheduler] = None,
                 clock=time.perf_counter,
                 kernel_tune: Optional[bool] = None,
                 page_size: Optional[int] = None,
                 n_pages: Optional[int] = None,
                 quantize_pages: bool = False,
                 prefix_cache: bool = True,
                 decode_kernel: bool = False):
        assert cfg.family != "audio", "encoder models have no decode path"
        self._decode_kernel = bool(decode_kernel)
        if self._decode_kernel:
            # decode through the Pallas decode_attention kernel: dense
            # caches stay resident (int8 stays int8), and paged dense/moe
            # decode reads pages through the tables via scalar prefetch
            # instead of gathering a dense view (see _decode_paged_impl);
            # tokens are drawn on device by the fused_sampling kernel
            cfg = dataclasses.replace(cfg, decode_impl="pallas")
        self.cfg = cfg
        self.params = params
        self.n_slots = n_slots
        self.max_len = max_len
        # recurrent state (ssm/hybrid) cannot mask a pad suffix the way
        # attention masks cache rows: admission is length-bucketed instead
        self._recurrent = cfg.family in ("ssm", "hybrid")
        self._seed0 = int(seed)       # base of engine-derived request seeds
        self._prefix_cache = bool(prefix_cache)
        if page_size is not None:
            from repro.serving.pages import PagePool

            self._pages: Optional[Any] = PagePool(
                cfg, n_slots, max_len, page_size, n_pages=n_pages,
                quantize=quantize_pages)
        else:
            self._pages = None
        self._decode = jax.jit(
            lambda p, t, pos, c: lm.decode_step(
                p, cfg, {"tokens": t, "pos": pos}, c))
        self._prefill = jax.jit(
            lambda p, t, ln, idx, c: self._prefill_scatter(p, t, ln, idx, c))
        # slot-axis cache row movement (shared by preemption/resume here
        # and by the disaggregated engines, which subclass this one)
        self._gather = jax.jit(
            lambda idx, c: lm.gather_cache_rows(cfg, idx, c))
        self._inject = jax.jit(
            lambda rows, idx, c: lm.scatter_cache_rows(cfg, idx, rows, c))
        # paged kernel decode needs per-slot tables threaded into the
        # model; vlm keeps the gather-to-dense fallback (its per-site kv
        # slicing predates pool-shaped leaves)
        self._paged_kernel = (self._decode_kernel
                              and self._pages is not None
                              and cfg.family in ("dense", "moe"))
        if self._pages is not None:
            self._decode_paged = jax.jit(
                lambda p, t, pos, tb, pool, res: self._decode_paged_impl(
                    p, t, pos, tb, pool, res))
            self._prefill_paged = jax.jit(
                lambda off, p, t, ln, pmap, pref, idx, pool, res:
                self._prefill_paged_impl(off, p, t, ln, pmap, pref, idx,
                                         pool, res),
                static_argnums=(0,))
        super().__init__(capacity=n_slots, scheduler=scheduler, clock=clock,
                         kernel_tune=kernel_tune)
        if self._pages is not None:
            # paged mode never allocates the dense slot caches — that is
            # the whole point (resident capacity bounded by pages, not
            # slots x max_len); generate() builds its own fresh caches
            self._caches = None
            self._pool = self._pages.init_pool_arrays()
            self._residual = self._pages.init_residual_arrays()
        else:
            self._caches = lm.make_caches(cfg, n_slots, max_len)
        self._tok = np.zeros((n_slots,), np.int32)   # pending token per slot
        self._pos = np.zeros((n_slots,), np.int32)   # its cache index
        if isinstance(self.scheduler, ShardedScheduler):
            self._shard_state(self.scheduler)

    def _shard_state(self, sched: ShardedScheduler) -> None:
        """Pin the decode state onto the scheduler's mesh: params
        replicated (decode wants weights stationary), KV caches sharded
        along their slot (``batch``) axis via ``lm.cache_shardings`` so
        each data-parallel device owns ``n_slots / n_devices`` slots end
        to end.  Per-tick token/position arrays follow through
        ``scheduler.place()``; the jitted prefill-scatter and decode
        steps then run SPMD with device-local cache updates."""
        from jax.sharding import NamedSharding, PartitionSpec

        self.params = jax.device_put(
            self.params, NamedSharding(sched.mesh, PartitionSpec()))
        if self._pages is not None:
            # the pool's page axis keeps the logical name "batch", so the
            # same shape-aware rules that shard slots shard pages; the
            # pool's block-preferring allocator then keeps a slot's pages
            # on the device that owns the slot's decode rows
            from repro.parallel import sharding as sharding_lib

            self._pool = jax.device_put(
                self._pool, sharding_lib.shardings_for(
                    self._pool, self._pages.pool_specs(),
                    sched.rules, sched.mesh))
            self._residual = jax.device_put(
                self._residual, sharding_lib.shardings_for(
                    self._residual, self._pages.residual_specs(),
                    sched.rules, sched.mesh))
            self._pages.set_device_blocks(sched.n_devices)
            return
        self._caches = jax.device_put(
            self._caches, lm.cache_shardings(self.cfg, self._caches,
                                             sched.mesh, sched.rules))

    def _prefill_scatter(self, params, tokens, lengths, slot_idx, caches):
        """Prefill a (bucketed) sub-batch on fresh caches, then scatter its
        rows into the engine caches at ``slot_idx`` — admission cost scales
        with the number of admitted slots, not engine capacity."""
        sub = lm.make_caches(self.cfg, tokens.shape[0], self.max_len)
        logits, sub = lm.ragged_prefill_step(
            params, self.cfg, {"tokens": tokens, "lengths": lengths}, sub)
        return logits, lm.scatter_cache_rows(self.cfg, slot_idx, sub, caches)

    def _decode_paged_impl(self, params, tok, pos, tables, pool, residual):
        """One paged decode tick.

        Kernel path (``decode_kernel=True``, dense/moe): the pool leaves
        pass straight through (:meth:`PagePool.pool_tree`, no gather) and
        the decode_attention kernel reads each slot's resident pages
        through its table row via scalar prefetch, writing the fresh row
        in place — a slot touches only its own pages instead of the full
        gathered ``(n_slots, max_len)`` view.

        Fallback: gather the dense view through the page tables, run the
        ordinary ``lm.decode_step``, scatter each slot's new row back
        into its mapped page.  Residual (non-paged) leaves are read-only
        during decode."""
        if self._paged_kernel:
            tree = self._pages.pool_tree(pool, residual)
            logits, new_tree = lm.decode_step(
                params, self.cfg, {"tokens": tok, "pos": pos}, tree,
                paged_tables=tables)
            new_pool, _ = self._pages.pool_untree(new_tree)
            return logits, new_pool
        view = self._pages.build_view(pool, residual, tables)
        logits, new_view = lm.decode_step(
            params, self.cfg, {"tokens": tok, "pos": pos}, view)
        return logits, self._pages.scatter_decode_rows(
            pool, new_view, tables, pos)

    def _prefill_paged_impl(self, off, params, tokens, lengths, page_map,
                            prefix_rows, slot_idx, pool, residual):
        """Paged (possibly continuation) prefill: a fresh sub cache just
        long enough for the written page span, prefilled from position
        ``off`` (0 = ordinary ragged prefill; > 0 continues from the
        dequantized shared-prefix pages in ``prefix_rows``), then
        scattered into the pool at page granularity."""
        pages = self._pages
        nb = tokens.shape[0]
        total = off + page_map.shape[1] * pages.page_size
        if off == 0:
            sub = lm.make_caches(self.cfg, nb, total)
            logits, sub = lm.ragged_prefill_step(
                params, self.cfg, {"tokens": tokens, "lengths": lengths},
                sub)
        else:
            sub = pages.make_continuation_caches(pool, prefix_rows, nb,
                                                 total)
            logits, sub = lm.continuation_prefill_step(
                params, self.cfg, {"tokens": tokens, "lengths": lengths},
                sub, off)
        new_pool = pages.write_prefill_pages(pool, sub, page_map, off)
        new_res = pages.scatter_residual_rows(
            residual, pages.residual_rows_from(sub), slot_idx)
        return logits, new_pool, new_res

    # -- sampling ----------------------------------------------------------
    #
    # Counter-based (see repro.kernels.sampling): every draw is a pure
    # function of (request seed, sequence position of the drawn token),
    # so temperature>0 decode is reproducible and independent of batch
    # composition, slot assignment, preemption, and disagg handoffs.
    # Greedy stays an exact raw-logits argmax on every path.

    def _bind_seed(self, task: SlotTask) -> int:
        """The request's sampling seed, materialized at admission: a
        request without an explicit seed gets one derived from the
        engine seed and its rid, written back onto the request so it
        survives preemption and travels with a disagg handoff."""
        req = task.payload
        seed = getattr(req, "seed", None)
        if seed is None:
            seed = (self._seed0 ^ ((task.rid + 1) * 0x9E3779B1)) & 0x7FFFFFFF
            req.seed = seed             # guarded-by: single ticker thread
        return int(seed)

    def _sample_row(self, logits_row: np.ndarray, temperature: float,
                    seed: int, pos: int, top_k: int = 0,
                    top_p: float = 1.0) -> int:
        from repro.kernels.sampling import sample_token_host

        return sample_token_host(logits_row, temperature, seed, pos,
                                 top_k=top_k, top_p=top_p)

    def _sample_task_row(self, logits_row: np.ndarray, task: SlotTask,
                         pos: int) -> int:
        req = task.payload
        return self._sample_row(
            logits_row, float(getattr(req, "temperature", 0.0)),
            self._bind_seed(task), pos,
            top_k=int(getattr(req, "top_k", 0) or 0),
            top_p=float(getattr(req, "top_p", 1.0)))

    def _sample_batch_device(self, logits, active, pos_of) -> np.ndarray:
        """Kernel-path sampling: one fused_sampling launch draws every
        active slot's token on device; the only host transfer of the
        tick is the (n_slots,) int32 token vector — the full (B, V)
        logits never leave the device."""
        from repro import kernels

        n = self._tok.shape[0]
        temp = np.zeros((n,), np.float32)
        seeds = np.zeros((n,), np.uint32)
        poss = np.zeros((n,), np.int32)
        tks = np.zeros((n,), np.int32)
        tps = np.ones((n,), np.float32)
        for s, task in active:
            req = task.payload
            temp[s] = float(getattr(req, "temperature", 0.0))
            seeds[s] = self._bind_seed(task)
            poss[s] = pos_of(s)
            tks[s] = int(getattr(req, "top_k", 0) or 0)
            tps[s] = float(getattr(req, "top_p", 1.0))
        return np.asarray(jax.block_until_ready(kernels.fused_sampling(
            logits, temp, seeds, poss, top_k=tks, top_p=tps, tune=False)))

    # -- single-batch convenience ------------------------------------------

    def generate(self, prompts: List[List[int]], max_new_tokens: int = 16,
                 temperature: float = 0.0, seed: Optional[int] = None,
                 top_k: int = 0, top_p: float = 1.0) -> List[List[int]]:
        """Batched prefill + greedy/temperature decode — ragged-correct:
        each prompt keeps its own length and position ids, so the result
        matches per-request generation (attention-cached families).

        Temperature>0 draws are counter-based: row ``i`` samples with
        seed ``(base ^ ((i + 1) * 0x9E3779B1)) & 0x7FFFFFFF`` (base =
        ``seed`` or the engine seed) and counter = the token's sequence
        position, so repeated calls are reproducible."""
        b = len(prompts)
        for p in prompts:
            self._check_prompt(p)
        if max_new_tokens <= 0:
            return [list(p) for p in prompts]
        plens = sorted({len(p) for p in prompts})
        if self._recurrent and len(plens) > 1:
            raise ValueError(
                f"ragged prompts (lengths {plens}) in one generate() batch "
                f"would fold pad tokens into the recurrent "
                f"({self.cfg.family}) state; pass uniform-length prompts, "
                f"or submit() them — the engine admits recurrent prompts "
                f"in exact-length buckets")
        caches = lm.make_caches(self.cfg, b, self.max_len)
        # recurrent: no pad suffix at all (exact length); attention
        # families mask the pad, so pow2 bucketing is free
        plen = (plens[-1] if self._recurrent
                else pow2_bucket(max(plens), self.max_len))
        tokens = np.zeros((b, plen), np.int32)
        lengths = np.ones((b,), np.int32)
        for i, p in enumerate(prompts):
            tokens[i, :len(p)] = p                   # left-aligned, pad right
            lengths[i] = len(p)
        logits, caches = self._prefill(
            self.params, jnp.asarray(tokens), jnp.asarray(lengths),
            jnp.arange(b), caches)
        logits = np.asarray(jax.block_until_ready(logits))
        out = [list(p) for p in prompts]
        base = self._seed0 if seed is None else int(seed)
        row_seed = [(base ^ ((i + 1) * 0x9E3779B1)) & 0x7FFFFFFF
                    for i in range(b)]
        pos = lengths.copy()
        alive = np.ones((b,), bool)           # slots still within max_len
        for k in range(max_new_tokens):
            for i in range(b):
                if alive[i]:
                    out[i].append(self._sample_row(
                        logits[i], temperature, row_seed[i], int(pos[i]),
                        top_k=top_k, top_p=top_p))
            if k == max_new_tokens - 1:
                break
            alive &= pos < self.max_len       # per-slot stop (cache full)
            if not alive.any():
                break
            nxt = np.array([out[i][-1] if alive[i] else 0
                            for i in range(b)], np.int32)
            logits, caches = self._decode(
                self.params, jnp.asarray(nxt[:, None]),
                jnp.asarray(np.minimum(pos, self.max_len - 1)), caches)
            logits = np.asarray(jax.block_until_ready(logits))
            pos += 1
        return out

    # -- workload hooks ----------------------------------------------------

    def _check_prompt(self, prompt) -> None:
        if not len(prompt):
            raise ValueError("empty prompt")
        if len(prompt) >= self.max_len:
            raise ValueError(
                f"prompt length {len(prompt)} leaves no room to generate "
                f"(max_len={self.max_len})")

    def _expand(self, request: Request
                ) -> Tuple[List[SlotTask], Dict[str, Any]]:
        prompt = [int(t) for t in request.prompt]
        request.prompt = prompt
        self._check_prompt(prompt)
        if request.max_new_tokens <= 0:
            return [], {}                 # prefill-free identity completion
        return [SlotTask(payload=request)], {}

    def _admit(self, new: List[Tuple[int, SlotTask]]
               ) -> Tuple[List[int], int]:
        """Ragged batched prefill for the newly admitted slots only: a
        pow2-bucketed sub-batch (cost scales with admissions, not engine
        capacity) whose cache rows are scattered into the slot caches.

        Recurrent families (ssm/hybrid) get *length-bucketed admission*
        instead: the new tasks are grouped by exact prompt length and each
        group prefills with zero pad suffix, because a recurrent state —
        unlike a KV cache — cannot mask pad tokens out after the fact.
        This closes the documented ragged-prefill gap (recurrent serving
        is exact, regression-tested) at the cost of one compiled prefill
        shape per distinct prompt length seen.

        Tasks previously preempted (``_evict`` saved their cache rows)
        take the *resume* path instead of prefilling again: one batched
        scatter re-injects their rows at the new slots and decode
        continues from the saved token/position — the finished sequence
        is exactly what an un-preempted run produces.
        """
        if self._pages is not None:
            return self._admit_paged(new)
        resume = [(s, t) for s, t in new if "resume_rows" in t.state]
        new = [(s, t) for s, t in new if "resume_rows" not in t.state]
        pre_finished: List[int] = []
        if resume:
            rows = lm.concat_cache_rows(
                self.cfg, [t.state.pop("resume_rows") for _, t in resume])
            self._caches = self._inject(
                self._place_rows(rows),
                self.scheduler.place(
                    np.asarray([s for s, _ in resume], np.int32)),
                self._caches)
            for s, task in resume:
                self._tok[s] = task.state.pop("resume_tok")
                self._pos[s] = task.state.pop("resume_pos")
                if task.state["left"] <= 0 or self._pos[s] >= self.max_len:
                    pre_finished.append(s)
        if not new:
            return pre_finished, 0
        if self._recurrent:
            groups: Dict[int, List[Tuple[int, SlotTask]]] = {}
            for s, task in new:
                groups.setdefault(len(task.payload.prompt),
                                  []).append((s, task))
            finished: List[int] = list(pre_finished)
            for plen in sorted(groups):
                finished += self._prefill_group(groups[plen], plen)
            return finished, len(new)
        plen = pow2_bucket(
            max(len(t.payload.prompt) for _, t in new), self.max_len)
        return pre_finished + self._prefill_group(new, plen), len(new)

    def _prefill_group(self, new: List[Tuple[int, SlotTask]], plen: int
                       ) -> List[int]:
        """Prefill one sub-batch whose prompts all fit in ``plen``."""
        nb = pow2_bucket(len(new), self.capacity)
        self._maybe_tune_prefill(nb, plen)
        tokens = np.zeros((nb, plen), np.int32)
        lengths = np.ones((nb,), np.int32)
        slot_idx = np.full((nb,), self.capacity, np.int32)  # pad rows: OOB
        for i, (s, task) in enumerate(new):
            p = task.payload.prompt
            tokens[i, :len(p)] = p
            lengths[i] = len(p)
            slot_idx[i] = s
        place = self.scheduler.place
        logits, self._caches = self._prefill(
            self.params, place(tokens), place(lengths),
            place(slot_idx), self._caches)
        logits = np.asarray(jax.block_until_ready(logits))
        finished = []
        for i, (s, task) in enumerate(new):
            req = task.payload
            tok = self._sample_task_row(logits[i], task, int(lengths[i]))
            task.state = {"out": list(req.prompt) + [tok],
                          "left": req.max_new_tokens - 1}
            self._emit(task.rid, tok)
            self._tok[s] = tok
            self._pos[s] = lengths[i]
            if task.state["left"] <= 0 or self._pos[s] >= self.max_len:
                finished.append(s)
        return finished

    # -- paged-cache admission / lifecycle ---------------------------------

    def _admit_paged(self, new: List[Tuple[int, SlotTask]]
                     ) -> Tuple[List[int], int]:
        """Paged admission.  Resumed tasks splice their saved table row
        back (or re-import a host spill); fresh tasks look their prompt
        up in the prefix index and prefill only past the longest hit.

        Fresh tasks are processed in waves of equal prefix-hit length,
        shortest first, and a wave defers any task whose *next* page
        hash duplicates a groupmate's — that page registers when the
        representative's group prefills, so the deferred task re-checks
        and picks the hit up.  Two identical system prompts submitted in
        the same tick therefore still prefill the shared span exactly
        once."""
        pages = self._pages
        ps = pages.page_size
        resume = [(s, t) for s, t in new
                  if "resume_pages" in t.state or "resume_spill" in t.state]
        resumed = {id(t) for _, t in resume}
        fresh = [(s, t) for s, t in new if id(t) not in resumed]
        pre_finished: List[int] = []
        for s, task in resume:
            if "resume_spill" in task.state:
                payload, n = task.state.pop("resume_spill")
                pgs = self._alloc_pages(n, s)
                self._pool = pages.import_pages(self._pool, payload, pgs)
            else:
                pgs = task.state.pop("resume_pages")
            pages.bind_slot(s, pgs)
            self._tok[s] = task.state.pop("resume_tok")
            self._pos[s] = task.state.pop("resume_pos")
            if task.state["left"] <= 0 or self._pos[s] >= self.max_len:
                pre_finished.append(s)
        if not fresh:
            return pre_finished, 0
        infos: List[List[Any]] = []
        for s, task in fresh:
            hashes = (pages.chain_hashes(task.payload.prompt)
                      if self._prefix_cache else [])
            hits = pages.acquire_prefix(hashes) if hashes else []
            infos.append([s, task, hashes, hits])
        finished = list(pre_finished)
        while infos:
            min_hit = min(len(info[3]) for info in infos)
            group, defer, seen_next = [], [], set()
            for info in infos:
                if len(info[3]) != min_hit:
                    defer.append(info)
                    continue
                nxt = info[2][min_hit] if min_hit < len(info[2]) else None
                if nxt is not None and nxt in seen_next:
                    defer.append(info)
                    continue
                if nxt is not None:
                    seen_next.add(nxt)
                group.append(info)
            finished += self._prefill_paged_group(group, min_hit * ps)
            for info in defer:   # hits can only grow as groups register
                info[3] += pages.extend_prefix(info[2], len(info[3]))
            infos = defer
        return finished, len(fresh)

    def _prefill_paged_group(self, group: List[List[Any]], off: int
                             ) -> List[int]:
        """Prefill one wave of tasks sharing prefix-hit length ``off``
        (0 = full prefill).  Suffixes pad to the dense engine's pow2
        bucket (so full prefills stay bit-identical to the dense path),
        pages past each task's own span map to the drop sentinel, and
        full suffix pages register into the prefix index."""
        pages = self._pages
        ps = pages.page_size
        nb = pow2_bucket(len(group), self.capacity)
        smax = max(len(info[1].payload.prompt) - off for info in group)
        splen = pow2_bucket(smax, self.max_len - off)
        npg = -(-splen // ps)
        if off == 0:
            self._maybe_tune_prefill(nb, splen)
        tokens = np.zeros((nb, splen), np.int32)
        lengths = np.ones((nb,), np.int32)
        slot_idx = np.full((nb,), self.capacity, np.int32)  # pad rows: OOB
        page_rows = np.full((nb, npg), pages.n_pages, np.int32)
        prefix_rows = np.zeros((nb, off // ps), np.int32)
        hit_reqs = hit_pages = 0
        for i, (s, task, hashes, hits) in enumerate(group):
            p = task.payload.prompt
            suffix = p[off:]
            tokens[i, :len(suffix)] = suffix
            lengths[i] = len(suffix)
            slot_idx[i] = s
            # pages covering positions [0, len(p)] — the prompt plus the
            # first decode write; later pages allocate lazily in _step
            own = self._alloc_pages(len(p) // ps + 1 - len(hits), s)
            allp = list(hits) + own
            pages.bind_slot(s, allp)
            for j in range(len(hits), len(hashes)):
                pages.register_hash(allp[j], hashes[j])
            base = off // ps
            for j in range(min(npg, len(allp) - base)):
                page_rows[i, j] = allp[base + j]
            prefix_rows[i, :] = allp[:base]
            if hits:
                hit_reqs += 1
                hit_pages += len(hits)
        place = self.scheduler.place
        logits, self._pool, self._residual = self._prefill_paged(
            off, self.params, place(tokens), place(lengths),
            jnp.asarray(page_rows), jnp.asarray(prefix_rows),
            place(slot_idx), self._pool, self._residual)
        logits = np.asarray(jax.block_until_ready(logits))
        finished = []
        for i, (s, task, hashes, hits) in enumerate(group):
            req = task.payload
            tok = self._sample_task_row(logits[i], task, len(req.prompt))
            task.state = {"out": list(req.prompt) + [tok],
                          "left": req.max_new_tokens - 1}
            self._emit(task.rid, tok)
            self._tok[s] = tok
            self._pos[s] = len(req.prompt)
            if task.state["left"] <= 0 or self._pos[s] >= self.max_len:
                finished.append(s)
        self._count_pages(
            prefill_ticks=1, prefix_hits=hit_reqs,
            prefix_pages_hit=hit_pages,
            prefill_tokens=sum(len(info[1].payload.prompt) - off
                               for info in group))
        return finished

    def _alloc_pages(self, n: int, slot: int) -> List[int]:
        """Allocate with spill fallback: when the pool is dry, preempted
        (queued) requests' pages move to host memory and free up —
        admission pressure never crashes a losslessly preempted task."""
        if n <= 0:
            return []
        from repro.serving.pages import PagePoolExhausted

        try:
            return self._pages.allocate(n, slot)
        except PagePoolExhausted:
            if not self._spill_preempted():
                raise
            return self._pages.allocate(n, slot)

    def _spill_preempted(self) -> bool:
        """Export every queued preempted task's pages to host numpy and
        release them; resume re-imports into fresh pages.  Returns
        whether anything was spilled."""
        with self._lock:
            targets = [t for t in self._queue if "resume_pages" in t.state]
        spilled = 0
        for task in targets:
            pgs = task.state.pop("resume_pages")
            payload = jax.tree.map(np.asarray, jax.block_until_ready(
                self._pages.export_pages(self._pool, pgs)))
            task.state["resume_spill"] = (payload, len(pgs))
            self._pages.release(pgs)
            spilled += len(pgs)
        if spilled:
            self._count_pages(spilled_pages=spilled)
        return spilled > 0

    def _ensure_decode_pages(self, active: List[Tuple[int, SlotTask]]
                             ) -> None:
        """Allocate the page under each active slot's write head when the
        decode position crosses a page boundary."""
        pages = self._pages
        for s, _ in active:
            idx = int(self._pos[s]) // pages.page_size
            if pages.page_at(s, idx) < 0:
                pages.set_slot_page(s, idx, self._alloc_pages(1, s)[0])

    def _release_slot(self, slot: int, task: SlotTask) -> None:
        if getattr(self, "_pages", None) is not None:
            pgs = self._pages.unbind_slot(slot)
            if pgs:
                self._pages.release(pgs)

    def _count_pages(self, **counts: int) -> None:
        with self._lock:
            d = self._stats.pages
            for k, v in counts.items():
                d[k] = d.get(k, 0) + int(v)

    def pin_page_hashes(self, hashes: List[Optional[bytes]]
                        ) -> Dict[int, int]:
        """Pin prefix-index hits on this engine's pool (empty when not
        paged) — the disaggregated front-end's handoff-dedup probe."""
        if self._pages is None:
            return {}
        return self._pages.pin_hashes(hashes)

    def release_page_pins(self, pages: List[int]) -> None:
        """Drop references taken by :meth:`pin_page_hashes` — the
        front-end's failed-delivery unwind."""
        if self._pages is not None and pages:
            self._pages.release(pages)

    @property
    def paged(self) -> bool:
        return self._pages is not None

    @property
    def free_pages(self) -> Optional[int]:
        """Allocatable pages right now (None when not paged) — the
        admission-control backpressure gauge."""
        return self._pages.free_pages if self._pages is not None else None

    @property
    def total_pages(self) -> Optional[int]:
        return self._pages.total_pages if self._pages is not None else None

    def _batch_for(self, n_active: int) -> int:
        return self.capacity            # decode shape pinned by the caches

    def _place_rows(self, rows: Any) -> Any:
        """Cache rows about to scatter into (possibly sharded) slot
        caches: replicate onto the scheduler's mesh so the jitted
        scatter stays device-local per slot shard."""
        if isinstance(self.scheduler, ShardedScheduler):
            from repro.parallel.sharding import replicated_shardings

            return jax.device_put(
                rows, replicated_shardings(rows, self.scheduler.mesh))
        return rows

    def _evict(self, slot: int, task: SlotTask) -> None:
        """Lossless preemption: gather the slot's cache rows (the same
        slot-axis gather a :class:`repro.serving.CacheHandoff` uses) plus
        the pending token/position into ``task.state``; the generated
        tokens already live there (``state["out"]``).  ``_admit`` later
        re-injects the rows at whatever slot the task lands in and the
        decode continues exactly where it stopped.

        Paged mode preempts by *reference*: the task keeps its page
        ownership and only the table row is saved — O(1), no device
        gather; resume splices the row into the new slot.  (If the pool
        later runs dry, ``_spill_preempted`` demotes the references to a
        host copy — still lossless.)"""
        if self._pages is not None:
            task.state["resume_pages"] = self._pages.unbind_slot(slot)
            task.state["resume_tok"] = int(self._tok[slot])
            task.state["resume_pos"] = int(self._pos[slot])
            return
        task.state["resume_rows"] = jax.block_until_ready(
            self._gather(jnp.asarray([slot], jnp.int32), self._caches))
        task.state["resume_tok"] = int(self._tok[slot])
        task.state["resume_pos"] = int(self._pos[slot])

    def _maybe_tune_prefill(self, nb: int, plen: int) -> None:
        """Measured flash-attention tuning for one exact prefill bucket
        (``kernel_tune=True`` engines only).

        Prefill shapes depend on traffic (sub-batch and prompt-length
        buckets), so guessing them at warm-up would tune buckets the
        runtime never hits.  ``_admit`` runs eagerly, before the jitted
        prefill traces — the first admission at a new ``(nb, plen)``
        bucket measures with concrete arrays here, and the trace that
        immediately follows freezes the cached winner in.  Later
        admissions in the same bucket hit the cache and pay nothing.
        """
        if not self.kernel_tune or self.cfg.attn_impl != "pallas":
            return
        from repro.kernels import tuning as ktuning
        from repro.kernels.registry import registry as kernel_registry

        kspec = kernel_registry.get("flash_attention")
        if not kspec.is_available():
            return
        cfg = self.cfg
        # measure in the model's compute dtype: the cache key includes
        # the dtype, and the traced prefill dispatches q/k/v in cdtype
        cd = cfg.cdtype()
        q = jax.random.normal(
            jax.random.key(0), (nb, plen, cfg.n_heads, cfg.head_dim)
        ).astype(cd)
        k = jax.random.normal(
            jax.random.key(1), (nb, plen, cfg.n_kv_heads, cfg.head_dim)
        ).astype(cd)
        v = jax.random.normal(
            jax.random.key(2), (nb, plen, cfg.n_kv_heads, cfg.head_dim)
        ).astype(cd)
        cache = ktuning.default_cache()
        if cache.get(ktuning.cache_key_for(kspec, (q, k, v))) is None:
            t0 = time.perf_counter()
            ktuning.autotune(
                kspec, (q, k, v),
                {"causal": True, "softmax_mode": cfg.softmax_mode},
                cache=cache)
            # one-off measurement, not serving time: keep it out of the
            # tick wall the SLO scheduler and throughput stats observe
            self._exclude_tick_time(time.perf_counter() - t0)

    def _step(self, active: List[Tuple[int, SlotTask]], n_batch: int
              ) -> Tuple[List[int], int]:
        place = self.scheduler.place
        if self._pages is not None:
            self._ensure_decode_pages(active)
            logits, self._pool = self._decode_paged(
                self.params, place(self._tok[:, None]), place(self._pos),
                jnp.asarray(self._pages.tables_snapshot()),
                self._pool, self._residual)
        else:
            logits, self._caches = self._decode(
                self.params, place(self._tok[:, None]),
                place(self._pos), self._caches)
        if self._decode_kernel:
            # fused on-device sampling: only the (n_slots,) token vector
            # crosses to host; each sampled token's counter is the
            # position it will occupy (pos + 1)
            toks = self._sample_batch_device(
                logits, active, lambda s: int(self._pos[s]) + 1)
        else:
            logits = np.asarray(jax.block_until_ready(logits))
        finished = []
        for s, task in active:
            if self._decode_kernel:
                nxt = int(toks[s])
            else:
                nxt = self._sample_task_row(logits[s], task,
                                            int(self._pos[s]) + 1)
            task.state["out"].append(nxt)
            task.state["left"] -= 1
            self._emit(task.rid, nxt)
            self._pos[s] += 1
            self._tok[s] = nxt
            if task.state["left"] <= 0 or self._pos[s] >= self.max_len:
                finished.append(s)
        return finished, len(active)

    def _request_class(self, request: Request) -> str:
        """Latency histogram key: prompts bucketed to powers of two, so
        p50/p95 are reported per prefill-cost class (``"lm/p8"`` = prompt
        length in (4, 8])."""
        return f"lm/p{pow2_bucket(len(request.prompt), self.max_len)}"

    def _finalize(self, entry, latency_s: float) -> Completion:
        tokens = (entry.tasks[0].state["out"] if entry.tasks
                  else list(entry.request.prompt))   # max_new_tokens <= 0
        return Completion(rid=entry.request.rid, tokens=tokens,
                          latency_s=latency_s)

    def stats(self):
        """Engine stats; paged engines additionally merge the pool's
        allocation/eviction/pin counters into ``stats().pages`` next to
        the engine-side prefill/prefix-hit counters."""
        st = super().stats()
        if self._pages is not None:
            merged = self._pages.counters()
            merged.update(st.pages)
            st.pages = merged
        return st
