"""Pluggable tick schedulers for :class:`repro.serving.EngineCore`.

A scheduler makes the four decisions the paper's throughput story hinges
on (CapsAcc / PIM-CapsNet: scheduling and data movement around the compute,
not the kernel alone):

  * **admission** — ``plan()``: how many slots may be occupied this tick
    (the *effective batch size*);
  * **shape** — ``quantize()``: the concrete compiled batch the workload
    pads to (a small, bounded set of shapes keeps the jit cache finite);
  * **placement** — ``place()``: where the tick's batch lives (host,
    single device, or sharded across a mesh via ``parallel.sharding``);
  * **interleaving** — ``phase()``: whether a tick admits new work
    (prefill), steps the resident work (decode), or does both.  The
    default ``"mixed"`` keeps the legacy behaviour where prefill rides
    the admission tick.

The engine feeds back one :class:`~repro.serving.core.TickRecord` per tick
through ``observe()`` so adaptive schedulers (the SLO controller) can close
the loop on measured latency.

Variants:

  * :class:`FIFOScheduler` — admit everything, always run the full
    fixed-shape batch (one executable; the shape-stability posture of the
    original drain-the-queue engines).
  * :class:`SLOBatchScheduler` — adapt the effective batch size to a
    target p95 tick latency: halve when the observed p95 overshoots the
    SLO, double back when a full window sits comfortably under it.
  * :class:`ShardedScheduler` — split each tick's batch across the
    ``batch``-mapped axes of a mesh (pure data parallelism) while
    delegating admission decisions to an inner scheduler.
  * :class:`InterleavingScheduler` — dedicate whole ticks to prefill
    (admission) or decode (stepping) so a burst of long prompts cannot
    stretch the inter-token latency of the already-resident slots.
  * :class:`DisaggScheduler` — the phase policy of a
    :class:`repro.serving.DisaggregatedEngine` front-end, which adds a
    fourth tick kind: ``"handoff"`` (move finished prefills to a decode
    engine).  Plain engines have no handoff stage and coerce the answer
    to ``"mixed"``, so the scheduler is safe to bind anywhere.
  * :class:`PriorityScheduler` — priority classes with preemption: queued
    tasks admit in (priority, arrival) order, and when a higher-priority
    task is queued with no free slot the scheduler evicts the
    lowest-priority resident (the engine saves its resumable state and
    requeues it — lossless, see ``EngineCore._evict``).  Admission
    size/shape/placement delegate to an inner scheduler.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, Deque, Optional

import numpy as np


def pow2_bucket(n: int, cap: int) -> int:
    """Smallest power of two >= n, clipped to [1, cap]."""
    n = max(1, min(int(n), int(cap)))
    b = 1
    while b < n:
        b *= 2
    return min(b, int(cap))


@dataclasses.dataclass(frozen=True)
class TickRecord:
    """What the engine observed for one tick (scheduler feedback)."""

    n_active: int                  # real slot tasks stepped
    n_batch: int                   # compiled batch the workload ran
    wall_s: float                  # admit + step wall-clock


class Scheduler:
    """Base scheduler: admit to capacity, one full-capacity shape.

    ``bind(core)`` is called once by the engine; schedulers are stateful
    and must not be shared between live engines.  All hooks are invoked
    by the engine with its tick lock held by a single ticker thread, so
    implementations need no locking of their own; they must not call
    back into the engine.
    """

    capacity: int = 0

    def bind(self, core: Any) -> None:
        self.capacity = core.capacity

    def plan(self, n_queued: int, n_active: int) -> int:
        """Max slots that may be occupied this tick (effective batch)."""
        return self.capacity

    def phase(self, n_queued: int, n_active: int) -> str:
        """Tick interleaving policy: ``"mixed"`` (admit *and* step — the
        legacy behaviour where prefill rides the admission tick),
        ``"prefill"`` (admission/prefill only; resident slots idle one
        tick), ``"decode"`` (step only; the queue waits), or
        ``"handoff"`` (disaggregated front-ends only: move finished
        prefill state to a decode engine).  The engine coerces
        impossible answers (e.g. ``"decode"`` with no resident work, or
        ``"handoff"`` on an engine with no handoff stage) back to
        ``"mixed"`` so a scheduler can never stall it."""
        return "mixed"

    def quantize(self, n_active: int, capacity: int) -> int:
        """Concrete compiled batch size for ``n_active`` filled slots."""
        return capacity

    def shapes(self, capacity: int) -> tuple:
        """Every batch size ``quantize`` can emit (warmup compiles each,
        so no tick pays compile time inside the measured path)."""
        return (capacity,)

    def place(self, batch: Any) -> Any:
        """Device placement of a tick's batch array (default: leave it to
        jit's host->default-device transfer)."""
        return batch

    def select(self, queue: Any) -> int:
        """Index into the engine's task queue of the next task to admit.
        The default 0 keeps admission strictly FIFO; a priority policy
        may reorder *across* classes but must stay FIFO within a class
        (the conformance suite pins starvation-freedom)."""
        return 0

    def preempt(self, queued: Any, residents: Any) -> tuple:
        """Slot ids to evict before this tick's admission.

        ``queued`` is the engine's task backlog (:class:`SlotTask`-like
        objects carrying ``priority``), ``residents`` the occupied
        ``(slot, task)`` pairs.  Evicted tasks are handed to the
        workload's ``_evict`` hook (which saves resumable state) and
        requeued at the *front* of the queue — never dropped.  Default:
        no preemption."""
        return ()

    def observe(self, record: TickRecord) -> None:
        pass


class FIFOScheduler(Scheduler):
    """Admit in arrival order up to capacity; always run the one
    full-capacity executable (maximum shape stability)."""


class SLOBatchScheduler(Scheduler):
    """Latency-SLO-aware effective batch size controller.

    Tracks a sliding window of per-tick wall-clock and compares its p95
    against ``target_p95_ms``:

      * p95 above target  -> halve the effective batch (fast back-off;
        acts as soon as ``min_samples`` ticks are in the window);
      * a *full* window at or below ``grow_frac * target`` -> double it
        (slow recovery, up to engine capacity).

    Tick shapes are power-of-two buckets of the effective batch, so the
    jit cache stays O(log capacity).
    """

    def __init__(self, target_p95_ms: float, window: int = 16,
                 min_samples: int = 4, grow_frac: float = 0.5,
                 initial_batch: Optional[int] = None):
        if target_p95_ms < 0:
            raise ValueError("target_p95_ms must be >= 0")
        self.target_p95_ms = float(target_p95_ms)
        self.window = int(window)
        self.min_samples = max(1, int(min_samples))
        self.grow_frac = float(grow_frac)
        self._initial = initial_batch
        self._batch = initial_batch or 1
        self._lat: Deque[float] = deque(maxlen=self.window)

    @property
    def effective_batch(self) -> int:
        return self._batch

    def bind(self, core: Any) -> None:
        super().bind(core)
        self._batch = min(self._initial or self.capacity, self.capacity)
        self._lat.clear()

    def plan(self, n_queued: int, n_active: int) -> int:
        return self._batch

    def quantize(self, n_active: int, capacity: int) -> int:
        return pow2_bucket(n_active, capacity)

    def shapes(self, capacity: int) -> tuple:
        out, b = [], 1
        while b < capacity:
            out.append(b)
            b *= 2
        return tuple(out) + (capacity,)

    def observe(self, record: TickRecord) -> None:
        if record.n_batch <= 0:
            return
        self._lat.append(record.wall_s * 1e3)
        if len(self._lat) < self.min_samples:
            return
        p95 = float(np.percentile(np.asarray(self._lat), 95))
        if p95 > self.target_p95_ms and self._batch > 1:
            self._batch = max(1, self._batch // 2)
            self._lat.clear()
        elif (len(self._lat) == self.window
              and p95 <= self.grow_frac * self.target_p95_ms
              and self._batch < self.capacity):
            self._batch = min(self.capacity, self._batch * 2)
            self._lat.clear()


class InterleavingScheduler(Scheduler):
    """Prefill/decode tick interleaving (disaggregated-in-time serving).

    The mixed tick couples two very different costs: a newly admitted
    slot's prefill is O(prompt length) while a resident slot's decode
    step is O(1) token.  Under the legacy ``"mixed"`` policy a burst of
    long prompts rides the same tick as everyone else's decode step and
    stretches inter-token latency for the whole batch.  This scheduler
    dedicates whole ticks instead:

      * queue non-empty and a slot free -> a **prefill** tick (admit and
        prefill the newcomers; residents idle exactly one tick);
      * otherwise -> a **decode** tick (step residents; the queue waits
        for the next free slot).

    ``decode_ratio`` bounds how often prefill may steal a tick: after a
    prefill tick, at least ``decode_ratio`` decode ticks run before the
    next admission (0 = admit whenever possible).  Admission size and
    shape delegate to ``inner``, so SLO batching composes underneath.
    """

    def __init__(self, inner: Optional[Scheduler] = None,
                 decode_ratio: int = 0):
        if decode_ratio < 0:
            raise ValueError("decode_ratio must be >= 0")
        self.inner = inner or FIFOScheduler()
        self.decode_ratio = int(decode_ratio)
        self._since_prefill = 0

    def bind(self, core: Any) -> None:
        super().bind(core)
        self.inner.bind(core)
        self._since_prefill = self.decode_ratio   # first tick may admit

    def plan(self, n_queued: int, n_active: int) -> int:
        return self.inner.plan(n_queued, n_active)

    def quantize(self, n_active: int, capacity: int) -> int:
        return self.inner.quantize(n_active, capacity)

    def shapes(self, capacity: int) -> tuple:
        return self.inner.shapes(capacity)

    def place(self, batch: Any) -> Any:
        return self.inner.place(batch)

    def phase(self, n_queued: int, n_active: int) -> str:
        if n_active == 0 and n_queued > 0:
            # idle engine: admit now (answering "decode" here would be
            # coerced to "mixed" by the engine, silently bypassing the
            # decode_ratio promise and leaving the counter stale)
            self._since_prefill = 0
            return "prefill"
        free = self.capacity - n_active
        may_admit = (n_queued > 0 and free > 0
                     and self._since_prefill >= self.decode_ratio)
        if may_admit and self.plan(n_queued, n_active) > n_active:
            self._since_prefill = 0
            return "prefill"
        self._since_prefill += 1
        return "decode"

    def observe(self, record: TickRecord) -> None:
        self.inner.observe(record)


class DisaggScheduler(Scheduler):
    """Phase policy for a :class:`repro.serving.DisaggregatedEngine`.

    Priorities: drain the **handoff** queue first (a stranded handoff is
    finished prefill work resident on *neither* engine — it holds cache
    state hostage while both sides idle).  Otherwise, prefill and decode
    live on *separate engines*, so when both sides have work the answer
    is ``"mixed"`` — both advance every front-end tick, which is what
    makes the disaggregation guarantee real: a sustained arrival stream
    keeps the prefill engine busy forever without ever costing the
    resident decodes a tick (a strict prefill-first policy would starve
    them).  Only when one side is idle does the tick dedicate to the
    other.

    ``handoff_depth`` is poked by the front-end before each ``phase()``
    call — the two-int ``phase(n_queued, n_active)`` signature is shared
    with every other scheduler, and ``n_queued`` there is the *total*
    front-end backlog (prefill queue + handoff queue).  On a plain
    :class:`repro.serving.EngineCore` nothing sets ``handoff_depth``, a
    ``"handoff"`` answer is coerced to ``"mixed"``, and the scheduler
    degrades to interleaving-style prefill/decode separation.

    ``overlap=True`` answers ``"mixed"`` instead of ``"handoff"`` when
    the handoff queue is non-empty: transfer, prefill and decode all
    advance in the same front-end tick.  This is the phase policy built
    for an *async* :class:`repro.serving.Transport`
    (``device_to_device``): delivery is dispatch-only, so draining the
    queue inside a mixed tick costs the decodes nothing — a dedicated
    handoff phase would just add dead ticks.  With a blocking transport
    the default drain-first policy keeps the (expensive) transfer out
    of the way of a whole-pool mixed tick.
    """

    def __init__(self, overlap: bool = False):
        self.handoff_depth = 0
        self.overlap = overlap

    def phase(self, n_queued: int, n_active: int) -> str:
        if self.handoff_depth > 0:
            return "mixed" if self.overlap else "handoff"
        if n_queued > 0 and n_active > 0:
            return "mixed"            # separate engines: advance both
        if n_queued > 0:
            return "prefill"
        if n_active > 0:
            return "decode"
        return "mixed"


class PriorityScheduler(Scheduler):
    """Priority classes with lossless preemption.

    Requests carry an integer ``priority`` (0 = most urgent — the engine
    stamps it onto every :class:`~repro.serving.core.SlotTask` at
    submit).  Two policies compose here:

      * **admission order** — ``select()`` picks the queued task with the
        smallest ``(priority, arrival)`` key, so higher classes jump the
        queue but admission stays FIFO *within* a class (starvation-free
        per class; a sustained stream of higher-priority work may starve
        a lower class by design — that is what the priority contract
        means, and what SLO admission control upstream is for).
      * **preemption** — when a queued task outranks a resident and no
        slot is free, ``preempt()`` evicts the *lowest*-priority resident
        (at most ``max_evictions_per_tick`` per tick).  Eviction is
        lossless: the engine's ``_evict`` hook saves the resident's
        resumable state (LM: cache rows + generated tokens, via the same
        ``gather_cache_rows`` machinery cache handoffs use) and the task
        requeues, resuming later exactly where it stopped.

    Ties never preempt: a resident is only evicted for a *strictly*
    more urgent queued task, so equal-priority traffic cannot ping-pong.
    Admission size / shape / placement / phase delegate to ``inner``
    (FIFO unless given), so SLO batching or interleaving compose below.
    """

    def __init__(self, inner: Optional[Scheduler] = None,
                 max_evictions_per_tick: int = 1):
        if max_evictions_per_tick < 0:
            raise ValueError("max_evictions_per_tick must be >= 0")
        self.inner = inner or FIFOScheduler()
        self.max_evictions_per_tick = int(max_evictions_per_tick)

    def bind(self, core: Any) -> None:
        super().bind(core)
        self.inner.bind(core)

    def plan(self, n_queued: int, n_active: int) -> int:
        return self.inner.plan(n_queued, n_active)

    def phase(self, n_queued: int, n_active: int) -> str:
        return self.inner.phase(n_queued, n_active)

    def quantize(self, n_active: int, capacity: int) -> int:
        return self.inner.quantize(n_active, capacity)

    def shapes(self, capacity: int) -> tuple:
        return self.inner.shapes(capacity)

    def place(self, batch: Any) -> Any:
        return self.inner.place(batch)

    def observe(self, record: TickRecord) -> None:
        self.inner.observe(record)

    @staticmethod
    def _prio(task: Any) -> int:
        return int(getattr(task, "priority", 0))

    def select(self, queue: Any) -> int:
        best, best_p = 0, None
        for i, task in enumerate(queue):
            p = self._prio(task)
            if best_p is None or p < best_p:   # strict: FIFO within class
                best, best_p = i, p
        return best

    def preempt(self, queued: Any, residents: Any) -> tuple:
        if not queued or not residents or not self.max_evictions_per_tick:
            return ()
        free = self.capacity - len(residents)
        # most-urgent queued first; worst resident is the only candidate
        want = sorted(self._prio(t) for t in queued)
        victims = sorted(residents, key=lambda st: self._prio(st[1]),
                         reverse=True)
        out = []
        for p in want:
            if free > 0:               # a free slot serves this admission
                free -= 1
                continue
            if len(out) >= self.max_evictions_per_tick or not victims:
                break
            if self._prio(victims[0][1]) > p:    # strictly less urgent
                out.append(victims.pop(0)[0])
            else:
                break
        return tuple(out)


class ShardedScheduler(Scheduler):
    """Split each tick's batch across mesh devices (pure DP serving).

    Placement maps the leading (batch) dim of the tick array onto the
    mesh axes the ``batch`` logical axis resolves to under
    ``parallel.sharding`` rules (``("pod", "data")`` by default), so the
    jitted forward runs SPMD across the mesh.  Admission, latency
    adaptation and tick phasing delegate to ``inner`` (FIFO unless
    given, so an SLO or interleaving controller composes under
    sharding).

    Workloads:

      * **image** (:class:`repro.serving.CapsuleEngine`) — stateless
        ticks; only the per-tick frame batch is placed, via ``place()``.
      * **LM decode** (:class:`repro.serving.ServeEngine`) — stateful:
        the engine additionally shards its *KV caches* over the mesh at
        construction (the cache ``batch`` axis is the slot axis, so each
        device owns ``capacity / n_devices`` slots end to end) and
        routes the per-tick token/position arrays through ``place()``.
        Engine capacity must divide evenly over the batch-axis devices
        (checked in ``bind``).
    """

    def __init__(self, mesh: Any, inner: Optional[Scheduler] = None,
                 rules: Any = None):
        from repro.parallel import sharding as sharding_lib

        self.mesh = mesh
        self.inner = inner or FIFOScheduler()
        self.rules = rules if rules is not None else sharding_lib.DEFAULT_RULES
        axes = self.rules.lookup("batch", mesh.axis_names)
        axes = (axes,) if isinstance(axes, str) else (axes or ())
        self.n_devices = 1
        for a in axes:
            self.n_devices *= int(mesh.shape[a])

    def bind(self, core: Any) -> None:
        super().bind(core)
        if self.capacity % self.n_devices:
            raise ValueError(
                f"engine capacity {self.capacity} not divisible by the "
                f"{self.n_devices} batch-axis devices of the mesh")
        self.inner.bind(core)

    def plan(self, n_queued: int, n_active: int) -> int:
        return self.inner.plan(n_queued, n_active)

    def phase(self, n_queued: int, n_active: int) -> str:
        return self.inner.phase(n_queued, n_active)

    def quantize(self, n_active: int, capacity: int) -> int:
        b = self.inner.quantize(n_active, capacity)
        b = -(-b // self.n_devices) * self.n_devices     # ceil to multiple
        return min(b, capacity)

    def shapes(self, capacity: int) -> tuple:
        return tuple(sorted({self.quantize(b, capacity)
                             for b in self.inner.shapes(capacity)}))

    def place(self, batch: Any) -> Any:
        import jax
        from jax.sharding import NamedSharding

        from repro.parallel import sharding as sharding_lib

        arr = np.asarray(batch)
        spec = sharding_lib.shape_aware_spec(
            ("batch",) + (None,) * (arr.ndim - 1), arr.shape, self.rules,
            dict(zip(self.mesh.axis_names, self.mesh.devices.shape)))
        return jax.device_put(arr, NamedSharding(self.mesh, spec))

    def observe(self, record: TickRecord) -> None:
        self.inner.observe(record)
