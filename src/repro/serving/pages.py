"""Block-paged KV cache: global page pool, per-slot page tables, prefix
reuse, and quantized pages.

The dense serving cache (``lm.make_caches``) gives every slot a full
``max_len`` KV allocation, so resident-request capacity is bounded by
slot count and shared system prompts re-prefill per request.  This
module replaces that layout with the paged one:

  * **Page pool** — every cache leaf whose :func:`lm.cache_specs` axes
    contain both ``batch`` and ``kv_seq`` is re-shaped so the slot axis
    becomes a *page* axis (``n_pages``) and the sequence axis becomes
    the *within-page* axis (``page_size``).  Leaves without a ``kv_seq``
    axis (e.g. the vlm cross-attention cache) stay dense slot-axis
    "residual" state.  The pool axes keep their logical names, so
    ``parallel.sharding`` rules place pages across a mesh exactly the
    way they place slots (the page axis is the ``batch`` axis).
  * **Page tables** — host-side ``(n_slots, max_len // page_size)``
    int32 maps from slot-local page index to pool page id (``-1`` =
    unmapped).  The traced ops below consume a device copy per tick;
    geometry is static so nothing retraces.
  * **Traced ops** — :meth:`PagePool.build_view` gathers a dense
    ``(n_slots, max_len)`` cache view for ``lm.decode_step``,
    :meth:`PagePool.scatter_decode_rows` writes one decoded row per
    slot back through the table, :meth:`PagePool.write_prefill_pages`
    scatters freshly prefilled rows at page granularity, and
    :meth:`PagePool.make_continuation_caches` materialises a
    dequantized shared-prefix cache for
    :func:`lm.continuation_prefill_step`.  All are pure functions of
    ``(pool arrays, tables)`` reading only init-time metadata, so they
    jit inside the engine tick.
  * **Content-addressed prefix index** — full prompt pages hash as a
    chain (``h_j = sha256(h_{j-1} || tokens_j)`` seeded with the model
    arch, page size, and quantization flag), registered pages are
    never written again (decode writes land at positions past the
    prompt, i.e. in privately-owned pages, so copy-on-write is
    structural rather than copied), and a later request whose chain
    prefix matches pins the shared pages and prefills only its suffix.
  * **Quantized pages** — with ``quantize=True`` KV pools store int8
    with a per-row float32 scale pool alongside (``k`` → ``k_scale``,
    amax/127 per ``(page, position)`` over heads x head_dim —
    ``attention.quantize_kv_rows``).  Prefill computes bf16 and
    quantizes at the page write; decode reads the int8 view and
    dequantizes inside ``attention.self_attention``.

Lifecycle: a page is *free*, *owned* (refcount > 0; each resident or
preempted request holds one reference per page in its table), or
*cached* (refcount 0 but still registered in the prefix index —
evictable in LRU order when the free list runs dry).  Lossless
preemption is a table-row save (pages stay owned, O(1), no device
traffic); a disaggregated handoff exports page payloads and the decode
side re-imports only the pages it doesn't already hold by hash.

Thread-safety: the pool is shared across engine tick threads and the
disaggregated front-end (which pins prefix hits on a *target* engine's
pool while that engine ticks), so all host state is guarded by the
pool's own lock; see the ``guarded-by`` annotations, enforced by
capslint's lock-discipline rule.
"""

from __future__ import annotations

import dataclasses
import hashlib
import threading
from collections import OrderedDict
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import attention
from repro.models import lm

PAGEABLE_FAMILIES = ("dense", "moe", "vlm")


class PagePoolExhausted(RuntimeError):
    """No free or evictable page is left.  Raised by
    :meth:`PagePool.allocate`; the serving engine responds by spilling
    preempted requests' pages to host memory and retrying, so the error
    only propagates when *resident* demand genuinely exceeds the pool."""


@dataclasses.dataclass(frozen=True)
class _PagedLeaf:
    """Init-time metadata for one paged pool leaf (KV or scale)."""

    path: Tuple[str, ...]             # path in the make_caches tree
    key: str                          # "/".join(path): flat pool-dict key
    axes: Tuple[Optional[str], ...]   # logical axes (pool == view names)
    bax: int                          # batch/page axis position
    sax: int                          # kv_seq/within-page axis position
    shape: Tuple[int, ...]            # pool array shape
    dtype: Any                        # pool dtype (int8 when quantized)
    view_dtype: Any                   # dense-view dtype (the model's)
    scale_key: Optional[str] = None   # sibling scale leaf (KV leaves only)
    scale_path: Optional[Tuple[str, ...]] = None
    scale_shape: Optional[Tuple[int, ...]] = None


@dataclasses.dataclass(frozen=True)
class _ResidualLeaf:
    """A cache leaf that stays dense slot-axis (no ``kv_seq`` axis)."""

    path: Tuple[str, ...]
    key: str
    axes: Tuple[Optional[str], ...]
    bax: int                          # batch (slot) axis position
    shape: Tuple[int, ...]
    dtype: Any


def _walk(tree: Any, prefix: Tuple[str, ...] = ()):
    if isinstance(tree, dict):
        for k in sorted(tree):
            yield from _walk(tree[k], prefix + (k,))
    else:
        yield prefix, tree


def _get(tree: Any, path: Sequence[str]) -> Any:
    for k in path:
        tree = tree[k]
    return tree


def _set(tree: Dict[str, Any], path: Sequence[str], val: Any) -> None:
    for k in path[:-1]:
        tree = tree.setdefault(k, {})
    tree[path[-1]] = val


class PagePool:
    """Global page pool + per-slot page tables for one serving engine.

    Splits into two halves that never mix:

      * pure *traced* ops (``build_view`` / ``scatter_decode_rows`` /
        ``write_prefill_pages`` / ``make_continuation_caches`` /
        ``export_pages`` / ``import_pages`` and the residual-row
        helpers) — functions of explicit array arguments plus
        init-time metadata, safe under ``jax.jit``;
      * host *bookkeeping* (allocation, refcounts, the prefix index,
        page tables) — all under ``self._lock``.

    The engine owns the actual pool/residual arrays (so its jitted tick
    can thread them functionally) and calls back here for both halves.
    """

    def __init__(self, cfg, n_slots: int, max_len: int, page_size: int,
                 n_pages: Optional[int] = None, quantize: bool = False):
        if cfg.family not in PAGEABLE_FAMILIES:
            raise ValueError(
                f"paged KV cache requires an attention family "
                f"{PAGEABLE_FAMILIES}, not {cfg.family!r} (recurrent "
                f"state has no kv_seq axis to page)")
        if page_size <= 0 or max_len % page_size != 0:
            raise ValueError(f"page_size={page_size} must be positive and "
                             f"divide max_len={max_len}")
        self.cfg = cfg
        self.n_slots = int(n_slots)
        self.max_len = int(max_len)
        self.page_size = int(page_size)
        self.pages_per_slot = max_len // page_size
        self.n_pages = int(n_pages) if n_pages is not None \
            else self.n_slots * self.pages_per_slot
        if self.n_pages < self.pages_per_slot:
            raise ValueError(
                f"n_pages={self.n_pages} cannot hold even one full slot "
                f"({self.pages_per_slot} pages)")
        self.quantize = bool(quantize)

        specs = lm.cache_specs(cfg)
        structs = lm.make_caches(cfg, n_slots, max_len, as_structs=True)
        self._paged: List[_PagedLeaf] = []
        self._residual: List[_ResidualLeaf] = []
        for path, axes in _walk(specs):
            st = _get(structs, path)
            if "batch" in axes and "kv_seq" in axes:
                bax, sax = axes.index("batch"), axes.index("kv_seq")
                if not (bax < sax and len(axes) == sax + 3):
                    raise ValueError(
                        f"unsupported paged leaf layout {axes} at "
                        f"{'/'.join(path)}")
                shape = list(st.shape)
                shape[bax], shape[sax] = self.n_pages, self.page_size
                kw: Dict[str, Any] = {}
                if self.quantize:
                    kw["scale_path"] = path[:-1] + (path[-1] + "_scale",)
                    kw["scale_key"] = "/".join(kw["scale_path"])
                    kw["scale_shape"] = tuple(shape[:sax + 1])
                self._paged.append(_PagedLeaf(
                    path=path, key="/".join(path), axes=tuple(axes),
                    bax=bax, sax=sax, shape=tuple(shape),
                    dtype=jnp.int8 if self.quantize else st.dtype,
                    view_dtype=st.dtype, **kw))
            else:
                if "batch" not in axes:
                    raise ValueError(
                        f"cache leaf {'/'.join(path)} has neither a "
                        f"batch nor kv_seq axis; cannot page or slot it")
                self._residual.append(_ResidualLeaf(
                    path=path, key="/".join(path), axes=tuple(axes),
                    bax=axes.index("batch"), shape=tuple(st.shape),
                    dtype=st.dtype))
        if not self._paged:
            raise ValueError(f"{cfg.family} cache has no pageable leaves")

        # chain-hash seed: two pools agree on page hashes iff they agree
        # on the model, the page geometry, and the page representation
        self._hash_seed = hashlib.sha256(
            f"{cfg.arch_id}|{self.page_size}|{int(self.quantize)}"
            .encode()).digest()

        self._lock = threading.Lock()
        self._free: List[int] = list(       # guarded-by: _lock
            range(self.n_pages))
        self._refs = np.zeros(              # guarded-by: _lock
            (self.n_pages,), np.int32)
        self._prefix_index: Dict[bytes, int] = {}   # guarded-by: _lock
        self._page_hash: Dict[int, bytes] = {}      # guarded-by: _lock
        self._evictable: "OrderedDict[int, None]" \
            = OrderedDict()                 # guarded-by: _lock
        self._tables = np.full(             # guarded-by: _lock
            (self.n_slots, self.pages_per_slot), -1, np.int32)
        self._counters: Dict[str, int] = {  # guarded-by: _lock
            "allocated": 0, "freed": 0, "cache_evicted": 0,
            "registered": 0, "pinned": 0}
        self._n_blocks = 1                  # guarded-by: _lock

    # -- geometry / array construction (no host state) ---------------------

    def init_pool_arrays(self) -> Dict[str, jax.Array]:
        """Zeroed pool arrays, one flat dict entry per paged leaf (plus
        its scale sibling when quantized)."""
        out: Dict[str, jax.Array] = {}
        for lf in self._paged:
            out[lf.key] = jnp.zeros(lf.shape, lf.dtype)
            if lf.scale_key is not None:
                out[lf.scale_key] = jnp.zeros(lf.scale_shape, jnp.float32)
        return out

    def init_residual_arrays(self) -> Dict[str, jax.Array]:
        """Zeroed dense slot-axis arrays for the non-paged leaves."""
        return {rl.key: jnp.zeros(rl.shape, rl.dtype)
                for rl in self._residual}

    def pool_specs(self) -> Dict[str, Tuple[Optional[str], ...]]:
        """Logical-axis dict matching :meth:`init_pool_arrays` — the page
        axis keeps the name ``batch``, so ``sharding.shardings_for``
        places pages across a mesh the same way it places slots."""
        out: Dict[str, Tuple[Optional[str], ...]] = {}
        for lf in self._paged:
            out[lf.key] = lf.axes
            if lf.scale_key is not None:
                out[lf.scale_key] = lf.axes[:lf.sax + 1]
        return out

    def residual_specs(self) -> Dict[str, Tuple[Optional[str], ...]]:
        return {rl.key: rl.axes for rl in self._residual}

    def _all_paged(self) -> List[Tuple[str, Tuple[str, ...], int, int]]:
        """(key, view path, bax, sax) for every pool leaf, scales
        included — the leaves traced gathers/scatters iterate."""
        out = []
        for lf in self._paged:
            out.append((lf.key, lf.path, lf.bax, lf.sax))
            if lf.scale_key is not None:
                out.append((lf.scale_key, lf.scale_path, lf.bax, lf.sax))
        return out

    def page_payload_struct(self, n: int) -> Dict[str, jax.ShapeDtypeStruct]:
        """Expected :meth:`export_pages` payload geometry for ``n`` pages
        (page axis leading) — what a decode engine validates a paged
        handoff against."""
        out: Dict[str, jax.ShapeDtypeStruct] = {}
        for lf in self._paged:
            moved = [lf.shape[lf.bax]] + [s for i, s in enumerate(lf.shape)
                                          if i != lf.bax]
            out[lf.key] = jax.ShapeDtypeStruct((n,) + tuple(moved[1:]),
                                               lf.dtype)
            if lf.scale_key is not None:
                smoved = [s for i, s in enumerate(lf.scale_shape)
                          if i != lf.bax]
                out[lf.scale_key] = jax.ShapeDtypeStruct(
                    (n,) + tuple(smoved), jnp.float32)
        return out

    # -- traced ops (pure; safe under jit) ---------------------------------

    def _gather_pages(self, arr: jax.Array, tv: jax.Array, bax: int,
                      sax: int) -> jax.Array:
        """Gather table rows ``tv`` (B, P) of pool leaf ``arr`` into a
        dense (B, P * page_size) sequence at the leaf's own axis
        positions.  ``tv`` must be pre-clipped to valid page ids."""
        pm = jnp.moveaxis(arr, (bax, sax), (0, 1))
        g = pm[tv]
        g = g.reshape((tv.shape[0], tv.shape[1] * self.page_size)
                      + pm.shape[2:])
        return jnp.moveaxis(g, (0, 1), (bax, sax))

    def build_view(self, pool: Dict[str, jax.Array],
                   residual: Dict[str, jax.Array], tables: jax.Array,
                   dequant: bool = False) -> Dict[str, Any]:
        """Assemble the dense ``(n_slots, max_len)`` cache-view pytree
        ``lm.decode_step`` consumes.  Unmapped table entries clip to
        page 0 — their rows are garbage, masked by the attention
        ``kv_valid_len`` (positions past a slot's write head contribute
        exact zeros).  With ``dequant=True`` a quantized pool yields a
        bf16 view without scale leaves; by default the int8 + scale
        leaves pass through for dequant-on-read in the attention."""
        tv = jnp.clip(jnp.asarray(tables, jnp.int32), 0, self.n_pages - 1)
        view: Dict[str, Any] = {}
        for lf in self._paged:
            g = self._gather_pages(pool[lf.key], tv, lf.bax, lf.sax)
            if lf.scale_key is not None:
                gs = self._gather_pages(pool[lf.scale_key], tv, lf.bax,
                                        lf.sax)
                if dequant:
                    g = attention.dequantize_kv(g, gs, lf.view_dtype)
                else:
                    _set(view, lf.scale_path, gs)
            _set(view, lf.path, g)
        for rl in self._residual:
            _set(view, rl.path, residual[rl.key])
        return view

    def pool_tree(self, pool: Dict[str, jax.Array],
                  residual: Dict[str, jax.Array]) -> Dict[str, Any]:
        """Assemble the cache pytree ``lm.decode_step`` consumes with the
        *pool* leaves passed through untouched — no gather.  Paired with
        ``paged_tables``, the decode_attention kernel reads pages through
        the per-slot tables via scalar prefetch and writes the fresh row
        straight into its page, so the dense ``(n_slots, max_len)`` view
        is never materialized."""
        view: Dict[str, Any] = {}
        for lf in self._paged:
            _set(view, lf.path, pool[lf.key])
            if lf.scale_key is not None:
                _set(view, lf.scale_path, pool[lf.scale_key])
        for rl in self._residual:
            _set(view, rl.path, residual[rl.key])
        return view

    def pool_untree(self, tree: Dict[str, Any]
                    ) -> Tuple[Dict[str, jax.Array], Dict[str, jax.Array]]:
        """Inverse of :meth:`pool_tree`: split an updated cache pytree
        back into the flat (pool, residual) dicts."""
        pool: Dict[str, jax.Array] = {}
        for lf in self._paged:
            pool[lf.key] = _get(tree, lf.path)
            if lf.scale_key is not None:
                pool[lf.scale_key] = _get(tree, lf.scale_path)
        residual = {rl.key: _get(tree, rl.path) for rl in self._residual}
        return pool, residual

    def scatter_decode_rows(self, pool: Dict[str, jax.Array],
                            new_view: Dict[str, Any], tables: jax.Array,
                            pos: jax.Array) -> Dict[str, jax.Array]:
        """Write each slot's decoded row (position ``pos[b]``) from the
        updated view back into its mapped page.  Slots whose page is
        unmapped (table ``-1`` — idle slots) route to an out-of-bounds
        sentinel and drop: negative indices would *wrap* in jax scatter,
        so the sentinel mapping is load-bearing."""
        tables = jnp.asarray(tables, jnp.int32)
        b = tables.shape[0]
        pidx = pos // self.page_size
        off_in = pos % self.page_size
        pid = tables[jnp.arange(b), pidx]
        pid = jnp.where(pid < 0, self.n_pages, pid)
        new_pool = dict(pool)
        for key, path, bax, sax in self._all_paged():
            vm = jnp.moveaxis(_get(new_view, path), (bax, sax), (0, 1))
            row = vm[jnp.arange(b), pos]
            pm = jnp.moveaxis(new_pool[key], (bax, sax), (0, 1))
            pm = pm.at[pid, off_in].set(row.astype(pm.dtype), mode="drop")
            new_pool[key] = jnp.moveaxis(pm, (0, 1), (bax, sax))
        return new_pool

    def write_prefill_pages(self, pool: Dict[str, jax.Array],
                            sub_caches: Dict[str, Any],
                            page_map: jax.Array, off: int
                            ) -> Dict[str, jax.Array]:
        """Scatter freshly prefilled rows into the pool at page
        granularity.  ``sub_caches`` is the (bf16) cache tree a prefill
        step just wrote (kv_seq length >= ``off + npg * page_size``);
        ``page_map`` (nb, npg) maps each batch row's page-aligned span
        starting at ``off`` to pool page ids, with the out-of-bounds
        sentinel ``n_pages`` marking pad rows / unallocated tail pages
        (dropped).  Quantized pools quantize per row here — the one
        place prefilled state crosses from bf16 into int8."""
        nb, npg = page_map.shape
        ps = self.page_size
        flat = jnp.asarray(page_map, jnp.int32).reshape(-1)
        new_pool = dict(pool)
        for lf in self._paged:
            sm = jnp.moveaxis(_get(sub_caches, lf.path), (lf.bax, lf.sax),
                              (0, 1))
            span = jax.lax.slice_in_dim(sm, off, off + npg * ps, axis=1)
            rows = span.reshape((nb * npg, ps) + sm.shape[2:])
            pm = jnp.moveaxis(new_pool[lf.key], (lf.bax, lf.sax), (0, 1))
            if lf.scale_key is not None:
                q, sc = attention.quantize_kv_rows(rows)
                pm = pm.at[flat].set(q, mode="drop")
                sp = jnp.moveaxis(new_pool[lf.scale_key],
                                  (lf.bax, lf.sax), (0, 1))
                sp = sp.at[flat].set(sc, mode="drop")
                new_pool[lf.scale_key] = jnp.moveaxis(sp, (0, 1),
                                                      (lf.bax, lf.sax))
            else:
                pm = pm.at[flat].set(rows.astype(pm.dtype), mode="drop")
            new_pool[lf.key] = jnp.moveaxis(pm, (0, 1), (lf.bax, lf.sax))
        return new_pool

    def make_continuation_caches(self, pool: Dict[str, jax.Array],
                                 prefix_tables: jax.Array, nb: int,
                                 total_len: int) -> Dict[str, Any]:
        """A fresh ``lm.make_caches(cfg, nb, total_len)`` tree whose
        first ``prefix_tables.shape[1] * page_size`` rows hold the
        (dequantized) shared-prefix pages — the cache
        :func:`lm.continuation_prefill_step` continues from."""
        ps = self.page_size
        off = prefix_tables.shape[1] * ps
        fresh = lm.make_caches(self.cfg, nb, total_len)
        tv = jnp.clip(jnp.asarray(prefix_tables, jnp.int32), 0,
                      self.n_pages - 1)
        out: Dict[str, Any] = {}
        for lf in self._paged:
            g = self._gather_pages(pool[lf.key], tv, lf.bax, lf.sax)
            if lf.scale_key is not None:
                gs = self._gather_pages(pool[lf.scale_key], tv, lf.bax,
                                        lf.sax)
                g = attention.dequantize_kv(g, gs, lf.view_dtype)
            base = jnp.moveaxis(_get(fresh, lf.path), (lf.bax, lf.sax),
                                (0, 1))
            gm = jnp.moveaxis(g, (lf.bax, lf.sax), (0, 1))
            base = base.at[:, :off].set(gm.astype(base.dtype))
            _set(out, lf.path, jnp.moveaxis(base, (0, 1), (lf.bax, lf.sax)))
        for rl in self._residual:
            # serving prompts carry no image features: the residual
            # (vlm cross) cache is zeros, matching the unified engine
            _set(out, rl.path, _get(fresh, rl.path))
        return out

    def residual_rows_from(self, sub_caches: Dict[str, Any]
                           ) -> Dict[str, jax.Array]:
        """Flat residual-leaf dict extracted from a full cache tree."""
        return {rl.key: _get(sub_caches, rl.path) for rl in self._residual}

    def gather_residual_rows(self, residual: Dict[str, jax.Array],
                             slot_idx: jax.Array) -> Dict[str, jax.Array]:
        return {rl.key: jnp.take(residual[rl.key],
                                 jnp.asarray(slot_idx, jnp.int32),
                                 axis=rl.bax)
                for rl in self._residual}

    def concat_residual_rows(self, rows_list: Sequence[Dict[str, Any]]
                             ) -> Dict[str, jax.Array]:
        """Concatenate per-slot residual-row dicts along the slot axis —
        one batched scatter for a whole handoff group."""
        return {rl.key: jnp.concatenate(
                    [jnp.asarray(r[rl.key]) for r in rows_list],
                    axis=rl.bax)
                for rl in self._residual}

    def residual_rows_struct(self, n: int) -> Dict[str, jax.ShapeDtypeStruct]:
        """Expected residual-row geometry for ``n`` slots — the other
        half of a paged handoff's validation signature."""
        out: Dict[str, jax.ShapeDtypeStruct] = {}
        for rl in self._residual:
            shape = list(rl.shape)
            shape[rl.bax] = n
            out[rl.key] = jax.ShapeDtypeStruct(tuple(shape), rl.dtype)
        return out

    def scatter_residual_rows(self, residual: Dict[str, jax.Array],
                              rows: Dict[str, jax.Array],
                              slot_idx: jax.Array) -> Dict[str, jax.Array]:
        """Write per-slot residual rows; out-of-range ``slot_idx``
        (pad entries = ``n_slots``) drop."""
        new = dict(residual)
        idx = jnp.asarray(slot_idx, jnp.int32)
        for rl in self._residual:
            pm = jnp.moveaxis(new[rl.key], rl.bax, 0)
            rm = jnp.moveaxis(rows[rl.key], rl.bax, 0)
            pm = pm.at[idx].set(rm.astype(pm.dtype), mode="drop")
            new[rl.key] = jnp.moveaxis(pm, 0, rl.bax)
        return new

    def export_pages(self, pool: Dict[str, jax.Array],
                     page_ids: Sequence[int]) -> Dict[str, jax.Array]:
        """Copy the given pages out (page axis leading per leaf) — the
        transferable payload of a handoff or a preemption spill."""
        ids = jnp.asarray(np.asarray(page_ids, np.int32))
        return {key: jnp.take(jnp.moveaxis(pool[key], bax, 0), ids, axis=0)
                for key, _, bax, _ in self._all_paged()}

    def import_pages(self, pool: Dict[str, jax.Array],
                     payload: Dict[str, Any], page_ids: Sequence[int]
                     ) -> Dict[str, jax.Array]:
        """Write an :meth:`export_pages` payload into the given pages."""
        ids = jnp.asarray(np.asarray(page_ids, np.int32))
        new = dict(pool)
        for key, _, bax, _ in self._all_paged():
            pm = jnp.moveaxis(new[key], bax, 0)
            pm = pm.at[ids].set(jnp.asarray(payload[key]).astype(pm.dtype))
            new[key] = jnp.moveaxis(pm, 0, bax)
        return new

    @staticmethod
    def take_payload(payload: Dict[str, Any], idx: Sequence[int]
                     ) -> Dict[str, Any]:
        """Subset an :meth:`export_pages` payload by page position —
        how a handoff sheds pages its target already holds."""
        ii = np.asarray(idx, np.int32)
        return {k: jnp.take(jnp.asarray(v), ii, axis=0)
                for k, v in payload.items()}

    # -- content-addressed prefix hashing (pure) ---------------------------

    def chain_hashes(self, prompt: Sequence[int]) -> List[bytes]:
        """Chained page hashes of the prompt's *full* pages, capped so at
        least one suffix token always remains to prefill (the request
        must still produce its own first-token logits)."""
        n = (len(prompt) - 1) // self.page_size
        out: List[bytes] = []
        h = self._hash_seed
        for j in range(n):
            m = hashlib.sha256(h)
            m.update(np.asarray(
                prompt[j * self.page_size:(j + 1) * self.page_size],
                np.int64).tobytes())
            h = m.digest()
            out.append(h)
        return out

    # -- host bookkeeping (guarded) ----------------------------------------

    @property
    def free_pages(self) -> int:
        """Pages allocatable right now (free + evictable cached)."""
        with self._lock:
            return len(self._free) + len(self._evictable)

    @property
    def total_pages(self) -> int:
        return self.n_pages

    def counters(self) -> Dict[str, int]:
        with self._lock:
            return dict(self._counters)

    def set_device_blocks(self, n: int) -> None:
        """Partition pages into ``n`` contiguous blocks matching the
        sharded page-axis layout; allocation then prefers a slot's own
        block so slot-local decode gather/scatter stays device-local."""
        with self._lock:
            self._n_blocks = max(1, int(n))

    def _block_of_locked(self, page: int) -> int:
        return page * self._n_blocks // self.n_pages

    def _slot_block_locked(self, slot: int) -> int:
        return slot * self._n_blocks // self.n_slots

    def _take_one_locked(self, block: int) -> int:
        if self._free:
            if self._n_blocks > 1:
                for i, p in enumerate(self._free):
                    if self._block_of_locked(p) == block:
                        return self._free.pop(i)
            return self._free.pop()
        if self._evictable:
            pick = None
            if self._n_blocks > 1:
                for p in self._evictable:
                    if self._block_of_locked(p) == block:
                        pick = p
                        break
            if pick is None:
                pick = next(iter(self._evictable))   # LRU head
            del self._evictable[pick]
            h = self._page_hash.pop(pick, None)
            if h is not None:
                self._prefix_index.pop(h, None)
            self._counters["cache_evicted"] += 1
            return pick
        raise PagePoolExhausted(
            f"page pool exhausted: {self.n_pages} pages all owned "
            f"(resident + preempted demand exceeds the pool; raise "
            f"n_pages or admit less)")

    def allocate(self, n: int, slot: int = 0) -> List[int]:
        """Take ``n`` pages (refcount 1 each), evicting cached pages LRU
        when the free list is dry.  Raises :class:`PagePoolExhausted`
        atomically — on failure nothing is taken."""
        with self._lock:
            block = self._slot_block_locked(slot % max(self.n_slots, 1))
            if n > len(self._free) + len(self._evictable):
                raise PagePoolExhausted(
                    f"page pool exhausted: need {n} pages, "
                    f"{len(self._free) + len(self._evictable)} available "
                    f"of {self.n_pages}")
            out = [self._take_one_locked(block) for _ in range(n)]
            for p in out:
                self._refs[p] = 1
            self._counters["allocated"] += n
            return out

    def retain(self, pages: Sequence[int]) -> None:
        with self._lock:
            for p in pages:
                self._retain_one_locked(p)

    def _retain_one_locked(self, p: int) -> None:
        if self._refs[p] == 0:
            # cached -> owned again
            self._evictable.pop(p, None)
        self._refs[p] += 1

    def release(self, pages: Sequence[int]) -> None:
        """Drop one reference per page.  A page reaching refcount 0
        stays *cached* (evictable, still a prefix-index hit) when it is
        registered, else returns to the free list."""
        with self._lock:
            for p in pages:
                if self._refs[p] <= 0:
                    raise ValueError(f"release of unowned page {p}")
                self._refs[p] -= 1
                if self._refs[p] == 0:
                    if p in self._page_hash:
                        self._evictable[p] = None
                        self._evictable.move_to_end(p)
                    else:
                        self._free.append(p)
                    self._counters["freed"] += 1

    def register_hash(self, page: int, h: bytes) -> None:
        """Publish a full, never-again-written page into the prefix
        index.  First writer wins; a duplicate hash keeps the existing
        entry (the new page simply stays private)."""
        with self._lock:
            if h in self._prefix_index or page in self._page_hash:
                return
            self._prefix_index[h] = page
            self._page_hash[page] = h
            self._counters["registered"] += 1

    def acquire_prefix(self, hashes: Sequence[bytes]) -> List[int]:
        """Pin the longest indexed chain prefix; returns the pinned page
        ids (one reference each, in page order)."""
        return self.extend_prefix(hashes, 0)

    def extend_prefix(self, hashes: Sequence[bytes], start: int
                      ) -> List[int]:
        """Continue :meth:`acquire_prefix` from chain position ``start``
        — used when a same-tick sibling registered more pages since the
        first lookup."""
        with self._lock:
            out: List[int] = []
            for h in hashes[start:] if start else hashes:
                p = self._prefix_index.get(h)
                if p is None:
                    break
                self._retain_one_locked(p)
                self._counters["pinned"] += 1
                out.append(p)
            return out

    def pin_hashes(self, hashes: Sequence[Optional[bytes]]
                   ) -> Dict[int, int]:
        """Pin every individually indexed hash (no chain-prefix rule):
        ``{position: page}`` for the hits, each retained.  The
        disaggregated front-end calls this on the *target* pool to
        compute which handoff pages need not travel; a failed delivery
        must :meth:`release` the returned pages."""
        with self._lock:
            out: Dict[int, int] = {}
            for i, h in enumerate(hashes):
                if h is None:
                    continue
                p = self._prefix_index.get(h)
                if p is None:
                    continue
                self._retain_one_locked(p)
                self._counters["pinned"] += 1
                out[i] = p
            return out

    # -- page tables (guarded) ---------------------------------------------

    def bind_slot(self, slot: int, pages: Sequence[int]) -> None:
        """Map a slot's table row to ``pages`` (slot-local order,
        contiguous from page index 0); the rest unmapped."""
        if len(pages) > self.pages_per_slot:
            raise ValueError(f"{len(pages)} pages exceed the "
                             f"{self.pages_per_slot}-page slot table")
        with self._lock:
            self._tables[slot, :] = -1
            self._tables[slot, :len(pages)] = np.asarray(pages, np.int32) \
                if pages else np.empty((0,), np.int32)

    def set_slot_page(self, slot: int, idx: int, page: int) -> None:
        with self._lock:
            self._tables[slot, idx] = page

    def page_at(self, slot: int, idx: int) -> int:
        with self._lock:
            return int(self._tables[slot, idx])

    def slot_pages(self, slot: int) -> List[int]:
        """The slot's mapped pages in slot-local order."""
        with self._lock:
            row = self._tables[slot]
            return [int(p) for p in row[row >= 0]]

    def slot_page_hashes(self, slot: int) -> List[Optional[bytes]]:
        """Per mapped page, its prefix-index hash (None for private
        pages) — what a handoff advertises for dedup."""
        with self._lock:
            row = self._tables[slot]
            return [self._page_hash.get(int(p)) for p in row[row >= 0]]

    def unbind_slot(self, slot: int) -> List[int]:
        """Clear the slot's table row, returning its pages *without*
        releasing them (preemption keeps ownership; retirement follows
        with :meth:`release`)."""
        with self._lock:
            row = self._tables[slot]
            pages = [int(p) for p in row[row >= 0]]
            self._tables[slot, :] = -1
            return pages

    def tables_snapshot(self) -> np.ndarray:
        with self._lock:
            return self._tables.copy()
