"""Pluggable :class:`CacheHandoff` delivery between serving engines.

PR 5's disaggregated handoff moved cache rows implicitly: the prefill
engine gathered them, the front-end passed the pytree by reference, and
the decode engine's scatter pulled whatever placement the rows happened
to have.  That is the *in-process* transport — correct, but it hides the
transfer on the decode critical path, which is exactly the bottleneck
FastCaps avoids on FPGA by co-designing the whole pipeline instead of
accelerating one stage.  This module makes the transfer a typed,
measured, swappable stage:

  * :class:`Transport` — the contract.  ``deliver(handoff, target)``
    moves ``handoff.rows`` into the target engine's memory space and
    returns a :class:`TransferRecord` with per-leg wall-clock timings.
    Delivery is all-or-nothing: ``handoff.rows`` is reassigned only on
    success, so a failed delivery never leaves a half-moved pytree and
    the front-end can requeue the handoff onto a surviving route.
    ``close()`` is idempotent; delivering through a closed transport
    raises :class:`TransportError`.
  * :class:`InProcessTransport` — today's behavior, made explicit: rows
    pass through untouched (one ``pass`` leg, ~0 cost).  The right
    choice when prefill and decode share a device.
  * :class:`HostStagedTransport` — explicit device -> host -> device
    staging with per-leg timing (``d2h``, ``h2d``), both legs blocking.
    This is the portable route between engines with no common
    addressable device space — and the yardstick the overlapped
    transport is measured against: its cost sits fully on the decode
    critical path.
  * :class:`DeviceToDeviceTransport` — ``jax.device_put`` across meshes
    with **async dispatch** (one ``dispatch`` leg): the copy is enqueued
    onto the target placement and *not* blocked on, so it overlaps with
    decode ticks already in flight.  The recorded critical-path cost is
    dispatch only — handoff cost vanishes from the decode loop, the
    CapsAcc point (throughput comes from keeping intermediate state
    on-device between stages) made measurable.

Per-leg timings land in ``EngineStats.transfer`` as
``"<transport>/<leg>"`` histograms plus a ``"<transport>/total"``
critical-path histogram when a :class:`repro.serving.DisaggregatedEngine`
drives the transport (the PR-5 ``"handoff"`` queue-wait histogram is
unchanged).  Every transport also keeps its own bounded ring of
:class:`TransferRecord`\\ s and an optional ``on_transfer`` hook — the
conformance suite's observability surface.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from typing import Any, Callable, Deque, Dict, Optional

import jax
import numpy as np

from repro.models.lm import cache_row_nbytes

__all__ = [
    "TransferRecord", "Transport", "TransportError",
    "InProcessTransport", "HostStagedTransport", "DeviceToDeviceTransport",
    "TRANSPORTS", "make_transport", "select_transport", "target_mesh",
]


class TransportError(RuntimeError):
    """A transport could not deliver a handoff (closed, or the move
    itself failed).  The front-end treats it like an engine death: the
    handoff requeues onto a surviving route, never dropped."""


@dataclasses.dataclass(frozen=True)
class TransferRecord:
    """One delivered handoff, as the transport saw it.

    ``legs`` maps leg name -> seconds of *critical-path* wall-clock (the
    time ``deliver`` spent before returning — an async dispatch leg
    records only the enqueue cost, which is the whole point).  ``nbytes``
    is the payload size (0 for row-less done/stateless handoffs)."""

    transport: str
    rid: int
    legs: Dict[str, float]
    nbytes: int = 0

    @property
    def total_s(self) -> float:
        """Critical-path seconds this delivery cost the front-end."""
        return float(sum(self.legs.values()))


def target_mesh(target: Any):
    """The mesh a delivery target decodes on, or ``None``.

    Engines expose placement through their scheduler
    (:class:`repro.serving.ShardedScheduler` carries ``.mesh``); plain
    single-device engines have no mesh and rows go to the default
    device."""
    return getattr(getattr(target, "scheduler", None), "mesh", None)


class Transport:
    """Base contract for moving :class:`repro.serving.CacheHandoff` rows
    between a prefill engine and a decode engine.

    Subclasses implement :meth:`_move`; everything else — close
    semantics, record keeping, the all-or-nothing rows swap — is shared
    so every implementation satisfies the same conformance suite.
    ``clock`` is injectable for deterministic tests."""

    name = "base"
    #: leg names this transport records for a rows-carrying delivery, in
    #: order — the conformance suite pins them as part of the contract
    LEGS: tuple = ()

    def __init__(self,
                 on_transfer: Optional[Callable[[TransferRecord], None]] = None,
                 clock: Callable[[], float] = time.perf_counter,
                 keep_records: int = 256):
        self._on_transfer = on_transfer
        self._clock = clock
        self._lock = threading.Lock()
        self._closed = False                        # guarded-by: _lock
        self._records: Deque[TransferRecord] = (    # guarded-by: _lock
            deque(maxlen=keep_records))

    # -- contract ----------------------------------------------------------

    def deliver(self, handoff: Any, target: Any) -> TransferRecord:
        """Move ``handoff.rows`` into ``target``'s memory space.

        Returns the :class:`TransferRecord`.  ``handoff.rows`` is
        reassigned only when the whole move succeeded; on any failure
        the handoff is exactly as it was, so the caller can retry it on
        another route.  Raises :class:`TransportError` when closed."""
        with self._lock:
            if self._closed:
                raise TransportError(
                    f"{self.name} transport is closed; cannot deliver "
                    f"handoff rid={getattr(handoff, 'rid', '?')}")
        rows = getattr(handoff, "rows", None)
        if rows is None:              # done/stateless handoff: nothing moves
            legs: Dict[str, float] = {}
            nbytes = 0
        else:
            nbytes = cache_row_nbytes(rows)
            moved, legs = self._move(rows, target)
            handoff.rows = moved      # all-or-nothing: only on success
        rec = TransferRecord(transport=self.name,
                             rid=int(getattr(handoff, "rid", -1)),
                             legs=legs, nbytes=nbytes)
        with self._lock:
            self._records.append(rec)
        if self._on_transfer is not None:
            self._on_transfer(rec)
        return rec

    def _move(self, rows: Any, target: Any):
        """Move one rows pytree; returns ``(moved_rows, legs)``.
        Subclass hook — must not mutate ``rows`` in place."""
        raise NotImplementedError

    def close(self) -> None:
        """Idempotent: after the first call every ``deliver`` raises
        :class:`TransportError`; closing again is a no-op."""
        with self._lock:
            self._closed = True

    @property
    def closed(self) -> bool:
        with self._lock:
            return self._closed

    @property
    def records(self):
        """Snapshot of the most recent :class:`TransferRecord`\\ s, in
        delivery order (bounded ring, ``keep_records`` deep)."""
        with self._lock:
            return list(self._records)

    def _target_shardings(self, rows: Any, target: Any):
        """Replicated shardings on the target's mesh, or ``None`` when
        the target has no mesh (rows then go to the default device)."""
        mesh = target_mesh(target)
        if mesh is None:
            return None
        from repro.parallel.sharding import replicated_shardings

        return replicated_shardings(rows, mesh)


class InProcessTransport(Transport):
    """Rows stay exactly where the prefill engine left them — the
    pre-transport behavior, now explicit and measured.  Correct whenever
    both engines address the same devices (the decode engine's own
    ``_place_rows`` still replicates onto its mesh at injection)."""

    name = "in_process"
    LEGS = ("pass",)

    def _move(self, rows: Any, target: Any):
        t0 = self._clock()
        return rows, {"pass": max(self._clock() - t0, 0.0)}


class HostStagedTransport(Transport):
    """Explicit device -> host -> device staging, both legs blocking.

    ``d2h`` copies every leaf to host memory (``np.asarray`` forces the
    device sync); ``h2d`` puts the host copy onto the target's mesh (or
    default device) and blocks until the copy lands.  The whole round
    trip sits on the decode critical path — this is the portable
    baseline the overlapped transport is measured against."""

    name = "host_staged"
    LEGS = ("d2h", "h2d")

    def _move(self, rows: Any, target: Any):
        t0 = self._clock()
        host = jax.tree.map(np.asarray, rows)
        t1 = self._clock()
        shardings = self._target_shardings(host, target)
        if shardings is None:
            staged = jax.device_put(host)
        else:
            staged = jax.device_put(host, shardings)
        staged = jax.block_until_ready(staged)
        t2 = self._clock()
        return staged, {"d2h": max(t1 - t0, 0.0), "h2d": max(t2 - t1, 0.0)}


class DeviceToDeviceTransport(Transport):
    """``jax.device_put`` across meshes, overlapped with decode ticks.

    The copy is *dispatched* onto the target placement and not blocked
    on: XLA's async copy engine moves the rows while the decode engines
    keep ticking, and the scatter that eventually consumes them
    synchronizes naturally.  The recorded ``dispatch`` leg is the only
    cost the front-end pays — with this transport the handoff transfer
    vanishes from the decode critical path (the acceptance yardstick in
    ``BENCH_fig1_transport.json``)."""

    name = "device_to_device"
    LEGS = ("dispatch",)

    def _move(self, rows: Any, target: Any):
        t0 = self._clock()
        shardings = self._target_shardings(rows, target)
        if shardings is None:
            moved = jax.device_put(rows)
        else:
            moved = jax.device_put(rows, shardings)
        # deliberately no block_until_ready: the whole point is overlap
        return moved, {"dispatch": max(self._clock() - t0, 0.0)}


TRANSPORTS: Dict[str, type] = {
    InProcessTransport.name: InProcessTransport,
    HostStagedTransport.name: HostStagedTransport,
    DeviceToDeviceTransport.name: DeviceToDeviceTransport,
}


def make_transport(kind: str, **kwargs: Any) -> Transport:
    """Build a transport by name (``in_process`` / ``host_staged`` /
    ``device_to_device``); kwargs forward to the constructor."""
    try:
        cls = TRANSPORTS[kind]
    except KeyError:
        raise ValueError(
            f"unknown transport {kind!r}; choose from "
            f"{sorted(TRANSPORTS)}") from None
    return cls(**kwargs)


def select_transport(prefill: Any, decodes: Any, **kwargs: Any) -> Transport:
    """Auto-selection: device-to-device when any decode engine owns a
    mesh distinct from the prefill engine's (the multi-host shape —
    rows must actually move), else in-process (shared device space;
    nothing to stage)."""
    pre_mesh = target_mesh(prefill) if prefill is not None else None
    for eng in decodes or ():
        mesh = target_mesh(eng)
        if mesh is not None and mesh is not pre_mesh:
            return DeviceToDeviceTransport(**kwargs)
    return InProcessTransport(**kwargs)
