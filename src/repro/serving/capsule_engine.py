"""CapsuleEngine: batched CapsNet image serving (the ServeEngine analogue).

The paper's throughput story (Fig. 1: 82 -> 1351 FPS) is a *served*
workload, not a bare jit loop.  This engine serves image-classification
requests through one fixed-shape jitted forward:

* **Request queue** — requests carry a ragged number of frames; the engine
  flattens them into a frame queue.
* **Slot recycling / padding-to-batch** — every tick packs exactly
  ``batch_size`` frame slots: frames from different requests share a batch
  (recycling slots freed by completed requests), and the final partial
  batch is zero-padded so the compiled executable never changes shape
  (the same shape-stability posture as ``ServeEngine``'s decode step).
* **FPS / latency stats** — cumulative frames, batches, padding waste and
  wall-clock, plus per-request latency from submit to completion.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Deque, Dict, List, Optional

import jax
import numpy as np


@dataclasses.dataclass
class ImageRequest:
    """A batch-of-frames classification request (ragged ``images`` count).

    ``rid=None`` lets the engine assign the next free id at submit time.
    """

    images: np.ndarray                # (n_frames, H, W, C)
    rid: Optional[int] = None


@dataclasses.dataclass
class ImageCompletion:
    rid: int
    classes: np.ndarray               # (n_frames,) int32 predictions
    lengths: np.ndarray               # (n_frames, n_classes) capsule lengths
    latency_s: float                  # submit -> completion wall-clock


@dataclasses.dataclass
class EngineStats:
    """Cumulative over the engine's lifetime (monotone non-decreasing)."""

    frames: int = 0                   # real frames served
    padded_frames: int = 0            # zero-pad waste
    batches: int = 0
    wall_s: float = 0.0               # time spent in forward ticks

    @property
    def fps(self) -> float:
        return self.frames / self.wall_s if self.wall_s > 0 else 0.0

    @property
    def ms_per_batch(self) -> float:
        return 1e3 * self.wall_s / self.batches if self.batches else 0.0


class CapsuleEngine:
    """Fixed-shape micro-batched inference over a :class:`DeployedCapsNet`.

    ``deployed`` is any object with ``cfg`` (a CapsNetConfig) and
    ``forward(images) -> lengths`` — in practice the artifact returned by
    ``FastCapsPipeline.compile``.
    """

    def __init__(self, deployed: Any, batch_size: int = 32):
        self.deployed = deployed
        self.batch_size = batch_size
        cfg = deployed.cfg
        self._frame_shape = (cfg.image_hw, cfg.image_hw, cfg.in_channels)
        self._queue: Deque[ImageRequest] = deque()
        self._submit_t: Dict[int, float] = {}
        self._stats = EngineStats()
        self._next_rid = 0

    # -- request intake ----------------------------------------------------

    def submit(self, request: ImageRequest) -> int:
        """Enqueue one request; returns its rid (assigned if unset)."""
        imgs = np.asarray(request.images, np.float32)
        if imgs.ndim != 4 or imgs.shape[1:] != self._frame_shape:
            raise ValueError(
                f"request images must be (n,) + {self._frame_shape}, got "
                f"{imgs.shape}")
        if request.rid is None:
            request.rid = self._next_rid
            self._next_rid += 1
        elif request.rid >= self._next_rid:
            self._next_rid = request.rid + 1     # keep auto ids collision-free
        if request.rid in self._submit_t:
            raise ValueError(f"duplicate rid {request.rid}")
        request.images = imgs
        self._queue.append(request)
        self._submit_t[request.rid] = time.perf_counter()
        return request.rid

    def warmup(self) -> None:
        """Compile the fixed-shape executable outside the measured path."""
        dummy = np.zeros((self.batch_size,) + self._frame_shape, np.float32)
        jax.block_until_ready(self.deployed.forward(dummy))

    # -- serving loop ------------------------------------------------------

    def run(self) -> List[ImageCompletion]:
        """Drain the queue; returns completions in completion order."""
        bsz = self.batch_size
        # flatten requests into (request, frame_index) slots
        pending: Deque[tuple] = deque()
        buffers: Dict[int, Dict[str, Any]] = {}
        done: List[ImageCompletion] = []
        while self._queue:
            req = self._queue.popleft()
            n = req.images.shape[0]
            if n == 0:                        # empty request: complete now
                done.append(ImageCompletion(
                    rid=req.rid,
                    classes=np.zeros((0,), np.int32),
                    lengths=np.zeros((0, self.deployed.cfg.n_classes),
                                     np.float32),
                    latency_s=time.perf_counter()
                    - self._submit_t.pop(req.rid)))
                continue
            buffers[req.rid] = {
                "req": req, "left": n,
                "lengths": np.zeros((n, self.deployed.cfg.n_classes),
                                    np.float32)}
            for k in range(n):
                pending.append((req.rid, k))

        batch = np.zeros((bsz,) + self._frame_shape, np.float32)
        while pending:
            slots: List[Optional[tuple]] = []
            batch[:] = 0.0                     # padding slots stay zero
            while pending and len(slots) < bsz:
                rid, k = pending.popleft()
                batch[len(slots)] = buffers[rid]["req"].images[k]
                slots.append((rid, k))
            t0 = time.perf_counter()
            lengths = np.asarray(
                jax.block_until_ready(self.deployed.forward(batch)))
            dt = time.perf_counter() - t0
            self._stats.batches += 1
            self._stats.frames += len(slots)
            self._stats.padded_frames += bsz - len(slots)
            self._stats.wall_s += dt
            now = time.perf_counter()
            for s, (rid, k) in enumerate(slots):
                buf = buffers[rid]
                buf["lengths"][k] = lengths[s]
                buf["left"] -= 1
                if buf["left"] == 0:
                    done.append(ImageCompletion(
                        rid=rid,
                        classes=np.argmax(buf["lengths"], -1).astype(
                            np.int32),
                        lengths=buf["lengths"],
                        latency_s=now - self._submit_t.pop(rid)))
        return done

    def serve(self, requests: List[ImageRequest]) -> List[ImageCompletion]:
        """Submit all requests and run them to completion."""
        for r in requests:
            self.submit(r)
        return self.run()

    def stats(self) -> EngineStats:
        return dataclasses.replace(self._stats)
