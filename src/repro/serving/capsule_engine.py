"""CapsuleEngine: batched CapsNet image serving over the shared EngineCore.

The paper's throughput story (Fig. 1: 82 -> 1351 FPS) is a *served*
workload, not a bare jit loop.  This adapter serves image-classification
requests through one fixed-shape jitted forward:

* **Request expansion** — requests carry a ragged number of frames; each
  frame becomes one slot task, so frames from different requests share a
  tick's batch (slot recycling).
* **Scheduler-shaped batches** — every tick packs the occupied slots into
  a batch whose size the scheduler chose: the FIFO scheduler always runs
  the one full-capacity executable (zero-padding the tail), the SLO
  scheduler shrinks/grows power-of-two buckets against a p95 target, and
  the sharded scheduler places the batch across a mesh.
* **Async admission** — ``submit()`` is thread-safe and non-blocking;
  frames submitted while a tick is in flight join the next tick.
* **FPS / latency stats** — cumulative frames, ticks, padding waste and
  wall-clock, plus per-request latency from submit to completion.

``engine = deployed.serve(scheduler=...)`` (on a
:class:`repro.deploy.DeployedCapsNet`) is the canonical way in.  To
serve one deployment from a *pool* of engines behind a single
``submit()/poll()`` surface, wrap CapsuleEngines in a
:class:`repro.serving.DisaggregatedEngine` with ``prefill=None`` (the
stateless form of disaggregated serving — image tasks carry no cache,
so the handoff is pure dispatch); ``bench_fig1_throughput.py
--scheduler disagg`` measures exactly that topology.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.serving.core import EngineCore, EngineStats, SlotTask  # noqa: F401
from repro.serving.schedulers import Scheduler, pow2_bucket


@dataclasses.dataclass
class ImageRequest:
    """A batch-of-frames classification request (ragged ``images`` count).

    ``rid=None`` lets the engine assign the next free id at submit time.
    ``stream=True`` emits one :class:`repro.serving.StreamEvent` per
    classified frame (``item=(frame_index, class_id)``) on the
    ``poll(stream=True)`` channel as ticks complete, instead of waiting
    for the whole request.
    """

    images: np.ndarray                # (n_frames, H, W, C)
    rid: Optional[int] = None
    stream: bool = False


@dataclasses.dataclass
class ImageCompletion:
    rid: int
    classes: np.ndarray               # (n_frames,) int32 predictions
    lengths: np.ndarray               # (n_frames, n_classes) capsule lengths
    latency_s: float                  # submit -> completion wall-clock


class CapsuleEngine(EngineCore):
    """Fixed-shape micro-batched inference over a :class:`DeployedCapsNet`.

    ``deployed`` is any object with ``cfg`` (a CapsNetConfig) and
    ``forward(images) -> lengths`` — in practice the artifact returned by
    ``FastCapsPipeline.compile``.  ``batch_size`` is the engine capacity
    (max frames per tick); the scheduler decides how much of it each tick
    actually uses.
    """

    def __init__(self, deployed: Any, batch_size: int = 32,
                 scheduler: Optional[Scheduler] = None,
                 clock=time.perf_counter,
                 kernel_tune: Optional[bool] = None):
        self.deployed = deployed
        self.batch_size = batch_size
        cfg = deployed.cfg
        self._frame_shape = (cfg.image_hw, cfg.image_hw, cfg.in_channels)
        self._n_classes = cfg.n_classes
        super().__init__(capacity=batch_size, scheduler=scheduler,
                         clock=clock, kernel_tune=kernel_tune)

    # -- workload hooks ----------------------------------------------------

    def _expand(self, request: ImageRequest
                ) -> Tuple[List[SlotTask], Dict[str, Any]]:
        imgs = np.asarray(request.images, np.float32)
        if imgs.ndim != 4 or imgs.shape[1:] != self._frame_shape:
            raise ValueError(
                f"request images must be (n,) + {self._frame_shape}, got "
                f"{imgs.shape}")
        request.images = imgs
        n = imgs.shape[0]
        state = {"lengths": np.zeros((n, self._n_classes), np.float32)}
        return [SlotTask(payload=(k, imgs[k])) for k in range(n)], state

    def _step(self, active: List[Tuple[int, SlotTask]], n_batch: int
              ) -> Tuple[List[int], int]:
        batch = np.zeros((n_batch,) + self._frame_shape, np.float32)
        for i, (_, task) in enumerate(active):
            batch[i] = task.payload[1]
        lengths = np.asarray(jax.block_until_ready(
            self.deployed.forward(self.scheduler.place(batch))))
        for i, (_, task) in enumerate(active):
            k = task.payload[0]
            self._requests[task.rid].state["lengths"][k] = lengths[i]
            self._emit(task.rid, (k, int(np.argmax(lengths[i]))))
        return [s for s, _ in active], len(active)

    def _request_class(self, request: ImageRequest) -> str:
        """Latency histogram key: frame counts bucketed to powers of two
        (``"image/f4"`` = requests carrying 3-4 frames)."""
        return f"image/f{pow2_bucket(len(request.images), self.capacity)}"

    def _finalize(self, entry, latency_s: float) -> ImageCompletion:
        buf = entry.state["lengths"]
        return ImageCompletion(
            rid=entry.request.rid,
            classes=np.argmax(buf, -1).astype(np.int32),
            lengths=buf,
            latency_s=latency_s)

    def _warmup(self) -> None:
        # compile every batch shape the scheduler can emit, so no tick
        # (and no SLO latency observation) pays compile time
        for n in self.scheduler.shapes(self.capacity):
            dummy = np.zeros((n,) + self._frame_shape, np.float32)
            jax.block_until_ready(
                self.deployed.forward(self.scheduler.place(dummy)))

    def _pretune(self) -> None:
        # bind-time kernel tuning: measure fused_routing block sizes for
        # every u_hat shape the scheduler's batch shapes imply, so the
        # warm-up traces of deployed.forward resolve tuned configs
        spec = getattr(self.deployed, "spec", None)
        if spec is None or spec.mode != "pallas":
            return
        from repro.kernels import tuning as ktuning
        from repro.kernels.registry import registry as kernel_registry

        kspec = kernel_registry.get("fused_routing")
        if not kspec.is_available():
            return
        cfg = self.deployed.cfg
        cache = ktuning.default_cache()
        for n in self.scheduler.shapes(self.capacity):
            u_hat = (jax.random.normal(
                jax.random.key(0),
                (n, cfg.n_primary_caps, cfg.n_classes, cfg.digit_dim))
                * 0.2)
            if cache.get(ktuning.cache_key_for(kspec, (u_hat,))) is None:
                ktuning.autotune(
                    kspec, (u_hat,),
                    {"n_iters": cfg.routing_iters,
                     "softmax_mode": spec.softmax}, cache=cache)
