"""EngineCore: the one serving loop every workload adapter shares.

The paper's Fig. 1 numbers are *served* throughput, so serving is a
first-class API, not a demo loop.  ``EngineCore`` owns everything that is
workload-independent about a slot-based, fixed-shape inference engine:

  * **slot state** — ``capacity`` slots, each holding one
    :class:`SlotTask`; a request expands into one or more tasks (CapsNet:
    one per frame; LM: one per sequence) that occupy a slot from admission
    until completion;
  * **async admission** — ``submit()`` only touches the queue under a
    lock, so requests can arrive from other threads (or from callbacks
    fired mid-tick) while a tick is in flight; the next tick picks them
    up;
  * **the tick** — admit up to ``scheduler.plan()`` tasks, let the
    workload prefill/step a schedulable, fixed-shape batch, then retire
    finished slots and emit completions;
  * **cumulative stats** — monotone counters (items, padding waste,
    ticks, wall-clock, completed requests) shared by every workload.

Workload adapters (:class:`repro.serving.CapsuleEngine`,
:class:`repro.serving.ServeEngine`) subclass this and implement four
hooks — ``_expand`` / ``_admit`` / ``_step`` / ``_finalize`` — giving both
image serving and LM decode the same
``submit() / poll() / run_until_idle() / stats()`` surface.

Scheduling (effective batch size, compiled shape, device placement) is
delegated to a pluggable :class:`repro.serving.Scheduler`.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from typing import Any, Callable, Deque, Dict, List, Optional, Tuple

from repro.serving.schedulers import FIFOScheduler, Scheduler, TickRecord


@dataclasses.dataclass
class EngineStats:
    """Cumulative over the engine's lifetime (monotone non-decreasing).

    ``items`` are workload units: frames for the image workload, generated
    tokens for LM decode.  The ``frames``/``batches`` aliases keep the
    image-serving vocabulary of the original CapsuleEngine stats.
    """

    items: int = 0                    # real work units served
    padded: int = 0                   # zero-pad slot waste
    ticks: int = 0                    # engine ticks that did work
    wall_s: float = 0.0               # time spent in admit+step
    completed: int = 0                # requests fully served

    @property
    def throughput(self) -> float:
        """Items (frames / tokens) per second of engine wall-clock."""
        return self.items / self.wall_s if self.wall_s > 0 else 0.0

    @property
    def ms_per_tick(self) -> float:
        return 1e3 * self.wall_s / self.ticks if self.ticks else 0.0

    # image-serving aliases (Fig. 1 vocabulary)
    fps = throughput
    frames = property(lambda self: self.items)
    padded_frames = property(lambda self: self.padded)
    batches = property(lambda self: self.ticks)
    ms_per_batch = ms_per_tick


@dataclasses.dataclass
class SlotTask:
    """One schedulable unit of a request (a frame, or a whole sequence)."""

    payload: Any                      # workload-specific immutable input
    rid: int = -1                     # owning request id (set at submit)
    state: Dict[str, Any] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class _RequestEntry:
    request: Any
    tasks: List[SlotTask]
    state: Dict[str, Any]
    left: int
    t0: float


class EngineCore:
    """Slot engine skeleton; subclass and implement the workload hooks.

    Hooks (called with the tick lock *released*, single ticker at a time):

      * ``_expand(request) -> (tasks, request_state)`` — validate and
        split a request into :class:`SlotTask`s (may raise ``ValueError``);
      * ``_admit(new) -> (finished_slot_ids, items)`` — react to tasks
        newly placed in slots (LM: ragged batched prefill);
      * ``_step(active, n_batch) -> (finished_slot_ids, items)`` — run one
        fixed-shape tick over the occupied slots;
      * ``_finalize(entry, latency_s) -> completion`` — build the
        completion object once all of a request's tasks finished;
      * ``_batch_for(n_active) -> int`` — compiled batch for this tick
        (defaults to ``scheduler.quantize``; fixed-cache workloads
        override to capacity);
      * ``_warmup()`` — optional eager compile outside the measured path.

    ``clock`` is injectable so schedulers can be tested against a
    deterministic time source.
    """

    def __init__(self, capacity: int, scheduler: Optional[Scheduler] = None,
                 clock: Callable[[], float] = time.perf_counter):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = int(capacity)
        self.scheduler = scheduler or FIFOScheduler()
        self.scheduler.bind(self)
        self._clock = clock
        self._slots: List[Optional[SlotTask]] = [None] * self.capacity
        self._queue: Deque[SlotTask] = deque()
        self._requests: Dict[int, _RequestEntry] = {}
        self._completions: Deque[Any] = deque()
        self._stats = EngineStats()
        self._next_rid = 0
        self._lock = threading.Lock()          # queue / requests / stats
        self._tick_lock = threading.Lock()     # one ticker at a time

    # -- workload hooks ----------------------------------------------------

    def _expand(self, request: Any) -> Tuple[List[SlotTask], Dict[str, Any]]:
        raise NotImplementedError

    def _admit(self, new: List[Tuple[int, SlotTask]]
               ) -> Tuple[List[int], int]:
        return [], 0

    def _step(self, active: List[Tuple[int, SlotTask]], n_batch: int
              ) -> Tuple[List[int], int]:
        raise NotImplementedError

    def _finalize(self, entry: _RequestEntry, latency_s: float) -> Any:
        raise NotImplementedError

    def _batch_for(self, n_active: int) -> int:
        return self.scheduler.quantize(n_active, self.capacity)

    def _warmup(self) -> None:
        pass

    # -- shared surface ----------------------------------------------------

    def submit(self, request: Any) -> int:
        """Enqueue one request (thread-safe, non-blocking); returns its rid.

        ``request.rid`` is assigned when ``None``; explicit rids must be
        unique among in-flight requests (completed rids may be reused).
        Zero-task requests complete immediately.
        """
        tasks, state = self._expand(request)
        with self._lock:
            rid = getattr(request, "rid", None)
            if rid is None:
                rid = self._next_rid
                self._next_rid += 1
            elif rid >= self._next_rid:
                self._next_rid = rid + 1   # keep auto ids collision-free
            if rid in self._requests:
                raise ValueError(f"duplicate rid {rid}")
            request.rid = rid
            for t in tasks:
                t.rid = rid
            entry = _RequestEntry(request=request, tasks=tasks, state=state,
                                  left=len(tasks), t0=self._clock())
            if not tasks:
                self._completions.append(
                    self._finalize(entry, max(self._clock() - entry.t0, 0.0)))
                self._stats.completed += 1
            else:
                self._requests[rid] = entry
                self._queue.extend(tasks)
        return rid

    def poll(self) -> List[Any]:
        """Drain and return the completions ready so far (non-blocking)."""
        out = []
        with self._lock:
            while self._completions:
                out.append(self._completions.popleft())
        return out

    def tick(self) -> bool:
        """One engine step: admit, run, retire.  Returns False when idle."""
        with self._tick_lock:
            with self._lock:
                n_active = sum(s is not None for s in self._slots)
                plan = self.scheduler.plan(len(self._queue), n_active)
                plan = max(1, min(int(plan), self.capacity))
                new: List[Tuple[int, SlotTask]] = []
                for s in range(self.capacity):
                    if n_active >= plan or not self._queue:
                        break
                    if self._slots[s] is None:
                        task = self._queue.popleft()
                        self._slots[s] = task
                        new.append((s, task))
                        n_active += 1
                active = [(s, t) for s, t in enumerate(self._slots)
                          if t is not None]
            if not active:
                return False

            t0 = self._clock()
            finished: List[int] = []
            items = 0
            if new:
                f, i = self._admit(new)
                finished += f
                items += i
            done = set(finished)
            still = [(s, t) for s, t in active if s not in done]
            n_batch = 0
            if still:
                n_batch = max(len(still),
                              min(self._batch_for(len(still)), self.capacity))
                f, i = self._step(still, n_batch)
                finished += f
                items += i
            wall = max(self._clock() - t0, 0.0)

            with self._lock:
                st = self._stats
                st.ticks += 1
                st.items += items
                st.padded += max(n_batch - len(still), 0)
                st.wall_s += wall
                now = self._clock()
                for s in finished:
                    task = self._slots[s]
                    self._slots[s] = None
                    entry = self._requests[task.rid]
                    entry.left -= 1
                    if entry.left == 0:
                        del self._requests[task.rid]
                        self._completions.append(
                            self._finalize(entry, max(now - entry.t0, 0.0)))
                        st.completed += 1
            self.scheduler.observe(
                TickRecord(n_active=len(still), n_batch=n_batch, wall_s=wall))
            return True

    def run_until_idle(self) -> List[Any]:
        """Tick until queue and slots drain; returns the completions
        ready at exit.  Submissions made while running — from other
        threads or mid-tick callbacks — are served as long as they land
        before the engine observes an empty queue; a submit racing that
        final check stays queued for the next run/tick."""
        while True:
            if self.tick():
                continue
            if self.n_pending == 0:
                return self.poll()

    def serve(self, requests: List[Any]) -> List[Any]:
        """Submit all requests and run them to completion."""
        for r in requests:
            self.submit(r)
        return self.run_until_idle()

    def warmup(self) -> None:
        """Compile the tick executables outside the measured path."""
        self._warmup()

    def stats(self) -> EngineStats:
        with self._lock:
            return dataclasses.replace(self._stats)

    @property
    def n_pending(self) -> int:
        """Queued tasks + occupied slots (0 means the engine is idle)."""
        with self._lock:
            return len(self._queue) + sum(
                s is not None for s in self._slots)
