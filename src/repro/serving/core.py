"""EngineCore: the one serving loop every workload adapter shares.

The paper's Fig. 1 numbers are *served* throughput, so serving is a
first-class API, not a demo loop.  ``EngineCore`` owns everything that is
workload-independent about a slot-based, fixed-shape inference engine:

  * **slot state** — ``capacity`` slots, each holding one
    :class:`SlotTask`; a request expands into one or more tasks (CapsNet:
    one per frame; LM: one per sequence) that occupy a slot from admission
    until completion;
  * **async admission** — ``submit()`` only touches the queue under a
    lock, so requests can arrive from other threads (or from callbacks
    fired mid-tick) while a tick is in flight; the next tick picks them
    up;
  * **the tick** — admit up to ``scheduler.plan()`` tasks, let the
    workload prefill/step a schedulable, fixed-shape batch, then retire
    finished slots and emit completions; ``scheduler.phase()`` may
    dedicate a tick to admission (prefill) or stepping (decode) instead
    of the default mixed tick;
  * **streaming** — workloads may emit per-item :class:`StreamEvent`\\ s
    (LM: one per generated token) for requests that opted in;
    ``poll(stream=True)`` drains them while plain ``poll()`` keeps the
    completion-level contract;
  * **cumulative stats** — monotone counters (items, padding waste,
    ticks, wall-clock, completed requests) plus per-request-class
    latency histograms (p50/p95), shared by every workload.

Workload adapters (:class:`repro.serving.CapsuleEngine`,
:class:`repro.serving.ServeEngine`) subclass this and implement four
hooks — ``_expand`` / ``_admit`` / ``_step`` / ``_finalize`` — giving both
image serving and LM decode the same
``submit() / poll() / run_until_idle() / stats()`` surface.

Scheduling (effective batch size, compiled shape, device placement) is
delegated to a pluggable :class:`repro.serving.Scheduler`.
"""

from __future__ import annotations

import bisect
import contextlib
import dataclasses
import threading
import time
from collections import deque
from typing import Any, Callable, Deque, Dict, List, Optional, Tuple

from repro.kernels import tuning as kernel_tuning
from repro.serving.schedulers import FIFOScheduler, Scheduler, TickRecord


class _Log2Histogram:
    """Shared fixed-bucket histogram core (counts only, O(1) memory).

    Subclasses define ``BOUNDS`` — ascending bucket upper bounds, plus an
    implicit overflow bucket — so ``record`` never rebins and two
    snapshots of the same histogram are comparable bucket by bucket.
    ``_percentile`` reports the upper bound of the bucket the requested
    quantile falls in (Prometheus-style: pessimistic by at most one
    bucket width).  There is exactly one quantile implementation; the
    latency and depth views only differ in bounds, units and extras.
    """

    BOUNDS: tuple = ()

    def __init__(self):
        self.counts = [0] * (len(self.BOUNDS) + 1)
        self.count = 0

    def _record(self, value) -> None:
        self.counts[bisect.bisect_left(self.BOUNDS, value)] += 1
        self.count += 1

    def _percentile(self, q: float) -> float:
        """Bucket upper bound below which ``q`` percent of observations
        fell; 0.0 for an empty histogram."""
        if not self.count:
            return 0.0
        rank = q / 100.0 * self.count
        seen = 0
        for i, c in enumerate(self.counts):
            seen += c
            if seen >= rank and c:
                return (float(self.BOUNDS[i]) if i < len(self.BOUNDS)
                        else float("inf"))
        return float("inf")

    def copy(self):
        out = type(self)()
        for k, v in self.__dict__.items():
            setattr(out, k, list(v) if isinstance(v, list) else v)
        return out


class LatencyHistogram(_Log2Histogram):
    """Latency histogram: buckets span 50 us to ~45 min (pow2 upper
    bounds in ms).  ``record`` takes seconds; percentiles report ms."""

    BOUNDS_MS = tuple(0.05 * 2 ** i for i in range(26))   # 0.05ms..~45min
    BOUNDS = BOUNDS_MS

    def __init__(self):
        super().__init__()
        self.total_s = 0.0

    def record(self, seconds: float) -> None:
        s = max(float(seconds), 0.0)
        self._record(s * 1e3)
        self.total_s += s

    def percentile_ms(self, q: float) -> float:
        """Latency (ms) below which ``q`` percent of requests completed;
        0.0 for an empty histogram."""
        return self._percentile(q)

    @property
    def p50_ms(self) -> float:
        return self.percentile_ms(50.0)

    @property
    def p95_ms(self) -> float:
        return self.percentile_ms(95.0)

    @property
    def mean_ms(self) -> float:
        return 1e3 * self.total_s / self.count if self.count else 0.0

    def __repr__(self) -> str:
        return (f"LatencyHistogram(n={self.count}, p50={self.p50_ms:.3g}ms, "
                f"p95={self.p95_ms:.3g}ms)")


class DepthHistogram(_Log2Histogram):
    """Histogram of non-negative integer levels (queue depths observed
    at each tick): buckets 0, 1, 2, 4, ... 2**19 plus overflow, and
    ``peak`` keeps the exact high-water mark."""

    BOUNDS = (0,) + tuple(2 ** i for i in range(20))

    def __init__(self):
        super().__init__()
        self.total = 0
        self.peak = 0

    def record(self, depth: int) -> None:
        d = max(int(depth), 0)
        self._record(d)
        self.total += d
        self.peak = max(self.peak, d)

    def percentile(self, q: float) -> float:
        """Depth below which ``q`` percent of observations fell; 0.0 for
        an empty histogram."""
        return self._percentile(q)

    @property
    def p50(self) -> float:
        return self.percentile(50.0)

    @property
    def p95(self) -> float:
        return self.percentile(95.0)

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def __repr__(self) -> str:
        return (f"DepthHistogram(n={self.count}, p50={self.p50:.3g}, "
                f"p95={self.p95:.3g}, peak={self.peak})")


@dataclasses.dataclass
class EngineStats:
    """Cumulative over the engine's lifetime (monotone non-decreasing).

    ``items`` are workload units: frames for the image workload, generated
    tokens for LM decode.  The ``frames``/``batches`` aliases keep the
    image-serving vocabulary of the original CapsuleEngine stats.

    ``latency`` maps a *request class* (the workload's coarse label for a
    request, e.g. ``"lm/p8"`` for prompts bucketed to length 8 — see
    ``EngineCore._request_class``) to a :class:`LatencyHistogram` of
    submit-to-completion wall-clock, so p50/p95 can be read per class
    without retaining per-request records.  Snapshots from ``stats()``
    deep-copy the histograms: they never mutate under the caller.

    ``depth`` maps a tick *phase* (``"mixed"`` / ``"prefill"`` /
    ``"decode"``, plus ``"handoff"`` on a disaggregated front-end) to a
    :class:`DepthHistogram` of the queue depth observed at each tick of
    that phase, and ``transfer`` maps a handoff stage to a
    :class:`LatencyHistogram` of its transfer wall-clock — both only
    populated by engines that run the corresponding phase.  The
    ``transfer`` key vocabulary on a disaggregated front-end:
    ``"handoff"`` is the queue wait (prefill completion to decode
    submit), and each routed :class:`repro.serving.Transport` adds
    per-leg critical-path histograms ``"<transport>/<leg>"`` (e.g.
    ``"host_staged/d2h"``, ``"device_to_device/dispatch"``) plus a
    ``"<transport>/total"`` sum — the yardstick for how much delivery
    cost sits on the decode critical path.
    """

    items: int = 0                    # real work units served
    padded: int = 0                   # zero-pad slot waste
    ticks: int = 0                    # engine ticks that did work
    wall_s: float = 0.0               # time spent in admit+step
    completed: int = 0                # requests fully served
    preempted: int = 0                # resident tasks evicted + requeued
    latency: Dict[str, LatencyHistogram] = dataclasses.field(
        default_factory=dict)         # request class -> latency histogram
    depth: Dict[str, DepthHistogram] = dataclasses.field(
        default_factory=dict)         # tick phase -> queue-depth histogram
    transfer: Dict[str, LatencyHistogram] = dataclasses.field(
        default_factory=dict)         # handoff stage / transport leg ->
    #                                   transfer latency
    pages: Dict[str, int] = dataclasses.field(
        default_factory=dict)         # paged-KV counters (allocations,
    #                                   prefix hits, prefill savings —
    #                                   see repro.serving.pages)

    @property
    def throughput(self) -> float:
        """Items (frames / tokens) per second of engine wall-clock."""
        return self.items / self.wall_s if self.wall_s > 0 else 0.0

    @property
    def ms_per_tick(self) -> float:
        return 1e3 * self.wall_s / self.ticks if self.ticks else 0.0

    def latency_summary(self) -> Dict[str, Tuple[int, float, float]]:
        """``{request class: (count, p50 ms, p95 ms)}`` for reporting."""
        return {k: (h.count, h.p50_ms, h.p95_ms)
                for k, h in sorted(self.latency.items())}

    def depth_summary(self) -> Dict[str, Tuple[int, float, float, int]]:
        """``{phase: (ticks, p50 depth, p95 depth, peak)}`` for reporting."""
        return {k: (h.count, h.p50, h.p95, h.peak)
                for k, h in sorted(self.depth.items())}

    def transfer_summary(self) -> Dict[str, Tuple[int, float, float]]:
        """``{handoff stage: (count, p50 ms, p95 ms)}`` for reporting."""
        return {k: (h.count, h.p50_ms, h.p95_ms)
                for k, h in sorted(self.transfer.items())}

    # image-serving aliases (Fig. 1 vocabulary)
    fps = throughput
    frames = property(lambda self: self.items)
    padded_frames = property(lambda self: self.padded)
    batches = property(lambda self: self.ticks)
    ms_per_batch = ms_per_tick


@dataclasses.dataclass
class SlotTask:
    """One schedulable unit of a request (a frame, or a whole sequence)."""

    payload: Any                      # workload-specific immutable input
    rid: int = -1                     # owning request id (set at submit)
    priority: int = 0                 # request priority (0 = most urgent)
    state: Dict[str, Any] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class StreamEvent:
    """One token-level (or frame-level) result on the streaming channel.

    ``seq`` is the 0-based per-request emission index — strictly
    increasing per rid, so consumers can assert ordering.  The final
    event of a request has ``done=True``, ``item=None`` and carries the
    request's completion object (the same object plain ``poll()``
    returns), making the stream self-contained.  One caveat: completed
    rids may be reused by a later ``submit()``, and a reused rid's
    events restart at ``seq=0`` — drain ``poll(stream=True)`` before
    reusing an explicit rid, or let the engine assign fresh rids.
    """

    rid: int
    seq: int
    item: Any = None                  # token id / frame class, None on done
    done: bool = False
    completion: Any = None            # set on the done event only


def allocate_rid(request: Any, inflight: Dict[int, Any], next_rid: int
                 ) -> Tuple[int, int]:
    """Resolve a request's rid under THE engine rid rules (one place —
    :class:`EngineCore` and the disaggregated front-end must not drift):
    ``None`` takes the next auto id; an explicit id bumps the auto
    counter past itself so later auto ids never collide; an id already
    in ``inflight`` raises.  Sets ``request.rid``; returns
    ``(rid, next_rid)``.  Caller must hold its state lock."""
    rid = getattr(request, "rid", None)
    if rid is None:
        rid = next_rid
        next_rid += 1
    elif rid >= next_rid:
        next_rid = rid + 1
    if rid in inflight:
        raise ValueError(f"duplicate rid {rid}")
    request.rid = rid
    return rid, next_rid


@dataclasses.dataclass
class _RequestEntry:
    request: Any
    tasks: List[SlotTask]
    state: Dict[str, Any]
    left: int
    t0: float
    cls: str = "default"              # request class (latency histogram key)
    stream: bool = False              # emit StreamEvents for this request
    emitted: int = 0                  # next StreamEvent.seq


class EngineCore:
    """Slot engine skeleton; subclass and implement the workload hooks.

    Hooks (called with the tick lock *released*, single ticker at a time):

      * ``_expand(request) -> (tasks, request_state)`` — validate and
        split a request into :class:`SlotTask`s (may raise ``ValueError``);
      * ``_admit(new) -> (finished_slot_ids, items)`` — react to tasks
        newly placed in slots (LM: ragged batched prefill);
      * ``_step(active, n_batch) -> (finished_slot_ids, items)`` — run one
        fixed-shape tick over the occupied slots;
      * ``_finalize(entry, latency_s) -> completion`` — build the
        completion object once all of a request's tasks finished;
      * ``_batch_for(n_active) -> int`` — compiled batch for this tick
        (defaults to ``scheduler.quantize``; fixed-cache workloads
        override to capacity);
      * ``_warmup()`` — optional eager compile outside the measured path;
      * ``_pretune()`` — optional measured kernel autotuning with
        concrete example inputs, run by ``warmup()`` before anything
        compiles when ``kernel_tune=True``.

    ``kernel_tune`` selects the engine's kernel-config policy: ``True``
    binds tick executables against the autotuner cache (the
    :mod:`repro.kernels` registry resolves tuned block sizes at trace
    time, so the choice is frozen into the compiled executables),
    ``False`` pins the deterministic defaults, and ``None`` (default)
    inherits the ambient :func:`repro.kernels.tuning.tuning` policy.

    ``clock`` is injectable so schedulers can be tested against a
    deterministic time source.
    """

    def __init__(self, capacity: int, scheduler: Optional[Scheduler] = None,
                 clock: Callable[[], float] = time.perf_counter,
                 kernel_tune: Optional[bool] = None):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = int(capacity)
        self.kernel_tune = kernel_tune
        self.scheduler = scheduler or FIFOScheduler()
        self.scheduler.bind(self)
        self._clock = clock
        self._slots: List[Optional[SlotTask]] = (      # guarded-by: _lock
            [None] * self.capacity)
        self._queue: Deque[SlotTask] = deque()         # guarded-by: _lock
        self._requests: Dict[int, _RequestEntry] = {}  # guarded-by: _lock
        self._completions: Deque[Any] = deque()        # guarded-by: _lock
        self._events: Deque[StreamEvent] = deque()     # guarded-by: _lock
        self._stats = EngineStats()                    # guarded-by: _lock
        self._tick_excluded = 0.0      # one-off hook time (autotuning);
        #                                ticker-private (under _tick_lock)
        self._next_rid = 0                             # guarded-by: _lock
        self._lock = threading.Lock()          # queue / requests / stats
        self._tick_lock = threading.Lock()     # one ticker at a time

    # -- workload hooks ----------------------------------------------------

    def _expand(self, request: Any) -> Tuple[List[SlotTask], Dict[str, Any]]:
        raise NotImplementedError

    def _admit(self, new: List[Tuple[int, SlotTask]]
               ) -> Tuple[List[int], int]:
        return [], 0

    def _step(self, active: List[Tuple[int, SlotTask]], n_batch: int
              ) -> Tuple[List[int], int]:
        raise NotImplementedError

    def _finalize(self, entry: _RequestEntry, latency_s: float) -> Any:
        raise NotImplementedError

    def _batch_for(self, n_active: int) -> int:
        return self.scheduler.quantize(n_active, self.capacity)

    def _warmup(self) -> None:
        pass

    def _evict(self, slot: int, task: SlotTask) -> None:
        """Save ``task``'s resumable state before it is requeued (called
        with the slot already freed, tick lock held, state lock
        released).  Preemption must be *lossless*: ``_admit`` of a
        requeued task must continue exactly where it stopped, so
        workloads with carried state override this to capture it (LM:
        cache rows / pending token / position — see
        ``ServeEngine._evict``).  The default saves nothing, which is
        correct only for workloads whose ``_admit`` is already
        resume-aware (e.g. a countdown kept in ``task.state``)."""

    def _release_slot(self, slot: int, task: SlotTask) -> None:
        """Reclaim per-slot workload resources after ``task`` finished
        and its slot was retired (called once per finished slot, state
        lock released).  The dense cache needs nothing — the slot's
        rows are simply overwritten by the next admission — but the
        paged cache must drop the task's page references
        (``ServeEngine._release_slot``)."""

    def _pretune(self) -> None:
        """Measured kernel autotuning with concrete inputs (workloads
        override); runs before the first trace so trace-time registry
        dispatch finds the cache populated."""
        pass

    def _kernel_scope(self):
        """Tuning-policy scope every hook runs under (fresh per use —
        context managers are single-shot)."""
        if self.kernel_tune is None:
            return contextlib.nullcontext()
        return kernel_tuning.tuning(self.kernel_tune)

    def _exclude_tick_time(self, seconds: float) -> None:
        """Hooks call this (ticker thread only) to mark one-off work —
        e.g. a measured kernel autotune on a first-seen shape bucket —
        so it is subtracted from the tick wall before throughput stats
        and ``scheduler.observe`` see it; an SLO scheduler must react to
        serving time, not to a one-time measurement."""
        self._tick_excluded += max(float(seconds), 0.0)

    def _request_class(self, request: Any) -> str:
        """Coarse label keying the latency histogram (override per
        workload; a small, bounded set of labels keeps stats O(1))."""
        return "default"

    def _wants_stream(self, request: Any) -> bool:
        """Whether this request opted into token-level StreamEvents
        (default: its ``stream`` attribute; absent means completion-only,
        so the legacy request types stream nothing)."""
        return bool(getattr(request, "stream", False))

    # -- internal helpers --------------------------------------------------

    def _emit(self, rid: int, item: Any) -> None:
        """Queue one streaming item for ``rid`` (no-op unless the request
        opted in).  Workload hooks may call this with the lock released —
        it re-acquires it — but only from the single ticker thread, which
        is what keeps ``seq`` strictly increasing per request."""
        with self._lock:
            entry = self._requests.get(rid)
            if entry is None or not entry.stream:
                return
            self._events.append(StreamEvent(rid=rid, seq=entry.emitted,
                                            item=item))
            entry.emitted += 1

    def _complete_locked(self, entry: _RequestEntry, now: float) -> None:
        """Finalize one request: completion queue, latency histogram, and
        the terminal StreamEvent for streaming requests.  Call with
        ``self._lock`` held."""
        completion = self._finalize(entry, max(now - entry.t0, 0.0))
        self._completions.append(completion)
        st = self._stats
        st.completed += 1
        st.latency.setdefault(
            entry.cls, LatencyHistogram()).record(max(now - entry.t0, 0.0))
        if entry.stream:
            self._events.append(StreamEvent(
                rid=entry.request.rid, seq=entry.emitted, done=True,
                completion=completion))
            entry.emitted += 1

    # -- shared surface ----------------------------------------------------

    def submit(self, request: Any) -> int:
        """Enqueue one request (thread-safe, non-blocking); returns its rid.

        May be called from any thread, including callbacks fired while a
        tick is in flight; the request joins the next tick's admission.
        ``request.rid`` is assigned when ``None``; explicit rids must be
        unique among in-flight requests (completed rids may be reused).
        Zero-task requests complete immediately.  Raises ``ValueError``
        (from the workload's ``_expand``) on malformed payloads before
        any engine state changes.
        """
        tasks, state = self._expand(request)
        prio = int(getattr(request, "priority", 0))
        with self._lock:
            rid, self._next_rid = allocate_rid(request, self._requests,
                                               self._next_rid)
            for t in tasks:
                t.rid = rid
                t.priority = prio
            entry = _RequestEntry(request=request, tasks=tasks, state=state,
                                  left=len(tasks), t0=self._clock(),
                                  cls=self._request_class(request),
                                  stream=self._wants_stream(request))
            if not tasks:
                self._complete_locked(entry, self._clock())
            else:
                self._requests[rid] = entry
                self._queue.extend(tasks)
        return rid

    def poll(self, stream: bool = False) -> List[Any]:
        """Drain results ready so far (thread-safe, non-blocking).

        * ``poll()`` — the completion-level contract: one workload
          completion object per finished request, in finish order.
          Every request (streaming or not) lands here, so
          ``run_until_idle()``/``serve()`` callers are unaffected by
          streaming.
        * ``poll(stream=True)`` — the token-level channel: ordered
          :class:`StreamEvent`\\ s for requests that opted in
          (``request.stream=True``), one per emitted item, terminated
          per request by a ``done=True`` event carrying the completion.
          Events for different requests interleave in emission order;
          ``seq`` is strictly increasing within a rid.

        The two channels drain independently: a streaming consumer that
        never calls plain ``poll()`` should discard its completions
        eventually, and vice versa a completion-level consumer of a
        streaming request should drain ``poll(stream=True)`` or not set
        ``stream`` — both queues are unbounded.
        """
        out: List[Any] = []
        with self._lock:
            src = self._events if stream else self._completions
            while src:
                out.append(src.popleft())
        return out

    def tick(self) -> bool:
        """One engine step: admit, run, retire.  Returns False when idle.

        ``scheduler.phase()`` picks the tick kind: ``"mixed"`` admits and
        steps (prefill rides the admission tick — the legacy behaviour),
        ``"prefill"`` dedicates the tick to admission (resident slots
        idle one tick), ``"decode"`` dedicates it to stepping (the queue
        waits).  Impossible answers are coerced back to ``"mixed"`` —
        ``"decode"`` with nothing resident, ``"prefill"`` with nothing
        queued, and any phase this engine has no machinery for (e.g. the
        ``"handoff"`` phase of a disaggregated front-end) — so no
        scheduler can stall the engine.  Each tick records the queue
        depth it observed under its phase in ``EngineStats.depth``.

        Before admission, ``scheduler.preempt()`` may evict residents in
        favour of higher-priority queued work: the slot frees, the
        workload's ``_evict`` hook saves the task's resumable state, and
        the task requeues at the front of the queue — never dropped, and
        its request entry (latency clock, stream ``seq``) is untouched.
        Admission then pops the queue at ``scheduler.select()`` instead
        of strictly left (default 0 keeps FIFO).
        """
        with self._tick_lock:
            with self._lock:
                queued = list(self._queue)
                residents = [(s, t) for s, t in enumerate(self._slots)
                             if t is not None]
                evicted: List[Tuple[int, SlotTask]] = []
                if queued and residents:
                    for s in self.scheduler.preempt(queued, residents):
                        s = int(s)
                        if 0 <= s < self.capacity \
                                and self._slots[s] is not None:
                            evicted.append((s, self._slots[s]))
                            self._slots[s] = None
            if evicted:
                for s, task in evicted:
                    self._evict(s, task)   # hooks run with lock released
                with self._lock:
                    for _, task in reversed(evicted):
                        self._queue.appendleft(task)
                    self._stats.preempted += len(evicted)
            with self._lock:
                n_active = sum(s is not None for s in self._slots)
                n_queued = len(self._queue)
                phase = self.scheduler.phase(n_queued, n_active)
                if phase not in ("prefill", "decode"):
                    phase = "mixed"   # incl. "handoff": no such stage here
                elif phase == "decode" and n_active == 0:
                    phase = "mixed"
                elif phase == "prefill" and n_queued == 0:
                    phase = "mixed"
                if n_queued or n_active:
                    self._stats.depth.setdefault(
                        phase, DepthHistogram()).record(n_queued)
                new: List[Tuple[int, SlotTask]] = []
                if phase != "decode":
                    plan = self.scheduler.plan(n_queued, n_active)
                    plan = max(1, min(int(plan), self.capacity))
                    for s in range(self.capacity):
                        if n_active >= plan or not self._queue:
                            break
                        if self._slots[s] is None:
                            i = int(self.scheduler.select(self._queue))
                            if not 0 <= i < len(self._queue):
                                i = 0
                            task = self._queue[i]
                            del self._queue[i]
                            self._slots[s] = task
                            new.append((s, task))
                            n_active += 1
                active = [(s, t) for s, t in enumerate(self._slots)
                          if t is not None]
            if not active:
                return False

            t0 = self._clock()
            self._tick_excluded = 0.0
            finished: List[int] = []
            items = 0
            with self._kernel_scope():
                if new:
                    f, i = self._admit(new)
                    finished += f
                    items += i
                done = set(finished)
                still = [(s, t) for s, t in active if s not in done]
                n_batch = 0
                if still and not (phase == "prefill" and new):
                    n_batch = max(len(still),
                                  min(self._batch_for(len(still)),
                                      self.capacity))
                    f, i = self._step(still, n_batch)
                    finished += f
                    items += i
            wall = max(self._clock() - t0 - self._tick_excluded, 0.0)

            retired: List[Tuple[int, SlotTask]] = []
            with self._lock:
                st = self._stats
                st.ticks += 1
                st.items += items
                st.padded += max(n_batch - len(still), 0)
                st.wall_s += wall
                now = self._clock()
                for s in finished:
                    task = self._slots[s]
                    self._slots[s] = None
                    retired.append((s, task))
                    entry = self._requests[task.rid]
                    entry.left -= 1
                    if entry.left == 0:
                        del self._requests[task.rid]
                        self._complete_locked(entry, now)
            for s, task in retired:
                self._release_slot(s, task)   # hooks run lock-released
            self.scheduler.observe(
                TickRecord(n_active=len(still), n_batch=n_batch, wall_s=wall))
            return True

    def run_until_idle(self) -> List[Any]:
        """Tick until queue and slots drain; returns the completions
        ready at exit (completion-level — streaming events stay queued
        for ``poll(stream=True)``).  Submissions made while running —
        from other threads or mid-tick callbacks — are served as long as
        they land before the engine observes an empty queue; a submit
        racing that final check stays queued for the next run/tick."""
        while True:
            if self.tick():
                continue
            if self.n_pending == 0:
                return self.poll()

    def serve(self, requests: List[Any]) -> List[Any]:
        """Submit all requests and run them to completion."""
        for r in requests:
            self.submit(r)
        return self.run_until_idle()

    def warmup(self) -> None:
        """Compile the tick executables outside the measured path.

        With ``kernel_tune=True`` this is also the bind point for tuned
        kernel configs: ``_pretune`` measures candidates eagerly
        (populating the on-disk autotuner cache), then the warm-up
        traces pick the cached winners up and freeze them into the tick
        executables."""
        with self._kernel_scope():
            if self.kernel_tune:
                self._pretune()
            self._warmup()

    def stats(self) -> EngineStats:
        """Snapshot of the cumulative :class:`EngineStats` (thread-safe).

        The snapshot is detached — counters and latency histograms are
        copied, so it never mutates as the engine keeps serving."""
        with self._lock:
            return dataclasses.replace(
                self._stats,
                latency={k: h.copy()
                         for k, h in self._stats.latency.items()},
                depth={k: h.copy()
                       for k, h in self._stats.depth.items()},
                transfer={k: h.copy()
                          for k, h in self._stats.transfer.items()},
                pages=dict(self._stats.pages))

    @property
    def n_pending(self) -> int:
        """Queued tasks + occupied slots (0 means the engine is idle)."""
        with self._lock:
            return len(self._queue) + sum(
                s is not None for s in self._slots)

    @property
    def n_queued(self) -> int:
        """Tasks waiting for a slot (backlog only — excludes residents;
        the quantity ``EngineStats.depth`` histograms record)."""
        with self._lock:
            return len(self._queue)
