"""Disaggregated prefill: admission/prefill and decode on separate engines.

The :class:`repro.serving.InterleavingScheduler` separates prefill from
decode *in time* — dedicated ticks on one engine.  This module separates
them *across hardware*: a :class:`PrefillEngine` owns admission and the
compute-bound ragged prefill, one or more :class:`DecodeEngine`\\ s own
the memory-bound token loop, and finished prefill state moves between
them as a typed :class:`CacheHandoff` (slot-axis gather on the prefill
side -> transfer -> scatter into the decode engine's slot).  This is the
FastCaps shape of the argument one level up: the paper's throughput came
from co-designing the *stages around* the routing kernel, not the kernel
alone, and here the two serving stages with opposite roofline positions
stop sharing an engine entirely — a prefill burst can no longer steal
even one tick from resident decodes.

The moving parts:

  * :class:`CacheHandoff` — the typed contract between the two sides:
    per-request cache rows (KV for attention families, recurrent state
    for ssm/hybrid — both gathered with ``lm.gather_cache_rows``), the
    pending token/position, the partial output, and the model signature
    the decode side validates against (family/arch/cache geometry/
    dtypes) so a mis-routed handoff fails loudly instead of decoding
    garbage.
  * :class:`PrefillEngine` — a :class:`repro.serving.ServeEngine` whose
    slots live exactly one admission: every request finishes *at
    prefill* and completes with a ``CacheHandoff`` instead of tokens.
  * :class:`DecodeEngine` — a :class:`repro.serving.ServeEngine` that
    admits ``CacheHandoff``\\ s: injection scatters the rows into its own
    slot caches (``lm.scatter_cache_rows``), re-placed through its
    scheduler's ``place()`` so a :class:`repro.serving.ShardedScheduler`
    composes — the rows replicate onto the decode mesh and the scatter
    stays device-local per slot shard.
  * :class:`DisaggregatedEngine` — the front-end that keeps the standard
    ``submit() / poll() / run_until_idle() / stats()`` surface
    (including ``poll(stream=True)`` ordering across the handoff
    boundary), drives the three stages under a scheduler whose
    ``phase()`` may answer ``"handoff"``, fails a handoff over to
    another decode engine when one dies mid-transfer (requeued, never
    dropped), and reports per-phase queue-depth and transfer-latency
    histograms through :class:`repro.serving.EngineStats`.

Disaggregated serving is **exact**: prefill uses the same ragged (or,
for recurrent families, length-bucketed) admission as the unified
engine, and the gathered rows are bit-identical state, so the decoded
tokens match per-request ``generate()`` (regression-tested for
dense/vlm/ssm/hybrid on 1- and 2-device hosts).
"""

from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from typing import (Any, Callable, Deque, Dict, List, Optional, Set, Tuple)

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import lm
from repro.serving.core import (DepthHistogram, EngineCore, EngineStats,
                                LatencyHistogram, SlotTask, StreamEvent,
                                allocate_rid)
from repro.serving.engine import ServeEngine
from repro.serving.pages import PagePool
from repro.serving.schedulers import (DisaggScheduler, Scheduler,
                                      ShardedScheduler)
from repro.serving.transport import (InProcessTransport, TransferRecord,
                                     Transport, make_transport,
                                     select_transport)


@dataclasses.dataclass
class CacheHandoff:
    """Per-request decode state handed from a prefill to a decode engine.

    ``rows`` is a ``lm.make_caches(cfg, 1, max_len)``-shaped pytree — one
    slot's gathered cache rows (``None`` when ``done``: the request
    finished at prefill and only needs its completion emitted, or when
    ``stateless``: a dispatch-only handoff for workloads with no
    carried state, e.g. image frames).  ``family`` / ``arch_id`` /
    ``max_len`` plus the rows' tree/shape/dtypes are the signature
    :meth:`DecodeEngine.validate_handoff` checks before admitting.
    """

    rid: int
    request: Any                      # the original workload request
    family: Optional[str]             # LM family (None: stateless workload)
    arch_id: Optional[str]
    max_len: int
    rows: Any                         # cache pytree with batch dim 1, or None
    tok: int                          # pending token to feed the next decode
    pos: int                          # its cache index
    out: List[int]                    # prompt + tokens generated so far
    left: int                         # tokens still to generate
    done: bool = False                # finished at prefill; no decode needed
    stateless: bool = False           # dispatch-only (no carried state)
    stream: bool = False              # original request opted into streaming
    cls: str = "default"              # request class (latency histograms)
    t_handoff: float = 0.0            # when the handoff entered the queue
    # sampling state travels typed with the handoff: the seed was
    # materialized at prefill admission (engine._bind_seed), so the
    # decode side draws the exact same counter-based sequence a unified
    # engine would — temperature>0 is reproducible across the boundary
    seed: int = 0
    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    # paged handoffs (repro.serving.pages): ``rows`` becomes
    # ``{"pages": export_pages payload, "residual": residual rows}``.
    # ``page_hashes`` advertises the prefix-index identity of each page
    # (None = private) so the front-end can pin target-side hits and
    # strip them from the payload — a handoff then moves only the pages
    # the target doesn't already hold, plus an O(pages) table splice.
    paged: bool = False
    page_size: int = 0
    quantized: bool = False
    n_pages: int = 0                  # pages in the slot's table at export
    page_hashes: Optional[List[Optional[bytes]]] = None
    page_missing: Optional[List[int]] = None   # positions in rows["pages"]
    page_pinned: Optional[Dict[int, int]] = None  # target pos -> pinned page


@dataclasses.dataclass
class HandoffRequest:
    """What a :class:`DisaggregatedEngine` submits to a decode engine:
    one :class:`CacheHandoff` wrapped in the standard request shape
    (``rid`` / ``stream``), so it flows through the ordinary
    ``EngineCore.submit`` path and slot admission."""

    handoff: CacheHandoff
    rid: Optional[int] = None
    stream: bool = False

    @property
    def temperature(self) -> float:
        """Sampling temperature travels typed on the handoff."""
        return float(self.handoff.temperature)

    @property
    def seed(self) -> int:
        """Materialized sampling seed — never None past prefill."""
        return int(self.handoff.seed)

    @property
    def top_k(self) -> int:
        return int(self.handoff.top_k)

    @property
    def top_p(self) -> float:
        return float(self.handoff.top_p)

    @property
    def priority(self) -> int:
        """Priority class travels with the original request, so a
        :class:`repro.serving.PriorityScheduler` on a decode engine can
        preempt across the handoff boundary."""
        return int(getattr(self.handoff.request, "priority", 0))


class PrefillEngine(ServeEngine):
    """Admission/prefill half of a disaggregated pair.

    A :class:`repro.serving.ServeEngine` whose slots live exactly one
    admission tick: after the (ragged / length-bucketed) batched prefill
    of ``ServeEngine._admit``, every admitted slot's cache rows are
    gathered out (``lm.gather_cache_rows`` on the slot axis) and the
    request *completes* — its completion object is a
    :class:`CacheHandoff`, not tokens.  ``max_new_tokens <= 0`` requests
    still complete with an identity :class:`repro.serving.Completion`.

    The engine itself never streams (``_wants_stream`` is pinned False);
    the handoff carries the request's ``stream`` flag so token events
    start on the decode side with ``seq=0`` at the prefill-sampled first
    token — the same numbering a unified engine emits.  Any scheduler
    fits: admission size/shape delegate as usual, and a
    :class:`repro.serving.ShardedScheduler` shards the prefill itself.
    """

    def _wants_stream(self, request: Any) -> bool:
        return False                  # streaming starts on the decode side

    def _admit(self, new: List[Tuple[int, SlotTask]]
               ) -> Tuple[List[int], int]:
        finished, items = super()._admit(new)
        done = set(finished)
        for s, task in new:
            req = task.payload
            task.state["handoff"] = CacheHandoff(
                rid=task.rid, request=req,
                family=self.cfg.family, arch_id=self.cfg.arch_id,
                max_len=self.max_len, rows=None,
                tok=int(self._tok[s]), pos=int(self._pos[s]),
                out=list(task.state["out"]), left=int(task.state["left"]),
                done=(s in done),
                stream=bool(getattr(req, "stream", False)),
                cls=self._request_class(req),
                seed=int(getattr(req, "seed", None) or 0),
                temperature=float(getattr(req, "temperature", 0.0)),
                top_k=int(getattr(req, "top_k", 0) or 0),
                top_p=float(getattr(req, "top_p", 1.0)))
        # one batched slot-axis gather + one device sync for the whole
        # admission (not one per request), then an eager per-request
        # split of the already-gathered rows
        pending = [(s, task) for s, task in new
                   if not task.state["handoff"].done]
        if pending and self._pages is not None:
            # paged export: one pool-wide page copy for the whole group,
            # split per slot eagerly.  Slots retire right after this, so
            # registered pages demote to *cached* on the prefill pool —
            # a later request with the same prompt prefix still hits.
            per_slot = [self._pages.slot_pages(s) for s, _ in pending]
            flat = [p for ids in per_slot for p in ids]
            payload = jax.block_until_ready(
                self._pages.export_pages(self._pool, flat))
            res_all = jax.block_until_ready(
                self._pages.gather_residual_rows(
                    self._residual, [s for s, _ in pending]))
            base = 0
            for i, (s, task) in enumerate(pending):
                h = task.state["handoff"]
                n = len(per_slot[i])
                h.paged = True
                h.page_size = self._pages.page_size
                h.quantized = self._pages.quantize
                h.n_pages = n
                h.page_hashes = self._pages.slot_page_hashes(s)
                h.page_missing = list(range(n))
                h.rows = {
                    "pages": self._pages.take_payload(
                        payload, range(base, base + n)),
                    "residual": self._pages.gather_residual_rows(
                        res_all, [i]),
                }
                base += n
        elif pending:
            rows_all = jax.block_until_ready(self._gather(
                jnp.asarray([s for s, _ in pending], jnp.int32),
                self._caches))
            for i, (_, task) in enumerate(pending):
                task.state["handoff"].rows = lm.gather_cache_rows(
                    self.cfg, jnp.asarray([i], jnp.int32), rows_all)
        # every admitted slot retires this tick: the slot's state left in
        # the handoff, the slot itself is free for the next admission
        return [s for s, _ in new], items

    def _finalize(self, entry, latency_s: float):
        if not entry.tasks:           # max_new_tokens <= 0: identity
            return super()._finalize(entry, latency_s)
        return entry.tasks[0].state["handoff"]


class DecodeEngine(ServeEngine):
    """Decode half of a disaggregated pair.

    A :class:`repro.serving.ServeEngine` that admits
    :class:`HandoffRequest`\\ s: ``submit`` validates the handoff
    signature (family/arch/cache geometry/dtypes — a mismatch raises
    ``ValueError`` before any engine state changes, never decodes
    garbage), and admission *injects* instead of prefilling — the rows
    scatter into this engine's slot caches at the assigned slot, with
    the slot index routed through ``scheduler.place()`` and the rows
    replicated onto the scheduler's mesh when sharded.  Plain
    :class:`repro.serving.Request`\\ s are still accepted (it remains a
    full ServeEngine), so a decode engine can drain mixed traffic.
    """

    def __init__(self, *args, **kwargs):
        super().__init__(*args, **kwargs)
        self._expected_rows = lm.make_caches(self.cfg, 1, self.max_len,
                                             as_structs=True)

    def validate_handoff(self, h: CacheHandoff) -> None:
        """Raise ``ValueError`` unless ``h`` can be decoded *exactly*
        by this engine: same model family and arch, same cache length,
        and cache rows whose tree/shape/dtypes match this engine's own
        ``lm.make_caches`` geometry."""
        if h.family != self.cfg.family or h.arch_id != self.cfg.arch_id:
            raise ValueError(
                f"cache handoff rid={h.rid} was prefilled by model "
                f"family={h.family!r} arch={h.arch_id!r}; this decode "
                f"engine runs family={self.cfg.family!r} "
                f"arch={self.cfg.arch_id!r} — decoding it would produce "
                f"garbage, refusing")
        if h.max_len != self.max_len:
            raise ValueError(
                f"cache handoff rid={h.rid} carries max_len={h.max_len} "
                f"cache rows; this decode engine's slots are "
                f"max_len={self.max_len} — shapes cannot line up")
        if h.done:
            return                    # no rows travel with a done handoff
        if h.paged != (self._pages is not None):
            raise ValueError(
                f"cache handoff rid={h.rid} is "
                f"{'paged' if h.paged else 'dense'}; this decode engine's "
                f"cache is {'paged' if self._pages is not None else 'dense'}"
                f" — the layouts cannot splice")
        if h.paged:
            self._validate_paged(h)
            return
        want_leaves, want_def = jax.tree.flatten(self._expected_rows)
        got_leaves, got_def = jax.tree.flatten(h.rows)
        if want_def != got_def:
            raise ValueError(
                f"cache handoff rid={h.rid}: cache tree structure does "
                f"not match this engine's {self.cfg.family} cache "
                f"({got_def} != {want_def})")
        for w, g in zip(want_leaves, got_leaves):
            shape = tuple(getattr(g, "shape", ()))
            if shape != tuple(w.shape):
                raise ValueError(
                    f"cache handoff rid={h.rid}: cache leaf shape "
                    f"{shape} != expected {tuple(w.shape)}")
            if jnp.dtype(getattr(g, "dtype", None)) != jnp.dtype(w.dtype):
                raise ValueError(
                    f"cache handoff rid={h.rid}: cache leaf dtype "
                    f"{jnp.dtype(getattr(g, 'dtype', None))} != expected "
                    f"{jnp.dtype(w.dtype)}")

    def _validate_paged(self, h: CacheHandoff) -> None:
        """Paged half of :meth:`validate_handoff`: the page geometry and
        representation must agree exactly (hashes are only comparable
        between pools with identical seeds), and the travelling payload
        must match this pool's per-page leaf shapes/dtypes."""
        if (h.page_size != self._pages.page_size
                or h.quantized != self._pages.quantize):
            raise ValueError(
                f"cache handoff rid={h.rid} carries "
                f"page_size={h.page_size} quantized={h.quantized} pages; "
                f"this decode engine's pool is "
                f"page_size={self._pages.page_size} "
                f"quantized={self._pages.quantize} — page payloads and "
                f"prefix hashes are not interchangeable")
        missing = (h.page_missing if h.page_missing is not None
                   else list(range(h.n_pages)))
        covered = set(missing) | set(h.page_pinned or {})
        if covered != set(range(h.n_pages)):
            raise ValueError(
                f"cache handoff rid={h.rid}: travelling + pinned pages "
                f"cover positions {sorted(covered)}, need 0..{h.n_pages - 1}")
        want = dict(self._pages.page_payload_struct(len(missing)))
        want.update(self._pages.residual_rows_struct(1))
        got = {}
        if isinstance(h.rows, dict):
            got.update(h.rows.get("pages") or {})
            got.update(h.rows.get("residual") or {})
        if sorted(got) != sorted(want):
            raise ValueError(
                f"cache handoff rid={h.rid}: paged payload leaves "
                f"{sorted(got)} != expected {sorted(want)}")
        for k, w in want.items():
            g = got[k]
            if tuple(getattr(g, "shape", ())) != tuple(w.shape):
                raise ValueError(
                    f"cache handoff rid={h.rid}: paged leaf {k} shape "
                    f"{tuple(getattr(g, 'shape', ()))} != expected "
                    f"{tuple(w.shape)}")
            if jnp.dtype(getattr(g, "dtype", None)) != jnp.dtype(w.dtype):
                raise ValueError(
                    f"cache handoff rid={h.rid}: paged leaf {k} dtype "
                    f"{jnp.dtype(getattr(g, 'dtype', None))} != expected "
                    f"{jnp.dtype(w.dtype)}")

    # -- workload hooks ----------------------------------------------------

    def _expand(self, request: Any) -> Tuple[List[SlotTask], Dict[str, Any]]:
        if not isinstance(request, HandoffRequest):
            return super()._expand(request)
        self.validate_handoff(request.handoff)
        return [SlotTask(payload=request)], {}

    def _admit(self, new: List[Tuple[int, SlotTask]]
               ) -> Tuple[List[int], int]:
        plain = [(s, t) for s, t in new
                 if not isinstance(t.payload, HandoffRequest)]
        hand = [(s, t) for s, t in new
                if isinstance(t.payload, HandoffRequest)]
        finished, items = (super()._admit(plain) if plain else ([], 0))
        finished = list(finished)
        place = self.scheduler.place
        # one batched scatter for the whole handoff group (each jitted
        # scatter rewrites every cache leaf functionally, so k separate
        # injections would cost k whole-cache copies)
        live = [(s, t.payload.handoff) for s, t in hand
                if not t.payload.handoff.done]
        if live and self._pages is not None:
            self._admit_paged_handoffs(live)
        elif live:
            rows = lm.concat_cache_rows(self.cfg, [h.rows for _, h in live])
            self._caches = self._inject(
                self._place_rows(rows),
                place(np.asarray([s for s, _ in live], np.int32)),
                self._caches)
        for s, task in hand:
            h = task.payload.handoff
            task.state = {"out": list(h.out), "left": int(h.left)}
            self._tok[s] = h.tok
            self._pos[s] = h.pos
            # first token event: prefill sampled it, decode emits it, so
            # the stream starts at seq=0 exactly like a unified engine
            self._emit(task.rid, h.out[-1] if h.out else None)
            if h.left <= 0 or h.pos >= self.max_len:
                finished.append(s)
        return finished, items        # injected tokens were counted by
        #                               the prefill engine's stats

    def _admit_paged_handoffs(self, live: List[Tuple[int, CacheHandoff]]
                              ) -> None:
        """Splice a group of paged handoffs into this engine's pool: one
        batched ``import_pages`` for every travelling page in the group,
        front-end-pinned pages reused in place (their reference transfers
        to the slot binding), fresh pages registered under the hashes the
        prefill side advertised so *later* handoffs dedup against them."""
        all_ids: List[int] = []
        all_payloads: List[Dict[str, Any]] = []
        for s, h in live:
            pinned = h.page_pinned or {}
            missing = (h.page_missing if h.page_missing is not None
                       else list(range(h.n_pages)))
            fresh = self._alloc_pages(len(missing), s)
            allp: List[int] = [-1] * h.n_pages
            for pos, pg in pinned.items():
                allp[pos] = pg
            for j, pos in enumerate(missing):
                allp[pos] = fresh[j]
            hashes = h.page_hashes or []
            for j, pos in enumerate(missing):
                if pos < len(hashes) and hashes[pos] is not None:
                    self._pages.register_hash(fresh[j], hashes[pos])
            self._pages.bind_slot(s, allp)
            if fresh:
                all_ids.extend(fresh)
                all_payloads.append(h.rows["pages"])
        if all_ids:
            payload = {k: jnp.concatenate(
                           [jnp.asarray(p[k]) for p in all_payloads])
                       for k in all_payloads[0]}
            self._pool = self._pages.import_pages(self._pool, payload,
                                                  all_ids)
        res = [h.rows["residual"] for _, h in live]
        if res and self._pages.residual_specs():
            self._residual = self._pages.scatter_residual_rows(
                self._residual,
                self._pages.concat_residual_rows(res),
                np.asarray([s for s, _ in live], np.int32))

    def _request_class(self, request: Any) -> str:
        if isinstance(request, HandoffRequest):
            return request.handoff.cls
        return super()._request_class(request)


@dataclasses.dataclass
class _Tracked:
    """Front-end bookkeeping for one in-flight request."""

    t0: float                         # front-end submit wall-clock
    cls: str                          # request class (latency histogram)
    stream: bool


class DisaggregatedEngine:
    """Front-end over one prefill engine and N decode engines.

    Keeps the standard engine surface — ``submit() / poll() /
    run_until_idle() / stats() / warmup() / tick() / serve()`` — while
    requests flow prefill -> handoff queue -> decode.  Each ``tick()``
    asks ``scheduler.phase()`` (default :class:`DisaggScheduler`) which
    stage to run: ``"prefill"`` ticks the prefill engine, ``"handoff"``
    drains the handoff queue into decode engines, ``"decode"`` ticks the
    decode engines, ``"mixed"`` does all three.  Impossible answers are
    coerced exactly as :class:`repro.serving.EngineCore` does, so no
    scheduler can stall the front-end.

    **Streaming** — ``poll(stream=True)`` relays the decode engines'
    :class:`repro.serving.StreamEvent`\\ s: a request's whole stream comes
    from the one decode engine that owns it, so per-rid ``seq`` ordering
    holds across the handoff boundary, and the ``done`` event carries the
    same completion object plain ``poll()`` returns (with end-to-end
    latency: front-end submit to final token, both engine legs and the
    queue wait included).

    **Transport** — every rows-carrying handoff is *delivered* through a
    :class:`repro.serving.Transport` before the decode submit: the rows
    move into the target engine's memory space (in-process pass-through,
    blocking host staging, or async cross-mesh ``device_put`` — see
    ``repro.serving.transport``) and the per-leg timings land in
    ``stats().transfer`` as ``"<transport>/<leg>"`` histograms plus a
    ``"<transport>/total"`` critical-path histogram, next to the PR-5
    ``"handoff"`` queue-wait histogram.  ``transport`` accepts an
    instance, a name (``"in_process"`` / ``"host_staged"`` /
    ``"device_to_device"``), or ``"auto"`` (device-to-device when the
    decode pool owns meshes distinct from prefill's, else in-process).
    Stateless dispatch-only handoffs carry no rows and bypass the
    transport.  When both sides run a paged cache
    (``repro.serving.pages``), the payload is the slot's *pages* rather
    than dense rows, and before delivery the front-end pins the target
    pool's prefix-index hits for the advertised page hashes and strips
    them from the payload — only pages the target doesn't already hold
    travel (``stats().pages`` counts ``handoff_pages_moved`` /
    ``handoff_pages_dedup``).

    **Fault handling** — a decode engine whose transport delivery or
    ``submit`` raises during a handoff is marked dead and the handoff
    *requeues* onto the next engine (never dropped — a failed delivery
    leaves ``rows`` untouched, so the surviving route re-delivers the
    exact same state); a ``ValueError`` (typed handoff rejection)
    propagates instead, since it means a mis-built pair.  When every
    decode engine is dead the front-end raises rather than spin.

    **Elastic pool** — the decode side may grow and shrink while
    serving: ``add_decode()`` joins a fresh engine (it starts receiving
    handoffs on the next transfer), ``retire_decode()`` begins *draining*
    one (no new handoffs route to it; resident requests finish
    normally — the same property that makes failover safe makes retiring
    safe), and ``reap_retired()`` removes engines that finished
    draining.  Retired engines' work counters stay in the aggregated
    stats, and no request is ever dropped by a scale-down:
    ``retire_decode`` refuses to drain the last live engine.  The
    :class:`repro.traffic.AutoscaleController` closes the loop by
    driving these on the ``depth_summary()`` signal.

    **Stats** — aggregated :class:`repro.serving.EngineStats`: items /
    ticks / wall-clock summed over the member engines, completion counts
    and end-to-end latency histograms owned by the front-end, plus
    per-phase queue-depth histograms (``depth``) and handoff
    transfer-latency histograms (``transfer``).

    ``prefill=None`` is the stateless degenerate form (no carried state,
    e.g. :class:`repro.serving.CapsuleEngine` pools): submissions become
    dispatch-only handoffs and the front-end is a validating
    load-balancer with the same phase/stats machinery.
    """

    def __init__(self, prefill: Optional[EngineCore],
                 decodes: List[EngineCore],
                 scheduler: Optional[Scheduler] = None,
                 clock: Callable[[], float] = time.perf_counter,
                 transport: Optional[Any] = None):
        if not decodes:
            raise ValueError("need at least one decode engine")
        self.prefill = prefill
        if transport is None:
            transport = InProcessTransport()
        elif transport == "auto":
            transport = select_transport(prefill, decodes)
        elif isinstance(transport, str):
            transport = make_transport(transport)
        elif not isinstance(transport, Transport):
            raise TypeError(f"transport must be a Transport instance or "
                            f"name, got {type(transport).__name__}")
        self.transport = transport    # set once here, never rebound
        self.decodes = list(decodes)              # guarded-by: _tick_lock
        self.capacity = sum(e.capacity            # guarded-by: _tick_lock
                            for e in self.decodes)
        self.scheduler = scheduler or DisaggScheduler()
        self.scheduler.bind(self)
        self._clock = clock
        self._handoffs: Deque[CacheHandoff] = deque()   # guarded-by: _lock
        self._inflight: Dict[int, _Tracked] = {}        # guarded-by: _lock
        self._completions: Deque[Any] = deque()         # guarded-by: _lock
        self._events: Deque[StreamEvent] = deque()      # guarded-by: _lock
        self._stats = EngineStats()                     # guarded-by: _lock
        self._next_rid = 0                              # guarded-by: _lock
        # engine-identity sets/lists (indices would go stale as the
        # elastic pool grows and shrinks):
        # _dead = submit raised mid-handoff; _draining = retiring, drains
        # but takes no new work; _retired = removed, stats retained
        self._dead: Set[EngineCore] = set()       # guarded-by: _tick_lock
        self._draining: Set[EngineCore] = set()   # guarded-by: _tick_lock
        self._retired: List[EngineCore] = []      # guarded-by: _tick_lock
        self._rr = 0    # round-robin cursor      # guarded-by: _tick_lock
        self._lock = threading.Lock()
        self._tick_lock = threading.Lock()

    # -- shared surface ----------------------------------------------------

    def submit(self, request: Any) -> int:
        """Enqueue one request (thread-safe, non-blocking); returns its
        rid.  Validation errors (malformed payloads) raise before any
        front-end or member-engine state changes."""
        front = self.prefill if self.prefill is not None else self.decodes[0]
        cls = front._request_class(request)
        stream = bool(getattr(request, "stream", False))
        with self._lock:
            rid, self._next_rid = allocate_rid(request, self._inflight,
                                               self._next_rid)
            # registered before the member submit: the ticker may finish
            # the request between that submit and any later bookkeeping
            self._inflight[rid] = _Tracked(t0=self._clock(), cls=cls,
                                           stream=stream)
        try:
            if self.prefill is not None:
                self.prefill.submit(request)
            else:
                self.decodes[0]._expand(request)   # validate eagerly
                with self._lock:
                    self._handoffs.append(CacheHandoff(
                        rid=rid, request=request, family=None, arch_id=None,
                        max_len=0, rows=None, tok=0, pos=0, out=[], left=0,
                        stateless=True, stream=stream, cls=cls,
                        t_handoff=self._clock()))
        except BaseException:
            with self._lock:
                self._inflight.pop(rid, None)
            raise
        return rid

    def poll(self, stream: bool = False) -> List[Any]:
        """Drain completions (or, with ``stream=True``, the relayed
        :class:`repro.serving.StreamEvent`\\ s) ready so far — the same
        two-channel contract as :class:`repro.serving.EngineCore`."""
        out: List[Any] = []
        with self._lock:
            src = self._events if stream else self._completions
            while src:
                out.append(src.popleft())
        return out

    def tick(self) -> bool:
        """One front-end step; returns False when every stage was idle."""
        with self._tick_lock:
            with self._lock:
                n_handoff = len(self._handoffs)
            n_prefill = self.prefill.n_pending if self.prefill else 0
            n_decode = sum(e.n_pending for e in self.decodes)
            sched = self.scheduler
            if hasattr(sched, "handoff_depth"):
                sched.handoff_depth = n_handoff
            phase = sched.phase(n_prefill + n_handoff, n_decode)
            if phase not in ("prefill", "handoff", "decode"):
                phase = "mixed"
            elif phase == "prefill" and (self.prefill is None
                                         or n_prefill == 0):
                phase = "mixed"
            elif phase == "handoff" and n_handoff == 0:
                phase = "mixed"
            elif phase == "decode" and n_decode == 0:
                phase = "mixed"
            if n_prefill or n_handoff or n_decode:
                # depth records *backlog awaiting service* (queue-only,
                # the same quantity EngineCore.tick records) — n_pending
                # above additionally counts residents, which phase
                # decisions need but depth histograms must not
                q_pre = self.prefill.n_queued if self.prefill else 0
                q_dec = sum(e.n_queued for e in self.decodes)
                with self._lock:
                    st = self._stats
                    if self.prefill is not None:  # stateless pools have
                        st.depth.setdefault(      # no prefill stage
                            "prefill", DepthHistogram()).record(q_pre)
                    st.depth.setdefault(
                        "handoff", DepthHistogram()).record(n_handoff)
                    st.depth.setdefault(
                        "decode", DepthHistogram()).record(q_dec)
            busy = False
            if phase in ("mixed", "prefill") and self.prefill is not None:
                busy |= self.prefill.tick()
            # always collect: handoffs/completions parked inside a member
            # engine are invisible to n_pending until moved up here
            self._collect_prefill()
            if phase in ("mixed", "handoff"):
                busy |= self._transfer_all_locked() > 0
            if phase in ("mixed", "decode"):
                # dead engines (submit raised) still tick: they receive no
                # new handoffs, but any resident work must drain — and a
                # genuinely dead engine's tick() raising is an explicit
                # failure, never a silent hang
                for eng in self.decodes:
                    busy |= eng.tick()
            self._collect_decode()
            return busy

    def run_until_idle(self) -> List[Any]:
        """Tick until every stage drains; returns the completions ready
        at exit (streaming events stay queued for ``poll(stream=True)``)."""
        while True:
            if self.tick():
                continue
            if self.n_pending == 0:
                return self.poll()

    def serve(self, requests: List[Any]) -> List[Any]:
        """Submit all requests and run them to completion."""
        for r in requests:
            self.submit(r)
        return self.run_until_idle()

    def warmup(self) -> None:
        for eng in self._members():
            eng.warmup()

    def stats(self) -> EngineStats:
        """Aggregated snapshot: member-engine work counters summed,
        front-end completion/latency/depth/transfer histograms copied."""
        agg = EngineStats()
        for eng in self._members():
            s = eng.stats()
            agg.items += s.items
            agg.padded += s.padded
            agg.ticks += s.ticks
            agg.wall_s += s.wall_s
            for k, v in s.pages.items():
                agg.pages[k] = agg.pages.get(k, 0) + v
        with self._lock:
            agg.completed = self._stats.completed
            agg.latency = {k: h.copy()
                           for k, h in self._stats.latency.items()}
            agg.depth = {k: h.copy() for k, h in self._stats.depth.items()}
            agg.transfer = {k: h.copy()
                            for k, h in self._stats.transfer.items()}
            for k, v in self._stats.pages.items():
                agg.pages[k] = agg.pages.get(k, 0) + v
        return agg

    @property
    def n_pending(self) -> int:
        """Queued handoffs + pending work in every member engine."""
        n = sum(e.n_pending for e in self.decodes)
        if self.prefill is not None:
            n += self.prefill.n_pending
        with self._lock:
            return n + len(self._handoffs)

    @property
    def n_live_decodes(self) -> int:
        """Decode engines currently accepting new handoffs (excludes
        dead and draining engines) — the autoscaler's pool-size view."""
        return len([e for e in self.decodes
                    if e not in self._dead and e not in self._draining])

    @property
    def handoff_backlog(self) -> int:
        """Handoffs parked between prefill and decode right now."""
        with self._lock:
            return len(self._handoffs)

    # -- elastic decode pool -----------------------------------------------

    def add_decode(self, engine: EngineCore) -> None:
        """Join one decode engine to the pool (thread-safe; takes effect
        on the next handoff transfer).  The caller warms it up."""
        with self._tick_lock:
            self.decodes.append(engine)
            self.capacity += engine.capacity

    def retire_decode(self, engine: Optional[EngineCore] = None
                      ) -> Optional[EngineCore]:
        """Begin draining one decode engine: it receives no new handoffs,
        resident requests finish normally, and once idle
        ``reap_retired()`` removes it.  ``engine=None`` picks the
        newest live engine.  Returns the draining engine, or ``None``
        when there is no candidate — the last live engine is never
        drained, so a scale-down can never strand traffic."""
        with self._tick_lock:
            live = [e for e in self.decodes
                    if e not in self._dead and e not in self._draining]
            if engine is None:
                if len(live) <= 1:
                    return None
                engine = live[-1]
            elif engine not in live or len(live) <= 1:
                return None
            self._draining.add(engine)
            return engine

    def reap_retired(self) -> List[EngineCore]:
        """Remove draining engines that finished their resident work.
        Their cumulative work counters stay in ``stats()`` (the
        aggregate includes retired engines), so scale-downs never make
        the monotone stats run backwards."""
        with self._tick_lock:
            done = [e for e in self.decodes
                    if e in self._draining and e.n_pending == 0]
            for e in done:
                # drain parked results first: a completion left inside a
                # removed engine would be a silently dropped request
                for c in e.poll():
                    self._finish(c)
                evs = e.poll(stream=True)
                if evs:
                    with self._lock:
                        self._events.extend(evs)
                self._draining.discard(e)
                self.decodes.remove(e)
                self.capacity -= e.capacity
                self._retired.append(e)
            return done

    # -- internals ---------------------------------------------------------

    def _members(self) -> List[EngineCore]:
        return (([self.prefill] if self.prefill is not None else [])
                + self.decodes + self._retired)

    def _collect_prefill(self) -> None:
        if self.prefill is None:
            return
        for c in self.prefill.poll():
            if isinstance(c, CacheHandoff):
                c.t_handoff = self._clock()
                with self._lock:
                    self._handoffs.append(c)
            else:                     # identity completion (no decode leg)
                self._finish(c)

    def _collect_decode(self) -> None:
        for eng in self.decodes:
            for c in eng.poll():
                self._finish(c)
            evs = eng.poll(stream=True)
            if evs:
                with self._lock:
                    self._events.extend(evs)

    def _finish(self, completion: Any) -> None:
        now = self._clock()
        with self._lock:
            tr = self._inflight.pop(getattr(completion, "rid", None), None)
            if tr is not None:
                # end-to-end latency (both engine legs + the queue wait);
                # the decode engine stamped only its own leg.  The done
                # StreamEvent shares this object, so the stream sees the
                # same number.
                completion.latency_s = max(now - tr.t0, 0.0)
                self._stats.completed += 1
                self._stats.latency.setdefault(
                    tr.cls, LatencyHistogram()).record(completion.latency_s)
            self._completions.append(completion)

    def _transfer_all_locked(self) -> int:
        """Drain the handoff queue into the decode pool.  ``_locked`` =
        the caller holds ``_tick_lock`` (the engine-pool views read and
        written here — ``_dead``, ``_rr`` — are tick-owned); ``_lock`` is
        still taken internally for the handoff queue itself."""
        moved = 0
        while True:
            with self._lock:
                if not self._handoffs:
                    return moved
                h = self._handoffs.popleft()
            if self._transfer_one_locked(h):
                moved += 1
            else:
                with self._lock:       # requeued, never dropped
                    self._handoffs.appendleft(h)
                if not [e for e in self.decodes if e not in self._dead]:
                    raise RuntimeError(
                        f"all {len(self.decodes)} decode engines failed; "
                        f"{len(self._handoffs)} handoff(s) requeued and "
                        f"stranded")
                return moved

    def _transfer_one_locked(self, h: CacheHandoff) -> bool:
        # draining engines take no new work — unless every live engine is
        # draining (a mis-driven controller), in which case serving beats
        # stranding the handoff
        cands = [e for e in self.decodes
                 if e not in self._dead and e not in self._draining]
        if not cands:
            cands = [e for e in self.decodes if e not in self._dead]
        n = len(cands)
        for k in range(n):
            eng = cands[(self._rr + k) % n]
            pinned, full_rows = self._dedup_pages(h, eng)
            try:
                if h.stateless:
                    rec = None        # dispatch-only: no rows to move
                    eng.submit(h.request)
                else:
                    # deliver-then-submit: the transport moves the rows
                    # into the target engine's memory space (a failed
                    # delivery leaves them untouched, so the next
                    # candidate re-delivers identical state)
                    rec = self.transport.deliver(h, eng)
                    eng.submit(HandoffRequest(handoff=h, rid=h.rid,
                                              stream=h.stream))
            except ValueError:
                # typed handoff rejection: a mis-built pair is a real bug
                # and must surface — but the never-dropped invariant still
                # holds, so the handoff goes back on the queue first
                self._undedup_pages(h, eng, pinned, full_rows)
                with self._lock:
                    self._handoffs.appendleft(h)
                raise
            # Engine (or its transport route) died mid-handoff: *any*
            # failure class here means the same thing — mark it dead and
            # fail over to the next candidate.  Nothing is swallowed: the
            # handoff is requeued by the caller (never-dropped invariant)
            # and a fully-dead pool raises RuntimeError there.
            # capslint: disable=exception-hygiene
            except Exception:
                self._undedup_pages(h, eng, pinned, full_rows)
                self._dead.add(eng)
                continue
            self._rr = (self._rr + k + 1) % max(n, 1)
            with self._lock:
                tr = self._stats.transfer
                tr.setdefault("handoff", LatencyHistogram()).record(
                    max(self._clock() - h.t_handoff, 0.0))
                if rec is not None:
                    for leg, s in rec.legs.items():
                        tr.setdefault(f"{rec.transport}/{leg}",
                                      LatencyHistogram()).record(s)
                    tr.setdefault(f"{rec.transport}/total",
                                  LatencyHistogram()).record(rec.total_s)
                if h.paged and not h.done:
                    pg = self._stats.pages
                    moved = len(h.page_missing
                                if h.page_missing is not None
                                else range(h.n_pages))
                    pg["handoff_pages_moved"] = (
                        pg.get("handoff_pages_moved", 0) + moved)
                    pg["handoff_pages_dedup"] = (
                        pg.get("handoff_pages_dedup", 0) + len(pinned))
            return True
        return False                  # caller requeues

    def _dedup_pages(self, h: CacheHandoff, eng: EngineCore
                     ) -> Tuple[Dict[int, int], Optional[Any]]:
        """Before delivering a paged handoff, pin the target pool's
        prefix-index hits for the advertised page hashes and strip those
        pages from the travelling payload — the handoff then moves only
        what the target doesn't already hold.  Returns the pins and the
        saved full payload for the failure unwind."""
        if (h.stateless or h.done or not h.paged or not h.page_hashes
                or not isinstance(h.rows, dict)):
            return {}, None
        pin = getattr(eng, "pin_page_hashes", None)
        if pin is None:
            return {}, None
        pinned = pin(h.page_hashes)
        if not pinned:
            return {}, None
        full_rows = h.rows
        missing = [i for i in range(h.n_pages) if i not in pinned]
        h.page_pinned = dict(pinned)
        h.page_missing = missing
        h.rows = {"pages": PagePool.take_payload(full_rows["pages"],
                                                 missing),
                  "residual": full_rows["residual"]}
        return pinned, full_rows

    def _undedup_pages(self, h: CacheHandoff, eng: EngineCore,
                       pinned: Dict[int, int], full_rows: Optional[Any]
                       ) -> None:
        """Failed delivery: restore the full payload and drop the pins
        taken on the failed target, so the next candidate (with its own
        prefix index) re-dedups from scratch."""
        if full_rows is not None:
            h.rows = full_rows
            h.page_missing = list(range(h.n_pages))
        h.page_pinned = None
        if pinned:
            eng.release_page_pins(list(pinned.values()))


def disaggregated_lm_engine(cfg, params, n_slots: int = 4,
                            max_len: int = 512, seed: int = 0,
                            n_decode: int = 1,
                            prefill_slots: Optional[int] = None,
                            prefill_scheduler: Optional[Scheduler] = None,
                            decode_schedulers: Optional[
                                List[Optional[Scheduler]]] = None,
                            scheduler: Optional[Scheduler] = None,
                            clock: Callable[[], float] = time.perf_counter,
                            kernel_tune: Optional[bool] = None,
                            transport: Optional[Any] = None,
                            page_size: Optional[int] = None,
                            n_pages: Optional[int] = None,
                            quantize_pages: bool = False,
                            decode_kernel: bool = False
                            ) -> DisaggregatedEngine:
    """The standard LM disaggregation: one :class:`PrefillEngine` feeding
    ``n_decode`` :class:`DecodeEngine`\\ s of ``n_slots`` slots each,
    sharing ``params``.  ``decode_schedulers`` (one per decode engine —
    scheduler instances are stateful and must never be shared) lets e.g.
    a :class:`repro.serving.ShardedScheduler` place each decode engine on
    its own mesh; ``scheduler`` is the front-end phase policy
    (:class:`repro.serving.DisaggScheduler` by default); ``transport``
    is the handoff delivery route (instance, name, or ``"auto"`` — see
    :class:`repro.serving.Transport`)."""
    if decode_schedulers is None:
        decode_schedulers = [None] * n_decode
    if len(decode_schedulers) != n_decode:
        raise ValueError(f"need one decode scheduler per engine "
                         f"({len(decode_schedulers)} != {n_decode})")
    pk = dict(page_size=page_size, n_pages=n_pages,
              quantize_pages=quantize_pages, decode_kernel=decode_kernel)
    pre = PrefillEngine(cfg, params, n_slots=prefill_slots or n_slots,
                        max_len=max_len, seed=seed,
                        scheduler=prefill_scheduler, clock=clock,
                        kernel_tune=kernel_tune, **pk)
    dec = [DecodeEngine(cfg, params, n_slots=n_slots, max_len=max_len,
                        seed=seed, scheduler=decode_schedulers[i],
                        clock=clock, kernel_tune=kernel_tune, **pk)
           for i in range(n_decode)]
    return DisaggregatedEngine(pre, dec, scheduler=scheduler, clock=clock,
                               transport=transport)


def multihost_disaggregated_lm_engine(cfg, params, n_slots: int = 4,
                                      max_len: int = 512, seed: int = 0,
                                      n_decode: int = 1,
                                      prefill_slots: Optional[int] = None,
                                      scheduler: Optional[Scheduler] = None,
                                      clock: Callable[[], float]
                                      = time.perf_counter,
                                      kernel_tune: Optional[bool] = None,
                                      transport: Optional[Any] = "auto",
                                      devices: Optional[List[Any]] = None,
                                      page_size: Optional[int] = None,
                                      n_pages: Optional[int] = None,
                                      quantize_pages: bool = False,
                                      decode_kernel: bool = False
                                      ) -> DisaggregatedEngine:
    """Multi-host-shaped LM disaggregation: prefill and every decode
    engine own **distinct meshes** over disjoint device groups
    (:func:`repro.parallel.sharding.disjoint_submeshes`), so a cache
    handoff genuinely crosses a device boundary and the transport does
    real work.  Each engine replicates its own copy of ``params`` onto
    its mesh and shards its slot caches there — the multi-host memory
    model, emulated in one process (on a 1-device host the submeshes
    degrade to shared-device placement, so the topology still runs
    everywhere).

    ``transport`` defaults to ``"auto"``, which selects by *actual*
    placement: on a multi-device host the decode meshes are distinct
    from prefill's, so rows move cross-mesh via
    :class:`repro.serving.DeviceToDeviceTransport` (async dispatch,
    overlapped with decode ticks); on a 1-device host the degenerate
    submeshes share the one device and auto stays in-process (nothing
    needs to move).
    """
    from repro.parallel.sharding import disjoint_submeshes

    meshes = disjoint_submeshes(1 + n_decode, devices=devices)
    pk = dict(page_size=page_size, n_pages=n_pages,
              quantize_pages=quantize_pages, decode_kernel=decode_kernel)
    pre = PrefillEngine(cfg, params, n_slots=prefill_slots or n_slots,
                        max_len=max_len, seed=seed,
                        scheduler=ShardedScheduler(meshes[0]), clock=clock,
                        kernel_tune=kernel_tune, **pk)
    dec = [DecodeEngine(cfg, params, n_slots=n_slots, max_len=max_len,
                        seed=seed, scheduler=ShardedScheduler(meshes[1 + i]),
                        clock=clock, kernel_tune=kernel_tune, **pk)
           for i in range(n_decode)]
    return DisaggregatedEngine(pre, dec, scheduler=scheduler, clock=clock,
                               transport=transport)
