"""Generate the EXPERIMENTS.md §Dry-run and §Roofline tables from the
dry-run JSON artifacts.

    PYTHONPATH=src python -m repro.launch.report --dir experiments/dryrun
"""

from __future__ import annotations

import argparse
import json
from typing import Any, Dict, List

from repro.launch import roofline as rf


def gib(b: float) -> str:
    return f"{b / 2**30:.2f}"


def dryrun_table(recs: List[Dict[str, Any]]) -> str:
    hdr = ("| arch | shape | mesh | args GiB/dev | temp GiB/dev | "
           "flops/dev | coll bytes/dev | AG/AR/RS/A2A | compile s |")
    sep = "|" + "---|" * 9
    lines = [hdr, sep]
    for r in recs:
        cc = r.get("collective_counts", {})
        counts = "/".join(str(int(cc.get(k, 0))) for k in
                          ("all-gather", "all-reduce", "reduce-scatter",
                           "all-to-all"))
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {gib(r['arg_bytes_per_dev'])} "
            f"| {gib(r['temp_bytes_per_dev'])} "
            f"| {r['flops_per_dev']:.3e} "
            f"| {r['collective_bytes_per_dev']:.3e} "
            f"| {counts} | {r['compile_s']:.0f} |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--section", choices=["dryrun", "roofline", "both"],
                    default="both")
    args = ap.parse_args()
    recs = rf.load_records(args.dir)
    recs.sort(key=lambda r: (r["arch"], r["shape"], r["mesh"]))
    if args.section in ("dryrun", "both"):
        print("### Dry-run matrix\n")
        print(dryrun_table(recs))
        print()
    if args.section in ("roofline", "both"):
        print("### Roofline (single-pod 16x16)\n")
        rows = [rf.analyze(r) for r in recs if r["mesh"] == "16x16"]
        print(rf.to_markdown(rows))


if __name__ == "__main__":
    main()
