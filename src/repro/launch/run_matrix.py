"""Drive the full dry-run matrix, one subprocess per cell (isolates compile
memory; a failed cell cannot take down the sweep).

    PYTHONPATH=src python -m repro.launch.run_matrix \
        --out experiments/dryrun --hlo-dir experiments/hlo --mesh both
"""

from __future__ import annotations

import argparse
import os
import subprocess
import sys
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--hlo-dir", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--timeout", type=int, default=2400)
    args = ap.parse_args()

    from repro import configs as cfg_lib   # no jax involvement
    from repro.launch.dryrun import CAPSNET_SHAPES

    cells = list(cfg_lib.CELLS) + [
        (a, s) for a in cfg_lib.PAPER_ARCHS for s in CAPSNET_SHAPES]
    meshes = {"single": ["single"], "multi": ["multi"],
              "both": ["single", "multi"]}[args.mesh]

    failures = []
    t0 = time.time()
    for arch, shape in cells:
        if not arch.startswith("capsnet") and cfg_lib.cell_status(arch,
                                                                  shape):
            print(f"[skip] {arch:22s} {shape:12s} "
                  f"{cfg_lib.cell_status(arch, shape)}", flush=True)
            continue
        for mesh in meshes:
            mesh_name = "2x16x16" if mesh == "multi" else "16x16"
            if args.skip_existing:
                f = os.path.join(args.out,
                                 f"{arch}__{shape}__{mesh_name}.json")
                if os.path.exists(f):
                    print(f"[have] {arch:22s} {shape:12s} {mesh_name}",
                          flush=True)
                    continue
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", arch, "--shape", shape, "--mesh", mesh,
                   "--out", args.out]
            if args.hlo_dir and mesh == "single":
                cmd += ["--hlo-dir", args.hlo_dir]
            try:
                r = subprocess.run(cmd, capture_output=True, text=True,
                                   timeout=args.timeout)
                ok_lines = [l for l in r.stdout.splitlines()
                            if l.startswith("[ ok ]")]
                if r.returncode == 0 and ok_lines:
                    print(ok_lines[-1], flush=True)
                else:
                    failures.append((arch, shape, mesh_name))
                    tail = (r.stdout + r.stderr).strip().splitlines()[-6:]
                    print(f"[FAIL] {arch} {shape} {mesh_name}:", flush=True)
                    for line in tail:
                        print("   ", line, flush=True)
            except subprocess.TimeoutExpired:
                failures.append((arch, shape, mesh_name, "timeout"))
                print(f"[TIMEOUT] {arch} {shape} {mesh_name}", flush=True)
    dt = time.time() - t0
    print(f"\nmatrix done in {dt/60:.1f} min; {len(failures)} failures")
    for f in failures:
        print("  FAIL:", *f)
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
