"""Training launcher: end-to-end driver for any registered architecture.

    # reduced config on CPU (smoke / examples):
    PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b \
        --reduced --steps 50 --batch 8 --seq 64

    # CapsNet (the paper's model) with the FastCaps prune pipeline:
    PYTHONPATH=src python -m repro.launch.train --arch capsnet-mnist \
        --steps 200 --prune lakp:0.97 --finetune-steps 100

On a real fleet the same driver runs under the production mesh with the
sharded train step (parallel/sharding.py); here it runs on the host
devices so the full loop (data -> step -> checkpoint -> resume) is
exercised end to end.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs as cfg_lib
from repro.core import capsnet as capsnet_lib
from repro.core import pruning as pruning_lib
from repro.data import synthetic_digits, tokens
from repro.deploy import FastCapsPipeline
from repro.models import lm
from repro.optim import AdamWConfig
from repro.training import Trainer, TrainerConfig


def train_lm(args) -> None:
    cfg = cfg_lib.get_config(args.arch)
    if args.reduced:
        cfg = cfg_lib.reduced(cfg)
    stream = tokens.TokenStream(tokens.TokenStreamConfig(vocab=cfg.vocab))
    tcfg = TrainerConfig(
        optim=AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                          total_steps=args.steps),
        ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
        log_every=args.log_every)
    trainer = Trainer(tcfg, lambda p, b: lm.loss_fn(p, cfg, b),
                      lambda k: lm.init(cfg, k))
    t0 = time.time()
    res = trainer.run(stream.batches(args.batch, args.seq, args.steps),
                      args.steps)
    dt = time.time() - t0
    print(f"[{cfg.arch_id}] {res.step} steps in {dt:.1f}s "
          f"({res.step / dt:.2f} steps/s)")
    for h in res.history:
        print("  ", {k: round(v, 4) for k, v in h.items()})


def train_capsnet(args) -> None:
    cfg = cfg_lib.get_config(args.arch)
    if args.reduced:
        cfg = cfg_lib.reduced(cfg)
    variant = "fashion" if "fmnist" in args.arch else "digits"
    data = synthetic_digits.load(synthetic_digits.DigitsConfig(
        variant=variant, n_train=args.n_train, n_test=args.n_test))
    tr_x, tr_y = data["train"]
    te_x, te_y = data["test"]

    def loss_fn(p, b):
        return capsnet_lib.loss_fn(p, cfg, b["images"], b["labels"])

    def batches(n, seed=0):
        for bx, by in synthetic_digits.batches(tr_x, tr_y, args.batch, seed,
                                               epochs=1000):
            yield {"images": bx, "labels": by}

    tcfg = TrainerConfig(
        optim=AdamWConfig(lr=args.lr, weight_decay=0.0,
                          warmup_steps=max(args.steps // 10, 1),
                          total_steps=args.steps),
        ckpt_dir=args.ckpt_dir, log_every=args.log_every)
    trainer = Trainer(tcfg, loss_fn, lambda k: capsnet_lib.init(cfg, k))
    res = trainer.run(batches(args.steps), args.steps)
    print(f"[{cfg.arch_id}] trained {res.step} steps; "
          f"final: {res.history[-1] if res.history else {}}")

    eval_fn = jax.jit(lambda p, x: capsnet_lib.forward(p, cfg, x)[0])
    acc = float(jnp.mean((jnp.argmax(eval_fn(res.params, te_x), -1)
                          == te_y)))
    print(f"  test acc (dense): {acc:.4f}")

    if args.prune:
        method, rate = args.prune.split(":")
        rate = float(rate)
        def finetune(masked, masks):
            ft = Trainer(
                TrainerConfig(optim=AdamWConfig(
                    lr=args.lr / 3, weight_decay=0.0,
                    warmup_steps=1, total_steps=args.finetune_steps)),
                loss_fn, lambda k: masked,
                mask_fn=lambda g: pruning_lib.mask_gradients(g, masks))
            return ft.run(batches(args.finetune_steps),
                          args.finetune_steps).params
        pipe = FastCapsPipeline(cfg, params=res.params)
        pipe.prune(rate, rate, method=method).finetune(finetune)
        acc_p = float(jnp.mean((jnp.argmax(
            eval_fn(pipe.params, te_x), -1) == te_y)))
        deployed = pipe.compact().compile()
        acc_c = float(jnp.mean((deployed.classify(te_x) == te_y)))
        c_cfg = deployed.cfg
        print(f"  pruned[{method}:{rate}] compression="
              f"{pipe.compression:.4f} "
              f"index_overhead={pipe.index_overhead_frac:.5f}")
        print(f"  test acc (pruned+finetuned): {acc_p:.4f}; "
              f"compacted ({c_cfg.caps_types}/{cfg.caps_types} capsule "
              f"types, {c_cfg.n_primary_caps} capsules): {acc_c:.4f}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True,
                    choices=cfg_lib.list_archs())
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    # capsnet options
    ap.add_argument("--prune", default=None, help="lakp:0.97 | kp:0.97")
    ap.add_argument("--finetune-steps", type=int, default=50)
    ap.add_argument("--n-train", type=int, default=512)
    ap.add_argument("--n-test", type=int, default=256)
    args = ap.parse_args()
    if args.arch.startswith("capsnet"):
        train_capsnet(args)
    else:
        train_lm(args)


if __name__ == "__main__":
    main()
