import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) cell and record memory / cost / collective analysis.

This is how the distribution config is proven coherent without hardware:
jax.jit(step, in_shardings, out_shardings).lower(**structs).compile() runs
the full GSPMD partitioner for the production mesh; sharding mismatches,
compile-time OOMs and unsupported collectives all fail HERE.

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-1.7b \
        --shape train_4k --mesh single --out experiments/dryrun
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both

Results (one JSON per cell) feed launch/roofline.py and EXPERIMENTS.md.
"""

import argparse
import dataclasses
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro import configs as cfg_lib
from repro.core import capsnet as capsnet_lib
from repro.launch import hlo_analysis, hlo_cost
from repro.launch.mesh import (make_production_mesh, mesh_context,
                              require_virtual_devices)
from repro.models import common, lm
from repro.models.common import LMConfig
from repro.optim import adamw
from repro.parallel import sharding as shard_lib


# ---------------------------------------------------------------------------
# Cell construction: (fn, arg structs, arg shardings)
# ---------------------------------------------------------------------------


def _train_cell(cfg: LMConfig, shape: str, rules, mesh):
    params = lm.param_structs(cfg)
    opt = jax.eval_shape(adamw.init_state, params)
    batch = cfg_lib.input_specs(cfg, shape)

    params_ax = lm.specs(cfg)
    params_sh = shard_lib.shardings_for(params, params_ax, rules, mesh)
    opt_sh = {"m": params_sh, "v": params_sh,
              "step": shard_lib.shardings_for(
                  opt["step"], None, rules, mesh)}
    batch_sh = shard_lib.shardings_for(
        batch, cfg_lib.batch_axes(cfg, shape), rules, mesh)
    ocfg = adamw.AdamWConfig()

    def step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: lm.loss_fn(p, cfg, batch), has_aux=True)(params)
        new_p, new_o, om = adamw.apply_updates(params, grads, opt_state, ocfg)
        return new_p, new_o, dict(metrics, **om)

    return (step, (params, opt, batch), (params_sh, opt_sh, batch_sh),
            (params_sh, opt_sh, None))


def _prefill_cell(cfg: LMConfig, shape: str, rules, mesh):
    info = cfg_lib.SHAPES[shape]
    b, s = info["batch"], info["seq"]
    batch = cfg_lib.input_specs(cfg, shape)
    params = lm.param_structs(cfg)
    params_sh = shard_lib.shardings_for(params, lm.specs(cfg), rules, mesh)
    batch_sh = shard_lib.shardings_for(
        batch, cfg_lib.batch_axes(cfg, shape), rules, mesh)

    if cfg.family == "audio":
        def enc(params, batch):
            x, _, _ = lm.forward(params, cfg, batch)
            return common.unembed(params["embed"], cfg, x[:, -1:, :])
        return enc, (params, batch), (params_sh, batch_sh), None

    caches = lm.make_caches(cfg, b, s, as_structs=True)
    caches_sh = shard_lib.shardings_for(caches, lm.cache_specs(cfg), rules,
                                        mesh)

    def prefill(params, batch, caches):
        return lm.prefill_step(params, cfg, batch, caches)

    return (prefill, (params, batch, caches),
            (params_sh, batch_sh, caches_sh), (None, caches_sh))


def _decode_cell(cfg: LMConfig, shape: str, rules, mesh):
    info = cfg_lib.SHAPES[shape]
    b, s = info["batch"], info["seq"]
    batch = cfg_lib.input_specs(cfg, shape)
    params = lm.param_structs(cfg)
    params_sh = shard_lib.shardings_for(params, lm.specs(cfg), rules, mesh)
    batch_sh = shard_lib.shardings_for(
        batch, cfg_lib.batch_axes(cfg, shape), rules, mesh)
    caches = lm.make_caches(cfg, b, s, as_structs=True)
    caches_sh = shard_lib.shardings_for(caches, lm.cache_specs(cfg), rules,
                                        mesh)

    def decode(params, batch, caches):
        return lm.decode_step(params, cfg, batch, caches)

    return (decode, (params, batch, caches),
            (params_sh, batch_sh, caches_sh), (None, caches_sh))


def _capsnet_cell(cfg, shape: str, rules, mesh):
    b = {"train_1k": 1024, "infer_1k": 1024}[shape]
    params = jax.eval_shape(
        lambda k: capsnet_lib.init(cfg, k),
        jax.ShapeDtypeStruct((2,), jnp.uint32))
    params_sh = shard_lib.shardings_for(params, capsnet_lib.specs(cfg),
                                        rules, mesh)
    images = jax.ShapeDtypeStruct((b, cfg.image_hw, cfg.image_hw,
                                   cfg.in_channels), jnp.float32)
    labels = jax.ShapeDtypeStruct((b,), jnp.int32)
    im_sh = shard_lib.shardings_for(images, ("batch", None, None, None),
                                    rules, mesh)
    lb_sh = shard_lib.shardings_for(labels, ("batch",), rules, mesh)

    if shape == "train_1k":
        opt = jax.eval_shape(adamw.init_state, params)
        opt_sh = {"m": params_sh, "v": params_sh,
                  "step": shard_lib.shardings_for(opt["step"], None, rules,
                                                  mesh)}
        ocfg = adamw.AdamWConfig()

        def step(params, opt_state, images, labels):
            (loss, m), grads = jax.value_and_grad(
                capsnet_lib.loss_fn, has_aux=True)(params, cfg, images,
                                                   labels)
            return adamw.apply_updates(params, grads, opt_state, ocfg)[:2]

        return (step, (params, opt, images, labels),
                (params_sh, opt_sh, im_sh, lb_sh), (params_sh, opt_sh))

    def infer(params, images):
        lengths, _ = capsnet_lib.forward(params, cfg, images)
        return lengths

    return infer, (params, images), (params_sh, im_sh), None


def apply_variant(cfg, variant: str, kind: str = "train"):
    """Config-level optimization bundles (§Perf).

    The SHIPPED defaults are the optimized settings ("opt"): H1 flash-bwd
    attention remat, H2 loss-chunk remat, H-B1 one-hot MoE dispatch,
    H-C1 global decode dispatch, bf16 deployment weights for inference
    kinds (the paper's own 16-bit-deployment finding).  ``--variant base``
    reverts to the pre-hillclimb baseline for A/B lowering."""
    if arch_is_capsnet(cfg):
        return cfg
    if variant == "base":
        kw = {"attn_scan_remat": False, "loss_remat": False}
        if getattr(cfg, "moe", None) is not None:
            kw["moe"] = dataclasses.replace(
                cfg.moe, dispatch="scatter", global_decode_dispatch=False)
        return dataclasses.replace(cfg, **kw)
    kw = {}
    if kind in ("prefill", "decode"):
        kw["param_dtype"] = "bfloat16"
    return dataclasses.replace(cfg, **kw) if kw else cfg


def arch_is_capsnet(cfg) -> bool:
    return not isinstance(cfg, LMConfig)


def build_cell(arch: str, shape: str, rules, mesh, variant: str = "base"):
    cfg = cfg_lib.get_config(arch)
    if arch.startswith("capsnet"):
        cfg = apply_variant(cfg, variant)
        return _capsnet_cell(cfg, shape, rules, mesh)
    kind = cfg_lib.SHAPES[shape]["kind"]
    cfg = apply_variant(cfg, variant, kind)
    if kind == "train":
        return _train_cell(cfg, shape, rules, mesh)
    if kind == "prefill":
        return _prefill_cell(cfg, shape, rules, mesh)
    return _decode_cell(cfg, shape, rules, mesh)


# ---------------------------------------------------------------------------
# Runner
# ---------------------------------------------------------------------------


def run_cell(arch: str, shape: str, multi_pod: bool,
             out_dir: Optional[str] = None,
             hlo_dir: Optional[str] = None,
             variant: str = "base") -> Dict[str, Any]:
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    kind = (cfg_lib.SHAPES[shape]["kind"]
            if not arch.startswith("capsnet") else "train")
    rules = shard_lib.rules_for_arch(arch, kind=kind)
    t0 = time.time()
    fn, structs, in_sh, out_sh = build_cell(arch, shape, rules, mesh,
                                            variant)
    with mesh_context(mesh):
        jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh)
        lowered = jitted.lower(*structs)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):   # older jax: one dict per computation
        ca = ca[0] if ca else {}
    hlo = compiled.as_text()
    coll = hlo_analysis.collective_stats(hlo)
    census = hlo_analysis.op_census(hlo)
    # trip-count-weighted costs (launch/hlo_cost.py): cost_analysis() counts
    # while bodies once, which undercounts scanned models by ~n_layers x.
    wc = hlo_cost.weighted_cost(hlo)

    rec = {
        "arch": arch,
        "shape": shape,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_chips": n_chips,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        # memory_analysis is per device
        "arg_bytes_per_dev": int(getattr(ma, "argument_size_in_bytes", 0)),
        "out_bytes_per_dev": int(getattr(ma, "output_size_in_bytes", 0)),
        "temp_bytes_per_dev": int(getattr(ma, "temp_size_in_bytes", 0)),
        "alias_bytes_per_dev": int(getattr(ma, "alias_size_in_bytes", 0)),
        # trip-count-weighted, per device (post-SPMD module)
        "flops_per_dev": float(wc.flops),
        "bytes_per_dev": float(wc.bytes),
        "transcendentals_per_dev": float(wc.transcendentals),
        "collective_bytes_per_dev": float(wc.collective_bytes),
        "collective_counts": {k: float(v)
                              for k, v in wc.collective_count.items()},
        "collective_bytes_by_kind": {
            k: float(v) for k, v in wc.collective_by_kind.items()},
        # raw (unweighted) cost_analysis for reference
        "raw_flops_per_dev": float(ca.get("flops", 0.0)),
        "raw_bytes_per_dev": float(ca.get("bytes accessed", 0.0)),
        "raw_collective_bytes_per_dev": int(coll.total_bytes),
        "op_census": census,
        "reshape_copy_bytes": hlo_analysis.reshape_transpose_bytes(hlo),
    }
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        fname = f"{arch}__{shape}__{rec['mesh']}.json"
        with open(os.path.join(out_dir, fname), "w") as f:
            json.dump(rec, f, indent=1)
    if hlo_dir:
        os.makedirs(hlo_dir, exist_ok=True)
        fname = f"{arch}__{shape}__{rec['mesh']}.hlo.txt"
        with open(os.path.join(hlo_dir, fname), "w") as f:
            f.write(hlo)
    return rec


CAPSNET_SHAPES = ["train_1k", "infer_1k"]


def all_cells(include_capsnet: bool = True):
    cells = list(cfg_lib.CELLS)
    if include_capsnet:
        cells += [(a, s) for a in cfg_lib.PAPER_ARCHS for s in CAPSNET_SHAPES]
    return cells


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--hlo-dir", default=None)
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--no-capsnet", action="store_true")
    ap.add_argument("--variant", choices=["base", "opt"], default="opt")
    args = ap.parse_args()

    require_virtual_devices(512)
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    if args.all:
        cells = all_cells(include_capsnet=not args.no_capsnet)
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    failures = []
    for arch, shape in cells:
        status = (cfg_lib.cell_status(arch, shape)
                  if not arch.startswith("capsnet") else None)
        if status:
            print(f"[skip] {arch:22s} {shape:12s} {status}")
            continue
        for mp in meshes:
            mesh_name = "2x16x16" if mp else "16x16"
            tag = f"{arch:22s} {shape:12s} {mesh_name}"
            if args.skip_existing and args.out:
                fname = os.path.join(args.out,
                                     f"{arch}__{shape}__{mesh_name}.json")
                if os.path.exists(fname):
                    print(f"[have] {tag}")
                    continue
            try:
                rec = run_cell(arch, shape, mp, args.out, args.hlo_dir,
                               variant=args.variant)
                print(f"[ ok ] {tag} "
                      f"flops/dev={rec['flops_per_dev']:.3e} "
                      f"coll={rec['collective_bytes_per_dev']:.3e}B "
                      f"temp={rec['temp_bytes_per_dev'] / 2**30:.2f}GiB "
                      f"compile={rec['compile_s']:.0f}s")
            except Exception as e:  # noqa: BLE001 — report every cell
                failures.append((arch, shape, mesh_name, repr(e)))
                print(f"[FAIL] {tag} {e!r}")
                traceback.print_exc(limit=3)
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print("  ", *f)
        raise SystemExit(1)
    print("\nAll dry-run cells compiled.")


if __name__ == "__main__":
    main()
