"""Post-SPMD HLO analysis: collective bytes, op census, remat duplication.

collective_bytes is NOT in compiled.cost_analysis(); we parse the HLO text
and sum operand sizes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute op (roofline §: collective term).

Shapes are parsed from the HLO result type, e.g.
    %all-gather.3 = bf16[16,4096,12288]{2,1,0} all-gather(...)
Tuple results (e.g. fused all-reduce of several tensors) sum their parts.
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict, List, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# one shaped buffer, e.g.  bf16[16,4096,128]{2,1,0}  or f32[] (scalar)
_SHAPE_RE = re.compile(r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([\d,]*)\]")
# an HLO instruction line:  %name = <result type> opcode(...)
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.+?)\s+([\w\-]+)\(")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_kind: Dict[str, int]
    count_by_kind: Dict[str, int]

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())

    @property
    def total_count(self) -> int:
        return sum(self.count_by_kind.values())

    def summary(self) -> str:
        rows = [f"  {k:20s} n={self.count_by_kind[k]:4d} "
                f"bytes={self.bytes_by_kind[k]:.3e}"
                for k in sorted(self.bytes_by_kind)]
        return "\n".join(rows) if rows else "  (no collectives)"


def collective_stats(hlo_text: str) -> CollectiveStats:
    """Sum result-shape bytes of every collective op in (post-SPMD) HLO.

    Result shape is used as the proxy for moved bytes: for all-reduce it
    equals the payload; for all-gather it is the gathered output (an upper
    bound on per-link traffic x ring steps within a constant); consistency
    across iterations is what the perf loop needs.  ``-start`` variants
    (async collectives) are counted once; ``-done`` ops are skipped."""
    bytes_by = defaultdict(int)
    count_by = defaultdict(int)
    for line in hlo_text.splitlines():
        m = _INSTR_RE.match(line)
        if not m:
            continue
        result_type, opcode = m.group(1), m.group(2)
        base = opcode.removesuffix("-start")
        if opcode.endswith("-done") or base not in _COLLECTIVES:
            continue
        nbytes = _shape_bytes(result_type)
        bytes_by[base] += nbytes
        count_by[base] += 1
    return CollectiveStats(dict(bytes_by), dict(count_by))


def op_census(hlo_text: str, top: int = 15) -> List[Tuple[str, int]]:
    """Instruction count per opcode (remat shows up as duplicate fusions)."""
    counts: Dict[str, int] = defaultdict(int)
    for line in hlo_text.splitlines():
        m = _INSTR_RE.match(line)
        if m:
            counts[m.group(2)] += 1
    return sorted(counts.items(), key=lambda kv: -kv[1])[:top]


def reshape_transpose_bytes(hlo_text: str) -> int:
    """Bytes flowing through layout-change ops (sharding-mismatch smell)."""
    total = 0
    for line in hlo_text.splitlines():
        m = _INSTR_RE.match(line)
        if m and m.group(2) in ("transpose", "reshape", "copy"):
            total += _shape_bytes(m.group(1))
    return total
