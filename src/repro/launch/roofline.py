"""Roofline analysis: three terms per (arch x shape x mesh) from the
dry-run artifacts (launch/dryrun.py JSON records).

    compute term    = FLOPs_per_dev / peak_FLOP/s          [s]
    memory term     = bytes_per_dev / HBM_bw               [s]
    collective term = collective_bytes_per_dev / link_bw   [s]

Hardware constants (TPU v5e-class target):
    197 TFLOP/s bf16 per chip; 819 GB/s HBM; ~50 GB/s/link ICI.

FLOPs/bytes are the trip-count-weighted per-device costs (hlo_cost.py).
MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D (MoE) per the spec; the ratio
MODEL_FLOPS / HLO_FLOPS shows how much compiled compute is "useful"
(catches remat/redundancy waste).

    PYTHONPATH=src python -m repro.launch.roofline --dir experiments/dryrun
"""

from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Any, Dict, List, Optional

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
LINK_BW = 50e9               # bytes/s / link

# Parameter counts (total, active) computed analytically per arch; filled
# by params_for() below.


def _lm_param_count(cfg) -> Dict[str, float]:
    """Analytic N (total) and N_active (MoE: shared + top_k experts)."""
    d, dff, L, V = cfg.d_model, cfg.d_ff, cfg.n_layers, cfg.vocab
    hd = cfg.head_dim
    nh, nkv = cfg.n_heads, cfg.n_kv_heads
    attn = d * nh * hd + 2 * d * nkv * hd + nh * hd * d
    embed = V * d * (1 if cfg.tie_embeddings else 2)

    if cfg.family == "moe":
        m = cfg.moe
        expert = 3 * d * m.d_expert
        router = d * m.n_experts
        shared = 3 * d * (m.n_shared * m.d_expert) if m.n_shared else 0
        layer_total = attn + router + shared + m.n_experts * expert
        layer_active = attn + router + shared + m.top_k * expert
        return {"total": embed + L * layer_total,
                "active": embed + L * layer_active}
    if cfg.family == "hybrid":
        s = cfg.ssm
        di = s.d_inner(d)
        nh_m = s.n_heads(d)
        mamba = (d * (2 * di + 2 * s.n_groups * s.d_state + nh_m)
                 + s.d_conv * (di + 2 * s.n_groups * s.d_state)
                 + di * d + 2 * di + 3 * nh_m)
        shared_blk = attn + 3 * d * dff
        n = embed + L * mamba + shared_blk
        return {"total": n, "active": n}
    if cfg.family == "ssm":
        x = cfg.xlstm
        di = int(x.mlstm_proj_factor * d)
        mlstm = (d * 2 * di + x.d_conv * di + 3 * di * (di // 1)
                 // cfg.n_heads * cfg.n_heads // 1)
        # q,k,v projections are (di, di); gates (di, 2*nh)
        mlstm = (d * 2 * di + x.d_conv * di + 3 * di * di
                 + di * 2 * cfg.n_heads + 2 * di + di * d)
        dffs = ((int(x.slstm_ff_factor * d) + 63) // 64) * 64
        slstm = (d * 4 * d + cfg.n_heads * (d // cfg.n_heads) ** 2 * 4
                 + d * 2 * dffs + dffs * d)
        k = x.slstm_every
        n_groups = L // k
        n = embed + n_groups * ((k - 1) * mlstm + slstm)
        return {"total": n, "active": n}
    if cfg.family == "vlm":
        k = cfg.cross_attn_every
        n_units = L // (k + 1)
        blk = attn + 3 * d * dff
        cross = attn + 3 * d * dff + 1
        n = embed + d * d + n_units * (k * blk + cross)
        return {"total": n, "active": n}
    # dense / audio
    mlp = (3 if cfg.glu else 2) * d * dff
    n = embed + L * (attn + mlp) + d
    return {"total": n, "active": n}


def params_for(arch: str) -> Dict[str, float]:
    from repro import configs as cfg_lib
    cfg = cfg_lib.get_config(arch)
    if arch.startswith("capsnet"):
        from repro.core import capsnet as cn
        import jax
        import jax.numpy as jnp
        p = jax.eval_shape(lambda k: cn.init(cfg, k),
                           jax.ShapeDtypeStruct((2,), jnp.uint32))
        n = sum(int(x.size) for x in jax.tree.leaves(p))
        return {"total": float(n), "active": float(n)}
    return {k: float(v) for k, v in _lm_param_count(cfg).items()}


def model_flops(arch: str, shape: str) -> float:
    """MODEL_FLOPS = 6*N_active*D for train; 2*N_active*D for inference
    (forward only), with D = tokens processed by the step."""
    from repro import configs as cfg_lib
    p = params_for(arch)["active"]
    if shape in ("train_1k", "infer_1k"):                  # capsnet cells
        b = 1024
        mult = 6.0 if shape == "train_1k" else 2.0
        return mult * p * b
    info = cfg_lib.SHAPES[shape]
    kind = info["kind"]
    if kind == "train":
        return 6.0 * p * info["batch"] * info["seq"]
    if kind == "prefill":
        return 2.0 * p * info["batch"] * info["seq"]
    return 2.0 * p * info["batch"]                         # decode: 1 tok/row


def analyze(rec: Dict[str, Any]) -> Dict[str, Any]:
    t_comp = rec["flops_per_dev"] / PEAK_FLOPS
    t_mem = rec["bytes_per_dev"] / HBM_BW
    t_coll = rec["collective_bytes_per_dev"] / LINK_BW
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    mf = model_flops(rec["arch"], rec["shape"])
    hlo_total = rec["flops_per_dev"] * rec["n_chips"]
    useful = mf / hlo_total if hlo_total else 0.0
    # roofline fraction: useful-compute time / modelled step time
    t_useful = (mf / rec["n_chips"]) / PEAK_FLOPS
    frac = t_useful / bound if bound > 0 else 0.0
    return {
        **{k: rec[k] for k in ("arch", "shape", "mesh", "n_chips")},
        "t_compute_s": t_comp,
        "t_memory_s": t_mem,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "model_flops": mf,
        "hlo_flops_total": hlo_total,
        "useful_ratio": useful,
        "roofline_frac": frac,
    }


def load_records(directory: str) -> List[Dict[str, Any]]:
    recs = []
    for path in sorted(glob.glob(os.path.join(directory, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def to_markdown(rows: List[Dict[str, Any]]) -> str:
    hdr = ("| arch | shape | mesh | compute s | memory s | collective s | "
           "dominant | MODEL/HLO | roofline frac |")
    sep = "|" + "---|" * 9
    lines = [hdr, sep]
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['t_compute_s']:.3e} | {r['t_memory_s']:.3e} "
            f"| {r['t_collective_s']:.3e} | **{r['dominant']}** "
            f"| {r['useful_ratio']:.3f} | {r['roofline_frac']:.3f} |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--mesh", default=None, help="filter: 16x16 / 2x16x16")
    ap.add_argument("--markdown", action="store_true")
    args = ap.parse_args()
    rows = [analyze(r) for r in load_records(args.dir)
            if args.mesh is None or r["mesh"] == args.mesh]
    rows.sort(key=lambda r: (r["arch"], r["shape"], r["mesh"]))
    if args.markdown:
        print(to_markdown(rows))
        return
    for r in rows:
        print(f"{r['arch']:22s} {r['shape']:12s} {r['mesh']:8s} "
              f"comp={r['t_compute_s']:.3e} mem={r['t_memory_s']:.3e} "
              f"coll={r['t_collective_s']:.3e} dom={r['dominant']:10s} "
              f"useful={r['useful_ratio']:.3f} "
              f"roofline={r['roofline_frac']:.3f}")


if __name__ == "__main__":
    main()
