"""Production mesh construction (spec-mandated entry point).

A FUNCTION, not a module-level constant: importing this module never
touches jax device state, so tests/benches that import it still see the
single CPU device unless they explicitly build the mesh.
"""

from __future__ import annotations

import jax


def make_mesh(shape, axes):
    """jax.make_mesh across jax versions: AxisType landed after 0.4.x."""
    try:
        from jax.sharding import AxisType
    except ImportError:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def mesh_context(mesh):
    """Ambient-mesh context manager across jax versions: ``jax.set_mesh``
    (new) -> ``jax.sharding.use_mesh`` -> the Mesh object itself (0.4.x)."""
    set_mesh = getattr(jax, "set_mesh", None)
    if set_mesh is not None:
        return set_mesh(mesh)
    use_mesh = getattr(jax.sharding, "use_mesh", None)
    if use_mesh is not None:
        return use_mesh(mesh)
    return mesh


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 (data, model) single pod; 2x16x16 (pod, data, model) for two
    pods.  ``pod`` is the slow cross-pod (DCN/ICI-cross) axis and by
    default only ever carries batch (pure DP), so the sole cross-pod
    collective is the gradient all-reduce (DESIGN.md §4)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh():
    """1x1 mesh on the real local device (smoke tests, examples)."""
    return make_mesh((1, 1), ("data", "model"))


def require_virtual_devices(n: int = 512) -> None:
    """Sanity check that the dry-run env var took effect."""
    have = jax.device_count()
    if have < n:
        raise RuntimeError(
            f"dry-run needs {n} host platform devices, found {have}. "
            "XLA_FLAGS=--xla_force_host_platform_device_count=512 must be "
            "set before jax initializes (launch/dryrun.py does this).")
