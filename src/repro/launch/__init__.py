# Launchers: mesh construction, multi-pod dry-run, roofline analysis,
# training and serving CLIs.  NOTE: repro.launch.dryrun sets XLA_FLAGS at
# import time (512 host devices) — never import it from tests/benches.
