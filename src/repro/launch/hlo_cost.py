"""Trip-count-weighted HLO cost analysis (the dry-run profiler).

``compiled.cost_analysis()`` on the CPU backend counts while-loop bodies
ONCE — a scanned 28-layer model reports ~1 layer of FLOPs.  XLA however
annotates every scan-derived loop with ``backend_config=
{"known_trip_count":{"n":"28"}}``, so this module re-derives

    flops / transcendentals / bytes-accessed / collective bytes

from the post-SPMD HLO text with loop bodies multiplied by their trip
counts (nested loops multiply).  Conventions follow HloCostAnalysis:
elementwise = numel(result) flops; dot = 2*numel(result)*K; fusion bytes =
fusion operands + result (internal values live in registers); GTE/tuple/
parameter/bitcast are free.  Conditionals take the max across branches.

Validated against analytic 6*N*D model FLOPs in tests/test_roofline.py.
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "u1": 1,
}

_SHAPE_RE = re.compile(r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([\d,]*)\]")
_COMP_HEADER_RE = re.compile(
    r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\((.*)\)\s*->\s*(.+?)\s*\{\s*$")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\(")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLED_RE = re.compile(
    r"(?:condition|body|calls|to_apply)=%([\w.\-]+)"
    r"|branch_computations=\{([^}]*)\}")
_DOT_LHS_C = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_DOT_LHS_B = re.compile(r"lhs_batch_dims=\{([\d,]*)\}")
_CONV_LABELS = re.compile(r"dim_labels=([\w\?]+)_([\w\?]+)->([\w\?]+)")

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum",
    "negate", "abs", "select", "and", "or", "xor", "not", "compare",
    "sign", "floor", "ceil", "round-nearest-afz", "round-nearest-even",
    "clamp", "remainder", "shift-left", "shift-right-logical",
    "shift-right-arithmetic", "atan2", "is-finite",
}
_TRANSCENDENTAL = {
    "exponential", "exponential-minus-one", "log", "log-plus-one", "tanh",
    "rsqrt", "sqrt", "cbrt", "power", "sine", "cosine", "tan", "logistic",
    "erf", "expm1",
}
_FREE = {
    "parameter", "get-tuple-element", "tuple", "bitcast", "constant",
    "iota", "after-all", "partition-id", "replica-id", "copy-start",
    "copy-done", "get-dimension-size", "opt-barrier",
}
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_numel_bytes(type_str: str) -> Tuple[int, int]:
    numel = 0
    nbytes = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        numel += n
        nbytes += n * _DTYPE_BYTES[dt]
    return numel, nbytes


def _shape_dims(type_str: str) -> Optional[List[int]]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None
    dims = m.group(2)
    return [int(d) for d in dims.split(",")] if dims else []


@dataclasses.dataclass
class Instr:
    name: str
    result_type: str
    opcode: str
    operands: List[str]
    attrs: str
    line: str
    is_root: bool = False


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    transcendentals: float = 0.0
    bytes: float = 0.0
    collective_bytes: float = 0.0
    collective_by_kind: Dict[str, float] = dataclasses.field(
        default_factory=dict)
    collective_count: Dict[str, float] = dataclasses.field(
        default_factory=dict)

    def add(self, other: "Cost", mult: float = 1.0) -> None:
        self.flops += other.flops * mult
        self.transcendentals += other.transcendentals * mult
        self.bytes += other.bytes * mult
        self.collective_bytes += other.collective_bytes * mult
        for k, v in other.collective_by_kind.items():
            self.collective_by_kind[k] = (
                self.collective_by_kind.get(k, 0.0) + v * mult)
        for k, v in other.collective_count.items():
            self.collective_count[k] = (
                self.collective_count.get(k, 0.0) + v * mult)


def _parse_operands(rest: str) -> Tuple[List[str], str]:
    """rest starts right after the opening '('; returns (operand names,
    attrs after the matching ')')."""
    depth = 1
    i = 0
    while i < len(rest) and depth:
        if rest[i] == "(":
            depth += 1
        elif rest[i] == ")":
            depth -= 1
        i += 1
    inner, attrs = rest[:i - 1], rest[i:]
    ops = re.findall(r"%([\w.\-]+)", inner)
    return ops, attrs


def parse_module(hlo_text: str) -> Dict[str, List[Instr]]:
    comps: Dict[str, List[Instr]] = {}
    current: Optional[str] = None
    for line in hlo_text.splitlines():
        if current is None:
            m = _COMP_HEADER_RE.match(line)
            if m:
                current = m.group(1)
                comps[current] = []
            continue
        if line.startswith("}"):
            current = None
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, rtype, opcode = m.group(1), m.group(2), m.group(3)
        rest = line[m.end():]
        ops, attrs = _parse_operands(rest)
        comps[current].append(Instr(name, rtype, opcode, ops, attrs, line,
                                    is_root=line.lstrip().startswith("ROOT")))
    return comps


class WeightedCostAnalysis:
    def __init__(self, hlo_text: str):
        self.comps = parse_module(hlo_text)
        self.entry = self._find_entry(hlo_text)
        self._memo: Dict[str, Cost] = {}
        # name -> result type, per computation
        self._types: Dict[str, Dict[str, str]] = {
            c: {i.name: i.result_type for i in instrs}
            for c, instrs in self.comps.items()
        }

    def _find_entry(self, text: str) -> str:
        for line in text.splitlines():
            if line.startswith("ENTRY"):
                m = _COMP_HEADER_RE.match(line)
                if m:
                    return m.group(1)
        # fall back: last computation
        return list(self.comps)[-1]

    def cost(self) -> Cost:
        return self._comp_cost(self.entry)

    def _comp_cost(self, comp: str, fused: bool = False) -> Cost:
        key = comp + ("#f" if fused else "")
        if key in self._memo:
            return self._memo[key]
        total = Cost()
        self._memo[key] = total           # break cycles defensively
        for instr in self.comps.get(comp, []):
            total.add(self._instr_cost(comp, instr, fused=fused))
        return total

    def _fusion_bytes(self, comp: str, instr: Instr, called: str) -> float:
        """Bytes for a fusion op: result write + per-operand reads, where

        * an operand whose ONLY uses inside the fused computation are
          slice-family ops is charged via those slices (fused mode), not
          at its full buffer size — a fusion that dynamic-slices a stacked
          scan buffer only touches the slice;
        * a fusion whose ROOT is dynamic-update-slice (the scan-accumulator
          in-place pattern) writes only the update region: the result is
          charged at 2x the update size and the aliased full-size
          accumulator operand is pass-through (0 bytes)."""
        _, rbytes = _shape_numel_bytes(instr.result_type)
        inner = self.comps.get(called, [])
        root = next((i for i in inner if i.is_root),
                    inner[-1] if inner else None)
        dus_root = root is not None and root.opcode == "dynamic-update-slice"
        if dus_root:
            upd_bytes = 0
            if len(root.operands) > 1:
                t = {i.name: i.result_type for i in inner}.get(
                    root.operands[1])
                if t:
                    upd_bytes = _shape_numel_bytes(t)[1]
            total = 2.0 * upd_bytes
        else:
            total = float(rbytes)
        # param index -> param instruction name
        params = {}
        for i in inner:
            if i.opcode == "parameter":
                m = re.search(r"parameter\((\d+)\)", i.line)
                if m:
                    params[int(m.group(1))] = i.name
        slice_only = {}
        for idx, pname in params.items():
            uses = [i for i in inner if pname in i.operands]
            slice_only[idx] = bool(uses) and all(
                i.opcode in ("dynamic-slice", "gather", "slice")
                and i.operands and i.operands[0] == pname
                for i in uses)
        for k, op_name in enumerate(instr.operands):
            if slice_only.get(k, False):
                continue                  # charged via fused-mode slices
            t = self._types[comp].get(op_name)
            if not t:
                continue
            b = _shape_numel_bytes(t)[1]
            if dus_root and b == rbytes:
                continue                  # aliased accumulator pass-through
            total += b
        return total

    def _operand_dims(self, comp: str, name: str) -> Optional[List[int]]:
        t = self._types[comp].get(name)
        return _shape_dims(t) if t else None

    def _operand_bytes(self, comp: str, names: List[str]) -> int:
        total = 0
        for n in names:
            t = self._types[comp].get(n)
            if t:
                total += _shape_numel_bytes(t)[1]
        return total

    def _instr_cost(self, comp: str, instr: Instr,
                    fused: bool = False) -> Cost:
        op = instr.opcode
        c = Cost()
        if op in _FREE:
            return c
        numel, rbytes = _shape_numel_bytes(instr.result_type)

        if op == "while":
            trip = 1
            m = _TRIP_RE.search(instr.attrs)
            if m:
                trip = int(m.group(1))
            called = _CALLED_RE.findall(instr.attrs)
            for g1, g2 in called:
                if g1:
                    c.add(self._comp_cost(g1), mult=trip)
            return c

        if op == "conditional":
            branches: List[str] = []
            for g1, g2 in _CALLED_RE.findall(instr.attrs):
                if g2:
                    branches += re.findall(r"%([\w.\-]+)", g2)
                elif g1:
                    branches.append(g1)
            best = Cost()
            for b in branches:
                bc = self._comp_cost(b)
                if bc.flops >= best.flops:
                    best = bc
            c.add(best)
            c.bytes += rbytes + self._operand_bytes(comp, instr.operands)
            return c

        if op == "fusion":
            called = [g1 for g1, g2 in _CALLED_RE.findall(instr.attrs)
                      if g1]
            for g1 in called:
                inner = self._comp_cost(g1, fused=True)
                c.flops += inner.flops
                c.transcendentals += inner.transcendentals
                c.bytes += inner.bytes      # fused-mode: slice touches only
                c.collective_bytes += inner.collective_bytes
            if called:
                c.bytes += self._fusion_bytes(comp, instr, called[0])
            else:
                c.bytes += rbytes + self._operand_bytes(comp,
                                                        instr.operands)
            return c

        if op in ("call", "async-start"):
            for g1, g2 in _CALLED_RE.findall(instr.attrs):
                if g1:
                    c.add(self._comp_cost(g1))
            c.bytes += rbytes + self._operand_bytes(comp, instr.operands)
            return c

        base = op.removesuffix("-start")
        if base in _COLLECTIVES and not op.endswith("-done"):
            c.collective_bytes += rbytes
            c.collective_by_kind[base] = rbytes
            c.collective_count[base] = 1
            c.bytes += rbytes + self._operand_bytes(comp, instr.operands)
            return c

        if op == "dot":
            k = 1.0
            lhs_dims = (self._operand_dims(comp, instr.operands[0])
                        if instr.operands else None)
            mc = _DOT_LHS_C.search(instr.attrs)
            if lhs_dims is not None and mc:
                for d in (mc.group(1).split(",") if mc.group(1) else []):
                    k *= lhs_dims[int(d)]
            c.flops += 2.0 * numel * k
            if not fused:
                c.bytes += rbytes + self._operand_bytes(comp,
                                                        instr.operands)
            return c

        if op == "convolution":
            rhs_dims = (self._operand_dims(comp, instr.operands[1])
                        if len(instr.operands) > 1 else None)
            k = 1.0
            if rhs_dims:
                ml = _CONV_LABELS.search(instr.attrs)
                if ml:
                    rhs_labels = ml.group(2)
                    o_idx = rhs_labels.find("o")
                    out_f = rhs_dims[o_idx] if o_idx >= 0 else 1
                    k = 1.0
                    for d in rhs_dims:
                        k *= d
                    k /= max(out_f, 1)
                else:
                    k = float(rhs_dims[0])
            c.flops += 2.0 * numel * k
            if not fused:
                c.bytes += rbytes + self._operand_bytes(comp,
                                                        instr.operands)
            return c

        if op in ("reduce", "reduce-window"):
            in_numel = 0
            if instr.operands:
                t = self._types[comp].get(instr.operands[0])
                if t:
                    in_numel = _shape_numel_bytes(t)[0]
            c.flops += float(max(in_numel, numel))
            if not fused:
                c.bytes += rbytes + self._operand_bytes(comp,
                                                        instr.operands)
            return c

        if op in _TRANSCENDENTAL:
            c.flops += float(numel)
            c.transcendentals += float(numel)
            if not fused:
                c.bytes += rbytes + self._operand_bytes(comp,
                                                        instr.operands)
            return c

        # slice-family ops move only the sliced region, not the full
        # operand buffer (charging the whole stacked-params tensor per
        # scan iteration would overstate HBM traffic by ~n_layers x);
        # dynamic-update-slice writes in place (aliased) — charge the
        # update region read+write.
        if op in ("dynamic-slice", "gather", "slice"):
            c.bytes += (1.0 if fused else 2.0) * rbytes
            return c
        if op in ("dynamic-update-slice", "scatter"):
            upd = 0
            if len(instr.operands) > 1:
                t = self._types[comp].get(instr.operands[1])
                if t:
                    upd = _shape_numel_bytes(t)[1]
            c.bytes += 2.0 * upd
            return c
        if op == "broadcast":
            if not fused:
                c.bytes += rbytes + min(
                    rbytes, self._operand_bytes(comp, instr.operands))
            return c

        if op in _ELEMENTWISE or op == "map":
            c.flops += float(numel)
        # everything else (transpose, reshape, concatenate, pad, convert,
        # copy, sort, rng...) costs bytes but ~0 flops
        if not fused:
            c.bytes += rbytes + self._operand_bytes(comp, instr.operands)
        return c


def weighted_cost(hlo_text: str) -> Cost:
    return WeightedCostAnalysis(hlo_text).cost()
