"""Serving launcher: LM decode or batched CapsNet image inference.

    # LM: batched prefill + decode demo on a reduced config
    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b \
        --reduced --requests 6 --max-new 12

    # CapsNet: FastCapsPipeline -> CapsuleEngine, FPS report (paper Fig. 1)
    PYTHONPATH=src python -m repro.launch.serve --arch capsnet-mnist \
        --requests 8 --batch 16 --routing pallas
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import configs as cfg_lib
from repro.models import lm
from repro.serving import CapsuleEngine, ImageRequest, Request, ServeEngine


def serve_lm(args) -> None:
    cfg = cfg_lib.get_config(args.arch)
    if args.reduced:
        cfg = cfg_lib.reduced(cfg)
    if cfg.family == "audio":
        raise SystemExit("encoder-only arch has no decode path")
    params = lm.init(cfg, jax.random.key(0))
    engine = ServeEngine(cfg, params, n_slots=args.slots,
                         max_len=args.max_len)
    rng = np.random.RandomState(0)
    reqs = [Request(prompt=list(rng.randint(1, cfg.vocab // 2,
                                            size=rng.randint(3, 9))),
                    max_new_tokens=args.max_new, rid=i)
            for i in range(args.requests)]
    prompt_len = {r.rid: len(r.prompt) for r in reqs}
    t0 = time.time()
    completions = engine.serve(reqs)
    dt = time.time() - t0
    # Completion.tokens includes the prompt; report only generated tokens.
    total_new = sum(len(c.tokens) - prompt_len[c.rid] for c in completions)
    print(f"[{cfg.arch_id}] served {len(completions)} requests "
          f"({total_new} new tokens) in {dt:.2f}s "
          f"({total_new / max(dt, 1e-9):.1f} tok/s)")
    for c in sorted(completions, key=lambda c: c.rid):
        print(f"  rid={c.rid}: {c.tokens}")


def serve_capsnet(args) -> None:
    """The paper's deployment path: prune -> compact -> compile -> serve."""
    from repro.deploy import FastCapsPipeline

    cfg = cfg_lib.get_config(args.arch)
    if args.reduced:
        cfg = cfg_lib.reduced(cfg)
    pipe = FastCapsPipeline(cfg).build(seed=0)
    if args.sparsity > 0:
        pipe.prune(args.sparsity, args.sparsity,
                   type_keep=max(cfg.caps_types // 4, 1)).compact()
    deployed = pipe.compile(routing=args.routing)
    print(f"[{cfg.arch_id}] deployed: routing={deployed.spec.mode}"
          f"(softmax={deployed.spec.softmax}) "
          f"{deployed.n_params:,} params, "
          f"{deployed.flops_per_image / 1e6:.1f} MFLOP/image")

    engine = CapsuleEngine(deployed, batch_size=args.batch)
    engine.warmup()
    rng = np.random.RandomState(0)
    reqs = [ImageRequest(
                images=rng.rand(rng.randint(1, 2 * args.batch),
                                cfg.image_hw, cfg.image_hw,
                                cfg.in_channels).astype(np.float32),
                rid=i)
            for i in range(args.requests)]
    completions = engine.serve(reqs)
    stats = engine.stats()
    print(f"  served {len(completions)} requests / {stats.frames} frames "
          f"in {stats.batches} batches ({stats.padded_frames} pad): "
          f"{stats.fps:.1f} FPS, {stats.ms_per_batch:.2f} ms/batch")
    for c in sorted(completions, key=lambda c: c.rid):
        print(f"  rid={c.rid}: {len(c.classes)} frames, "
              f"latency={c.latency_s * 1e3:.1f} ms, "
              f"classes={c.classes[:8].tolist()}"
              f"{'...' if len(c.classes) > 8 else ''}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=cfg_lib.list_archs())
    ap.add_argument("--reduced", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="CPU-smoke-sized config (--no-reduced for the "
                         "published size)")
    ap.add_argument("--requests", type=int, default=6)
    # LM options
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--max-len", type=int, default=128)
    # CapsNet options
    ap.add_argument("--batch", type=int, default=16,
                    help="CapsuleEngine micro-batch size")
    ap.add_argument("--routing", default="pallas",
                    choices=["reference", "optimized", "pallas"])
    ap.add_argument("--sparsity", type=float, default=0.6,
                    help="LAKP sparsity for both conv layers (0 = dense)")
    args = ap.parse_args()
    if args.arch.startswith("capsnet"):
        serve_capsnet(args)
    else:
        serve_lm(args)


if __name__ == "__main__":
    main()
