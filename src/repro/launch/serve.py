"""Serving launcher: batched prefill + decode demo on a reduced config.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b \
        --reduced --requests 6 --max-new 12
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import configs as cfg_lib
from repro.models import lm
from repro.serving import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True,
                    choices=cfg_lib.list_archs(include_paper=False))
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--max-len", type=int, default=128)
    args = ap.parse_args()

    cfg = cfg_lib.get_config(args.arch)
    if args.reduced:
        cfg = cfg_lib.reduced(cfg)
    if cfg.family == "audio":
        raise SystemExit("encoder-only arch has no decode path")
    params = lm.init(cfg, jax.random.key(0))
    engine = ServeEngine(cfg, params, n_slots=args.slots,
                         max_len=args.max_len)
    rng = np.random.RandomState(0)
    reqs = [Request(prompt=list(rng.randint(1, cfg.vocab // 2,
                                            size=rng.randint(3, 9))),
                    max_new_tokens=args.max_new, rid=i)
            for i in range(args.requests)]
    t0 = time.time()
    completions = engine.serve(reqs)
    dt = time.time() - t0
    total_new = sum(c.tokens and len(c.tokens) for c in completions)
    print(f"[{cfg.arch_id}] served {len(completions)} requests "
          f"({total_new} tokens) in {dt:.2f}s")
    for c in sorted(completions, key=lambda c: c.rid):
        print(f"  rid={c.rid}: {c.tokens}")


if __name__ == "__main__":
    main()
