"""Serving launcher: LM decode or batched CapsNet image inference, both
through the unified ``repro.serving`` engine API
(``submit() / poll() / run_until_idle() / stats()``).

    # LM: continuous-batching ragged prefill + decode on a reduced config
    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b \
        --reduced --requests 6 --max-new 12

    # LM, token-streaming, prefill/decode-interleaved ticks
    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b \
        --reduced --scheduler interleave --stream

    # LM, KV caches sharded across every local device (slot-parallel)
    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b \
        --reduced --scheduler sharded --slots 4

    # LM, paged KV cache: global page pool + per-slot page tables,
    # content-addressed prefix reuse, optional int8 cache pages
    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b \
        --reduced --paged --page-size 16 --pages 64 --quantize-pages

    # LM, disaggregated: prefill engine + 2 decode engines, cache handoffs
    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b \
        --reduced --scheduler disagg --decode-engines 2

    # LM, multi-host disaggregated: prefill/decode on disjoint submeshes,
    # handoffs staged through the host (or device_to_device / auto)
    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b \
        --reduced --scheduler disagg --multihost --transport host_staged

    # CapsNet: FastCapsPipeline -> DeployedCapsNet.serve(), FPS report
    PYTHONPATH=src python -m repro.launch.serve --arch capsnet-mnist \
        --requests 8 --batch 16 --routing pallas --scheduler slo --slo-ms 50

    # Traffic replay: seeded bursty arrivals against an autoscaled
    # disaggregated pool, with priority preemption + SLO admission
    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b \
        --reduced --trace bursty --autoscale --priority \
        --trace-rate 30 --trace-horizon 2 --decode-engines 3
"""

from __future__ import annotations

import argparse

import jax
import numpy as np

from repro import configs as cfg_lib
from repro.models import lm
from repro.serving import (DecodeEngine, DisaggregatedEngine, FIFOScheduler,
                           ImageRequest, InterleavingScheduler,
                           PriorityScheduler, Request, ServeEngine,
                           ShardedScheduler, SLOBatchScheduler,
                           disaggregated_lm_engine,
                           multihost_disaggregated_lm_engine)


def _make_scheduler(args):
    if args.scheduler == "slo":
        return SLOBatchScheduler(target_p95_ms=args.slo_ms)
    if args.scheduler == "interleave":
        return InterleavingScheduler()
    if args.scheduler == "sharded":
        from repro.launch.mesh import make_mesh

        n = jax.device_count()
        return ShardedScheduler(make_mesh((n,), ("data",)))
    if args.priority:
        return PriorityScheduler()
    return FIFOScheduler()


def _paged_kwargs(args) -> dict:
    """Page-pool / decode-kernel engine kwargs from the CLI flags."""
    kw = dict(decode_kernel=args.decode_kernel)
    if args.paged:
        kw.update(page_size=args.page_size, n_pages=args.pages,
                  quantize_pages=args.quantize_pages)
    return kw


def _print_pages(stats) -> None:
    if getattr(stats, "pages", None):
        summary = " ".join(f"{k}={v}" for k, v in
                           sorted(stats.pages.items()))
        print(f"  pages: {summary}")


def _print_latency(stats) -> None:
    for cls, (n, p50, p95) in stats.latency_summary().items():
        print(f"  latency[{cls}]: n={n} p50={p50:.1f} ms p95={p95:.1f} ms")
    for phase, (n, p50, p95, peak) in stats.depth_summary().items():
        print(f"  depth[{phase}]: ticks={n} p50={p50:.0f} p95={p95:.0f} "
              f"peak={peak}")
    for stage, (n, p50, p95) in stats.transfer_summary().items():
        print(f"  transfer[{stage}]: n={n} p50={p50:.2f} ms "
              f"p95={p95:.2f} ms")


def _print_scale_events(events) -> None:
    if not events:
        print("  autoscale: no scale events")
        return
    for e in events:
        print(f"  autoscale[{e.action}]: t={e.t:.3f}s -> "
              f"{e.n_live} live engine(s)")


def serve_traffic(args) -> None:
    """Replay a seeded arrival trace (``--trace poisson|bursty``) against
    an LM engine — optionally a disaggregated pool with closed-loop
    autoscaling (``--autoscale``), priority preemption (``--priority``)
    and SLO admission control (``--admission``)."""
    from repro.traffic import (AutoscaleController, SLOAdmission,
                               bursty_trace, default_classes,
                               default_factory, poisson_trace, replay)

    cfg = cfg_lib.get_config(args.arch)
    if args.reduced:
        cfg = cfg_lib.reduced(cfg)
    if cfg.family == "audio":
        raise SystemExit("encoder-only arch has no decode path")
    params = lm.init(cfg, jax.random.key(0))

    classes = default_classes()
    if args.trace == "bursty":
        trace = bursty_trace(classes, rates=[args.trace_rate / 6,
                                             args.trace_rate],
                             dwell=[0.4, 0.2], horizon=args.trace_horizon,
                             seed=args.trace_seed)
    else:
        trace = poisson_trace(classes, rate=args.trace_rate,
                              horizon=args.trace_horizon,
                              seed=args.trace_seed)

    pk = _paged_kwargs(args)
    controller = None
    if args.autoscale:
        def mk():
            return DecodeEngine(cfg, params, n_slots=args.slots,
                                max_len=args.max_len, **pk)
        engine = disaggregated_lm_engine(
            cfg, params, n_slots=args.slots, max_len=args.max_len,
            n_decode=1, transport=args.transport,
            decode_schedulers=[PriorityScheduler()] if args.priority
            else None, **pk)
        controller = AutoscaleController(mk, min_engines=1,
                                         max_engines=args.decode_engines)
    elif args.scheduler == "disagg":
        engine = disaggregated_lm_engine(
            cfg, params, n_slots=args.slots, max_len=args.max_len,
            n_decode=args.decode_engines, transport=args.transport,
            decode_schedulers=[PriorityScheduler()
                               for _ in range(args.decode_engines)]
            if args.priority else None, **pk)
    else:
        engine = ServeEngine(cfg, params, n_slots=args.slots,
                             max_len=args.max_len,
                             scheduler=_make_scheduler(args), **pk)
    admission = SLOAdmission() if args.admission else None

    rep = replay(engine, trace,
                 factory=default_factory(trace, vocab=cfg.vocab // 2),
                 controller=controller, admission=admission)

    stats = rep.stats
    print(f"[{cfg.arch_id}] trace={args.trace} seed={args.trace_seed}: "
          f"{len(trace)} arrivals over {trace.horizon:.1f}s "
          f"({trace.rate():.1f} req/s)")
    print(f"  submitted={rep.submitted} completed={rep.completed} "
          f"rejected={rep.rejected} dropped={rep.dropped} "
          f"preempted={stats.preempted}")
    assert rep.dropped == 0, "never-dropped invariant violated"
    print(f"  served {stats.items} new tokens in {stats.wall_s:.2f}s "
          f"({stats.throughput:.1f} tok/s, {stats.ms_per_tick:.1f} "
          f"ms/tick)")
    _print_latency(stats)
    _print_pages(stats)
    if controller is not None:
        _print_scale_events(rep.scale_events)
        if rep.mean_live_engines is not None:
            print(f"  autoscale: mean live engines = "
                  f"{rep.mean_live_engines:.2f} "
                  f"(max {args.decode_engines})")


def serve_lm(args) -> None:
    cfg = cfg_lib.get_config(args.arch)
    if args.reduced:
        cfg = cfg_lib.reduced(cfg)
    if cfg.family == "audio":
        raise SystemExit("encoder-only arch has no decode path")
    params = lm.init(cfg, jax.random.key(0))
    if args.scheduler == "disagg":
        # disaggregated prefill: admission/prefill on a dedicated engine,
        # decode on --decode-engines engines joined by cache handoffs
        # delivered over --transport; --multihost places prefill and each
        # decode engine on disjoint submeshes (handoffs cross meshes)
        factory = (multihost_disaggregated_lm_engine if args.multihost
                   else disaggregated_lm_engine)
        engine = factory(
            cfg, params, n_slots=args.slots, max_len=args.max_len,
            n_decode=args.decode_engines,
            kernel_tune=args.kernel_tune or None,
            transport=args.transport, **_paged_kwargs(args))
    else:
        engine = ServeEngine(cfg, params, n_slots=args.slots,
                             max_len=args.max_len,
                             scheduler=_make_scheduler(args),
                             kernel_tune=args.kernel_tune or None,
                             **_paged_kwargs(args))
    if args.kernel_tune:
        engine.warmup()
    rng = np.random.RandomState(0)
    reqs = [Request(prompt=list(rng.randint(1, cfg.vocab // 2,
                                            size=rng.randint(3, 9))),
                    max_new_tokens=args.max_new, rid=i, stream=args.stream)
            for i in range(args.requests)]
    if args.stream:
        # token-level results as they are generated (poll(stream=True))
        for r in reqs:
            engine.submit(r)
        completions = []
        while True:
            busy = engine.tick()
            for ev in engine.poll(stream=True):
                if ev.done:
                    completions.append(ev.completion)
                    print(f"  rid={ev.rid}: done")
                else:
                    print(f"  rid={ev.rid} #{ev.seq}: token {ev.item}")
            if not busy and engine.n_pending == 0:
                break
        engine.poll()                      # drain the compat channel
    else:
        completions = engine.serve(reqs)
    stats = engine.stats()
    # Completion.tokens includes the prompt; stats count generated tokens.
    print(f"[{cfg.arch_id}] served {stats.completed} requests "
          f"({stats.items} new tokens) in {stats.wall_s:.2f}s "
          f"({stats.throughput:.1f} tok/s, "
          f"{stats.ms_per_tick:.1f} ms/tick)")
    _print_latency(stats)
    _print_pages(stats)
    for c in sorted(completions, key=lambda c: c.rid):
        print(f"  rid={c.rid}: latency={c.latency_s * 1e3:.0f} ms "
              f"{c.tokens}")


def serve_capsnet(args) -> None:
    """The paper's deployment path: prune -> compact -> compile -> serve."""
    from repro.deploy import FastCapsPipeline

    cfg = cfg_lib.get_config(args.arch)
    if args.reduced:
        cfg = cfg_lib.reduced(cfg)
    pipe = FastCapsPipeline(cfg).build(seed=0)
    if args.sparsity > 0:
        pipe.prune(args.sparsity, args.sparsity,
                   type_keep=max(cfg.caps_types // 4, 1)).compact()
    deployed = pipe.compile(routing=args.routing)
    print(f"[{cfg.arch_id}] deployed: routing={deployed.spec.mode}"
          f"(softmax={deployed.spec.softmax}) "
          f"{deployed.n_params:,} params, "
          f"{deployed.flops_per_image / 1e6:.1f} MFLOP/image")

    if args.scheduler == "disagg":
        # stateless disaggregation: dispatch frames over an engine pool
        engine = DisaggregatedEngine(
            None, [deployed.serve(batch_size=args.batch,
                                  kernel_tune=args.kernel_tune or None)
                   for _ in range(args.decode_engines)])
    else:
        engine = deployed.serve(batch_size=args.batch,
                                scheduler=_make_scheduler(args),
                                kernel_tune=args.kernel_tune or None)
    engine.warmup()
    rng = np.random.RandomState(0)
    for i in range(args.requests):
        engine.submit(ImageRequest(
            images=rng.rand(rng.randint(1, 2 * args.batch),
                            cfg.image_hw, cfg.image_hw,
                            cfg.in_channels).astype(np.float32),
            rid=i))
    completions = engine.run_until_idle()
    stats = engine.stats()
    print(f"  served {stats.completed} requests / {stats.frames} frames "
          f"in {stats.batches} ticks ({stats.padded_frames} pad): "
          f"{stats.fps:.1f} FPS, {stats.ms_per_batch:.2f} ms/tick")
    _print_latency(stats)
    for c in sorted(completions, key=lambda c: c.rid):
        print(f"  rid={c.rid}: {len(c.classes)} frames, "
              f"latency={c.latency_s * 1e3:.1f} ms, "
              f"classes={c.classes[:8].tolist()}"
              f"{'...' if len(c.classes) > 8 else ''}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=cfg_lib.list_archs())
    ap.add_argument("--reduced", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="CPU-smoke-sized config (--no-reduced for the "
                         "published size)")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--scheduler", default="fifo",
                    choices=["fifo", "slo", "interleave", "sharded",
                             "disagg"],
                    help="tick scheduler (slo adapts batch to --slo-ms; "
                         "interleave separates prefill/decode ticks; "
                         "sharded places slots across all local devices; "
                         "disagg splits prefill and decode onto separate "
                         "engines joined by cache handoffs)")
    ap.add_argument("--slo-ms", type=float, default=100.0,
                    help="SLO scheduler p95 tick-latency target")
    ap.add_argument("--decode-engines", type=int, default=2,
                    help="disagg: number of decode engines behind the "
                         "prefill engine")
    ap.add_argument("--transport", default="auto",
                    choices=["auto", "in_process", "host_staged",
                             "device_to_device"],
                    help="disagg: cache-handoff delivery route (auto "
                         "selects by mesh placement — device-to-device "
                         "when decode owns a different mesh than prefill, "
                         "in-process otherwise)")
    ap.add_argument("--multihost", action="store_true",
                    help="disagg (LM): place prefill and each decode "
                         "engine on disjoint submeshes over the local "
                         "devices, so cache handoffs genuinely cross a "
                         "device boundary")
    # LM options
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--paged", action="store_true",
                    help="LM: block-paged KV cache (global page pool + "
                         "per-slot page tables) with content-addressed "
                         "prefix reuse across requests")
    ap.add_argument("--page-size", type=int, default=16,
                    help="paged: tokens per cache page")
    ap.add_argument("--pages", type=int, default=None,
                    help="paged: total pool pages (default sizes the "
                         "pool to n_slots * max_len tokens)")
    ap.add_argument("--quantize-pages", action="store_true",
                    help="paged: store KV pages as int8 with per-row "
                         "scales, dequantized on read in-kernel")
    ap.add_argument("--decode-kernel", action="store_true",
                    help="decode through the Pallas decode_attention "
                         "kernel (paged caches read in place through the "
                         "page tables; int8 pages dequantize in-kernel) "
                         "and draw tokens on device via fused_sampling")
    ap.add_argument("--kernel-tune", action="store_true",
                    help="autotune kernel block sizes at warm-up and bind "
                         "the winners into the tick executables")
    ap.add_argument("--stream", action="store_true",
                    help="LM: print token-level StreamEvents as they are "
                         "generated (poll(stream=True))")
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--max-len", type=int, default=128)
    # Traffic-replay options
    ap.add_argument("--trace", default="none",
                    choices=["none", "poisson", "bursty"],
                    help="replay a seeded arrival trace instead of a "
                         "fixed request batch (LM only)")
    ap.add_argument("--trace-rate", type=float, default=20.0,
                    help="mean arrival rate (req/s); bursty uses it as "
                         "the burst-state rate")
    ap.add_argument("--trace-horizon", type=float, default=2.0,
                    help="trace length in seconds")
    ap.add_argument("--trace-seed", type=int, default=0,
                    help="trace RNG seed (same seed -> same arrivals "
                         "and payloads)")
    ap.add_argument("--autoscale", action="store_true",
                    help="traffic: start one decode engine and let the "
                         "depth-signal controller grow/drain the pool "
                         "up to --decode-engines")
    ap.add_argument("--priority", action="store_true",
                    help="PriorityScheduler: urgent classes admit first "
                         "and may preempt (lossless) resident work")
    ap.add_argument("--admission", action="store_true",
                    help="traffic: SLO admission control (shed arrivals "
                         "whose class SLO is already unattainable)")
    # CapsNet options
    ap.add_argument("--batch", type=int, default=16,
                    help="CapsuleEngine capacity (max frames per tick)")
    ap.add_argument("--routing", default="pallas",
                    choices=["reference", "optimized", "pallas"])
    ap.add_argument("--sparsity", type=float, default=0.6,
                    help="LAKP sparsity for both conv layers (0 = dense)")
    args = ap.parse_args()
    if args.multihost and args.scheduler != "disagg":
        ap.error("--multihost requires --scheduler disagg")
    if args.paged and args.arch.startswith("capsnet"):
        ap.error("--paged applies to LM serving only")
    if args.arch.startswith("capsnet"):
        serve_capsnet(args)
    elif args.trace != "none":
        serve_traffic(args)
    else:
        serve_lm(args)


if __name__ == "__main__":
    main()
