"""FastCaps approximate math (paper §III-B) adapted to TPU.

Eq. 2 — Taylor expansion of exp around a = 0.5, 5 multiply + 5 add (Horner):

    e^x ≈ e^a · (0.60653 + x·(0.60659 + x·(0.30260 + x·(0.10347 +
                 x·(0.02118 + 0.00833·x)))))

On the PYNQ-Z1 this cut exp() from 27 to 14 cycles.  On TPU the VPU has a
fast native exp, so the motive changes (see DESIGN.md §2): the polynomial is
kept as a *faithful mode* — it is pure MAC work, so inside a Pallas kernel it
pipelines on the same units as the matmuls with no transcendental path.

Beyond-paper extension: the raw polynomial is only accurate on roughly
x ∈ [-1.5, 2.5].  CapsNet routing logits live there; attention logits do not.
``range_reduce=True`` applies exp(x) = exp(x/2^k)^(2^k) with fixed k=5 (five
squarings — still MAC-only), extending usable range to ~[-48, 48].

Eq. 3 — a/b = exp(log a − log b), which cut the fixed-point divider from 49
to 36 cycles.  TPU has a fast reciprocal so this is off by default; it is
implemented for fidelity and benchmarked.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

# Paper Eq. 2 constants (a = 0.5).
TAYLOR_A = 0.5
E_A = 1.6487212707001282  # e^0.5
TAYLOR_COEFFS = (0.60653, 0.60659, 0.30260, 0.10347, 0.02118, 0.00833)


def taylor_exp_raw(x: jax.Array) -> jax.Array:
    """Paper Eq. 2 verbatim: 5 multiplies + 5 adds (Horner) + 1 scale."""
    c0, c1, c2, c3, c4, c5 = TAYLOR_COEFFS
    p = c4 + c5 * x
    p = c3 + x * p
    p = c2 + x * p
    p = c1 + x * p
    p = c0 + x * p
    return E_A * p


def taylor_exp(x: jax.Array, range_reduce: bool = False,
               reduce_k: int = 5) -> jax.Array:
    """Eq. 2 exp; optionally with square-and-multiply range reduction."""
    if not range_reduce:
        return taylor_exp_raw(x)
    scale = float(2 ** reduce_k)
    # Clamp so exp(x) for very negative x flushes to ~0 without the polynomial
    # going negative (poly has roots below ~ -1.6 after scaling).
    x = jnp.clip(x, -scale * 1.0, scale * 1.0)
    y = taylor_exp_raw(x / scale)
    for _ in range(reduce_k):
        y = y * y
    return y


def div_exp_log(a: jax.Array, b: jax.Array, eps: float = 1e-30) -> jax.Array:
    """Paper Eq. 3: a/b = exp(log a − log b), for a,b > 0."""
    return jnp.exp(jnp.log(jnp.maximum(a, eps)) - jnp.log(jnp.maximum(b, eps)))


def taylor_softmax(x: jax.Array, axis: int = -1,
                   range_reduce: bool = True,
                   use_div_exp_log: bool = False) -> jax.Array:
    """Softmax using Eq. 2 exp (and optionally Eq. 3 division)."""
    m = jax.lax.stop_gradient(jnp.max(x, axis=axis, keepdims=True))
    e = taylor_exp(x - m, range_reduce=range_reduce)
    denom = jnp.sum(e, axis=axis, keepdims=True)
    if use_div_exp_log:
        return div_exp_log(e, denom)
    return e / jnp.maximum(denom, 1e-30)


def squash(s: jax.Array, axis: int = -1, eps: float = 1e-9) -> jax.Array:
    """CapsNet squash: v = (‖s‖²/(1+‖s‖²)) · s/‖s‖ (Sabour et al. Eq. 1)."""
    sq = jnp.sum(jnp.square(s), axis=axis, keepdims=True)
    norm = jnp.sqrt(sq + eps)
    return (sq / (1.0 + sq)) * (s / norm)


def squash_fast(s: jax.Array, axis: int = -1, eps: float = 1e-9) -> jax.Array:
    """Squash with a single rsqrt (hardware-friendly form used on the PE
    array side of the accelerator; Fig. 11a computes ‖s‖² once)."""
    sq = jnp.sum(jnp.square(s), axis=axis, keepdims=True)
    inv = jax.lax.rsqrt(sq + eps)
    return s * (sq * inv / (1.0 + sq))
