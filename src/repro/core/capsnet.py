"""Full-fledged CapsNet (Sabour et al. [4], paper Fig. 3) in pure JAX.

Architecture (MNIST shapes):
    Conv1        9x9 conv, 1 -> 256 ch, stride 1, ReLU       -> (B, 20, 20, 256)
    PrimaryCaps  9x9 conv, 256 -> n_caps_types*caps_dim ch,
                 stride 2, reshape to capsules, squash       -> (B, 1152, 8)
    DigitCaps    per-(i, j) linear maps u_hat = W_ij u_i,
                 dynamic routing (core/routing.py)           -> (B, 10, 16)
    Decoder      FC 160 -> 512 -> 1024 -> 784, sigmoid (reconstruction reg.)

Loss: margin loss (Sabour Eq. 4) + 0.0005 * MSE reconstruction.

Pruning integration (paper Fig. 6): conv weights are stored OIHW so
``core/lakp`` can score/mask kernels directly.  ``compact()`` physically
removes capsule *types* whose conv2 channels were fully pruned — 1152 -> 252
capsules on MNIST in the paper — shrinking the routing weight W from
(1152, 10, 8, 16) to (252, 10, 8, 16): the 1280x routing-parameter reduction.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import approx_math
from repro.deploy.registry import RoutingSpec, resolve as resolve_routing
from repro.models.common import ParamDef, fanin_init, init_params, param_specs


@dataclasses.dataclass(frozen=True)
class CapsNetConfig:
    arch_id: str = "capsnet-mnist"
    image_hw: int = 28
    in_channels: int = 1
    n_classes: int = 10
    conv1_channels: int = 256
    conv1_kernel: int = 9
    caps_types: int = 32          # PrimaryCaps capsule types
    caps_dim: int = 8             # PrimaryCaps capsule dimension
    caps_kernel: int = 9
    caps_stride: int = 2
    digit_dim: int = 16           # DigitCaps dimension
    routing_iters: int = 3
    # Typed routing spec (repro.deploy); None means the reference variant.
    routing: Optional[RoutingSpec] = None
    decoder_hidden: Tuple[int, int] = (512, 1024)
    recon_weight: float = 0.0005
    param_dtype: str = "float32"
    # margin loss constants (Sabour Eq. 4)
    m_plus: float = 0.9
    m_minus: float = 0.1
    lambda_down: float = 0.5

    @property
    def conv1_out_hw(self) -> int:
        return self.image_hw - self.conv1_kernel + 1

    @property
    def caps_out_hw(self) -> int:
        return (self.conv1_out_hw - self.caps_kernel) // self.caps_stride + 1

    @property
    def n_primary_caps(self) -> int:
        return self.caps_types * self.caps_out_hw ** 2

    @property
    def primary_conv_channels(self) -> int:
        return self.caps_types * self.caps_dim

    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    def routing_spec(self) -> RoutingSpec:
        """The effective RoutingSpec (reference routing when unset)."""
        if self.routing is not None:
            return self.routing
        return RoutingSpec.reference()


# ---------------------------------------------------------------------------
# Parameter declaration
# ---------------------------------------------------------------------------


def capsnet_defs(cfg: CapsNetConfig) -> Dict[str, Any]:
    k1, k2 = cfg.conv1_kernel, cfg.caps_kernel
    c1 = cfg.conv1_channels
    c2 = cfg.primary_conv_channels
    n_in, n_out = cfg.n_primary_caps, cfg.n_classes
    d_in, d_out = cfg.caps_dim, cfg.digit_dim
    img = cfg.image_hw ** 2 * cfg.in_channels
    h1, h2 = cfg.decoder_hidden
    return {
        # OIHW conv weights (LAKP scores kernels on this layout directly)
        "conv1": {
            "w": ParamDef((c1, cfg.in_channels, k1, k1),
                          ("conv_out", "conv_in", None, None),
                          fanin_init(cfg.in_channels * k1 * k1)),
            "b": ParamDef((c1,), ("conv_out",),
                          lambda k, s, d: jnp.zeros(s, d)),
        },
        "conv2": {
            "w": ParamDef((c2, c1, k2, k2), ("conv_out", "conv_in", None, None),
                          fanin_init(c1 * k2 * k2)),
            "b": ParamDef((c2,), ("conv_out",),
                          lambda k, s, d: jnp.zeros(s, d)),
        },
        # DigitCaps transform: u_hat[b,i,j,:] = u[b,i,:] @ W[i,j]
        "digit": {
            "w": ParamDef((n_in, n_out, d_in, d_out),
                          ("caps_in", "caps_out", None, None),
                          fanin_init(d_in)),
        },
        "decoder": {
            "w1": ParamDef((n_out * d_out, h1), (None, "mlp"), fanin_init()),
            "b1": ParamDef((h1,), ("mlp",), lambda k, s, d: jnp.zeros(s, d)),
            "w2": ParamDef((h1, h2), ("mlp", None), fanin_init()),
            "b2": ParamDef((h2,), (None,), lambda k, s, d: jnp.zeros(s, d)),
            "w3": ParamDef((h2, img), (None, None), fanin_init()),
            "b3": ParamDef((img,), (None,), lambda k, s, d: jnp.zeros(s, d)),
        },
    }


def init(cfg: CapsNetConfig, key: jax.Array) -> Dict[str, Any]:
    return init_params(capsnet_defs(cfg), key, cfg.pdtype())


def specs(cfg: CapsNetConfig) -> Dict[str, Any]:
    return param_specs(capsnet_defs(cfg))


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


def _conv2d(x: jax.Array, w_oihw: jax.Array, b: jax.Array, stride: int
            ) -> jax.Array:
    """NHWC x OIHW -> NHWC, VALID padding."""
    y = jax.lax.conv_general_dilated(
        x, w_oihw, window_strides=(stride, stride), padding="VALID",
        dimension_numbers=("NHWC", "OIHW", "NHWC"),
    )
    return y + b


def primary_capsules(params: Dict[str, Any], cfg: CapsNetConfig,
                     images: jax.Array) -> jax.Array:
    """images (B, H, W, C) -> squashed primary capsules (B, N_in, caps_dim)."""
    h = jax.nn.relu(_conv2d(images, params["conv1"]["w"],
                            params["conv1"]["b"], 1))
    h = _conv2d(h, params["conv2"]["w"], params["conv2"]["b"],
                cfg.caps_stride)                      # (B, 6, 6, types*dim)
    b = h.shape[0]
    hw = cfg.caps_out_hw
    # channel layout: (types, dim); capsule index = (type, y, x)
    h = h.reshape(b, hw, hw, h.shape[-1] // cfg.caps_dim, cfg.caps_dim)
    h = h.transpose(0, 3, 1, 2, 4).reshape(b, -1, cfg.caps_dim)
    return approx_math.squash(h, axis=-1)


def predictions(params: Dict[str, Any], u: jax.Array) -> jax.Array:
    """u (B, N_in, d_in) x W (N_in, N_out, d_in, d_out) -> u_hat."""
    return jnp.einsum("bid,ijde->bije", u, params["digit"]["w"])


def digit_capsules(params: Dict[str, Any], cfg: CapsNetConfig,
                   u: jax.Array) -> Tuple[jax.Array, jax.Array]:
    u_hat = predictions(params, u)
    route_fn = resolve_routing(cfg.routing_spec())
    return route_fn(u_hat, n_iters=cfg.routing_iters)


def decode(params: Dict[str, Any], cfg: CapsNetConfig, v: jax.Array,
           labels: jax.Array) -> jax.Array:
    """Reconstruction decoder; masks all but the true class's capsule."""
    d = params["decoder"]
    mask = jax.nn.one_hot(labels, cfg.n_classes, dtype=v.dtype)  # (B, J)
    x = (v * mask[:, :, None]).reshape(v.shape[0], -1)
    x = jax.nn.relu(x @ d["w1"] + d["b1"])
    x = jax.nn.relu(x @ d["w2"] + d["b2"])
    return jax.nn.sigmoid(x @ d["w3"] + d["b3"])


def forward(params: Dict[str, Any], cfg: CapsNetConfig, images: jax.Array
            ) -> Tuple[jax.Array, jax.Array]:
    """images -> (class capsule lengths (B, n_classes), capsules v)."""
    u = primary_capsules(params, cfg, images)
    v, _ = digit_capsules(params, cfg, u)
    lengths = jnp.linalg.norm(v.astype(jnp.float32), axis=-1)
    return lengths, v


# ---------------------------------------------------------------------------
# Losses / metrics
# ---------------------------------------------------------------------------


def margin_loss(lengths: jax.Array, labels: jax.Array, cfg: CapsNetConfig
                ) -> jax.Array:
    t = jax.nn.one_hot(labels, cfg.n_classes, dtype=jnp.float32)
    pos = jnp.square(jnp.maximum(0.0, cfg.m_plus - lengths))
    neg = jnp.square(jnp.maximum(0.0, lengths - cfg.m_minus))
    per_class = t * pos + cfg.lambda_down * (1.0 - t) * neg
    return jnp.mean(jnp.sum(per_class, axis=-1))


def loss_fn(params: Dict[str, Any], cfg: CapsNetConfig,
            images: jax.Array, labels: jax.Array
            ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    u = primary_capsules(params, cfg, images)
    v, _ = digit_capsules(params, cfg, u)
    lengths = jnp.linalg.norm(v.astype(jnp.float32) + 1e-12, axis=-1)
    l_margin = margin_loss(lengths, labels, cfg)
    recon = decode(params, cfg, v, labels)
    flat = images.reshape(images.shape[0], -1).astype(jnp.float32)
    l_recon = jnp.mean(jnp.sum(jnp.square(recon - flat), axis=-1))
    loss = l_margin + cfg.recon_weight * l_recon
    acc = jnp.mean((jnp.argmax(lengths, -1) == labels).astype(jnp.float32))
    return loss, {"loss": loss, "margin": l_margin,
                  "recon": l_recon, "acc": acc}


# ---------------------------------------------------------------------------
# Pruning integration (paper Fig. 6 pipeline)
# ---------------------------------------------------------------------------


def conv_chain(params: Dict[str, Any]) -> list:
    """The prunable conv chain, with DigitCaps W as conv2's look-ahead
    neighbour: W (N_in, N_out, d_in, d_out) folds to a dense
    (conv2-out-channel, class*dim) matrix so LAKP can see how much each
    PrimaryCaps channel matters downstream."""
    w_digit = params["digit"]["w"]
    n_in, n_out, d_in, d_out = w_digit.shape
    # each conv2 output channel = one (type, dim) pair; capsule i uses
    # channels type(i)*d_in ... +d_in.  Aggregate |W| onto (types*d_in, ...)
    # by summing over spatial positions of each type.
    return [params["conv1"]["w"], params["conv2"]["w"], w_digit]


def digit_w_as_dense(w_digit: jax.Array, caps_types: int, caps_dim: int,
                     hw: int) -> jax.Array:
    """(N_in, N_out, d_in, d_out) -> (types*caps_dim [conv2 out ch], rest).

    Capsule i = (type t, spatial p); its d_in inputs are conv2 channels
    t*caps_dim..+caps_dim.  Summing |W| over spatial positions gives the
    dense next-layer weight LAKP expects: rows = conv2 out channels.
    """
    n_in, n_out, d_in, d_out = w_digit.shape
    w = jnp.abs(w_digit).reshape(caps_types, hw * hw, n_out, d_in, d_out)
    w = jnp.sum(w, axis=1)                        # (types, n_out, d_in, d_out)
    w = w.transpose(0, 2, 1, 3).reshape(caps_types * d_in, n_out * d_out)
    return w


def lakp_masks(params: Dict[str, Any], cfg: CapsNetConfig,
               sparsity_conv1: float, sparsity_conv2: float,
               method: str = "lakp", norm: str = "l1",
               type_keep: Optional[int] = None):
    """Score + mask the two conv layers (the paper prunes Conv1 and the
    PrimaryCaps conv).  Returns (mask1, mask2).

    ``type_keep``: the paper's "interconnection study" step (Fig. 6) —
    after kernel masking, whole capsule *types* are eliminated down to the
    ``type_keep`` highest-scored ones (paper: 32 -> 7 on MNIST, 32 -> 12 on
    F-MNIST), zeroing every kernel of the dropped types."""
    from repro.core import lakp as lakp_lib

    w1, w2 = params["conv1"]["w"], params["conv2"]["w"]
    w_next = digit_w_as_dense(params["digit"]["w"], cfg.caps_types,
                              cfg.caps_dim, cfg.caps_out_hw)
    if method == "lakp":
        # w_next is (conv2_out_ch, n_out*d_out) == dense (in, out) layout
        s1 = lakp_lib.lakp_kernel_scores(w1, None, w2, norm=norm)
        s2 = lakp_lib.lakp_kernel_scores(w2, w1, w_next, norm=norm)
    elif method == "kp":
        s1, s2 = lakp_lib.kp_scores(w1), lakp_lib.kp_scores(w2)
    else:
        raise ValueError(method)
    m1 = lakp_lib.mask_from_scores(s1, sparsity_conv1)
    m2 = lakp_lib.mask_from_scores(s2, sparsity_conv2)
    if type_keep is not None and type_keep < cfg.caps_types:
        m2 = eliminate_capsule_types(s2 * m2, cfg, type_keep)
    return m1, m2


def eliminate_capsule_types(masked_scores2: jax.Array, cfg: CapsNetConfig,
                            keep: int) -> jax.Array:
    """Keep only the ``keep`` capsule types with the highest surviving
    kernel score; zero all kernels of the other types (and keep the
    surviving-kernel mask within kept types)."""
    o, i = masked_scores2.shape
    per_type = masked_scores2.reshape(cfg.caps_types, cfg.caps_dim, i)
    type_scores = jnp.sum(per_type, axis=(1, 2))            # (types,)
    order = jnp.argsort(-type_scores)
    keep_idx = order[:keep]
    type_mask = jnp.zeros((cfg.caps_types,)).at[keep_idx].set(1.0)
    ch_mask = jnp.repeat(type_mask, cfg.caps_dim)           # (O,)
    return (masked_scores2 > 0).astype(jnp.float32) * ch_mask[:, None]


def apply_masks(params: Dict[str, Any], masks) -> Dict[str, Any]:
    from repro.core import lakp as lakp_lib

    m1, m2 = masks
    out = jax.tree.map(lambda x: x, params)  # shallow copy
    out["conv1"] = dict(params["conv1"])
    out["conv2"] = dict(params["conv2"])
    out["conv1"]["w"] = lakp_lib.apply_kernel_mask(params["conv1"]["w"], m1)
    out["conv2"]["w"] = lakp_lib.apply_kernel_mask(params["conv2"]["w"], m2)
    return out


def compact(params: Dict[str, Any], cfg: CapsNetConfig, masks
            ) -> Tuple[Dict[str, Any], CapsNetConfig, Dict[str, jax.Array]]:
    """Physically remove pruned structures (paper §III-C index memory, TPU
    compaction analogue — DESIGN.md §2).

    * conv1: output channels with no surviving kernel are removed (and the
      corresponding conv2 input channels).
    * conv2: capsule *types* whose all caps_dim channels lost every kernel
      are removed — this is the 1152 -> 252 capsule elimination — and the
      DigitCaps weight rows for those capsules are removed.

    Returns (compacted params, updated config, surviving index vectors).
    """
    m1, m2 = masks
    w1, b1 = params["conv1"]["w"], params["conv1"]["b"]
    w2, b2 = params["conv2"]["w"], params["conv2"]["b"]
    wd = params["digit"]["w"]

    alive1 = jnp.nonzero(jnp.any(m1 > 0, axis=1))[0]          # conv1 out ch
    w1c = w1[alive1]
    b1c = b1[alive1]
    w2c = w2[:, alive1]                                       # conv2 in ch
    m2c = m2                                                  # (O2, I2) rows keep

    # capsule types: group conv2 out channels by caps_dim
    alive_ch = jnp.any(m2c > 0, axis=1)                       # (O2,)
    types_alive = jnp.any(
        alive_ch.reshape(cfg.caps_types, cfg.caps_dim), axis=1)
    type_idx = jnp.nonzero(types_alive)[0]                    # surviving types
    ch_idx = (type_idx[:, None] * cfg.caps_dim
              + jnp.arange(cfg.caps_dim)[None, :]).reshape(-1)
    w2c = w2c[ch_idx]
    b2c = b2[ch_idx]

    # DigitCaps rows: capsule i = (type, spatial); keep surviving types
    hw2 = cfg.caps_out_hw ** 2
    wd_t = wd.reshape(cfg.caps_types, hw2, cfg.n_classes, cfg.caps_dim,
                      cfg.digit_dim)
    wd_c = wd_t[type_idx].reshape(-1, cfg.n_classes, cfg.caps_dim,
                                  cfg.digit_dim)

    new_cfg = dataclasses.replace(
        cfg,
        conv1_channels=int(alive1.shape[0]),
        caps_types=int(type_idx.shape[0]),
    )
    out = {
        "conv1": {"w": w1c, "b": b1c},
        "conv2": {"w": w2c, "b": b2c},
        "digit": {"w": wd_c},
        "decoder": params["decoder"],
    }
    index = {"conv1_out": alive1, "caps_types": type_idx}
    return out, new_cfg, index


def param_count(params: Dict[str, Any]) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(params))
