"""Core: the paper's contribution — CapsNet, dynamic routing, LAKP pruning,
approximate math (Eq. 2/3), and the prune->finetune->compact pipeline."""

from repro.core import approx_math, capsnet, lakp, pruning, routing  # noqa: F401
