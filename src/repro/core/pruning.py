"""Prune -> fine-tune -> compact pipeline (paper Fig. 6).

The methodology: (1) score + mask kernels with LAKP (or a baseline method),
(2) fine-tune the masked network (masked weights stay zero: gradients are
multiplied by the mask each step), (3) study interconnections and physically
eliminate dead kernels/capsules (``capsnet.compact``), (4) hand the compacted
model to the optimized-routing deployment path.

The same pipeline generalizes to LM architectures (DESIGN.md §5): FFN hidden
blocks, attention-head blocks and MoE experts are pruned with
``lakp.prune_blocks`` and compacted with ``lakp.compact_blocks``.

The canonical CapsNet entry point is ``repro.deploy.FastCapsPipeline``
(the former ``prune_capsnet`` free function completed its deprecation
cycle and is gone); this module keeps the optimizer-facing mask helper
and the LM-substrate structured pruning.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core import lakp as lakp_lib


def mask_gradients(grads: Dict[str, Any], masks) -> Dict[str, Any]:
    """Keep pruned kernels at zero during fine-tuning."""
    m1, m2 = masks
    out = jax.tree.map(lambda g: g, grads)
    out["conv1"] = dict(grads["conv1"])
    out["conv2"] = dict(grads["conv2"])
    out["conv1"]["w"] = lakp_lib.apply_kernel_mask(grads["conv1"]["w"], m1)
    out["conv2"]["w"] = lakp_lib.apply_kernel_mask(grads["conv2"]["w"], m2)
    return out


# ---------------------------------------------------------------------------
# LM-substrate structured pruning (DESIGN.md §5 generalization)
# ---------------------------------------------------------------------------


def prune_lm_ffn(params: Dict[str, Any], n_blocks: int, sparsity: float,
                 method: str = "lakp") -> Tuple[Dict[str, Any], jax.Array]:
    """Prune hidden blocks of one FFN param dict ({wi, wo[, wg]})."""
    w_in, w_out = params["wi"], params["wo"]
    wi2 = w_in.reshape(w_in.shape[0], -1)
    wo2 = w_out.reshape(w_out.shape[0], -1) if w_out.ndim == 2 else w_out
    wi_m, wo_m, mask = lakp_lib.prune_blocks(
        wi2, wo2, n_blocks, sparsity, method=method)
    out = dict(params)
    out["wi"], out["wo"] = wi_m.reshape(w_in.shape), wo_m.reshape(w_out.shape)
    if "wg" in params:
        blk = w_in.shape[1] // n_blocks
        m_f = jnp.repeat(mask, blk)
        out["wg"] = params["wg"] * m_f[None, :].astype(params["wg"].dtype)
    return out, mask


def prune_lm_heads(params: Dict[str, Any], n_heads: int, n_kv_heads: int,
                   sparsity: float, method: str = "lakp"
                   ) -> Tuple[Dict[str, Any], jax.Array]:
    """Prune attention heads in KV-head groups (so GQA stays consistent).

    Scores: look-ahead product of the group's Q-projection fan-in and
    O-projection fan-out (K/V share the group).  Mask granularity is one KV
    group = n_heads/n_kv_heads query heads.
    """
    wq, wo = params["wq"], params["wo"]          # (d, H, hd), (H, hd, d)
    d, h, hd = wq.shape
    g = h // n_kv_heads
    wq2 = wq.reshape(d, h * hd)
    wo2 = wo.reshape(h * hd, d)
    if method == "lakp":
        scores = lakp_lib.block_lookahead_scores(wq2, wo2, n_kv_heads)
    else:
        scores = lakp_lib.block_magnitude_scores(wq2, wo2, n_kv_heads)
    mask = lakp_lib.mask_from_scores(scores, sparsity)    # (n_kv,)
    mq = jnp.repeat(mask, g * hd).reshape(1, h, hd)
    mkv = jnp.repeat(mask, hd).reshape(1, n_kv_heads, hd)
    out = dict(params)
    out["wq"] = wq * mq.astype(wq.dtype)
    out["wk"] = params["wk"] * mkv.astype(wq.dtype)
    out["wv"] = params["wv"] * mkv.astype(wq.dtype)
    out["wo"] = wo * mq.reshape(h, hd, 1).astype(wo.dtype)
    return out, mask


def prune_moe_experts(params: Dict[str, Any], sparsity: float,
                      method: str = "lakp") -> Tuple[Dict[str, Any], jax.Array]:
    """Prune whole routed experts (the MoE analogue of capsule elimination).

    Expert score = lookahead product of its input/output projections; the
    router column of a pruned expert is driven to -inf-like suppression by
    zeroing (top-k then never selects an all-zero-output expert only if the
    router also suppresses it, so we zero the router column too).
    """
    wi, wo = params["wi"], params["wo"]          # (E, d, f), (E, f, d)
    e = wi.shape[0]
    if method == "lakp":
        a = jnp.sum(jnp.abs(wi), axis=(1, 2))
        b = jnp.sum(jnp.abs(wo), axis=(1, 2))
        scores = a * b
    else:
        scores = jnp.sum(jnp.abs(wi), axis=(1, 2)) + jnp.sum(
            jnp.abs(wo), axis=(1, 2))
    mask = lakp_lib.mask_from_scores(scores, sparsity)    # (E,)
    m3 = mask.reshape(e, 1, 1)
    out = dict(params)
    out["wi"] = wi * m3.astype(wi.dtype)
    out["wg"] = params["wg"] * m3.astype(wi.dtype)
    out["wo"] = wo * m3.astype(wi.dtype)
    # suppress pruned experts at the router via the additive logit bias
    # (a weight-level offset would flip sign with negative activations)
    out["router_b"] = (params.get(
        "router_b", jnp.zeros((e,), params["router"].dtype))
        + (mask - 1.0) * 1e9).astype(params["router"].dtype)
    return out, mask
