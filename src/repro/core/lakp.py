"""Look-Ahead Kernel Pruning (LAKP) — the paper's Algorithm 1 — plus baselines.

Paper semantics
---------------
Eq. 1 (per-parameter look-ahead score, from Park et al. ICLR'20):

    L_i(w) = |w| * ||W_{i-1}[j, :]||_F * ||W_{i+1}[:, k]||_F

Algorithm 1 (kernel-structured): the score of a *kernel* — one (out_ch,
in_ch) k x k slice of a conv weight — is the SUM of the look-ahead scores of
its parameters.  Per layer, the lowest-scored kernels are masked until the
layer's sparsity target is met.

Fig. 7 works the example with L1 kernel norms (sums of |w|), not Frobenius:

    score(W_i(a,b)) = sum|W_i(a,b)|
                      * (sum_c sum|W_{i-1}(b,c)|)      # kernels producing in-ch b
                      * (sum_d sum|W_{i+1}(d,a)|)      # kernels consuming out-ch a

    giving 2295 / 2280 / 3060 / 3800 for the 2x2x3x3 example and, at 50%
    sparsity, mask [[0,0],[1,1]].

We implement both norms (``norm="l1"`` matches Fig. 7 and is the default;
``norm="fro"`` matches Eq. 1 verbatim).  Boundary layers use 1.0 for the
missing neighbour factor (Park et al. convention).

Weight layout: conv kernels are OIHW — shape (out_ch, in_ch, kh, kw).  A
"kernel" is one [o, i, :, :] slice.  Dense layers participate as neighbours
with shape (in, out) (one "kernel" per (in, out) scalar — the general case of
kh = kw = 1).

Baselines implemented alongside (the paper compares against both):
  * ``kp_scores``           — magnitude-based Kernel Pruning [14] (Mao et al.)
  * ``unstructured_mask``   — per-weight magnitude pruning [21] (Han et al.)

Generalization to LM structures (DESIGN.md §5): ``block_lookahead_scores``
scores any structured block (FFN hidden unit, attention head, MoE expert)
as  n(W_in block) * n(W_out block) — the look-ahead product restricted to
the structure's own fan-in/fan-out matrices.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Norm helpers
# ---------------------------------------------------------------------------


def _kernel_norms(w: jax.Array, norm: str) -> jax.Array:
    """Per-kernel norms of an OIHW conv weight -> (out_ch, in_ch).

    Also accepts 2-D (in, out) dense weights, returning |w| (or w^2 for
    ``fro`` — see note below) transposed to (out, in).
    """
    if w.ndim == 2:  # dense (in, out) -> treat each scalar as a 1x1 kernel
        a = jnp.abs(w).T if norm == "l1" else jnp.square(w).T
        return a
    assert w.ndim == 4, f"expected OIHW conv weight, got shape {w.shape}"
    if norm == "l1":
        return jnp.sum(jnp.abs(w), axis=(2, 3))
    # For Frobenius the *sums over kernels* below must add squares and take
    # the root at the end, so return squared sums here.
    return jnp.sum(jnp.square(w), axis=(2, 3))


def _finalize(x: jax.Array, norm: str) -> jax.Array:
    return x if norm == "l1" else jnp.sqrt(x)


# ---------------------------------------------------------------------------
# LAKP kernel scores (Algorithm 1 lines 5-7)
# ---------------------------------------------------------------------------


def lakp_kernel_scores(
    w_i: jax.Array,
    w_prev: Optional[jax.Array] = None,
    w_next: Optional[jax.Array] = None,
    norm: str = "l1",
) -> jax.Array:
    """Look-ahead scores for every kernel of layer i -> (out_ch, in_ch).

    ``w_prev``/``w_next`` are the adjacent layers' weights (OIHW conv or
    (in, out) dense); ``None`` means the layer is at a boundary and the
    corresponding factor is 1.
    """
    own = _kernel_norms(w_i, norm)                        # (O, I)
    o, i = own.shape

    if w_prev is not None:
        prev = _kernel_norms(w_prev, norm)                # (O_prev=I, I_prev)
        assert prev.shape[0] == i, (
            f"prev layer out_ch {prev.shape[0]} != layer in_ch {i}")
        prev_fac = jnp.sum(prev, axis=1)                  # (I,)
    else:
        prev_fac = jnp.ones((i,), w_i.dtype)

    if w_next is not None:
        nxt = _kernel_norms(w_next, norm)                 # (O_next, I_next=O)
        assert nxt.shape[1] == o, (
            f"next layer in_ch {nxt.shape[1]} != layer out_ch {o}")
        next_fac = jnp.sum(nxt, axis=0)                   # (O,)
    else:
        next_fac = jnp.ones((o,), w_i.dtype)

    own = _finalize(own, norm)
    prev_fac = _finalize(prev_fac, norm)
    next_fac = _finalize(next_fac, norm)
    return own * prev_fac[None, :] * next_fac[:, None]


def kp_scores(w_i: jax.Array) -> jax.Array:
    """Magnitude-based kernel pruning [14]: score = sum |w| per kernel."""
    return _kernel_norms(w_i, "l1")


# ---------------------------------------------------------------------------
# Masking (Algorithm 1 lines 8-10)
# ---------------------------------------------------------------------------


def mask_from_scores(scores: jax.Array, sparsity: float) -> jax.Array:
    """Zero the ``sparsity`` fraction of lowest-scored entries.

    Exactly floor(sparsity * N) entries are pruned (deterministic count, as
    Algorithm 1's s_i-th smallest threshold implies).  Ties are broken by
    flat index (stable), making the mask deterministic.
    """
    flat = scores.reshape(-1)
    n = flat.shape[0]
    n_prune = int(sparsity * n)
    if n_prune <= 0:
        return jnp.ones_like(flat, jnp.float32).reshape(scores.shape)
    if n_prune >= n:
        return jnp.zeros_like(flat, jnp.float32).reshape(scores.shape)
    # argsort ascending; prune the first n_prune positions.
    order = jnp.argsort(flat, stable=True)
    mask = jnp.ones((n,), jnp.float32).at[order[:n_prune]].set(0.0)
    return mask.reshape(scores.shape)


def apply_kernel_mask(w: jax.Array, mask: jax.Array) -> jax.Array:
    """Algorithm 1 line 10: W~ = M . W  (mask broadcast over kernel dims)."""
    if w.ndim == 4:
        return w * mask[:, :, None, None].astype(w.dtype)
    if w.ndim == 2:
        return w * mask.T.astype(w.dtype)
    raise ValueError(f"unsupported weight ndim {w.ndim}")


# ---------------------------------------------------------------------------
# Algorithm 1 — whole-network layer-wise LAKP
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class PruneResult:
    weights: List[jax.Array]      # pruned (masked) weights, same shapes
    masks: List[jax.Array]        # (out_ch, in_ch) kernel masks per layer
    scores: List[jax.Array]       # kernel scores per layer


def lakp_prune(
    weights: Sequence[jax.Array],
    sparsities: Sequence[float],
    norm: str = "l1",
) -> PruneResult:
    """Algorithm 1: layer-wise look-ahead kernel pruning of a conv chain.

    ``weights`` — the L conv weights (OIHW), in forward order.  Layer i's
    neighbours are weights[i-1] and weights[i+1] (boundary -> factor 1).
    ``sparsities`` — desired per-layer kernel sparsity s_i in [0, 1).
    """
    assert len(weights) == len(sparsities)
    out_w, out_m, out_s = [], [], []
    for i, w in enumerate(weights):
        w_prev = weights[i - 1] if i > 0 else None
        w_next = weights[i + 1] if i + 1 < len(weights) else None
        scores = lakp_kernel_scores(w, w_prev, w_next, norm=norm)
        mask = mask_from_scores(scores, float(sparsities[i]))
        out_w.append(apply_kernel_mask(w, mask))
        out_m.append(mask)
        out_s.append(scores)
    return PruneResult(out_w, out_m, out_s)


def kp_prune(
    weights: Sequence[jax.Array],
    sparsities: Sequence[float],
) -> PruneResult:
    """Magnitude-based kernel pruning [14] with the same masking machinery."""
    out_w, out_m, out_s = [], [], []
    for w, s in zip(weights, sparsities):
        scores = kp_scores(w)
        mask = mask_from_scores(scores, float(s))
        out_w.append(apply_kernel_mask(w, mask))
        out_m.append(mask)
        out_s.append(scores)
    return PruneResult(out_w, out_m, out_s)


def unstructured_mask(w: jax.Array, sparsity: float) -> jax.Array:
    """Per-weight magnitude pruning [21]: mask of w's shape."""
    return mask_from_scores(jnp.abs(w), sparsity)


# ---------------------------------------------------------------------------
# Structured-pruning bookkeeping (paper §III-C)
# ---------------------------------------------------------------------------


def surviving_channel_index(mask: jax.Array, group: int = 1) -> jax.Array:
    """Output channels (groups of ``group`` channels) with >=1 surviving kernel.

    This is the paper's "index memory": with structured kernel pruning only
    per-kernel (or per-channel-group) indices are stored — 0.1% of surviving
    weights rather than per-weight indices as in unstructured pruning.
    ``group`` > 1 groups output channels (a PrimaryCaps capsule type spans
    ``caps_dim`` conv output channels).
    """
    alive = jnp.any(mask > 0, axis=1)                     # (O,) any in-ch alive
    if group > 1:
        o = alive.shape[0]
        alive = jnp.any(alive.reshape(o // group, group), axis=1)
    return jnp.nonzero(alive, size=None)[0]


def index_overhead_bytes(masks: Sequence[jax.Array], bytes_per_index: int = 2
                         ) -> int:
    """Bytes needed to store surviving-kernel indices (paper: ~0.1%)."""
    total = 0
    for m in masks:
        total += int(jnp.sum(m > 0)) * bytes_per_index
    return total


def effective_compression(masks: Sequence[jax.Array],
                          weights: Sequence[jax.Array]) -> float:
    """Fraction of conv parameters removed (the paper's compression rate)."""
    kept = 0
    total = 0
    for m, w in zip(masks, weights):
        kernel_size = int(w.shape[2] * w.shape[3]) if w.ndim == 4 else 1
        kept += int(jnp.sum(m > 0)) * kernel_size
        total += int(w.size)
    return 1.0 - kept / max(total, 1)


# ---------------------------------------------------------------------------
# Generalization to LM structures (DESIGN.md §5): FFN units, heads, experts
# ---------------------------------------------------------------------------


def block_lookahead_scores(w_in: jax.Array, w_out: jax.Array,
                           n_blocks: int, norm: str = "l1") -> jax.Array:
    """Look-ahead scores for ``n_blocks`` structured blocks of a paired
    (W_in: (d, f), W_out: (f, d)) layer — FFN hidden units grouped into
    blocks, attention heads (f = n_heads * head_dim), MoE experts (stacked
    f), etc.

    score(block) = n(W_in[:, block]) * n(W_out[block, :])
    """
    d, f = w_in.shape
    assert w_out.shape[0] == f, (w_in.shape, w_out.shape)
    assert f % n_blocks == 0, (f, n_blocks)
    blk = f // n_blocks
    if norm == "l1":
        a = jnp.sum(jnp.abs(w_in).reshape(d, n_blocks, blk), axis=(0, 2))
        b = jnp.sum(jnp.abs(w_out).reshape(n_blocks, blk, -1), axis=(1, 2))
    else:
        a = jnp.sqrt(jnp.sum(jnp.square(w_in).reshape(d, n_blocks, blk),
                             axis=(0, 2)))
        b = jnp.sqrt(jnp.sum(jnp.square(w_out).reshape(n_blocks, blk, -1),
                             axis=(1, 2)))
    return a * b


def block_magnitude_scores(w_in: jax.Array, w_out: jax.Array,
                           n_blocks: int) -> jax.Array:
    """Magnitude (KP-style) block scores: n1(W_in block) + n1(W_out block)."""
    d, f = w_in.shape
    blk = f // n_blocks
    a = jnp.sum(jnp.abs(w_in).reshape(d, n_blocks, blk), axis=(0, 2))
    b = jnp.sum(jnp.abs(w_out).reshape(n_blocks, blk, -1), axis=(1, 2))
    return a + b


def prune_blocks(w_in: jax.Array, w_out: jax.Array, n_blocks: int,
                 sparsity: float, method: str = "lakp",
                 norm: str = "l1") -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Mask whole blocks of a paired FFN-like layer; returns (w_in~, w_out~,
    block mask (n_blocks,))."""
    if method == "lakp":
        scores = block_lookahead_scores(w_in, w_out, n_blocks, norm)
    elif method == "kp":
        scores = block_magnitude_scores(w_in, w_out, n_blocks)
    else:
        raise ValueError(method)
    mask = mask_from_scores(scores, sparsity)             # (n_blocks,)
    d, f = w_in.shape
    blk = f // n_blocks
    m_f = jnp.repeat(mask, blk)                           # (f,)
    return (w_in * m_f[None, :].astype(w_in.dtype),
            w_out * m_f[:, None].astype(w_out.dtype),
            mask)


def compact_blocks(w_in: jax.Array, w_out: jax.Array, mask: jax.Array
                   ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Physically remove pruned blocks (TPU analogue of index memory —
    DESIGN.md §2: compaction, not sparse indexing).  Returns compacted
    (w_in, w_out, surviving block indices)."""
    idx = jnp.nonzero(mask > 0)[0]
    n_blocks = mask.shape[0]
    d, f = w_in.shape
    blk = f // n_blocks
    w_in_b = w_in.reshape(d, n_blocks, blk)[:, idx].reshape(d, -1)
    w_out_b = w_out.reshape(n_blocks, blk, -1)[idx].reshape(-1, w_out.shape[1])
    return w_in_b, w_out_b, idx
