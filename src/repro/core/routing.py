"""Dynamic routing between capsules (Sabour et al., paper Fig. 4).

Inputs: prediction vectors ``u_hat`` of shape (B, N_in, N_out, D_out) where
``u_hat[b, i, j, :]`` is capsule i's prediction for parent capsule j.

Algorithm (r iterations, r=3 in the paper):

    b_ij = 0
    repeat r times:
        c_i: = softmax(b_i:)                 over parents j     (Softmax step)
        s_j  = sum_i c_ij * u_hat_ij                            (FC step)
        v_j  = squash(s_j)                                      (Squash step)
        b_ij += <u_hat_ij, v_j>                                 (Agreement step)

Variant selection lives in ``repro.deploy``: build a typed
``RoutingSpec`` and ``resolve()`` it through the registry; the free
functions below are the registered implementations.

Variants (``mode``):
  * ``reference``  — exact softmax/div, einsum contractions; the oracle.
  * ``optimized``  — the FastCaps §III-B simplifications mapped to TPU:
        - Taylor-series exp (Eq. 2) in the softmax, optional exp/log div
          (Eq. 3);
        - the Agreement/FC contractions expressed as (N_out*D)-shaped
          matmuls (the paper's loop reordering: j,k become the outer loops,
          removing the write conflict — here the MXU-shaped contraction);
  * ``pallas``     — kernels/routing: the whole r-iteration loop fused in
        one VMEM-resident Pallas kernel (the paper's "everything in BRAM").

All variants return (v, c_last): parent capsules (B, N_out, D_out) and the
final coupling coefficients (B, N_in, N_out).
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import approx_math


def _softmax_parents(b: jax.Array, mode: str, use_div_exp_log: bool = False
                     ) -> jax.Array:
    """Softmax over the parent axis (last axis of (B, N_in, N_out))."""
    if mode == "taylor":
        return approx_math.taylor_softmax(
            b, axis=-1, range_reduce=True, use_div_exp_log=use_div_exp_log)
    return jax.nn.softmax(b, axis=-1)


def route_reference(u_hat: jax.Array, n_iters: int = 3,
                    ) -> Tuple[jax.Array, jax.Array]:
    """Oracle implementation — direct transcription of Fig. 4."""
    bsz, n_in, n_out, d = u_hat.shape
    uf = u_hat.astype(jnp.float32)
    b = jnp.zeros((bsz, n_in, n_out), jnp.float32)
    c = v = None
    for _ in range(n_iters):
        c = jax.nn.softmax(b, axis=-1)                       # (B, I, J)
        s = jnp.einsum("bij,bijd->bjd", c, uf)               # FC
        v = approx_math.squash(s, axis=-1)                   # Squash
        b = b + jnp.einsum("bijd,bjd->bij", uf, v)           # Agreement
    return v.astype(u_hat.dtype), c


def route_optimized(u_hat: jax.Array, n_iters: int = 3,
                    softmax_mode: str = "taylor",
                    use_div_exp_log: bool = False,
                    ) -> Tuple[jax.Array, jax.Array]:
    """FastCaps-optimized routing (paper §III-B) in pure JAX.

    The contraction layout is the TPU analogue of the paper's reordered
    loops (Code 2): ``u_hat`` is viewed as (B, N_in, N_out*D) so the FC step
    ``s = c^T @ u`` and the Agreement step ``b += u @ v`` are single
    MXU-shaped matmuls over the flattened parent axis, with no scatter into
    ``b`` (the write conflict the paper removes by making j,k outer loops).
    """
    bsz, n_in, n_out, d = u_hat.shape
    uf = u_hat.astype(jnp.float32).reshape(bsz, n_in, n_out * d)
    b = jnp.zeros((bsz, n_in, n_out), jnp.float32)
    c = v = None
    for _ in range(n_iters):
        c = _softmax_parents(b, softmax_mode, use_div_exp_log)
        # FC: (B, J, I) @ (B, I, J*D) -> diag over J — cheaper as one matmul
        # producing (B, J, J*D) would waste J x; instead contract per-parent
        # via the (B, I, J, D) view folded to a batched matmul over (I):
        s = jnp.einsum("bij,bijd->bjd", c, uf.reshape(bsz, n_in, n_out, d))
        v = approx_math.squash_fast(s, axis=-1)
        # Agreement as a single (B, I, J*D) x (B, J*D block-diag v) matmul —
        # flattened: b_ij = sum_d u[b,i,j,d] * v[b,j,d]
        b = b + jnp.einsum("bijd,bjd->bij",
                           uf.reshape(bsz, n_in, n_out, d), v)
    return v.astype(u_hat.dtype), c


def route_pallas(u_hat: jax.Array, n_iters: int = 3,
                 softmax_mode: str = "taylor",
                 interpret: bool | None = None
                 ) -> Tuple[jax.Array, jax.Array]:
    """Fused VMEM-resident routing kernel, dispatched through the
    :data:`repro.kernels.registry` (block sizes come from the tuner cache
    or the deterministic legalized defaults).

    ``interpret=None`` lets the registry probe the backend (compiled on
    TPU, interpret mode elsewhere).
    """
    from repro import kernels

    return kernels.fused_routing(
        u_hat, n_iters=n_iters, softmax_mode=softmax_mode,
        interpret=interpret)


def routing_flops(bsz: int, n_in: int, n_out: int, d: int, n_iters: int = 3
                  ) -> int:
    """Analytic FLOP count of the routing loop (for Fig. 8 / roofline)."""
    per_iter = (
        2 * bsz * n_in * n_out * d      # FC (mul+add)
        + 2 * bsz * n_in * n_out * d    # Agreement
        + 6 * bsz * n_in * n_out        # softmax (exp + norm, ~6 flops/elt)
        + 6 * bsz * n_out * d           # squash
    )
    return per_iter * n_iters
