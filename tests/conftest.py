"""Test config: CPU, single device (the dry-run sets 512 devices ONLY in
its own subprocess — never here), fp64 off, deterministic seeds."""

import os

# Make sure accidental imports of repro.launch.dryrun in a dev loop don't
# leak 512 virtual devices into the test process: tests must see 1 device.
# The serving-conformance CI lane opts out explicitly (it *wants* a forced
# 2-device CPU host for the sharded/disaggregated placement paths).
if os.environ.get("REPRO_TESTS_KEEP_XLA_FLAGS", "") != "1":
    os.environ.pop("XLA_FLAGS", None)

import jax  # noqa: E402

jax.config.update("jax_platform_name", "cpu")


def pytest_configure(config):
    # registered in pytest.ini too; kept here so running a test file from
    # another rootdir still knows the marker
    config.addinivalue_line(
        "markers", "slow: long-running test (deselect with -m \"not slow\")")
