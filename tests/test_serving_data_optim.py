"""Serving engine, synthetic data, optimizer, LM structured pruning."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import pruning as pr
from repro.data import synthetic_digits as sd
from repro.data.tokens import TokenStream, TokenStreamConfig
from repro.models import attention as attn_lib
from repro.models import lm
from repro.models import moe as moe_lib
from repro.models.common import LMConfig, MoEConfig, init_params
from repro.optim import adamw
from repro.serving import Request, ServeEngine


def tiny_lm(**kw):
    base = dict(arch_id="tiny", family="dense", n_layers=2, d_model=32,
                n_heads=4, n_kv_heads=2, d_ff=64, vocab=64, remat=False,
                compute_dtype="float32", param_dtype="float32")
    base.update(kw)
    return LMConfig(**base)


class TestServing:
    def test_generate_greedy_deterministic(self):
        cfg = tiny_lm()
        params = lm.init(cfg, jax.random.key(0))
        eng = ServeEngine(cfg, params, max_len=64)
        a = eng.generate([[1, 2, 3]], max_new_tokens=6)
        b = eng.generate([[1, 2, 3]], max_new_tokens=6)
        assert a == b
        assert len(a[0]) == 9

    def test_generate_matches_manual_decode(self):
        """Engine greedy decode == manual argmax loop over decode_step."""
        cfg = tiny_lm()
        params = lm.init(cfg, jax.random.key(0))
        eng = ServeEngine(cfg, params, max_len=32)
        prompt = [5, 9, 2, 7]
        out = eng.generate([prompt], max_new_tokens=4)[0]
        caches = lm.make_caches(cfg, 1, 32)
        logits, caches = lm.prefill_step(
            params, cfg, {"tokens": jnp.asarray([prompt])}, caches)
        toks = list(prompt)
        pos = len(prompt)
        for _ in range(4):
            nxt = int(jnp.argmax(logits[0]))
            toks.append(nxt)
            logits, caches = lm.decode_step(
                params, cfg, {"tokens": jnp.asarray([[nxt]]),
                              "pos": jnp.int32(pos)}, caches)
            pos += 1
        assert out == toks

    def test_slot_engine_completes_all(self):
        cfg = tiny_lm()
        params = lm.init(cfg, jax.random.key(0))
        eng = ServeEngine(cfg, params, n_slots=2, max_len=48)
        reqs = [Request(prompt=[i + 1, i + 2], max_new_tokens=3, rid=i)
                for i in range(5)]
        comps = eng.serve(reqs)
        assert sorted(c.rid for c in comps) == [0, 1, 2, 3, 4]
        for c in comps:
            assert len(c.tokens) == 2 + 3


class TestData:
    def test_digits_deterministic(self):
        a = sd.load(sd.DigitsConfig(n_train=8, n_test=4, seed=3))
        b = sd.load(sd.DigitsConfig(n_train=8, n_test=4, seed=3))
        np.testing.assert_array_equal(a["train"][0], b["train"][0])

    def test_digits_shapes_range(self):
        d = sd.load(sd.DigitsConfig(n_train=16, n_test=8))
        x, y = d["train"]
        assert x.shape == (16, 28, 28, 1)
        assert x.min() >= 0.0 and x.max() <= 1.0
        assert set(np.unique(y)) <= set(range(10))

    def test_classes_visually_distinct(self):
        """Mean images of different classes differ substantially."""
        d = sd.load(sd.DigitsConfig(n_train=200, n_test=8, noise=0.0))
        x, y = d["train"]
        means = [x[y == c].mean(0) for c in range(10) if (y == c).sum() > 3]
        dists = [np.abs(a - b).mean() for i, a in enumerate(means)
                 for b in means[i + 1:]]
        assert min(dists) > 0.01

    def test_token_stream_learnable_structure(self):
        """Markov stream: successor distribution is concentrated."""
        ts = TokenStream(TokenStreamConfig(vocab=64, seed=0))
        batch = ts.sample(8, 256, seed=1)
        toks, labels = batch["tokens"], batch["labels"]
        assert toks.shape == (8, 256)
        # labels are next tokens
        np.testing.assert_array_equal(toks[:, 1:], labels[:, :-1])
        # ~90% of transitions land in the branch successors
        hits = 0
        total = 0
        for b in range(8):
            for t in range(255):
                total += 1
                if labels[b, t] in ts.successors[toks[b, t]]:
                    hits += 1
        assert hits / total > 0.8


class TestOptim:
    def test_adamw_converges_on_quadratic(self):
        target = jnp.asarray([1.0, -2.0, 3.0])
        params = {"w": jnp.zeros(3)}
        state = adamw.init_state(params)
        cfg = adamw.AdamWConfig(lr=0.1, weight_decay=0.0, grad_clip=0.0,
                                schedule="constant", warmup_steps=0,
                                total_steps=100)
        for _ in range(200):
            g = {"w": 2 * (params["w"] - target)}
            params, state, _ = adamw.apply_updates(params, g, state, cfg)
        np.testing.assert_allclose(np.asarray(params["w"]),
                                   np.asarray(target), atol=1e-2)

    def test_grad_clip(self):
        g = {"a": jnp.full((4,), 100.0)}
        clipped, norm = adamw.clip_by_global_norm(g, 1.0)
        assert abs(float(jnp.linalg.norm(clipped["a"])) - 1.0) < 1e-5
        assert float(norm) == pytest.approx(200.0)

    def test_schedule_warmup_and_decay(self):
        cfg = adamw.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                                schedule="cosine", min_lr_frac=0.1)
        assert float(adamw.schedule_lr(cfg, jnp.int32(5))) == \
            pytest.approx(0.5)
        assert float(adamw.schedule_lr(cfg, jnp.int32(10))) == \
            pytest.approx(1.0)
        assert float(adamw.schedule_lr(cfg, jnp.int32(100))) == \
            pytest.approx(0.1, abs=1e-6)

    def test_weight_decay_shrinks(self):
        params = {"w": jnp.ones(4)}
        state = adamw.init_state(params)
        cfg = adamw.AdamWConfig(lr=0.1, weight_decay=0.5, grad_clip=0.0,
                                schedule="constant", warmup_steps=0)
        g = {"w": jnp.zeros(4)}
        p2, _, _ = adamw.apply_updates(params, g, state, cfg)
        assert float(p2["w"][0]) < 1.0


class TestLMPruning:
    def test_prune_ffn_blocks(self):
        cfg = tiny_lm()
        from repro.models import mlp as mlp_lib
        params = init_params(mlp_lib.mlp_defs(cfg), jax.random.key(0),
                             jnp.float32)
        pruned, mask = pr.prune_lm_ffn(params, n_blocks=8, sparsity=0.5)
        assert int(mask.sum()) == 4
        # zeroed columns of wi/wg and rows of wo line up
        blk = cfg.d_ff // 8
        for b in range(8):
            sl = slice(b * blk, (b + 1) * blk)
            if float(mask[b]) == 0.0:
                assert float(jnp.abs(pruned["wi"][:, sl]).sum()) == 0.0
                assert float(jnp.abs(pruned["wo"][sl, :]).sum()) == 0.0

    def test_prune_heads_gqa_groups(self):
        cfg = tiny_lm(n_heads=4, n_kv_heads=2)
        params = init_params(attn_lib.attention_defs(cfg),
                             jax.random.key(0), jnp.float32)
        pruned, mask = pr.prune_lm_heads(params, 4, 2, sparsity=0.5)
        assert mask.shape == (2,)
        dead = int(jnp.argmin(mask))
        assert float(jnp.abs(pruned["wk"][:, dead]).sum()) == 0.0

    def test_prune_moe_experts_never_routes_to_dead(self):
        cfg = tiny_lm(family="moe",
                      moe=MoEConfig(n_experts=8, top_k=2, d_expert=16))
        params = init_params(moe_lib.moe_defs(cfg), jax.random.key(0),
                             jnp.float32)
        pruned, mask = pr.prune_moe_experts(params, sparsity=0.5)
        x = jax.random.normal(jax.random.key(1), (2, 32, 32))
        logits = x @ pruned["router"] + pruned["router_b"]
        _, ids = jax.lax.top_k(logits, 2)
        dead = set(np.where(np.asarray(mask) == 0)[0].tolist())
        assert not (set(np.unique(np.asarray(ids))) & dead)
