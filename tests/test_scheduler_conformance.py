"""One contract suite for EVERY Scheduler implementation.

The scheduler surface grew to six variants (FIFO, SLO-batch, sharded,
interleaving, priority-preempting, and the disaggregated front-end
policy); this file is the single parametrized source of their shared
invariants, so a new variant cannot drift from the protocol without
failing here:

  * batch selection — occupied slots and compiled batches never exceed
    engine capacity, and the oldest queued request is never starved;
  * admission order — the engine's queue is FIFO under every scheduler;
  * ``phase()`` legality — answers come from the four-phase vocabulary
    for any (queued, active) state;
  * ``place()`` idempotence — re-placing an already-placed array is
    value-identical (and never errors);
  * ``quantize()`` / ``shapes()`` coherence — every quantized batch is
    within [1, capacity], covers the active count, and is pre-declared
    by ``shapes()`` so warmup can compile it.

Runs on any host; CI additionally runs it with a forced 2-device CPU so
the sharded scheduler's placement paths are real (the local mesh spans
``jax.device_count()`` devices).
"""

import jax
import numpy as np
import pytest

from engine_testlib import ToyEngine, ToyRequest
from repro.launch.mesh import make_mesh
from repro.serving import (DisaggScheduler, FIFOScheduler,
                           InterleavingScheduler, PriorityScheduler,
                           Scheduler, ShardedScheduler, SLOBatchScheduler)

CAPACITY = 4          # divisible by any plausible forced CPU device count

PHASES = {"mixed", "prefill", "decode", "handoff"}


def _sharded():
    n = jax.device_count()
    return ShardedScheduler(make_mesh((n,), ("data",)))


SCHEDULERS = {
    "base": Scheduler,
    "fifo": FIFOScheduler,
    "slo": lambda: SLOBatchScheduler(target_p95_ms=5.0, window=4,
                                     min_samples=2),
    "sharded": _sharded,
    "interleave": lambda: InterleavingScheduler(decode_ratio=1),
    "disagg": DisaggScheduler,
    # overlap mode answers "mixed" while handoffs are queued (async
    # transports drain them alongside decode ticks) — same vocabulary,
    # same conformance surface
    "disagg_overlap": lambda: DisaggScheduler(overlap=True),
    # uniform-priority traffic must degrade to plain FIFO (select ties
    # break first-come, preempt never fires), so every shared invariant
    # — including admission order — holds unchanged
    "priority": PriorityScheduler,
}


@pytest.fixture(params=sorted(SCHEDULERS))
def sched_name(request):
    return request.param


def make_engine(sched_name, capacity=CAPACITY):
    # schedulers are stateful and must not be shared between engines:
    # every engine gets a fresh instance from its factory
    return ToyEngine(capacity=capacity, scheduler=SCHEDULERS[sched_name]())


def make_bound(sched_name, capacity=CAPACITY):
    return make_engine(sched_name, capacity).scheduler


class TestBatchSelection:
    def test_capacity_never_exceeded(self, sched_name):
        eng = make_engine(sched_name)
        for i in range(6):
            eng.submit(ToyRequest(n_tasks=3, steps=2, rid=i))
        comps = eng.run_until_idle()
        assert eng.max_occupied <= eng.capacity
        assert eng.max_batch <= eng.capacity
        assert sorted(c.rid for c in comps) == list(range(6))

    def test_oldest_request_never_starved(self, sched_name):
        """Under a continuous trickle of newer work, the first-submitted
        request still completes within a bounded number of ticks."""
        eng = make_engine(sched_name, capacity=2)
        first = eng.submit(ToyRequest(steps=3))
        done = []
        for _ in range(40):
            eng.submit(ToyRequest(steps=1))
            eng.tick()
            done += [c.rid for c in eng.poll()]
            if first in done:
                break
        assert first in done, f"{sched_name}: oldest request starved"

    def test_admission_is_fifo(self, sched_name):
        eng = make_engine(sched_name)
        rids = [eng.submit(ToyRequest(steps=2)) for _ in range(8)]
        eng.run_until_idle()
        assert eng.admitted_order == rids

    def test_results_identical_across_schedulers(self, sched_name):
        """Scheduling policy changes *when* work runs, never the result."""
        def outcome(name):
            eng = make_engine(name)
            comps = eng.serve([ToyRequest(n_tasks=n, steps=s, rid=i)
                               for i, (n, s) in enumerate(
                                   [(2, 1), (1, 3), (3, 2), (0, 1)])])
            return sorted((c.rid, c.items) for c in comps)

        assert outcome(sched_name) == outcome("fifo")


class TestPhaseLegality:
    def test_phase_vocabulary(self, sched_name):
        sched = make_bound(sched_name)
        for q in range(5):
            for a in range(5):
                assert sched.phase(q, a) in PHASES

    def test_unknown_phases_coerced_by_engine(self):
        """A plain engine given the disaggregated policy must coerce
        "handoff" (it has no handoff stage) and keep serving."""
        eng = make_engine("disagg")
        comps = eng.serve([ToyRequest(steps=2) for _ in range(5)])
        assert len(comps) == 5


class TestPlacement:
    def test_place_preserves_values(self, sched_name):
        sched = make_bound(sched_name)
        x = np.arange(float(CAPACITY * 3), dtype=np.float32
                      ).reshape(CAPACITY, 3)
        np.testing.assert_array_equal(np.asarray(sched.place(x)), x)

    def test_place_idempotent_on_placed_arrays(self, sched_name):
        sched = make_bound(sched_name)
        x = np.arange(float(CAPACITY * 2), dtype=np.float32
                      ).reshape(CAPACITY, 2)
        p1 = sched.place(x)
        p2 = sched.place(p1)          # already placed: no error, same value
        np.testing.assert_array_equal(np.asarray(p2), np.asarray(p1))
        if hasattr(p1, "sharding"):   # and the same placement
            assert p2.sharding.is_equivalent_to(p1.sharding, p1.ndim)


class TestShapeCoherence:
    def test_quantize_bounds_and_shapes_cover(self, sched_name):
        sched = make_bound(sched_name, capacity=8)
        shapes = sched.shapes(8)
        assert all(1 <= b <= 8 for b in shapes)
        for n in range(1, 9):
            q = sched.quantize(n, 8)
            assert min(n, 8) <= q <= 8, (sched_name, n, q)
            assert q in shapes, (sched_name, n, q, shapes)

    def test_plan_positive(self, sched_name):
        sched = make_bound(sched_name)
        for q in range(5):
            for a in range(5):
                assert int(sched.plan(q, a)) >= 1
