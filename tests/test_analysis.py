"""Tests for repro.analysis (capslint) — the static-analysis gate itself.

Each rule gets three fixture flavors: a planted violation (asserting the
exact rule id, sub-code and ``file:line``), a suppressed variant, and a
clean variant.  On top of that: baseline round-trip (incl. stale-entry
detection), fingerprint stability under code motion, ``--changed-only``
filtering, and a subprocess meta-test that the committed repo itself is
clean under ``python -m repro.analysis --strict``.
"""

import json
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

from repro.analysis import (Baseline, Finding, Project, apply_suppressions,
                            default_registry, sort_findings)
from repro.analysis.__main__ import filter_changed
from repro.analysis.checkers.exceptions import ExceptionHygieneChecker
from repro.analysis.checkers.legality import KernelLegalityChecker
from repro.analysis.checkers.locks import LockDisciplineChecker
from repro.analysis.checkers.purity import JitPurityChecker

REPO = Path(__file__).resolve().parent.parent


def lint(tmp_path, source, checker, name="mod.py"):
    """Write ``source`` into a throwaway project, run one checker, and
    return (kept, suppressed) findings."""
    (tmp_path / name).write_text(textwrap.dedent(source))
    project = Project.load([tmp_path], root=tmp_path)
    findings = list(checker.run(project))
    return apply_suppressions(project, findings)


def locations(findings):
    return [(f.rule, f.code, f.path, f.line) for f in findings]


# ---------------------------------------------------------------------------
# lock-discipline
# ---------------------------------------------------------------------------

LOCKED_SRC = """\
import threading


class Engine:
    def __init__(self):
        self._lock = threading.Lock()
        self._queue = []                      # guarded-by: _lock

    def submit(self, item):
        self._queue.append(item)              # line 10: unguarded

    def submit_ok(self, item):
        with self._lock:
            self._queue.append(item)

    def _drain_locked(self):
        self._queue.clear()

    def nudge(self):
        self._drain_locked()                  # line 20: no lock held

    def nudge_ok(self):
        with self._lock:
            self._drain_locked()
"""


class TestLockDiscipline:
    def test_planted_violations_exact_location(self, tmp_path):
        kept, _ = lint(tmp_path, LOCKED_SRC, LockDisciplineChecker())
        assert ("lock-discipline", "unguarded-mutation", "mod.py", 10) \
            in locations(kept)
        assert ("lock-discipline", "locked-call-unlocked", "mod.py", 20) \
            in locations(kept)
        assert len(kept) == 2             # the _ok paths stay clean

    def test_suppression(self, tmp_path):
        src = LOCKED_SRC.replace(
            "# line 10: unguarded",
            "# capslint: disable=lock-discipline — test")
        kept, suppressed = lint(tmp_path, src, LockDisciplineChecker())
        assert [f.code for f in kept] == ["locked-call-unlocked"]
        assert [f.code for f in suppressed] == ["unguarded-mutation"]

    def test_clean_code_no_findings(self, tmp_path):
        src = """\
        import threading


        class Engine:
            def __init__(self):
                self._lock = threading.Lock()
                self._queue = []              # guarded-by: _lock

            def submit(self, item):
                with self._lock:
                    self._queue.append(item)
        """
        kept, _ = lint(tmp_path, src, LockDisciplineChecker())
        assert kept == []

    def test_transport_shaped_violation_exact_location(self, tmp_path):
        """Transport lock discipline is policed like any engine's: the
        closed flag and record ring are ``# guarded-by:`` annotated
        shared state, so a deliver() mutating them outside the lock is a
        planted error at an exact location — the shape the real
        ``repro.serving.transport`` base class must never regress to."""
        src = """\
        import threading


        class Transport:
            def __init__(self):
                self._lock = threading.Lock()
                self._closed = False              # guarded-by: _lock
                self._records = []                # guarded-by: _lock

            def deliver(self, handoff, target):
                if self._closed:
                    raise RuntimeError("closed")
                self._records.append(handoff)     # line 13: unguarded

            def deliver_ok(self, handoff, target):
                with self._lock:
                    self._records.append(handoff)

            def close(self):
                self._closed = True               # line 20: unguarded

            def close_ok(self):
                with self._lock:
                    self._closed = True
        """
        kept, _ = lint(tmp_path, src, LockDisciplineChecker())
        assert ("lock-discipline", "unguarded-mutation", "mod.py", 13) \
            in locations(kept)
        assert ("lock-discipline", "unguarded-mutation", "mod.py", 20) \
            in locations(kept)
        assert len([f for f in kept if f.code == "unguarded-mutation"]) == 2

    def test_page_pool_shaped_violation_exact_location(self, tmp_path):
        """The page pool's free list and prefix index are
        ``# guarded-by:`` annotated shared state (the shape of
        ``repro.serving.pages.PagePool``): an allocate() popping the
        free list or a hash registration writing the index outside the
        lock is a planted error at an exact location."""
        src = """\
        import threading


        class PagePool:
            def __init__(self):
                self._lock = threading.Lock()
                self._free = [2, 1, 0]            # guarded-by: _lock
                self._prefix_index = {}           # guarded-by: _lock

            def allocate(self):
                return self._free.pop()           # line 11: unguarded

            def register_hash(self, digest, page):
                self._prefix_index[digest] = page  # line 14: unguarded

            def allocate_ok(self):
                with self._lock:
                    return self._free.pop()

            def register_hash_ok(self, digest, page):
                with self._lock:
                    self._prefix_index[digest] = page
        """
        kept, _ = lint(tmp_path, src, LockDisciplineChecker())
        assert ("lock-discipline", "unguarded-mutation", "mod.py", 11) \
            in locations(kept)
        assert ("lock-discipline", "unguarded-mutation", "mod.py", 14) \
            in locations(kept)
        assert len(kept) == 2             # the _ok paths stay clean

    def test_unannotated_field_is_not_policed(self, tmp_path):
        src = """\
        class Engine:
            def __init__(self):
                self._scratch = []

            def submit(self, item):
                self._scratch.append(item)
        """
        kept, _ = lint(tmp_path, src, LockDisciplineChecker())
        assert kept == []


# ---------------------------------------------------------------------------
# jit-purity
# ---------------------------------------------------------------------------

PURITY_SRC = """\
import functools
import random
import time

import jax


@jax.jit
def bad_branch(x):
    if x > 0:                                 # line 10: tracer branch
        return x
    return -x


@jax.jit
def bad_cast(x):
    return int(x.sum())                       # line 17: tracer cast


def helper(x):
    return x + random.random()                # line 21: impure, reachable


@jax.jit
def calls_helper(x):
    return helper(x) * time.time()            # line 26: impure in root


@functools.partial(jax.jit, static_argnames=("flag",))
def static_branch_ok(x, flag):
    if flag:                                  # static arg: clean
        return x
    return -x


@jax.jit
def shape_branch_ok(x):
    if x.shape[0] > 1:                        # shape is trace-time: clean
        return x
    return -x
"""


class TestJitPurity:
    def test_planted_violations_exact_location(self, tmp_path):
        kept, _ = lint(tmp_path, PURITY_SRC, JitPurityChecker())
        locs = locations(kept)
        assert ("jit-purity", "tracer-branch", "mod.py", 10) in locs
        assert ("jit-purity", "tracer-cast", "mod.py", 17) in locs
        assert ("jit-purity", "impure-call", "mod.py", 21) in locs
        assert ("jit-purity", "impure-call", "mod.py", 26) in locs

    def test_static_and_shape_branches_clean(self, tmp_path):
        kept, _ = lint(tmp_path, PURITY_SRC, JitPurityChecker())
        lines = [f.line for f in kept]
        assert all(ln < 28 for ln in lines), \
            f"clean functions were flagged: {locations(kept)}"

    def test_mutable_closure(self, tmp_path):
        src = """\
        import threading

        import jax


        class Engine:
            def __init__(self):
                self._lock = threading.Lock()
                self._stats = {}              # guarded-by: _lock

            @jax.jit
            def tick(self, x):
                return x + len(self._stats)   # line 13: stale closure
        """
        kept, _ = lint(tmp_path, src, JitPurityChecker())
        assert ("jit-purity", "mutable-closure", "mod.py", 13) \
            in locations(kept)

    def test_suppression(self, tmp_path):
        src = PURITY_SRC.replace("# line 10: tracer branch",
                                 "# capslint: disable=jit-purity")
        kept, suppressed = lint(tmp_path, src, JitPurityChecker())
        assert "tracer-branch" not in [f.code for f in kept]
        assert "tracer-branch" in [f.code for f in suppressed]

    def test_unjitted_code_not_policed(self, tmp_path):
        src = """\
        import time


        def eager(x):
            if x > 0:
                return time.time()
            return int(x)
        """
        kept, _ = lint(tmp_path, src, JitPurityChecker())
        assert kept == []


# ---------------------------------------------------------------------------
# exception-hygiene
# ---------------------------------------------------------------------------

EXC_SRC = """\
def swallow():
    try:
        return 1
    except Exception:                         # line 4: silent swallow
        return None


def reraise_ok():
    try:
        return 1
    except Exception:
        raise


def logged_ok(log):
    try:
        return 1
    except Exception as e:
        log.warning("failed: %s", e)
        return None


def narrow_ok():
    try:
        return 1
    except ValueError:
        return None
"""


class TestExceptionHygiene:
    def test_planted_violation_exact_location(self, tmp_path):
        kept, _ = lint(tmp_path, EXC_SRC, ExceptionHygieneChecker())
        assert locations(kept) == [
            ("exception-hygiene", "silent-swallow", "mod.py", 4)]

    def test_suppression_is_the_justification(self, tmp_path):
        src = EXC_SRC.replace(
            "# line 4: silent swallow",
            "# capslint: disable=exception-hygiene — probe")
        kept, suppressed = lint(tmp_path, src, ExceptionHygieneChecker())
        assert kept == []
        assert len(suppressed) == 1


# ---------------------------------------------------------------------------
# kernel-legality
# ---------------------------------------------------------------------------

BAD_KERNEL_SRC = """\
import numpy as np

from repro.kernels.registry import (KernelRegistry, KernelSpec,
                                    _legalize_blocks)
from repro.kernels.tuning import largest_divisor


def block_dims(x, **kwargs):
    return {"blk": x.shape[0]}


def raw_legalize(config, x, **kwargs):
    return config                   # no clamping: blk=8 vs dim 12


def make_example(case):
    return (np.zeros(case["shape"], np.float32),), {}


def build_registry(legalize, dims=block_dims):
    reg = KernelRegistry()
    reg.register(KernelSpec(
        name="badkernel",
        build=lambda: None,
        reference=lambda: None,
        space={"blk": (8, 64)},
        tuned=("blk",),
        base_config={"blk": 8},
        legalize=legalize,
        make_example=make_example,
        example_cases=({"shape": (12, 4)},),
        block_dims=dims,
    ))
    return reg
"""


@pytest.fixture
def bad_kernel_mod(tmp_path):
    """The fixture registry lives in a compiled temp module so the
    checker's ``__code__``-derived file:line points inside tmp_path."""
    path = tmp_path / "badkernels.py"
    path.write_text(BAD_KERNEL_SRC)
    ns = {}
    exec(compile(BAD_KERNEL_SRC, str(path), "exec"), ns)
    return ns, path


class TestKernelLegality:
    def test_non_divisor_exact_location(self, tmp_path, bad_kernel_mod):
        ns, path = bad_kernel_mod
        reg = ns["build_registry"](ns["raw_legalize"])
        project = Project.load([tmp_path], root=tmp_path)
        kept = list(KernelLegalityChecker(reg).run(project))
        hits = [f for f in kept if f.code == "non-divisor"]
        assert hits, f"expected non-divisor, got {locations(kept)}"
        f = hits[0]
        assert f.rule == "kernel-legality"
        assert f.symbol == "badkernel"
        # location = the block_dims def in the fixture module (line 8)
        assert f.path == "badkernels.py"
        assert f.line == ns["block_dims"].__code__.co_firstlineno

    def test_derived_legalize_is_legal(self, tmp_path, bad_kernel_mod):
        ns, _ = bad_kernel_mod
        reg = ns["build_registry"](
            ns["_legalize_blocks"](ns["block_dims"]))
        project = Project.load([tmp_path], root=tmp_path)
        kept = list(KernelLegalityChecker(reg).run(project))
        assert [f for f in kept if f.severity == "error"] == []

    def test_unstable_legalize(self, tmp_path, bad_kernel_mod):
        ns, _ = bad_kernel_mod

        def drifting(config, x, **kwargs):
            config["blk"] = max(1, config["blk"] // 2)   # shrinks again
            return config

        reg = ns["build_registry"](drifting)
        project = Project.load([tmp_path], root=tmp_path)
        codes = {f.code for f in KernelLegalityChecker(reg).run(project)}
        assert "unstable-legalize" in codes

    def test_missing_block_dims_is_warning(self, tmp_path, bad_kernel_mod):
        ns, _ = bad_kernel_mod
        reg = ns["build_registry"](ns["raw_legalize"], dims=None)
        project = Project.load([tmp_path], root=tmp_path)
        kept = list(KernelLegalityChecker(reg).run(project))
        assert [(f.code, f.severity) for f in kept] == [
            ("unverifiable", "warning")]

    def test_divisor_violation(self, tmp_path, bad_kernel_mod):
        """A spec declaring ``block_divisors`` pairs (e.g. the paged
        dequant kernel's page_size | kv_block) but legalizing the two
        knobs independently is flagged; deriving legalize with the same
        ``divisors=`` is clean."""
        ns, _ = bad_kernel_mod
        KernelRegistry = ns["KernelRegistry"]
        KernelSpec = ns["KernelSpec"]

        def build(legalize):
            reg = KernelRegistry()
            reg.register(KernelSpec(
                name="pagedkernel",
                build=lambda: None,
                reference=lambda: None,
                space={"page": (8, 12), "blk": (4, 8, 64)},
                tuned=("page", "blk"),
                base_config={"page": 8, "blk": 64},
                legalize=legalize,
                make_example=ns["make_example"],
                example_cases=({"shape": (96, 4)},),
                block_dims=lambda x, **kw: {"blk": x.shape[0]},
                block_divisors=(("page", "blk"),),
            ))
            return reg

        # blk legalized alone: page=12 with blk=8 never re-aligned
        reg = build(ns["_legalize_blocks"](
            lambda x, **kw: {"blk": x.shape[0]}))
        project = Project.load([tmp_path], root=tmp_path)
        kept = list(KernelLegalityChecker(reg).run(project))
        hits = [f for f in kept if f.code == "divisor-violation"]
        assert hits, f"expected divisor-violation, got {locations(kept)}"
        assert hits[0].symbol == "pagedkernel"

        reg = build(ns["_legalize_blocks"](
            lambda x, **kw: {"blk": x.shape[0]},
            divisors=(("page", "blk"),)))
        kept = list(KernelLegalityChecker(reg).run(project))
        assert [f for f in kept if f.severity == "error"] == [], \
            [f.render() for f in kept]

    def test_real_registry_is_clean(self, tmp_path):
        """The shipped kernel registry must satisfy its own invariant."""
        project = Project.load([tmp_path], root=tmp_path)
        kept = list(KernelLegalityChecker().run(project))
        assert [f for f in kept if f.severity == "error"] == [], \
            [f.render() for f in kept]


# ---------------------------------------------------------------------------
# kernel-legality: decode-path specs (decode_attention / fused_sampling)
# ---------------------------------------------------------------------------

DECODE_KERNEL_SRC = """\
import numpy as np

from repro.kernels.registry import (KernelRegistry, KernelSpec,
                                    _legalize_blocks)


def decode_block_dims(q, k=None, v=None, kv_valid_len=None, **kwargs):
    t = k.shape[1] if k is not None else q.shape[1]
    return {"kv_block": t, "slot_block": q.shape[0]}


def sampling_block_dims(logits, *args, **kwargs):
    return {"batch_block": logits.shape[0]}


def raw_legalize(config, *args, **kwargs):
    return config                   # no clamping at all


def make_decode_example(case):
    # every axis value distinct: the checker's bucket scaling replaces
    # EVERY axis equal to a block dim's value, so a batch/heads collision
    # would blow an unblocked axis up to serving size
    b, t = case["dims"]
    q = np.zeros((b, 1, 6, 7), np.float32)
    k = np.zeros((b, t, 3, 7), np.float32)
    v = np.zeros((b, t, 3, 7), np.float32)
    valid = np.full((b,), t, np.int32)
    return (q, k, v, valid), {}


def make_sampling_example(case):
    b, vocab = case["dims"]
    return (np.zeros((b, vocab), np.float32),), {}


def build_registry(decode_legalize, sampling_legalize):
    reg = KernelRegistry()
    reg.register(KernelSpec(
        name="decode_attention_planted",
        build=lambda: None,
        reference=lambda: None,
        space={"kv_block": (8, 64, 512), "slot_block": (1, 8),
               "page_size": (8, 16)},
        tuned=("kv_block", "slot_block"),
        base_config={"kv_block": 512, "slot_block": 1, "page_size": 16},
        legalize=decode_legalize,
        make_example=make_decode_example,
        example_cases=({"dims": (5, 24)},),
        block_dims=decode_block_dims,
        block_divisors=(("page_size", "kv_block"),),
    ))
    reg.register(KernelSpec(
        name="fused_sampling_planted",
        build=lambda: None,
        reference=lambda: None,
        space={"batch_block": (8, 64)},
        tuned=("batch_block",),
        base_config={"batch_block": 8},
        legalize=sampling_legalize,
        make_example=make_sampling_example,
        example_cases=({"dims": (12, 5)},),
        block_dims=sampling_block_dims,
    ))
    return reg
"""


@pytest.fixture
def decode_kernel_mod(tmp_path):
    """Decode-shaped planted specs (the ``decode_attention`` /
    ``fused_sampling`` geometry in miniature) in a compiled temp module,
    so checker locations point inside tmp_path."""
    path = tmp_path / "decodekernels.py"
    path.write_text(DECODE_KERNEL_SRC)
    ns = {}
    exec(compile(DECODE_KERNEL_SRC, str(path), "exec"), ns)
    return ns, path


class TestDecodeSpecLegality:
    """The two decode-path specs must stay inside the legality gate: a
    candidate the legalizer does not clamp to the example's ragged
    dims, or a ``page_size | kv_block`` divisor pair the two knobs
    break when legalized independently, is an error."""

    def test_planted_illegal_candidates_flagged(self, tmp_path,
                                                decode_kernel_mod):
        ns, _ = decode_kernel_mod
        reg = ns["build_registry"](ns["raw_legalize"], ns["raw_legalize"])
        project = Project.load([tmp_path], root=tmp_path)
        kept = list(KernelLegalityChecker(reg).run(project))
        bad = {f.symbol for f in kept if f.code == "non-divisor"}
        # kv_block=512 vs T=24, and batch_block=8 vs B=12
        assert bad == {"decode_attention_planted",
                       "fused_sampling_planted"}, locations(kept)

    def test_divisor_pair_enforced(self, tmp_path, decode_kernel_mod):
        """kv_block clamped without the page_size pairing: page_size=16
        never divides the clamped kv_block, exactly the bug
        ``block_divisors`` exists to catch on the real spec."""
        ns, _ = decode_kernel_mod
        reg = ns["build_registry"](
            ns["_legalize_blocks"](ns["decode_block_dims"]),
            ns["_legalize_blocks"](ns["sampling_block_dims"]))
        project = Project.load([tmp_path], root=tmp_path)
        kept = list(KernelLegalityChecker(reg).run(project))
        hits = [f for f in kept if f.code == "divisor-violation"]
        assert {f.symbol for f in hits} == {"decode_attention_planted"}, \
            locations(kept)

    def test_paired_legalize_is_clean(self, tmp_path, decode_kernel_mod):
        ns, _ = decode_kernel_mod
        reg = ns["build_registry"](
            ns["_legalize_blocks"](ns["decode_block_dims"],
                                   divisors=(("page_size", "kv_block"),)),
            ns["_legalize_blocks"](ns["sampling_block_dims"]))
        project = Project.load([tmp_path], root=tmp_path)
        kept = list(KernelLegalityChecker(reg).run(project))
        assert [f for f in kept if f.severity == "error"] == [], \
            [f.render() for f in kept]

    def test_shipped_decode_specs_declare_divisor_pair(self):
        """The real registry's decode_attention spec carries the
        page_size | kv_block pairing (fused_sampling has no paged
        geometry and must not)."""
        from repro.kernels.registry import registry as real

        assert (("page_size", "kv_block")
                in tuple(real.get("decode_attention").block_divisors))
        assert not real.get("fused_sampling").block_divisors


# ---------------------------------------------------------------------------
# findings / suppressions / baseline plumbing
# ---------------------------------------------------------------------------

def mk(rule="lock-discipline", code="unguarded-mutation", path="a.py",
       line=10, message="field `_q` mutated", symbol="Engine.submit",
       severity="error"):
    return Finding(rule=rule, code=code, path=path, line=line,
                   message=message, symbol=symbol, severity=severity)


class TestFindings:
    def test_fingerprint_ignores_line(self):
        assert mk(line=10).fingerprint() == mk(line=99).fingerprint()
        assert mk().fingerprint() != mk(code="locked-call-unlocked"
                                        ).fingerprint()

    def test_sort_severity_then_location(self):
        fs = [mk(path="b.py", severity="warning"), mk(path="b.py"),
              mk(path="a.py", line=20), mk(path="a.py", line=5)]
        ordered = sort_findings(fs)
        assert [(f.severity, f.path, f.line) for f in ordered] == [
            ("error", "a.py", 5), ("error", "a.py", 20),
            ("error", "b.py", 10), ("warning", "b.py", 10)]

    def test_rule_dot_code_and_all_suppressions(self, tmp_path):
        src = """\
        def swallow():
            try:
                return 1
            # capslint: disable=exception-hygiene.silent-swallow
            except Exception:
                return None


        def swallow2():
            try:
                return 1
            except Exception:                 # capslint: disable=all
                return None
        """
        kept, suppressed = lint(tmp_path, src, ExceptionHygieneChecker())
        assert kept == []
        assert len(suppressed) == 2


class TestBaseline:
    def test_round_trip(self, tmp_path):
        path = tmp_path / "baseline.json"
        Baseline.load(path).save(path, [mk(), mk(path="b.py", line=3)])
        new, accepted, stale = Baseline.load(path).split(
            [mk(line=42), mk(path="b.py", line=7), mk(path="c.py")])
        assert [f.path for f in accepted] == ["a.py", "b.py"]
        assert [f.path for f in new] == ["c.py"]
        assert stale == []

    def test_stale_entries_reported(self, tmp_path):
        path = tmp_path / "baseline.json"
        Baseline.load(path).save(path, [mk(), mk(path="gone.py")])
        new, accepted, stale = Baseline.load(path).split([mk()])
        assert new == [] and len(accepted) == 1
        assert [e["path"] for e in stale] == ["gone.py"]

    def test_missing_file_is_empty(self, tmp_path):
        b = Baseline.load(tmp_path / "nope.json")
        assert b.entries == {}

    def test_version_mismatch_raises(self, tmp_path):
        path = tmp_path / "baseline.json"
        path.write_text(json.dumps({"version": 99, "findings": []}))
        with pytest.raises(ValueError, match="version"):
            Baseline.load(path)


class TestChangedOnly:
    def test_filter_changed(self):
        fs = [mk(path="a.py"), mk(path="b.py"), mk(path="c/d.py")]
        assert [f.path for f in filter_changed(fs, ["b.py", "c/d.py"])] \
            == ["b.py", "c/d.py"]
        assert filter_changed(fs, []) == []


# ---------------------------------------------------------------------------
# the registry protocol + the gate on the real repo
# ---------------------------------------------------------------------------

class TestRegistry:
    def test_default_registry_names(self):
        assert default_registry().names() == [
            "exception-hygiene", "jit-purity", "kernel-legality",
            "lock-discipline"]

    def test_unknown_checker_raises(self):
        with pytest.raises(ValueError, match="unknown checker"):
            default_registry().get("nope")

    def test_select_subset(self, tmp_path):
        (tmp_path / "m.py").write_text("x = 1\n")
        project = Project.load([tmp_path], root=tmp_path)
        out = default_registry().run(project,
                                     select=["exception-hygiene"])
        assert out == []


class TestRepoGate:
    """`python -m repro.analysis --strict` must pass on the committed repo
    (modulo the committed baseline) — the CI lane in test form."""

    def test_strict_json_clean(self, tmp_path):
        out = tmp_path / "findings.json"
        proc = subprocess.run(
            [sys.executable, "-m", "repro.analysis", "--strict",
             "--json", str(out)],
            cwd=REPO, capture_output=True, text=True,
            env={**__import__("os").environ,
                 "PYTHONPATH": str(REPO / "src")})
        assert proc.returncode == 0, proc.stdout + proc.stderr
        payload = json.loads(out.read_text())
        assert payload["ok"] is True
        assert payload["counts"]["errors"] == 0
        assert payload["counts"]["modules"] > 50

    def test_list_catalogue(self):
        proc = subprocess.run(
            [sys.executable, "-m", "repro.analysis", "--list"],
            cwd=REPO, capture_output=True, text=True,
            env={**__import__("os").environ,
                 "PYTHONPATH": str(REPO / "src")})
        assert proc.returncode == 0
        for rule in ("lock-discipline", "jit-purity", "kernel-legality",
                     "exception-hygiene"):
            assert rule in proc.stdout
