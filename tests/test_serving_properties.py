"""Property-based serving-surface tests (hypothesis, skip-guarded).

Random interleavings of ``submit() / tick() / poll()`` — shrinkable op
sequences instead of the hand-picked scenarios of ``test_serving_api.py``
— must preserve the engine contracts:

  * per-request ``StreamEvent`` ordering (``seq`` = 0..k, done last and
    exactly once, only for requests that opted in);
  * ``EngineStats`` monotonicity after *every* op (counters, latency
    histogram buckets, per-phase depth histograms);
  * completion exactness: every submitted request completes exactly
    once after a full drain, with the right task count.

The disaggregated section drives the workload-free toy pair
(``ToyPrefillEngine -> FlakyTransport -> ToyDecodeEngine``) through the
same random op sequences with random *transport* delay/failure
injection: delivery interleavings may park handoffs anywhere between
the engines, routes may die mid-transfer, and the StreamEvent ordering
+ EngineStats monotonicity contracts (now including the per-transport
per-leg ``transfer`` histograms) must hold regardless — with every
handoff's rows arriving bit-exact (the toy decode engine verifies them
against the handoff identity on admission).

The invariant harness (``run_ops``) is plain code shared with
deterministic regression cases, so the contract stays exercised even
where hypothesis is absent (tier-1 CI intentionally omits it and these
cases must *skip*, via ``hypothesis_compat``); the dedicated
serving-conformance CI job installs hypothesis and runs the randomized
sequences on a forced 2-device host.
"""

from engine_testlib import (FlakyTransport, ToyDecodeEngine,
                            ToyEngine, ToyPrefillEngine, ToyRequest)
from hypothesis_compat import given, settings, st
from repro.serving import DisaggregatedEngine


def assert_monotone(prev, cur):
    """Every EngineStats quantity may only grow between snapshots."""
    assert cur.items >= prev.items
    assert cur.padded >= prev.padded
    assert cur.ticks >= prev.ticks
    assert cur.wall_s >= prev.wall_s
    assert cur.completed >= prev.completed
    for cls, h1 in prev.latency.items():
        h2 = cur.latency[cls]
        assert h2.count >= h1.count
        assert all(b >= a for a, b in zip(h1.counts, h2.counts))
    for phase, h1 in prev.depth.items():
        h2 = cur.depth[phase]
        assert h2.count >= h1.count
        assert h2.peak >= h1.peak
        assert all(b >= a for a, b in zip(h1.counts, h2.counts))
    for stage, h1 in prev.transfer.items():
        h2 = cur.transfer[stage]
        assert h2.count >= h1.count
        assert all(b >= a for a, b in zip(h1.counts, h2.counts))


def run_ops(ops):
    """Drive a ToyEngine through one op sequence, checking stats
    monotonicity at every step and the stream/completion contracts after
    a full drain.  Returns the engine for extra assertions."""
    eng = ToyEngine(capacity=3)
    completions = []
    events = []
    expected = {}                     # rid -> (n_tasks, streamed?)
    prev = eng.stats()
    for op in ops:
        if op[0] == "submit":
            _, n_tasks, steps, stream = op
            rid = eng.submit(ToyRequest(n_tasks=n_tasks, steps=steps,
                                        stream=stream))
            expected[rid] = (n_tasks, stream)
        elif op[0] == "tick":
            eng.tick()
        elif op[0] == "poll":
            completions += eng.poll()
        elif op[0] == "stream":
            events += eng.poll(stream=True)
        cur = eng.stats()
        assert_monotone(prev, cur)
        prev = cur

    completions += eng.run_until_idle()
    events += eng.poll(stream=True)
    completions += eng.poll()
    assert eng.n_pending == 0

    # completion contract: everyone completes exactly once, task-exact
    assert sorted(c.rid for c in completions) == sorted(expected)
    for c in completions:
        assert c.items == expected[c.rid][0]
    assert eng.stats().completed == len(expected)

    # stream contract: ordered per rid, one done event last, opt-in only
    per_rid = {}
    for ev in events:
        per_rid.setdefault(ev.rid, []).append(ev)
    for rid, evs in per_rid.items():
        assert expected[rid][1], f"rid {rid} streamed without opting in"
        assert [e.seq for e in evs] == list(range(len(evs)))
        assert [e.done for e in evs] == [False] * (len(evs) - 1) + [True]
        assert evs[-1].completion.rid == rid
    for rid, (n_tasks, stream) in expected.items():
        if stream:
            assert rid in per_rid, f"streaming rid {rid} emitted nothing"
    return eng


def run_disagg_ops(ops, delays=(), fail_on=()):
    """Drive a toy disaggregated pair (prefill -> FlakyTransport ->
    decode pool) through one op sequence, checking stats monotonicity
    (including the per-transport per-leg transfer histograms) at every
    step and the stream/completion contracts after a full drain.

    ``delays`` are synthetic per-delivery leg seconds (recorded into the
    histograms, never slept); ``fail_on`` are delivery-attempt indices
    that die mid-transfer — each triggered failure kills one route, so
    the pool is sized ``len(fail_on) + 1`` and a surviving route always
    exists (the never-dropped invariant is asserted, not assumed)."""
    fail_on = set(fail_on)
    transport = FlakyTransport(delays=delays, fail_on=fail_on)
    eng = DisaggregatedEngine(
        ToyPrefillEngine(capacity=2),
        [ToyDecodeEngine(capacity=2) for _ in range(len(fail_on) + 1)],
        transport=transport)
    completions = []
    events = []
    expected = {}                     # rid -> (n_tasks, streamed?)
    prev = eng.stats()
    for op in ops:
        if op[0] == "submit":
            _, n_tasks, steps, stream = op
            rid = eng.submit(ToyRequest(n_tasks=n_tasks, steps=steps,
                                        stream=stream))
            expected[rid] = (min(n_tasks, 1), stream)   # handoffs are
            #                                             per-request
        elif op[0] == "tick":
            eng.tick()
        elif op[0] == "poll":
            completions += eng.poll()
        elif op[0] == "stream":
            events += eng.poll(stream=True)
        cur = eng.stats()
        assert_monotone(prev, cur)
        prev = cur

    completions += eng.run_until_idle()
    events += eng.poll(stream=True)
    completions += eng.poll()
    assert eng.n_pending == 0

    # completion contract: everyone completes exactly once — requeues
    # and dead routes may reorder delivery but never drop or duplicate
    assert sorted(c.rid for c in completions) == sorted(expected)
    for c in completions:
        assert c.items == expected[c.rid][0]
    st_ = eng.stats()
    assert st_.completed == len(expected)

    # transfer contract: one handoff queue-wait and one per-leg record
    # per *successful* delivery; each triggered failure killed exactly
    # one route and cost exactly one extra delivery attempt
    n_handoffs = sum(1 for n, _ in expected.values() if n >= 1)
    if n_handoffs:
        assert st_.transfer["handoff"].count == n_handoffs
        assert st_.transfer["flaky/pass"].count == n_handoffs
        assert st_.transfer["flaky/total"].count == n_handoffs
    n_failed = sum(1 for i in fail_on if i < transport.calls)
    assert transport.calls == n_handoffs + n_failed
    assert len(eng._dead) == n_failed

    # stream contract: ordered per rid across the handoff boundary,
    # one done event last, opt-in only
    per_rid = {}
    for ev in events:
        per_rid.setdefault(ev.rid, []).append(ev)
    for rid, evs in per_rid.items():
        assert expected[rid][1], f"rid {rid} streamed without opting in"
        assert [e.seq for e in evs] == list(range(len(evs)))
        assert [e.done for e in evs] == [False] * (len(evs) - 1) + [True]
        assert evs[-1].completion.rid == rid
    for rid, (n_tasks, stream) in expected.items():
        if stream and n_tasks >= 1:   # zero-task requests finish at
            #                           prefill: no decode, no stream
            assert rid in per_rid, f"streaming rid {rid} emitted nothing"
    return eng


OPS = st.one_of(
    st.tuples(st.just("submit"), st.integers(min_value=0, max_value=4),
              st.integers(min_value=1, max_value=3), st.booleans()),
    st.tuples(st.just("tick")),
    st.tuples(st.just("poll")),
    st.tuples(st.just("stream")),
)

DISAGG_OPS = st.one_of(
    st.tuples(st.just("submit"), st.integers(min_value=0, max_value=1),
              st.integers(min_value=1, max_value=3), st.booleans()),
    st.tuples(st.just("tick")),
    st.tuples(st.just("poll")),
    st.tuples(st.just("stream")),
)


@settings(max_examples=50, deadline=None)
@given(st.lists(OPS, max_size=40))
def test_random_op_sequences_hold_invariants(ops):
    run_ops(list(ops))


@settings(max_examples=30, deadline=None)
@given(st.lists(st.tuples(st.integers(min_value=0, max_value=3),
                          st.integers(min_value=1, max_value=4),
                          st.booleans()),
                min_size=1, max_size=12))
def test_burst_submit_then_drain(reqs):
    """All-at-once admission pressure: a pure submit burst then drain."""
    ops = [("submit", n, s, stream) for n, s, stream in reqs]
    run_ops(ops)


@settings(max_examples=40, deadline=None)
@given(st.lists(DISAGG_OPS, max_size=30),
       st.lists(st.floats(min_value=0.0, max_value=0.25, allow_nan=False,
                          allow_infinity=False),
                max_size=5),
       st.sets(st.integers(min_value=0, max_value=20), max_size=3))
def test_random_disagg_sequences_with_flaky_transport(ops, delays, fail_on):
    """Random op sequences x random transport delay/failure injection:
    handoffs may be parked, delayed arbitrarily, or lose their route
    mid-transfer at any delivery interleaving — ordering, monotonicity
    (incl. per-leg transfer histograms), and delivery exactness hold."""
    run_disagg_ops(list(ops), delays=delays, fail_on=fail_on)


def test_deterministic_sequences_smoke():
    """The same invariant harness on fixed sequences, so the contract is
    exercised even where hypothesis is absent."""
    run_ops([("submit", 2, 2, True), ("tick",), ("submit", 0, 1, False),
             ("stream",), ("tick",), ("poll",), ("submit", 4, 1, True),
             ("tick",), ("tick",), ("stream",)])
    run_ops([("tick",), ("poll",), ("stream",)])
    run_ops([("submit", 1, 3, True), ("submit", 3, 1, False), ("tick",)])


def test_deterministic_disagg_sequences_smoke():
    """Fixed disagg sequences through the same harness: a clean run, a
    first-delivery transport failure, a mid-run failure with synthetic
    delays, and an empty-engine drain — exercised even without
    hypothesis."""
    run_disagg_ops([("submit", 1, 2, True), ("tick",), ("tick",),
                    ("stream",), ("submit", 1, 1, False), ("tick",),
                    ("poll",), ("submit", 0, 1, True), ("tick",)])
    run_disagg_ops([("submit", 1, 2, False), ("tick",), ("tick",)],
                   fail_on={0})
    run_disagg_ops([("submit", 1, 1, True), ("submit", 1, 3, True),
                    ("tick",), ("submit", 1, 2, False), ("tick",),
                    ("stream",), ("tick",)],
                   delays=[0.01, 0.2], fail_on={1, 3})
    run_disagg_ops([("tick",), ("poll",), ("stream",)])
