"""Property-based serving-surface tests (hypothesis, skip-guarded).

Random interleavings of ``submit() / tick() / poll()`` — shrinkable op
sequences instead of the hand-picked scenarios of ``test_serving_api.py``
— must preserve the engine contracts:

  * per-request ``StreamEvent`` ordering (``seq`` = 0..k, done last and
    exactly once, only for requests that opted in);
  * ``EngineStats`` monotonicity after *every* op (counters, latency
    histogram buckets, per-phase depth histograms);
  * completion exactness: every submitted request completes exactly
    once after a full drain, with the right task count.

The invariant harness (``run_ops``) is plain code shared with
deterministic regression cases, so the contract stays exercised even
where hypothesis is absent (tier-1 CI intentionally omits it and these
cases must *skip*, via ``hypothesis_compat``); the dedicated
serving-conformance CI job installs hypothesis and runs the randomized
sequences on a forced 2-device host.
"""

from engine_testlib import ToyEngine, ToyRequest
from hypothesis_compat import given, settings, st


def assert_monotone(prev, cur):
    """Every EngineStats quantity may only grow between snapshots."""
    assert cur.items >= prev.items
    assert cur.padded >= prev.padded
    assert cur.ticks >= prev.ticks
    assert cur.wall_s >= prev.wall_s
    assert cur.completed >= prev.completed
    for cls, h1 in prev.latency.items():
        h2 = cur.latency[cls]
        assert h2.count >= h1.count
        assert all(b >= a for a, b in zip(h1.counts, h2.counts))
    for phase, h1 in prev.depth.items():
        h2 = cur.depth[phase]
        assert h2.count >= h1.count
        assert h2.peak >= h1.peak
        assert all(b >= a for a, b in zip(h1.counts, h2.counts))


def run_ops(ops):
    """Drive a ToyEngine through one op sequence, checking stats
    monotonicity at every step and the stream/completion contracts after
    a full drain.  Returns the engine for extra assertions."""
    eng = ToyEngine(capacity=3)
    completions = []
    events = []
    expected = {}                     # rid -> (n_tasks, streamed?)
    prev = eng.stats()
    for op in ops:
        if op[0] == "submit":
            _, n_tasks, steps, stream = op
            rid = eng.submit(ToyRequest(n_tasks=n_tasks, steps=steps,
                                        stream=stream))
            expected[rid] = (n_tasks, stream)
        elif op[0] == "tick":
            eng.tick()
        elif op[0] == "poll":
            completions += eng.poll()
        elif op[0] == "stream":
            events += eng.poll(stream=True)
        cur = eng.stats()
        assert_monotone(prev, cur)
        prev = cur

    completions += eng.run_until_idle()
    events += eng.poll(stream=True)
    completions += eng.poll()
    assert eng.n_pending == 0

    # completion contract: everyone completes exactly once, task-exact
    assert sorted(c.rid for c in completions) == sorted(expected)
    for c in completions:
        assert c.items == expected[c.rid][0]
    assert eng.stats().completed == len(expected)

    # stream contract: ordered per rid, one done event last, opt-in only
    per_rid = {}
    for ev in events:
        per_rid.setdefault(ev.rid, []).append(ev)
    for rid, evs in per_rid.items():
        assert expected[rid][1], f"rid {rid} streamed without opting in"
        assert [e.seq for e in evs] == list(range(len(evs)))
        assert [e.done for e in evs] == [False] * (len(evs) - 1) + [True]
        assert evs[-1].completion.rid == rid
    for rid, (n_tasks, stream) in expected.items():
        if stream:
            assert rid in per_rid, f"streaming rid {rid} emitted nothing"
    return eng


OPS = st.one_of(
    st.tuples(st.just("submit"), st.integers(min_value=0, max_value=4),
              st.integers(min_value=1, max_value=3), st.booleans()),
    st.tuples(st.just("tick")),
    st.tuples(st.just("poll")),
    st.tuples(st.just("stream")),
)


@settings(max_examples=50, deadline=None)
@given(st.lists(OPS, max_size=40))
def test_random_op_sequences_hold_invariants(ops):
    run_ops(list(ops))


@settings(max_examples=30, deadline=None)
@given(st.lists(st.tuples(st.integers(min_value=0, max_value=3),
                          st.integers(min_value=1, max_value=4),
                          st.booleans()),
                min_size=1, max_size=12))
def test_burst_submit_then_drain(reqs):
    """All-at-once admission pressure: a pure submit burst then drain."""
    ops = [("submit", n, s, stream) for n, s, stream in reqs]
    run_ops(ops)


def test_deterministic_sequences_smoke():
    """The same invariant harness on fixed sequences, so the contract is
    exercised even where hypothesis is absent."""
    run_ops([("submit", 2, 2, True), ("tick",), ("submit", 0, 1, False),
             ("stream",), ("tick",), ("poll",), ("submit", 4, 1, True),
             ("tick",), ("tick",), ("stream",)])
    run_ops([("tick",), ("poll",), ("stream",)])
    run_ops([("submit", 1, 3, True), ("submit", 3, 1, False), ("tick",)])
