"""repro.deploy API: registry resolution/fallback, FastCapsPipeline
equivalence with the core free functions, CapsuleEngine batching."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import capsnet as cn
from repro.core import routing as routing_lib
from repro.deploy import (DeployedCapsNet, FastCapsPipeline, PipelineError,
                          RoutingSpec, normalize, registry, resolve)
from repro.serving import CapsuleEngine, ImageRequest


def tiny_cfg(**kw):
    base = dict(conv1_channels=16, caps_types=4, decoder_hidden=(32, 64))
    base.update(kw)
    return cn.CapsNetConfig(**base)


def u_hat(seed, b=2, i=24, j=10, d=16, scale=0.2):
    return jax.random.normal(jax.random.key(seed), (b, i, j, d)) * scale


class TestRegistry:
    def test_variants_registered(self):
        assert {"reference", "optimized", "pallas"} <= set(registry.names())

    def test_unknown_mode_raises(self):
        with pytest.raises(ValueError, match="unknown routing mode"):
            resolve(RoutingSpec(mode="does-not-exist"))

    def test_unknown_named_spec_raises(self):
        with pytest.raises(ValueError, match="unknown routing variant"):
            RoutingSpec.named("hls")

    def test_bad_softmax_rejected(self):
        with pytest.raises(ValueError, match="softmax"):
            RoutingSpec(mode="optimized", softmax="newton")

    def test_pallas_interpret_probed_from_backend(self):
        """Off-TPU (tests force CPU) pallas must fall back to interpret
        mode — chosen by the registry probe, not hardcoded."""
        spec = normalize(RoutingSpec.pallas())
        assert spec.mode == "pallas"
        assert spec.interpret is (jax.default_backend() != "tpu")

    def test_pallas_interpret_pin_respected(self):
        spec = normalize(RoutingSpec.pallas(interpret=True))
        assert spec.interpret is True

    def test_unavailable_variant_falls_back(self):
        from repro.deploy.registry import RoutingRegistry, RoutingVariant

        reg = RoutingRegistry()
        reg.register(RoutingVariant("opt", lambda s: routing_lib.route_optimized))
        reg.register(RoutingVariant("fancy", lambda s: None,
                                    is_available=lambda: False,
                                    fallback="opt"))
        assert reg.normalize(RoutingSpec(mode="fancy")).mode == "opt"

    def test_unavailable_without_fallback_raises(self):
        from repro.deploy.registry import RoutingRegistry, RoutingVariant

        reg = RoutingRegistry()
        reg.register(RoutingVariant("fancy", lambda s: None,
                                    is_available=lambda: False))
        with pytest.raises(RuntimeError, match="unavailable"):
            reg.normalize(RoutingSpec(mode="fancy"))

    def test_resolved_fns_agree_with_free_functions(self):
        uh = u_hat(0)
        v_reg, c_reg = resolve(RoutingSpec.optimized(softmax="exact"))(uh)
        v_ref, c_ref = routing_lib.route_reference(uh)
        np.testing.assert_allclose(np.asarray(v_reg), np.asarray(v_ref),
                                   atol=1e-6)
        np.testing.assert_allclose(np.asarray(c_reg), np.asarray(c_ref),
                                   atol=1e-6)

    def test_legacy_route_wrapper_gone(self):
        """The PR-1 deprecation cycle is finished: no free route()."""
        assert not hasattr(routing_lib, "route")

    def test_config_routing_spec_default_and_override(self):
        cfg = tiny_cfg()
        assert cfg.routing_spec() == RoutingSpec.reference()
        cfg2 = dataclasses.replace(
            cfg, routing=RoutingSpec.optimized(softmax="taylor"))
        assert cfg2.routing_spec() == RoutingSpec.optimized(softmax="taylor")


class TestFastCapsPipeline:
    def test_matches_core_free_functions(self):
        """Pipeline stages == the core mask/apply/compact free functions."""
        cfg = tiny_cfg()
        params = cn.init(cfg, jax.random.key(0))
        masks = cn.lakp_masks(params, cfg, 0.5, 0.75, type_keep=2)
        masked = cn.apply_masks(params, masks)
        compact_p, compact_cfg, _ = cn.compact(masked, cfg, masks)

        pipe = FastCapsPipeline(cfg, params=params)
        pipe.prune(0.5, 0.75, type_keep=2).compact()
        assert pipe.cfg == dataclasses.replace(
            compact_cfg, routing=pipe.cfg.routing)
        for a, b in zip(jax.tree.leaves(pipe.params),
                        jax.tree.leaves(compact_p)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert pipe.compression is not None
        assert pipe.index_overhead_frac is not None

    def test_compiled_forward_matches_free_function(self):
        cfg = tiny_cfg()
        pipe = FastCapsPipeline(cfg).build(seed=0)
        dep = pipe.compile(routing="reference")
        imgs = jax.random.uniform(jax.random.key(1), (3, 28, 28, 1))
        lengths_free, _ = cn.forward(pipe.params, cfg, imgs)
        np.testing.assert_allclose(np.asarray(dep.forward(imgs)),
                                   np.asarray(lengths_free), atol=1e-6)

    def test_optimized_agreement_on_fixed_seed(self):
        """Acceptance: optimized-vs-reference prediction agreement >= 99%."""
        pipe = FastCapsPipeline(tiny_cfg()).build(seed=0)
        pipe.prune(0.6, 0.9, type_keep=2).compact()
        dep_ref = pipe.compile(routing="reference")
        dep_opt = pipe.compile(routing=RoutingSpec.pallas(softmax="taylor"))
        imgs = jax.random.uniform(jax.random.key(1), (16, 28, 28, 1))
        agree = float(jnp.mean((dep_ref.classify(imgs)
                                == dep_opt.classify(imgs))))
        assert agree >= 0.99

    def test_stage_order_enforced(self):
        pipe = FastCapsPipeline(tiny_cfg())
        with pytest.raises(PipelineError):
            pipe.prune(0.5, 0.5)            # before build
        pipe.build()
        with pytest.raises(PipelineError):
            pipe.compact()                  # before prune
        pipe.prune(0.5, 0.5)
        with pytest.raises(PipelineError):
            pipe.build()                    # build twice
        pipe.compact()

    def test_deployed_is_immutable_with_accounting(self):
        pipe = FastCapsPipeline(tiny_cfg()).build(seed=0)
        dep = pipe.compile()
        assert isinstance(dep, DeployedCapsNet)
        assert dep.n_params == cn.param_count(pipe.params)
        assert dep.flops_per_image > 0
        with pytest.raises(dataclasses.FrozenInstanceError):
            dep.n_params = 0

    def test_save_roundtrip(self, tmp_path):
        from repro.checkpointing import checkpoint

        pipe = FastCapsPipeline(tiny_cfg()).build(seed=0)
        dep = pipe.compile()
        dep.save(str(tmp_path), step=3)
        assert (tmp_path / "deploy.json").exists()
        step, restored = checkpoint.load_latest(str(tmp_path), dep.params)
        assert step == 3
        for a, b in zip(jax.tree.leaves(restored),
                        jax.tree.leaves(dep.params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_deploy_convenience(self):
        dep = FastCapsPipeline(tiny_cfg()).deploy(
            0.5, 0.75, type_keep=2, routing="optimized")
        assert dep.cfg.caps_types == 2
        assert dep.spec.mode == "optimized"


class TestCapsuleEngine:
    def _deployed(self, batch=4):
        pipe = FastCapsPipeline(tiny_cfg()).build(seed=0)
        return pipe.compile(routing="optimized")

    def _reqs(self, counts, seed=0):
        rng = np.random.RandomState(seed)
        return [ImageRequest(rng.rand(n, 28, 28, 1).astype(np.float32),
                             rid=i)
                for i, n in enumerate(counts)]

    def test_ragged_requests_complete(self):
        dep = self._deployed()
        eng = CapsuleEngine(dep, batch_size=4)
        comps = eng.serve(self._reqs([1, 5, 3, 2]))
        assert sorted(c.rid for c in comps) == [0, 1, 2, 3]
        assert [len(c.classes) for c in
                sorted(comps, key=lambda c: c.rid)] == [1, 5, 3, 2]
        stats = eng.stats()
        assert stats.frames == 11
        assert stats.batches == 3           # ceil(11 / 4)
        assert stats.padded_frames == 1

    def test_predictions_match_direct_forward(self):
        """Padding-to-batch and slot packing must not change predictions."""
        dep = self._deployed()
        eng = CapsuleEngine(dep, batch_size=4)
        reqs = self._reqs([3, 6])
        comps = {c.rid: c for c in eng.serve(reqs)}
        for r in reqs:
            direct = np.asarray(dep.classify(r.images))
            np.testing.assert_array_equal(comps[r.rid].classes, direct)

    def test_fps_stats_monotone(self):
        dep = self._deployed()
        eng = CapsuleEngine(dep, batch_size=4)
        eng.warmup()
        eng.serve(self._reqs([4, 2]))
        s1 = eng.stats()
        eng.serve(self._reqs([5], seed=1))
        s2 = eng.stats()
        assert s1.fps > 0
        assert s2.frames > s1.frames
        assert s2.batches > s1.batches
        assert s2.wall_s > s1.wall_s

    def test_bad_frame_shape_rejected(self):
        eng = CapsuleEngine(self._deployed(), batch_size=4)
        with pytest.raises(ValueError, match="request images"):
            eng.submit(ImageRequest(np.zeros((2, 14, 14, 1), np.float32)))

    def test_zero_frame_request_completes_empty(self):
        eng = CapsuleEngine(self._deployed(), batch_size=4)
        rid = eng.submit(ImageRequest(np.zeros((0, 28, 28, 1), np.float32)))
        comps = eng.run_until_idle()
        assert [c.rid for c in comps] == [rid]
        assert comps[0].classes.shape == (0,)
        assert eng._requests == {}          # no leaked in-flight entry

    def test_rid_auto_assignment(self):
        """Requests with rid=None get unique engine-assigned ids, also
        when mixed with explicit rids."""
        eng = CapsuleEngine(self._deployed(), batch_size=4)
        frames = np.zeros((1, 28, 28, 1), np.float32)
        r0 = eng.submit(ImageRequest(frames.copy()))
        r1 = eng.submit(ImageRequest(frames.copy(), rid=5))
        r2 = eng.submit(ImageRequest(frames.copy()))
        assert len({r0, r1, r2}) == 3
        assert r1 == 5 and r2 > 5
        comps = eng.run_until_idle()
        assert sorted(c.rid for c in comps) == sorted([r0, r1, r2])

    def test_duplicate_rid_rejected(self):
        eng = CapsuleEngine(self._deployed(), batch_size=4)
        eng.submit(ImageRequest(np.zeros((1, 28, 28, 1), np.float32),
                                rid=7))
        with pytest.raises(ValueError, match="duplicate"):
            eng.submit(ImageRequest(np.zeros((1, 28, 28, 1), np.float32),
                                    rid=7))
