"""Autotuner: deterministic defaults, measured tuning, on-disk cache."""

import json
import os

import jax
import numpy as np
import pytest

from repro import kernels
from repro.kernels import tuning
from repro.kernels.registry import registry


@pytest.fixture
def tune_cache(tmp_path, monkeypatch):
    """Point the process-wide cache at a fresh temp dir."""
    monkeypatch.setenv(tuning.CACHE_ENV, str(tmp_path))
    cache = tuning.default_cache()
    cache.clear_memory()
    return cache


class TestHelpers:
    @pytest.mark.parametrize("n,cap,want", [
        (32, 8, 8), (9, 8, 3), (12, 8, 6), (7, 8, 7), (1, 8, 1),
        (250, 256, 250), (33, 256, 33), (13, 4, 1), (16, 16, 16),
    ])
    def test_largest_divisor(self, n, cap, want):
        assert tuning.largest_divisor(n, cap) == want
        assert n % tuning.largest_divisor(n, cap) == 0

    @pytest.mark.parametrize("n,cap", [
        (0, 8), (-3, 8), (12, 0), (12, -1), (0, 0),
    ])
    def test_largest_divisor_rejects_nonpositive(self, n, cap):
        # the kernel-legality checker relies on this contract: a zero-size
        # dimension or block request is a caller bug, never a silent 1
        with pytest.raises(ValueError, match="must be positive"):
            tuning.largest_divisor(n, cap)

    def test_shape_bucket(self):
        assert tuning.shape_bucket([(9, 252, 10, 16)]) == "16x256x16x16"
        assert tuning.shape_bucket([(8, 16), (8, 16)]) == "8x16,8x16"


class TestTuneCache:
    def test_roundtrip_and_persistence(self, tune_cache):
        key = tuning.TuneCache.key("k", "cpu", "8x16", "float32")
        tune_cache.put(key, {"row_block": 8}, {"row_block=8": 0.001})
        assert tune_cache.get(key) == {"row_block": 8}
        # a fresh instance reads the same file back
        fresh = tuning.TuneCache(tune_cache.path)
        assert fresh.get(key) == {"row_block": 8}
        blob = json.load(open(tune_cache.path))
        assert blob["version"] == tuning.CACHE_VERSION

    def test_corrupt_file_degrades_to_empty(self, tmp_path):
        p = tmp_path / "autotune.json"
        p.write_text("not json")
        cache = tuning.TuneCache(str(p))
        assert cache.get("anything") is None


class TestAutotune:
    def test_tunes_caches_and_dispatch_picks_winner(self, tune_cache):
        spec = registry.get("fused_routing")
        if not spec.is_available():
            pytest.skip("pallas unavailable")
        u = jax.random.normal(jax.random.key(0), (8, 16, 5, 4)) * 0.2
        best, timings = tuning.autotune(spec, (u,),
                                        {"softmax_mode": "exact"},
                                        cache=tune_cache, iters=1)
        # the base config is always a candidate, so the winner cannot be
        # slower than the old hard-coded blocks on this machine
        base = spec.legalize(dict(spec.base_config), u)
        assert (timings[tuning.config_label(best)]
                <= timings[tuning.config_label(base)])
        assert os.path.exists(tune_cache.path)
        # tuned dispatch resolves the cached winner; parity holds
        cfg = registry.resolve_config("fused_routing", u, tune=True)
        assert cfg == spec.legalize({**spec.base_config, **best}, u)
        with tuning.tuning(True):
            v_t, _ = kernels.fused_routing(u)
        v_d, _ = kernels.fused_routing(u, tune=False)
        np.testing.assert_allclose(np.asarray(v_t), np.asarray(v_d),
                                   atol=1e-6)

    def test_candidates_are_legal_and_include_base(self, tune_cache):
        spec = registry.get("flash_attention")
        q = jax.ShapeDtypeStruct((1, 96, 4, 32), "float32")
        k = jax.ShapeDtypeStruct((1, 96, 2, 32), "float32")
        cands = tuning.candidate_configs(spec, q, k, k)
        assert spec.legalize(dict(spec.base_config), q, k, k) == cands[0]
        for c in cands:
            assert 96 % c["q_block"] == 0 and 96 % c["kv_block"] == 0
        # legalization dedupes the product down to distinct configs
        assert len(cands) == len({tuple(sorted(c.items())) for c in cands})

    def test_trace_time_dispatch_reads_cache_only(self, tune_cache):
        """Inside jit, tuned dispatch must not try to measure: it reads
        the cache (miss -> deterministic defaults) and never errors."""
        u = jax.random.normal(jax.random.key(0), (4, 8, 5, 4)) * 0.2

        @jax.jit
        def fn(u):
            return kernels.fused_routing(u, tune=True)[0]

        v = fn(u)
        v_ref = kernels.fused_routing(u, tune=False)[0]
        np.testing.assert_allclose(np.asarray(v), np.asarray(v_ref),
                                   atol=1e-6)


class TestPolicyScope:
    def test_scope_overrides_env(self, monkeypatch):
        monkeypatch.delenv(tuning.TUNE_ENV, raising=False)
        assert not tuning.tune_enabled()
        with tuning.tuning(True):
            assert tuning.tune_enabled()
            with tuning.tuning(False):
                assert not tuning.tune_enabled()
            assert tuning.tune_enabled()
        assert not tuning.tune_enabled()
        monkeypatch.setenv(tuning.TUNE_ENV, "1")
        assert tuning.tune_enabled()
        with tuning.tuning(False):
            assert not tuning.tune_enabled()


class TestCacheConcurrency:
    """Two processes sharing REPRO_KERNEL_CACHE_DIR must never corrupt
    the JSON cache (merge-on-write + per-writer tmp + atomic rename)."""

    WRITER = r"""
import sys
from repro.kernels import tuning

tag = sys.argv[1]
cache = tuning.default_cache()
for i in range(40):
    key = tuning.TuneCache.key(f"k_{tag}_{i}", "cpu", "8x16", "float32")
    cache.put(key, {"block": i}, {f"block={i}": 0.001})
print("WRITER_DONE", tag)
"""

    def test_concurrent_processes_do_not_corrupt(self, tmp_path):
        env = dict(os.environ)
        env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..",
                                         "src")
        env[tuning.CACHE_ENV] = str(tmp_path)
        import subprocess
        import sys
        procs = [subprocess.Popen([sys.executable, "-c", self.WRITER, tag],
                                  env=env, stdout=subprocess.PIPE,
                                  stderr=subprocess.PIPE, text=True)
                 for tag in ("a", "b")]
        for p in procs:
            out, err = p.communicate(timeout=120)
            assert p.returncode == 0, err
            assert "WRITER_DONE" in out
        # whatever the interleaving, the published file is valid JSON of
        # the right version, the flock'd read-merge-replace loses neither
        # writer's keys, and no stale tmp files leak
        blob = json.load(open(tmp_path / "autotune.json"))
        assert blob["version"] == tuning.CACHE_VERSION
        entries = blob["entries"]
        n_a = sum(1 for k in entries if k.startswith("k_a_"))
        n_b = sum(1 for k in entries if k.startswith("k_b_"))
        assert (n_a, n_b) == (40, 40), (n_a, n_b)
        assert not list(tmp_path.glob("*.tmp"))

    def test_merge_on_write_keeps_foreign_entries(self, tmp_path):
        """A second cache instance (standing in for another process)
        writing to the same file must not erase entries the first one
        already published."""
        a = tuning.TuneCache(str(tmp_path / "autotune.json"))
        b = tuning.TuneCache(str(tmp_path / "autotune.json"))
        ka = tuning.TuneCache.key("ka", "cpu", "8", "float32")
        kb = tuning.TuneCache.key("kb", "cpu", "8", "float32")
        a.put(ka, {"block": 1})
        b.put(kb, {"block": 2})       # b never saw a's write at load time
        fresh = tuning.TuneCache(str(tmp_path / "autotune.json"))
        assert fresh.get(ka) == {"block": 1}
        assert fresh.get(kb) == {"block": 2}

    def test_stale_snapshot_does_not_revert_newer_foreign_write(self,
                                                                tmp_path):
        """Merge-on-write overlays only the keys THIS instance wrote: a
        process holding an old in-memory copy of key K must not revert
        another process's newer K when it writes an unrelated key."""
        path = str(tmp_path / "autotune.json")
        k = tuning.TuneCache.key("k", "cpu", "8", "float32")
        other = tuning.TuneCache.key("other", "cpu", "8", "float32")
        a = tuning.TuneCache(path)
        a.put(k, {"block": 1})
        b = tuning.TuneCache(path)
        assert b.get(k) == {"block": 1}   # b's snapshot now holds old K
        a.put(k, {"block": 99})           # a publishes a newer K
        b.put(other, {"block": 2})        # b writes an unrelated key
        fresh = tuning.TuneCache(path)
        assert fresh.get(k) == {"block": 99}, "stale snapshot reverted K"
        assert fresh.get(other) == {"block": 2}


class TestTuneFalseDeterminism:
    """tune=False config resolution must be identical across runs — the
    deterministic CI path cannot depend on cache state or process."""

    RESOLVER = r"""
import json
from repro.kernels.registry import registry

out = {}
for name in registry.names():
    spec = registry.get(name)
    if not spec.is_available():
        continue
    for i, case in enumerate(spec.example_cases):
        args, kwargs = spec.make_example(case)
        out[f"{name}#{i}"] = registry.default_config(name, *args, **kwargs)
print(json.dumps(out, sort_keys=True))
"""

    def test_identical_across_fresh_processes(self, tmp_path):
        import subprocess
        import sys
        env = dict(os.environ)
        env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..",
                                         "src")
        runs = []
        for k in range(2):
            env[tuning.CACHE_ENV] = str(tmp_path / f"cache{k}")  # both cold
            r = subprocess.run([sys.executable, "-c", self.RESOLVER],
                               env=env, capture_output=True, text=True,
                               timeout=300)
            assert r.returncode == 0, r.stderr
            runs.append(r.stdout.strip().splitlines()[-1])
        assert runs[0] == runs[1]
        assert json.loads(runs[0])    # non-empty, well-formed


class TestServingBindTime:
    def test_capsule_engine_pretunes_at_warmup(self, tune_cache):
        """kernel_tune=True: warmup autotunes fused_routing for the
        scheduler's batch shapes before the forward compiles."""
        from repro.core import capsnet as cn
        from repro.deploy import FastCapsPipeline, RoutingSpec

        cfg = cn.CapsNetConfig(arch_id="capsnet-tune", conv1_channels=8,
                               caps_types=4, decoder_hidden=(16, 32))
        dep = FastCapsPipeline(cfg).build(seed=0).compile(
            routing=RoutingSpec.pallas(softmax="taylor"))
        engine = dep.serve(batch_size=2, kernel_tune=True)
        engine.warmup()
        entries = json.load(open(tune_cache.path))["entries"]
        assert any(k.startswith("fused_routing|") for k in entries)
        # and the engine still serves correctly with tuned executables
        frames = np.random.RandomState(0).rand(
            3, cfg.image_hw, cfg.image_hw, cfg.in_channels).astype("f")
        from repro.serving import ImageRequest

        done = engine.serve([ImageRequest(frames)])
        assert done[0].classes.shape == (3,)
