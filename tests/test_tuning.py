"""Autotuner: deterministic defaults, measured tuning, on-disk cache."""

import json
import os

import jax
import numpy as np
import pytest

from repro import kernels
from repro.kernels import tuning
from repro.kernels.registry import registry


@pytest.fixture
def tune_cache(tmp_path, monkeypatch):
    """Point the process-wide cache at a fresh temp dir."""
    monkeypatch.setenv(tuning.CACHE_ENV, str(tmp_path))
    cache = tuning.default_cache()
    cache.clear_memory()
    return cache


class TestHelpers:
    @pytest.mark.parametrize("n,cap,want", [
        (32, 8, 8), (9, 8, 3), (12, 8, 6), (7, 8, 7), (1, 8, 1),
        (250, 256, 250), (33, 256, 33), (13, 4, 1), (16, 16, 16),
    ])
    def test_largest_divisor(self, n, cap, want):
        assert tuning.largest_divisor(n, cap) == want
        assert n % tuning.largest_divisor(n, cap) == 0

    def test_shape_bucket(self):
        assert tuning.shape_bucket([(9, 252, 10, 16)]) == "16x256x16x16"
        assert tuning.shape_bucket([(8, 16), (8, 16)]) == "8x16,8x16"


class TestTuneCache:
    def test_roundtrip_and_persistence(self, tune_cache):
        key = tuning.TuneCache.key("k", "cpu", "8x16", "float32")
        tune_cache.put(key, {"row_block": 8}, {"row_block=8": 0.001})
        assert tune_cache.get(key) == {"row_block": 8}
        # a fresh instance reads the same file back
        fresh = tuning.TuneCache(tune_cache.path)
        assert fresh.get(key) == {"row_block": 8}
        blob = json.load(open(tune_cache.path))
        assert blob["version"] == tuning.CACHE_VERSION

    def test_corrupt_file_degrades_to_empty(self, tmp_path):
        p = tmp_path / "autotune.json"
        p.write_text("not json")
        cache = tuning.TuneCache(str(p))
        assert cache.get("anything") is None


class TestAutotune:
    def test_tunes_caches_and_dispatch_picks_winner(self, tune_cache):
        spec = registry.get("fused_routing")
        if not spec.is_available():
            pytest.skip("pallas unavailable")
        u = jax.random.normal(jax.random.key(0), (8, 16, 5, 4)) * 0.2
        best, timings = tuning.autotune(spec, (u,),
                                        {"softmax_mode": "exact"},
                                        cache=tune_cache, iters=1)
        # the base config is always a candidate, so the winner cannot be
        # slower than the old hard-coded blocks on this machine
        base = spec.legalize(dict(spec.base_config), u)
        assert (timings[tuning.config_label(best)]
                <= timings[tuning.config_label(base)])
        assert os.path.exists(tune_cache.path)
        # tuned dispatch resolves the cached winner; parity holds
        cfg = registry.resolve_config("fused_routing", u, tune=True)
        assert cfg == spec.legalize({**spec.base_config, **best}, u)
        with tuning.tuning(True):
            v_t, _ = kernels.fused_routing(u)
        v_d, _ = kernels.fused_routing(u, tune=False)
        np.testing.assert_allclose(np.asarray(v_t), np.asarray(v_d),
                                   atol=1e-6)

    def test_candidates_are_legal_and_include_base(self, tune_cache):
        spec = registry.get("flash_attention")
        q = jax.ShapeDtypeStruct((1, 96, 4, 32), "float32")
        k = jax.ShapeDtypeStruct((1, 96, 2, 32), "float32")
        cands = tuning.candidate_configs(spec, q, k, k)
        assert spec.legalize(dict(spec.base_config), q, k, k) == cands[0]
        for c in cands:
            assert 96 % c["q_block"] == 0 and 96 % c["kv_block"] == 0
        # legalization dedupes the product down to distinct configs
        assert len(cands) == len({tuple(sorted(c.items())) for c in cands})

    def test_trace_time_dispatch_reads_cache_only(self, tune_cache):
        """Inside jit, tuned dispatch must not try to measure: it reads
        the cache (miss -> deterministic defaults) and never errors."""
        u = jax.random.normal(jax.random.key(0), (4, 8, 5, 4)) * 0.2

        @jax.jit
        def fn(u):
            return kernels.fused_routing(u, tune=True)[0]

        v = fn(u)
        v_ref = kernels.fused_routing(u, tune=False)[0]
        np.testing.assert_allclose(np.asarray(v), np.asarray(v_ref),
                                   atol=1e-6)


class TestPolicyScope:
    def test_scope_overrides_env(self, monkeypatch):
        monkeypatch.delenv(tuning.TUNE_ENV, raising=False)
        assert not tuning.tune_enabled()
        with tuning.tuning(True):
            assert tuning.tune_enabled()
            with tuning.tuning(False):
                assert not tuning.tune_enabled()
            assert tuning.tune_enabled()
        assert not tuning.tune_enabled()
        monkeypatch.setenv(tuning.TUNE_ENV, "1")
        assert tuning.tune_enabled()
        with tuning.tuning(False):
            assert not tuning.tune_enabled()


class TestServingBindTime:
    def test_capsule_engine_pretunes_at_warmup(self, tune_cache):
        """kernel_tune=True: warmup autotunes fused_routing for the
        scheduler's batch shapes before the forward compiles."""
        from repro.core import capsnet as cn
        from repro.deploy import FastCapsPipeline, RoutingSpec

        cfg = cn.CapsNetConfig(arch_id="capsnet-tune", conv1_channels=8,
                               caps_types=4, decoder_hidden=(16, 32))
        dep = FastCapsPipeline(cfg).build(seed=0).compile(
            routing=RoutingSpec.pallas(softmax="taylor"))
        engine = dep.serve(batch_size=2, kernel_tune=True)
        engine.warmup()
        entries = json.load(open(tune_cache.path))["entries"]
        assert any(k.startswith("fused_routing|") for k in entries)
        # and the engine still serves correctly with tuned executables
        frames = np.random.RandomState(0).rand(
            3, cfg.image_hw, cfg.image_hw, cfg.in_channels).astype("f")
        from repro.serving import ImageRequest

        done = engine.serve([ImageRequest(frames)])
        assert done[0].classes.shape == (3,)
