"""Disaggregated prefill: PrefillEngine -> CacheHandoff -> DecodeEngine.

Covers the tentpole guarantees:

  * exactness — `DisaggregatedEngine` output matches per-request
    ``generate()`` bit-for-bit for dense/vlm/ssm/hybrid tiny configs
    (recurrent families ride the length-bucketed prefill path), on this
    host and on a forced 2-device host with sharded decode (subprocess);
  * streaming — per-rid StreamEvent ordering holds across the handoff
    boundary, and the done event carries the end-to-end completion;
  * fault injection — a decode engine rejects a mismatched handoff
    (dtype/shape/model-family) with a clear error before any state
    changes, and a decode engine killed mid-handoff — or a transport
    route erroring mid-transfer — causes a requeue + failover onto a
    surviving route, never a dropped request, under every transport;
  * stats — per-phase queue-depth and handoff transfer-latency
    histograms (including the per-transport per-leg keys) populate and
    aggregate.
"""

import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from engine_testlib import FlakyTransport
from repro.models import lm
from repro.models.common import LMConfig, SSMConfig, XLSTMConfig
from repro.serving import (CacheHandoff, DecodeEngine, DisaggregatedEngine,
                           HandoffRequest, PrefillEngine, Request,
                           ServeEngine, disaggregated_lm_engine,
                           multihost_disaggregated_lm_engine)

PROMPTS = [[1, 2, 3], [5, 6, 7, 8, 9, 10, 11], [2, 4]]
TRANSPORTS = ["in_process", "host_staged", "device_to_device"]


def tiny(family="dense", **kw):
    base = dict(arch_id="tiny-" + family, family=family, n_layers=2,
                d_model=32, n_heads=4, n_kv_heads=2, d_ff=64, vocab=64,
                remat=False, compute_dtype="float32", param_dtype="float32")
    base.update(kw)
    return LMConfig(**base)


def cfg_for(family):
    if family == "dense":
        return tiny()
    if family == "vlm":
        return tiny("vlm", n_layers=3, cross_attn_every=2, n_image_tokens=8)
    if family == "ssm":
        return tiny("ssm", d_model=16, n_heads=2, d_ff=0, vocab=32,
                    xlstm=XLSTMConfig(slstm_every=2, chunk_size=8))
    if family == "hybrid":
        return tiny("hybrid", d_model=16, n_heads=2, d_ff=32, vocab=32,
                    hybrid_attn_every=2,
                    ssm=SSMConfig(d_state=4, d_conv=4, expand=2, head_dim=8,
                                  n_groups=1, chunk_size=8))
    raise ValueError(family)


class TestExactness:
    """Acceptance: disaggregated serving == per-request generation."""

    @pytest.mark.parametrize("family", ["dense", "vlm", "ssm", "hybrid"])
    def test_matches_per_request_generate(self, family):
        cfg = cfg_for(family)
        params = lm.init(cfg, jax.random.key(0))
        n_decode = 2 if family == "dense" else 1
        eng = disaggregated_lm_engine(cfg, params, n_slots=2, max_len=32,
                                      n_decode=n_decode)
        ref = ServeEngine(cfg, params, n_slots=2, max_len=32)
        reqs = [Request(prompt=p, max_new_tokens=4, rid=i)
                for i, p in enumerate(PROMPTS)]
        comps = {c.rid: c for c in eng.serve(reqs)}
        for i, p in enumerate(PROMPTS):
            want = ref.generate([p], max_new_tokens=4)[0]
            assert comps[i].tokens == want, (family, i)

    @pytest.mark.parametrize("family", ["dense", "vlm", "ssm", "hybrid"])
    @pytest.mark.parametrize("transport", ["host_staged",
                                           "device_to_device"])
    def test_matches_generate_under_every_transport(self, family, transport):
        """The acceptance matrix: every moving transport variant stays
        bit-exact vs per-request generation for every cache family (the
        in-process default is the matrix's third row, pinned by
        ``test_matches_per_request_generate`` above)."""
        cfg = cfg_for(family)
        params = lm.init(cfg, jax.random.key(0))
        eng = disaggregated_lm_engine(cfg, params, n_slots=2, max_len=32,
                                      n_decode=2, transport=transport)
        ref = ServeEngine(cfg, params, n_slots=2, max_len=32)
        reqs = [Request(prompt=p, max_new_tokens=4, rid=i)
                for i, p in enumerate(PROMPTS)]
        comps = {c.rid: c for c in eng.serve(reqs)}
        for i, p in enumerate(PROMPTS):
            want = ref.generate([p], max_new_tokens=4)[0]
            assert comps[i].tokens == want, (family, transport, i)
        st = eng.stats()
        assert st.transfer[f"{transport}/total"].count == len(PROMPTS)

    def test_multihost_distinct_meshes_exact(self):
        """Prefill and decode engines on their own meshes (degenerate
        shared-device submeshes on a 1-device host — the 2-device case
        runs in the subprocess test below): still bit-exact, with the
        auto-selected transport."""
        cfg = cfg_for("dense")
        params = lm.init(cfg, jax.random.key(0))
        eng = multihost_disaggregated_lm_engine(cfg, params, n_slots=2,
                                                max_len=32, n_decode=1)
        ref = ServeEngine(cfg, params, n_slots=2, max_len=32)
        reqs = [Request(prompt=p, max_new_tokens=4, rid=i)
                for i, p in enumerate(PROMPTS)]
        comps = {c.rid: c for c in eng.serve(reqs)}
        for i, p in enumerate(PROMPTS):
            assert comps[i].tokens == ref.generate([p],
                                                   max_new_tokens=4)[0]

    def test_zero_new_tokens_identity(self):
        cfg = cfg_for("dense")
        eng = disaggregated_lm_engine(cfg, lm.init(cfg, jax.random.key(0)),
                                      n_slots=2, max_len=32)
        comps = eng.serve([Request(prompt=[4, 5, 6], max_new_tokens=0)])
        assert comps[0].tokens == [4, 5, 6]
        assert eng.stats().completed == 1

    def test_single_token_finishes_at_prefill(self):
        """max_new_tokens=1 is fully served by the prefill side; the done
        handoff still routes through decode so stream/stat accounting is
        one path."""
        cfg = cfg_for("dense")
        params = lm.init(cfg, jax.random.key(0))
        eng = disaggregated_lm_engine(cfg, params, n_slots=2, max_len=32)
        ref = ServeEngine(cfg, params, n_slots=2, max_len=32)
        comps = eng.serve([Request(prompt=[1, 2, 3], max_new_tokens=1)])
        assert comps[0].tokens == ref.generate([[1, 2, 3]],
                                               max_new_tokens=1)[0]


class TestStreaming:
    def test_token_order_across_handoff_boundary(self):
        cfg = cfg_for("dense")
        params = lm.init(cfg, jax.random.key(0))
        eng = disaggregated_lm_engine(cfg, params, n_slots=2, max_len=32,
                                      n_decode=2)
        ref = ServeEngine(cfg, params, n_slots=2, max_len=32)
        rids = [eng.submit(Request(prompt=p, max_new_tokens=3, stream=True))
                for p in PROMPTS]
        comps = {c.rid: c for c in eng.run_until_idle()}
        per_rid = {r: [] for r in rids}
        for ev in eng.poll(stream=True):
            per_rid[ev.rid].append(ev)
        for r, p in zip(rids, PROMPTS):
            evs = per_rid[r]
            assert [e.seq for e in evs] == list(range(len(evs)))
            assert evs[-1].done and evs[-1].item is None
            toks = [e.item for e in evs if not e.done]
            assert len(toks) == 3     # one event per generated token,
            #                           starting with the prefill-sampled one
            assert comps[r].tokens == list(p) + toks
            assert comps[r].tokens == ref.generate([p], max_new_tokens=3)[0]
            # the done event carries the same (end-to-end) completion
            assert evs[-1].completion is comps[r]


class TestStats:
    def test_phase_depth_and_transfer_histograms(self):
        cfg = cfg_for("dense")
        eng = disaggregated_lm_engine(cfg, lm.init(cfg, jax.random.key(0)),
                                      n_slots=2, max_len=32)
        eng.serve([Request(prompt=p, max_new_tokens=3, rid=i)
                   for i, p in enumerate(PROMPTS)])
        st = eng.stats()
        assert st.completed == 3
        assert st.items == 3 * 3      # generated tokens across both engines
        assert set(st.depth) >= {"prefill", "handoff", "decode"}
        assert st.depth["handoff"].peak >= 1
        assert st.transfer["handoff"].count == 3   # one transfer per request
        # per-transport per-leg critical-path histograms, one entry per
        # delivered handoff (default transport: in_process)
        assert st.transfer["in_process/pass"].count == 3
        assert st.transfer["in_process/total"].count == 3
        assert st.latency_summary() and st.depth_summary() \
            and st.transfer_summary()

    def test_snapshot_detached_and_monotone(self):
        cfg = cfg_for("dense")
        eng = disaggregated_lm_engine(cfg, lm.init(cfg, jax.random.key(0)),
                                      n_slots=2, max_len=32)
        eng.serve([Request(prompt=[1, 2], max_new_tokens=2)])
        s1 = eng.stats()
        eng.serve([Request(prompt=[3, 4], max_new_tokens=2)])
        s2 = eng.stats()
        assert s1.completed == 1 and s2.completed == 2
        assert s2.items > s1.items and s2.ticks > s1.ticks
        for k, h in s1.depth.items():
            assert s2.depth[k].count >= h.count
        assert s1.transfer["handoff"].count == 1   # detached snapshot


def _one_handoff(cfg, params, prompt=(1, 2, 3), max_new=4):
    pre = PrefillEngine(cfg, params, n_slots=2, max_len=32)
    pre.submit(Request(prompt=list(prompt), max_new_tokens=max_new))
    (h,) = pre.run_until_idle()
    assert isinstance(h, CacheHandoff)
    return h


class TestHandoffValidation:
    """Fault injection: a decode engine must refuse a handoff it cannot
    decode exactly — no silent garbage decode."""

    def setup_method(self, method):
        self.cfg = cfg_for("dense")
        self.params = lm.init(self.cfg, jax.random.key(0))

    def test_family_mismatch_rejected(self):
        h = _one_handoff(self.cfg, self.params)
        other = cfg_for("ssm")
        dec = DecodeEngine(other, lm.init(other, jax.random.key(0)),
                           n_slots=2, max_len=32)
        with pytest.raises(ValueError, match="family"):
            dec.submit(HandoffRequest(handoff=h))

    def test_max_len_mismatch_rejected(self):
        h = _one_handoff(self.cfg, self.params)
        dec = DecodeEngine(self.cfg, self.params, n_slots=2, max_len=64)
        with pytest.raises(ValueError, match="max_len"):
            dec.submit(HandoffRequest(handoff=h))

    def test_dtype_mismatch_rejected(self):
        h = _one_handoff(self.cfg, self.params)
        h.rows = jax.tree.map(lambda x: x.astype("float16"), h.rows)
        dec = DecodeEngine(self.cfg, self.params, n_slots=2, max_len=32)
        with pytest.raises(ValueError, match="dtype"):
            dec.submit(HandoffRequest(handoff=h))

    def test_shape_mismatch_rejected(self):
        h = _one_handoff(self.cfg, self.params)
        h.rows = jax.tree.map(lambda x: x[..., :-1], h.rows)
        dec = DecodeEngine(self.cfg, self.params, n_slots=2, max_len=32)
        with pytest.raises(ValueError, match="shape"):
            dec.submit(HandoffRequest(handoff=h))

    def test_rejection_leaves_engine_clean(self):
        """A refused handoff changes nothing: the engine still serves."""
        good = _one_handoff(self.cfg, self.params)
        bad = _one_handoff(self.cfg, self.params, prompt=(7, 8))
        bad.rows = jax.tree.map(lambda x: x.astype("float16"), bad.rows)
        dec = DecodeEngine(self.cfg, self.params, n_slots=2, max_len=32)
        with pytest.raises(ValueError):
            dec.submit(HandoffRequest(handoff=bad))
        assert dec.n_pending == 0
        dec.submit(HandoffRequest(handoff=good, rid=good.rid))
        (comp,) = dec.run_until_idle()
        ref = ServeEngine(self.cfg, self.params, n_slots=2, max_len=32)
        assert comp.tokens == ref.generate([[1, 2, 3]], max_new_tokens=4)[0]


class TestFailover:
    """Fault injection: a decode engine killed mid-handoff must cause a
    requeue onto another engine, never a dropped request."""

    def _pair(self, kill_first, transport=None):
        cfg = cfg_for("dense")
        params = lm.init(cfg, jax.random.key(0))
        pre = PrefillEngine(cfg, params, n_slots=2, max_len=32)
        decs = [DecodeEngine(cfg, params, n_slots=2, max_len=32)
                for _ in range(2)]
        if kill_first:
            def boom(request):
                raise RuntimeError("decode engine killed mid-handoff")
            decs[0].submit = boom
        return cfg, params, DisaggregatedEngine(pre, decs,
                                                transport=transport)

    @pytest.mark.parametrize("transport", TRANSPORTS)
    def test_killed_engine_fails_over(self, transport):
        """An engine killed mid-handoff fails over under every transport
        — including the moving ones, whose delivery has already happened
        when the submit dies (the rows re-deliver to the survivor)."""
        cfg, params, eng = self._pair(kill_first=True, transport=transport)
        rid = eng.submit(Request(prompt=[1, 2, 3], max_new_tokens=4))
        comps = eng.run_until_idle()
        assert [c.rid for c in comps] == [rid]      # requeued, not dropped
        assert eng._dead == {eng.decodes[0]}
        ref = ServeEngine(cfg, params, n_slots=2, max_len=32)
        assert comps[0].tokens == ref.generate([[1, 2, 3]],
                                               max_new_tokens=4)[0]

    def test_transport_error_mid_transfer_requeues_and_survives(self):
        """A transport route erroring mid-transfer behaves exactly like
        a killed engine: the target is marked dead, the handoff requeues
        onto a surviving route, tokens stay exact, and the failed
        delivery leaves no partial state (rows re-deliver untouched)."""
        cfg = cfg_for("dense")
        params = lm.init(cfg, jax.random.key(0))
        flaky = FlakyTransport(fail_on={0})         # first delivery dies
        eng = disaggregated_lm_engine(cfg, params, n_slots=2, max_len=32,
                                      n_decode=2, transport=flaky)
        rid = eng.submit(Request(prompt=[1, 2, 3], max_new_tokens=4))
        comps = eng.run_until_idle()
        assert [c.rid for c in comps] == [rid]      # requeued, not dropped
        assert len(eng._dead) == 1                  # the failed route's target
        assert flaky.calls == 2                     # failed + surviving
        ref = ServeEngine(cfg, params, n_slots=2, max_len=32)
        assert comps[0].tokens == ref.generate([[1, 2, 3]],
                                               max_new_tokens=4)[0]
        st = eng.stats()
        assert st.transfer["flaky/total"].count == 1   # only the success

    def test_no_decode_starvation_under_sustained_arrivals(self):
        """A new request arriving every front-end tick must not stop the
        already-resident decodes from progressing (DisaggScheduler
        answers "mixed" when both sides have work — separate engines
        advance together)."""
        cfg = cfg_for("dense")
        params = lm.init(cfg, jax.random.key(0))
        eng = disaggregated_lm_engine(cfg, params, n_slots=2, max_len=48)
        first = eng.submit(Request(prompt=[1, 2, 3], max_new_tokens=3))
        done = []
        for i in range(12):           # arrivals never pause
            eng.submit(Request(prompt=[4 + (i % 3)], max_new_tokens=3))
            eng.tick()
            done += [c.rid for c in eng.poll()]
            if first in done:
                break
        assert first in done, "resident decode starved by prefill arrivals"

    def test_typed_rejection_mid_transfer_requeues_before_raising(self):
        """A ValueError during transfer (heterogeneous pool: one decode
        engine cannot take this handoff) must surface — but the handoff
        goes back on the queue first, never dropped."""
        cfg = cfg_for("dense")
        other = cfg_for("ssm")
        params = lm.init(cfg, jax.random.key(0))
        pre = PrefillEngine(cfg, params, n_slots=2, max_len=32)
        bad = DecodeEngine(other, lm.init(other, jax.random.key(0)),
                           n_slots=2, max_len=32)
        eng = DisaggregatedEngine(pre, [bad])
        eng.submit(Request(prompt=[1, 2, 3], max_new_tokens=4))
        with pytest.raises(ValueError, match="family"):
            eng.run_until_idle()
        assert len(eng._handoffs) == 1              # requeued, not dropped

    def test_all_engines_dead_raises_with_handoff_requeued(self):
        cfg = cfg_for("dense")
        params = lm.init(cfg, jax.random.key(0))
        pre = PrefillEngine(cfg, params, n_slots=2, max_len=32)
        dec = DecodeEngine(cfg, params, n_slots=2, max_len=32)

        def boom(request):
            raise RuntimeError("killed")
        dec.submit = boom
        eng = DisaggregatedEngine(pre, [dec])
        eng.submit(Request(prompt=[1, 2, 3], max_new_tokens=4))
        with pytest.raises(RuntimeError, match="decode engines failed"):
            eng.run_until_idle()
        assert len(eng._handoffs) == 1              # stranded but not lost


def test_disagg_sharded_decode_on_2device_cpu_mesh():
    """Acceptance regression on a 2-device host: disaggregated serving
    with the decode engine's KV caches/recurrent state sharded along the
    slot axis (ShardedScheduler) matches per-request generation for the
    four stateful families (subprocess: the test process is pinned to
    one device)."""
    script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import jax, numpy as np
from jax.sharding import NamedSharding
from repro.models import lm
from repro.models.common import LMConfig, SSMConfig, XLSTMConfig
from repro.launch.mesh import make_mesh
from repro.serving import (Request, ServeEngine, ShardedScheduler,
                           disaggregated_lm_engine)

def tiny(family="dense", **kw):
    base = dict(arch_id="tiny-" + family, family=family, n_layers=2,
                d_model=32, n_heads=4, n_kv_heads=2, d_ff=64, vocab=64,
                remat=False, compute_dtype="float32",
                param_dtype="float32")
    base.update(kw)
    return LMConfig(**base)

CFGS = [
    ("dense", tiny()),
    ("vlm", tiny("vlm", n_layers=3, cross_attn_every=2, n_image_tokens=8)),
    ("ssm", tiny("ssm", d_model=16, n_heads=2, d_ff=0, vocab=32,
                 xlstm=XLSTMConfig(slstm_every=2, chunk_size=8))),
    ("hybrid", tiny("hybrid", d_model=16, n_heads=2, d_ff=32, vocab=32,
                    hybrid_attn_every=2,
                    ssm=SSMConfig(d_state=4, d_conv=4, expand=2,
                                  head_dim=8, n_groups=1, chunk_size=8))),
]
PROMPTS = [[1, 2, 3], [5, 6, 7, 8, 9, 10, 11], [2, 4]]
for name, cfg in CFGS:
    params = lm.init(cfg, jax.random.key(0))
    sched = ShardedScheduler(make_mesh((2,), ("data",)))
    eng = disaggregated_lm_engine(cfg, params, n_slots=2, max_len=32,
                                  n_decode=1, decode_schedulers=[sched])
    leaf = jax.tree.leaves(eng.decodes[0]._caches)[0]
    assert isinstance(leaf.sharding, NamedSharding)
    ref = ServeEngine(cfg, params, n_slots=2, max_len=32)
    reqs = [Request(prompt=p, max_new_tokens=3, rid=i)
            for i, p in enumerate(PROMPTS)]
    comps = {c.rid: c for c in eng.serve(reqs)}
    for i, p in enumerate(PROMPTS):
        want = ref.generate([p], max_new_tokens=3)[0]
        assert comps[i].tokens == want, (name, i, comps[i].tokens, want)
    print(name, "OK")
print("DISAGG_SHARDED_OK")
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, timeout=600)
    assert "DISAGG_SHARDED_OK" in r.stdout, r.stdout + r.stderr


def test_transport_failover_sharded_decode_on_2device_cpu_mesh():
    """Killed-mid-handoff coverage for each transport on a REAL 2-device
    multihost topology (subprocess): prefill and both decode engines own
    distinct single-device meshes, the first decode engine dies at
    submit, and every transport must fail the handoff over to the
    engine on the *other* device with tokens staying exact — the rows
    genuinely re-deliver across a device boundary."""
    script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import jax
from repro.models import lm
from repro.models.common import LMConfig
from repro.parallel.sharding import disjoint_submeshes
from repro.serving import (DecodeEngine, DisaggregatedEngine, PrefillEngine,
                           Request, ServeEngine, ShardedScheduler)

cfg = LMConfig(arch_id="tiny-dense", family="dense", n_layers=2, d_model=32,
               n_heads=4, n_kv_heads=2, d_ff=64, vocab=64, remat=False,
               compute_dtype="float32", param_dtype="float32")
params = lm.init(cfg, jax.random.key(0))
ref = ServeEngine(cfg, params, n_slots=2, max_len=32)
want = ref.generate([[1, 2, 3]], max_new_tokens=4)[0]
for transport in ["in_process", "host_staged", "device_to_device"]:
    meshes = disjoint_submeshes(2)        # prefill dev0, survivor dev1
    pre = PrefillEngine(cfg, params, n_slots=2, max_len=32,
                        scheduler=ShardedScheduler(meshes[0]))
    decs = [DecodeEngine(cfg, params, n_slots=2, max_len=32,
                         scheduler=ShardedScheduler(meshes[i % 2]))
            for i in range(2)]
    def boom(request):
        raise RuntimeError("decode engine killed mid-handoff")
    decs[0].submit = boom
    eng = DisaggregatedEngine(pre, decs, transport=transport)
    rid = eng.submit(Request(prompt=[1, 2, 3], max_new_tokens=4))
    comps = eng.run_until_idle()
    assert [c.rid for c in comps] == [rid], transport
    assert eng._dead == {decs[0]}, transport
    assert comps[0].tokens == want, (transport, comps[0].tokens, want)
    st = eng.stats()
    assert st.transfer[transport + "/total"].count == 1, transport
    print(transport, "OK")
print("TRANSPORT_FAILOVER_OK")
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, timeout=600)
    assert "TRANSPORT_FAILOVER_OK" in r.stdout, r.stdout + r.stderr


def test_image_dispatch_pool():
    """The stateless degenerate form: prefill=None dispatches image
    requests over a pool of CapsuleEngines with the same front-end
    surface, validation-at-submit, and transfer stats."""
    from repro.core import capsnet as cn
    from repro.deploy import FastCapsPipeline
    from repro.serving import CapsuleEngine, ImageRequest

    cfg = cn.CapsNetConfig(conv1_channels=8, caps_types=2,
                           decoder_hidden=(16, 32))
    dep = FastCapsPipeline(cfg).build(seed=0).compile(routing="optimized")
    eng = DisaggregatedEngine(
        None, [CapsuleEngine(dep, batch_size=4) for _ in range(2)])
    rng = np.random.RandomState(0)
    reqs = [ImageRequest(rng.rand(n, 28, 28, 1).astype(np.float32), rid=i)
            for i, n in enumerate([3, 2, 5])]
    comps = {c.rid: c for c in eng.serve(reqs)}
    for r in reqs:
        want = np.asarray(dep.classify(r.images))
        np.testing.assert_array_equal(comps[r.rid].classes, want)
    st = eng.stats()
    assert st.frames == 10 and st.completed == 3
    assert st.transfer["handoff"].count == 3
    assert "prefill" not in st.depth        # no prefill stage, no phantom row
    with pytest.raises(ValueError, match="images must be"):
        eng.submit(ImageRequest(np.zeros((2, 3, 3, 1), np.float32)))
    assert eng.n_pending == 0
