"""Docs-consistency: the tier-1 mirror of the CI docs job.

``tools/check_docs.py`` must pass — every ``repro.*`` module named in
``docs/*.md`` resolves, and the README quickstart snippet executes.
Runs in a subprocess so a broken snippet cannot poison this process's
jax/device state.
"""

import os
import subprocess
import sys


def test_check_docs_passes():
    root = os.path.join(os.path.dirname(__file__), "..")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(root, "src")
    r = subprocess.run(
        [sys.executable, os.path.join(root, "tools", "check_docs.py")],
        env=env, capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stdout + r.stderr
