"""Graceful hypothesis fallback for the property-based tests.

When ``hypothesis`` is installed this module re-exports ``given``,
``settings`` and ``strategies as st`` unchanged.  When it is absent (the
CI image intentionally omits it), the decorators degrade to a runtime
``pytest.skip`` so the property-based cases *skip* instead of erroring
the whole module at collection time.
"""

from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import pytest

    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(fn):
            def skipped(*args, **kwargs):
                pytest.skip("hypothesis not installed")
            skipped.__name__ = fn.__name__
            skipped.__doc__ = fn.__doc__
            return skipped
        return deco

    def settings(*_args, **_kwargs):
        return lambda fn: fn

    def _stub(*_args, **_kwargs):
        """Self-returning callable: absorbs strategy construction and
        ``@st.composite`` decorator chains; values are never drawn."""
        return _stub

    class _StrategyStub:
        def __getattr__(self, name):
            return _stub

    st = _StrategyStub()
