"""The unified ``repro.serving`` engine API: shared EngineCore surface,
async admission while ticking, SLO batch adaptation, sharded scheduling
on a multi-device CPU mesh (image ticks and LM KV-cache decode), stats
monotonicity with per-class latency histograms, streaming ``poll()``,
prefill/decode tick interleaving, and the ragged-prefill regression
(slot serving == per-request generation)."""

import os
import subprocess
import sys
import threading

import jax
import numpy as np
import pytest

from repro.core import capsnet as cn
from repro.deploy import FastCapsPipeline
from repro.models import lm
from repro.models.common import LMConfig
from repro.serving import (CapsuleEngine, EngineCore, ImageRequest,
                           InterleavingScheduler, LatencyHistogram,
                           Request, ServeEngine, SLOBatchScheduler,
                           TickRecord)


def tiny_capsnet_cfg(**kw):
    base = dict(conv1_channels=16, caps_types=4, decoder_hidden=(32, 64))
    base.update(kw)
    return cn.CapsNetConfig(**base)


def deployed(**kw):
    pipe = FastCapsPipeline(tiny_capsnet_cfg(**kw)).build(seed=0)
    return pipe.compile(routing="optimized")


def tiny_lm(**kw):
    base = dict(arch_id="tiny", family="dense", n_layers=2, d_model=32,
                n_heads=4, n_kv_heads=2, d_ff=64, vocab=64, remat=False,
                compute_dtype="float32", param_dtype="float32")
    base.update(kw)
    return LMConfig(**base)


def frames(n, seed=0):
    return np.random.RandomState(seed).rand(n, 28, 28, 1).astype(np.float32)


class FakeClock:
    """Deterministic clock: each read advances by ``step`` seconds."""

    def __init__(self, step=0.01):
        self.t = 0.0
        self.step = step

    def __call__(self):
        self.t += self.step
        return self.t


class TestSharedSurface:
    def test_both_engines_are_engine_cores(self):
        caps = CapsuleEngine(deployed(), batch_size=4)
        cfg = tiny_lm()
        serve = ServeEngine(cfg, lm.init(cfg, jax.random.key(0)),
                            n_slots=2, max_len=32)
        for eng in (caps, serve):
            assert isinstance(eng, EngineCore)
            for name in ("submit", "poll", "run_until_idle", "stats",
                         "serve", "tick", "warmup"):
                assert callable(getattr(eng, name))

    def test_poll_is_incremental(self):
        eng = CapsuleEngine(deployed(), batch_size=4)
        eng.submit(ImageRequest(frames(2)))
        assert eng.poll() == []             # nothing ticked yet
        assert eng.tick() is True
        got = eng.poll()
        assert len(got) == 1
        assert eng.poll() == []             # drained
        assert eng.tick() is False          # idle


class TestAsyncAdmission:
    def test_submit_mid_tick_is_served(self):
        """A request submitted while a tick is in flight (from a callback
        fired inside the jitted forward wrapper) joins the next tick of
        the same run_until_idle call."""
        dep = deployed()
        eng = CapsuleEngine(dep, batch_size=2)
        late_rid = []

        class Hooked:
            cfg = dep.cfg

            def forward(self, x):
                if not late_rid:
                    late_rid.append(
                        eng.submit(ImageRequest(frames(1, seed=9))))
                return dep.forward(x)

        eng.deployed = Hooked()
        first = eng.submit(ImageRequest(frames(3)))
        comps = eng.run_until_idle()
        assert sorted(c.rid for c in comps) == sorted([first, late_rid[0]])

    def test_submit_from_other_thread(self):
        eng = CapsuleEngine(deployed(), batch_size=2)
        eng.submit(ImageRequest(frames(4)))

        def feeder():
            for i in range(3):
                eng.submit(ImageRequest(frames(1, seed=i + 1)))

        t = threading.Thread(target=feeder)
        t.start()
        comps = eng.run_until_idle()
        t.join()
        comps += eng.run_until_idle()       # anything that raced the drain
        assert len(comps) == 4
        assert eng.n_pending == 0


class TestSLOScheduler:
    def test_shrinks_under_impossible_target(self):
        """Every tick overshoots a 0ms target -> effective batch backs off
        to 1 (deterministic via the injected clock)."""
        sched = SLOBatchScheduler(target_p95_ms=0.0, window=4,
                                  min_samples=2)
        eng = CapsuleEngine(deployed(), batch_size=8, scheduler=sched,
                            clock=FakeClock(step=0.005))
        eng.serve([ImageRequest(frames(40))])
        assert sched.effective_batch == 1

    def test_grows_under_loose_target(self):
        """Ticks far below target -> effective batch doubles back up."""
        sched = SLOBatchScheduler(target_p95_ms=1e9, window=2,
                                  min_samples=2, initial_batch=1)
        eng = CapsuleEngine(deployed(), batch_size=4, scheduler=sched,
                            clock=FakeClock(step=0.001))
        eng.serve([ImageRequest(frames(24))])
        assert sched.effective_batch == 4

    def test_observe_unit_logic(self):
        """plan/observe contract without an engine: shrink on overshoot,
        grow only on a full under-target window."""
        sched = SLOBatchScheduler(target_p95_ms=10.0, window=4,
                                  min_samples=2)
        sched.capacity = 8
        sched._batch = 8
        for _ in range(2):
            sched.observe(TickRecord(8, 8, wall_s=0.05))   # 50ms > 10ms
        assert sched.effective_batch == 4
        for _ in range(4):
            sched.observe(TickRecord(4, 4, wall_s=0.001))  # 1ms << 10ms
        assert sched.effective_batch == 8

    def test_quantize_pow2(self):
        sched = SLOBatchScheduler(target_p95_ms=10.0)
        assert [sched.quantize(n, 8) for n in (1, 2, 3, 5, 8)] == \
            [1, 2, 4, 8, 8]

    def test_predictions_unchanged_by_slo_batching(self):
        dep = deployed()
        req = ImageRequest(frames(10))
        eng = CapsuleEngine(dep, batch_size=8,
                            scheduler=SLOBatchScheduler(target_p95_ms=0.0,
                                                        min_samples=1))
        comp = eng.serve([req])[0]
        np.testing.assert_array_equal(
            comp.classes, np.asarray(dep.classify(req.images)))


class TestStatsMonotone:
    def test_capsule_stats_monotone(self):
        eng = CapsuleEngine(deployed(), batch_size=4)
        eng.warmup()
        eng.serve([ImageRequest(frames(5))])
        s1 = eng.stats()
        eng.serve([ImageRequest(frames(3, seed=1))])
        s2 = eng.stats()
        assert s1.fps > 0
        assert (s2.items, s2.ticks, s2.completed) > \
            (s1.items, s1.ticks, s1.completed)
        assert s2.wall_s > s1.wall_s
        assert s2.padded >= s1.padded

    def test_lm_stats_monotone(self):
        cfg = tiny_lm()
        eng = ServeEngine(cfg, lm.init(cfg, jax.random.key(0)),
                          n_slots=2, max_len=32)
        eng.serve([Request(prompt=[1, 2], max_new_tokens=2)])
        s1 = eng.stats()
        eng.serve([Request(prompt=[3, 4, 5], max_new_tokens=3)])
        s2 = eng.stats()
        assert s1.items == 2 and s2.items == 5      # generated tokens
        assert s2.ticks > s1.ticks
        assert s2.wall_s > s1.wall_s
        assert s2.completed == 2


class TestRaggedLM:
    """The PR's ragged-prefill fix: per-slot prompt lengths and position
    ids must reproduce per-request generation exactly."""

    PROMPTS = [[1, 2, 3], [5, 6, 7, 8, 9, 10, 11], [2, 4]]

    def _engine(self, n_slots=2):
        cfg = tiny_lm()
        return ServeEngine(cfg, lm.init(cfg, jax.random.key(0)),
                           n_slots=n_slots, max_len=48)

    def test_ragged_generate_matches_per_request(self):
        eng = self._engine()
        batched = eng.generate(self.PROMPTS, max_new_tokens=5)
        single = [eng.generate([p], max_new_tokens=5)[0]
                  for p in self.PROMPTS]
        assert batched == single

    def test_slot_serve_matches_per_request_generation(self):
        """Continuous batching (3 ragged requests over 2 slots, admission
        mid-flight) produces the same greedy tokens as one-at-a-time."""
        eng = self._engine(n_slots=2)
        reqs = [Request(prompt=p, max_new_tokens=4, rid=i)
                for i, p in enumerate(self.PROMPTS)]
        comps = {c.rid: c for c in eng.serve(reqs)}
        for i, p in enumerate(self.PROMPTS):
            assert comps[i].tokens == eng.generate([p], max_new_tokens=4)[0]

    def test_generate_zero_new_tokens_is_identity(self):
        eng = self._engine()
        assert eng.generate(self.PROMPTS, max_new_tokens=0) == \
            [list(p) for p in self.PROMPTS]

    def test_serve_zero_new_tokens_is_identity(self):
        """submit/serve agrees with generate: max_new_tokens<=0 returns
        the prompt unchanged (prefill-free completion)."""
        eng = self._engine()
        comps = eng.serve([Request(prompt=[4, 5, 6], max_new_tokens=0)])
        assert comps[0].tokens == [4, 5, 6]

    def test_generate_overlong_prompt_rejected(self):
        eng = self._engine()
        with pytest.raises(ValueError, match="no room"):
            eng.generate([list(range(1, 50))], max_new_tokens=2)

    def test_sharded_scheduler_accepted_for_lm(self):
        """ServeEngine takes a ShardedScheduler: the KV caches are placed
        via lm.cache_shardings and decode matches the plain engine (a
        1-device mesh here; the 2-device exactness regression runs in
        test_sharded_lm_decode_on_cpu_mesh)."""
        from repro.launch.mesh import make_mesh
        from repro.serving import ShardedScheduler

        cfg = tiny_lm()
        params = lm.init(cfg, jax.random.key(0))
        eng = ServeEngine(cfg, params, n_slots=2, max_len=48,
                          scheduler=ShardedScheduler(make_mesh((1,),
                                                               ("data",))))
        ref = ServeEngine(cfg, params, n_slots=2, max_len=48)
        reqs = [Request(prompt=p, max_new_tokens=4, rid=i)
                for i, p in enumerate(self.PROMPTS)]
        comps = {c.rid: c for c in eng.serve(reqs)}
        for i, p in enumerate(self.PROMPTS):
            assert comps[i].tokens == ref.generate([p], max_new_tokens=4)[0]

    def test_generate_per_slot_max_len_stop(self):
        """A slot hitting max_len stops alone; shorter prompts keep
        generating — batched still equals per-request."""
        cfg = tiny_lm()
        eng = ServeEngine(cfg, lm.init(cfg, jax.random.key(0)),
                          n_slots=2, max_len=16)
        prompts = [[1, 2], [3] * 14]
        batched = eng.generate(prompts, max_new_tokens=8)
        single = [eng.generate([p], max_new_tokens=8)[0] for p in prompts]
        assert batched == single
        assert len(batched[0]) == 2 + 8        # unaffected by the other slot

    def test_empty_and_overlong_prompts_rejected(self):
        eng = self._engine()
        with pytest.raises(ValueError, match="empty prompt"):
            eng.submit(Request(prompt=[]))
        with pytest.raises(ValueError, match="no room"):
            eng.submit(Request(prompt=list(range(1, 50))))


class TestRecurrentRagged:
    """Recurrent families (ssm/hybrid) cannot mask a pad suffix out of
    their state: the engine admits them in exact-length buckets (ragged
    serving is exact), and ``generate()`` refuses ragged batches."""

    PROMPTS = [[1, 2, 3], [5, 6, 7, 8, 9, 10, 11], [2, 4]]

    def _engines(self):
        from repro.models.common import SSMConfig, XLSTMConfig

        ssm = tiny_lm(arch_id="tiny-ssm", family="ssm", d_model=16,
                      n_heads=2, d_ff=0, vocab=32,
                      xlstm=XLSTMConfig(slstm_every=2, chunk_size=8))
        hybrid = tiny_lm(arch_id="tiny-hyb", family="hybrid", d_model=16,
                         n_heads=2, d_ff=32, vocab=32, hybrid_attn_every=2,
                         ssm=SSMConfig(d_state=4, d_conv=4, expand=2,
                                       head_dim=8, n_groups=1,
                                       chunk_size=8))
        for cfg in (ssm, hybrid):
            yield cfg.family, ServeEngine(
                cfg, lm.init(cfg, jax.random.key(0)), n_slots=2,
                max_len=32)

    def test_ragged_slot_serve_is_exact(self):
        """The regression for the ROADMAP gap: length-bucketed admission
        means no pad token ever enters the recurrent state, so serving
        ragged prompts matches per-request generation token-for-token."""
        for family, eng in self._engines():
            reqs = [Request(prompt=p, max_new_tokens=4, rid=i)
                    for i, p in enumerate(self.PROMPTS)]
            comps = {c.rid: c for c in eng.serve(reqs)}
            for i, p in enumerate(self.PROMPTS):
                want = eng.generate([p], max_new_tokens=4)[0]
                assert comps[i].tokens == want, (family, i)

    def test_generate_ragged_batch_raises(self):
        """The error path: a ragged generate() batch cannot be served
        exactly in one recurrent prefill, so it must fail loudly."""
        for family, eng in self._engines():
            with pytest.raises(ValueError, match="recurrent|ragged"):
                eng.generate(self.PROMPTS, max_new_tokens=2)

    def test_generate_uniform_batch_ok(self):
        for family, eng in self._engines():
            out = eng.generate([[1, 2, 3], [4, 5, 6]], max_new_tokens=3)
            singles = [eng.generate([p], max_new_tokens=3)[0]
                       for p in ([1, 2, 3], [4, 5, 6])]
            assert out == singles, family


class TestStreamingPoll:
    """Token-level poll(stream=True): ordered StreamEvents per request,
    terminated by a done event carrying the completion; the plain poll()
    completion channel is unaffected."""

    def _engine(self):
        cfg = tiny_lm()
        return ServeEngine(cfg, lm.init(cfg, jax.random.key(0)),
                           n_slots=2, max_len=48)

    def test_token_events_ordered_and_match_completion(self):
        eng = self._engine()
        rid = eng.submit(Request(prompt=[1, 2, 3], max_new_tokens=5,
                                 stream=True))
        assert eng.poll(stream=True) == []      # nothing generated yet
        events = []
        while eng.tick():
            events += eng.poll(stream=True)
        events += eng.poll(stream=True)
        comps = eng.poll()                      # compat channel still works
        assert len(comps) == 1 and comps[0].rid == rid
        assert [e.seq for e in events] == list(range(len(events)))
        assert all(e.rid == rid for e in events)
        tokens = [e.item for e in events if not e.done]
        assert len(tokens) == 5                 # one event per new token
        assert events[-1].done and events[-1].item is None
        assert events[-1].completion.tokens == comps[0].tokens
        assert comps[0].tokens == [1, 2, 3] + tokens
        assert eng.poll(stream=True) == []      # drained

    def test_interleaved_streams_keep_per_rid_order(self):
        eng = self._engine()
        rids = [eng.submit(Request(prompt=p, max_new_tokens=3, stream=True))
                for p in ([1, 2], [3, 4, 5], [6])]
        comps = {c.rid: c for c in eng.run_until_idle()}
        per_rid = {r: [] for r in rids}
        for ev in eng.poll(stream=True):
            per_rid[ev.rid].append(ev)
        for r in rids:
            evs = per_rid[r]
            assert [e.seq for e in evs] == list(range(len(evs)))
            assert evs[-1].done
            toks = [e.item for e in evs if not e.done]
            assert comps[r].tokens[-len(toks):] == toks

    def test_non_streaming_request_emits_nothing(self):
        eng = self._engine()
        eng.serve([Request(prompt=[1, 2], max_new_tokens=3)])
        assert eng.poll(stream=True) == []

    def test_image_engine_streams_per_frame(self):
        eng = CapsuleEngine(deployed(), batch_size=2)
        req = ImageRequest(frames(3), stream=True)
        comp = eng.serve([req])[0]
        events = eng.poll(stream=True)
        assert [e.seq for e in events] == list(range(len(events)))
        assert events[-1].done and events[-1].completion.rid == comp.rid
        got = dict(e.item for e in events if not e.done)
        assert sorted(got) == [0, 1, 2]         # every frame streamed once
        for k, cls_id in got.items():
            assert cls_id == int(comp.classes[k])


class TestLatencyHistogram:
    def test_record_and_percentiles(self):
        h = LatencyHistogram()
        assert h.p50_ms == 0.0 and h.count == 0
        for ms in (1.0, 1.0, 1.0, 100.0):
            h.record(ms / 1e3)
        assert h.count == 4
        # p50 lands in the 1ms bucket (upper bound 1.6ms), p95 in 100ms's
        assert h.p50_ms == pytest.approx(1.6)
        assert 100.0 <= h.p95_ms <= 204.8
        assert h.p50_ms <= h.p95_ms
        assert h.mean_ms == pytest.approx((3 * 1.0 + 100.0) / 4)

    def test_copy_is_detached(self):
        h = LatencyHistogram()
        h.record(0.01)
        snap = h.copy()
        h.record(10.0)
        assert snap.count == 1 and h.count == 2

    def test_engine_histograms_monotone_per_class(self):
        cfg = tiny_lm()
        eng = ServeEngine(cfg, lm.init(cfg, jax.random.key(0)),
                          n_slots=2, max_len=48)
        eng.serve([Request(prompt=[1, 2, 3], max_new_tokens=2)])
        s1 = eng.stats()
        eng.serve([Request(prompt=[4, 5, 6], max_new_tokens=2),
                   Request(prompt=[7, 8], max_new_tokens=2)])
        s2 = eng.stats()
        # prompt lengths 3 -> class lm/p4, 2 -> lm/p2
        assert s1.latency["lm/p4"].count == 1
        assert s2.latency["lm/p4"].count == 2
        assert s2.latency["lm/p2"].count == 1
        for cls, h1 in s1.latency.items():
            h2 = s2.latency[cls]
            assert h2.count >= h1.count
            assert all(b >= a for a, b in zip(h1.counts, h2.counts))
        assert s2.latency_summary()["lm/p4"][0] == 2

    def test_stats_snapshot_is_detached(self):
        """stats() deep-copies the histograms: a held snapshot must not
        mutate as the engine keeps serving."""
        cfg = tiny_lm()
        eng = ServeEngine(cfg, lm.init(cfg, jax.random.key(0)),
                          n_slots=2, max_len=48)
        eng.serve([Request(prompt=[1, 2, 3], max_new_tokens=2)])
        snap = eng.stats()
        eng.serve([Request(prompt=[3, 2, 1], max_new_tokens=2)])
        assert snap.latency["lm/p4"].count == 1
        assert eng.stats().latency["lm/p4"].count == 2

    def test_capsule_engine_classes(self):
        eng = CapsuleEngine(deployed(), batch_size=4)
        eng.serve([ImageRequest(frames(1)), ImageRequest(frames(3, seed=1))])
        summary = eng.stats().latency_summary()
        assert set(summary) == {"image/f1", "image/f4"}


class TestInterleaving:
    """Prefill/decode tick separation: same results, decode ticks never
    admit, prefill ticks never step residents."""

    def test_phase_unit_logic(self):
        sched = InterleavingScheduler()
        sched.capacity = 4
        sched.inner.capacity = 4
        assert sched.phase(n_queued=2, n_active=1) == "prefill"
        assert sched.phase(n_queued=0, n_active=2) == "decode"
        assert sched.phase(n_queued=2, n_active=4) == "decode"  # no free slot

    def test_decode_ratio_throttles_admission(self):
        sched = InterleavingScheduler(decode_ratio=2)
        sched.capacity = 4
        sched.inner.capacity = 4
        sched.bind(type("C", (), {"capacity": 4})())
        assert sched.phase(2, 1) == "prefill"      # first tick may admit
        assert sched.phase(2, 2) == "decode"       # then 2 decode ticks
        assert sched.phase(2, 2) == "decode"
        assert sched.phase(2, 2) == "prefill"
        # an idle engine admits immediately — the ratio never starves it
        assert sched.phase(2, 0) == "prefill"

    def test_lm_results_match_mixed_ticks(self):
        cfg = tiny_lm()
        params = lm.init(cfg, jax.random.key(0))
        prompts = [[1, 2, 3], [5, 6, 7, 8, 9, 10, 11], [2, 4]]
        eng = ServeEngine(cfg, params, n_slots=2, max_len=48,
                          scheduler=InterleavingScheduler())
        ref = ServeEngine(cfg, params, n_slots=2, max_len=48)
        reqs = [Request(prompt=p, max_new_tokens=4, rid=i)
                for i, p in enumerate(prompts)]
        comps = {c.rid: c for c in eng.serve(reqs)}
        for i, p in enumerate(prompts):
            assert comps[i].tokens == ref.generate([p], max_new_tokens=4)[0]
        # dedicated prefill ticks: more ticks than the mixed engine needs
        ref_comps = {c.rid: c for c in ref.serve(
            [Request(prompt=p, max_new_tokens=4, rid=i)
             for i, p in enumerate(prompts)])}
        assert eng.stats().ticks > ref.stats().ticks
        assert comps[0].tokens == ref_comps[0].tokens

    def test_default_phase_is_mixed(self):
        from repro.serving import FIFOScheduler, Scheduler
        for sched in (Scheduler(), FIFOScheduler(),
                      SLOBatchScheduler(target_p95_ms=10.0)):
            assert sched.phase(3, 1) == "mixed"


def test_sharded_lm_decode_on_cpu_mesh():
    """The tentpole regression: ServeEngine under a ShardedScheduler on a
    2-device CPU mesh — KV caches sharded along the slot axis — generates
    exactly the same greedy tokens as per-request generation on the plain
    engine, for the dense and vlm families (subprocess: the test process
    is pinned to one device)."""
    script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import jax, numpy as np
from jax.sharding import NamedSharding
from repro.models import lm
from repro.models.common import LMConfig
from repro.launch.mesh import make_mesh
from repro.serving import Request, ServeEngine, ShardedScheduler

def tiny(family="dense", **kw):
    base = dict(arch_id="tiny-" + family, family=family, n_layers=2,
                d_model=32, n_heads=4, n_kv_heads=2, d_ff=64, vocab=64,
                remat=False, compute_dtype="float32",
                param_dtype="float32")
    base.update(kw)
    return LMConfig(**base)

PROMPTS = [[1, 2, 3], [5, 6, 7, 8, 9, 10, 11], [2, 4]]
for name, cfg in [("dense", tiny()),
                  ("vlm", tiny("vlm", n_layers=3, cross_attn_every=2,
                               n_image_tokens=8))]:
    params = lm.init(cfg, jax.random.key(0))
    mesh = make_mesh((2,), ("data",))
    sched = ShardedScheduler(mesh)
    assert sched.n_devices == 2
    eng = ServeEngine(cfg, params, n_slots=2, max_len=48, scheduler=sched)
    # the slot (batch) axis of the KV cache is really sharded
    leaf = jax.tree.leaves(eng._caches)[0]
    assert isinstance(leaf.sharding, NamedSharding)
    assert "data" in tuple(leaf.sharding.spec), leaf.sharding
    ref = ServeEngine(cfg, params, n_slots=2, max_len=48)
    reqs = [Request(prompt=p, max_new_tokens=4, rid=i)
            for i, p in enumerate(PROMPTS)]
    comps = {c.rid: c for c in eng.serve(reqs)}
    for i, p in enumerate(PROMPTS):
        want = ref.generate([p], max_new_tokens=4)[0]
        assert comps[i].tokens == want, (name, i, comps[i].tokens, want)
    print(name, "OK")

# capacity must divide over the mesh's batch devices
try:
    ServeEngine(tiny(), lm.init(tiny(), jax.random.key(0)), n_slots=3,
                max_len=48, scheduler=ShardedScheduler(
                    make_mesh((2,), ("data",))))
except ValueError as e:
    assert "divisible" in str(e), e
    print("DIVISIBILITY_OK")
print("SHARDED_LM_OK")
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, timeout=600)
    assert "SHARDED_LM_OK" in r.stdout, r.stdout + r.stderr
    assert "DIVISIBILITY_OK" in r.stdout, r.stdout + r.stderr


def test_sharded_scheduler_on_cpu_mesh():
    """ShardedScheduler splits tick batches over a 2-device CPU mesh
    (subprocess: the test process is pinned to one device)."""
    script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import jax, numpy as np
from repro.core import capsnet as cn
from repro.deploy import FastCapsPipeline
from repro.launch.mesh import make_mesh
from repro.serving import (CapsuleEngine, ImageRequest, ShardedScheduler,
                           SLOBatchScheduler)

cfg = cn.CapsNetConfig(conv1_channels=8, caps_types=2,
                       decoder_hidden=(16, 32))
dep = FastCapsPipeline(cfg).build(seed=0).compile(routing="optimized")
mesh = make_mesh((2,), ("data",))
# SLO inner -> power-of-two buckets, rounded up to device multiples
sched = ShardedScheduler(mesh, inner=SLOBatchScheduler(target_p95_ms=1e9))
assert sched.n_devices == 2
eng = CapsuleEngine(dep, batch_size=4, scheduler=sched)
assert sched.quantize(3, 4) == 4 and sched.quantize(1, 4) == 2
rng = np.random.RandomState(0)
reqs = [ImageRequest(rng.rand(n, 28, 28, 1).astype(np.float32), rid=i)
        for i, n in enumerate([3, 2])]
comps = {c.rid: c for c in eng.serve(reqs)}
for r in reqs:
    got = comps[r.rid].classes
    want = np.asarray(dep.classify(r.images))
    assert (got == want).all(), (got, want)
st = eng.stats()
assert st.frames == 5 and st.ticks == 2
print("SHARDED_SERVE_OK", st.frames)
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, timeout=600)
    assert "SHARDED_SERVE_OK" in r.stdout, r.stdout + r.stderr
