"""The unified ``repro.serving`` engine API: shared EngineCore surface,
async admission while ticking, SLO batch adaptation, sharded scheduling
on a multi-device CPU mesh, stats monotonicity, and the ragged-prefill
regression (slot serving == per-request generation)."""

import os
import subprocess
import sys
import threading

import jax
import numpy as np
import pytest

from repro.core import capsnet as cn
from repro.deploy import FastCapsPipeline
from repro.models import lm
from repro.models.common import LMConfig
from repro.serving import (CapsuleEngine, EngineCore, ImageRequest,
                           Request, ServeEngine, SLOBatchScheduler,
                           TickRecord)


def tiny_capsnet_cfg(**kw):
    base = dict(conv1_channels=16, caps_types=4, decoder_hidden=(32, 64))
    base.update(kw)
    return cn.CapsNetConfig(**base)


def deployed(**kw):
    pipe = FastCapsPipeline(tiny_capsnet_cfg(**kw)).build(seed=0)
    return pipe.compile(routing="optimized")


def tiny_lm(**kw):
    base = dict(arch_id="tiny", family="dense", n_layers=2, d_model=32,
                n_heads=4, n_kv_heads=2, d_ff=64, vocab=64, remat=False,
                compute_dtype="float32", param_dtype="float32")
    base.update(kw)
    return LMConfig(**base)


def frames(n, seed=0):
    return np.random.RandomState(seed).rand(n, 28, 28, 1).astype(np.float32)


class FakeClock:
    """Deterministic clock: each read advances by ``step`` seconds."""

    def __init__(self, step=0.01):
        self.t = 0.0
        self.step = step

    def __call__(self):
        self.t += self.step
        return self.t


class TestSharedSurface:
    def test_both_engines_are_engine_cores(self):
        caps = CapsuleEngine(deployed(), batch_size=4)
        cfg = tiny_lm()
        serve = ServeEngine(cfg, lm.init(cfg, jax.random.key(0)),
                            n_slots=2, max_len=32)
        for eng in (caps, serve):
            assert isinstance(eng, EngineCore)
            for name in ("submit", "poll", "run_until_idle", "stats",
                         "serve", "tick", "warmup"):
                assert callable(getattr(eng, name))

    def test_poll_is_incremental(self):
        eng = CapsuleEngine(deployed(), batch_size=4)
        eng.submit(ImageRequest(frames(2)))
        assert eng.poll() == []             # nothing ticked yet
        assert eng.tick() is True
        got = eng.poll()
        assert len(got) == 1
        assert eng.poll() == []             # drained
        assert eng.tick() is False          # idle


class TestAsyncAdmission:
    def test_submit_mid_tick_is_served(self):
        """A request submitted while a tick is in flight (from a callback
        fired inside the jitted forward wrapper) joins the next tick of
        the same run_until_idle call."""
        dep = deployed()
        eng = CapsuleEngine(dep, batch_size=2)
        late_rid = []

        class Hooked:
            cfg = dep.cfg

            def forward(self, x):
                if not late_rid:
                    late_rid.append(
                        eng.submit(ImageRequest(frames(1, seed=9))))
                return dep.forward(x)

        eng.deployed = Hooked()
        first = eng.submit(ImageRequest(frames(3)))
        comps = eng.run_until_idle()
        assert sorted(c.rid for c in comps) == sorted([first, late_rid[0]])

    def test_submit_from_other_thread(self):
        eng = CapsuleEngine(deployed(), batch_size=2)
        eng.submit(ImageRequest(frames(4)))

        def feeder():
            for i in range(3):
                eng.submit(ImageRequest(frames(1, seed=i + 1)))

        t = threading.Thread(target=feeder)
        t.start()
        comps = eng.run_until_idle()
        t.join()
        comps += eng.run_until_idle()       # anything that raced the drain
        assert len(comps) == 4
        assert eng.n_pending == 0


class TestSLOScheduler:
    def test_shrinks_under_impossible_target(self):
        """Every tick overshoots a 0ms target -> effective batch backs off
        to 1 (deterministic via the injected clock)."""
        sched = SLOBatchScheduler(target_p95_ms=0.0, window=4,
                                  min_samples=2)
        eng = CapsuleEngine(deployed(), batch_size=8, scheduler=sched,
                            clock=FakeClock(step=0.005))
        eng.serve([ImageRequest(frames(40))])
        assert sched.effective_batch == 1

    def test_grows_under_loose_target(self):
        """Ticks far below target -> effective batch doubles back up."""
        sched = SLOBatchScheduler(target_p95_ms=1e9, window=2,
                                  min_samples=2, initial_batch=1)
        eng = CapsuleEngine(deployed(), batch_size=4, scheduler=sched,
                            clock=FakeClock(step=0.001))
        eng.serve([ImageRequest(frames(24))])
        assert sched.effective_batch == 4

    def test_observe_unit_logic(self):
        """plan/observe contract without an engine: shrink on overshoot,
        grow only on a full under-target window."""
        sched = SLOBatchScheduler(target_p95_ms=10.0, window=4,
                                  min_samples=2)
        sched.capacity = 8
        sched._batch = 8
        for _ in range(2):
            sched.observe(TickRecord(8, 8, wall_s=0.05))   # 50ms > 10ms
        assert sched.effective_batch == 4
        for _ in range(4):
            sched.observe(TickRecord(4, 4, wall_s=0.001))  # 1ms << 10ms
        assert sched.effective_batch == 8

    def test_quantize_pow2(self):
        sched = SLOBatchScheduler(target_p95_ms=10.0)
        assert [sched.quantize(n, 8) for n in (1, 2, 3, 5, 8)] == \
            [1, 2, 4, 8, 8]

    def test_predictions_unchanged_by_slo_batching(self):
        dep = deployed()
        req = ImageRequest(frames(10))
        eng = CapsuleEngine(dep, batch_size=8,
                            scheduler=SLOBatchScheduler(target_p95_ms=0.0,
                                                        min_samples=1))
        comp = eng.serve([req])[0]
        np.testing.assert_array_equal(
            comp.classes, np.asarray(dep.classify(req.images)))


class TestStatsMonotone:
    def test_capsule_stats_monotone(self):
        eng = CapsuleEngine(deployed(), batch_size=4)
        eng.warmup()
        eng.serve([ImageRequest(frames(5))])
        s1 = eng.stats()
        eng.serve([ImageRequest(frames(3, seed=1))])
        s2 = eng.stats()
        assert s1.fps > 0
        assert (s2.items, s2.ticks, s2.completed) > \
            (s1.items, s1.ticks, s1.completed)
        assert s2.wall_s > s1.wall_s
        assert s2.padded >= s1.padded

    def test_lm_stats_monotone(self):
        cfg = tiny_lm()
        eng = ServeEngine(cfg, lm.init(cfg, jax.random.key(0)),
                          n_slots=2, max_len=32)
        eng.serve([Request(prompt=[1, 2], max_new_tokens=2)])
        s1 = eng.stats()
        eng.serve([Request(prompt=[3, 4, 5], max_new_tokens=3)])
        s2 = eng.stats()
        assert s1.items == 2 and s2.items == 5      # generated tokens
        assert s2.ticks > s1.ticks
        assert s2.wall_s > s1.wall_s
        assert s2.completed == 2


class TestRaggedLM:
    """The PR's ragged-prefill fix: per-slot prompt lengths and position
    ids must reproduce per-request generation exactly."""

    PROMPTS = [[1, 2, 3], [5, 6, 7, 8, 9, 10, 11], [2, 4]]

    def _engine(self, n_slots=2):
        cfg = tiny_lm()
        return ServeEngine(cfg, lm.init(cfg, jax.random.key(0)),
                           n_slots=n_slots, max_len=48)

    def test_ragged_generate_matches_per_request(self):
        eng = self._engine()
        batched = eng.generate(self.PROMPTS, max_new_tokens=5)
        single = [eng.generate([p], max_new_tokens=5)[0]
                  for p in self.PROMPTS]
        assert batched == single

    def test_slot_serve_matches_per_request_generation(self):
        """Continuous batching (3 ragged requests over 2 slots, admission
        mid-flight) produces the same greedy tokens as one-at-a-time."""
        eng = self._engine(n_slots=2)
        reqs = [Request(prompt=p, max_new_tokens=4, rid=i)
                for i, p in enumerate(self.PROMPTS)]
        comps = {c.rid: c for c in eng.serve(reqs)}
        for i, p in enumerate(self.PROMPTS):
            assert comps[i].tokens == eng.generate([p], max_new_tokens=4)[0]

    def test_generate_zero_new_tokens_is_identity(self):
        eng = self._engine()
        assert eng.generate(self.PROMPTS, max_new_tokens=0) == \
            [list(p) for p in self.PROMPTS]

    def test_serve_zero_new_tokens_is_identity(self):
        """submit/serve agrees with generate: max_new_tokens<=0 returns
        the prompt unchanged (prefill-free completion)."""
        eng = self._engine()
        comps = eng.serve([Request(prompt=[4, 5, 6], max_new_tokens=0)])
        assert comps[0].tokens == [4, 5, 6]

    def test_generate_overlong_prompt_rejected(self):
        eng = self._engine()
        with pytest.raises(ValueError, match="no room"):
            eng.generate([list(range(1, 50))], max_new_tokens=2)

    def test_sharded_scheduler_rejected_for_lm(self):
        import jax.numpy  # noqa: F401  (jax already imported)
        from repro.launch.mesh import make_mesh
        from repro.serving import ShardedScheduler

        cfg = tiny_lm()
        with pytest.raises(ValueError, match="image workload"):
            ServeEngine(cfg, lm.init(cfg, jax.random.key(0)), n_slots=2,
                        max_len=32,
                        scheduler=ShardedScheduler(make_mesh((1,),
                                                             ("data",))))

    def test_generate_per_slot_max_len_stop(self):
        """A slot hitting max_len stops alone; shorter prompts keep
        generating — batched still equals per-request."""
        cfg = tiny_lm()
        eng = ServeEngine(cfg, lm.init(cfg, jax.random.key(0)),
                          n_slots=2, max_len=16)
        prompts = [[1, 2], [3] * 14]
        batched = eng.generate(prompts, max_new_tokens=8)
        single = [eng.generate([p], max_new_tokens=8)[0] for p in prompts]
        assert batched == single
        assert len(batched[0]) == 2 + 8        # unaffected by the other slot

    def test_empty_and_overlong_prompts_rejected(self):
        eng = self._engine()
        with pytest.raises(ValueError, match="empty prompt"):
            eng.submit(Request(prompt=[]))
        with pytest.raises(ValueError, match="no room"):
            eng.submit(Request(prompt=list(range(1, 50))))


def test_sharded_scheduler_on_cpu_mesh():
    """ShardedScheduler splits tick batches over a 2-device CPU mesh
    (subprocess: the test process is pinned to one device)."""
    script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import jax, numpy as np
from repro.core import capsnet as cn
from repro.deploy import FastCapsPipeline
from repro.launch.mesh import make_mesh
from repro.serving import (CapsuleEngine, ImageRequest, ShardedScheduler,
                           SLOBatchScheduler)

cfg = cn.CapsNetConfig(conv1_channels=8, caps_types=2,
                       decoder_hidden=(16, 32))
dep = FastCapsPipeline(cfg).build(seed=0).compile(routing="optimized")
mesh = make_mesh((2,), ("data",))
# SLO inner -> power-of-two buckets, rounded up to device multiples
sched = ShardedScheduler(mesh, inner=SLOBatchScheduler(target_p95_ms=1e9))
assert sched.n_devices == 2
eng = CapsuleEngine(dep, batch_size=4, scheduler=sched)
assert sched.quantize(3, 4) == 4 and sched.quantize(1, 4) == 2
rng = np.random.RandomState(0)
reqs = [ImageRequest(rng.rand(n, 28, 28, 1).astype(np.float32), rid=i)
        for i, n in enumerate([3, 2])]
comps = {c.rid: c for c in eng.serve(reqs)}
for r in reqs:
    got = comps[r.rid].classes
    want = np.asarray(dep.classify(r.images))
    assert (got == want).all(), (got, want)
st = eng.stats()
assert st.frames == 5 and st.ticks == 2
print("SHARDED_SERVE_OK", st.frames)
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, timeout=600)
    assert "SHARDED_SERVE_OK" in r.stdout, r.stdout + r.stderr
