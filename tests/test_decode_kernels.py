"""Decode-exactness conformance for the decode-path kernels.

The serving decode tick can now run through two Pallas kernels —
``decode_attention`` (q_len=1 GQA attention reading the dense slot
caches or the paged pool in place) and ``fused_sampling`` (temperature /
top-k / top-p / categorical draw fused on device).  Kernels are only
allowed to *relocate* the computation, never change it, so this file is
the gate:

* greedy conformance — serving with ``decode_kernel=True`` is
  bit-identical to per-request ``generate()`` AND to the pre-kernel
  chunked decode path, across {dense, paged, int8-paged} caches x
  {dense, vlm, moe} families x schedulers (int8 pages on the documented
  tiny fixture, where quantization does not flip the argmax);
* kernel properties — ``decode_attention`` matches its jnp oracle over
  randomized ragged ``kv_valid_len`` and shuffled/sentinel page tables
  (hypothesis where installed, via ``hypothesis_compat``; the same
  harness runs fixed deterministic cases everywhere);
* seeded sampling — counter-based draws are keyed by (seed, sequence
  position), so temperature>0 decodes are reproducible and invariant
  to batch composition, slot assignment, priority preemption /
  re-injection, and the disaggregated handoff boundary (all three
  transports); a chi-square check keeps ``fused_sampling``'s empirical
  distribution honest against the softmax law and numpy's categorical;
* the forced-2-device acceptance run: kernel-path paged decode on a
  sharded mesh stays bit-exact (the CI serving-conformance lane).
"""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from hypothesis_compat import given, settings, st
from repro import kernels
from repro.kernels.attention.ref import decode_attention_ref
from repro.models import lm
from repro.models.attention import quantize_kv_rows
from repro.models.common import LMConfig, MoEConfig
from repro.serving import (FIFOScheduler, InterleavingScheduler,
                           PriorityScheduler, Request, ServeEngine,
                           disaggregated_lm_engine)

TRANSPORTS = ["in_process", "host_staged", "device_to_device"]
PROMPTS = [[1, 2, 3], [5, 6, 7, 8, 9, 10, 11], [2, 4]]
PAGE = 8
MAX_LEN = 32
MAX_NEW = 4

CACHE_MODES = {
    "dense": {},
    "paged": dict(page_size=PAGE),
    "paged_int8": dict(page_size=PAGE, quantize_pages=True),
}


def tiny(family="dense", **kw):
    base = dict(arch_id="tiny-" + family, family=family, n_layers=2,
                d_model=32, n_heads=4, n_kv_heads=2, d_ff=64, vocab=64,
                remat=False, compute_dtype="float32",
                param_dtype="float32")
    base.update(kw)
    return LMConfig(**base)


def cfg_for(family):
    if family == "dense":
        return tiny()
    if family == "vlm":
        return tiny("vlm", n_layers=3, cross_attn_every=2,
                    n_image_tokens=8)
    if family == "moe":
        return tiny("moe", moe=MoEConfig(n_experts=4, top_k=2,
                                         d_expert=32))
    raise ValueError(family)


_PARAMS = {}


def params_for(family):
    if family not in _PARAMS:
        _PARAMS[family] = lm.init(cfg_for(family), jax.random.key(0))
    return _PARAMS[family]


def serve_tokens(eng, prompts=PROMPTS, max_new=MAX_NEW, **req_kw):
    comps = eng.serve([Request(prompt=p, max_new_tokens=max_new, rid=i,
                               **req_kw)
                       for i, p in enumerate(prompts)])
    return {c.rid: list(c.tokens) for c in comps}


# ---------------------------------------------------------------------------
# greedy conformance: kernel decode == generate() == chunked decode
# ---------------------------------------------------------------------------


class TestGreedyConformance:
    @pytest.mark.parametrize("family", ["dense", "vlm", "moe"])
    @pytest.mark.parametrize("cache", sorted(CACHE_MODES))
    def test_kernel_matches_generate_and_chunked(self, family, cache):
        """decode_kernel=True serving is bit-identical to per-request
        generate() and to the pre-kernel chunked decode path.  int8
        pages ride the documented tiny fixture where quantization does
        not flip the greedy argmax (same contract as
        test_disagg_paged.py)."""
        cfg, params = cfg_for(family), params_for(family)
        pk = CACHE_MODES[cache]
        kern = ServeEngine(cfg, params, n_slots=2, max_len=MAX_LEN,
                           decode_kernel=True, **pk)
        got = serve_tokens(kern)
        ref = ServeEngine(cfg, params, n_slots=2, max_len=MAX_LEN)
        for i, p in enumerate(PROMPTS):
            want = ref.generate([p], max_new_tokens=MAX_NEW)[0]
            assert got[i] == want, (family, cache, i)
        chunked = ServeEngine(cfg, params, n_slots=2, max_len=MAX_LEN,
                              **pk)
        assert serve_tokens(chunked) == got, (family, cache)

    @pytest.mark.parametrize("sched", ["fifo", "priority", "interleave"])
    def test_kernel_exact_under_schedulers(self, sched):
        """The kernel decode tick is scheduler-agnostic: whatever
        batches the scheduler composes, greedy tokens match
        generate()."""
        mk = {"fifo": FIFOScheduler, "priority": PriorityScheduler,
              "interleave": lambda: InterleavingScheduler(decode_ratio=1),
              }[sched]
        cfg, params = cfg_for("dense"), params_for("dense")
        eng = ServeEngine(cfg, params, n_slots=2, max_len=MAX_LEN,
                          page_size=PAGE, decode_kernel=True,
                          scheduler=mk())
        got = serve_tokens(eng)
        ref = ServeEngine(cfg, params, n_slots=2, max_len=MAX_LEN)
        for i, p in enumerate(PROMPTS):
            assert got[i] == ref.generate([p], max_new_tokens=MAX_NEW)[0], \
                (sched, i)


# ---------------------------------------------------------------------------
# kernel properties: decode_attention vs oracle over ragged/paged state
# ---------------------------------------------------------------------------


def check_paged_decode_case(seed, valid_lens, shuffle_seed, quantized):
    """One randomized paged-decode case: build a shuffled page
    assignment (resident pages permuted across the pool, tail table
    entries left as -1 sentinels), run the Pallas kernel against the
    jnp oracle, and require allclose.  Shared by the hypothesis
    property and the deterministic smoke cases."""
    b = len(valid_lens)
    nkv, h, d, page = 2, 4, 8, 4
    p_per = 4                                   # pages per slot
    n_pages = b * p_per
    max_len = p_per * page
    rng = np.random.RandomState(seed)
    q = jnp.asarray(rng.randn(b, 1, h, d), jnp.float32)
    k = jnp.asarray(rng.randn(n_pages, page, nkv, d), jnp.float32)
    v = jnp.asarray(rng.randn(n_pages, page, nkv, d), jnp.float32)
    valid = jnp.asarray([min(n, max_len) for n in valid_lens], jnp.int32)

    # shuffled assignment: every slot owns p_per distinct pool pages,
    # but only the resident prefix is bound — the tail stays -1
    perm = np.random.RandomState(shuffle_seed).permutation(n_pages)
    tables = np.full((b, p_per), -1, np.int64)
    for i in range(b):
        n_resident = -(-int(valid[i]) // page)      # ceil
        own = perm[i * p_per:(i + 1) * p_per]
        tables[i, :n_resident] = own[:n_resident]
    # the engine pre-clips sentinel entries into the valid page range
    # (kv_valid_len masks whatever the clipped entries alias)
    clipped = jnp.asarray(np.clip(tables, 0, n_pages - 1), jnp.int32)

    if quantized:
        kq, ks = quantize_kv_rows(k.reshape(1, -1, nkv, d))
        vq, vs = quantize_kv_rows(v.reshape(1, -1, nkv, d))
        kq = kq.reshape(n_pages, page, nkv, d)
        vq = vq.reshape(n_pages, page, nkv, d)
        ks = ks.reshape(n_pages, page)
        vs = vs.reshape(n_pages, page)
        got = kernels.decode_attention(q, kq, vq, valid, tables=clipped,
                                       ks=ks, vs=vs, tune=False)
        want = decode_attention_ref(q, kq, vq, valid, tables=clipped,
                                    ks=ks, vs=vs)
    else:
        got = kernels.decode_attention(q, k, v, valid, tables=clipped,
                                       tune=False)
        want = decode_attention_ref(q, k, v, valid, tables=clipped)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)

    # a slot's output must not depend on how OTHER slots' tails alias
    # after clipping: re-clip the sentinels to a different page and
    # the result is unchanged
    clipped2 = jnp.asarray(np.where(tables < 0, (tables + 7) % n_pages,
                                    tables), jnp.int32)
    got2 = kernels.decode_attention(q, (kq if quantized else k),
                                    (vq if quantized else v), valid,
                                    tables=clipped2,
                                    ks=(ks if quantized else None),
                                    vs=(vs if quantized else None),
                                    tune=False)
    np.testing.assert_allclose(np.asarray(got2), np.asarray(got),
                               atol=2e-5, rtol=2e-5)


def check_dense_decode_case(seed, valid_lens, quantized):
    """Dense-cache variant of the same oracle check."""
    b = len(valid_lens)
    nkv, h, d, t = 2, 4, 8, 16
    rng = np.random.RandomState(seed)
    q = jnp.asarray(rng.randn(b, 1, h, d), jnp.float32)
    k = jnp.asarray(rng.randn(b, t, nkv, d), jnp.float32)
    v = jnp.asarray(rng.randn(b, t, nkv, d), jnp.float32)
    valid = jnp.asarray([min(n, t) for n in valid_lens], jnp.int32)
    if quantized:
        kq, ks = quantize_kv_rows(k)
        vq, vs = quantize_kv_rows(v)
        got = kernels.decode_attention(q, kq, vq, valid, ks=ks, vs=vs,
                                       tune=False)
        want = decode_attention_ref(q, kq, vq, valid, ks=ks, vs=vs)
    else:
        got = kernels.decode_attention(q, k, v, valid, tune=False)
        want = decode_attention_ref(q, k, v, valid)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


class TestDecodeAttentionProperties:
    @settings(max_examples=25, deadline=None)
    @given(st.integers(min_value=0, max_value=10_000),
           st.lists(st.integers(min_value=0, max_value=16), min_size=1,
                    max_size=4),
           st.integers(min_value=0, max_value=10_000),
           st.booleans())
    def test_paged_matches_oracle(self, seed, valid_lens, shuffle_seed,
                                  quantized):
        check_paged_decode_case(seed, valid_lens, shuffle_seed, quantized)

    @settings(max_examples=25, deadline=None)
    @given(st.integers(min_value=0, max_value=10_000),
           st.lists(st.integers(min_value=0, max_value=16), min_size=1,
                    max_size=4),
           st.booleans())
    def test_dense_matches_oracle(self, seed, valid_lens, quantized):
        check_dense_decode_case(seed, valid_lens, quantized)

    def test_deterministic_smoke(self):
        """The same harnesses on fixed cases, so the oracle contract is
        exercised even where hypothesis is absent: ragged lengths, an
        empty slot (valid=0), full slots, shuffled tables, quantized
        pools."""
        check_paged_decode_case(0, [16, 3], 1, quantized=False)
        check_paged_decode_case(1, [0, 16, 7], 2, quantized=False)
        check_paged_decode_case(2, [5, 9], 3, quantized=True)
        check_paged_decode_case(3, [1], 4, quantized=True)
        check_dense_decode_case(0, [16, 3, 0], quantized=False)
        check_dense_decode_case(1, [7, 16], quantized=True)


# ---------------------------------------------------------------------------
# seeded sampling: reproducible, schedule-invariant, distribution-honest
# ---------------------------------------------------------------------------


SAMPLING_KW = dict(temperature=0.8, top_k=8, top_p=0.95)


def seeded_tokens(eng, prompts=PROMPTS, **kw):
    req_kw = dict(SAMPLING_KW)
    req_kw.update(kw)
    comps = eng.serve([Request(prompt=p, max_new_tokens=MAX_NEW, rid=i,
                               seed=1000 + i, **req_kw)
                       for i, p in enumerate(prompts)])
    return {c.rid: list(c.tokens) for c in comps}


class TestSeededSampling:
    @pytest.mark.parametrize("decode_kernel", [False, True])
    def test_reproducible_and_batch_invariant(self, decode_kernel):
        """Same per-request seeds => same temperature>0 sequences, no
        matter how requests are batched together (all at once vs one at
        a time) or how many slots the engine runs — the sampling
        counter is the token's sequence position, not anything the
        scheduler decides."""
        cfg, params = cfg_for("dense"), params_for("dense")

        def mk(n_slots):
            return ServeEngine(cfg, params, n_slots=n_slots,
                               max_len=MAX_LEN, page_size=PAGE,
                               decode_kernel=decode_kernel)

        together = seeded_tokens(mk(2))
        assert seeded_tokens(mk(2)) == together          # reproducible
        assert seeded_tokens(mk(3)) == together          # slot-mix
        solo = {}
        for i, p in enumerate(PROMPTS):                  # batch-of-one
            eng = mk(2)
            [c] = eng.serve([Request(prompt=p, max_new_tokens=MAX_NEW,
                                     rid=i, seed=1000 + i, **SAMPLING_KW)])
            solo[i] = list(c.tokens)
        assert solo == together
        # and the draws are genuinely non-greedy on this fixture
        greedy = serve_tokens(mk(2))
        assert together != greedy

    def test_generate_seeded_reproducible(self):
        cfg, params = cfg_for("dense"), params_for("dense")
        eng = ServeEngine(cfg, params, n_slots=2, max_len=MAX_LEN)
        a = eng.generate(PROMPTS, max_new_tokens=MAX_NEW, seed=7,
                         **SAMPLING_KW)
        b = eng.generate(PROMPTS, max_new_tokens=MAX_NEW, seed=7,
                         **SAMPLING_KW)
        assert a == b
        c = eng.generate(PROMPTS, max_new_tokens=MAX_NEW, seed=8,
                         **SAMPLING_KW)
        assert a != c

    @pytest.mark.parametrize("decode_kernel", [False, True])
    def test_preemption_does_not_change_draws(self, decode_kernel):
        """Priority preemption evicts a mid-decode request and resumes
        it later in some other slot at some later tick — the
        position-keyed counter means its remaining draws are the ones
        it would have made undisturbed."""
        cfg, params = cfg_for("dense"), params_for("dense")

        def mk():
            return ServeEngine(cfg, params, n_slots=1, max_len=MAX_LEN,
                               page_size=PAGE, decode_kernel=decode_kernel,
                               scheduler=PriorityScheduler())

        victim = Request(prompt=[1, 2, 3], max_new_tokens=8, rid=0,
                         seed=42, priority=1, **SAMPLING_KW)
        # undisturbed run
        eng = mk()
        [c] = eng.serve([dataclass_copy(victim)])
        want = list(c.tokens)
        # preempted run: the victim decodes alone, then a more urgent
        # request arrives and takes the only slot
        eng = mk()
        eng.submit(dataclass_copy(victim))
        eng.tick()
        eng.tick()
        eng.submit(Request(prompt=[9, 9], max_new_tokens=2, rid=1,
                           seed=43, priority=0, **SAMPLING_KW))
        comps = {c.rid: list(c.tokens) for c in eng.run_until_idle()}
        assert eng.stats().preempted >= 1
        assert comps[0] == want

    @pytest.mark.parametrize("transport", TRANSPORTS)
    def test_disagg_handoff_preserves_draws(self, transport):
        """Temperature>0 serving across the prefill->decode handoff
        matches the monolithic engine under every transport: the seed
        and sampling knobs travel as typed CacheHandoff fields."""
        cfg, params = cfg_for("dense"), params_for("dense")
        mono = ServeEngine(cfg, params, n_slots=2, max_len=MAX_LEN)
        eng = disaggregated_lm_engine(cfg, params, n_slots=2,
                                      max_len=MAX_LEN, n_decode=2,
                                      transport=transport)
        assert seeded_tokens(eng) == seeded_tokens(mono), transport

    def test_disagg_kernel_mode_matches_monolith(self):
        """decode_kernel=True on both sides of the paged handoff: the
        device-sampled decode draws match the kernel-mode monolith."""
        cfg, params = cfg_for("dense"), params_for("dense")
        mono = ServeEngine(cfg, params, n_slots=2, max_len=MAX_LEN,
                           page_size=PAGE, decode_kernel=True)
        eng = disaggregated_lm_engine(cfg, params, n_slots=2,
                                      max_len=MAX_LEN, n_decode=2,
                                      page_size=PAGE, decode_kernel=True)
        assert seeded_tokens(eng) == seeded_tokens(mono)

    def test_fused_sampling_chi_square(self):
        """Distribution sanity: over many (seed, pos) counters on one
        fixed logits row, fused_sampling's empirical distribution must
        fit the softmax law about as well as numpy's own categorical
        sampler — chi-square statistic under a generous critical value
        (df=7; 45 is far beyond the 1e-6 tail)."""
        vocab, n = 8, 2048
        rng = np.random.RandomState(0)
        row = rng.randn(vocab).astype(np.float32) * 1.5
        probs = np.exp(row - row.max())
        probs /= probs.sum()

        logits = jnp.asarray(np.tile(row, (n, 1)))
        toks = np.asarray(kernels.fused_sampling(
            logits, jnp.ones((n,), jnp.float32),
            jnp.arange(n, dtype=jnp.int32),
            jnp.zeros((n,), jnp.int32), tune=False))
        np_toks = rng.choice(vocab, size=n, p=probs)

        def chi2(samples):
            obs = np.bincount(samples, minlength=vocab)
            exp = probs * n
            return float(((obs - exp) ** 2 / exp).sum())

        assert chi2(toks) < 45.0, chi2(toks)
        assert chi2(np_toks) < 45.0, chi2(np_toks)
        # same counter twice => same draw (determinism, not an RNG)
        toks2 = np.asarray(kernels.fused_sampling(
            logits, jnp.ones((n,), jnp.float32),
            jnp.arange(n, dtype=jnp.int32),
            jnp.zeros((n,), jnp.int32), tune=False))
        assert (toks == toks2).all()

    def test_fused_sampling_slot_order_invariant(self):
        """Permuting the rows of one sampling launch permutes the drawn
        tokens identically — nothing in the kernel couples a draw to
        its slot index."""
        b, vocab = 8, 16
        rng = np.random.RandomState(3)
        logits = jnp.asarray(rng.randn(b, vocab), jnp.float32)
        temp = jnp.asarray(rng.uniform(0.5, 1.5, b), jnp.float32)
        seeds = jnp.asarray(rng.randint(0, 2**31, b), jnp.int32)
        pos = jnp.asarray(rng.randint(0, 64, b), jnp.int32)
        base = np.asarray(kernels.fused_sampling(logits, temp, seeds, pos,
                                                 tune=False))
        perm = np.random.RandomState(4).permutation(b)
        got = np.asarray(kernels.fused_sampling(
            logits[perm], temp[perm], seeds[perm], pos[perm], tune=False))
        assert (got == base[perm]).all()

    def test_greedy_is_plain_argmax(self):
        """temperature<=0 must stay the bit-exact raw argmax — no
        masking, no perturbation."""
        rng = np.random.RandomState(5)
        logits = jnp.asarray(rng.randn(4, 32), jnp.float32)
        toks = np.asarray(kernels.fused_sampling(
            logits, jnp.zeros((4,), jnp.float32),
            jnp.arange(4, dtype=jnp.int32),
            jnp.arange(4, dtype=jnp.int32), tune=False))
        assert (toks == np.asarray(logits).argmax(-1)).all()


def dataclass_copy(req):
    import dataclasses

    return dataclasses.replace(req)


# ---------------------------------------------------------------------------
# forced-2-device acceptance: sharded kernel-path paged decode
# ---------------------------------------------------------------------------


def test_decode_kernel_sharded_on_2device_cpu_mesh():
    """Kernel-path paged decode with a ShardedScheduler mesh on a
    forced 2-device host stays bit-exact vs generate(), greedy and
    seeded (subprocess: the test process is pinned to one device)."""
    script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import jax
from repro.models import lm
from repro.models.common import LMConfig
from repro.launch.mesh import make_mesh
from repro.serving import Request, ServeEngine, ShardedScheduler

cfg = LMConfig(arch_id="tiny-dense", family="dense", n_layers=2,
               d_model=32, n_heads=4, n_kv_heads=2, d_ff=64, vocab=64,
               remat=False, compute_dtype="float32",
               param_dtype="float32")
params = lm.init(cfg, jax.random.key(0))
PROMPTS = [[1, 2, 3], [5, 6, 7, 8, 9, 10, 11], [2, 4]]
sched = ShardedScheduler(make_mesh((2,), ("data",)))
eng = ServeEngine(cfg, params, n_slots=2, max_len=32, page_size=8,
                  decode_kernel=True, scheduler=sched)
ref = ServeEngine(cfg, params, n_slots=2, max_len=32)
comps = {c.rid: c for c in eng.serve(
    [Request(prompt=p, max_new_tokens=3, rid=i)
     for i, p in enumerate(PROMPTS)])}
for i, p in enumerate(PROMPTS):
    want = ref.generate([p], max_new_tokens=3)[0]
    assert comps[i].tokens == want, (i, comps[i].tokens, want)
eng2 = ServeEngine(cfg, params, n_slots=2, max_len=32, page_size=8,
                   decode_kernel=True,
                   scheduler=ShardedScheduler(make_mesh((2,), ("data",))))
seeded = {c.rid: c.tokens for c in eng2.serve(
    [Request(prompt=p, max_new_tokens=3, rid=i, seed=100 + i,
             temperature=0.8, top_k=8) for i, p in enumerate(PROMPTS)])}
eng3 = ServeEngine(cfg, params, n_slots=2, max_len=32, page_size=8,
                   decode_kernel=True)
again = {c.rid: c.tokens for c in eng3.serve(
    [Request(prompt=p, max_new_tokens=3, rid=i, seed=100 + i,
             temperature=0.8, top_k=8) for i, p in enumerate(PROMPTS)])}
assert seeded == again, (seeded, again)
print("DECODE_KERNEL_SHARDED_OK")
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..",
                                     "src")
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, timeout=600)
    assert "DECODE_KERNEL_SHARDED_OK" in r.stdout, r.stdout + r.stderr


if __name__ == "__main__":
    sys.exit(pytest.main([__file__, "-q"]))
