"""SSM blocks: chunk-size invariance + chunked-vs-recurrent equivalence."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import mamba2, xlstm
from repro.models.common import LMConfig, SSMConfig, XLSTMConfig


def mamba_cfg(chunk=8):
    return LMConfig(arch_id="m", family="hybrid", n_layers=1, d_model=16,
                    n_heads=2, n_kv_heads=2, d_ff=32, vocab=32,
                    compute_dtype="float32", param_dtype="float32",
                    ssm=SSMConfig(d_state=4, d_conv=4, expand=2, head_dim=8,
                                  n_groups=1, chunk_size=chunk))


def xlstm_cfg(chunk=8):
    return LMConfig(arch_id="x", family="ssm", n_layers=2, d_model=16,
                    n_heads=2, n_kv_heads=2, d_ff=0, vocab=32,
                    compute_dtype="float32", param_dtype="float32",
                    xlstm=XLSTMConfig(slstm_every=2, chunk_size=chunk))


class TestMamba2:
    def test_chunk_size_invariance(self):
        """The SSD output must not depend on the chunk size."""
        from repro.models.common import init_params
        outs = []
        for chunk in (4, 8, 16, 32):
            cfg = mamba_cfg(chunk)
            params = init_params(mamba2.mamba2_defs(cfg),
                                 jax.random.key(0), jnp.float32)
            x = jax.random.normal(jax.random.key(1), (2, 32, 16))
            y, _ = mamba2.mamba2_apply(params, cfg, x)
            outs.append(np.asarray(y))
        for o in outs[1:]:
            np.testing.assert_allclose(outs[0], o, atol=1e-4, rtol=1e-4)

    def test_chunked_equals_stepwise_decode(self):
        """Processing a sequence chunked == feeding tokens one at a time
        through the recurrent state (the long_500k decode path)."""
        from repro.models.common import init_params
        cfg = mamba_cfg(8)
        params = init_params(mamba2.mamba2_defs(cfg), jax.random.key(0),
                             jnp.float32)
        b, s = 2, 16
        x = jax.random.normal(jax.random.key(1), (b, s, 16))
        state = mamba2.mamba2_init_state(cfg, b)
        y_full, _ = mamba2.mamba2_apply(params, cfg, x,
                                        mamba2.mamba2_init_state(cfg, b))
        ys = []
        for t in range(s):
            y_t, state = mamba2.mamba2_apply(params, cfg, x[:, t:t + 1],
                                             state)
            ys.append(y_t)
        y_step = jnp.concatenate(ys, axis=1)
        np.testing.assert_allclose(np.asarray(y_full), np.asarray(y_step),
                                   atol=1e-3, rtol=1e-3)

    def test_state_carries_context(self):
        """Same token, different histories -> different outputs."""
        from repro.models.common import init_params
        cfg = mamba_cfg()
        params = init_params(mamba2.mamba2_defs(cfg), jax.random.key(0),
                             jnp.float32)
        x1 = jax.random.normal(jax.random.key(1), (1, 8, 16))
        x2 = jax.random.normal(jax.random.key(2), (1, 8, 16))
        tok = jax.random.normal(jax.random.key(3), (1, 1, 16))
        _, s1 = mamba2.mamba2_apply(params, cfg, x1,
                                    mamba2.mamba2_init_state(cfg, 1))
        _, s2 = mamba2.mamba2_apply(params, cfg, x2,
                                    mamba2.mamba2_init_state(cfg, 1))
        y1, _ = mamba2.mamba2_apply(params, cfg, tok, s1)
        y2, _ = mamba2.mamba2_apply(params, cfg, tok, s2)
        assert float(jnp.max(jnp.abs(y1 - y2))) > 1e-5


class TestMLSTM:
    def test_chunk_size_invariance(self):
        from repro.models.common import init_params
        outs = []
        for chunk in (4, 8, 32):
            cfg = xlstm_cfg(chunk)
            params = init_params(xlstm.mlstm_defs(cfg), jax.random.key(0),
                                 jnp.float32)
            x = jax.random.normal(jax.random.key(1), (2, 32, 16))
            y, _ = xlstm.mlstm_apply(params, cfg, x)
            outs.append(np.asarray(y))
        for o in outs[1:]:
            np.testing.assert_allclose(outs[0], o, atol=1e-4, rtol=1e-4)

    def test_chunked_equals_stepwise_decode(self):
        from repro.models.common import init_params
        cfg = xlstm_cfg(4)
        params = init_params(xlstm.mlstm_defs(cfg), jax.random.key(0),
                             jnp.float32)
        b, s = 1, 12
        x = jax.random.normal(jax.random.key(1), (b, s, 16))
        zeros = jax.tree.map(
            lambda sd: jnp.zeros(sd.shape, sd.dtype),
            xlstm.mlstm_state_defs(cfg, b))
        zeros["m"] = jnp.full_like(zeros["m"], -jnp.inf)
        y_full, _ = xlstm.mlstm_apply(params, cfg, x, dict(zeros))
        state = dict(zeros)
        ys = []
        for t in range(s):
            y_t, state = xlstm.mlstm_apply(params, cfg, x[:, t:t + 1],
                                           state)
            ys.append(y_t)
        y_step = jnp.concatenate(ys, axis=1)
        np.testing.assert_allclose(np.asarray(y_full), np.asarray(y_step),
                                   atol=1e-3, rtol=1e-3)


class TestSLSTM:
    def test_state_continuation(self):
        """Splitting a sequence across two calls == one call."""
        from repro.models.common import init_params
        cfg = xlstm_cfg()
        params = init_params(xlstm.slstm_defs(cfg), jax.random.key(0),
                             jnp.float32)
        b, s = 2, 16
        x = jax.random.normal(jax.random.key(1), (b, s, 16))
        zeros = jax.tree.map(lambda sd: jnp.zeros(sd.shape, sd.dtype),
                             xlstm.slstm_state_defs(cfg, b))
        zeros["m"] = jnp.full_like(zeros["m"], -1e30)
        y_full, _ = xlstm.slstm_apply(params, cfg, x, dict(zeros))
        y1, st = xlstm.slstm_apply(params, cfg, x[:, :8], dict(zeros))
        y2, _ = xlstm.slstm_apply(params, cfg, x[:, 8:], st)
        y_split = jnp.concatenate([y1, y2], axis=1)
        np.testing.assert_allclose(np.asarray(y_full), np.asarray(y_split),
                                   atol=1e-4, rtol=1e-4)
