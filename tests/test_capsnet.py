"""CapsNet system tests: shapes, learning, prune pipeline, compaction."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import capsnet as cn
from repro.core import pruning as pr
from repro.data import synthetic_digits as sd
from repro.deploy import FastCapsPipeline, RoutingSpec


def tiny_cfg(**kw):
    base = dict(conv1_channels=16, caps_types=4, decoder_hidden=(32, 64))
    base.update(kw)
    return cn.CapsNetConfig(**base)


class TestShapes:
    def test_paper_dimensions(self):
        """Fig. 3: 1152 primary capsules on 28x28 MNIST, 6x6 spatial."""
        cfg = cn.CapsNetConfig()
        assert cfg.conv1_out_hw == 20
        assert cfg.caps_out_hw == 6
        assert cfg.n_primary_caps == 1152
        assert cfg.primary_conv_channels == 256

    def test_forward_shapes_and_finite(self):
        cfg = tiny_cfg()
        params = cn.init(cfg, jax.random.key(0))
        imgs = jax.random.uniform(jax.random.key(1), (3, 28, 28, 1))
        lengths, v = cn.forward(params, cfg, imgs)
        assert lengths.shape == (3, 10)
        assert v.shape == (3, 10, 16)
        assert bool(jnp.all(jnp.isfinite(lengths)))

    @pytest.mark.parametrize("mode", ["reference", "optimized", "pallas"])
    def test_routing_modes_agree(self, mode):
        cfg_ref = tiny_cfg(routing=RoutingSpec.reference())
        cfg_m = tiny_cfg(routing=RoutingSpec(mode=mode))   # exact softmax
        params = cn.init(cfg_ref, jax.random.key(0))
        imgs = jax.random.uniform(jax.random.key(1), (2, 28, 28, 1))
        l_ref, _ = cn.forward(params, cfg_ref, imgs)
        l_m, _ = cn.forward(params, cfg_m, imgs)
        np.testing.assert_allclose(np.asarray(l_ref), np.asarray(l_m),
                                   atol=1e-4)

    def test_taylor_softmax_mode_close(self):
        """Paper claim: optimized nonlinearities don't change predictions."""
        cfg_e = tiny_cfg(routing=RoutingSpec.optimized(softmax="exact"))
        cfg_t = tiny_cfg(routing=RoutingSpec.optimized(
            softmax="taylor", div_exp_log=True))
        params = cn.init(cfg_e, jax.random.key(0))
        imgs = jax.random.uniform(jax.random.key(1), (4, 28, 28, 1))
        l_e, _ = cn.forward(params, cfg_e, imgs)
        l_t, _ = cn.forward(params, cfg_t, imgs)
        assert (jnp.argmax(l_e, -1) == jnp.argmax(l_t, -1)).all()


class TestLoss:
    def test_margin_loss_zero_when_perfect(self):
        cfg = tiny_cfg()
        lengths = jnp.full((2, 10), 0.05).at[0, 3].set(0.95).at[1, 7].set(
            0.95)
        loss = cn.margin_loss(lengths, jnp.array([3, 7]), cfg)
        assert float(loss) < 1e-6

    def test_loss_decreases_with_training(self):
        cfg = tiny_cfg()
        params = cn.init(cfg, jax.random.key(0))
        data = sd.load(sd.DigitsConfig(n_train=64, n_test=16))
        x, y = jnp.asarray(data["train"][0][:16]), jnp.asarray(
            data["train"][1][:16])

        @jax.jit
        def step(p):
            (l, m), g = jax.value_and_grad(cn.loss_fn, has_aux=True)(
                p, cfg, x, y)
            return jax.tree.map(lambda a, b: a - 0.02 * b, p, g), l

        losses = []
        for _ in range(12):
            params, l = step(params)
            losses.append(float(l))
        assert losses[-1] < losses[0]


class TestPrunePipeline:
    def test_masked_equals_compacted(self):
        """Fig. 6 step: masked-dense forward == compacted forward."""
        cfg = tiny_cfg()
        params = cn.init(cfg, jax.random.key(0))
        masks = cn.lakp_masks(params, cfg, 0.5, 0.75)
        masked = cn.apply_masks(params, masks)
        compact_p, compact_cfg, idx = cn.compact(masked, cfg, masks)
        imgs = jax.random.uniform(jax.random.key(1), (2, 28, 28, 1))
        l_masked, _ = cn.forward(masked, cfg, imgs)
        l_compact, _ = cn.forward(compact_p, compact_cfg, imgs)
        np.testing.assert_allclose(np.asarray(l_masked),
                                   np.asarray(l_compact), atol=1e-4)

    def test_capsule_elimination(self):
        """The Fig. 6 "interconnection study": capsule types are eliminated
        down to type_keep (paper: 32 -> 7 on MNIST -> 252 capsules)."""
        cfg = tiny_cfg()
        params = cn.init(cfg, jax.random.key(0))
        masks = cn.lakp_masks(params, cfg, 0.0, 0.5, type_keep=2)
        _, compact_cfg, idx = cn.compact(params, cfg, masks)
        assert compact_cfg.caps_types == 2
        assert compact_cfg.n_primary_caps == 2 * cfg.caps_out_hw ** 2

    def test_elimination_preserves_forward_equivalence(self):
        cfg = tiny_cfg()
        params = cn.init(cfg, jax.random.key(0))
        masks = cn.lakp_masks(params, cfg, 0.3, 0.5, type_keep=3)
        masked = cn.apply_masks(params, masks)
        compact_p, compact_cfg, _ = cn.compact(masked, cfg, masks)
        imgs = jax.random.uniform(jax.random.key(1), (2, 28, 28, 1))
        l_m, _ = cn.forward(masked, cfg, imgs)
        l_c, _ = cn.forward(compact_p, compact_cfg, imgs)
        np.testing.assert_allclose(np.asarray(l_m), np.asarray(l_c),
                                   atol=1e-4)

    def test_pipeline_compression_accounting(self):
        cfg = tiny_cfg()
        params = cn.init(cfg, jax.random.key(0))
        pipe = FastCapsPipeline(cfg, params=params)
        pipe.prune(0.8, 0.8, method="lakp").compact()
        assert 0.75 < pipe.compression < 0.85
        assert pipe.index_overhead_frac < 0.02
        n_dense = cn.param_count(params)
        n_compact = cn.param_count(pipe.params)
        assert n_compact < n_dense

    def test_kp_vs_lakp_differ(self):
        """The two scoring methods pick different kernels in general."""
        cfg = tiny_cfg()
        params = cn.init(cfg, jax.random.key(42))
        m_l = cn.lakp_masks(params, cfg, 0.5, 0.5, method="lakp")
        m_k = cn.lakp_masks(params, cfg, 0.5, 0.5, method="kp")
        same = all(
            bool(jnp.array_equal(a, b)) for a, b in zip(m_l, m_k))
        assert not same

    def test_mask_gradients(self):
        cfg = tiny_cfg()
        params = cn.init(cfg, jax.random.key(0))
        masks = cn.lakp_masks(params, cfg, 0.5, 0.5)
        grads = jax.tree.map(jnp.ones_like, params)
        mg = pr.mask_gradients(grads, masks)
        w1 = np.asarray(mg["conv1"]["w"])
        m1 = np.asarray(masks[0])
        assert (w1[m1 == 0] == 0).all()
        assert (w1[m1 == 1] == 1).all()


class TestRoutingWeightReduction:
    def test_routing_params_shrink(self):
        """Paper: each capsule carries n_classes*digit_dim*caps_dim routing
        params; eliminating capsule types shrinks W proportionally."""
        cfg = tiny_cfg()
        params = cn.init(cfg, jax.random.key(0))
        masks = cn.lakp_masks(params, cfg, 0.0, 0.95, type_keep=2)
        c_params, c_cfg, _ = cn.compact(params, cfg, masks)
        before = params["digit"]["w"].size
        after = c_params["digit"]["w"].size
        assert after * cfg.caps_types == before * c_cfg.caps_types
        assert after < before
