"""Sharding policy unit tests + a small-mesh dry-run in a subprocess."""

import json
import os
import subprocess
import sys

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.parallel import sharding as sh


MESH_2D = {"data": 16, "model": 16}
MESH_3D = {"pod": 2, "data": 16, "model": 16}


class TestShapeAwareSpec:
    def test_basic_mapping(self):
        spec = sh.shape_aware_spec(("batch", "seq", None),
                                   (256, 4096, 1024), sh.DEFAULT_RULES,
                                   MESH_2D)
        assert spec == P("data")

    def test_multi_axis_batch(self):
        spec = sh.shape_aware_spec(("batch", None), (256, 8),
                                   sh.DEFAULT_RULES, MESH_3D)
        assert spec == P(("pod", "data"))

    def test_indivisible_dim_replicates(self):
        # batch=1 (long_500k): data freed, claimed by kv_seq
        spec = sh.shape_aware_spec(
            ("layers", "batch", "kv_seq", "kv_heads", "kv_head_dim"),
            (6, 1, 524288, 32, 64), sh.DEFAULT_RULES, MESH_2D)
        assert spec == P(None, None, "data", "model")

    def test_gqa_kv_heads_fallback_to_head_dim(self):
        # kv_heads=8 < model=16 -> kv_head_dim claims model
        spec = sh.shape_aware_spec(
            ("layers", "batch", "kv_seq", "kv_heads", "kv_head_dim"),
            (88, 128, 32768, 8, 128), sh.DEFAULT_RULES, MESH_2D)
        assert spec == P(None, "data", None, None, "model")

    def test_axis_claimed_once(self):
        spec = sh.shape_aware_spec(("mlp", "heads"), (64, 64),
                                   sh.DEFAULT_RULES, MESH_2D)
        # both map to model; first dim wins
        assert spec == P("model")

    def test_partial_axis_tuple(self):
        # batch=48: divisible by pod(2) but not pod*data(32) -> longest
        # valid prefix ("pod",) survives (partial sharding beats none)
        spec = sh.shape_aware_spec(("batch",), (48,), sh.DEFAULT_RULES,
                                   MESH_3D)
        assert spec == P("pod")

    def test_hubert_vocab_replicates(self):
        spec = sh.shape_aware_spec(("vocab", "embed"), (504, 1280),
                                   sh.DEFAULT_RULES, MESH_2D)
        assert spec == P(None, "data")

    def test_xlstm_no_tp_policy(self):
        """§Perf H-A1: small-d_model archs run pure DP + FSDP — no model-
        axis sharding on weights; batch claims (data, model)."""
        rules = sh.rules_for_arch("xlstm-1.3b")
        spec = sh.shape_aware_spec(
            ("mlstm_inner", "heads", "head_dim_v"), (4096, 4, 1024),
            rules, MESH_2D)
        assert spec == P()
        # train batch claims both axes (256 = 16 x 16)
        spec = sh.shape_aware_spec(("batch", "seq", None),
                                   (256, 4096, 2048), rules, MESH_2D)
        assert spec == P(("data", "model"))
        # weights stay FSDP over data
        spec = sh.shape_aware_spec(("embed", "mlstm_up"), (2048, 8192),
                                   rules, MESH_2D)
        assert spec == P("data")

    def test_deepseek_keeps_ep(self):
        rules = sh.rules_for_arch("deepseek-moe-16b")
        spec = sh.shape_aware_spec(("expert", "embed", "expert_mlp"),
                                   (64, 2048, 1408), rules, MESH_2D)
        assert spec == P("model", "data")


class TestShardingsFor:
    def test_tree_with_nones(self):
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        structs = {"w": jax.ShapeDtypeStruct((16, 8), "float32"),
                   "b": jax.ShapeDtypeStruct((8,), "float32")}
        axes = {"w": ("embed", "mlp"), "b": None}
        out = sh.shardings_for(structs, axes, sh.DEFAULT_RULES, mesh)
        # mesh axes of size 1 still map (harmless no-op placement)
        assert out["w"].spec == P("data", "model")
        assert out["b"].spec == P()


@pytest.mark.slow
def test_small_mesh_dryrun_subprocess(tmp_path):
    """End-to-end dry-run on an 8-virtual-device mesh in a subprocess
    (the 512-device production dry-run is exercised by launch/dryrun.py)."""
    script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from repro import configs as cfg_lib
from repro.launch.mesh import make_mesh, mesh_context
from repro.models import lm
from repro.optim import adamw
from repro.parallel import sharding as shard_lib

cfg = cfg_lib.reduced(cfg_lib.get_config("qwen3-1.7b"))
mesh = make_mesh((2, 4), ("data", "model"))
rules = shard_lib.rules_for_arch(cfg.arch_id)
params = lm.param_structs(cfg)
opt = jax.eval_shape(adamw.init_state, params)
batch = {"tokens": jax.ShapeDtypeStruct((8, 32), jnp.int32),
         "labels": jax.ShapeDtypeStruct((8, 32), jnp.int32)}
p_sh = shard_lib.shardings_for(params, lm.specs(cfg), rules, mesh)
o_sh = {"m": p_sh, "v": p_sh,
        "step": shard_lib.shardings_for(opt["step"], None, rules, mesh)}
b_sh = shard_lib.shardings_for(
    batch, {"tokens": ("batch", "seq"), "labels": ("batch", "seq")},
    rules, mesh)
ocfg = adamw.AdamWConfig()

def step(p, o, b):
    (l, m), g = jax.value_and_grad(
        lambda p_: lm.loss_fn(p_, cfg, b), has_aux=True)(p)
    return adamw.apply_updates(p, g, o, ocfg)[:2]

with mesh_context(mesh):
    compiled = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh),
                       out_shardings=(p_sh, o_sh)).lower(
        params, opt, batch).compile()
ca = compiled.cost_analysis()
if isinstance(ca, (list, tuple)):     # older jax: one dict per computation
    ca = ca[0]
assert ca["flops"] > 0
print("SUBPROCESS_DRYRUN_OK", ca["flops"])
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, timeout=600)
    assert "SUBPROCESS_DRYRUN_OK" in r.stdout, r.stdout + r.stderr
