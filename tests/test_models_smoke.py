"""Per-architecture smoke tests (deliverable f): instantiate the REDUCED
config of every assigned arch, run one forward/train step on CPU, assert
output shapes + no NaNs.  The FULL configs are exercised only via the
dry-run (ShapeDtypeStruct, no allocation)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs as cfg_lib
from repro.models import lm
from repro.optim import adamw

ARCHS = cfg_lib.ASSIGNED_ARCHS


def make_batch(cfg, b=2, s=16, seed=0):
    key = jax.random.key(seed)
    if cfg.family == "audio":
        return {
            "features": jax.random.normal(key, (b, s, cfg.d_model)),
            "labels": jax.random.randint(key, (b, s), 0, cfg.vocab),
        }
    batch = {
        "tokens": jax.random.randint(key, (b, s), 0, cfg.vocab),
        "labels": jax.random.randint(key, (b, s), 0, cfg.vocab),
    }
    if cfg.family == "vlm":
        batch["image_features"] = jax.random.normal(
            key, (b, cfg.n_image_tokens, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCHS)
class TestReducedSmoke:
    def test_forward_and_train_step(self, arch):
        cfg = cfg_lib.reduced(cfg_lib.get_config(arch))
        params = lm.init(cfg, jax.random.key(0))
        batch = make_batch(cfg)
        loss, metrics = lm.loss_fn(params, cfg, batch)
        assert loss.shape == ()
        assert bool(jnp.isfinite(loss)), f"{arch}: non-finite loss"

        opt = adamw.init_state(params)
        ocfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)

        @jax.jit
        def step(p, o, b):
            (l, m), g = jax.value_and_grad(
                lambda p_: lm.loss_fn(p_, cfg, b), has_aux=True)(p)
            p2, o2, om = adamw.apply_updates(p, g, o, ocfg)
            return p2, o2, l

        p2, o2, l = step(params, opt, batch)
        assert bool(jnp.isfinite(l))
        # params changed and stayed finite
        changed = any(
            not np.array_equal(np.asarray(a), np.asarray(b_))
            for a, b_ in zip(jax.tree.leaves(params), jax.tree.leaves(p2)))
        assert changed, f"{arch}: step did not update params"
        assert all(bool(jnp.all(jnp.isfinite(x)))
                   for x in jax.tree.leaves(p2))


_DECODE_ARCHS = [a for a in ARCHS if a != "hubert-xlarge"]


@pytest.mark.parametrize("arch", _DECODE_ARCHS)
def test_decode_matches_full_forward(arch):
    """Incremental decode == full forward (catches every cache bug).

    Prefill on k tokens then decode the rest one-by-one; logits at each
    decoded position must match the full-sequence forward logits."""
    cfg = dataclasses.replace(
        cfg_lib.reduced(cfg_lib.get_config(arch)),
        compute_dtype="float32")
    params = lm.init(cfg, jax.random.key(0))
    b, s, k = 2, 12, 6
    toks = jax.random.randint(jax.random.key(1), (b, s), 0, cfg.vocab)
    batch = {"tokens": toks}
    if cfg.family == "vlm":
        img = jax.random.normal(jax.random.key(2),
                                (b, cfg.n_image_tokens, cfg.d_model))
        batch["image_features"] = img

    # full forward logits at every position
    from repro.models import common
    x, _, _ = lm.forward(params, cfg, batch)
    full_logits = common.unembed(params["embed"], cfg, x)     # (B,S,V)

    caches = lm.make_caches(cfg, b, s + 4)
    prefill_batch = dict(batch)
    prefill_batch["tokens"] = toks[:, :k]
    # tolerances scale with logit magnitude (tied-embedding archs produce
    # O(70) logits); real cache bugs produce O(1) divergence.
    scale = max(float(jnp.max(jnp.abs(full_logits))), 1.0)
    atol = 2e-4 * scale
    logits, caches = lm.prefill_step(params, cfg, prefill_batch, caches)
    np.testing.assert_allclose(np.asarray(logits),
                               np.asarray(full_logits[:, k - 1]),
                               atol=atol, rtol=1e-3)
    for pos in range(k, s):
        dbatch = {"tokens": toks[:, pos:pos + 1], "pos": jnp.int32(pos)}
        logits, caches = lm.decode_step(params, cfg, dbatch, caches)
        np.testing.assert_allclose(
            np.asarray(logits), np.asarray(full_logits[:, pos]),
            atol=atol, rtol=1e-3,
            err_msg=f"{arch}: decode diverges at pos {pos}")


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_exactness(arch):
    """The registered full config matches the published spec table."""
    spec = {
        "zamba2-1.2b": (38, 2048, 32, 32, 8192, 32000),
        "xlstm-1.3b": (48, 2048, 4, 4, 0, 50304),
        "mistral-large-123b": (88, 12288, 96, 8, 28672, 32768),
        "llama3.2-1b": (16, 2048, 32, 8, 8192, 128256),
        "qwen3-1.7b": (28, 2048, 16, 8, 6144, 151936),
        "qwen1.5-110b": (80, 8192, 64, 8, 49152, 152064),
        "deepseek-moe-16b": (28, 2048, 16, 16, 1408, 102400),
        "dbrx-132b": (40, 6144, 48, 8, 10752, 100352),
        "hubert-xlarge": (48, 1280, 16, 16, 5120, 504),
        "llama-3.2-vision-90b": (100, 8192, 64, 8, 28672, 128256),
    }[arch]
    cfg = cfg_lib.get_config(arch)
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
           cfg.d_ff, cfg.vocab)
    assert got == spec, f"{arch}: {got} != {spec}"


def test_moe_details():
    c = cfg_lib.get_config("deepseek-moe-16b")
    assert c.moe.n_experts == 64 and c.moe.top_k == 6 and c.moe.n_shared == 2
    c = cfg_lib.get_config("dbrx-132b")
    assert c.moe.n_experts == 16 and c.moe.top_k == 4
    assert cfg_lib.get_config("zamba2-1.2b").ssm.d_state == 64
    assert cfg_lib.get_config("qwen3-1.7b").qk_norm
    assert cfg_lib.get_config("qwen1.5-110b").qkv_bias
    assert not cfg_lib.get_config("hubert-xlarge").causal


def test_cell_matrix():
    """40 assigned cells; documented skips only."""
    assert len(cfg_lib.CELLS) == 40
    runnable = cfg_lib.runnable_cells()
    skipped = [(a, s) for (a, s) in cfg_lib.CELLS
               if cfg_lib.cell_status(a, s)]
    assert len(runnable) + len(skipped) == 40
    # 7 full-attention archs skip long_500k; hubert skips both decode shapes
    assert len(skipped) == 9
    assert ("zamba2-1.2b", "long_500k") in runnable
    assert ("xlstm-1.3b", "long_500k") in runnable
    assert ("hubert-xlarge", "decode_32k") in skipped
