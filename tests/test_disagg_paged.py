"""Disaggregated serving over the paged KV cache (``repro.serving.pages``).

The paged handoff is a page-table splice, not a row copy: prefill
exports page-granular payloads, the front-end pins whatever pages the
*target* pool already holds (content-addressed dedup — only missing
pages travel), and decode imports the misses and binds its own slot
table.  The contract here:

* bit-exactness: paged disaggregated serving matches per-request
  ``generate()`` for every pageable family, across all three
  :class:`Transport` kinds, quantized pages within the documented
  tolerance (token-identical on this fixture);
* dedup: a second request sharing a system prompt moves only its tail
  pages (``handoff_pages_moved`` / ``handoff_pages_dedup`` counters);
* validation: a decode engine refuses paged/dense mismatches and any
  page-geometry (page_size / quantized) disagreement — hashes and
  payloads from a different layout are never interchangeable;
* the forced-2-device subprocess acceptance run: paged disagg with a
  sharded decode mesh stays exact (the CI serving-conformance lane).
"""

import os
import subprocess
import sys

import jax
import pytest

from repro.models import lm
from repro.models.common import LMConfig, MoEConfig
from repro.serving import (CacheHandoff, DecodeEngine, HandoffRequest,
                           PrefillEngine, Request, ServeEngine,
                           disaggregated_lm_engine,
                           multihost_disaggregated_lm_engine)

TRANSPORTS = ["in_process", "host_staged", "device_to_device"]
PROMPTS = [[1, 2, 3], [5, 6, 7, 8, 9, 10, 11], [2, 4]]
PAGE = 8


def tiny(family="dense", **kw):
    base = dict(arch_id="tiny-" + family, family=family, n_layers=2,
                d_model=32, n_heads=4, n_kv_heads=2, d_ff=64, vocab=64,
                remat=False, compute_dtype="float32",
                param_dtype="float32")
    base.update(kw)
    return LMConfig(**base)


def cfg_for(family):
    if family == "dense":
        return tiny()
    if family == "vlm":
        return tiny("vlm", n_layers=3, cross_attn_every=2,
                    n_image_tokens=8)
    if family == "moe":
        return tiny("moe", moe=MoEConfig(n_experts=4, top_k=2,
                                         d_expert=32))
    raise ValueError(family)


class TestPagedDisaggExactness:
    @pytest.mark.parametrize("family", ["dense", "vlm", "moe"])
    @pytest.mark.parametrize("transport", TRANSPORTS)
    def test_matches_generate_under_every_transport(self, family,
                                                    transport):
        cfg = cfg_for(family)
        params = lm.init(cfg, jax.random.key(0))
        ref = ServeEngine(cfg, params, n_slots=2, max_len=32)
        eng = disaggregated_lm_engine(cfg, params, n_slots=2, max_len=32,
                                      n_decode=2, transport=transport,
                                      page_size=PAGE)
        comps = {c.rid: c for c in eng.serve(
            [Request(prompt=p, max_new_tokens=4, rid=i)
             for i, p in enumerate(PROMPTS)])}
        for i, p in enumerate(PROMPTS):
            want = ref.generate([p], max_new_tokens=4)[0]
            assert comps[i].tokens == want, (family, transport, i)
        st = eng.stats().pages
        assert st.get("handoff_pages_moved", 0) > 0

    def test_multihost_paged_exact(self):
        cfg = tiny()
        params = lm.init(cfg, jax.random.key(0))
        ref = ServeEngine(cfg, params, n_slots=2, max_len=32)
        eng = multihost_disaggregated_lm_engine(
            cfg, params, n_slots=2, max_len=32, n_decode=1,
            page_size=PAGE)
        comps = {c.rid: c for c in eng.serve(
            [Request(prompt=p, max_new_tokens=4, rid=i)
             for i, p in enumerate(PROMPTS)])}
        for i, p in enumerate(PROMPTS):
            assert comps[i].tokens == ref.generate(
                [p], max_new_tokens=4)[0], i

    def test_quantized_paged_within_tolerance(self):
        """Quantized page payloads travel as int8 + per-row scales and
        decode through the dequantizing attention path: greedy tokens
        match the unquantized reference on this fixture (the documented
        tolerance — docs/serving.md)."""
        cfg = tiny()
        params = lm.init(cfg, jax.random.key(0))
        ref = ServeEngine(cfg, params, n_slots=2, max_len=32)
        eng = disaggregated_lm_engine(cfg, params, n_slots=2, max_len=32,
                                      n_decode=2, page_size=PAGE,
                                      quantize_pages=True)
        comps = {c.rid: c for c in eng.serve(
            [Request(prompt=p, max_new_tokens=4, rid=i)
             for i, p in enumerate(PROMPTS)])}
        for i, p in enumerate(PROMPTS):
            assert comps[i].tokens == ref.generate(
                [p], max_new_tokens=4)[0], i


class TestHandoffPageDedup:
    def test_shared_prefix_pages_do_not_travel_twice(self):
        """Two sequential requests share a 16-token (2-page) system
        prompt.  The first handoff moves every page; by the second, the
        target pool already caches the shared pages (registered on
        import), so the front-end pins them and ships only the tail."""
        cfg = tiny()
        params = lm.init(cfg, jax.random.key(0))
        ref = ServeEngine(cfg, params, n_slots=2, max_len=64)
        shared = list(range(1, 17))
        eng = disaggregated_lm_engine(cfg, params, n_slots=2, max_len=64,
                                      n_decode=1, page_size=PAGE)
        for i, t in enumerate([20, 21]):
            [c] = eng.serve([Request(prompt=shared + [t],
                                     max_new_tokens=4, rid=i)])
            assert c.tokens == ref.generate([shared + [t]],
                                            max_new_tokens=4)[0], i
        st = eng.stats().pages
        assert st["handoff_pages_dedup"] == 2
        # first handoff moved its 3 pages; the second only the tail page
        assert st["handoff_pages_moved"] == 4


def _paged_handoff(cfg, params, prompt=(1, 2, 3), max_new=4, **pool_kw):
    pre = PrefillEngine(cfg, params, n_slots=2, max_len=32,
                        page_size=PAGE, **pool_kw)
    pre.submit(Request(prompt=list(prompt), max_new_tokens=max_new))
    (h,) = pre.run_until_idle()
    assert isinstance(h, CacheHandoff) and h.paged
    return h


class TestPagedHandoffValidation:
    """A decode engine must refuse a paged handoff whose page geometry
    it cannot decode exactly — no silent garbage decode."""

    def setup_method(self, method):
        self.cfg = cfg_for("dense")
        self.params = lm.init(self.cfg, jax.random.key(0))

    def test_paged_handoff_to_dense_engine_rejected(self):
        h = _paged_handoff(self.cfg, self.params)
        dec = DecodeEngine(self.cfg, self.params, n_slots=2, max_len=32)
        with pytest.raises(ValueError, match="paged"):
            dec.submit(HandoffRequest(handoff=h))

    def test_dense_handoff_to_paged_engine_rejected(self):
        pre = PrefillEngine(self.cfg, self.params, n_slots=2, max_len=32)
        pre.submit(Request(prompt=[1, 2, 3], max_new_tokens=4))
        (h,) = pre.run_until_idle()
        dec = DecodeEngine(self.cfg, self.params, n_slots=2, max_len=32,
                           page_size=PAGE)
        with pytest.raises(ValueError, match="paged"):
            dec.submit(HandoffRequest(handoff=h))

    def test_page_size_mismatch_rejected(self):
        h = _paged_handoff(self.cfg, self.params)
        dec = DecodeEngine(self.cfg, self.params, n_slots=2, max_len=32,
                           page_size=16)
        with pytest.raises(ValueError, match="page_size"):
            dec.submit(HandoffRequest(handoff=h))

    def test_quantization_mismatch_rejected(self):
        h = _paged_handoff(self.cfg, self.params)
        dec = DecodeEngine(self.cfg, self.params, n_slots=2, max_len=32,
                           page_size=PAGE, quantize_pages=True)
        with pytest.raises(ValueError, match="quantized"):
            dec.submit(HandoffRequest(handoff=h))

    def test_rejection_leaves_engine_clean(self):
        good = _paged_handoff(self.cfg, self.params)
        bad = _paged_handoff(self.cfg, self.params, prompt=(7, 8))
        bad.page_size = 16                # tamper: wrong geometry
        dec = DecodeEngine(self.cfg, self.params, n_slots=2, max_len=32,
                           page_size=PAGE)
        with pytest.raises(ValueError):
            dec.submit(HandoffRequest(handoff=bad))
        assert dec.n_pending == 0
        dec.submit(HandoffRequest(handoff=good, rid=good.rid))
        (comp,) = dec.run_until_idle()
        ref = ServeEngine(self.cfg, self.params, n_slots=2, max_len=32)
        assert comp.tokens == ref.generate([[1, 2, 3]],
                                           max_new_tokens=4)[0]


def test_paged_disagg_sharded_decode_on_2device_cpu_mesh():
    """Acceptance regression on a forced 2-device host: paged
    disaggregated serving with the decode pool's page axis sharded by a
    ShardedScheduler mesh stays bit-exact (subprocess: the test process
    is pinned to one device)."""
    script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import jax
from repro.models import lm
from repro.models.common import LMConfig
from repro.launch.mesh import make_mesh
from repro.serving import (Request, ServeEngine, ShardedScheduler,
                           disaggregated_lm_engine)

cfg = LMConfig(arch_id="tiny-dense", family="dense", n_layers=2,
               d_model=32, n_heads=4, n_kv_heads=2, d_ff=64, vocab=64,
               remat=False, compute_dtype="float32",
               param_dtype="float32")
params = lm.init(cfg, jax.random.key(0))
PROMPTS = [[1, 2, 3], [5, 6, 7, 8, 9, 10, 11], [2, 4]]
sched = ShardedScheduler(make_mesh((2,), ("data",)))
eng = disaggregated_lm_engine(cfg, params, n_slots=2, max_len=32,
                              n_decode=1, decode_schedulers=[sched],
                              page_size=8)
ref = ServeEngine(cfg, params, n_slots=2, max_len=32)
comps = {c.rid: c for c in eng.serve(
    [Request(prompt=p, max_new_tokens=3, rid=i)
     for i, p in enumerate(PROMPTS)])}
for i, p in enumerate(PROMPTS):
    want = ref.generate([p], max_new_tokens=3)[0]
    assert comps[i].tokens == want, (i, comps[i].tokens, want)
assert eng.stats().pages.get("handoff_pages_moved", 0) > 0
print("PAGED_DISAGG_SHARDED_OK")
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..",
                                     "src")
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, timeout=600)
    assert "PAGED_DISAGG_SHARDED_OK" in r.stdout, r.stdout + r.stderr


if __name__ == "__main__":
    sys.exit(pytest.main([__file__, "-q"]))
