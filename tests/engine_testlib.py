"""Workload-free EngineCore doubles shared by the scheduler-conformance
and property-based serving suites.

``ToyEngine`` is a pure-python :class:`repro.serving.EngineCore`: each
task counts down ``steps`` ticks and emits one stream item per step.  No
model compiles, so engine/scheduler contracts can be exercised
exhaustively (hundreds of randomized op sequences) in milliseconds; the
instrumentation records exactly the quantities the contracts bound
(slot high-water marks, admission order, compiled batch sizes).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Tuple

from repro.serving.core import EngineCore, SlotTask


@dataclasses.dataclass
class ToyRequest:
    """``n_tasks`` parallel slot tasks, each needing ``steps`` ticks."""

    n_tasks: int = 1
    steps: int = 1
    rid: Optional[int] = None
    stream: bool = False
    priority: int = 0                 # 0 = most urgent


@dataclasses.dataclass
class ToyCompletion:
    rid: int
    items: int                        # tasks served
    latency_s: float


class ToyEngine(EngineCore):
    """Counting engine: `_step` decrements each active task's countdown.

    Instrumentation (never resets):

      * ``max_occupied`` — high-water mark of slots simultaneously active;
      * ``max_batch`` — largest compiled batch any tick requested;
      * ``admitted_order`` — rids in slot-admission order (one entry per
        task), for FIFO/starvation assertions.
    """

    def __init__(self, capacity: int = 4, scheduler=None, clock=None):
        super().__init__(capacity=capacity, scheduler=scheduler,
                         clock=clock or time.perf_counter)
        self.max_occupied = 0
        self.max_batch = 0
        self.admitted_order: List[int] = []

    # -- workload hooks ----------------------------------------------------

    def _expand(self, request: ToyRequest
                ) -> Tuple[List[SlotTask], Dict[str, Any]]:
        if request.n_tasks < 0 or request.steps < 1:
            raise ValueError("bad toy request")
        return [SlotTask(payload=request.steps)
                for _ in range(request.n_tasks)], {}

    def _admit(self, new: List[Tuple[int, SlotTask]]) -> Tuple[List[int], int]:
        for _, task in new:
            # setdefault keeps a preempted task's remaining countdown:
            # the toy's whole resumable state lives in task.state, so
            # the default (no-op) _evict hook is already lossless here
            task.state.setdefault("left", task.payload)
            self.admitted_order.append(task.rid)
        return [], 0

    def _step(self, active: List[Tuple[int, SlotTask]], n_batch: int
              ) -> Tuple[List[int], int]:
        self.max_occupied = max(self.max_occupied, len(active))
        self.max_batch = max(self.max_batch, n_batch)
        finished = []
        for s, task in active:
            task.state["left"] -= 1
            self._emit(task.rid, ("step", task.state["left"]))
            if task.state["left"] <= 0:
                finished.append(s)
        return finished, len(active)

    def _request_class(self, request: ToyRequest) -> str:
        return f"toy/t{request.n_tasks}"

    def _finalize(self, entry, latency_s: float) -> ToyCompletion:
        return ToyCompletion(rid=entry.request.rid, items=len(entry.tasks),
                             latency_s=latency_s)
