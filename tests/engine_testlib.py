"""Workload-free EngineCore doubles shared by the scheduler-conformance
and property-based serving suites.

``ToyEngine`` is a pure-python :class:`repro.serving.EngineCore`: each
task counts down ``steps`` ticks and emits one stream item per step.  No
model compiles, so engine/scheduler contracts can be exercised
exhaustively (hundreds of randomized op sequences) in milliseconds; the
instrumentation records exactly the quantities the contracts bound
(slot high-water marks, admission order, compiled batch sizes).

``ToyPrefillEngine`` / ``ToyDecodeEngine`` are the disaggregated pair of
the same idea: prefill completes every request with a
:class:`repro.serving.CacheHandoff` whose rows *encode the handoff
identity* (:func:`toy_rows`), and decode verifies them bit-exactly on
admission — so any :class:`repro.serving.Transport` that corrupts,
drops, or reorders a leaf fails loudly without compiling a model.
``FlakyTransport`` injects scripted delays and failures into that path.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.serving.core import EngineCore, SlotTask
from repro.serving.disagg import CacheHandoff, HandoffRequest
from repro.serving.transport import InProcessTransport, TransportError


@dataclasses.dataclass
class ToyRequest:
    """``n_tasks`` parallel slot tasks, each needing ``steps`` ticks."""

    n_tasks: int = 1
    steps: int = 1
    rid: Optional[int] = None
    stream: bool = False
    priority: int = 0                 # 0 = most urgent


@dataclasses.dataclass
class ToyCompletion:
    rid: int
    items: int                        # tasks served
    latency_s: float


class ToyEngine(EngineCore):
    """Counting engine: `_step` decrements each active task's countdown.

    Instrumentation (never resets):

      * ``max_occupied`` — high-water mark of slots simultaneously active;
      * ``max_batch`` — largest compiled batch any tick requested;
      * ``admitted_order`` — rids in slot-admission order (one entry per
        task), for FIFO/starvation assertions.
    """

    def __init__(self, capacity: int = 4, scheduler=None, clock=None):
        super().__init__(capacity=capacity, scheduler=scheduler,
                         clock=clock or time.perf_counter)
        self.max_occupied = 0
        self.max_batch = 0
        self.admitted_order: List[int] = []

    # -- workload hooks ----------------------------------------------------

    def _expand(self, request: ToyRequest
                ) -> Tuple[List[SlotTask], Dict[str, Any]]:
        if request.n_tasks < 0 or request.steps < 1:
            raise ValueError("bad toy request")
        return [SlotTask(payload=request.steps)
                for _ in range(request.n_tasks)], {}

    def _admit(self, new: List[Tuple[int, SlotTask]]) -> Tuple[List[int], int]:
        for _, task in new:
            # setdefault keeps a preempted task's remaining countdown:
            # the toy's whole resumable state lives in task.state, so
            # the default (no-op) _evict hook is already lossless here
            task.state.setdefault("left", task.payload)
            self.admitted_order.append(task.rid)
        return [], 0

    def _step(self, active: List[Tuple[int, SlotTask]], n_batch: int
              ) -> Tuple[List[int], int]:
        self.max_occupied = max(self.max_occupied, len(active))
        self.max_batch = max(self.max_batch, n_batch)
        finished = []
        for s, task in active:
            task.state["left"] -= 1
            self._emit(task.rid, ("step", task.state["left"]))
            if task.state["left"] <= 0:
                finished.append(s)
        return finished, len(active)

    def _request_class(self, request: ToyRequest) -> str:
        return f"toy/t{request.n_tasks}"

    def _finalize(self, entry, latency_s: float) -> ToyCompletion:
        return ToyCompletion(rid=entry.request.rid, items=len(entry.tasks),
                             latency_s=latency_s)


def toy_rows(rid: int, steps: int) -> Dict[str, np.ndarray]:
    """Deterministic cache-row payload derived from the handoff identity
    (mixed shapes/dtypes, like a real cache pytree), so the decode side
    can verify delivery exactness without any shared state."""
    return {"state": np.full((2, 3), float(rid * 1000 + steps), np.float32),
            "tag": np.asarray([rid, steps], np.int32)}


class ToyPrefillEngine(ToyEngine):
    """Prefill half of a workload-free disaggregated pair.

    Mirrors :class:`repro.serving.PrefillEngine`: slots live one
    admission (the countdown is pinned to a single tick), the engine
    never streams, and every request *completes at prefill* with a
    :class:`repro.serving.CacheHandoff` — ``family="toy"``, ``left`` set
    to the request's ``steps`` (the decode-side countdown), and rows
    from :func:`toy_rows`.  Zero-task requests complete with the plain
    identity :class:`ToyCompletion`, exactly like ``max_new_tokens <= 0``
    on the real engine.
    """

    def _wants_stream(self, request: ToyRequest) -> bool:
        return False                  # streaming starts on the decode side

    def _expand(self, request: ToyRequest
                ) -> Tuple[List[SlotTask], Dict[str, Any]]:
        tasks, extra = super()._expand(request)
        # a handoff is per-request: one slot task, one prefill tick
        return [SlotTask(payload=1) for _ in tasks[:1]], extra

    def _finalize(self, entry, latency_s: float):
        if not entry.tasks:           # zero-task: identity completion
            return super()._finalize(entry, latency_s)
        req = entry.request
        return CacheHandoff(
            rid=req.rid, request=req, family="toy", arch_id="toy",
            max_len=0, rows=toy_rows(req.rid, req.steps), tok=0, pos=0,
            out=[], left=int(req.steps), stream=bool(req.stream),
            cls=self._request_class(req))


class ToyDecodeEngine(ToyEngine):
    """Decode half of the pair: admits :class:`HandoffRequest`\\ s whose
    rows it *verifies bit-exactly* against :func:`toy_rows` — tree keys,
    shapes, dtypes, values — raising ``ValueError`` on any mismatch (the
    same typed-rejection contract as ``DecodeEngine.validate_handoff``,
    which the front-end propagates as a mis-built pair).  A verified
    handoff counts down ``left`` ticks streaming one item per step."""

    def _expand(self, request: Any
                ) -> Tuple[List[SlotTask], Dict[str, Any]]:
        if not isinstance(request, HandoffRequest):
            return super()._expand(request)
        h = request.handoff
        if h.family != "toy":
            raise ValueError(
                f"toy decode engine got family {h.family!r} handoff")
        if not h.done:
            want = toy_rows(h.rid, h.left)
            got = h.rows if isinstance(h.rows, dict) else {}
            for key, w in want.items():
                g = np.asarray(got.get(key))
                if (g.shape != w.shape or g.dtype != w.dtype
                        or not np.array_equal(g, w)):
                    raise ValueError(
                        f"handoff rid={h.rid}: rows leaf {key!r} corrupted "
                        f"in transit ({g.dtype}{g.shape} vs "
                        f"{w.dtype}{w.shape})")
        return [SlotTask(payload=max(int(h.left), 1))], {}

    def _request_class(self, request: Any) -> str:
        if isinstance(request, HandoffRequest):
            return request.handoff.cls
        return super()._request_class(request)


class FlakyTransport(InProcessTransport):
    """In-process delivery with scripted synthetic delays and injected
    failures — the fault/latency harness for transport property and
    failover tests.

    ``fail_on`` holds 0-based delivery-attempt indices that raise
    :class:`repro.serving.TransportError` (the front-end then marks the
    target engine dead and fails over); ``delays`` cycles into the
    recorded ``pass`` leg as *synthetic* seconds — recorded, never
    slept, so a property suite can sweep wide delay distributions for
    free while the histograms still see them."""

    name = "flaky"
    LEGS = ("pass",)

    def __init__(self, delays=(), fail_on=(), **kwargs):
        super().__init__(**kwargs)
        self.delays = list(delays)
        self.fail_on = set(fail_on)
        self.calls = 0                              # guarded-by: _lock

    def _move(self, rows: Any, target: Any):
        with self._lock:
            i = self.calls
            self.calls += 1
        if i in self.fail_on:
            raise TransportError(f"injected failure on delivery {i}")
        delay = float(self.delays[i % len(self.delays)]) if self.delays \
            else 0.0
        return rows, {"pass": delay}
